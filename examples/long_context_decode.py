"""Long-context sparse decode: the union-of-TopK distributed attention
used by the long_500k dry-run cell, demonstrated on a host mesh.

Shards the KV sequence across all local devices (set
``XLA_FLAGS=--xla_force_host_platform_device_count=8`` for a multi-device
run; works on 1 device too), decodes with per-shard TopK + LSE merge, and
checks the result against the single-device sparse reference.

  XLA_FLAGS=--xla_force_host_platform_device_count=8 \
      PYTHONPATH=src python examples/long_context_decode.py
"""

import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.models import sparse_attention


def main():
    n_dev = len(jax.devices())
    mesh = jax.make_mesh((n_dev,), ("model",),
                         axis_types=(jax.sharding.AxisType.Auto,))
    b, s, kv, g, d, page = 1, 2048, 2, 4, 64, 16
    k_pages = 16
    rng = np.random.default_rng(0)
    q = jnp.asarray(rng.normal(size=(b, kv, g, d)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(b, s, kv, d)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(b, s, kv, d)), jnp.float32)
    kpage = jnp.asarray(k.reshape(b, s // page, page, kv, d).mean(2))
    pos = jnp.asarray(s - 1, jnp.int32)

    n_pages = s // page
    with jax.set_mesh(mesh):
        kd = jax.device_put(k, NamedSharding(mesh, P(None, "model")))
        vd = jax.device_put(v, NamedSharding(mesh, P(None, "model")))
        kpd = jax.device_put(kpage, NamedSharding(mesh, P(None, "model")))
        # (1) full coverage: per-shard selection keeps everything, so the
        # LSE merge must reproduce exact full attention
        out_full = sparse_attention.sparse_decode_distributed(
            q, kd, vd, kpd, pos, page=page, k_pages=n_pages, mesh=mesh,
            seq_axes=("model",))
        # (2) sparse budget: union-of-local-TopK (coverage-oriented
        # superset of the global TopK)
        out_k = sparse_attention.sparse_decode_distributed(
            q, kd, vd, kpd, pos, page=page, k_pages=k_pages, mesh=mesh,
            seq_axes=("model",))
    dense = sparse_attention.sparse_decode(q, k, v, kpage, pos, page=page,
                                           k_pages=n_pages)
    np.testing.assert_allclose(np.asarray(out_full), np.asarray(dense),
                               rtol=2e-4, atol=2e-4)
    print(f"[long-context] devices={n_dev}: full-coverage distributed "
          f"decode == exact attention  OK")
    corr = np.corrcoef(np.asarray(out_k).ravel(),
                       np.asarray(dense).ravel())[0, 1]
    kept = min(4 * k_pages // max(1, n_dev), n_pages // max(1, n_dev)) \
        * n_dev if n_dev > 1 else k_pages
    print(f"[long-context] union-TopK budget ~{kept}/{n_pages} pages: "
          f"corr(dist, exact)={corr:.3f} (random init = diffuse "
          f"attention, the worst case for TopK)")
    print("[long-context] distributed union-TopK sparse decode OK")


if __name__ == "__main__":
    main()
