"""End-to-end serving driver: batched requests through the engine with the
paper's TopK sparse-KV decode, reporting NSB hot-set statistics (the
serving-layer mirror of Fig. 6(c)/Fig. 8).

  PYTHONPATH=src python examples/serve_sparse_llm.py --batch 4 --gen 48
"""

import argparse
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax

from repro.configs import get_config
from repro.configs.base import ShapeCell
from repro.models import api
from repro.serve.engine import Engine


def main():
    p = argparse.ArgumentParser()
    p.add_argument("--arch", default="qwen2-1.5b")
    p.add_argument("--batch", type=int, default=4)
    p.add_argument("--prompt-len", type=int, default=96)
    p.add_argument("--gen", type=int, default=48)
    args = p.parse_args()

    cfg = get_config(args.arch).reduced()
    key = jax.random.PRNGKey(0)
    params = api.init_params(cfg, key)
    cell = ShapeCell("serve", args.prompt_len, args.batch, "prefill")
    batch = api.make_inputs(cfg, cell, key)
    max_len = args.prompt_len + args.gen

    dense = Engine(cfg, params, max_len=max_len, sparse=False)
    dense.generate(batch, args.gen)
    sparse = Engine(cfg, params, max_len=max_len, sparse=True, nsb_pages=48,
                    capture_trace=True)
    out = sparse.generate(batch, args.gen)
    s = sparse.stats

    pages_per_step_dense = max_len // cfg.kv_page      # full scan
    pages_per_step_sparse = min(cfg.kv_topk_pages,
                                max_len // cfg.kv_page)
    print(f"[serve] {args.batch} requests x {args.gen} tokens "
          f"({out.shape}) arch={cfg.name}")
    print(f"[serve] KV pages touched/step: dense={pages_per_step_dense} "
          f"sparse={pages_per_step_sparse} "
          f"({pages_per_step_dense / pages_per_step_sparse:.1f}x fewer)")
    print(f"[serve] NSB hot-set hit rate {s.hot_hit_rate:.1%} -> off-chip "
          f"page fetches reduced a further "
          f"{1 / max(1e-9, 1 - s.hot_hit_rate):.1f}x on top")
    print("[serve] this is the paper's LLM decode story: TopK sparsity "
          "cuts traffic, NVR+NSB make the remaining gathers cheap")

    # capture -> simulate round trip: replay THIS decode run's page
    # traffic through the cycle-level simulator (Fig. 5 modes)
    from repro.core.nvr import run_modes
    rs = {r.label: r for r in run_modes(sparse.captured_trace(), 2)}
    ino, nvr = rs["inorder"], rs["nvr"]
    print(f"[replay] captured trace: {ino.n_vloads} vector loads; "
          f"inorder {ino.demand_misses} demand misses -> nvr "
          f"{nvr.demand_misses} ({ino.total / nvr.total:.2f}x faster)")


if __name__ == "__main__":
    main()
