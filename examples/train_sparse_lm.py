"""End-to-end training driver: train a llama-family LM with the full
production stack (data pipeline, AdamW, checkpoints, watchdog).

Default is a CPU-sized model for a quick run; ``--params 100m`` selects a
~100M-parameter config (a few hundred steps is a real soak on CPU — the
same driver runs full configs on a TPU fleet via repro.launch.train).

  PYTHONPATH=src python examples/train_sparse_lm.py --steps 60
  PYTHONPATH=src python examples/train_sparse_lm.py --params 100m --steps 200
"""

import argparse
import dataclasses
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.configs import get_config
from repro.data import pipeline
from repro.train import trainer


def model_for(size: str):
    base = get_config("tinyllama-1.1b")
    if size == "tiny":
        return base.reduced()
    if size == "20m":
        return dataclasses.replace(
            base, n_layers=6, d_model=384, n_heads=6, n_kv_heads=2,
            head_dim=64, d_ff=1024, vocab=8192, param_dtype="float32")
    if size == "100m":
        return dataclasses.replace(
            base, n_layers=12, d_model=768, n_heads=12, n_kv_heads=4,
            head_dim=64, d_ff=2048, vocab=16384, param_dtype="float32")
    raise ValueError(size)


def main():
    p = argparse.ArgumentParser()
    p.add_argument("--params", default="tiny",
                   choices=["tiny", "20m", "100m"])
    p.add_argument("--steps", type=int, default=60)
    p.add_argument("--batch", type=int, default=8)
    p.add_argument("--seq", type=int, default=128)
    p.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    args = p.parse_args()

    cfg = model_for(args.params)
    n = cfg.params_count()
    print(f"[example] {cfg.name} variant: {n / 1e6:.1f}M params")
    tc = trainer.TrainConfig(steps=args.steps, lr=1e-3,
                             warmup=max(5, args.steps // 10),
                             ckpt_dir=args.ckpt_dir, ckpt_every=50,
                             log_every=10, remat="none")
    dcfg = pipeline.DataConfig(vocab=cfg.vocab, seq_len=args.seq,
                               global_batch=args.batch)
    it = ((s, {"tokens": t, "labels": l})
          for s, (t, l) in pipeline.batches(dcfg))
    state, hist = trainer.run(cfg, tc, it)
    print(f"[example] loss {hist[0]['loss']:.4f} -> {hist[-1]['loss']:.4f}; "
          f"checkpoints in {args.ckpt_dir}")


if __name__ == "__main__":
    main()
