"""Quickstart: the two layers of this repo in 60 seconds.

1. Paper-faithful layer — run the NVR simulator on a sparse workload and
   see the cache-miss/speedup story of the paper.
2. TPU-native layer — run the runahead kernels (interpret mode on CPU)
   against their oracles.

  PYTHONPATH=src python examples/quickstart.py
"""

import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax.numpy as jnp
import numpy as np


def simulator_demo():
    from repro.core.nvr import make_trace, run_modes
    print("=== NVR simulator: Double Sparsity (LLM sparse KV) ===")
    tr = make_trace("DS", dtype_bytes=2, scale=0.5)
    rs = {r.label: r for r in run_modes(tr, 2)}
    ino = rs["inorder"]
    print(f"{'mode':10s} {'cycles':>10s} {'stall':>10s} {'misses':>8s} "
          f"{'speedup':>8s}")
    for mode in ("dense", "inorder", "ooo", "stream", "imp", "dvr", "nvr"):
        r = rs[mode]
        print(f"{mode:10s} {r.total:10.0f} {r.stall:10.0f} "
              f"{r.demand_misses:8d} {ino.total / r.total:8.2f}x")
    nvr = rs["nvr"]
    print(f"\nNVR: accuracy {nvr.accuracy:.1%}, coverage {nvr.coverage:.1%},"
          f" off-chip traffic -{1 - nvr.offchip / ino.offchip:.1%}")


def kernel_demo():
    from repro.kernels import gather_spmm, ref, sparse_decode_attn
    print("\n=== TPU runahead kernels (interpret mode) ===")
    rng = np.random.default_rng(0)
    # one-side-sparse SpMM (the paper's Fig. 2 listing)
    cols = jnp.asarray(rng.integers(0, 64, (8, 4)), jnp.int32)
    vals = jnp.asarray(rng.normal(size=(8, 4)), jnp.float32)
    dense = jnp.asarray(rng.normal(size=(64, 256)), jnp.float32)
    out = gather_spmm(cols, vals, dense, block_n=128)
    np.testing.assert_allclose(out, ref.gather_spmm_ref(cols, vals, dense),
                               rtol=1e-5)
    print("gather_spmm: scalar-prefetched CSR/ELL SpMM == oracle  OK")
    # TopK sparse decode attention (Double Sparsity / H2O)
    q = jnp.asarray(rng.normal(size=(2, 2, 4, 64)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(2, 128, 2, 64)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(2, 128, 2, 64)), jnp.float32)
    idx = jnp.asarray(rng.integers(0, 16, (2, 2, 6)), jnp.int32)
    out = sparse_decode_attn(idx, q, k, v, page_size=8)
    want = ref.sparse_decode_attn_ref(idx, q, k, v, page_size=8)
    np.testing.assert_allclose(out, want, rtol=3e-5, atol=3e-6)
    print("sparse_decode_attn: TopK-page KV gather attention == oracle  OK")


if __name__ == "__main__":
    simulator_demo()
    kernel_demo()
    print("\nquickstart OK")
