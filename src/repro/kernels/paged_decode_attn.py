"""Paged TopK sparse-decode attention on the serve layer's physical pools.

Where ``sparse_decode_attn`` runs on contiguous ``[B, S, KV, D]`` caches
(the single-request layout), this kernel consumes the continuous-batching
engine's *native* memory model directly: one layer of the physical page
pool ``k/v_pool [P, page, KV, D]`` shared by every request, plus the
per-request TopK selection already resolved to **physical page ids**
through the block table (``sparse_attention.select_pages_blocktable``).

The NVR mechanism, mapped onto the Pallas pipeline:

* the resolved page-id chain (``phys``), the logical ids (``idx``, for
  causal masking) and the per-request frontiers (``pos``) are
  **scalar-prefetched** — available before the kernel body runs, exactly
  the role of NVR's resolved-address runahead state;
* the grid walks ``(request, kv_head, selected_page)`` and the pipeline
  **double-buffers the indirect page DMAs** across grid steps: while page
  ``p`` is attended, page ``p+1``'s HBM fetch is in flight.  Pipeline
  depth = runahead depth — the paper's decoupled speculative fetch,
  expressed as a BlockSpec index map;
* gather and online-softmax attention are **fused**: the gathered K/V
  tile lives only in VMEM, never materialised in HBM (the XLA path
  ``sparse_attention.attend_pages_paged`` builds the full
  ``[R, KV, K, page, D]`` gather in memory first).

Masking matches the XLA oracle bit-for-bit in structure: a selected page
may straddle the frontier (tokens at absolute position > ``pos[r]`` are
masked), NULL-padded selection slots of short requests are fully masked,
and fully-masked rows (padded batch slots) produce zeros, not NaNs.

Layout: phys/idx int32 ``[R, KV, K]``; pos int32 ``[R]``;
q ``[R, KV, G, D]``; k/v_pool ``[P, page, KV, D]`` (fp or int8 with the
shared fixed-scale quant).  Output ``[R, KV, G, D]``.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# fixed-scale symmetric int8 KV quantisation: RoPE preserves key norms,
# so one static scale suffices.  Canonical definition — the model layer
# (``models.sparse_attention``) imports it from here, since the kernel
# package must never import the model stack.
KV_QSCALE = 16.0


def _paged_kernel(phys_ref, idx_ref, pos_ref, q_ref, k_ref, v_ref, out_ref,
                  acc_ref, m_ref, l_ref, *, k_sel: int, page: int,
                  scale: float, kv_scale: float):
    ri, hi, pi = pl.program_id(0), pl.program_id(1), pl.program_id(2)

    @pl.when(pi == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, -jnp.inf)
        l_ref[...] = jnp.zeros_like(l_ref)

    q = q_ref[0, 0].astype(jnp.float32)                      # [G, D]
    k = k_ref[0, :, 0, :].astype(jnp.float32) * kv_scale     # [page, D]
    v = v_ref[0, :, 0, :].astype(jnp.float32) * kv_scale
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale
    # causal frontier mask in absolute token positions: the logical page
    # id places this physical page on the request's timeline
    lp = idx_ref[ri, hi, pi]
    tok = lp * page + jax.lax.broadcasted_iota(jnp.int32, (1, page), 1)
    s = jnp.where(tok <= pos_ref[ri], s, -jnp.inf)           # [G, page]

    # online softmax, -inf-safe: a fully-masked tile (NULL-padded
    # selection slot, or a padded batch row) contributes nothing
    m_prev = m_ref[:, :1]
    l_prev = l_ref[:, :1]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
    m_safe = jnp.where(jnp.isfinite(m_new), m_new, 0.0)
    alpha = jnp.where(jnp.isfinite(m_prev), jnp.exp(m_prev - m_safe), 0.0)
    p = jnp.where(jnp.isfinite(s), jnp.exp(s - m_safe), 0.0)
    l_new = l_prev * alpha + jnp.sum(p, axis=-1, keepdims=True)
    acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    m_ref[...] = jnp.broadcast_to(m_new, m_ref.shape)
    l_ref[...] = jnp.broadcast_to(l_new, l_ref.shape)

    @pl.when(pi == k_sel - 1)
    def _fini():
        l = l_ref[:, :1]
        out_ref[0, 0] = jnp.where(
            l > 0, acc_ref[...] / jnp.maximum(l, 1e-30), 0.0
        ).astype(out_ref.dtype)


@functools.partial(jax.jit, static_argnames=("page_size", "interpret"))
def _paged_decode_attn(phys, idx, pos, q, k_pool, v_pool, *, page_size: int,
                       interpret: bool):
    r, kv, g, d = q.shape
    _, _, k_sel = phys.shape
    scale = 1.0 / (d ** 0.5)
    kv_scale = (1.0 / KV_QSCALE if k_pool.dtype == jnp.int8 else 1.0)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=3,              # phys, idx, pos — the resolved
        grid=(r, kv, k_sel),                # runahead chain, known up front
        in_specs=[
            pl.BlockSpec((1, 1, g, d),
                         lambda ri, hi, pi, ph, ix, ps: (ri, hi, 0, 0)),
            # indirect page DMA: the index map consults the prefetched
            # physical id — the pipeline prefetches page pi+1 while pi is
            # attended (double-buffered speculative gather, depth = K)
            pl.BlockSpec((1, page_size, 1, d),
                         lambda ri, hi, pi, ph, ix, ps:
                         (ph[ri, hi, pi], 0, hi, 0)),
            pl.BlockSpec((1, page_size, 1, d),
                         lambda ri, hi, pi, ph, ix, ps:
                         (ph[ri, hi, pi], 0, hi, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, g, d),
                               lambda ri, hi, pi, ph, ix, ps: (ri, hi, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((g, d), jnp.float32),
            pltpu.VMEM((g, 128), jnp.float32),
            pltpu.VMEM((g, 128), jnp.float32),
        ],
    )
    kern = functools.partial(_paged_kernel, k_sel=k_sel, page=page_size,
                             scale=scale, kv_scale=kv_scale)
    return pl.pallas_call(
        kern, grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((r, kv, g, d), q.dtype),
        interpret=interpret)(
            phys.astype(jnp.int32), idx.astype(jnp.int32),
            pos.astype(jnp.int32), q, k_pool, v_pool)


def paged_decode_attn(phys: jax.Array, idx: jax.Array, pos: jax.Array,
                      q: jax.Array, k_pool: jax.Array, v_pool: jax.Array,
                      *, page_size: int, interpret: bool | None = None,
                      hot_map: jax.Array | None = None,
                      n_demand: int = 0) -> jax.Array:
    """Paged TopK decode attention on one layer of the physical pool.

    Args:
      phys: int32 [R, KV, K] physical page ids (the gather targets).
      idx:  int32 [R, KV, K] logical page ids (causal masking).
      pos:  int32 [R] per-request frontier positions.
      q:    [R, KV, G, D] one decode step's queries, GQA-grouped.
      k_pool, v_pool: [P, page, KV, D] one layer of the physical pools
        (int8 pools dequant with the shared fixed scale).
      page_size: tokens per physical page.
      interpret: run the Pallas interpreter (defaults to True off-TPU).
      hot_map: optional int32 [n_demand] runahead hot-map, demand page
        id -> staged NSB slot (-1 = not staged).  Page ids with a live
        slot are remapped to the pool's contiguous staging tail at
        ``n_demand + slot`` before the gather: the scalar-prefetched
        index map then DMAs the staged copy — a sequential read from the
        hot tier — instead of the scattered demand page.  Staged pages
        are byte-exact copies, so the result is bitwise-unchanged.
      n_demand: demand-region page count (tail slots start here);
        required with ``hot_map``.
    Returns: [R, KV, G, D], parity with
      ``sparse_attention.attend_pages_paged`` (fp32 online softmax).
    """
    from .ops import on_tpu
    if interpret is None:
        interpret = not on_tpu()
    if hot_map is not None:
        slot = hot_map[phys]                   # [R, KV, K]; -1 = demand
        phys = jnp.where(slot >= 0, n_demand + slot, phys)
    return _paged_decode_attn(phys, idx, pos, q, k_pool, v_pool,
                              page_size=page_size, interpret=interpret)
