"""NVR TPU kernels: runahead gather, sparse SpMM, TopK decode attention
(contiguous and block-table paged layouts), grouped MoE GEMM.  See ops.py
for the public API, ref.py for oracles."""

from .flash_prefill import flash_prefill
from .ops import (coalesce_indices, csr_to_ell, gather_rows, gather_spmm,
                  group_tokens_by_expert, moe_dispatch_matmul,
                  moe_paged_down, moe_paged_gateup, on_tpu,
                  sparse_decode_attn, topk_pages)
from .paged_decode_attn import paged_decode_attn

__all__ = [
    "coalesce_indices", "csr_to_ell", "flash_prefill", "gather_rows",
    "gather_spmm", "group_tokens_by_expert", "moe_dispatch_matmul",
    "moe_paged_down", "moe_paged_gateup", "on_tpu", "paged_decode_attn",
    "sparse_decode_attn", "topk_pages",
]
