"""Fused causal flash attention for prefill (TPU Pallas).

The prefill cells are the compute-heaviest in the dry-run (t_comp up to
5.4 s/step on grok); this kernel fuses QK^T -> online softmax -> PV in
VMEM tiles so scores never round-trip HBM.  GQA is handled in the K/V
BlockSpec index map (query head h reads KV head h // group); causal
blocks above the diagonal are masked with ``pl.when`` guarding the FMAs.

Grid: (B, H, Sq/bq, Sk/bk) — the trailing Sk axis iterates sequentially
per (B, H, q-block), carrying (m, l, acc) in VMEM scratch: the same
online-softmax recurrence as ``layers.chunked_attention`` (the pure-JAX
oracle used under pjit), validated against it in tests.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _flash_kernel(q_ref, k_ref, v_ref, out_ref, acc_ref, m_ref, l_ref, *,
                  n_kb: int, bq: int, bk: int, scale: float, causal: bool):
    kb = pl.program_id(3)
    qb = pl.program_id(2)

    @pl.when(kb == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, -jnp.inf)
        l_ref[...] = jnp.zeros_like(l_ref)

    run = (not causal) or (kb * bk <= qb * bq + bq - 1)

    @pl.when(run)
    def _compute():
        q = q_ref[0, :, 0, :].astype(jnp.float32) * scale   # [bq, D]
        k = k_ref[0, :, 0, :].astype(jnp.float32)           # [bk, D]
        v = v_ref[0, :, 0, :].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)
        if causal:
            qpos = qb * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
            kpos = kb * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
            s = jnp.where(qpos >= kpos, s, -jnp.inf)
        m_prev = m_ref[:, :1]
        l_prev = l_ref[:, :1]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
        m_safe = jnp.where(jnp.isfinite(m_new), m_new, 0.0)
        p = jnp.exp(s - m_safe)
        p = jnp.where(jnp.isfinite(s), p, 0.0)
        alpha = jnp.where(jnp.isfinite(m_prev), jnp.exp(m_prev - m_safe),
                          0.0)
        l_ref[...] = jnp.broadcast_to(
            l_prev * alpha + jnp.sum(p, axis=-1, keepdims=True), l_ref.shape)
        acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_ref[...] = jnp.broadcast_to(m_new, m_ref.shape)

    @pl.when(kb == n_kb - 1)
    def _fini():
        out_ref[0, :, 0, :] = (
            acc_ref[...] / jnp.maximum(l_ref[:, :1], 1e-30)
        ).astype(out_ref.dtype)


@functools.partial(jax.jit, static_argnames=("causal", "block_q", "block_k",
                                             "interpret"))
def flash_prefill(q: jax.Array, k: jax.Array, v: jax.Array, *,
                  causal: bool = True, block_q: int = 128,
                  block_k: int = 128, interpret: bool = True) -> jax.Array:
    """q [B,S,H,D]; k, v [B,S,KV,D]; H = KV * G.  Returns [B,S,H,D]."""
    b, s, h, d = q.shape
    _, sk, kv, _ = k.shape
    g = h // kv
    bq = min(block_q, s)
    bk = min(block_k, sk)
    assert s % bq == 0 and sk % bk == 0
    grid = (b, h, s // bq, sk // bk)
    scale = 1.0 / (d ** 0.5)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=0,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bq, 1, d),
                         lambda bi, hi, qi, ki: (bi, qi, hi, 0)),
            pl.BlockSpec((1, bk, 1, d),
                         lambda bi, hi, qi, ki: (bi, ki, hi // g, 0)),
            pl.BlockSpec((1, bk, 1, d),
                         lambda bi, hi, qi, ki: (bi, ki, hi // g, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, 1, d),
                               lambda bi, hi, qi, ki: (bi, qi, hi, 0)),
        scratch_shapes=[
            pltpu.VMEM((bq, d), jnp.float32),
            pltpu.VMEM((bq, 128), jnp.float32),
            pltpu.VMEM((bq, 128), jnp.float32),
        ],
    )
    kern = functools.partial(_flash_kernel, n_kb=sk // bk, bq=bq, bk=bk,
                             scale=scale, causal=causal)
    return pl.pallas_call(
        kern, grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b, s, h, d), q.dtype),
        interpret=interpret)(q, k, v)
