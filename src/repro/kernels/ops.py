"""Public jit'd kernel API + the VMIG/LBD-style index preprocessing.

``interpret`` defaults to True off-TPU (this container validates kernel
bodies in interpret mode); on a TPU backend the same calls compile to
Mosaic.  Every op has a pure-jnp oracle in ``ref.py``.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from .gather_rows import gather_rows as _gather_rows
from .gather_spmm import gather_spmm as _gather_spmm
from .moe_dispatch import moe_dispatch_matmul as _moe_dispatch_matmul
from .moe_dispatch import moe_paged_down, moe_paged_gateup  # noqa: F401
from .sparse_decode_attn import sparse_decode_attn as _sparse_decode_attn


def on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def _interp(interpret: bool | None) -> bool:
    return (not on_tpu()) if interpret is None else interpret


# -- kernels -----------------------------------------------------------------

def gather_rows(idx, table, *, interpret: bool | None = None):
    return _gather_rows(idx, table, interpret=_interp(interpret))


def gather_spmm(cols, vals, dense, *, block_n: int = 0,
                interpret: bool | None = None):
    return _gather_spmm(cols, vals, dense, block_n=block_n,
                        interpret=_interp(interpret))


def sparse_decode_attn(idx, q, k, v, *, page_size: int = 8,
                       interpret: bool | None = None):
    return _sparse_decode_attn(idx, q, k, v, page_size=page_size,
                               interpret=_interp(interpret))


def moe_dispatch_matmul(group_ids, x, w, *, block_t: int = 0,
                        block_f: int = 0, block_d: int = 0,
                        interpret: bool | None = None):
    return _moe_dispatch_matmul(group_ids, x, w, block_t=block_t,
                                block_f=block_f, block_d=block_d,
                                interpret=_interp(interpret))


# -- VMIG / LBD-style preprocessing ------------------------------------------

def coalesce_indices(idx: jax.Array) -> tuple[jax.Array, jax.Array]:
    """MSHR-coalescing analogue: sort + first-occurrence mask.

    Returns (sorted_idx, inverse_perm) such that
    ``gathered[inverse_perm]`` restores request order while duplicate rows
    hit the same (now adjacent) DMA.
    """
    order = jnp.argsort(idx)
    inv = jnp.argsort(order)
    return idx[order], inv


def csr_to_ell(rowptr: np.ndarray, col: np.ndarray, val: np.ndarray,
               nnz_pad: int | None = None) -> tuple[np.ndarray, np.ndarray]:
    """CSR -> ELL (fixed-width rows, zero-padded): the LBD bound-to-tile
    transform.  Host-side (data preparation)."""
    m = len(rowptr) - 1
    width = nnz_pad or int(max(1, (rowptr[1:] - rowptr[:-1]).max()))
    cols = np.zeros((m, width), dtype=np.int32)
    vals = np.zeros((m, width), dtype=val.dtype)
    for r in range(m):
        lo, hi = int(rowptr[r]), int(rowptr[r + 1])
        k = min(hi - lo, width)
        cols[r, :k] = col[lo:lo + k]
        vals[r, :k] = val[lo:lo + k]
    return cols, vals


def group_tokens_by_expert(expert_ids: jax.Array, n_experts: int,
                           block_t: int) -> tuple[jax.Array, jax.Array, jax.Array]:
    """Sort tokens by expert and pad each group to a block_t multiple.

    Returns (perm [T_pad] gather indices into x with T used as "padding
    token", group_ids [T_pad // block_t], inv_pos [T] scatter positions).
    Capacity is static: each expert gets ceil(T / n_experts / block_t) + 1
    blocks (tokens beyond capacity are dropped — standard MoE capacity).
    """
    t = expert_ids.shape[0]
    cap_blocks = int(np.ceil(t / n_experts / block_t)) + 1
    cap = cap_blocks * block_t
    # position of each token within its expert group
    sort_ord = jnp.argsort(expert_ids)
    sorted_eids = expert_ids[sort_ord]
    pos_in_grp = jnp.arange(t) - jnp.searchsorted(sorted_eids, sorted_eids)
    slot = sorted_eids * cap + pos_in_grp
    keep = pos_in_grp < cap
    perm = jnp.full((n_experts * cap,), t, dtype=jnp.int32)
    perm = perm.at[jnp.where(keep, slot, n_experts * cap - 1)].set(
        jnp.where(keep, sort_ord, t).astype(jnp.int32), mode="drop")
    group_ids = jnp.repeat(jnp.arange(n_experts, dtype=jnp.int32), cap_blocks)
    inv_pos = jnp.full((t + 1,), -1, dtype=jnp.int32)
    inv_pos = inv_pos.at[perm].set(jnp.arange(n_experts * cap,
                                              dtype=jnp.int32), mode="drop")
    return perm, group_ids, inv_pos[:t]


def topk_pages(scores: jax.Array, n_pages: int, page_size: int,
               k_pages: int) -> jax.Array:
    """Fuzzy (page-granular) TopK: aggregate token scores into page scores
    and select the K highest pages — the coverage-oriented selection."""
    b, h, s = scores.shape
    ps = scores.reshape(b, h, n_pages, page_size).max(axis=-1)
    _, idx = jax.lax.top_k(ps, k_pages)
    return idx.astype(jnp.int32)
