"""TopK sparse KV decode attention — the paper's flagship LLM use case
(Double Sparsity [5] / H2O [29]) as a TPU-native runahead kernel.

One new query token attends to only the ``K`` highest-scoring KV *pages*
(page = ``page_size`` consecutive tokens; ``page_size = 1`` is exact row
selection, larger pages are the paper's *fuzzy / coverage-oriented* fetch:
slightly more data per request, far fewer requests, MXU-aligned tiles).

The page indices (resolved TopK chain) are scalar-prefetched; the Pallas
pipeline double-buffers the indirect K/V page DMAs across grid steps —
speculative gather depth = pipeline depth, the NVR mechanism.

Layout: q [B, Hkv, G, D] (GQA groups), k/v [B, S, Hkv, D], idx [B, Hkv, P]
with page indices in [0, S/page_size).  Output [B, Hkv, G, D].
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _attn_kernel(idx_ref, q_ref, k_ref, v_ref, out_ref,
                 acc_ref, m_ref, l_ref, *, n_pages: int, scale: float):
    p = pl.program_id(2)

    @pl.when(p == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, -jnp.inf)
        l_ref[...] = jnp.zeros_like(l_ref)

    q = q_ref[0, 0].astype(jnp.float32)            # [G, D]
    k = k_ref[0, 0, :, 0, :].astype(jnp.float32)   # [P, D]
    v = v_ref[0, 0, :, 0, :].astype(jnp.float32)   # [P, D]
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale  # [G, P]
    m_prev = m_ref[:, :1]                          # [G, 1]
    l_prev = l_ref[:, :1]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
    alpha = jnp.exp(m_prev - m_new)
    pexp = jnp.exp(s - m_new)                      # [G, P]
    l_new = l_prev * alpha + jnp.sum(pexp, axis=-1, keepdims=True)
    acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot_general(
        pexp, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    m_ref[...] = jnp.broadcast_to(m_new, m_ref.shape)
    l_ref[...] = jnp.broadcast_to(l_new, l_ref.shape)

    @pl.when(p == n_pages - 1)
    def _fini():
        out_ref[0, 0] = (acc_ref[...] / l_ref[:, :1]).astype(out_ref.dtype)


@functools.partial(jax.jit,
                   static_argnames=("page_size", "interpret"))
def sparse_decode_attn(idx: jax.Array, q: jax.Array, k: jax.Array,
                       v: jax.Array, *, page_size: int = 8,
                       interpret: bool = True) -> jax.Array:
    """TopK-page decode attention.

    Args:
      idx: int32 [B, Hkv, P] page indices into [0, S // page_size).
      q:   [B, Hkv, G, D] query (one decode step, GQA-grouped).
      k,v: [B, S, Hkv, D] KV cache.
      page_size: tokens per gathered page (fuzzy-fetch granularity).
    Returns: [B, Hkv, G, D]
    """
    b, hkv, g, d = q.shape
    _, s, _, _ = k.shape
    _, _, n_pages = idx.shape
    assert s % page_size == 0
    scale = 1.0 / (d ** 0.5)
    kp = k.reshape(b, s // page_size, page_size, hkv, d)
    vp = v.reshape(b, s // page_size, page_size, hkv, d)
    grid = (b, hkv, n_pages)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, g, d), lambda bi, hi, pi, c: (bi, hi, 0, 0)),
            pl.BlockSpec((1, 1, page_size, 1, d),
                         lambda bi, hi, pi, c: (bi, c[bi, hi, pi], 0, hi, 0)),
            pl.BlockSpec((1, 1, page_size, 1, d),
                         lambda bi, hi, pi, c: (bi, c[bi, hi, pi], 0, hi, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, g, d), lambda bi, hi, pi, c: (bi, hi, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((g, d), jnp.float32),
            pltpu.VMEM((g, 128), jnp.float32),
            pltpu.VMEM((g, 128), jnp.float32),
        ],
    )
    kern = functools.partial(_attn_kernel, n_pages=n_pages, scale=scale)
    return pl.pallas_call(
        kern, grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b, hkv, g, d), q.dtype),
        interpret=interpret)(idx.astype(jnp.int32), q, kp, vp)
