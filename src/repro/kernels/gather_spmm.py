"""One-side-sparse SpMM (Fig. 2 of the paper) with runahead gather.

``out[m] = sum_j vals[m, j] * dense[cols[m, j], :]`` — the sparse weight
matrix is stored in ELL format (rows padded to a fixed nnz width, pad
entries carry ``val = 0`` so they are numerically inert).  The column-index
matrix is scalar-prefetched; the indirect row of the dense operand for
iteration ``j+1`` is DMA'd while iteration ``j`` runs FMAs — the paper's
SCD chain (``IA[sparse_func(W[i])]``) resolved ahead of compute.

The ELL padding *is* the LBD analogue: dynamic loop bounds (CSR rowptr)
become static tile bounds plus inert lanes, the coverage-oriented trade the
paper argues for (fetch slightly more, never stall).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _spmm_kernel(cols_ref, vals_ref, dense_ref, out_ref):
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    v = vals_ref[0, 0]
    out_ref[...] += v.astype(jnp.float32) * dense_ref[...].astype(jnp.float32)


@functools.partial(jax.jit, static_argnames=("block_n", "interpret"))
def _gather_spmm(cols: jax.Array, vals: jax.Array, dense: jax.Array, *,
                 block_n: int, interpret: bool) -> jax.Array:
    m, j = cols.shape
    _, n = dense.shape
    bn = block_n or n
    grid = (m, j, n // bn)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1), lambda mi, ji, ni, c: (mi, ji)),       # vals
            pl.BlockSpec((1, bn), lambda mi, ji, ni, c: (c[mi, ji], ni)),  # dense row
        ],
        out_specs=pl.BlockSpec((1, bn), lambda mi, ji, ni, c: (mi, ni)),
    )
    return pl.pallas_call(
        _spmm_kernel, grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        interpret=interpret)(cols.astype(jnp.int32), vals, dense)


def gather_spmm(cols: jax.Array, vals: jax.Array, dense: jax.Array, *,
                block_n: int = 0,
                interpret: bool | None = None) -> jax.Array:
    """ELL SpMM: cols/vals [M, J], dense [N_in, N] -> out [M, N] (f32).

    ``interpret`` defaults to auto-detect (interpret mode off-TPU,
    Mosaic on TPU), matching ``paged_decode_attn``.
    """
    from .ops import on_tpu       # deferred: ops re-exports this module
    if interpret is None:
        interpret = not on_tpu()
    return _gather_spmm(cols, vals, dense, block_n=block_n,
                        interpret=interpret)
