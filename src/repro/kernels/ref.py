"""Pure-jnp oracles for every kernel (the correctness contract)."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def gather_rows_ref(idx: jax.Array, table: jax.Array) -> jax.Array:
    return jnp.take(table, idx, axis=0)


def gather_spmm_ref(cols: jax.Array, vals: jax.Array,
                    dense: jax.Array) -> jax.Array:
    """ELL SpMM oracle: out[m] = sum_j vals[m,j] * dense[cols[m,j]]."""
    rows = jnp.take(dense, cols, axis=0).astype(jnp.float32)  # [M, J, N]
    return jnp.einsum("mj,mjn->mn", vals.astype(jnp.float32), rows)


def sparse_decode_attn_ref(idx: jax.Array, q: jax.Array, k: jax.Array,
                           v: jax.Array, *, page_size: int = 8) -> jax.Array:
    """TopK-page decode attention oracle.

    idx [B,Hkv,P] pages; q [B,Hkv,G,D]; k/v [B,S,Hkv,D] -> [B,Hkv,G,D].
    """
    b, hkv, g, d = q.shape
    _, s, _, _ = k.shape
    kp = k.reshape(b, s // page_size, page_size, hkv, d)
    vp = v.reshape(b, s // page_size, page_size, hkv, d)
    # gather pages: [B, Hkv, P, page, D]
    bi = jnp.arange(b)[:, None, None]
    hi = jnp.arange(hkv)[None, :, None]
    kg = kp[bi, idx, :, hi, :].astype(jnp.float32)
    vg = vp[bi, idx, :, hi, :].astype(jnp.float32)
    kg = kg.reshape(b, hkv, -1, d)
    vg = vg.reshape(b, hkv, -1, d)
    s_ = jnp.einsum("bhgd,bhtd->bhgt", q.astype(jnp.float32), kg) / (d ** 0.5)
    p = jax.nn.softmax(s_, axis=-1)
    return jnp.einsum("bhgt,bhtd->bhgd", p, vg).astype(q.dtype)


def moe_dispatch_matmul_ref(group_ids: jax.Array, x: jax.Array,
                            w: jax.Array, *, block_t: int) -> jax.Array:
    """Grouped GEMM oracle: out[tb] = x[tb] @ w[group_ids[tb]]."""
    t, d = x.shape
    xb = x.reshape(-1, block_t, d).astype(jnp.float32)       # [TB, bt, D]
    wg = jnp.take(w, group_ids, axis=0).astype(jnp.float32)  # [TB, D, F]
    out = jnp.einsum("btd,bdf->btf", xb, wg)
    return out.reshape(t, -1).astype(x.dtype)


def moe_paged_gateup_ref(pids: jax.Array, x: jax.Array,
                         pool: jax.Array) -> jax.Array:
    """Paged gate/up oracle: gather the routed experts' row tiles from
    the pool and project.  pids [R,K,NT]; x [R,D]; pool [P,tile_f,D]
    -> [R, K, NT*tile_f]."""
    r, k, nt = pids.shape
    w = jnp.take(pool, pids, axis=0)             # [R,K,NT,tile_f,D]
    w = w.reshape(r, k, -1, w.shape[-1]).astype(jnp.float32)
    return jnp.einsum("rd,rkfd->rkf", x.astype(jnp.float32),
                      w).astype(x.dtype)


def moe_paged_down_ref(pids: jax.Array, h: jax.Array,
                       pool: jax.Array) -> jax.Array:
    """Paged down oracle: pids [R,K,NT]; h [R,K,NT*tile_f];
    pool [P,tile_f,D] -> [R, K, D]."""
    r, k, nt = pids.shape
    w = jnp.take(pool, pids, axis=0)             # [R,K,NT,tile_f,D]
    w = w.reshape(r, k, -1, w.shape[-1]).astype(jnp.float32)
    return jnp.einsum("rkf,rkfd->rkd", h.astype(jnp.float32),
                      w).astype(h.dtype)
