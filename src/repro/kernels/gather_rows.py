"""Runahead row gather — the NVR primitive, TPU-native.

``table`` lives in HBM; ``idx`` (the resolved sparse chain, SCD-analogue) is
*scalar-prefetched* into SMEM before the kernel body runs, so the Pallas
pipeline engine issues the indirect HBM->VMEM DMA for grid step ``k+1``
while step ``k`` computes — a software vector-runahead with depth equal to
the pipeline's multiple-buffering depth.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _gather_kernel(idx_ref, tbl_ref, out_ref):
    out_ref[...] = tbl_ref[...]


@functools.partial(jax.jit, static_argnames=("block_d", "interpret"))
def gather_rows(idx: jax.Array, table: jax.Array, *, block_d: int = 0,
                interpret: bool = True) -> jax.Array:
    """out[k, :] = table[idx[k], :].

    Args:
      idx: int32 [K] row indices (may repeat — MSHR-coalescing is done by
        the caller via ``repro.core.sparse.coalesce``).
      table: [N, D] source rows in HBM.
      block_d: tile width along D (0 = full row).
    """
    k_rows, = idx.shape
    n, d = table.shape
    bd = block_d or d
    grid = (k_rows, d // bd)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=grid,
        in_specs=[pl.BlockSpec((1, bd), lambda k, j, idx_ref: (idx_ref[k], j))],
        out_specs=pl.BlockSpec((1, bd), lambda k, j, idx_ref: (k, j)),
    )
    return pl.pallas_call(
        _gather_kernel, grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((k_rows, d), table.dtype),
        interpret=interpret)(idx.astype(jnp.int32), table)
