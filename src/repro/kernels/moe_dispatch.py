"""Grouped (block-diagonal) MoE expert GEMM with ragged-bound runahead.

Tokens arrive *sorted by expert* and padded so no token block spans two
experts (the VMIG-coalescing analogue, done in ``ops.py``).  The per-block
expert id — the dynamic loop boundary the paper's LBD snoops from the NPU
sparse unit — is scalar-prefetched, so the expert weight tile for block
``t+1`` is DMA'd from HBM while block ``t`` is in the MXU.

out[t_block] = x[t_block] @ W[group_id[t_block]]        (MegaBlocks-style)
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _moe_kernel(gid_ref, x_ref, w_ref, out_ref, acc_ref, *, n_kblocks: int):
    kb = pl.program_id(2)

    @pl.when(kb == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jax.lax.dot_general(
        x_ref[...].astype(jnp.float32), w_ref[0].astype(jnp.float32),
        (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)

    @pl.when(kb == n_kblocks - 1)
    def _fini():
        out_ref[...] = acc_ref[...].astype(out_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_t", "block_f", "block_d",
                                             "interpret"))
def moe_dispatch_matmul(group_ids: jax.Array, x: jax.Array, w: jax.Array, *,
                        block_t: int = 0, block_f: int = 0, block_d: int = 0,
                        interpret: bool = True) -> jax.Array:
    """x [T, D] (expert-sorted, block-aligned), w [E, D, F] -> out [T, F].

    group_ids: int32 [T // block_t] expert id per token block.
    """
    t, d = x.shape
    e, _, f = w.shape
    bt = block_t or min(t, 128)
    bf = block_f or min(f, 128)
    bd = block_d or min(d, 512)
    assert t % bt == 0 and f % bf == 0 and d % bd == 0
    assert group_ids.shape == (t // bt,)
    grid = (t // bt, f // bf, d // bd)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bt, bd), lambda ti, fi, ki, g: (ti, ki)),
            pl.BlockSpec((1, bd, bf), lambda ti, fi, ki, g: (g[ti], ki, fi)),
        ],
        out_specs=pl.BlockSpec((bt, bf), lambda ti, fi, ki, g: (ti, fi)),
        scratch_shapes=[pltpu.VMEM((bt, bf), jnp.float32)],
    )
    kern = functools.partial(_moe_kernel, n_kblocks=d // bd)
    return pl.pallas_call(
        kern, grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((t, f), x.dtype),
        interpret=interpret)(group_ids.astype(jnp.int32), x, w)
