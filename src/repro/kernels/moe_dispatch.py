"""Grouped (block-diagonal) MoE expert GEMM with ragged-bound runahead.

Tokens arrive *sorted by expert* and padded so no token block spans two
experts (the VMIG-coalescing analogue, done in ``ops.py``).  The per-block
expert id — the dynamic loop boundary the paper's LBD snoops from the NPU
sparse unit — is scalar-prefetched, so the expert weight tile for block
``t+1`` is DMA'd from HBM while block ``t`` is in the MXU.

out[t_block] = x[t_block] @ W[group_id[t_block]]        (MegaBlocks-style)

The *paged* variants (:func:`moe_paged_gateup` / :func:`moe_paged_down`)
are the same mechanism one level deeper: expert weights no longer live as
dense ``[E, D, F]`` cubes but as fixed row-tile pages in a physical
expert-pool (``serve/expert_pool.py``), and the scalar-prefetched operand
is the *resolved physical page id* per (token, routed expert, tile) —
exactly ``paged_decode_attn``'s contract, with weight tiles instead of KV
pages.  The pipeline double-buffers the indirect tile DMAs against the
MXU: while tile ``t``'s GEMM runs, tile ``t+1``'s fetch is in flight.
Pipeline depth = runahead depth.

``interpret`` defaults to auto-detect (interpret mode off-TPU, Mosaic on
TPU), matching ``paged_decode_attn``.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _resolve_interpret(interpret: bool | None) -> bool:
    # deferred import: ops.py re-exports this module's public API
    from .ops import on_tpu
    return (not on_tpu()) if interpret is None else interpret


def _moe_kernel(gid_ref, x_ref, w_ref, out_ref, acc_ref, *, n_kblocks: int):
    kb = pl.program_id(2)

    @pl.when(kb == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jax.lax.dot_general(
        x_ref[...].astype(jnp.float32), w_ref[0].astype(jnp.float32),
        (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)

    @pl.when(kb == n_kblocks - 1)
    def _fini():
        out_ref[...] = acc_ref[...].astype(out_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_t", "block_f", "block_d",
                                             "interpret"))
def _moe_dispatch_matmul(group_ids: jax.Array, x: jax.Array, w: jax.Array, *,
                         block_t: int, block_f: int, block_d: int,
                         interpret: bool) -> jax.Array:
    t, d = x.shape
    e, _, f = w.shape
    bt = block_t or min(t, 128)
    bf = block_f or min(f, 128)
    bd = block_d or min(d, 512)
    assert t % bt == 0 and f % bf == 0 and d % bd == 0
    assert group_ids.shape == (t // bt,)
    grid = (t // bt, f // bf, d // bd)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bt, bd), lambda ti, fi, ki, g: (ti, ki)),
            pl.BlockSpec((1, bd, bf), lambda ti, fi, ki, g: (g[ti], ki, fi)),
        ],
        out_specs=pl.BlockSpec((bt, bf), lambda ti, fi, ki, g: (ti, fi)),
        scratch_shapes=[pltpu.VMEM((bt, bf), jnp.float32)],
    )
    kern = functools.partial(_moe_kernel, n_kblocks=d // bd)
    return pl.pallas_call(
        kern, grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((t, f), x.dtype),
        interpret=interpret)(group_ids.astype(jnp.int32), x, w)


def moe_dispatch_matmul(group_ids: jax.Array, x: jax.Array, w: jax.Array, *,
                        block_t: int = 0, block_f: int = 0, block_d: int = 0,
                        interpret: bool | None = None) -> jax.Array:
    """x [T, D] (expert-sorted, block-aligned), w [E, D, F] -> out [T, F].

    group_ids: int32 [T // block_t] expert id per token block.
    interpret: run the Pallas interpreter (defaults to True off-TPU).
    """
    return _moe_dispatch_matmul(group_ids, x, w, block_t=block_t,
                                block_f=block_f, block_d=block_d,
                                interpret=_resolve_interpret(interpret))


# -- paged expert-tile GEMMs ---------------------------------------------------

def _gateup_kernel(pid_ref, x_ref, w_ref, out_ref, acc_ref, *,
                   n_dblocks: int):
    di = pl.program_id(3)

    @pl.when(di == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # x tile [1, bd] x weight-page slice [tile_f, bd]^T -> [1, tile_f]
    acc_ref[...] += jax.lax.dot_general(
        x_ref[...].astype(jnp.float32), w_ref[0].astype(jnp.float32),
        (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32)

    @pl.when(di == n_dblocks - 1)
    def _fini():
        out_ref[...] = acc_ref[...].reshape(out_ref.shape).astype(
            out_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_d", "interpret"))
def _moe_paged_gateup(pids: jax.Array, x: jax.Array, pool: jax.Array, *,
                      block_d: int, interpret: bool) -> jax.Array:
    r, k, nt = pids.shape
    _, d = x.shape
    _, tile_f, dp = pool.shape
    assert dp == d, f"pool row dim {dp} != x feature dim {d}"
    bd = block_d or min(d, 512)
    assert d % bd == 0
    grid = (r, k, nt, d // bd)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bd), lambda ri, ji, ti, di, p: (ri, di)),
            # the indirect tile DMA: the index map consults the
            # prefetched physical page id, so tile (ti+1)'s fetch is in
            # flight while tile ti is in the MXU
            pl.BlockSpec((1, tile_f, bd),
                         lambda ri, ji, ti, di, p: (p[ri, ji, ti], 0, di)),
        ],
        out_specs=pl.BlockSpec((1, 1, tile_f),
                               lambda ri, ji, ti, di, p: (ri, ji, ti)),
        scratch_shapes=[pltpu.VMEM((1, tile_f), jnp.float32)],
    )
    kern = functools.partial(_gateup_kernel, n_dblocks=d // bd)
    return pl.pallas_call(
        kern, grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((r, k, nt * tile_f), x.dtype),
        interpret=interpret)(pids.astype(jnp.int32), x, pool)


def moe_paged_gateup(pids: jax.Array, x: jax.Array, pool: jax.Array, *,
                     block_d: int = 0,
                     interpret: bool | None = None) -> jax.Array:
    """Paged expert projection into the FFN hidden dim (gate / up).

    pids: int32 [R, K, NT] resolved physical page ids — row tiles of the
      routed expert's ``[F, D]`` weight plane, in tile order (the block
      table lookup ``bt_l[plane][eids]`` already done by the caller, hot
      tier remap included).
    x: [R, D] one decode step's FFN inputs.
    pool: [P, tile_f, D] the physical expert-weight pool (staging tail
      included — remapped ids address it transparently).
    Returns [R, K, NT * tile_f]: per routed expert, ``x @ W_plane^T``.
    """
    return _moe_paged_gateup(pids, x, pool, block_d=block_d,
                             interpret=_resolve_interpret(interpret))


def _down_kernel(pid_ref, h_ref, w_ref, out_ref, acc_ref, *, n_tiles: int):
    ti = pl.program_id(3)

    @pl.when(ti == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # hidden tile [1, tile_f] x weight-page slice [tile_f, bd] -> [1, bd]
    acc_ref[...] += jax.lax.dot_general(
        h_ref[...].reshape(1, -1).astype(jnp.float32),
        w_ref[0].astype(jnp.float32),
        (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)

    @pl.when(ti == n_tiles - 1)
    def _fini():
        out_ref[...] = acc_ref[...].reshape(out_ref.shape).astype(
            out_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_d", "interpret"))
def _moe_paged_down(pids: jax.Array, h: jax.Array, pool: jax.Array, *,
                    block_d: int, interpret: bool) -> jax.Array:
    r, k, nt = pids.shape
    _, tile_f, d = pool.shape
    assert h.shape == (r, k, nt * tile_f)
    bd = block_d or min(d, 512)
    assert d % bd == 0
    grid = (r, k, d // bd, nt)       # tiles last: contraction runs over
    grid_spec = pltpu.PrefetchScalarGridSpec(  # the paged dim here
        num_scalar_prefetch=1,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, tile_f),
                         lambda ri, ji, di, ti, p: (ri, ji, ti)),
            pl.BlockSpec((1, tile_f, bd),
                         lambda ri, ji, di, ti, p: (p[ri, ji, ti], 0, di)),
        ],
        out_specs=pl.BlockSpec((1, 1, bd),
                               lambda ri, ji, di, ti, p: (ri, ji, di)),
        scratch_shapes=[pltpu.VMEM((1, bd), jnp.float32)],
    )
    kern = functools.partial(_down_kernel, n_tiles=nt)
    return pl.pallas_call(
        kern, grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((r, k, d), h.dtype),
        interpret=interpret)(pids.astype(jnp.int32), h, pool)


def moe_paged_down(pids: jax.Array, h: jax.Array, pool: jax.Array, *,
                   block_d: int = 0,
                   interpret: bool | None = None) -> jax.Array:
    """Paged expert projection back to the model dim (down).

    The contraction runs over the *paged* dimension: each grid step
    fetches one ``[tile_f, D]`` weight page (indirect, scalar-prefetched
    id) and accumulates ``h_tile @ W_tile`` into the output block.

    pids: int32 [R, K, NT] resolved physical page ids of the down plane.
    h: [R, K, NT * tile_f] the gated FFN hidden activations.
    pool: [P, tile_f, D] the physical expert-weight pool.
    Returns [R, K, D].
    """
    return _moe_paged_down(pids, h, pool, block_d=block_d,
                           interpret=_resolve_interpret(interpret))
