"""AdamW with dtype-configurable (ZeRO-friendly) moment states.

Moments inherit the parameter sharding (already FSDP/TP-sharded by
``sharding.tree_param_specs``), which is ZeRO-1 on the mesh: no chip holds
a full optimizer state.  ``m_dtype=bfloat16`` halves optimizer memory for
the ≥100B archs (recorded as a §Perf memory-term lever).
"""

from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class AdamWConfig:
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    m_dtype: str = "bfloat16"
    v_dtype: str = "float32"
    grad_clip: float = 1.0


def init(params, cfg: AdamWConfig):
    m = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.dtype(cfg.m_dtype)),
                     params)
    v = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.dtype(cfg.v_dtype)),
                     params)
    return {"m": m, "v": v, "count": jnp.zeros((), jnp.int32)}


def global_norm(tree) -> jax.Array:
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree.leaves(tree)))


def update(grads, state, params, lr: jax.Array, cfg: AdamWConfig):
    count = state["count"] + 1
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.grad_clip / (gnorm + 1e-9)) \
        if cfg.grad_clip else 1.0

    def upd(g, m, v, p):
        g = g.astype(jnp.float32) * scale
        m2 = cfg.b1 * m.astype(jnp.float32) + (1 - cfg.b1) * g
        v2 = cfg.b2 * v.astype(jnp.float32) + (1 - cfg.b2) * jnp.square(g)
        mhat = m2 / (1 - cfg.b1 ** count.astype(jnp.float32))
        vhat = v2 / (1 - cfg.b2 ** count.astype(jnp.float32))
        step = mhat / (jnp.sqrt(vhat) + cfg.eps)
        if cfg.weight_decay and p.ndim >= 2:   # decay matrices only
            step = step + cfg.weight_decay * p.astype(jnp.float32)
        p2 = p.astype(jnp.float32) - lr * step
        return (p2.astype(p.dtype), m2.astype(m.dtype), v2.astype(v.dtype))

    out = jax.tree.map(upd, grads, state["m"], state["v"], params)
    new_params = jax.tree.map(lambda _, o: o[0], grads, out)
    new_m = jax.tree.map(lambda _, o: o[1], grads, out)
    new_v = jax.tree.map(lambda _, o: o[2], grads, out)
    return new_params, {"m": new_m, "v": new_v, "count": count}, gnorm


def cosine_schedule(base_lr: float, warmup: int, total: int):
    def lr(step):
        step = step.astype(jnp.float32)
        warm = base_lr * step / max(1, warmup)
        prog = jnp.clip((step - warmup) / max(1, total - warmup), 0.0, 1.0)
        cos = base_lr * 0.5 * (1 + jnp.cos(jnp.pi * prog))
        return jnp.where(step < warmup, warm, cos)
    return lr
