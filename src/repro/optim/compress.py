"""Gradient compression for the slow (cross-pod) all-reduce.

int8 per-tensor-scaled quantisation with error feedback (residual carried
to the next step so compression error does not bias the optimizer —
1-bit-Adam/PowerSGD-style).  ``compressed_psum`` demonstrates the two-stage
reduction under shard_map: full-precision within the pod (fast ICI),
int8 across pods (slow DCI) — an 8x wire-bytes reduction on the
inter-pod hop.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def quantize_int8(x: jax.Array) -> tuple[jax.Array, jax.Array]:
    scale = jnp.max(jnp.abs(x)) / 127.0 + 1e-30
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_int8(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


def compress_with_feedback(g: jax.Array, err: jax.Array):
    """Returns (quantised grad as f32, new error residual)."""
    target = g.astype(jnp.float32) + err
    q, s = quantize_int8(target)
    deq = dequantize_int8(q, s)
    return deq, target - deq


def init_error_state(grads):
    return jax.tree.map(lambda g: jnp.zeros(g.shape, jnp.float32), grads)


def compressed_psum(grads, err_state, axis_name: str):
    """Error-feedback int8 psum over ``axis_name`` (call inside shard_map).

    Wire format is int8 (the psum itself runs on the dequantised value to
    stay collective-friendly; on real hardware the int8 tensor + scale are
    what cross the DCI — we count those bytes in the roofline).
    """
    def one(g, e):
        deq, e2 = compress_with_feedback(g, e)
        n = jax.lax.psum(1, axis_name)
        red = jax.lax.psum(deq, axis_name) / n
        return red.astype(g.dtype), e2

    out = jax.tree.map(one, grads, err_state)
    new_grads = jax.tree.map(lambda _, o: o[0], grads, out)
    new_err = jax.tree.map(lambda _, o: o[1], grads, out)
    return new_grads, new_err


def wire_bytes(grads, compressed: bool) -> float:
    """Bytes crossing the slow axis per step (for the roofline collective
    term): bf16 uncompressed vs int8 + scale."""
    total = 0
    for g in jax.tree.leaves(grads):
        n = 1
        for d in g.shape:
            n *= d
        total += n * (1 if compressed else 2) + (4 if compressed else 0)
    return float(total)
