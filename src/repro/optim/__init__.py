from .adamw import AdamWConfig, cosine_schedule, global_norm, init, update
from .compress import (compressed_psum, compress_with_feedback,
                       dequantize_int8, init_error_state, quantize_int8,
                       wire_bytes)

__all__ = ["AdamWConfig", "cosine_schedule", "global_norm", "init", "update",
           "compressed_psum", "compress_with_feedback", "dequantize_int8",
           "init_error_state", "quantize_int8", "wire_bytes"]
