"""Roofline analysis over the dry-run artifacts (§Roofline of the brief).

Per (arch x shape x mesh) cell, from the compiled dry-run record:

  compute term    = HLO_FLOPs_per_device / peak_FLOPs            [s]
  memory term     = HLO_bytes_per_device / HBM_bw                [s]
  collective term = wire_bytes_per_device / ICI_link_bw          [s]

plus MODEL_FLOPS = 6·N·D (dense) or 6·N_active·D (MoE), the useful-compute
ratio MODEL_FLOPS / (HLO_FLOPs x chips), the dominant bottleneck, and the
roofline-bound MFU = (MODEL_FLOPS/chips/peak) / max(terms).

  PYTHONPATH=src python -m repro.roofline.analysis [--mesh pod] [--md out]
"""

from __future__ import annotations

import argparse
import glob
import json
import os

from ..launch import mesh as meshlib

RESULTS_DIR = os.path.join(os.path.dirname(__file__),
                           "../../../benchmarks/results/dryrun")


def load_cells(mesh: str | None = None) -> list[dict]:
    cells = []
    for path in sorted(glob.glob(os.path.join(RESULTS_DIR, "*.json"))):
        with open(path) as f:
            rec = json.load(f)
        if mesh and rec["mesh"] != mesh:
            continue
        cells.append(rec)
    return cells


def terms(rec: dict) -> dict:
    t_comp = rec["flops_per_device"] / meshlib.PEAK_FLOPS_BF16
    t_mem = rec["bytes_per_device"] / meshlib.HBM_BW
    t_coll = rec.get("wire_bytes_per_device",
                     rec["collectives"]["wire_bytes"]) / meshlib.ICI_BW
    dominant = max(("compute", t_comp), ("memory", t_mem),
                   ("collective", t_coll), key=lambda kv: kv[1])
    model = rec["model_flops_global"]
    hlo_global = rec["flops_per_device"] * rec["chips"]
    ratio = model / hlo_global if hlo_global else float("nan")
    t_step = max(t_comp, t_mem, t_coll)
    mfu_bound = (model / rec["chips"] / meshlib.PEAK_FLOPS_BF16) / t_step \
        if t_step else float("nan")
    return {
        "t_compute_s": t_comp, "t_memory_s": t_mem,
        "t_collective_s": t_coll, "dominant": dominant[0],
        "model_ratio": ratio, "mfu_bound": mfu_bound,
    }


MOVE_HINTS = {
    ("compute",): "reduce recompute (remat policy) / raise arithmetic "
                  "efficiency; compute term is the ceiling",
    ("memory",): "fuse/stream more (bigger tiles, bf16 end-to-end), cut "
                 "HLO bytes per step",
    ("collective",): "reshard to cut all-gather volume; overlap via "
                     "scan-level prefetch; compress the slow-axis traffic",
}


def row(rec: dict) -> dict:
    t = terms(rec)
    out = dict(rec)
    out.update(t)
    out["hint"] = MOVE_HINTS[(t["dominant"],)]
    return out


def markdown(cells: list[dict]) -> str:
    lines = [
        "| arch | shape | mesh | t_comp (ms) | t_mem (ms) | t_coll (ms) |"
        " dominant | 6ND/HLO | MFU bound | live GiB | fits |",
        "|---|---|---|---|---|---|---|---|---|---|---|"[:-4],
    ]
    for rec in cells:
        r = row(rec)
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} "
            f"| {1e3 * r['t_compute_s']:.2f} | {1e3 * r['t_memory_s']:.2f} "
            f"| {1e3 * r['t_collective_s']:.3f} | {r['dominant']} "
            f"| {r['model_ratio']:.2f} | {r['mfu_bound']:.2f} "
            f"| {r['live_bytes_per_device'] / 2**30:.2f} "
            f"| {'Y' if r['fits_hbm'] else 'N*'} |")
    return "\n".join(lines)


def main(argv=None):
    p = argparse.ArgumentParser()
    p.add_argument("--mesh", default=None)
    p.add_argument("--md", default=None)
    args = p.parse_args(argv)
    cells = load_cells(args.mesh)
    if not cells:
        print("no dry-run records found; run repro.launch.dryrun first")
        return
    md = markdown(cells)
    print(md)
    if args.md:
        with open(args.md, "w") as f:
            f.write(md + "\n")
    # summary of dominant terms
    from collections import Counter
    doms = Counter(row(c)["dominant"] for c in cells)
    print(f"\ndominant-term distribution: {dict(doms)}")


if __name__ == "__main__":
    main()
