"""Atomic, mesh-agnostic checkpointing with resharding restore.

Arrays are saved as full (unsharded) values in an .npz plus a JSON
manifest; ``restore`` re-places them under any mesh/sharding — elastic
scaling is a restore-time property, not a save-time one.  Writes are
tmp-file + atomic rename; the last ``keep`` checkpoints are retained.
Multi-host note: on a real cluster each process saves its addressable
shards under ``proc<k>``; this container is single-process so the
full-array path is exact.
"""

from __future__ import annotations

import json
import os
import re
import shutil
import tempfile

import jax
import ml_dtypes
import numpy as np

# numpy can't serialise ml_dtypes types: store them as bit-views
_VIEW_AS = {"bfloat16": np.uint16, "float8_e4m3fn": np.uint8,
            "float8_e5m2": np.uint8}


def _flatten(tree):
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    out, dtypes = {}, {}
    for path, leaf in flat:
        name = "/".join(str(getattr(k, "key", k)) for k in path)
        arr = np.asarray(leaf)
        dtypes[name] = str(arr.dtype)
        if str(arr.dtype) in _VIEW_AS:
            arr = arr.view(_VIEW_AS[str(arr.dtype)])
        out[name] = arr
    return out, dtypes


def save(ckpt_dir: str, step: int, tree, keep: int = 3,
         extra: dict | None = None) -> str:
    os.makedirs(ckpt_dir, exist_ok=True)
    arrays, dtypes = _flatten(tree)
    manifest = {"step": int(step),
                "names": sorted(arrays),
                "dtypes": dtypes,
                "extra": extra or {}}
    tmp = tempfile.mkdtemp(dir=ckpt_dir, prefix=".tmp_")
    try:
        np.savez(os.path.join(tmp, "arrays.npz"), **arrays)
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f)
        final = os.path.join(ckpt_dir, f"step_{step:08d}")
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)
    except BaseException:
        shutil.rmtree(tmp, ignore_errors=True)
        raise
    _gc(ckpt_dir, keep)
    return final


def _gc(ckpt_dir: str, keep: int) -> None:
    steps = sorted(latest_steps(ckpt_dir))
    for s in steps[:-keep]:
        shutil.rmtree(os.path.join(ckpt_dir, f"step_{s:08d}"),
                      ignore_errors=True)


def latest_steps(ckpt_dir: str) -> list[int]:
    if not os.path.isdir(ckpt_dir):
        return []
    out = []
    for name in os.listdir(ckpt_dir):
        m = re.fullmatch(r"step_(\d+)", name)
        if m and os.path.exists(os.path.join(ckpt_dir, name,
                                             "manifest.json")):
            out.append(int(m.group(1)))
    return sorted(out)


def restore(ckpt_dir: str, like, step: int | None = None,
            shardings=None):
    """Restore into the structure of ``like`` (a pytree of arrays or
    ShapeDtypeStructs).  ``shardings``: optional matching pytree of
    NamedSharding for elastic re-placement onto a (possibly different)
    mesh."""
    steps = latest_steps(ckpt_dir)
    if not steps:
        raise FileNotFoundError(f"no checkpoints under {ckpt_dir}")
    step = steps[-1] if step is None else step
    path = os.path.join(ckpt_dir, f"step_{step:08d}")
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)
    data = np.load(os.path.join(path, "arrays.npz"))

    flat_like, treedef = jax.tree_util.tree_flatten_with_path(like)
    by_name = {}
    for p, leaf in flat_like:
        name = "/".join(str(getattr(k, "key", k)) for k in p)
        by_name[name] = leaf
    missing = set(by_name) - set(manifest["names"])
    if missing:
        raise ValueError(f"checkpoint missing arrays: {sorted(missing)[:5]}")

    if shardings is not None:
        flat_sh = jax.tree_util.tree_flatten_with_path(shardings)[0]
        sh_by_name = {"/".join(str(getattr(k, "key", k)) for k in p): s
                      for p, s in flat_sh}
    else:
        sh_by_name = {}

    dtypes = manifest.get("dtypes", {})
    leaves = []
    for p, leaf in flat_like:
        name = "/".join(str(getattr(k, "key", k)) for k in p)
        arr = data[name]
        want = dtypes.get(name, "")
        if want in _VIEW_AS:
            arr = arr.view(getattr(ml_dtypes, want))
        sh = sh_by_name.get(name)
        if sh is not None:
            leaves.append(jax.device_put(arr, sh))
        else:
            leaves.append(jax.numpy.asarray(arr))
    tree = jax.tree_util.tree_unflatten(treedef, [l for _, l in flat_like])
    # rebuild with restored leaves in flatten order
    tree = jax.tree_util.tree_unflatten(treedef, leaves)
    return tree, manifest["step"], manifest.get("extra", {})
