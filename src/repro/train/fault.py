"""Fault-tolerance plumbing: preemption handling, straggler watchdog,
failure injection (for tests).

At 1000+ nodes the failure model is: (a) planned preemption (SIGTERM with
grace) -> checkpoint at the step boundary and exit 0 for the scheduler to
reschedule; (b) node loss -> the job restarts from the last atomic
checkpoint (restore is mesh-agnostic, so the replacement fleet may have a
different shape — elastic); (c) stragglers -> per-step wall-clock EWMA
flags slow steps; the runner logs and (on a real fleet) re-issues the
affected data shard to a hot spare.
"""

from __future__ import annotations

import os
import signal
import time
from dataclasses import dataclass, field


class PreemptionHandler:
    """SIGTERM -> set flag; trainer checkpoints at the next step boundary."""

    def __init__(self, signals=(signal.SIGTERM,)) -> None:
        self._requested = False
        self._installed = False
        self._signals = signals

    def install(self) -> None:
        if self._installed:
            return
        for s in self._signals:
            try:
                signal.signal(s, self._handler)
            except ValueError:   # non-main thread (tests)
                return
        self._installed = True

    def _handler(self, signum, frame) -> None:
        self._requested = True

    def request(self) -> None:    # test/injection hook
        self._requested = True

    @property
    def should_checkpoint_and_exit(self) -> bool:
        return self._requested


@dataclass
class StragglerWatchdog:
    """EWMA step-time monitor; flags steps slower than ``threshold`` x EWMA."""

    alpha: float = 0.1
    threshold: float = 2.0
    ewma: float = 0.0
    flagged: list = field(default_factory=list)
    _last: float = 0.0

    def start(self) -> None:
        self._last = time.monotonic()

    def stop(self, step: int) -> bool:
        dt = time.monotonic() - self._last
        is_straggler = self.ewma > 0 and dt > self.threshold * self.ewma
        if is_straggler:
            self.flagged.append((step, dt, self.ewma))
        # stragglers do not poison the EWMA
        if not is_straggler:
            self.ewma = dt if self.ewma == 0 else \
                (1 - self.alpha) * self.ewma + self.alpha * dt
        return is_straggler

    def mitigation_plan(self) -> str:
        """On a real fleet: re-dispatch the slow host's data shard to a hot
        spare and fence the host.  Here: structured log of the decision."""
        if not self.flagged:
            return "no stragglers"
        lines = [f"step {s}: {dt:.3f}s vs ewma {e:.3f}s -> "
                 "re-dispatch shard to spare; fence host"
                 for s, dt, e in self.flagged[-5:]]
        return "\n".join(lines)


def should_inject_failure(step: int) -> bool:
    """Deterministic failure injection driven by REPRO_FAIL_AT_STEP."""
    at = os.environ.get("REPRO_FAIL_AT_STEP")
    return at is not None and step == int(at)
