"""Pipeline parallelism over the "pod" axis (GPipe fill-drain).

The layer stack splits into S contiguous stages; stage s's parameters live
only on pod s (the stage dim of the stacked params is sharded on "pod").
Microbatches stream through: each step every stage runs its block on its
current activation, then ``ppermute`` shifts activations one stage right.
Fill-drain schedule => S + M - 1 steps for M microbatches; bubble fraction
(S-1)/(S+M-1).

This composes with the in-stage DP/TP sharding (shard_map is manual over
"pod" only; "data"/"model" stay auto/GSPMD).  Autodiff flows through
ppermute, so the same function trains — see tests/test_pipeline.py.

This is the cross-pod alternative to treating "pod" as an outer DP/FSDP
axis (the default in this repo): PP trades the cross-pod gradient
all-reduce for activation point-to-points of microbatch size — the right
trade when the inter-pod links are much slower than ICI (DCI-connected
multi-pod fleets).  Recorded as a selectable strategy, not the default.
"""

from __future__ import annotations


import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P


def pipeline_forward(stage_params, x_micro, stage_fn, mesh,
                     axis: str = "pod", dp_axes: tuple = ("data",)):
    """Run microbatches through the stage pipeline.

    stage_params: pytree with leading stage dim == mesh.shape[axis]
                  (sharded on ``axis``; replicated across ``dp_axes``).
    x_micro: [M, mb, ...] microbatched input activations; the mb dim is
             DP-sharded across ``dp_axes``.
    stage_fn(params_slice, x) -> y: one stage's computation.
    Returns [M, mb, ...] outputs (from the last stage).

    shard_map is fully manual over the mesh (ppermute needs manual
    axes); in-stage tensor parallelism inside stage_fn would use
    explicit collectives over the remaining axes.
    """
    dp = tuple(a for a in dp_axes if a in mesh.shape) or None
    n_stages = mesh.shape[axis]
    m = x_micro.shape[0]
    steps = n_stages + m - 1
    fwd_perm = [(i, i + 1) for i in range(n_stages - 1)]

    def local(sp, xs):
        # sp: this stage's params (leading dim 1) ; xs: [M, mb, ...]
        sp = jax.tree.map(lambda a: a[0], sp)
        sid = jax.lax.axis_index(axis)
        buf = jnp.zeros_like(xs[0])                    # incoming activation
        outs = jnp.zeros_like(xs)

        def step(carry, t):
            buf, outs = carry
            inject = jnp.clip(t, 0, m - 1)
            x_in = jnp.where(sid == 0, xs[inject], buf)
            y = stage_fn(sp, x_in)
            nxt = jax.lax.ppermute(y, axis, fwd_perm)
            # last stage emits microbatch t-(S-1) at step t
            out_idx = jnp.clip(t - (n_stages - 1), 0, m - 1)
            emit = (sid == n_stages - 1) & (t >= n_stages - 1)
            outs = jax.lax.dynamic_update_index_in_dim(
                outs, jnp.where(emit, y, outs[out_idx]), out_idx, 0)
            return (nxt, outs), None

        (_, outs), _ = jax.lax.scan(step, (buf, outs),
                                    jnp.arange(steps))
        # broadcast the last stage's outputs to every stage
        last = jnp.zeros_like(outs).at[...].set(
            jnp.where(sid == n_stages - 1, outs, 0))
        return jax.lax.psum(last, axis)

    return jax.shard_map(
        local, mesh=mesh,
        in_specs=(P(axis), P(None, dp)), out_specs=P(None, dp),
        check_vma=False)(stage_params, x_micro)


def split_stages(stacked_params, n_stages: int):
    """[L, ...] stacked layer params -> [S, L/S, ...] stage-stacked."""
    def re(a):
        l = a.shape[0]
        assert l % n_stages == 0, (l, n_stages)
        return a.reshape(n_stages, l // n_stages, *a.shape[1:])
    return jax.tree.map(re, stacked_params)


def make_stage_fn(layer_fn):
    """Wrap a single-layer fn into a stage fn scanning its layer slice."""
    def stage_fn(stage_layers, x):
        def body(carry, lp):
            return layer_fn(lp, carry), None
        y, _ = jax.lax.scan(body, x, stage_layers)
        return y
    return stage_fn


def bubble_fraction(n_stages: int, n_micro: int) -> float:
    return (n_stages - 1) / (n_stages + n_micro - 1)
