"""Training loop: jit'd train_step with explicit shardings, microbatch
gradient accumulation, checkpoint/restart, preemption, straggler watchdog.
"""

from __future__ import annotations

from dataclasses import dataclass, field

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from .. import optim, sharding
from ..models import api
from . import checkpoint, fault


@dataclass
class TrainConfig:
    steps: int = 100
    lr: float = 3e-4
    warmup: int = 20
    ckpt_dir: str = ""
    ckpt_every: int = 50
    log_every: int = 10
    remat: str = "full"
    unroll: bool = False     # dry-run cost analysis (see layers.scan_layers)
    microbatch: int = 0      # >0: gradient accumulation in chunks of this
    opt: optim.AdamWConfig = field(default_factory=optim.AdamWConfig)


def init_state(cfg, train_cfg: TrainConfig, key) -> dict:
    params = api.init_params(cfg, key)
    return {"params": params, "opt": optim.init(params, train_cfg.opt)}


def make_train_step(cfg, train_cfg: TrainConfig):
    lr_fn = optim.cosine_schedule(train_cfg.lr, train_cfg.warmup,
                                  train_cfg.steps)

    def loss_of(params, batch):
        return api.loss_fn(cfg, params, batch, remat=train_cfg.remat,
                           unroll=train_cfg.unroll)

    def grads_of(params, batch):
        mb = train_cfg.microbatch
        b = jax.tree.leaves(batch)[0].shape[0]
        if not mb or mb >= b:
            return jax.value_and_grad(loss_of)(params, batch)
        # gradient accumulation over microbatches (scan); accumulator in
        # param dtype (bf16): <=8 additions, saves a params-sized f32
        n = b // mb
        split = jax.tree.map(
            lambda x: x.reshape(n, mb, *x.shape[1:]), batch)

        def acc_fn(carry, mbatch):
            loss, g = jax.value_and_grad(loss_of)(params, mbatch)
            # ZeRO-2: reduce-scatter each microbatch's grads immediately
            # (otherwise every microbatch pays a full all-reduce)
            g = sharding.constrain_like_params(g)
            carry = (carry[0] + loss,
                     jax.tree.map(lambda a, b_: a + b_.astype(a.dtype),
                                  carry[1], g))
            return carry, None

        zero = (jnp.zeros(()), jax.tree.map(
            lambda p: jnp.zeros(p.shape, p.dtype), params))
        from ..models import layers as _l
        (loss, grads), _ = _l.inner_scan(acc_fn, zero, split, n)
        return loss / n, jax.tree.map(lambda g: g / n, grads)

    def train_step(state, batch, step):
        loss, grads = grads_of(state["params"], batch)
        # pin gradients to the parameter sharding: the batch-reduction
        # lowers to reduce-scatter on the FSDP axis instead of all-reduce
        grads = sharding.constrain_like_params(grads)
        lr = lr_fn(step)
        new_params, new_opt, gnorm = optim.update(
            grads, state["opt"], state["params"], lr, train_cfg.opt)
        metrics = {"loss": loss, "gnorm": gnorm, "lr": lr}
        return {"params": new_params, "opt": new_opt}, metrics

    return train_step


def state_shardings(state, mesh):
    """NamedSharding pytree for the train state (opt moments mirror
    params — ZeRO-1 via the FSDP axis in the param specs)."""
    axes = dict(mesh.shape)
    pspecs = sharding.tree_param_specs(state["params"], axes)

    def named(spec):
        return NamedSharding(mesh, spec)
    out = {
        "params": jax.tree.map(named, pspecs),
        "opt": {
            "m": jax.tree.map(named, pspecs),
            "v": jax.tree.map(named, pspecs),
            "count": NamedSharding(mesh, P()),
        },
    }
    return out


def batch_shardings(batch, mesh):
    dp = tuple(a for a in ("pod", "data") if a in mesh.shape)
    def one(x):
        spec = [None] * x.ndim
        if x.ndim and dp:
            spec[0] = dp
        return NamedSharding(mesh, P(*spec))
    return jax.tree.map(one, batch)


def run(cfg, train_cfg: TrainConfig, data_iter, *, mesh=None, state=None,
        key=None, callbacks=()):
    """Full training loop with restart/preemption/straggler handling.

    Returns (state, history).  ``data_iter`` yields (step, batch) so the
    pipeline is restart-consistent by construction.
    """
    key = key if key is not None else jax.random.PRNGKey(0)
    start_step = 0
    if state is None:
        state = init_state(cfg, train_cfg, key)
        if train_cfg.ckpt_dir and checkpoint.latest_steps(train_cfg.ckpt_dir):
            state, start_step, _ = checkpoint.restore(train_cfg.ckpt_dir,
                                                      state)
            print(f"[trainer] resumed from step {start_step}")

    step_fn = make_train_step(cfg, train_cfg)
    if mesh is not None:
        shardings = state_shardings(state, mesh)
        state = jax.device_put(state, shardings)
        step_fn = jax.jit(step_fn,
                          in_shardings=(shardings, None, None),
                          out_shardings=(shardings, None),
                          donate_argnums=(0,))
    else:
        step_fn = jax.jit(step_fn, donate_argnums=(0,))

    preempt = fault.PreemptionHandler()
    preempt.install()
    watchdog = fault.StragglerWatchdog()
    history = []
    for step, batch in data_iter:
        if step < start_step:
            continue
        if step >= train_cfg.steps:
            break
        watchdog.start()
        state, metrics = step_fn(state, batch, jnp.asarray(step))
        loss = float(metrics["loss"])
        watchdog.stop(step)
        history.append({"step": step, "loss": loss,
                        "gnorm": float(metrics["gnorm"])})
        if step % train_cfg.log_every == 0:
            print(f"[trainer] step {step} loss {loss:.4f} "
                  f"gnorm {float(metrics['gnorm']):.3f}")
        for cb in callbacks:
            cb(step, state, metrics)
        if fault.should_inject_failure(step):
            raise RuntimeError(f"injected failure at step {step}")
        done = step + 1 >= train_cfg.steps
        if train_cfg.ckpt_dir and (
                (step + 1) % train_cfg.ckpt_every == 0 or done
                or preempt.should_checkpoint_and_exit):
            checkpoint.save(train_cfg.ckpt_dir, step + 1, state)
        if preempt.should_checkpoint_and_exit:
            print("[trainer] preemption: checkpointed, exiting cleanly")
            break
    if watchdog.flagged:
        print("[trainer] straggler mitigation:\n"
              + watchdog.mitigation_plan())
    return state, history
