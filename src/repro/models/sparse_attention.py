"""TopK sparse-KV decode attention — the paper's technique as a model layer.

Double-Sparsity/H2O-style decode: approximate per-page scores from a small
*label cache* (page-pooled key summaries), select the TopK pages, and attend
only to those pages.  The gather is the NVR-accelerated operation: on TPU it
lowers to the ``sparse_decode_attn`` Pallas kernel (scalar-prefetched
runahead); the XLA path (used under pjit and on CPU) expresses the same
computation with ``take_along_axis``.

For sequence-sharded caches (long_500k) ``sparse_decode_sharded`` runs the
selection per shard under ``shard_map`` and merges partial attention with a
log-sum-exp combine: the attended set is the union of per-shard TopKs — a
coverage-oriented superset of the global TopK (the paper's fuzzy-fetch
philosophy, applied across chips).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P


# int8 KV-cache quantisation (beyond-paper §Perf lever): fixed-scale
# symmetric quant; quality is checked in tests (corr > 0.99 vs bf16 at
# full coverage).  The scale's canonical home is the kernel package —
# the Pallas paged kernel dequantises with the same constant, and
# kernels must not import the model stack.
from ..kernels.paged_decode_attn import KV_QSCALE


def kv_quant(x: jax.Array, dtype) -> jax.Array:
    if jnp.dtype(dtype) != jnp.int8:
        return x.astype(dtype)
    return jnp.clip(jnp.round(x.astype(jnp.float32) * KV_QSCALE),
                    -127, 127).astype(jnp.int8)


def kv_dequant_f32(x: jax.Array) -> jax.Array:
    if x.dtype == jnp.int8:
        return x.astype(jnp.float32) * (1.0 / KV_QSCALE)
    return x.astype(jnp.float32)


def page_scores(q: jax.Array, kpage: jax.Array) -> jax.Array:
    """q [B,KV,G,D], kpage [B,NP,KV,D] -> scores [B,KV,NP] (max over group)."""
    s = jnp.einsum("bkgd,bpkd->bkgp", q.astype(jnp.float32),
                   kpage.astype(jnp.float32))
    return jnp.max(s, axis=2)


def select_pages(q: jax.Array, kpage: jax.Array, n_pages_valid: jax.Array,
                 k_pages: int) -> jax.Array:
    """TopK page ids per (batch, kv head); invalid pages score -inf."""
    s = page_scores(q, kpage)                       # [B,KV,NP]
    npg = s.shape[-1]
    valid = jnp.arange(npg)[None, None, :] < n_pages_valid
    s = jnp.where(valid, s, -jnp.inf)
    _, idx = jax.lax.top_k(s, k_pages)
    return idx.astype(jnp.int32)


def select_pages_recorded(q: jax.Array, kpage: jax.Array,
                          n_pages_valid: jax.Array, k_pages: int,
                          stream) -> jax.Array:
    """``select_pages`` + trace capture: records the concrete selection
    into a :class:`repro.core.nvr.capture.PageStream` (one event per
    (batch, kv-head) slot) so serving traffic can be replayed through the
    NVR simulator.  Must run outside jit (the recorder needs values)."""
    idx = select_pages(q, kpage, n_pages_valid, k_pages)
    stream.record_batched(np.asarray(idx))
    return idx


def page_token_positions(idx: jax.Array, page: int) -> jax.Array:
    """Absolute token positions ``[..., P, page]`` of the tokens inside
    the selected pages ``idx [..., P]`` (shared by the attend variants
    and the capture adapters)."""
    return idx[..., None] * page + jnp.arange(page)


def attend_pages(q: jax.Array, k: jax.Array, v: jax.Array, idx: jax.Array,
                 pos: jax.Array, page: int) -> jax.Array:
    """Attend q [B,KV,G,D] to gathered pages of k/v [B,S,KV,D].

    idx [B,KV,P] page ids; tokens at absolute position > pos are masked
    (a selected page may straddle the frontier).
    Returns [B,KV,G,D].
    """
    b, s, kv, d = k.shape
    kp = k.reshape(b, s // page, page, kv, d)
    vp = v.reshape(b, s // page, page, kv, d)
    bi = jnp.arange(b)[:, None, None]
    hi = jnp.arange(kv)[None, :, None]
    kg = jnp.moveaxis(kp, 3, 1)[bi, hi, idx]        # [B,KV,P,page,D]
    vg = jnp.moveaxis(vp, 3, 1)[bi, hi, idx]
    scores = jnp.einsum("bkgd,bkptd->bkgpt", q.astype(jnp.float32),
                        kg.astype(jnp.float32)) / (d ** 0.5)
    tok_pos = page_token_positions(idx, page)
    mask = tok_pos <= pos                           # [B,KV,P,page]
    scores = jnp.where(mask[:, :, None], scores, -jnp.inf)
    bp, pt = scores.shape[-2], scores.shape[-1]
    flat = scores.reshape(*scores.shape[:-2], bp * pt)
    w = jax.nn.softmax(flat, axis=-1).reshape(scores.shape)
    out = jnp.einsum("bkgpt,bkptd->bkgd", w, vg.astype(jnp.float32))
    return out.astype(q.dtype)


def sparse_decode(q: jax.Array, k: jax.Array, v: jax.Array,
                  kpage: jax.Array, pos: jax.Array, *, page: int,
                  k_pages: int) -> jax.Array:
    """Full sparse decode: select + attend.  q [B,KV,G,D] -> [B,KV,G,D]."""
    n_valid = (pos // page) + 1
    idx = select_pages(q, kpage, n_valid, k_pages)
    return attend_pages(q, k, v, idx, pos, page)


def sparse_decode_distributed(q, k, v, kpage, pos, *, page: int,
                              k_pages: int, mesh, batch_axes=(),
                              seq_axes=(), kv_axes=()):
    """Distributed TopK sparse decode under shard_map.

    Three orthogonal shardings compose:
      * ``batch_axes``  — B sharded (DP), selection independent per row.
      * ``kv_axes``     — KV heads sharded (TP), selection per local head.
      * ``seq_axes``    — the KV *sequence* sharded (SP, long_500k): each
        shard TopKs its local pages and partial attentions merge with a
        log-sum-exp psum.  The attended set is the union of per-shard
        TopKs — a coverage-oriented superset of the global TopK (the
        paper's fuzzy-fetch philosophy across chips).

    q [B,KV,G,D]; k/v [B,S,KV,D]; kpage [B,NP,KV,D]; pos scalar.
    """
    from jax.experimental.shard_map import shard_map

    ba = tuple(a for a in batch_axes if a in mesh.shape)
    sa = tuple(a for a in seq_axes if a in mesh.shape)
    ka = tuple(a for a in kv_axes if a in mesh.shape)
    n_seq = 1
    for a in sa:
        n_seq *= mesh.shape[a]
    # coverage-oriented local budget: over-select 4x the proportional share
    k_local = max(2, (4 * k_pages) // n_seq) if n_seq > 1 else k_pages

    def local(qv, kl, vl, kpl, posv):
        b, sl, kv_h, d = kl.shape
        npl = kpl.shape[1]
        start = (jax.lax.axis_index(sa) * sl) if sa else 0
        local_pos = posv - start
        n_valid = jnp.clip(local_pos // page + 1, 0, npl)
        kp = int(min(k_local, npl))
        s = page_scores(qv, kpl)
        valid = jnp.arange(npl)[None, None, :] < n_valid
        s = jnp.where(valid, s, -jnp.inf)
        _, idx = jax.lax.top_k(s, kp)
        idx = idx.astype(jnp.int32)
        kpg = kl.reshape(b, sl // page, page, kv_h, d)
        vpg = vl.reshape(b, sl // page, page, kv_h, d)
        bi = jnp.arange(b)[:, None, None]
        hi = jnp.arange(kv_h)[None, :, None]
        kg = jnp.moveaxis(kpg, 3, 1)[bi, hi, idx]
        vg = jnp.moveaxis(vpg, 3, 1)[bi, hi, idx]
        sc = jnp.einsum("bkgd,bkptd->bkgpt", qv.astype(jnp.float32),
                        kg.astype(jnp.float32)) / (d ** 0.5)
        tok = start + page_token_positions(idx, page)
        mask = tok <= posv
        sc = jnp.where(mask[:, :, None], sc, -jnp.inf)
        flat = sc.reshape(*sc.shape[:3], -1)
        m = jnp.max(flat, axis=-1)
        m_safe = jnp.where(jnp.isfinite(m), m, 0.0)
        p = jnp.exp(flat - m_safe[..., None])
        p = jnp.where(jnp.isfinite(flat), p, 0.0)
        l = jnp.sum(p, axis=-1)
        acc = jnp.einsum("bkgn,bknd->bkgd", p,
                         vg.reshape(b, kv_h, -1, d))
        if not sa:
            out = acc / jnp.maximum(l, 1e-30)[..., None]
            return out.astype(qv.dtype)
        # LSE merge across sequence shards
        m_glob = jax.lax.pmax(m, sa)
        m_gsafe = jnp.where(jnp.isfinite(m_glob), m_glob, 0.0)
        scale = jnp.where(jnp.isfinite(m), jnp.exp(m - m_gsafe), 0.0)
        l_glob = jax.lax.psum(l * scale, sa)
        acc_glob = jax.lax.psum(acc * scale[..., None], sa)
        out = acc_glob / jnp.maximum(l_glob, 1e-30)[..., None]
        return out.astype(qv.dtype)

    bspec = ba if ba else None
    kspec = ka if ka else None
    sspec = sa if sa else None
    return shard_map(
        local, mesh=mesh,
        in_specs=(P(bspec, kspec, None, None),
                  P(bspec, sspec, kspec, None),
                  P(bspec, sspec, kspec, None),
                  P(bspec, sspec, kspec, None), P()),
        out_specs=P(bspec, kspec, None, None), check_rep=False)(
            q, k, v, kpage, pos)


# -- layer-indexed ("full-cache") variants -------------------------------------
#
# §Perf iteration: the scan-carried cache is [L,B,S,KV,D]; slicing layer li
# out (dynamic_index) and transposing (moveaxis) copies the WHOLE layer
# cache every step — O(cache) HBM traffic for an O(TopK) computation.
# These variants gather straight from the stacked cache with the layer
# index folded into the gather, so traffic is O(pages_read) as the paper
# intends.

def gather_pages_full(cache_full: jax.Array, li, idx: jax.Array,
                      page: int) -> jax.Array:
    """cache_full [L,B,S,KV,D], idx [B,KV,P] -> [B,KV,P,page,D] (one fused
    gather, no per-layer slice/transpose copies)."""
    l, b, s, kv, d = cache_full.shape
    kp6 = cache_full.reshape(l, b, s // page, page, kv, d)
    bi = jnp.arange(b)[:, None, None]
    hi = jnp.arange(kv)[None, :, None]
    return kp6[li, bi, idx, :, hi, :]


def attend_pages_full(q, k_full, v_full, li, idx, pos, page: int):
    """q [B,KV,G,D] attends gathered pages of layer ``li``."""
    d = q.shape[-1]
    kg = kv_dequant_f32(gather_pages_full(k_full, li, idx, page))
    vg = kv_dequant_f32(gather_pages_full(v_full, li, idx, page))
    scores = jnp.einsum("bkgd,bkptd->bkgpt", q.astype(jnp.float32),
                        kg) / (d ** 0.5)
    tok_pos = page_token_positions(idx, page)
    mask = tok_pos <= pos
    scores = jnp.where(mask[:, :, None], scores, -jnp.inf)
    bp, pt = scores.shape[-2], scores.shape[-1]
    flat = scores.reshape(*scores.shape[:-2], bp * pt)
    w = jax.nn.softmax(flat, axis=-1).reshape(scores.shape)
    out = jnp.einsum("bkgpt,bkptd->bkgd", w, vg)
    return out.astype(q.dtype)


def sparse_decode_full(q, k_full, v_full, kpage_li, li, pos, *, page: int,
                       k_pages: int):
    """Layer-indexed sparse decode: kpage_li [B,NP,KV,D] is this layer's
    (small) label cache; K/V pages gather straight from the stacked
    cache."""
    n_valid = (pos // page) + 1
    idx = select_pages(q, kpage_li, n_valid, k_pages)
    return attend_pages_full(q, k_full, v_full, li, idx, pos, page)


def sparse_decode_distributed_full(q, k_full, v_full, kpage_li, li, pos, *,
                                   page: int, k_pages: int, mesh,
                                   batch_axes=(), seq_axes=(), kv_axes=()):
    """Distributed variant of ``sparse_decode_full`` (shard_map)."""
    from jax.experimental.shard_map import shard_map

    ba = tuple(a for a in batch_axes if a in mesh.shape)
    sa = tuple(a for a in seq_axes if a in mesh.shape)
    ka = tuple(a for a in kv_axes if a in mesh.shape)
    n_seq = 1
    for a in sa:
        n_seq *= mesh.shape[a]
    k_local = max(2, (4 * k_pages) // n_seq) if n_seq > 1 else k_pages

    def local(qv, kl, vl, kpl, liv, posv):
        b, npl, kv_h, d = kpl.shape
        sl = kl.shape[2]
        start = (jax.lax.axis_index(sa) * sl) if sa else 0
        local_pos = posv - start
        n_valid = jnp.clip(local_pos // page + 1, 0, npl)
        kp = int(min(k_local, npl))
        s = page_scores(qv, kpl)
        valid = jnp.arange(npl)[None, None, :] < n_valid
        s = jnp.where(valid, s, -jnp.inf)
        _, idx = jax.lax.top_k(s, kp)
        idx = idx.astype(jnp.int32)
        kg = kv_dequant_f32(gather_pages_full(kl, liv, idx, page))
        vg = kv_dequant_f32(gather_pages_full(vl, liv, idx, page))
        sc = jnp.einsum("bkgd,bkptd->bkgpt", qv.astype(jnp.float32),
                        kg) / (d ** 0.5)
        tok = start + page_token_positions(idx, page)
        mask = tok <= posv
        sc = jnp.where(mask[:, :, None], sc, -jnp.inf)
        flat = sc.reshape(*sc.shape[:3], -1)
        m = jnp.max(flat, axis=-1)
        m_safe = jnp.where(jnp.isfinite(m), m, 0.0)
        p = jnp.exp(flat - m_safe[..., None])
        p = jnp.where(jnp.isfinite(flat), p, 0.0)
        l = jnp.sum(p, axis=-1)
        acc = jnp.einsum("bkgn,bknd->bkgd", p,
                         vg.reshape(b, kv_h, -1, d))
        if not sa:
            out = acc / jnp.maximum(l, 1e-30)[..., None]
            return out.astype(qv.dtype)
        m_glob = jax.lax.pmax(m, sa)
        m_gsafe = jnp.where(jnp.isfinite(m_glob), m_glob, 0.0)
        scale = jnp.where(jnp.isfinite(m), jnp.exp(m - m_gsafe), 0.0)
        l_glob = jax.lax.psum(l * scale, sa)
        acc_glob = jax.lax.psum(acc * scale[..., None], sa)
        out = acc_glob / jnp.maximum(l_glob, 1e-30)[..., None]
        return out.astype(qv.dtype)

    bspec = ba if ba else None
    kspec = ka if ka else None
    sspec = sa if sa else None
    return shard_map(
        local, mesh=mesh,
        in_specs=(P(bspec, kspec, None, None),
                  P(None, bspec, sspec, kspec, None),
                  P(None, bspec, sspec, kspec, None),
                  P(bspec, sspec, kspec, None), P(), P()),
        out_specs=P(bspec, kspec, None, None), check_rep=False)(
            q, k_full, v_full, kpage_li, li, pos)


# -- block-table-indexed ("paged") variants ------------------------------------
#
# Continuous-batching serve path: the KV cache is a pool of physical pages
# shared by all requests; each request maps logical page j -> physical page
# bt[j].  Selection scores logical pages from the physical page-summary
# pool and returns BOTH index spaces: logical ids feed the causal masking
# (absolute token positions), physical ids feed the gather — and are the
# very ids the KV allocator, the NSB hot-set model (capture.PageCache),
# and the captured simulator trace account in.

def select_pages_blocktable(q: jax.Array, kpage_pool_li: jax.Array,
                            block_table: jax.Array, n_pages_valid: jax.Array,
                            k_pages: int) -> tuple[jax.Array, jax.Array]:
    """TopK pages through a block table.

    q [R,KV,G,D]; kpage_pool_li [P,KV,D] (physical page summaries, one
    layer); block_table [R,NL] physical ids (NULL-padded); n_pages_valid
    [R].  Returns (logical idx [R,KV,K], physical idx [R,KV,K]).
    """
    kp = kpage_pool_li[block_table]                 # [R,NL,KV,D]
    s = page_scores(q, kp)                          # [R,KV,NL]
    nl = s.shape[-1]
    valid = jnp.arange(nl)[None, None, :] < n_pages_valid[:, None, None]
    s = jnp.where(valid, s, -jnp.inf)
    _, idx = jax.lax.top_k(s, k_pages)
    idx = idx.astype(jnp.int32)
    bt_b = jnp.broadcast_to(block_table[:, None, :],
                            (idx.shape[0], idx.shape[1], nl))
    phys = jnp.take_along_axis(bt_b, idx, axis=-1).astype(jnp.int32)
    return idx, phys


def attend_pages_paged(q: jax.Array, k_pool_li: jax.Array,
                       v_pool_li: jax.Array, idx: jax.Array,
                       phys: jax.Array, pos: jax.Array,
                       page: int, tp_axis: str | None = None,
                       hot_map: jax.Array | None = None,
                       n_demand: int = 0) -> jax.Array:
    """Attend q [R,KV,G,D] to physically-gathered pages.

    k_pool_li / v_pool_li [P,page,KV,D] (one layer of the pool); idx
    [R,KV,K] logical page ids (for position masking), phys [R,KV,K]
    physical page ids (for the gather); pos [R] per-request frontier.
    Fully-masked rows (padded batch slots) produce zeros, not NaNs.

    ``tp_axis`` (inside ``shard_map`` only): the KV-head axis is sharded
    — q/idx/phys and the pools carry this shard's head slice.  The page
    *gather* runs locally against the local pool slice (the
    memory-local NVR operation), then the small gathered TopK tiles —
    not the pools — are all-gathered and the attention math runs at the
    full-KV shape, identically replicated on every shard.  That split
    is what keeps tp>1 *bitwise* equal to tp=1: XLA's fused
    scores/softmax lowering is shape- and head-position-dependent at
    ulp level, so per-head math must run at the same shapes/positions
    as the unsharded oracle.  Returns the full-head [R,KV_total,G,D]
    when ``tp_axis`` is given.

    ``hot_map``/``n_demand`` (runahead): page ids with a staged NSB
    slot (``hot_map[p] >= 0``) redirect to the pool's contiguous
    staging tail at ``n_demand + slot`` — a byte-exact copy, so the
    output is bitwise-unchanged; only where the bytes are read from
    moves.  The remap happens *before* the tp all-gather, on local
    ids: the hot-map is replicated and the page axis never sharded,
    so every shard resolves identically.
    """
    if hot_map is not None:
        slot = hot_map[phys]                       # [R,KV,K]; -1 = demand
        phys = jnp.where(slot >= 0, n_demand + slot, phys)
    kv = k_pool_li.shape[2]
    hi = jnp.arange(kv)[None, :, None]
    # advanced indices (phys [R,KV,K], head [1,KV,1]) broadcast together,
    # picking each KV head's own selected pages: [R,KV,K,page,D]
    kg = kv_dequant_f32(k_pool_li[phys, :, hi])
    vg = kv_dequant_f32(v_pool_li[phys, :, hi])
    if tp_axis is not None:
        q, idx, kg, vg = jax.lax.all_gather(
            (q, idx, kg, vg), tp_axis, axis=1, tiled=True)
    d = q.shape[-1]
    scores = jnp.einsum("bkgd,bkptd->bkgpt", q.astype(jnp.float32),
                        kg) / (d ** 0.5)
    tok_pos = page_token_positions(idx, page)       # [R,KV,K,page]
    mask = tok_pos <= pos[:, None, None, None]
    scores = jnp.where(mask[:, :, None], scores, -jnp.inf)
    bp, pt = scores.shape[-2], scores.shape[-1]
    flat = scores.reshape(*scores.shape[:-2], bp * pt)
    m = jnp.max(flat, axis=-1, keepdims=True)
    m = jnp.where(jnp.isfinite(m), m, 0.0)
    p = jnp.exp(flat - m)
    p = jnp.where(jnp.isfinite(flat), p, 0.0)
    w = (p / jnp.maximum(p.sum(-1, keepdims=True), 1e-30)
         ).reshape(scores.shape)
    out = jnp.einsum("bkgpt,bkptd->bkgd", w, vg)
    return out.astype(q.dtype)


def attend_pages_paged_kernel(q: jax.Array, k_pool_li: jax.Array,
                              v_pool_li: jax.Array, idx: jax.Array,
                              phys: jax.Array, pos: jax.Array, page: int,
                              interpret: bool | None = None,
                              hot_map: jax.Array | None = None,
                              n_demand: int = 0) -> jax.Array:
    """Pallas-kernel twin of :func:`attend_pages_paged`.

    Same signature, same masking semantics, same fp32 online-softmax
    numerics (tolerance-level, not bitwise: the kernel streams pages
    through a running max/sum while the XLA path materialises the full
    gather then normalises once).  On TPU the selected pages are
    scalar-prefetched and the grid pipeline double-buffers the indirect
    page DMAs — the NVR runahead mechanism on the serve layer's native
    block-table layout; off-TPU it runs in interpret mode.  The XLA path
    stays the CPU fallback and the parity oracle.
    """
    from ..kernels.paged_decode_attn import paged_decode_attn
    return paged_decode_attn(phys, idx, pos, q, k_pool_li, v_pool_li,
                             page_size=page, interpret=interpret,
                             hot_map=hot_map, n_demand=n_demand)


def page_summary_from_pool(k_pool_li: jax.Array, phys: jax.Array,
                           n_tokens: jax.Array) -> jax.Array:
    """Exact label-cache entries for pool pages: mean of the first
    ``n_tokens`` keys of each page ``phys``.

    k_pool_li [P,page,KV,D]; phys [M]; n_tokens [M] (>=1).  Returns
    [M,KV,D].  Both the chunked-prefill and the paged-decode paths
    recompute summaries through this one function so the selection
    scores cannot drift between the two (preemption-recompute relies on
    bitwise-identical replay).
    """
    rows = kv_dequant_f32(k_pool_li[phys])          # [M,page,KV,D]
    page = rows.shape[1]
    tmask = (jnp.arange(page)[None, :, None, None]
             < n_tokens[:, None, None, None])
    cnt = jnp.maximum(n_tokens, 1).astype(jnp.float32)
    return (rows * tmask).sum(axis=1) / cnt[:, None, None]


def update_page_summary(kpage: jax.Array, k_new: jax.Array, pos: jax.Array,
                        page: int) -> jax.Array:
    """Incremental label-cache update: running mean of keys per page.

    kpage [B,NP,KV,D]; k_new [B,1,KV,D] written at absolute position pos.
    Implemented as a masked elementwise update: a dynamic-start slice on
    the (sequence-sharded) page dim would force GSPMD to all-gather the
    whole label cache every layer (§Perf iteration 2 — measured 537 MB/
    layer on gemma long_500k).
    """
    p_id = pos // page
    off = (pos % page).astype(jnp.float32)
    match = (jnp.arange(kpage.shape[1]) == p_id)[None, :, None, None]
    upd = (kpage * off + k_new.astype(kpage.dtype)) / (off + 1.0)
    return jnp.where(match, upd, kpage)
