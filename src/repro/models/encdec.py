"""SeamlessM4T-medium backbone: encoder-decoder transformer.

The speech frontend is a STUB per the assignment: ``input_specs`` provides
precomputed frame embeddings [B, S_src, d].  Decoder = causal self-attn +
cross-attn over encoder memory.  At decode time the paper's technique
applies twice: TopK sparse self-attn KV (long targets) and TopK sparse
*cross*-attention over long encoder memories.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .. import sharding
from . import layers, sparse_attention

Params = dict


def _dtype(cfg):
    return jnp.dtype(cfg.param_dtype)


def _attn_params(cfg, key, prefix=""):
    dt = _dtype(cfg)
    d, hd = cfg.d_model, cfg.hd
    ks = iter(jax.random.split(key, 4))
    return {
        f"{prefix}wq": layers.dense_init(next(ks), (d, cfg.n_heads * hd), dt),
        f"{prefix}wk": layers.dense_init(next(ks), (d, cfg.n_kv_heads * hd), dt),
        f"{prefix}wv": layers.dense_init(next(ks), (d, cfg.n_kv_heads * hd), dt),
        f"{prefix}wo": layers.dense_init(next(ks), (cfg.n_heads * hd, d), dt),
    }


def _mlp_params(cfg, key):
    dt = _dtype(cfg)
    ks = iter(jax.random.split(key, 2))
    return {
        "wi": layers.dense_init(next(ks), (cfg.d_model, cfg.d_ff), dt),
        "wo_mlp": layers.dense_init(next(ks), (cfg.d_ff, cfg.d_model), dt),
    }


def init_enc_layer(cfg, key):
    k1, k2 = jax.random.split(key)
    p = {"ln1": jnp.zeros((cfg.d_model,), jnp.float32),
         "ln2": jnp.zeros((cfg.d_model,), jnp.float32)}
    p.update(_attn_params(cfg, k1))
    p.update(_mlp_params(cfg, k2))
    return p


def init_dec_layer(cfg, key):
    k1, k2, k3 = jax.random.split(key, 3)
    p = {"ln1": jnp.zeros((cfg.d_model,), jnp.float32),
         "lnx": jnp.zeros((cfg.d_model,), jnp.float32),
         "ln2": jnp.zeros((cfg.d_model,), jnp.float32)}
    p.update(_attn_params(cfg, k1))
    p.update(_attn_params(cfg, k3, prefix="x_"))
    p.update(_mlp_params(cfg, k2))
    return p


def init_params(cfg, key) -> Params:
    k_emb, k_enc, k_dec, k_head = jax.random.split(key, 4)
    return {
        "embed": layers.dense_init(k_emb, (cfg.vocab, cfg.d_model),
                                   _dtype(cfg), 0.02),
        "enc_layers": layers.stack_layer_params(
            functools.partial(init_enc_layer, cfg), cfg.n_enc_layers, k_enc),
        "dec_layers": layers.stack_layer_params(
            functools.partial(init_dec_layer, cfg), cfg.n_layers, k_dec),
        "ln_enc": jnp.zeros((cfg.d_model,), jnp.float32),
        "ln_f": jnp.zeros((cfg.d_model,), jnp.float32),
    }


def _self_attn(cfg, x, p, causal, pos_offset=0, prefix=""):
    b, s, _ = x.shape
    hd = cfg.hd
    q = jnp.einsum("bsd,dh->bsh", x, p[f"{prefix}wq"].astype(x.dtype)
                   ).reshape(b, s, cfg.n_heads, hd)
    k = jnp.einsum("bsd,dh->bsh", x, p[f"{prefix}wk"].astype(x.dtype)
                   ).reshape(b, s, cfg.n_kv_heads, hd)
    v = jnp.einsum("bsd,dh->bsh", x, p[f"{prefix}wv"].astype(x.dtype)
                   ).reshape(b, s, cfg.n_kv_heads, hd)
    pos = pos_offset + jnp.arange(s)[None, :]
    q = layers.apply_rope(q, pos, cfg.rope_theta)
    k = layers.apply_rope(k, pos, cfg.rope_theta)
    o = layers.chunked_attention(q, k, v, causal=causal, chunk=min(1024, s))
    return jnp.einsum("bsh,hd->bsd", o.reshape(b, s, -1),
                      p[f"{prefix}wo"].astype(x.dtype)), (k, v)


def _cross_attn(cfg, x, memory_kv, p):
    b, s, _ = x.shape
    hd = cfg.hd
    q = jnp.einsum("bsd,dh->bsh", x, p["x_wq"].astype(x.dtype)
                   ).reshape(b, s, cfg.n_heads, hd)
    k, v = memory_kv
    o = layers.chunked_attention(q, k, v, causal=False,
                                 chunk=min(1024, k.shape[1]))
    return jnp.einsum("bsh,hd->bsd", o.reshape(b, s, -1),
                      p["x_wo"].astype(x.dtype))


def encode(params, cfg, src_embeds, *, remat: str = "full",
           unroll: bool = False):
    x = src_embeds.astype(_dtype(cfg))
    x = sharding.constrain(x, "batch", None, None)

    def body(carry, lp):
        h = layers.rms_norm(carry, lp["ln1"], cfg.norm_eps)
        y, _ = _self_attn(cfg, h, lp, causal=False)
        x2 = carry + y
        h2 = layers.rms_norm(x2, lp["ln2"], cfg.norm_eps)
        u = jax.nn.relu(jnp.einsum("bsd,df->bsf", h2,
                                   lp["wi"].astype(h2.dtype)))
        u = sharding.constrain(u, "batch", None, "mlp")
        return x2 + jnp.einsum("bsf,fd->bsd", u,
                               lp["wo_mlp"].astype(h2.dtype)), None

    if remat == "full":
        body = jax.checkpoint(body,
                              policy=jax.checkpoint_policies.nothing_saveable)
    x, _ = layers.scan_layers(body, x, params["enc_layers"], unroll)
    return layers.rms_norm(x, params["ln_enc"], cfg.norm_eps)


def _memory_kv(cfg, memory, lp):
    b, s, _ = memory.shape
    k = jnp.einsum("bsd,dh->bsh", memory, lp["x_wk"].astype(memory.dtype)
                   ).reshape(b, s, cfg.n_kv_heads, cfg.hd)
    v = jnp.einsum("bsd,dh->bsh", memory, lp["x_wv"].astype(memory.dtype)
                   ).reshape(b, s, cfg.n_kv_heads, cfg.hd)
    return k, v


def decode_fwd(params, cfg, memory, tokens, *, remat: str = "full",
               collect_kv: bool = False, unroll: bool = False):
    x = jnp.take(params["embed"], tokens, axis=0).astype(_dtype(cfg))
    x = sharding.constrain(x, "batch", None, None)

    def body(carry, lp):
        h = layers.rms_norm(carry, lp["ln1"], cfg.norm_eps)
        y, kv = _self_attn(cfg, h, lp, causal=True)
        x2 = carry + y
        hx = layers.rms_norm(x2, lp["lnx"], cfg.norm_eps)
        mkv = _memory_kv(cfg, memory, lp)
        x2 = x2 + _cross_attn(cfg, hx, mkv, lp)
        h2 = layers.rms_norm(x2, lp["ln2"], cfg.norm_eps)
        u = jax.nn.relu(jnp.einsum("bsd,df->bsf", h2,
                                   lp["wi"].astype(h2.dtype)))
        return x2 + jnp.einsum("bsf,fd->bsd", u,
                               lp["wo_mlp"].astype(h2.dtype)), \
            (kv if collect_kv else None)

    if remat == "full":
        body = jax.checkpoint(body,
                              policy=jax.checkpoint_policies.nothing_saveable)
    x, kvs = layers.scan_layers(body, x, params["dec_layers"], unroll)
    return layers.rms_norm(x, params["ln_f"], cfg.norm_eps), kvs


def loss_fn(params, cfg, src_embeds, tokens, labels, *, remat: str = "full",
            unroll: bool = False):
    memory = encode(params, cfg, src_embeds, remat=remat, unroll=unroll)
    hidden, _ = decode_fwd(params, cfg, memory, tokens, remat=remat,
                           unroll=unroll)
    return layers.chunked_xent(hidden, params["embed"].T, labels)


def init_cache(cfg, batch: int, max_len: int, memory, params) -> dict:
    """Self-attn KV cache + precomputed per-layer cross KV."""
    dt = _dtype(cfg)
    kv, hd, l = cfg.n_kv_heads, cfg.hd, cfg.n_layers
    # per-layer cross KV: [L, B, S_src, KV, D]
    xk = jax.vmap(lambda lp: _memory_kv(cfg, memory, lp)[0])(
        params["dec_layers"])
    xv = jax.vmap(lambda lp: _memory_kv(cfg, memory, lp)[1])(
        params["dec_layers"])
    cache = {
        "k": jnp.zeros((l, batch, max_len, kv, hd), dt),
        "v": jnp.zeros((l, batch, max_len, kv, hd), dt),
        "xk": xk, "xv": xv,
        "pos": jnp.zeros((), jnp.int32),
    }
    if cfg.sparse_kv:
        cache["kpage"] = jnp.zeros((l, batch, max_len // cfg.kv_page, kv, hd),
                                   jnp.float32)
    return cache


def decode_step(params, cfg, cache, token, *, sparse: bool | None = None,
                unroll: bool = False):
    use_sparse = cfg.sparse_kv if sparse is None else sparse
    x = jnp.take(params["embed"], token[:, None], axis=0).astype(_dtype(cfg))
    pos = cache["pos"]
    b = x.shape[0]
    max_len = cache["k"].shape[2]
    pos_arr = jnp.full((1, 1), pos)

    def body(carry, inp):
        xc = carry
        lp, kc, vc, kpc, xk, xv = inp
        h = layers.rms_norm(xc, lp["ln1"], cfg.norm_eps)
        hd = cfg.hd
        q = jnp.einsum("bsd,dh->bsh", h, lp["wq"].astype(h.dtype)
                       ).reshape(b, 1, cfg.n_heads, hd)
        k = jnp.einsum("bsd,dh->bsh", h, lp["wk"].astype(h.dtype)
                       ).reshape(b, 1, cfg.n_kv_heads, hd)
        v = jnp.einsum("bsd,dh->bsh", h, lp["wv"].astype(h.dtype)
                       ).reshape(b, 1, cfg.n_kv_heads, hd)
        q = layers.apply_rope(q, pos_arr, cfg.rope_theta)
        k = layers.apply_rope(k, pos_arr, cfg.rope_theta)
        kc = jax.lax.dynamic_update_slice_in_dim(kc, k.astype(kc.dtype),
                                                 pos, axis=1)
        vc = jax.lax.dynamic_update_slice_in_dim(vc, v.astype(vc.dtype),
                                                 pos, axis=1)
        g = cfg.n_heads // cfg.n_kv_heads
        if use_sparse:
            kpc = sparse_attention.update_page_summary(kpc, k, pos,
                                                       cfg.kv_page)
            qh = q.reshape(b, cfg.n_kv_heads, g, hd)
            o = sparse_attention.sparse_decode(
                qh, kc, vc, kpc, pos, page=cfg.kv_page,
                k_pages=min(cfg.kv_topk_pages, max_len // cfg.kv_page))
            o = o.reshape(b, 1, cfg.n_heads, hd)
        else:
            o = layers.chunked_attention(q, kc, vc, causal=True,
                                         q_offset=pos,
                                         chunk=min(4096, max_len))
        xc = xc + jnp.einsum("bsh,hd->bsd", o.reshape(b, 1, -1),
                             lp["wo"].astype(xc.dtype))
        hx = layers.rms_norm(xc, lp["lnx"], cfg.norm_eps)
        xc = xc + _cross_attn(cfg, hx, (xk, xv), lp)
        h2 = layers.rms_norm(xc, lp["ln2"], cfg.norm_eps)
        u = jax.nn.relu(jnp.einsum("bsd,df->bsf", h2,
                                   lp["wi"].astype(h2.dtype)))
        xc = xc + jnp.einsum("bsf,fd->bsd", u, lp["wo_mlp"].astype(h2.dtype))
        return xc, (kc, vc, kpc)

    kpage = cache.get("kpage")
    if kpage is None:
        kpage = jnp.zeros((cfg.n_layers, b, max_len // cfg.kv_page,
                           cfg.n_kv_heads, cfg.hd), jnp.float32)
    x, (k2, v2, kp2) = layers.scan_layers(
        body, x, (params["dec_layers"], cache["k"], cache["v"], kpage,
                  cache["xk"], cache["xv"]), unroll)
    x = layers.rms_norm(x, params["ln_f"], cfg.norm_eps)
    logits = jnp.einsum("bd,dv->bv", x[:, -1].astype(jnp.float32),
                        params["embed"].T.astype(jnp.float32))
    new_cache = dict(cache)
    new_cache.update({"k": k2, "v": v2, "pos": pos + 1})
    if "kpage" in cache:
        new_cache["kpage"] = kp2
    return logits, new_cache
