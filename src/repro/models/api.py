"""Uniform model API: one entry point per (family, step kind).

Used by the trainer, server, dry-run, and tests.  ``input_specs`` returns
ShapeDtypeStruct stand-ins (weak-type-correct, shardable, no allocation)
for every model input of a given (arch, shape) cell; ``make_inputs``
materialises small concrete batches for smoke tests.
"""

from __future__ import annotations

import functools
import jax
import jax.numpy as jnp

from ..configs.base import ArchConfig, ShapeCell
from . import encdec, hybrid, ssm, transformer, vlm


def init_params(cfg: ArchConfig, key) -> dict:
    if cfg.family == "ssm":
        return ssm.init_params(cfg, key)
    if cfg.family == "hybrid":
        return hybrid.init_params(cfg, key)
    if cfg.family == "encdec":
        return encdec.init_params(cfg, key)
    return transformer.init_params(cfg, key)   # dense | moe | vlm


def param_specs(cfg: ArchConfig) -> dict:
    """ShapeDtypeStruct pytree of the parameters (no allocation)."""
    return jax.eval_shape(functools.partial(init_params, cfg),
                          jax.random.PRNGKey(0))


def loss_fn(cfg: ArchConfig, params, batch: dict, *, remat: str = "full",
            unroll: bool = False):
    if cfg.family == "ssm":
        return ssm.loss_fn(params, cfg, batch["tokens"], batch["labels"],
                           remat=remat, unroll=unroll)
    if cfg.family == "hybrid":
        return hybrid.loss_fn(params, cfg, batch["tokens"], batch["labels"],
                              remat=remat, unroll=unroll)
    if cfg.family == "encdec":
        return encdec.loss_fn(params, cfg, batch["src_embeds"],
                              batch["tokens"], batch["labels"], remat=remat,
                              unroll=unroll)
    if cfg.family == "vlm":
        return vlm.loss_fn(params, cfg, batch["patches"], batch["tokens"],
                           batch["labels"], remat=remat, unroll=unroll)
    return transformer.loss_fn(params, cfg, batch["tokens"], batch["labels"],
                               remat=remat, unroll=unroll)


def prefill_fn(cfg: ArchConfig, params, batch: dict, *, remat: str = "full",
               unroll: bool = False):
    """Returns (last logits, cache)."""
    if cfg.family == "ssm":
        return ssm.prefill(params, cfg, batch["tokens"], remat=remat,
                           unroll=unroll)
    if cfg.family == "hybrid":
        return hybrid.prefill(params, cfg, batch["tokens"], remat=remat,
                              unroll=unroll)
    if cfg.family == "encdec":
        memory = encdec.encode(params, cfg, batch["src_embeds"], remat=remat,
                               unroll=unroll)
        hidden, kvs = encdec.decode_fwd(params, cfg, memory, batch["tokens"],
                                        remat=remat, collect_kv=True,
                                        unroll=unroll)
        k, v = kvs
        cache = encdec.init_cache(cfg, k.shape[1], k.shape[2], memory, params)
        cache["k"], cache["v"] = k, v
        cache["pos"] = jnp.asarray(k.shape[2], jnp.int32)
        logits = jnp.einsum("bd,dv->bv", hidden[:, -1].astype(jnp.float32),
                            params["embed"].T.astype(jnp.float32))
        return logits, cache
    if cfg.family == "vlm":
        return vlm.prefill(params, cfg, batch["patches"], batch["tokens"],
                           remat=remat, unroll=unroll)
    return transformer.prefill(params, cfg, batch["tokens"], remat=remat,
                               unroll=unroll)


def init_cache(cfg: ArchConfig, batch: int, max_len: int, params=None):
    if cfg.family == "ssm":
        return ssm.init_cache(cfg, batch)
    if cfg.family == "hybrid":
        return hybrid.init_cache(cfg, batch, max_len)
    if cfg.family == "encdec":
        memory = jnp.zeros((batch, cfg.src_len, cfg.d_model),
                           jnp.dtype(cfg.param_dtype))
        return encdec.init_cache(cfg, batch, max_len, memory, params)
    return transformer.init_cache(cfg, batch, max_len)


def decode_fn(cfg: ArchConfig, params, cache, token, *, sparse=None,
              dist=None, unroll: bool = False):
    if cfg.family == "ssm":
        return ssm.decode_step(params, cfg, cache, token, unroll=unroll)
    if cfg.family == "hybrid":
        return hybrid.decode_step(params, cfg, cache, token, unroll=unroll)
    if cfg.family == "encdec":
        return encdec.decode_step(params, cfg, cache, token, sparse=sparse,
                                  unroll=unroll)
    if cfg.family == "vlm":
        return vlm.decode_step(params, cfg, cache, token, sparse=sparse,
                               dist=dist, unroll=unroll)
    return transformer.decode_step(params, cfg, cache, token, sparse=sparse,
                                   dist=dist, unroll=unroll)


# -- inputs --------------------------------------------------------------------

def _train_shapes(cfg: ArchConfig, cell: ShapeCell) -> dict:
    b, s = cell.global_batch, cell.seq_len
    tok = jax.ShapeDtypeStruct((b, s), jnp.int32)
    if cfg.family == "encdec":
        return {"src_embeds": jax.ShapeDtypeStruct((b, cfg.src_len,
                                                    cfg.d_model),
                                                   jnp.bfloat16),
                "tokens": tok, "labels": tok}
    if cfg.family == "vlm":
        npatch = min(cfg.n_patches, s // 2)
        text = s - npatch
        t = jax.ShapeDtypeStruct((b, text), jnp.int32)
        return {"patches": jax.ShapeDtypeStruct((b, npatch, cfg.d_model),
                                                jnp.bfloat16),
                "tokens": t, "labels": t}
    return {"tokens": tok, "labels": tok}


def input_specs(cfg: ArchConfig, cell: ShapeCell) -> dict:
    """ShapeDtypeStructs for the cell's entry point."""
    if cell.kind == "train":
        return _train_shapes(cfg, cell)
    if cell.kind == "prefill":
        specs = _train_shapes(cfg, cell)
        specs.pop("labels")
        return specs
    # decode: one token + cache
    b, s = cell.global_batch, cell.seq_len
    token = jax.ShapeDtypeStruct((b,), jnp.int32)
    cache = jax.eval_shape(
        lambda: init_cache(cfg, b, s, params=param_specs_as_zeros(cfg)))
    return {"token": token, "cache": cache}


def param_specs_as_zeros(cfg: ArchConfig):
    """For cache-spec evaluation paths that need params structurally."""
    return jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype),
                        param_specs(cfg)) if cfg.family == "encdec" else None


def make_inputs(cfg: ArchConfig, cell: ShapeCell, key) -> dict:
    """Concrete small batches (smoke tests)."""
    specs = _train_shapes(cfg, cell)
    out = {}
    for name, s in specs.items():
        if s.dtype == jnp.int32:
            key, k = jax.random.split(key)
            out[name] = jax.random.randint(k, s.shape, 0, cfg.vocab)
        else:
            key, k = jax.random.split(key)
            out[name] = (jax.random.normal(k, s.shape, jnp.float32) * 0.02
                         ).astype(s.dtype)
    if cell.kind != "train":
        out.pop("labels", None)
    return out


def model_flops(cfg: ArchConfig, cell: ShapeCell) -> float:
    """MODEL_FLOPS: 6·N·D for training, 2·N_active per generated token for
    decode, 2·N_active·D for prefill."""
    n_active = cfg.active_params_count()
    tokens = cell.global_batch * cell.seq_len
    if cell.kind == "train":
        return 6.0 * n_active * tokens
    if cell.kind == "prefill":
        return 2.0 * n_active * tokens
    return 2.0 * n_active * cell.global_batch  # one decode step
