"""Dense decoder-only transformer (tinyllama / llama3.2 / gemma / qwen2 /
qwen2-vl backbone) + MoE variants (grok-1 / qwen3-moe) — scan-stacked.

Provides: init_params, forward (train/prefill), loss_fn (chunked vocab xent),
init_cache, decode_step (dense or TopK-sparse KV — the paper's technique).
"""

from __future__ import annotations

import functools
import jax
import jax.numpy as jnp

from .. import sharding
from . import layers, moe, sparse_attention

Params = dict


def _dtype(cfg):
    return jnp.dtype(cfg.param_dtype)


def init_layer(cfg, key) -> Params:
    dt = _dtype(cfg)
    d, hd = cfg.d_model, cfg.hd
    ks = iter(jax.random.split(key, 12))
    p = {
        "ln1": jnp.zeros((d,), jnp.float32),
        "ln2": jnp.zeros((d,), jnp.float32),
        "wq": layers.dense_init(next(ks), (d, cfg.n_heads * hd), dt),
        "wk": layers.dense_init(next(ks), (d, cfg.n_kv_heads * hd), dt),
        "wv": layers.dense_init(next(ks), (d, cfg.n_kv_heads * hd), dt),
        "wo": layers.dense_init(next(ks), (cfg.n_heads * hd, d), dt),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((cfg.n_heads * hd,), dt)
        p["bk"] = jnp.zeros((cfg.n_kv_heads * hd,), dt)
        p["bv"] = jnp.zeros((cfg.n_kv_heads * hd,), dt)
    if cfg.n_experts:
        p.update(moe.init_moe(cfg, next(ks), dt))
    else:
        p["wi"] = layers.dense_init(next(ks), (d, cfg.d_ff), dt)
        if cfg.act in ("swiglu", "geglu"):
            p["wg"] = layers.dense_init(next(ks), (d, cfg.d_ff), dt)
        p["wo_mlp"] = layers.dense_init(next(ks), (cfg.d_ff, d), dt)
    return p


def init_params(cfg, key) -> Params:
    dt = _dtype(cfg)
    k_emb, k_layers, k_head = jax.random.split(key, 3)
    params = {
        "embed": layers.dense_init(k_emb, (cfg.vocab, cfg.d_model), dt, 0.02),
        "layers": layers.stack_layer_params(
            functools.partial(init_layer, cfg), cfg.n_layers, k_layers),
        "ln_f": jnp.zeros((cfg.d_model,), jnp.float32),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = layers.dense_init(
            k_head, (cfg.d_model, cfg.vocab), dt)
    return params


def _ffn(x, p, cfg):
    if cfg.n_experts:
        return moe.moe_ffn(x, p, cfg)
    h = jnp.einsum("bsd,df->bsf", x, p["wi"].astype(x.dtype))
    if cfg.act in ("swiglu", "geglu"):
        g = jnp.einsum("bsd,df->bsf", x, p["wg"].astype(x.dtype))
        g = jax.nn.silu(g) if cfg.act == "swiglu" else jax.nn.gelu(g)
        h = g * h
    elif cfg.act == "gelu":
        h = jax.nn.gelu(h)
    h = sharding.constrain(h, "batch", None, "mlp")
    return jnp.einsum("bsf,fd->bsd", h, p["wo_mlp"].astype(x.dtype))


def _rope(cfg, x, pos, pos3=None):
    if cfg.mrope_sections:
        return layers.apply_mrope(x, pos3, cfg.mrope_sections, cfg.rope_theta)
    return layers.apply_rope(x, pos, cfg.rope_theta)


def layer_fwd(cfg, x, p, pos, pos3=None, collect_kv=False):
    """One decoder layer on [B,S,D]; returns (x, (k, v) | None)."""
    h = layers.rms_norm(x, p["ln1"], cfg.norm_eps)
    q, k, v = layers.gqa_project(h, p, cfg)
    q = _rope(cfg, q, pos, pos3)
    k = _rope(cfg, k, pos, pos3)
    q = sharding.constrain(q, "batch", None, "heads", None)
    k = sharding.constrain(k, "batch", None, "kv_heads", None)
    o = layers.chunked_attention(q, k, v, causal=True,
                                 logit_softcap=cfg.logit_softcap)
    x = x + layers.attn_out(o, p, cfg.d_model)
    h2 = layers.rms_norm(x, p["ln2"], cfg.norm_eps)
    x = x + _ffn(h2, p, cfg)
    # sequence parallelism: the residual stream (and hence the scan-saved
    # per-layer residual stack) lives S-sharded on "model"; GSPMD inserts
    # the all-gather before attention/MLP and the reduce-scatter after
    x = sharding.constrain(x, "batch", "seq_sp", None)
    return x, ((k, v) if collect_kv else None)


def forward(params: Params, cfg, tokens=None, *, input_embeds=None,
            pos3=None, collect_kv: bool = False, remat: str = "full",
            unroll: bool = False):
    """Run the stack; returns (hidden [B,S,D], kv | None)."""
    if input_embeds is None:
        x = jnp.take(params["embed"], tokens, axis=0).astype(_dtype(cfg))
        if getattr(cfg, "scale_embed", False):
            x = x * (cfg.d_model ** 0.5)
    else:
        x = input_embeds.astype(_dtype(cfg))
    x = sharding.constrain(x, "batch", None, None)
    b, s, _ = x.shape
    pos = jnp.arange(s)[None, :]

    def body(carry, lp):
        y, kv = layer_fwd(cfg, carry, lp, pos, pos3, collect_kv)
        return y, kv

    if remat == "full":
        body = jax.checkpoint(body,
                              policy=jax.checkpoint_policies.nothing_saveable)
    elif remat == "dots":
        body = jax.checkpoint(
            body, policy=jax.checkpoint_policies.checkpoint_dots)
    x, kvs = layers.scan_layers(body, x, params["layers"], unroll)
    x = layers.rms_norm(x, params["ln_f"], cfg.norm_eps)
    return x, kvs


def logits_last(params, cfg, hidden):
    head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    return jnp.einsum("bd,dv->bv", hidden[:, -1].astype(jnp.float32),
                      head.astype(jnp.float32))


def loss_fn(params: Params, cfg, tokens, labels, *, remat: str = "full",
            loss_chunk: int = 1024, unroll: bool = False):
    """Mean token cross-entropy, computed in S-chunks so the full [B,S,V]
    logits tensor never materialises (vocab stays TP-sharded)."""
    hidden, _ = forward(params, cfg, tokens, remat=remat, unroll=unroll)
    head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    return layers.chunked_xent(hidden, head, labels, loss_chunk)


def prefill(params: Params, cfg, tokens, *, input_embeds=None, pos3=None,
            remat: str = "full", unroll: bool = False):
    """Forward pass that also returns the stacked KV cache (inference
    prefill).  Returns (last-token logits [B,V], cache)."""
    hidden, kvs = forward(params, cfg, tokens, input_embeds=input_embeds,
                          pos3=pos3, collect_kv=True, remat=remat,
                          unroll=unroll)
    k, v = kvs
    s = k.shape[2]
    cache = make_cache(cfg, k, v, s)
    return logits_last(params, cfg, hidden), cache


# -- decode -------------------------------------------------------------------

def init_cache(cfg, batch: int, max_len: int, dtype=None) -> dict:
    dt = dtype or (jnp.int8 if cfg.kv_dtype == "int8" else _dtype(cfg))
    kv, hd = cfg.n_kv_heads, cfg.hd
    shape = (cfg.n_layers, batch, max_len, kv, hd)
    cache = {
        "k": jnp.zeros(shape, dt), "v": jnp.zeros(shape, dt),
        "pos": jnp.zeros((), jnp.int32),
    }
    if cfg.sparse_kv:
        np_ = max_len // cfg.kv_page
        cache["kpage"] = jnp.zeros((cfg.n_layers, batch, np_, kv, hd),
                                   jnp.float32)
    return cache


def make_cache(cfg, k, v, pos) -> dict:
    """Build a cache dict from prefill KV [L,B,S,KV,D] (page summaries
    derived by pooling; KV optionally int8-quantised)."""
    kq = sparse_attention.kv_quant(k, jnp.int8) \
        if cfg.kv_dtype == "int8" else k
    vq = sparse_attention.kv_quant(v, jnp.int8) \
        if cfg.kv_dtype == "int8" else v
    cache = {"k": kq, "v": vq, "pos": jnp.asarray(pos, jnp.int32)}
    if cfg.sparse_kv:
        l, b, s, kv, hd = k.shape
        pg = cfg.kv_page
        cache["kpage"] = k.reshape(l, b, s // pg, pg, kv, hd).astype(
            jnp.float32).mean(axis=3)
    return cache


def decode_step(params: Params, cfg, cache: dict, token, *, pos3=None,
                sparse: bool | None = None, dist: dict | None = None,
                unroll: bool = False):
    """One decode step: token [B] -> (logits [B,V], cache).

    ``dist``: optional {"mesh", "batch_axes", "seq_axes", "kv_axes"} for
    the distributed sparse path (shard_map)."""
    use_sparse = cfg.sparse_kv if sparse is None else sparse
    x = jnp.take(params["embed"], token[:, None], axis=0).astype(_dtype(cfg))
    if getattr(cfg, "scale_embed", False):
        x = x * (cfg.d_model ** 0.5)
    pos = cache["pos"]
    b = x.shape[0]
    max_len = cache["k"].shape[2]
    pos_arr = jnp.full((1, 1), pos)

    def _pin(arr, dims_spec):
        # keep the carried caches on their intended sharding through the
        # dynamic updates (GSPMD otherwise drifts to replication —
        # measured as a full-cache all-gather per layer)
        if dist is None:
            return arr
        from jax.sharding import PartitionSpec as P

        from .. import sharding as _sh
        if not _sh._mesh_axes():
            return arr
        return jax.lax.with_sharding_constraint(arr, P(*dims_spec))

    def _axes(name):
        if not dist:
            return None
        v = tuple(a for a in dist.get(name, ())
                  if a in dist["mesh"].shape)
        return v or None

    ba, sa, ka = _axes("batch_axes"), _axes("seq_axes"), _axes("kv_axes")

    def body(carry, lp_and_idx):
        # the full caches ride in the CARRY: XLA aliases the donated
        # buffers through the while loop (one copy), and the sparse path
        # gathers pages straight from the stacked cache with the layer
        # index folded into the gather — per-layer slice/moveaxis copies
        # would cost O(cache) HBM traffic per step (§Perf iteration 1)
        xc, kfull, vfull, kpfull = carry
        lp, li = lp_and_idx
        h = layers.rms_norm(xc, lp["ln1"], cfg.norm_eps)
        q, k_new, v_new = layers.gqa_project(h, lp, cfg)
        if cfg.mrope_sections:
            p3 = jnp.broadcast_to(pos_arr[None], (3, b, 1)) if pos3 is None else pos3
            q = layers.apply_mrope(q, p3, cfg.mrope_sections, cfg.rope_theta)
            k_new = layers.apply_mrope(k_new, p3, cfg.mrope_sections,
                                       cfg.rope_theta)
        else:
            q = layers.apply_rope(q, pos_arr, cfg.rope_theta)
            k_new = layers.apply_rope(k_new, pos_arr, cfg.rope_theta)
        # write the new token into the stacked caches (no layer slices)
        kfull = jax.lax.dynamic_update_slice(
            kfull, sparse_attention.kv_quant(k_new, kfull.dtype)[None],
            (li, 0, pos, 0, 0))
        vfull = jax.lax.dynamic_update_slice(
            vfull, sparse_attention.kv_quant(v_new, vfull.dtype)[None],
            (li, 0, pos, 0, 0))
        kfull = _pin(kfull, (None, ba, sa, ka, None))
        vfull = _pin(vfull, (None, ba, sa, ka, None))
        g = cfg.n_heads // cfg.n_kv_heads
        qh = q.reshape(b, cfg.n_kv_heads, g, cfg.hd)
        if use_sparse:
            kp_li = jax.lax.dynamic_index_in_dim(kpfull, li, 0,
                                                 keepdims=False)
            kp_li = sparse_attention.update_page_summary(
                kp_li, k_new, pos, cfg.kv_page)
            kpfull = jax.lax.dynamic_update_index_in_dim(kpfull, kp_li,
                                                         li, 0)
            kpfull = _pin(kpfull, (None, ba, sa, ka, None))
            if dist is not None:
                o = sparse_attention.sparse_decode_distributed_full(
                    qh, kfull, vfull, kp_li, li, pos, page=cfg.kv_page,
                    k_pages=cfg.kv_topk_pages, **dist)
            else:
                o = sparse_attention.sparse_decode_full(
                    qh, kfull, vfull, kp_li, li, pos, page=cfg.kv_page,
                    k_pages=min(cfg.kv_topk_pages, max_len // cfg.kv_page))
            o = o.reshape(b, 1, cfg.n_heads, cfg.hd)
        else:
            kc = jax.lax.dynamic_index_in_dim(kfull, li, 0, keepdims=False)
            vc = jax.lax.dynamic_index_in_dim(vfull, li, 0, keepdims=False)
            o = layers.chunked_attention(
                q, kc, vc, causal=True, q_offset=pos,
                chunk=min(4096, max_len), logit_softcap=cfg.logit_softcap)
        xc = xc + layers.attn_out(o, lp, cfg.d_model)
        h2 = layers.rms_norm(xc, lp["ln2"], cfg.norm_eps)
        xc = xc + _ffn(h2, lp, cfg)
        return (xc, kfull, vfull, kpfull), None

    kpage = cache.get("kpage")
    if kpage is None:
        kpage = jnp.zeros((cfg.n_layers, b, max_len // cfg.kv_page,
                           cfg.n_kv_heads, cfg.hd), jnp.float32)
    lidx = jnp.arange(cfg.n_layers, dtype=jnp.int32)
    (x, k2, v2, kp2), _ = layers.scan_layers(
        body, (x, cache["k"], cache["v"], kpage),
        (params["layers"], lidx), unroll)
    x = layers.rms_norm(x, params["ln_f"], cfg.norm_eps)
    logits = logits_last(params, cfg, x)
    new_cache = {"k": k2, "v": v2, "pos": pos + 1}
    if "kpage" in cache:
        new_cache["kpage"] = kp2
    return logits, new_cache
