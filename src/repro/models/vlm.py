"""Qwen2-VL backbone: text transformer + M-RoPE; vision frontend is a STUB
(``input_specs`` provides precomputed patch embeddings + a (t,h,w) grid).

Sequence layout: [patches | text].  Patches carry grid (t=0, h, w) M-RoPE
positions; text continues with sequential t positions after the grid.
"""

from __future__ import annotations

import jax.numpy as jnp

from . import transformer

init_params = transformer.init_params
init_cache = transformer.init_cache


def build_positions(cfg, n_patches: int, grid_hw: tuple[int, int],
                    text_len: int, batch: int):
    """M-RoPE position ids [3, B, n_patches + text_len]."""
    gh, gw = grid_hw
    hpos = (jnp.arange(n_patches) // gw) % gh
    wpos = jnp.arange(n_patches) % gw
    tpos = jnp.zeros((n_patches,), jnp.int32)
    t0 = max(gh, gw)
    text = t0 + jnp.arange(text_len)
    pos_t = jnp.concatenate([tpos, text])
    pos_h = jnp.concatenate([hpos, text])
    pos_w = jnp.concatenate([wpos, text])
    pos3 = jnp.stack([pos_t, pos_h, pos_w])              # [3, S]
    return jnp.broadcast_to(pos3[:, None, :],
                            (3, batch, n_patches + text_len))


def embed_multimodal(params, cfg, patch_embeds, tokens):
    txt = jnp.take(params["embed"], tokens, axis=0)
    x = jnp.concatenate([patch_embeds.astype(txt.dtype), txt], axis=1)
    return x


def loss_fn(params, cfg, patch_embeds, tokens, labels, *,
            remat: str = "full", unroll: bool = False):
    """Loss over text positions only (patch positions excluded)."""
    b, npatch, _ = patch_embeds.shape
    text_len = tokens.shape[1]
    gw = max(1, int(npatch ** 0.5))
    pos3 = build_positions(cfg, npatch, (max(1, npatch // gw), gw),
                           text_len, b)
    x = embed_multimodal(params, cfg, patch_embeds, tokens)
    hidden, _ = transformer.forward(params, cfg, input_embeds=x, pos3=pos3,
                                    remat=remat, unroll=unroll)
    hidden_text = hidden[:, npatch:]
    head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    from . import layers
    return layers.chunked_xent(hidden_text, head, labels,
                               chunk=min(1024, text_len))


def prefill(params, cfg, patch_embeds, tokens, *, remat: str = "full",
            unroll: bool = False):
    b, npatch, _ = patch_embeds.shape
    text_len = tokens.shape[1]
    gw = max(1, int(npatch ** 0.5))
    pos3 = build_positions(cfg, npatch, (max(1, npatch // gw), gw),
                           text_len, b)
    x = embed_multimodal(params, cfg, patch_embeds, tokens)
    hidden, kvs = transformer.forward(params, cfg, input_embeds=x, pos3=pos3,
                                      collect_kv=True, remat=remat,
                                      unroll=unroll)
    k, v = kvs
    cache = transformer.make_cache(cfg, k, v, k.shape[2])
    return transformer.logits_last(params, cfg, hidden), cache


def decode_step(params, cfg, cache, token, *, sparse=None, dist=None,
                unroll: bool = False):
    # text continues with uniform positions: pos3 = current pos on all axes
    b = token.shape[0]
    pos3 = jnp.broadcast_to(cache["pos"][None, None, None], (3, b, 1))
    return transformer.decode_step(params, cfg, cache, token, pos3=pos3,
                                   sparse=sparse, dist=dist, unroll=unroll)
