"""Mamba-2 (SSD — state-space duality, arXiv:2405.21060), chunked scan.

Attention-free mixer: the paper's sparse-KV technique is inapplicable here
(DESIGN.md §Arch-applicability); runahead still applies to the embedding
gather.  The SSD recurrence is computed with the chunked algorithm: O(c²)
intra-chunk (MXU-friendly einsums) + inter-chunk state carry, scanned over
chunks, so HLO stays small and decode is an O(1) state update.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .. import sharding
from . import layers

Params = dict


def _dtype(cfg):
    return jnp.dtype(cfg.param_dtype)


def init_layer(cfg, key) -> Params:
    dt = _dtype(cfg)
    d, di, ds, nh = cfg.d_model, cfg.d_inner, cfg.ssm_state, cfg.n_ssm_heads
    w = cfg.conv_width
    ks = iter(jax.random.split(key, 10))
    return {
        "ln": jnp.zeros((d,), jnp.float32),
        "wz": layers.dense_init(next(ks), (d, di), dt),
        "wx": layers.dense_init(next(ks), (d, di), dt),
        "wB": layers.dense_init(next(ks), (d, ds), dt),
        "wC": layers.dense_init(next(ks), (d, ds), dt),
        "wdt": layers.dense_init(next(ks), (d, nh), dt),
        "conv_x": layers.dense_init(next(ks), (w, di), dt, 0.5),
        "conv_B": layers.dense_init(next(ks), (w, ds), dt, 0.5),
        "conv_C": layers.dense_init(next(ks), (w, ds), dt, 0.5),
        "A_log": jnp.zeros((nh,), jnp.float32),
        "D": jnp.ones((nh,), jnp.float32),
        "dt_bias": jnp.zeros((nh,), jnp.float32),
        "ln_gate": jnp.zeros((di,), jnp.float32),
        "wout": layers.dense_init(next(ks), (di, d), dt),
    }


def init_params(cfg, key) -> Params:
    k_emb, k_layers = jax.random.split(key)
    return {
        "embed": layers.dense_init(k_emb, (cfg.vocab, cfg.d_model),
                                   _dtype(cfg), 0.02),
        "layers": layers.stack_layer_params(
            functools.partial(init_layer, cfg), cfg.n_layers, k_layers),
        "ln_f": jnp.zeros((cfg.d_model,), jnp.float32),
    }


def _causal_conv(x: jax.Array, w: jax.Array, state=None):
    """Depthwise causal conv over S. x [B,S,C], w [W,C].  Returns (y, new
    state [B,W-1,C])."""
    width = w.shape[0]
    if state is None:
        state = jnp.zeros((x.shape[0], width - 1, x.shape[-1]), x.dtype)
    xp = jnp.concatenate([state, x], axis=1)
    y = sum(xp[:, i:i + x.shape[1]] * w[i] for i in range(width))
    return jax.nn.silu(y), xp[:, -(width - 1):]


def ssd_chunked(xh, dt, A, Bm, Cm, chunk: int, state=None):
    """Chunked SSD. xh [B,S,nh,hd]; dt [B,S,nh]; A [nh]; Bm/Cm [B,S,ds].

    Returns (y [B,S,nh,hd], final state [B,nh,hd,ds]).
    """
    b, s, nh, hd = xh.shape
    ds = Bm.shape[-1]
    n = max(1, -(-s // chunk))
    while s % n:                       # s need not divide the chunk size
        n += 1
    c = s // n
    xc = xh.reshape(b, n, c, nh, hd)
    dtc = dt.reshape(b, n, c, nh)
    bc = Bm.reshape(b, n, c, ds)
    cc = Cm.reshape(b, n, c, ds)
    if state is None:
        state = jnp.zeros((b, nh, hd, ds), jnp.float32)

    def body_clean(h, inp):
        x_, dt_, b_, c_ = inp
        la = jnp.cumsum(dt_ * A, axis=1)
        scores = jnp.einsum("btn,bsn->bts", c_, b_)
        dmat = la[:, :, None, :] - la[:, None, :, :]
        mask = jnp.tril(jnp.ones((c, c), bool))
        decay = jnp.where(mask[None, :, :, None], jnp.exp(dmat), 0.0)
        w = scores[..., None] * decay * dt_[:, None, :, :]
        y_intra = jnp.einsum("btsn,bsnp->btnp", w, x_)
        y_inter = jnp.einsum("bts,bnps,btn->btnp", c_, h, jnp.exp(la))
        y = y_intra + y_inter
        tail = la[:, -1:, :] - la
        contrib = jnp.einsum("btn,btnp,bts->bnps", dt_ * jnp.exp(tail), x_, b_)
        h_new = h * jnp.exp(la[:, -1])[:, :, None, None] + contrib
        return h_new, y

    xs = (jnp.moveaxis(xc, 1, 0).astype(jnp.float32),
          jnp.moveaxis(dtc, 1, 0).astype(jnp.float32),
          jnp.moveaxis(bc, 1, 0).astype(jnp.float32),
          jnp.moveaxis(cc, 1, 0).astype(jnp.float32))
    if layers._INNER_UNROLL:
        state, ys = jax.lax.scan(body_clean, state, xs,
                                 unroll=min(n, 64))
    else:
        state, ys = jax.lax.scan(body_clean, state, xs)
    y = jnp.moveaxis(ys, 0, 1).reshape(b, s, nh, hd)
    return y, state


def mixer(cfg, x, p, conv_state=None, ssm_state=None, single_step=False):
    """Mamba2 mixer on [B,S,d].  Returns (y, conv_states, ssm_state)."""
    nh, hd, ds = cfg.n_ssm_heads, cfg.ssm_head_dim, cfg.ssm_state
    z = jnp.einsum("bsd,de->bse", x, p["wz"].astype(x.dtype))
    xi = jnp.einsum("bsd,de->bse", x, p["wx"].astype(x.dtype))
    bm = jnp.einsum("bsd,de->bse", x, p["wB"].astype(x.dtype))
    cm = jnp.einsum("bsd,de->bse", x, p["wC"].astype(x.dtype))
    dt = jnp.einsum("bsd,dn->bsn", x.astype(jnp.float32),
                    p["wdt"].astype(jnp.float32))
    dt = jax.nn.softplus(dt + p["dt_bias"])
    cs = conv_state or {}
    xi, cs_x = _causal_conv(xi, p["conv_x"].astype(x.dtype), cs.get("x"))
    bm, cs_b = _causal_conv(bm, p["conv_B"].astype(x.dtype), cs.get("B"))
    cm, cs_c = _causal_conv(cm, p["conv_C"].astype(x.dtype), cs.get("C"))
    xh = xi.reshape(*xi.shape[:2], nh, hd)
    A = -jnp.exp(p["A_log"])
    if single_step:
        # O(1) decode: h = exp(dt*A) h + dt * x B^T ; y = C h + D x
        a = jnp.exp(dt[:, 0] * A)                               # [B,nh]
        contrib = jnp.einsum("bn,bnp,bs->bnps", dt[:, 0],
                             xh[:, 0].astype(jnp.float32),
                             bm[:, 0].astype(jnp.float32))
        h_new = ssm_state * a[:, :, None, None] + contrib
        y = jnp.einsum("bs,bnps->bnp", cm[:, 0].astype(jnp.float32), h_new)
        y = y[:, None]
        ssm_state = h_new
    else:
        y, ssm_state = ssd_chunked(xh.astype(jnp.float32), dt, A,
                                   bm.astype(jnp.float32),
                                   cm.astype(jnp.float32),
                                   cfg.ssm_chunk, ssm_state)
    y = y + p["D"][None, None, :, None] * xh.astype(jnp.float32)
    y = y.reshape(*y.shape[:2], -1).astype(x.dtype)
    y = layers.rms_norm(y * jax.nn.silu(z), p["ln_gate"], cfg.norm_eps)
    out = jnp.einsum("bse,ed->bsd", y, p["wout"].astype(x.dtype))
    return out, {"x": cs_x, "B": cs_b, "C": cs_c}, ssm_state


def forward(params, cfg, tokens, *, remat: str = "full",
            unroll: bool = False):
    x = jnp.take(params["embed"], tokens, axis=0).astype(_dtype(cfg))
    x = sharding.constrain(x, "batch", None, None)

    def body(carry, lp):
        h = layers.rms_norm(carry, lp["ln"], cfg.norm_eps)
        y, _, _ = mixer(cfg, h, lp)
        return carry + y, None

    if remat == "full":
        body = jax.checkpoint(body,
                              policy=jax.checkpoint_policies.nothing_saveable)
    x, _ = layers.scan_layers(body, x, params["layers"], unroll)
    return layers.rms_norm(x, params["ln_f"], cfg.norm_eps)


def loss_fn(params, cfg, tokens, labels, *, remat: str = "full",
            unroll: bool = False):
    hidden = forward(params, cfg, tokens, remat=remat, unroll=unroll)
    return layers.chunked_xent(hidden, params["embed"].T, labels)


def prefill(params, cfg, tokens, *, remat: str = "full",
            unroll: bool = False):
    """Forward over the prompt collecting per-layer final states; returns
    (last-token logits, cache)."""
    x = jnp.take(params["embed"], tokens, axis=0).astype(_dtype(cfg))
    x = sharding.constrain(x, "batch", None, None)

    def body(carry, lp):
        h = layers.rms_norm(carry, lp["ln"], cfg.norm_eps)
        y, cs, ssm_state = mixer(cfg, h, lp)
        return carry + y, (cs["x"], cs["B"], cs["C"], ssm_state)

    if remat == "full":
        body = jax.checkpoint(body,
                              policy=jax.checkpoint_policies.nothing_saveable)
    x, (cx, cb, cc, ssm_states) = layers.scan_layers(
        body, x, params["layers"], unroll)
    x = layers.rms_norm(x, params["ln_f"], cfg.norm_eps)
    logits = jnp.einsum("bd,vd->bv", x[:, -1].astype(jnp.float32),
                        params["embed"].astype(jnp.float32))
    cache = {"conv_x": cx, "conv_B": cb, "conv_C": cc, "ssm": ssm_states,
             "pos": jnp.asarray(tokens.shape[1], jnp.int32)}
    return logits, cache


def init_cache(cfg, batch: int, max_len: int = 0) -> dict:
    nh, hd, ds, di = (cfg.n_ssm_heads, cfg.ssm_head_dim, cfg.ssm_state,
                      cfg.d_inner)
    w = cfg.conv_width
    l = cfg.n_layers
    dt = _dtype(cfg)
    return {
        "conv_x": jnp.zeros((l, batch, w - 1, di), dt),
        "conv_B": jnp.zeros((l, batch, w - 1, ds), dt),
        "conv_C": jnp.zeros((l, batch, w - 1, ds), dt),
        "ssm": jnp.zeros((l, batch, nh, hd, ds), jnp.float32),
        "pos": jnp.zeros((), jnp.int32),
    }


def decode_step(params, cfg, cache, token, *, unroll: bool = False):
    x = jnp.take(params["embed"], token[:, None], axis=0).astype(_dtype(cfg))

    def body(carry, inp):
        lp, cx, cb, cc, ssm = inp
        h = layers.rms_norm(carry, lp["ln"], cfg.norm_eps)
        y, cs, ssm2 = mixer(cfg, h, lp,
                            conv_state={"x": cx, "B": cb, "C": cc},
                            ssm_state=ssm, single_step=True)
        return carry + y, (cs["x"], cs["B"], cs["C"], ssm2)

    x, (cx, cb, cc, ssm) = layers.scan_layers(
        body, x, (params["layers"], cache["conv_x"], cache["conv_B"],
                  cache["conv_C"], cache["ssm"]), unroll)
    x = layers.rms_norm(x, params["ln_f"], cfg.norm_eps)
    logits = jnp.einsum("bd,vd->bv", x[:, -1].astype(jnp.float32),
                        params["embed"].astype(jnp.float32))
    return logits, {"conv_x": cx, "conv_B": cb, "conv_C": cc, "ssm": ssm,
                    "pos": cache["pos"] + 1}
