"""Shared model layers: norms, rotary (RoPE / M-RoPE), GQA attention with
memory-efficient chunked (flash-style) softmax, gated MLPs.

Everything is a pure function over a params dict; layer stacks are scanned
(``jax.lax.scan``) with parameters stacked on a leading layer axis, which
keeps HLO size and compile time O(1) in depth — essential for the 512-chip
dry-run of 80-94-layer models.
"""

from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

Params = dict

# When True, inner lax.scan loops (attention KV chunks, SSD chunks, loss
# chunks) are traced as Python loops.  ONLY used by the dry-run cost
# analysis: XLA's HLO cost model counts while-loop bodies once regardless
# of trip count, so unrolled tracing is required for true FLOP/byte counts.
_INNER_UNROLL = False


def set_inner_unroll(flag: bool) -> None:
    global _INNER_UNROLL
    _INNER_UNROLL = flag


def inner_scan(body, carry, xs_list, length: int):
    """lax.scan respecting the dry-run inner-unroll flag.

    xs_list: tuple of arrays with leading ``length`` axis.  The unrolled
    form uses ``lax.scan(unroll=k)``: the body is traced once and XLA
    replicates it, so cost analysis counts k iterations without the
    O(length) Python retracing a manual loop would pay.  k is capped at
    64 (XLA:CPU compile time of a 512-copy SSD body is pathological);
    loops longer than the cap are undercounted by length/64 and the
    dry-run applies a documented family-level correction
    (``launch/dryrun.py::inner_undercount``)."""
    if not _INNER_UNROLL:
        return jax.lax.scan(body, carry, xs_list)
    return jax.lax.scan(body, carry, xs_list,
                        unroll=min(int(length), 64))


def rms_norm(x: jax.Array, w: jax.Array, eps: float = 1e-6) -> jax.Array:
    var = jnp.mean(jnp.square(x.astype(jnp.float32)), axis=-1, keepdims=True)
    return (x.astype(jnp.float32) * jax.lax.rsqrt(var + eps)).astype(x.dtype) \
        * (1.0 + w.astype(x.dtype))


# -- rotary ------------------------------------------------------------------

def rope_freqs(head_dim: int, theta: float = 10000.0) -> jax.Array:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32)
                            / head_dim))


def apply_rope(x: jax.Array, pos: jax.Array, theta: float = 10000.0) -> jax.Array:
    """x [..., S, H, D]; pos [..., S] (broadcastable)."""
    d = x.shape[-1]
    freqs = rope_freqs(d, theta)                      # [D/2]
    ang = pos[..., None].astype(jnp.float32) * freqs  # [..., S, D/2]
    ang = ang[..., None, :]                           # [..., S, 1, D/2]
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


def apply_mrope(x: jax.Array, pos3: jax.Array, sections: tuple[int, int, int],
                theta: float = 1000000.0) -> jax.Array:
    """Qwen2-VL M-RoPE: head_dim/2 frequency slots split into (t, h, w)
    sections, each rotated by its own position stream.

    x [B, S, H, D]; pos3 [3, B, S]; sections sum to D//2.
    """
    d = x.shape[-1]
    freqs = rope_freqs(d, theta)                                   # [D/2]
    ang_all = pos3[..., None].astype(jnp.float32) * freqs          # [3,B,S,D/2]
    sec = jnp.concatenate([
        jnp.full((s,), i, dtype=jnp.int32)
        for i, s in enumerate(sections)])                          # [D/2]
    ang = jnp.take_along_axis(
        jnp.moveaxis(ang_all, 0, -1), sec[None, None, :, None], axis=-1
    )[..., 0]                                                      # [B,S,D/2]
    ang = ang[..., None, :]                                        # [B,S,1,D/2]
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# -- attention ----------------------------------------------------------------

def _deq(x: jax.Array) -> jax.Array:
    """f32 view of (possibly int8-quantised) KV values."""
    if x.dtype == jnp.int8:
        from .sparse_attention import KV_QSCALE
        return x.astype(jnp.float32) * (1.0 / KV_QSCALE)
    return x.astype(jnp.float32)


def chunked_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                      causal: bool = True, q_offset: int = 0,
                      window: int = 0, chunk: int = 1024,
                      logit_softcap: float = 0.0) -> jax.Array:
    """Flash-style GQA attention, scanned over KV chunks (O(S) memory).

    q [B, Sq, H, D]; k, v [B, Sk, KV, D]; H = KV * G.
    ``q_offset``: absolute position of q[0] (for decode / chunked prefill).
    ``window > 0``: local (sliding-window) attention.
    """
    b, sq, h, d = q.shape
    _, sk, kv, _ = k.shape
    g = h // kv
    qf = q.astype(jnp.float32) / (d ** 0.5)         # [B,Sq,H,D], H TP-sharded
    n_chunks = max(1, -(-sk // chunk))
    while sk % n_chunks:                             # sk need not divide chunk
        n_chunks += 1
    ck = sk // n_chunks
    kc = k.reshape(b, n_chunks, ck, kv, d)
    vc = v.reshape(b, n_chunks, ck, kv, d)
    qpos = q_offset + jnp.arange(sq)

    def body(carry, inp):
        m, l, acc = carry
        kb, vb, c0 = inp
        # broadcast KV group to flat heads per chunk (keeps the head dim
        # flat so TP sharding on H survives — no (kv, g) reshape)
        kh = jnp.broadcast_to(kb[:, :, :, None, :], (b, ck, kv, g, d)
                              ).reshape(b, ck, h, d)
        vh = jnp.broadcast_to(vb[:, :, :, None, :], (b, ck, kv, g, d)
                              ).reshape(b, ck, h, d)
        s = jnp.einsum("bqhd,bthd->bqht", qf, _deq(kh))
        if logit_softcap:
            s = jnp.tanh(s / logit_softcap) * logit_softcap
        kpos = c0 + jnp.arange(ck)
        mask = jnp.ones((sq, ck), dtype=bool)
        if causal:
            mask &= qpos[:, None] >= kpos[None, :]
        if window:
            mask &= kpos[None, :] > qpos[:, None] - window
        s = jnp.where(mask[None, :, None, :], s, -jnp.inf)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        # guard fully-masked rows
        m_safe = jnp.where(jnp.isfinite(m_new), m_new, 0.0)
        p = jnp.exp(s - m_safe[..., None])
        p = jnp.where(mask[None, :, None, :], p, 0.0)
        alpha = jnp.where(jnp.isfinite(m), jnp.exp(m - m_safe), 0.0)
        l_new = l * alpha + jnp.sum(p, axis=-1)
        acc_new = acc * alpha[..., None] + jnp.einsum(
            "bqht,bthd->bqhd", p, _deq(vh))
        return (m_new, l_new, acc_new), None

    m0 = jnp.full((b, sq, h), -jnp.inf, dtype=jnp.float32)
    l0 = jnp.zeros((b, sq, h), dtype=jnp.float32)
    a0 = jnp.zeros((b, sq, h, d), dtype=jnp.float32)
    chunk_starts = jnp.arange(n_chunks) * ck
    # flash-style backward: recompute per-chunk scores instead of letting
    # the scan stack them ([n_chunks, B, Sq, H, ck] f32 otherwise)
    (m, l, acc), _ = inner_scan(
        jax.checkpoint(body), (m0, l0, a0),
        (jnp.moveaxis(kc, 1, 0), jnp.moveaxis(vc, 1, 0), chunk_starts),
        n_chunks)
    out = acc / jnp.maximum(l, 1e-30)[..., None]
    return out.astype(q.dtype)


def gqa_project(x: jax.Array, p: Params, cfg: Any,
                n_heads: int | None = None,
                n_kv: int | None = None) -> tuple[jax.Array, jax.Array, jax.Array]:
    """QKV projection with optional bias; returns [B,S,H,D], [B,S,KV,D] x2.

    ``n_heads`` / ``n_kv`` override the cfg head counts for
    tensor-parallel shards whose wq/wk/wv carry only a head slice (the
    serve engine's shard_map bodies); the math is unchanged — only the
    final reshape sees the local counts."""
    b, s, _ = x.shape
    q = jnp.einsum("bsd,dh->bsh", x, p["wq"].astype(x.dtype))
    k = jnp.einsum("bsd,dh->bsh", x, p["wk"].astype(x.dtype))
    v = jnp.einsum("bsd,dh->bsh", x, p["wv"].astype(x.dtype))
    if cfg.qkv_bias:
        q = q + p["bq"].astype(x.dtype)
        k = k + p["bk"].astype(x.dtype)
        v = v + p["bv"].astype(x.dtype)
    nh = cfg.n_heads if n_heads is None else n_heads
    nk = cfg.n_kv_heads if n_kv is None else n_kv
    q = q.reshape(b, s, nh, cfg.hd)
    k = k.reshape(b, s, nk, cfg.hd)
    v = v.reshape(b, s, nk, cfg.hd)
    return q, k, v


def attn_out(o: jax.Array, p: Params, d_model: int) -> jax.Array:
    b, s, h, hd = o.shape
    return jnp.einsum("bsh,hd->bsd", o.reshape(b, s, h * hd),
                      p["wo"].astype(o.dtype))


def mlp(x: jax.Array, p: Params, act: str) -> jax.Array:
    up = jnp.einsum("bsd,df->bsf", x, p["wi"].astype(x.dtype))
    if act in ("swiglu", "geglu"):
        gate = jnp.einsum("bsd,df->bsf", x, p["wg"].astype(x.dtype))
        gate = jax.nn.silu(gate) if act == "swiglu" else jax.nn.gelu(gate)
        up = gate * up
    elif act == "gelu":
        up = jax.nn.gelu(up)
    elif act == "relu":
        up = jax.nn.relu(up)
    return jnp.einsum("bsf,fd->bsd", up, p["wo"].astype(x.dtype))


def chunked_xent(hidden: jax.Array, head: jax.Array, labels: jax.Array,
                 chunk: int = 1024) -> jax.Array:
    """Mean token cross-entropy computed in S-chunks: the [B,S,V] logits
    tensor never materialises (V stays TP-sharded, bf16 matmul, f32 LSE)."""
    from .. import sharding
    b, s, d = hidden.shape
    c = min(chunk, s)
    n = s // c
    hc = hidden.reshape(b, n, c, d).swapaxes(0, 1)
    lc = labels.reshape(b, n, c).swapaxes(0, 1)
    headb = head.astype(jnp.bfloat16)

    def chunk_loss(carry, inp):
        h, l = inp
        logits = jnp.einsum("bcd,dv->bcv", h.astype(jnp.bfloat16),
                            headb).astype(jnp.float32)
        logits = sharding.constrain(logits, "batch", None, "vocab")
        lse = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, l[..., None], axis=-1)[..., 0]
        return carry + jnp.sum(lse - gold), None

    total, _ = inner_scan(chunk_loss, jnp.zeros((), jnp.float32), (hc, lc), n)
    return total / (b * s)


# -- init helpers --------------------------------------------------------------

def scan_layers(body, x, stacked, unroll: bool = False):
    """``jax.lax.scan`` over stacked layer params, or a Python unroll.

    The unrolled form exists for the dry-run cost analysis: XLA's HLO cost
    model counts a while-loop body ONCE regardless of trip count, so the
    roofline extrapolates per-layer cost from unrolled depth-1/depth-2
    compiles while memory analysis uses the scanned (production) form.
    """
    if not unroll:
        return jax.lax.scan(body, x, stacked)
    n = jax.tree.leaves(stacked)[0].shape[0]
    ys = []
    for i in range(n):
        lp = jax.tree.map(lambda a: a[i], stacked)
        x, y = body(x, lp)
        ys.append(y)
    if ys and jax.tree.leaves(ys[0]):
        ys = jax.tree.map(lambda *z: jnp.stack(z), *ys)
    else:
        ys = None
    return x, ys


def dense_init(key, shape, dtype, scale: float | None = None):
    fan_in = shape[-2] if len(shape) > 1 else shape[-1]
    s = scale if scale is not None else fan_in ** -0.5
    return (jax.random.normal(key, shape, jnp.float32) * s).astype(dtype)


def stack_layer_params(init_one, n_layers: int, key) -> Params:
    keys = jax.random.split(key, n_layers)
    return jax.vmap(init_one)(keys)
