"""Mixture-of-Experts FFN (grok-1, qwen3-moe, switch-style).

Top-k routing with static per-row capacity (GShard-style token dropping).
The dispatch (sort / scatter / gather with dynamic slots) is ``vmap``-ed
over the batch dim, so every dispatch tensor carries a leading B axis that
shards on the data axes — GSPMD cannot shard a *global* dynamic scatter
(it replicates, which costs hundreds of GiB at grok/qwen3 scale), but it
shards batched scatters fine.  Expert tensors get EP on "model" when the
expert count divides the axis (qwen3: 128/16) and capacity/f-dim TP
otherwise (grok: 8 experts).  On TPU the per-group GEMM can lower to the
``moe_dispatch_matmul`` runahead kernel; the (sorted tokens, ragged group
bounds) structure is the paper's dynamic-loop-boundary pattern.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .. import sharding
from . import layers


def init_moe(cfg, key, dtype):
    d, e, f = cfg.d_model, cfg.n_experts, cfg.d_ff_expert or cfg.d_ff
    ks = jax.random.split(key, 4)
    return {
        "router": layers.dense_init(ks[0], (d, e), jnp.float32),
        "we_gate": layers.dense_init(ks[1], (e, d, f), dtype),
        "we_up": layers.dense_init(ks[2], (e, d, f), dtype),
        "we_down": layers.dense_init(ks[3], (e, f, d), dtype),
    }


def _capacity(s: int, k: int, e: int, factor: float) -> int:
    cap = int(factor * s * k / e) + 1
    return (cap + 15) // 16 * 16        # 16-aligned so "model" can shard it


def _route_row(xrow: jax.Array, router: jax.Array, e: int, k: int,
               cap: int):
    """Per-row dispatch plan.  xrow [S,D] -> (slot [S*k], keep [S*k],
    pair_token [S*k], gates [S,k]) in sorted-by-expert order."""
    s = xrow.shape[0]
    logits = jnp.einsum("sd,de->se", xrow.astype(jnp.float32), router)
    gates, eids = jax.lax.top_k(logits, k)                  # [S,k]
    gates = jax.nn.softmax(gates, axis=-1)
    pair_e = eids.reshape(-1)                               # [S*k]
    order = jnp.argsort(pair_e)
    sorted_e = pair_e[order]
    first = jnp.searchsorted(sorted_e, sorted_e)
    pos_in_e = jnp.arange(s * k) - first
    keep = pos_in_e < cap
    slot = sorted_e * cap + jnp.minimum(pos_in_e, cap - 1)
    return slot, keep, order // k, gates, order


def moe_ffn(x: jax.Array, p: dict, cfg,
            capacity_factor: float = 1.25) -> jax.Array:
    """x [B,S,D] -> [B,S,D] via top-k experts with static capacity."""
    b, s, d = x.shape
    e, k = cfg.n_experts, cfg.top_k
    cap = _capacity(s, k, e, capacity_factor)
    router = p["router"].astype(jnp.float32)

    slot, keep, pair_token, gates, order = jax.vmap(
        lambda xr: _route_row(xr, router, e, k, cap))(x)

    # batched scatter: xg[b, slot[b,i]] += x[b, pair_token[b,i]]
    def scatter_row(xr, sl, kp, pt):
        src = jnp.where(kp[:, None], xr[pt], 0.0)
        return jnp.zeros((e * cap, d), xr.dtype).at[
            jnp.where(kp, sl, 0)].add(src, mode="drop")

    xg = jax.vmap(scatter_row)(x, slot, keep, pair_token)   # [B,E*cap,D]
    xg = xg.reshape(b, e, cap, d)
    # EP on experts when divisible (qwen3 128/16).  The d dim stays
    # REPLICATED through the dispatch: sharding it on "model" makes the
    # row gather/scatter emit ~4 GiB all-reduces per layer across the
    # (e,cap) reshape (§Perf iteration 6 — dispatch locality beats
    # activation sharding here)
    xg = sharding.constrain(xg, "batch", "experts", None, None)
    gate = jax.nn.silu(jnp.einsum("becd,edf->becf", xg,
                                  p["we_gate"].astype(xg.dtype)))
    up = jnp.einsum("becd,edf->becf", xg, p["we_up"].astype(xg.dtype))
    hidden = sharding.constrain(gate * up, "batch", "experts", None,
                                "expert_mlp")
    yg = jnp.einsum("becf,efd->becd", hidden, p["we_down"].astype(xg.dtype))
    # (§Perf iteration 7, refuted: replicating E before the combine gather
    # costs MORE wire than GSPMD's masked-gather+all-reduce scheme.  The
    # remaining gap to the ~350 MB/chip all-to-all floor needs a
    # hand-written shard_map dispatch — see EXPERIMENTS.md §Perf.)
    yg = sharding.constrain(yg, "batch", "experts", None, None)
    yg = yg.reshape(b, e * cap, d)

    # gather pairs back and combine with router weights
    def combine(ygr, sl, kp, pt, gt, ord_):
        # bf16 combine: <= top_k additions per token, keeps the backward
        # cotangent chain out of f32 (a 2x live-memory lever at 314B scale)
        pair_out = jnp.where(kp[:, None], ygr[sl], 0.0)
        pair_gate = gt.reshape(-1)[ord_].astype(ygr.dtype)
        out = jnp.zeros((s, d), ygr.dtype).at[pt].add(
            pair_out * pair_gate[:, None])
        return out

    out = jax.vmap(combine)(yg, slot, keep, pair_token, gates, order)
    return out.astype(x.dtype)


def aux_load_balance_loss(x: jax.Array, router: jax.Array, cfg) -> jax.Array:
    """Switch-style load-balancing auxiliary loss."""
    b, s, d = x.shape
    logits = jnp.einsum("bsd,de->bse", x.astype(jnp.float32),
                        router.astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)
    top1 = jnp.argmax(logits, axis=-1)
    frac_tokens = jnp.mean(
        jax.nn.one_hot(top1, cfg.n_experts, dtype=jnp.float32), axis=(0, 1))
    frac_probs = jnp.mean(probs, axis=(0, 1))
    return cfg.n_experts * jnp.sum(frac_tokens * frac_probs)
