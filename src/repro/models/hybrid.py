"""RecurrentGemma / Griffin (arXiv:2402.19427): RG-LRU recurrent blocks
interleaved with local (sliding-window) MQA, pattern (rec, rec, attn).

The temporal state is O(1) per token (diagonal LRU + bounded window), so
long_500k decode runs natively — no KV TopK needed (DESIGN.md
§Arch-applicability).  Layers are scanned per *group* of the pattern; the
tail (n_layers % group) is applied unscanned.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .. import sharding
from . import layers

Params = dict
C_RGLRU = 8.0


def _dtype(cfg):
    return jnp.dtype(cfg.param_dtype)


def init_rec_layer(cfg, key) -> Params:
    dt = _dtype(cfg)
    d, ru = cfg.d_model, cfg.rglru_dim or cfg.d_model
    ks = iter(jax.random.split(key, 8))
    return {
        "ln": jnp.zeros((d,), jnp.float32),
        "w_in": layers.dense_init(next(ks), (d, ru), dt),
        "w_gate_branch": layers.dense_init(next(ks), (d, ru), dt),
        "conv": layers.dense_init(next(ks), (cfg.conv_width, ru), dt, 0.5),
        "w_rg_r": layers.dense_init(next(ks), (ru, ru), dt),
        "w_rg_i": layers.dense_init(next(ks), (ru, ru), dt),
        "lam": jnp.full((ru,), 3.0, jnp.float32),   # sigmoid(3) ~ .95 decay
        "w_out": layers.dense_init(next(ks), (ru, d), dt),
    }


def init_mlp_params(cfg, key) -> Params:
    dt = _dtype(cfg)
    d = cfg.d_model
    ks = iter(jax.random.split(key, 3))
    return {
        "ln2": jnp.zeros((d,), jnp.float32),
        "wi": layers.dense_init(next(ks), (d, cfg.d_ff), dt),
        "wg": layers.dense_init(next(ks), (d, cfg.d_ff), dt),
        "wo_mlp": layers.dense_init(next(ks), (cfg.d_ff, d), dt),
    }


def init_attn_layer(cfg, key) -> Params:
    dt = _dtype(cfg)
    d, hd = cfg.d_model, cfg.hd
    ks = iter(jax.random.split(key, 5))
    return {
        "ln": jnp.zeros((d,), jnp.float32),
        "wq": layers.dense_init(next(ks), (d, cfg.n_heads * hd), dt),
        "wk": layers.dense_init(next(ks), (d, cfg.n_kv_heads * hd), dt),
        "wv": layers.dense_init(next(ks), (d, cfg.n_kv_heads * hd), dt),
        "wo": layers.dense_init(next(ks), (cfg.n_heads * hd, d), dt),
    }


def init_group(cfg, key) -> Params:
    """One pattern group: params for each sublayer + its MLP."""
    p = {}
    ks = jax.random.split(key, 2 * len(cfg.pattern))
    for i, kind in enumerate(cfg.pattern):
        init = init_rec_layer if kind == "rec" else init_attn_layer
        p[f"sub{i}"] = init(cfg, ks[2 * i])
        p[f"mlp{i}"] = init_mlp_params(cfg, ks[2 * i + 1])
    return p


def init_params(cfg, key) -> Params:
    n_groups = cfg.n_layers // len(cfg.pattern)
    n_tail = cfg.n_layers % len(cfg.pattern)
    k_emb, k_g, k_t = jax.random.split(key, 3)
    params = {
        "embed": layers.dense_init(k_emb, (cfg.vocab, cfg.d_model),
                                   _dtype(cfg), 0.02),
        "groups": layers.stack_layer_params(
            functools.partial(init_group, cfg), n_groups, k_g),
        "ln_f": jnp.zeros((cfg.d_model,), jnp.float32),
    }
    if n_tail:
        tail = {}
        kt = jax.random.split(k_t, n_tail * 2)
        for i in range(n_tail):
            kind = cfg.pattern[i]
            init = init_rec_layer if kind == "rec" else init_attn_layer
            tail[f"sub{i}"] = init(cfg, kt[2 * i])
            tail[f"mlp{i}"] = init_mlp_params(cfg, kt[2 * i + 1])
        params["tail"] = tail
    return params


def rglru(x: jax.Array, p: Params, h0=None):
    """RG-LRU over [B,S,ru] via associative scan.  Returns (y, h_last)."""
    xf = x.astype(jnp.float32)
    r = jax.nn.sigmoid(jnp.einsum("bsr,rk->bsk", xf,
                                  p["w_rg_r"].astype(jnp.float32)))
    i = jax.nn.sigmoid(jnp.einsum("bsr,rk->bsk", xf,
                                  p["w_rg_i"].astype(jnp.float32)))
    log_a = C_RGLRU * r * jax.nn.log_sigmoid(p["lam"])      # [B,S,ru] (<0)
    a = jnp.exp(log_a)
    gated = jnp.sqrt(jnp.clip(1.0 - jnp.exp(2.0 * log_a), 1e-12)) * (i * xf)
    if h0 is not None:
        gated = gated.at[:, 0].add(a[:, 0] * h0)

    def op(c1, c2):
        a1, b1 = c1
        a2, b2 = c2
        return a1 * a2, b1 * a2 + b2

    _, h = jax.lax.associative_scan(op, (a, gated), axis=1)
    return h.astype(x.dtype), h[:, -1]


def rec_block(cfg, x, p, conv_state=None, h0=None, single_step=False):
    """Griffin recurrent block: (conv -> RG-LRU) * gelu-gate -> out."""
    u = jnp.einsum("bsd,dr->bsr", x, p["w_in"].astype(x.dtype))
    g = jax.nn.gelu(jnp.einsum("bsd,dr->bsr", x,
                               p["w_gate_branch"].astype(x.dtype)))
    width = p["conv"].shape[0]
    if conv_state is None:
        conv_state = jnp.zeros((x.shape[0], width - 1, u.shape[-1]), u.dtype)
    up = jnp.concatenate([conv_state, u], axis=1)
    uc = sum(up[:, i:i + u.shape[1]] * p["conv"][i].astype(u.dtype)
             for i in range(width))
    new_conv = up[:, -(width - 1):]
    y, h_last = rglru(uc, p, h0)
    out = jnp.einsum("bsr,rd->bsd", y * g, p["w_out"].astype(x.dtype))
    return out, new_conv, h_last


def attn_block(cfg, x, p, pos_offset=0, kv_cache=None, pos=None):
    """Local MQA block. With kv_cache (decode): window ring buffer."""
    b, s, _ = x.shape
    hd = cfg.hd
    q = jnp.einsum("bsd,dh->bsh", x, p["wq"].astype(x.dtype)).reshape(
        b, s, cfg.n_heads, hd)
    k = jnp.einsum("bsd,dh->bsh", x, p["wk"].astype(x.dtype)).reshape(
        b, s, cfg.n_kv_heads, hd)
    v = jnp.einsum("bsd,dh->bsh", x, p["wv"].astype(x.dtype)).reshape(
        b, s, cfg.n_kv_heads, hd)
    if kv_cache is None:
        posv = pos_offset + jnp.arange(s)[None, :]
        q = layers.apply_rope(q, posv, cfg.rope_theta)
        k = layers.apply_rope(k, posv, cfg.rope_theta)
        o = layers.chunked_attention(q, k, v, causal=True, window=cfg.window,
                                     chunk=min(1024, s))
        return jnp.einsum("bsh,hd->bsd", o.reshape(b, s, -1),
                          p["wo"].astype(x.dtype)), None
    kc, vc = kv_cache                     # [B, W, KV, D] ring buffers
    w = kc.shape[1]
    posv = jnp.full((1, 1), pos)
    q = layers.apply_rope(q, posv, cfg.rope_theta)
    k = layers.apply_rope(k, posv, cfg.rope_theta)
    slot = pos % w
    kc = jax.lax.dynamic_update_slice_in_dim(kc, k.astype(kc.dtype), slot, 1)
    vc = jax.lax.dynamic_update_slice_in_dim(vc, v.astype(vc.dtype), slot, 1)
    # ring positions: absolute position of each slot
    idxs = jnp.arange(w)
    abs_pos = jnp.where(idxs <= slot, pos - slot + idxs,
                        pos - slot + idxs - w)
    valid = abs_pos >= 0
    g = cfg.n_heads // cfg.n_kv_heads
    qg = q.reshape(b, cfg.n_kv_heads, g, hd).astype(jnp.float32) / (hd ** 0.5)
    sc = jnp.einsum("bkgd,bwkd->bkgw", qg, kc.astype(jnp.float32))
    sc = jnp.where(valid[None, None, None, :], sc, -jnp.inf)
    wgt = jax.nn.softmax(sc, axis=-1)
    o = jnp.einsum("bkgw,bwkd->bkgd", wgt, vc.astype(jnp.float32))
    o = o.reshape(b, 1, cfg.n_heads * hd).astype(x.dtype)
    return jnp.einsum("bsh,hd->bsd", o, p["wo"].astype(x.dtype)), (kc, vc)


def _mlp(cfg, x, p):
    h = layers.rms_norm(x, p["ln2"], cfg.norm_eps)
    g = jax.nn.gelu(jnp.einsum("bsd,df->bsf", h, p["wg"].astype(x.dtype)))
    u = jnp.einsum("bsd,df->bsf", h, p["wi"].astype(x.dtype))
    return x + jnp.einsum("bsf,fd->bsd", g * u, p["wo_mlp"].astype(x.dtype))


def _group_fwd(cfg, x, gp, pos_offset=0):
    for i, kind in enumerate(cfg.pattern):
        sub, mp = gp[f"sub{i}"], gp[f"mlp{i}"]
        h = layers.rms_norm(x, sub["ln"], cfg.norm_eps)
        if kind == "rec":
            y, _, _ = rec_block(cfg, h, sub)
        else:
            y, _ = attn_block(cfg, h, sub, pos_offset)
        x = x + y
        x = _mlp(cfg, x, mp)
    return x


def forward(params, cfg, tokens, *, remat: str = "full",
            unroll: bool = False):
    x = jnp.take(params["embed"], tokens, axis=0).astype(_dtype(cfg))
    x = sharding.constrain(x, "batch", None, None)

    def body(carry, gp):
        return _group_fwd(cfg, carry, gp), None

    if remat == "full":
        body = jax.checkpoint(body,
                              policy=jax.checkpoint_policies.nothing_saveable)
    x, _ = layers.scan_layers(body, x, params["groups"], unroll)
    if "tail" in params:
        n_tail = cfg.n_layers % len(cfg.pattern)
        for i in range(n_tail):
            sub, mp = params["tail"][f"sub{i}"], params["tail"][f"mlp{i}"]
            h = layers.rms_norm(x, sub["ln"], cfg.norm_eps)
            if cfg.pattern[i] == "rec":
                y, _, _ = rec_block(cfg, h, sub)
            else:
                y, _ = attn_block(cfg, h, sub)
            x = x + y
            x = _mlp(cfg, x, mp)
    return layers.rms_norm(x, params["ln_f"], cfg.norm_eps)


def loss_fn(params, cfg, tokens, labels, *, remat: str = "full",
            unroll: bool = False):
    hidden = forward(params, cfg, tokens, remat=remat, unroll=unroll)
    return layers.chunked_xent(hidden, params["embed"].T, labels)


def _ring_from_tail(cfg, k, v, w: int):
    """Scatter the last ``w`` tokens of [B,S,KV,D] into ring-buffer slots
    so slot i holds the token whose absolute position % w == i."""
    b, s, kv, d = k.shape
    take = min(w, s)
    pos = jnp.arange(s - take, s)
    slots = pos % w
    kc = jnp.zeros((b, w, kv, d), k.dtype).at[:, slots].set(k[:, -take:])
    vc = jnp.zeros((b, w, kv, d), v.dtype).at[:, slots].set(v[:, -take:])
    return kc, vc


def prefill(params, cfg, tokens, *, remat: str = "full",
            unroll: bool = False):
    """Forward over the prompt collecting recurrent states + window KV;
    returns (last-token logits, cache)."""
    x = jnp.take(params["embed"], tokens, axis=0).astype(_dtype(cfg))
    x = sharding.constrain(x, "batch", None, None)
    s = tokens.shape[1]
    w = cfg.window

    def body(carry, gp):
        xc = carry
        states = {}
        for i, kind in enumerate(cfg.pattern):
            sub, mp = gp[f"sub{i}"], gp[f"mlp{i}"]
            h = layers.rms_norm(xc, sub["ln"], cfg.norm_eps)
            if kind == "rec":
                y, conv2, h2 = rec_block(cfg, h, sub)
                states[f"conv{i}"] = conv2
                states[f"h{i}"] = h2
            else:
                b, sl, _ = h.shape
                hd = cfg.hd
                q = jnp.einsum("bsd,dh->bsh", h, sub["wq"].astype(h.dtype)
                               ).reshape(b, sl, cfg.n_heads, hd)
                k = jnp.einsum("bsd,dh->bsh", h, sub["wk"].astype(h.dtype)
                               ).reshape(b, sl, cfg.n_kv_heads, hd)
                v = jnp.einsum("bsd,dh->bsh", h, sub["wv"].astype(h.dtype)
                               ).reshape(b, sl, cfg.n_kv_heads, hd)
                posv = jnp.arange(sl)[None, :]
                qr = layers.apply_rope(q, posv, cfg.rope_theta)
                kr = layers.apply_rope(k, posv, cfg.rope_theta)
                o = layers.chunked_attention(qr, kr, v, causal=True,
                                             window=w, chunk=min(1024, sl))
                y = jnp.einsum("bsh,hd->bsd", o.reshape(b, sl, -1),
                               sub["wo"].astype(h.dtype))
                states[f"k{i}"], states[f"v{i}"] = _ring_from_tail(
                    cfg, kr, v, min(w, s))
            xc = xc + y
            xc = _mlp(cfg, xc, mp)
        return xc, states

    if remat == "full":
        body = jax.checkpoint(body,
                              policy=jax.checkpoint_policies.nothing_saveable)
    x, states = layers.scan_layers(body, x, params["groups"], unroll)
    cache = dict(states)
    if "tail" in params:
        n_tail = cfg.n_layers % len(cfg.pattern)
        for i in range(n_tail):
            sub, mp = params["tail"][f"sub{i}"], params["tail"][f"mlp{i}"]
            h = layers.rms_norm(x, sub["ln"], cfg.norm_eps)
            y, conv2, h2 = rec_block(cfg, h, sub)   # pattern prefix = rec
            cache[f"tail_conv{i}"] = conv2
            cache[f"tail_h{i}"] = h2
            x = x + y
            x = _mlp(cfg, x, mp)
    x = layers.rms_norm(x, params["ln_f"], cfg.norm_eps)
    logits = jnp.einsum("bd,vd->bv", x[:, -1].astype(jnp.float32),
                        params["embed"].astype(jnp.float32))
    cache["pos"] = jnp.asarray(s, jnp.int32)
    return logits, cache


def init_cache(cfg, batch: int, max_len: int = 0) -> dict:
    """Per-group states: conv [G,B,W-1,ru], lru h [G,B,ru] per rec sublayer;
    ring KV [G,B,window,KV,D] per attn sublayer."""
    n_groups = cfg.n_layers // len(cfg.pattern)
    n_tail = cfg.n_layers % len(cfg.pattern)
    ru = cfg.rglru_dim or cfg.d_model
    dt = _dtype(cfg)
    w = min(cfg.window, max_len) if max_len else cfg.window
    cache = {"pos": jnp.zeros((), jnp.int32)}
    for i, kind in enumerate(cfg.pattern):
        if kind == "rec":
            cache[f"conv{i}"] = jnp.zeros(
                (n_groups, batch, cfg.conv_width - 1, ru), dt)
            cache[f"h{i}"] = jnp.zeros((n_groups, batch, ru), jnp.float32)
        else:
            cache[f"k{i}"] = jnp.zeros((n_groups, batch, w, cfg.n_kv_heads,
                                        cfg.hd), dt)
            cache[f"v{i}"] = jnp.zeros((n_groups, batch, w, cfg.n_kv_heads,
                                        cfg.hd), dt)
    for i in range(n_tail):
        if cfg.pattern[i] == "rec":
            cache[f"tail_conv{i}"] = jnp.zeros(
                (batch, cfg.conv_width - 1, ru), dt)
            cache[f"tail_h{i}"] = jnp.zeros((batch, ru), jnp.float32)
        else:
            cache[f"tail_k{i}"] = jnp.zeros((batch, w, cfg.n_kv_heads,
                                             cfg.hd), dt)
            cache[f"tail_v{i}"] = jnp.zeros((batch, w, cfg.n_kv_heads,
                                             cfg.hd), dt)
    return cache


def decode_step(params, cfg, cache, token, *, unroll: bool = False):
    x = jnp.take(params["embed"], token[:, None], axis=0).astype(_dtype(cfg))
    pos = cache["pos"]

    def body(carry, inp):
        xc = carry
        gp = inp["gp"]
        new_states = {}
        for i, kind in enumerate(cfg.pattern):
            sub, mp = gp[f"sub{i}"], gp[f"mlp{i}"]
            h = layers.rms_norm(xc, sub["ln"], cfg.norm_eps)
            if kind == "rec":
                y, conv2, h2 = rec_block(cfg, h, sub,
                                         conv_state=inp[f"conv{i}"],
                                         h0=inp[f"h{i}"], single_step=True)
                new_states[f"conv{i}"] = conv2
                new_states[f"h{i}"] = h2
            else:
                y, (k2, v2) = attn_block(cfg, h, sub,
                                         kv_cache=(inp[f"k{i}"], inp[f"v{i}"]),
                                         pos=pos)
                new_states[f"k{i}"] = k2
                new_states[f"v{i}"] = v2
            xc = xc + y
            xc = _mlp(cfg, xc, mp)
        return xc, new_states

    xs = {"gp": params["groups"]}
    for key in cache:
        if key != "pos" and not key.startswith("tail_"):
            xs[key] = cache[key]
    x, new_states = layers.scan_layers(body, x, xs, unroll)
    new_cache = dict(new_states)
    # tail (unscanned) sublayers
    if "tail" in params:
        n_tail = cfg.n_layers % len(cfg.pattern)
        for i in range(n_tail):
            sub, mp = params["tail"][f"sub{i}"], params["tail"][f"mlp{i}"]
            h = layers.rms_norm(x, sub["ln"], cfg.norm_eps)
            if cfg.pattern[i] == "rec":
                y, conv2, h2 = rec_block(cfg, h, sub,
                                         conv_state=cache[f"tail_conv{i}"],
                                         h0=cache[f"tail_h{i}"],
                                         single_step=True)
                new_cache[f"tail_conv{i}"] = conv2
                new_cache[f"tail_h{i}"] = h2
            else:
                y, (k2, v2) = attn_block(
                    cfg, h, sub,
                    kv_cache=(cache[f"tail_k{i}"], cache[f"tail_v{i}"]),
                    pos=pos)
                new_cache[f"tail_k{i}"] = k2
                new_cache[f"tail_v{i}"] = v2
            x = x + y
            x = _mlp(cfg, x, mp)
    x = layers.rms_norm(x, params["ln_f"], cfg.norm_eps)
    logits = jnp.einsum("bd,vd->bv", x[:, -1].astype(jnp.float32),
                        params["embed"].astype(jnp.float32))
    new_cache["pos"] = pos + 1
    return logits, new_cache
