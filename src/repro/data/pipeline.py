"""Deterministic, shardable synthetic token pipeline.

Each (step, global-example) pair maps to a seed, so any host can
reconstruct exactly its shard of any step's batch — restart/elastic-safe by
construction (no iterator state to checkpoint beyond the step counter).
Batches are a Zipf-ish token mixture with induction-head structure
(repeated bigrams) so small models show a real, monotonic learnable signal.
"""

from __future__ import annotations

from dataclasses import dataclass

import numpy as np

import jax.numpy as jnp


@dataclass(frozen=True)
class DataConfig:
    vocab: int
    seq_len: int
    global_batch: int
    seed: int = 17


def _example(rng: np.random.Generator, cfg: DataConfig) -> np.ndarray:
    v = cfg.vocab
    s = cfg.seq_len
    base = rng.zipf(1.5, size=s).clip(1, v - 1)
    # induction structure: copy a window later in the sequence
    w = max(2, s // 8)
    start = rng.integers(0, s - 2 * w)
    dst = rng.integers(start + w, s - w)
    base[dst:dst + w] = base[start:start + w]
    return base.astype(np.int32)


def batch_at(step: int, cfg: DataConfig, shard: tuple[int, int] = (0, 1)):
    """Return (tokens, labels) for this host's shard of batch ``step``.

    shard = (index, count) along the global batch dim.
    """
    idx, count = shard
    per = cfg.global_batch // count
    rows = []
    for i in range(per):
        ex = idx * per + i
        rng = np.random.default_rng(
            np.random.SeedSequence([cfg.seed, step, ex]))
        rows.append(_example(rng, cfg))
    toks = np.stack(rows)
    labels = np.roll(toks, -1, axis=1)
    labels[:, -1] = 0
    return jnp.asarray(toks), jnp.asarray(labels)


def batches(cfg: DataConfig, start_step: int = 0,
            shard: tuple[int, int] = (0, 1)):
    step = start_step
    while True:
        yield step, batch_at(step, cfg, shard)
        step += 1
