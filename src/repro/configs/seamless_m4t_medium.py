"""SeamlessM4T-medium — enc-dec, speech frontend stubbed
[arXiv:2308.11596; hf]."""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="seamless-m4t-medium", family="encdec", n_layers=12,
    n_enc_layers=12, d_model=1024, n_heads=16, n_kv_heads=16, d_ff=4096,
    vocab=256206, head_dim=64, act="relu", src_len=3200,
    tie_embeddings=True)
