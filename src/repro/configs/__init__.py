"""Architecture registry: ``--arch <id>`` -> ArchConfig."""

from __future__ import annotations

from .base import SHAPES, ArchConfig, ShapeCell, smoke_shape

_MODULES = {
    "tinyllama-1.1b": "tinyllama_1_1b",
    "llama3.2-1b": "llama3_2_1b",
    "gemma-7b": "gemma_7b",
    "qwen2-1.5b": "qwen2_1_5b",
    "qwen2-vl-72b": "qwen2_vl_72b",
    "mamba2-130m": "mamba2_130m",
    "grok-1-314b": "grok_1_314b",
    "qwen3-moe-235b-a22b": "qwen3_moe_235b_a22b",
    "recurrentgemma-9b": "recurrentgemma_9b",
    "seamless-m4t-medium": "seamless_m4t_medium",
}

ARCH_NAMES = list(_MODULES)


def get_config(name: str) -> ArchConfig:
    import importlib
    if name not in _MODULES:
        raise KeyError(f"unknown arch {name!r}; available: {ARCH_NAMES}")
    mod = importlib.import_module(f".{_MODULES[name]}", __package__)
    return mod.CONFIG


__all__ = ["ARCH_NAMES", "SHAPES", "ArchConfig", "ShapeCell", "get_config",
           "smoke_shape"]
