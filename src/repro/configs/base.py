"""Architecture config schema + input-shape cells.

Every assigned architecture is a ``--arch <id>`` selectable ArchConfig; the
four input-shape cells (train_4k / prefill_32k / decode_32k / long_500k)
are ShapeCells.  ``reduced()`` yields the CPU smoke-test variant.
"""

from __future__ import annotations

from dataclasses import dataclass, replace


@dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                      # dense | moe | ssm | hybrid | encdec | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: int = 0                # 0 -> d_model // n_heads
    act: str = "swiglu"
    qkv_bias: bool = False
    tie_embeddings: bool = False
    scale_embed: bool = False        # gemma: embeddings scaled by sqrt(d)
    rope_theta: float = 10000.0
    logit_softcap: float = 0.0
    # MoE
    n_experts: int = 0
    top_k: int = 0
    d_ff_expert: int = 0
    # SSM (mamba2)
    ssm_state: int = 0
    ssm_expand: int = 2
    ssm_head_dim: int = 64
    ssm_chunk: int = 64
    conv_width: int = 4
    # hybrid (recurrentgemma): layer pattern, window for local attention
    pattern: tuple = ()              # e.g. ("rec", "rec", "attn")
    window: int = 0
    rglru_dim: int = 0
    # enc-dec
    n_enc_layers: int = 0
    src_len: int = 3200              # stub frontend output length (audio frames)
    # vlm
    mrope_sections: tuple = ()       # (t, h, w) head_dim/2 split
    n_patches: int = 1024            # stub vision frontend output length
    # NVR sparse-KV decode (the paper's technique)
    sparse_kv: bool = True           # eligible for TopK sparse decode
    kv_page: int = 16                # fuzzy gather granularity (tokens/page)
    kv_topk_pages: int = 64          # pages kept per head
    kv_dtype: str = "bfloat16"       # "int8": quantised KV cache (beyond-paper)
    # numerics
    param_dtype: str = "bfloat16"
    norm_eps: float = 1e-6

    @property
    def hd(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    @property
    def d_inner(self) -> int:        # mamba2 inner width
        return self.ssm_expand * self.d_model

    @property
    def n_ssm_heads(self) -> int:
        return self.d_inner // self.ssm_head_dim

    def reduced(self) -> "ArchConfig":
        """Tiny same-family variant for CPU smoke tests."""
        return replace(
            self,
            n_layers=min(self.n_layers, 2),
            n_enc_layers=min(self.n_enc_layers, 2),
            d_model=128,
            n_heads=4,
            n_kv_heads=min(self.n_kv_heads, 2) if self.n_kv_heads > 1 else 1,
            head_dim=32,
            d_ff=256,
            d_ff_expert=128 if self.d_ff_expert else 0,
            vocab=512,
            n_experts=min(self.n_experts, 4) if self.n_experts else 0,
            top_k=min(self.top_k, 2) if self.top_k else 0,
            ssm_state=min(self.ssm_state, 16) if self.ssm_state else 0,
            ssm_head_dim=32 if self.ssm_state else 64,
            ssm_chunk=8 if self.ssm_state else 64,
            pattern=self.pattern[:3] if self.pattern else (),
            window=min(self.window, 64) if self.window else 0,
            rglru_dim=128 if self.rglru_dim else 0,
            src_len=64,
            n_patches=16,
            mrope_sections=(8, 4, 4) if self.mrope_sections else (),
            kv_topk_pages=4,
            kv_page=4,
            param_dtype="float32",
        )

    def params_count(self) -> float:
        """Analytic parameter count (for 6ND roofline terms)."""
        d, hd = self.d_model, self.hd
        emb = self.vocab * d * (1 if self.tie_embeddings else 2)
        if self.family == "ssm":
            di, ds, nh = self.d_inner, self.ssm_state, self.n_ssm_heads
            per = (d * (2 * di + 2 * ds + nh)    # in_proj (z,x,B,C,dt heads)
                   + self.conv_width * (di + 2 * ds)
                   + di * d + 2 * d)
            return emb + self.n_layers * per
        att = d * (self.n_heads * hd + 2 * self.n_kv_heads * hd) \
            + self.n_heads * hd * d
        glu = 3 if self.act in ("swiglu", "geglu") else 2
        if self.n_experts:
            ffn = self.n_experts * glu * d * (self.d_ff_expert or self.d_ff) \
                + d * self.n_experts
        else:
            ffn = glu * d * self.d_ff
        per = att + ffn + 2 * d
        n_dec = self.n_layers
        total = emb + n_dec * per
        if self.n_enc_layers:
            total += self.n_enc_layers * (att + glu * d * self.d_ff + 2 * d)
            total += n_dec * att  # cross-attention in decoder
        if self.family == "hybrid":
            # recurrent layers replace attention with RG-LRU block
            n_rec = sum(1 for i in range(self.n_layers)
                        if self.pattern[i % len(self.pattern)] == "rec")
            rec_per = d * self.rglru_dim * 2 + self.rglru_dim * d \
                + 3 * self.rglru_dim
            total += n_rec * (rec_per - att)
        return float(total)

    def active_params_count(self) -> float:
        """Activated params per token (MoE: only top_k experts)."""
        if not self.n_experts:
            return self.params_count()
        full = self.params_count()
        glu = 3 if self.act in ("swiglu", "geglu") else 2
        expert_p = self.n_layers * self.n_experts * glu * self.d_model \
            * (self.d_ff_expert or self.d_ff)
        active_p = expert_p * self.top_k / self.n_experts
        return float(full - expert_p + active_p)


@dataclass(frozen=True)
class ShapeCell:
    name: str
    seq_len: int
    global_batch: int
    kind: str                        # train | prefill | decode


SHAPES = {
    "train_4k": ShapeCell("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeCell("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeCell("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeCell("long_500k", 524288, 1, "decode"),
}


def smoke_shape(kind: str) -> ShapeCell:
    if kind == "train":
        return ShapeCell("smoke_train", 64, 2, "train")
    if kind == "prefill":
        return ShapeCell("smoke_prefill", 64, 2, "prefill")
    return ShapeCell("smoke_decode", 64, 2, "decode")
