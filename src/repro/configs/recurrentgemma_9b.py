"""RecurrentGemma-9B — RG-LRU + local attention 1:2 [arXiv:2402.19427]."""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="recurrentgemma-9b", family="hybrid", n_layers=38, d_model=4096,
    n_heads=16, n_kv_heads=1, d_ff=12288, vocab=256000, head_dim=256,
    act="geglu", scale_embed=True, pattern=("rec", "rec", "attn"),
    window=2048, rglru_dim=4096, tie_embeddings=True, sparse_kv=False)
