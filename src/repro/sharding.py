"""Logical-axis sharding: rules map logical axis names -> mesh axes.

Models annotate activations with ``constrain(x, "batch", None, "mlp")``;
outside a mesh context this is a no-op (CPU unit tests), inside
``jax.sharding.use_mesh`` it becomes ``with_sharding_constraint``.

Default rules implement DP("pod","data") x TP("model") with FSDP on the
"data" axis for large parameters and EP on "model" for divisible expert
counts (MaxText-style).
"""

from __future__ import annotations

import re

import jax
import numpy as np
from jax.sharding import PartitionSpec as P

# logical name -> mesh axis (or tuple)
DEFAULT_RULES: dict = {
    "batch": ("pod", "data"),
    "seq": None,
    "seq_shard": ("data", "model"),   # long-context KV sequence sharding
    "heads": "model",
    "kv_heads": "model",
    "embed": None,
    "mlp": "model",
    "vocab": "model",
    "experts": "model",
    "expert_mlp": "model",
    "moe_cap": "model",
    "seq_sp": "model",   # Megatron-style sequence parallelism between blocks
    "fsdp": ("pod", "data"),   # on multi-pod, params/opt shard across pods too
    "layers": None,
    "stage": "pod",                    # pipeline-parallel stage axis (opt-in)
}

_rules = dict(DEFAULT_RULES)


def set_rules(overrides: dict | None = None) -> None:
    global _rules
    _rules = dict(DEFAULT_RULES)
    if overrides:
        _rules.update(overrides)


def get_rules() -> dict:
    return dict(_rules)


def _get_abstract_mesh():
    # jax >= 0.5 exposes this at jax.sharding; 0.4.3x only at jax._src.mesh
    fn = getattr(jax.sharding, "get_abstract_mesh", None)
    if fn is None:
        from jax._src import mesh as _mesh_mod
        fn = getattr(_mesh_mod, "get_abstract_mesh", None)
    return fn() if fn is not None else None


def _mesh_axes() -> dict:
    try:
        m = _get_abstract_mesh()
    except Exception:
        return {}
    # unset contexts read back as None (>=0.5) or an empty tuple (0.4.3x)
    shape = getattr(m, "shape", None)
    return dict(shape) if shape else {}


def resolve(*logical, mesh_axes: dict | None = None) -> P:
    """Translate logical axis names to a PartitionSpec valid on the current
    mesh (silently dropping axes the mesh does not have)."""
    axes = _mesh_axes() if mesh_axes is None else mesh_axes
    out = []
    for name in logical:
        if name is None:
            out.append(None)
            continue
        ax = _rules.get(name, None)
        if ax is None:
            out.append(None)
            continue
        if isinstance(ax, tuple):
            present = tuple(a for a in ax if a in axes)
            out.append(present if present else None)
        else:
            out.append(ax if ax in axes else None)
    return P(*out)


def constrain(x: jax.Array, *logical) -> jax.Array:
    axes = _mesh_axes()
    if not axes:
        return x
    spec = resolve(*logical, mesh_axes=axes)
    # drop shardings that do not divide the dimension, and de-duplicate
    # mesh axes (first dim wins)
    fixed = []
    used: set = set()
    for dim, s in zip(x.shape, spec):
        if s:
            parts = (s,) if isinstance(s, str) else tuple(s)
            parts = tuple(a for a in parts if a not in used)
            s = (parts[0] if len(parts) == 1 else parts) if parts else None
        n = int(np.prod([axes[a] for a in ((s,) if isinstance(s, str) else s)])
                ) if s else 1
        ok = s if s and dim % n == 0 else None
        if ok:
            used.update((ok,) if isinstance(ok, str) else ok)
        fixed.append(ok)
    return jax.lax.with_sharding_constraint(x, P(*fixed))


# -- parameter sharding rules --------------------------------------------------

# (regex on param path, logical spec per dim — trailing dims matched)
PARAM_RULES: list[tuple[str, tuple]] = [
    (r"embed$", ("vocab", "fsdp")),
    (r"lm_head$", ("fsdp", "vocab")),
    (r"\bwq$|\bwk$|\bwv$|\bwi$|\bwg$", ("fsdp", "heads")),
    (r"\bbq$|\bbk$|\bbv$", ("heads",)),
    (r"\bwo$|\bwo_mlp$", ("heads", "fsdp")),
    (r"\brouter$", ("fsdp", None)),
    (r"\bwe_gate$|\bwe_up$", ("experts", "fsdp", None)),
    (r"\bwe_down$", ("experts", None, "fsdp")),
    (r"\bln[0-9a-z_]*$|\bnorm[0-9a-z_]*$", (None,)),
    (r"\bw_rg.*$|\bconv.*$|\bwdt$|\bA_log$|\bD$|\bdt_bias$", (None,)),
]


def param_spec(path: str, shape: tuple, mesh_axes: dict,
               stacked: bool = False) -> P:
    """PartitionSpec for a parameter; leading layer axis (scan stack) is
    never sharded.  Falls back to a size-aware generic rule."""
    body = shape[1:] if stacked else shape
    logical: tuple | None = None
    for pat, spec in PARAM_RULES:
        if re.search(pat, path):
            logical = spec
            break
    if logical is not None and len(logical) == len(body):
        base = resolve(*logical, mesh_axes=mesh_axes)
    else:
        # generic: shard trailing dim on model if divisible, a big leading
        # dim on data (FSDP) if divisible
        spec = [None] * len(body)
        model = mesh_axes.get("model", 1)
        data = mesh_axes.get("data", 1)
        if body and model > 1 and body[-1] % model == 0 and body[-1] >= 512:
            spec[-1] = "model"
        for i, s in enumerate(body[:-1]):
            if data > 1 and s % data == 0 and s >= 1024:
                spec[i] = "data"
                break
        base = P(*spec)
    # drop shardings that do not divide
    fixed = []
    for dim, s in zip(body, base):
        n = 1
        if s:
            n = int(np.prod([mesh_axes[a]
                             for a in ((s,) if isinstance(s, str) else s)]))
        fixed.append(s if s and dim % n == 0 else None)
    # TP-rescue: if the model axis got dropped (e.g. grok: 8 experts on a
    # 16-way axis), recover it on the largest unsharded divisible dim so
    # huge tensors never end up 1D-sharded
    used = set()
    for s in fixed:
        for a in ((s,) if isinstance(s, str) else (s or ())):
            used.add(a)
    model = mesh_axes.get("model", 1)
    if model > 1 and "model" not in used:
        cands = [i for i, (dim, s) in enumerate(zip(body, fixed))
                 if s is None and dim % model == 0 and dim >= 512]
        if cands:
            best = max(cands, key=lambda i: body[i])
            fixed[best] = "model"
    if stacked:
        return P(None, *fixed)
    return P(*fixed)


# -- serve-layer tensor parallelism --------------------------------------------
#
# The paged serve engine shards along ONE axis only: the KV-head axis,
# over a 1-axis ("model",) mesh.  The sharded objects are the physical
# K/V/summary page pools and the QKV projection weights (head-sharded
# columns); *everything else* — output projection, FFN, norms, embed,
# lm_head — stays replicated, and per-head attention outputs are
# all-gathered before the output projection.  That asymmetry is
# deliberate: every cross-shard combine is a concatenation of
# independent per-head results, never an arithmetic reduction (no
# psum), so tp>1 logits are bitwise-identical to tp=1 — the serve
# layer's preemption-resume guarantee extended across shards.  (The
# training-path PARAM_RULES above shard FFN/vocab too and accept
# reduction-order drift; serving trades those FLOP savings for the
# bitwise invariant while keeping the KV pool — the memory-dominant
# object — at 1/tp bytes per shard.)

SERVE_TP_AXIS = "model"

_SERVE_TP_SHARDED = re.compile(r"\b(wq|wk|wv|bq|bk|bv)$")


def serve_pool_specs(axis: str = SERVE_TP_AXIS) -> tuple[P, P]:
    """(k/v pool spec, summary pool spec) for the paged engine's physical
    pools: ``[L, P, page, KV, D]`` and ``[L, P, KV, D]``, sharded on the
    KV-head dim only — the page-id dim is never sharded, so the
    allocator/scheduler/NVR-capture layers keep one global physical
    page-id space."""
    return (P(None, None, None, axis, None), P(None, None, axis, None))


def serve_param_specs(params, axis: str = SERVE_TP_AXIS):
    """PartitionSpec pytree for PagedEngine tensor parallelism.

    QKV projection weights/biases shard their trailing (flattened head)
    axis; every other leaf is fully replicated.  The flat head axis is
    head-major, so a 1/tp column slice is a contiguous block of whole
    GQA groups — consistent with the KV-head slice of the pools
    (requires ``n_heads % tp == 0 and n_kv_heads % tp == 0``).
    """
    def spec(path, leaf):
        name = "/".join(getattr(k, "key", str(k)) for k in path)
        if _SERVE_TP_SHARDED.search(name):
            nd = len(np.shape(leaf)) if not hasattr(leaf, "ndim") \
                else leaf.ndim
            return P(*([None] * (nd - 1)), axis)
        return P()
    return jax.tree_util.tree_map_with_path(spec, params)


def constrain_like_params(tree, stacked_prefix: str = "layers"):
    """Constrain a params-shaped pytree (e.g. gradients) to the parameter
    sharding rules — turns gradient all-reduces into reduce-scatters on
    the FSDP axis (halves the per-layer gradient wire volume)."""
    axes = _mesh_axes()
    if not axes:
        return tree
    specs = tree_param_specs(tree, axes, stacked_prefix)
    return jax.tree.map(
        lambda x, s: jax.lax.with_sharding_constraint(x, s), tree, specs,
        is_leaf=lambda x: hasattr(x, "shape"))


def tree_param_specs(params, mesh_axes: dict, stacked_prefix: str = "layers"):
    """Pytree of PartitionSpecs mirroring a params pytree."""
    flat = jax.tree_util.tree_flatten_with_path(params)[0]
    specs = {}
    for path, leaf in flat:
        keys = [getattr(k, "key", str(k)) for k in path]
        name = "/".join(keys)
        stacked = stacked_prefix in keys[:-1]
        shape = np.shape(leaf) if not hasattr(leaf, "shape") else leaf.shape
        specs[name] = param_spec(name, tuple(shape), mesh_axes, stacked)
    # rebuild tree
    def lookup(path, leaf):
        keys = "/".join(getattr(k, "key", str(k)) for k in path)
        return specs[keys]
    return jax.tree_util.tree_map_with_path(lookup, params)
