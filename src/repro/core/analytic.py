"""LLMCompass-style analytic performance model (paper Fig. 8).

Predicts prefill/decode throughput of a sparse-KV LLM as a function of
off-chip bandwidth, with and without NVR.  The NVR effect enters as the
*effective bandwidth efficiency* of irregular KV gathers: without
prefetching, scattered reads expose DRAM latency and rigid DMA granularity
(efficiency ~0.5); NVR's runahead + VMIG packing raises it to ~0.9 (its
measured coverage) — matching the paper's +50 % decode-throughput claim.
"""

from __future__ import annotations

from dataclasses import dataclass

import numpy as np


@dataclass(frozen=True)
class NPUSpec:
    flops: float = 128e12          # dense peak FLOP/s
    sram_kb: int = 256
    eff_regular: float = 0.85      # streaming DRAM efficiency
    eff_irregular: float = 0.50    # scattered-gather efficiency, no prefetch
    eff_nvr: float = 0.90          # with NVR (paper coverage >90 %)


@dataclass(frozen=True)
class LLMSpec:
    n_params: float = 7e9
    n_layers: int = 32
    d_model: int = 4096
    n_kv_heads: int = 8
    head_dim: int = 128
    bytes_per_el: int = 2
    kv_sparsity: float = 1 / 16.0  # Double-Sparsity TopK fraction


def prefill_throughput(m: LLMSpec, hw: NPUSpec, bw: float, seq: int,
                       nvr: bool) -> float:
    """Tokens/s for the (compute-bound) prefill stage."""
    flops_per_tok = 2 * m.n_params + 4 * m.n_layers * m.d_model * seq
    t_compute = flops_per_tok / hw.flops
    bytes_per_tok = m.n_params * m.bytes_per_el / seq  # weights amortised
    eff = hw.eff_regular if not nvr else max(hw.eff_regular, 0.9)
    t_mem = bytes_per_tok / (bw * eff)
    return 1.0 / max(t_compute, t_mem)


def decode_throughput(m: LLMSpec, hw: NPUSpec, bw: float, seq: int,
                      batch: int, nvr: bool) -> float:
    """Tokens/s/batch for the (IO-bound) decode stage with sparse KV."""
    kv_bytes_tok = (2 * m.n_layers * seq * m.kv_sparsity
                    * m.n_kv_heads * m.head_dim * m.bytes_per_el)
    w_bytes_tok = m.n_params * m.bytes_per_el / batch
    eff_kv = hw.eff_nvr if nvr else hw.eff_irregular
    t_kv = kv_bytes_tok / (bw * eff_kv)
    t_w = w_bytes_tok / (bw * hw.eff_regular)
    flops_per_tok = 2 * m.n_params / batch * 0 + 2 * m.n_params
    t_compute = flops_per_tok / hw.flops
    return batch / max(t_kv + t_w, t_compute)


def fig8_sweep(bws=None, seqs=(8192, 16384, 32768), batch: int = 64):
    """Returns rows: (stage, seq, bw_GBs, base_tok_s, nvr_tok_s)."""
    m, hw = LLMSpec(), NPUSpec()
    bws = bws or np.array([25, 50, 100, 200, 400, 800]) * 1e9
    rows = []
    for seq in seqs:
        for bw in bws:
            rows.append(("prefill", seq, bw / 1e9,
                         prefill_throughput(m, hw, bw, seq, False),
                         prefill_throughput(m, hw, bw, seq, True)))
            rows.append(("decode", seq, bw / 1e9,
                         decode_throughput(m, hw, bw, seq, batch, False),
                         decode_throughput(m, hw, bw, seq, batch, True)))
    return rows
