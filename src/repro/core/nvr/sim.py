"""End-to-end NVR simulation driver + metrics.

Execution modes (Fig. 5 bars):
  dense    — no sparsity skipping: regular streaming, perfectly prefetchable,
             but ``dense_compute_scale`` × the compute.
  inorder  — sparse, serial load/compute (baseline Gemmini).
  ooo      — sparse, *ideal* OoO: loads overlap compute; wall-clock is
             max(compute path, memory path).  Still suboptimal when IO-bound
             (the paper's point in §II-B).
  inorder + prefetcher — stream / imp / dvr / nvr, optional NSB.
"""

from __future__ import annotations

from dataclasses import dataclass, field

import numpy as np

from .machine import LINE_BYTES, Hierarchy, make_hierarchy
from .prefetchers import PREFETCHERS, Prefetcher
from .trace import Compute, Trace, VLoad

ISSUE = 1.0     # cycles to issue a vector load
HIT_LAT = 2.0   # scratchpad/L1-equivalent hit latency
OOO_WINDOW = 8  # ideal-OoO outstanding vector loads (coarse-grained NPU ROB)
DMA_GRANULE_LINES = 4  # rigid preload granularity without µ-inst prefetch


@dataclass
class SimResult:
    workload: str
    mode: str
    dtype_bytes: int
    nsb_kb: int
    total: float
    base: float
    stall: float
    compute: float
    n_vloads: int
    demand_misses: int
    l2_accesses: int
    demand_offchip: float
    prefetch_offchip: float
    pf_issued: int
    pf_used: int
    nsb_hits: int = 0
    coverage: float = float("nan")  # filled by sweeps vs baseline

    @property
    def offchip(self) -> float:
        return self.demand_offchip + self.prefetch_offchip

    @property
    def accuracy(self) -> float:
        return self.pf_used / self.pf_issued if self.pf_issued else float("nan")

    @property
    def miss_rate(self) -> float:
        return self.demand_misses / max(1, self.l2_accesses)


def simulate(trace: Trace, mode: str = "inorder",
             prefetcher: str | None = None, l2_kb: int = 256,
             nsb_kb: int = 0, dram_latency: float = 150.0,
             dram_bw: float = 16.0, pf_kwargs: dict | None = None) -> SimResult:
    hier = make_hierarchy(l2_kb=l2_kb, nsb_kb=nsb_kb,
                          dram_latency=dram_latency, dram_bw=dram_bw)
    pf: Prefetcher | None = None
    if prefetcher:
        kwargs = dict(pf_kwargs or {})
        if prefetcher == "nvr" and nsb_kb and "fill_nsb" not in kwargs:
            # the NSB is a *speculative* buffer: NVR prefetches fill it
            kwargs["fill_nsb"] = True
        pf = PREFETCHERS[prefetcher](**kwargs)

    if mode == "dense":
        comp = trace.total_compute() * trace.dense_compute_scale
        dense_bytes = trace.meta.get("dense_bytes",
                                     trace.total_compute() * 64)
        mem = dense_bytes / dram_bw + dram_latency
        total = max(comp, mem)
        return SimResult(trace.name, mode, 0, nsb_kb, total=total, base=comp,
                         stall=total - comp, compute=comp, n_vloads=0,
                         demand_misses=0, l2_accesses=0, demand_offchip=dense_bytes,
                         prefetch_offchip=0.0, pf_issued=0, pf_used=0)

    # without µ-inst-level (VMIG) restructuring, demand fetches happen at
    # rigid scratchpad-DMA granularity (paper §II-B / §IV-F)
    granule = 1 if pf is not None else DMA_GRANULE_LINES
    t = 0.0
    mem_ready = 0.0
    base = 0.0
    stall = 0.0
    compute = 0.0
    n_vloads = 0
    window: list[float] = []  # OoO outstanding-load completion times
    for i, op in enumerate(trace.ops):
        if isinstance(op, Compute):
            t += op.cycles
            base += op.cycles
            compute += op.cycles
            continue
        n_vloads += 1
        hier.drain(t)
        if pf is not None:
            pf.on_vload(i, op, trace, t, hier)
        lines = np.unique(op.addrs // LINE_BYTES)
        indirect = op.kind == "indirect"
        miss_before = hier.l2.stats.demand_misses
        ready = t
        for ln in lines:
            ready = max(ready, hier.access(int(ln), t, indirect, granule))
        if pf is not None and hier.l2.stats.demand_misses > miss_before:
            pf.on_miss(i, op, trace, t, hier)
        if mode == "inorder":
            t0 = t + ISSUE + HIT_LAT
            base += ISSUE + HIT_LAT
            if ready > t0:
                stall += ready - t0
                t = ready
            else:
                t = t0
        elif mode == "ooo":
            t += ISSUE
            base += ISSUE
            window.append(ready)
            if len(window) > OOO_WINDOW:
                # coarse-grained ROB: the oldest outstanding vector load
                # must retire before a new one can issue
                blocker = window.pop(0)
                if blocker > t:
                    stall += blocker - t
                    t = blocker
            mem_ready = max(mem_ready, ready)
        else:
            raise ValueError(mode)
    if mode == "ooo":
        total = max(t, mem_ready)
        stall = total - (base)
    else:
        total = t

    pf_issued = (hier.l2.stats.prefetch_fills
                 + (hier.nsb.stats.prefetch_fills if hier.nsb else 0))
    pf_used = hier.l2.stats.prefetch_used
    nsb_hits = 0
    if hier.nsb is not None:
        pf_used += hier.nsb.stats.prefetch_used
        nsb_hits = hier.nsb.stats.hits
    return SimResult(
        workload=trace.name, mode=mode if not prefetcher else prefetcher,
        dtype_bytes=0, nsb_kb=nsb_kb, total=total, base=base, stall=stall,
        compute=compute, n_vloads=n_vloads,
        demand_misses=hier.l2.stats.demand_misses,
        l2_accesses=hier.l2.stats.accesses,
        demand_offchip=hier.demand_offchip_bytes,
        prefetch_offchip=hier.prefetch_offchip_bytes,
        pf_issued=pf_issued, pf_used=pf_used, nsb_hits=nsb_hits)


@dataclass
class SweepResult:
    rows: list = field(default_factory=list)

    def add(self, r: SimResult) -> None:
        self.rows.append(r)

    def csv(self) -> str:
        hdr = ("workload,mode,dtype_bytes,nsb_kb,total,base,stall,compute,"
               "n_vloads,demand_misses,miss_rate,accuracy,coverage,"
               "demand_offchip,prefetch_offchip,offchip")
        out = [hdr]
        for r in self.rows:
            out.append(
                f"{r.workload},{r.mode},{r.dtype_bytes},{r.nsb_kb},"
                f"{r.total:.0f},{r.base:.0f},{r.stall:.0f},{r.compute:.0f},"
                f"{r.n_vloads},{r.demand_misses},{r.miss_rate:.4f},"
                f"{r.accuracy:.4f},{r.coverage:.4f},{r.demand_offchip:.0f},"
                f"{r.prefetch_offchip:.0f},{r.offchip:.0f}")
        return "\n".join(out)


MODES_FIG5 = ["dense", "inorder", "ooo", "stream", "imp", "dvr", "nvr"]


def run_modes(trace: Trace, dtype_bytes: int, nsb_kb: int = 0,
              l2_kb: int = 256) -> list[SimResult]:
    """Run the full Fig. 5 mode set on one trace; annotates coverage."""
    results = []
    baseline = None
    for mode in MODES_FIG5:
        if mode in ("dense", "inorder", "ooo"):
            r = simulate(trace, mode=mode, l2_kb=l2_kb, nsb_kb=nsb_kb)
        else:
            r = simulate(trace, mode="inorder", prefetcher=mode,
                         l2_kb=l2_kb, nsb_kb=nsb_kb)
        r.dtype_bytes = dtype_bytes
        if mode == "inorder":
            baseline = r
        if baseline is not None and baseline.demand_misses:
            r.coverage = 1.0 - r.demand_misses / baseline.demand_misses
        results.append(r)
    return results
