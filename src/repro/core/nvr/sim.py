"""Simulation driver facade + Fig. 5 mode set.

Execution modes (Fig. 5 bars):
  dense    — no sparsity skipping: regular streaming, perfectly prefetchable,
             but ``dense_compute_scale`` × the compute.
  inorder  — sparse, serial load/compute (baseline Gemmini).
  ooo      — sparse, *ideal* OoO: loads overlap compute; wall-clock is
             max(compute path, memory path).  Still suboptimal when IO-bound
             (the paper's point in §II-B).
  inorder + prefetcher — stream / imp / dvr / nvr, optional NSB.

The timing loop itself lives in :mod:`.engine.core` (event-driven, driven
by a structure-of-arrays compiled trace); this module keeps the seed's
``simulate()`` / ``run_modes()`` call signatures as thin wrappers so
existing call sites and notebooks keep working.
"""

from __future__ import annotations

from .engine.config import (DMA_GRANULE_LINES, HIT_LAT, ISSUE, OOO_WINDOW,
                            SimConfig)
from .engine.core import SimEngine
from .engine.result import SimResult, SweepResult
from .trace import Trace

__all__ = [
    "DMA_GRANULE_LINES", "HIT_LAT", "ISSUE", "OOO_WINDOW",
    "SimConfig", "SimEngine", "SimResult", "SweepResult",
    "MODES_FIG5", "simulate", "run_modes", "demand_miss_reduction",
    "demand_miss_reduction_from",
]


def simulate(trace: Trace, mode: str = "inorder",
             prefetcher: str | None = None, l2_kb: int = 256,
             nsb_kb: int = 0, dram_latency: float = 150.0,
             dram_bw: float = 16.0, pf_kwargs: dict | None = None,
             dtype_bytes: int = 0) -> SimResult:
    """One run with the seed's keyword-argument surface."""
    cfg = SimConfig(mode=mode, prefetcher=prefetcher, l2_kb=l2_kb,
                    nsb_kb=nsb_kb, dram_latency=dram_latency,
                    dram_bw=dram_bw, pf_kwargs=dict(pf_kwargs or {}))
    return SimEngine(cfg).run(trace, dtype_bytes=dtype_bytes)


MODES_FIG5 = ["dense", "inorder", "ooo", "stream", "imp", "dvr", "nvr"]


def demand_miss_reduction_from(results, target: str = "nvr",
                               baseline: str = "inorder") -> float:
    """Miss-reduction metric over an existing ``run_modes`` result set
    (list of SimResults or a label->SimResult dict) — call sites that
    already ran the mode sweep reuse it instead of simulating twice."""
    rs = results if isinstance(results, dict) \
        else {r.label: r for r in results}
    ino = rs[baseline]
    if not ino.demand_misses:
        return 0.0
    return 1.0 - rs[target].demand_misses / ino.demand_misses


def demand_miss_reduction(trace: Trace, dtype_bytes: int = 2,
                          target: str = "nvr",
                          baseline: str = "inorder") -> float:
    """Fraction of the baseline's demand misses ``target`` eliminates on
    this trace (0.0 when the baseline never misses).  The one shared
    definition the serving launcher, serve_bench, and capture replays
    report, so they cannot drift."""
    return demand_miss_reduction_from(run_modes(trace, dtype_bytes),
                                      target=target, baseline=baseline)


def run_modes(trace: Trace, dtype_bytes: int, nsb_kb: int = 0,
              l2_kb: int = 256) -> list[SimResult]:
    """Run the full Fig. 5 mode set on one trace; annotates coverage.

    Results carry separate ``mode`` and ``prefetcher`` fields; key by
    ``r.label`` to get the Fig. 5 bar names."""
    results = []
    baseline = None
    for mode in MODES_FIG5:
        if mode in ("dense", "inorder", "ooo"):
            r = simulate(trace, mode=mode, l2_kb=l2_kb, nsb_kb=nsb_kb,
                         dtype_bytes=dtype_bytes)
        else:
            r = simulate(trace, mode="inorder", prefetcher=mode,
                         l2_kb=l2_kb, nsb_kb=nsb_kb,
                         dtype_bytes=dtype_bytes)
        if mode == "inorder":
            baseline = r
        if baseline is not None and baseline.demand_misses:
            r.coverage = 1.0 - r.demand_misses / baseline.demand_misses
        results.append(r)
    return results
