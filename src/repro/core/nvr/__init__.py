"""Paper-faithful NVR simulator: NPU + cache hierarchy + prefetchers.

The timing loop is the event-driven :class:`~.engine.core.SimEngine`
(see ``engine/``); traces come from the synthetic Table-II generators
(``traces``) or from real serving/model traffic via the capture adapters
(``capture``).
"""

from . import capture
from .engine import (SimConfig, SimEngine, SweepSpec, available_prefetchers,
                     compile_trace, get_prefetcher, register_prefetcher,
                     run_sweep, write_artifacts)
from .engine.vectrace import VecTrace
from .machine import Cache, DRAM, Hierarchy, make_hierarchy, LINE_BYTES
from .prefetchers import (DVR, IMP, NVR, PREFETCHERS, Prefetcher,
                          StreamPrefetcher)
from .sim import (MODES_FIG5, SimResult, SweepResult, demand_miss_reduction,
                  demand_miss_reduction_from, run_modes, simulate)
from .trace import Compute, Trace, TraceBuilder, VLoad
from .traces import WORKLOADS, make_trace

__all__ = [
    "capture",
    "SimConfig", "SimEngine", "SweepSpec", "available_prefetchers",
    "compile_trace", "get_prefetcher", "register_prefetcher", "run_sweep",
    "write_artifacts", "VecTrace",
    "Cache", "DRAM", "Hierarchy", "make_hierarchy", "LINE_BYTES",
    "DVR", "IMP", "NVR", "PREFETCHERS", "Prefetcher", "StreamPrefetcher",
    "MODES_FIG5", "SimResult", "SweepResult", "demand_miss_reduction",
    "demand_miss_reduction_from", "run_modes", "simulate",
    "Compute", "Trace", "TraceBuilder", "VLoad", "WORKLOADS", "make_trace",
]
