"""Paper-faithful NVR simulator: NPU + cache hierarchy + prefetchers."""

from .machine import Cache, DRAM, Hierarchy, make_hierarchy, LINE_BYTES
from .prefetchers import DVR, IMP, NVR, PREFETCHERS, StreamPrefetcher
from .sim import MODES_FIG5, SimResult, SweepResult, run_modes, simulate
from .trace import Compute, Trace, TraceBuilder, VLoad
from .traces import WORKLOADS, make_trace

__all__ = [
    "Cache", "DRAM", "Hierarchy", "make_hierarchy", "LINE_BYTES",
    "DVR", "IMP", "NVR", "PREFETCHERS", "StreamPrefetcher",
    "MODES_FIG5", "SimResult", "SweepResult", "run_modes", "simulate",
    "Compute", "Trace", "TraceBuilder", "VLoad", "WORKLOADS", "make_trace",
]
