"""Table I — NVR hardware overhead accounting (bit-exact reimplementation).

We re-derive every structure's storage from its fields.  The paper's printed
per-row subtotals contain small arithmetic inconsistencies (e.g. SCD row
prints ``48 + 32×77 = 2464`` which is not self-consistent); we report both
the field-sum and the paper's printed subtotal, and the headline total
(9.72 KiB + optional 16 KiB NSB) as printed.
"""

from __future__ import annotations

import math
from dataclasses import dataclass


@dataclass
class Structure:
    name: str
    n: int
    fields: dict          # field name -> bits (already multiplied by N where due)
    paper_bits: int

    @property
    def bits(self) -> int:
        return int(sum(self.fields.values()))


def table1(n: int = 16) -> list[Structure]:
    lg = int(math.ceil(math.log2(n)))
    n2 = 2 * n
    lg2 = int(math.ceil(math.log2(n2)))
    sd = Structure("SD", n, {
        "pc": 48, "entry_id": n * lg, "prev_addr": 48 * n, "stride": 8 * n,
        "last_prefetch_addr": 48 * n, "stride_conf": 2 * n,
    }, paper_bits=1808)
    scd = Structure("SCD", n2, {
        "pc": 48, "entry_id": n2 * lg2, "lpi": 10 * n2, "ss_start": 48 * n2,
        "ss_offset": 10 * n2, "vector_size": 4 * n2, "valid": n2,
    }, paper_bits=2464)
    lbd = Structure("LBD", n, {
        "pc": 48 * n, "entry_id": n * lg, "loop_boundary": 16 * n,
        "iteration_counter": 16 * n, "increment": 16 * n,
        "boundary_conf": 4 * n, "sparse_mode": n, "level_conf": 2 * n,
    }, paper_bits=3424)
    vmig = Structure("VMIG", n2, {
        "pc": 48 * n2, "entry_id": n2 * lg2, "vrf": 64 * n2, "pie": 64 * n2,
        "iru": 4 * n2 + 4, "vigu": 256,
    }, paper_bits=3204)
    snoop = Structure("Snooper", n, {
        "cpu_pc": 48, "cpu_reg": 64, "npu_pc": 48,
        "sparse_structure": (48 + 10 + 10) * n,
    }, paper_bits=1248)
    return [sd, scd, lbd, vmig, snoop]


PAPER_TOTAL_KIB = 9.72
NSB_KIB = 16.0


def report(n: int = 16) -> str:
    rows = table1(n)
    out = ["structure,N,field_sum_bits,paper_bits"]
    for s in rows:
        out.append(f"{s.name},{s.n},{s.bits},{s.paper_bits}")
    field_total = sum(s.bits for s in rows)
    out.append(f"TOTAL_field_sum_bits,,{field_total},"
               f"{sum(s.paper_bits for s in rows)}")
    out.append(f"TOTAL_paper_headline_KiB,,{PAPER_TOTAL_KIB},"
               f"(+{NSB_KIB} KiB optional NSB)")
    return "\n".join(out)
