"""Frozen copy of the seed per-op/per-line ``simulate()`` loop.

This module preserves the original (pre-engine) implementation verbatim —
per-op ``np.unique`` calls, dataclass attribute access, isinstance
dispatch, and the original prefetcher classes that scan ``trace.ops``
directly.  It exists for two reasons:

1. **Parity oracle** — ``tests/test_engine.py`` asserts the event-driven
   engine reproduces these totals exactly on all 8 Table-II workloads.
2. **Speed baseline** — ``benchmarks/paper_figs.py::engine_speedup``
   measures the engine's Fig. 5 sweep against this loop (the acceptance
   bar is >= 5x).

Do not optimise this file; it is the thing being measured against.
"""

from __future__ import annotations

from collections import OrderedDict
from dataclasses import dataclass

import numpy as np

from ..machine import LINE_BYTES, cache_latency
from ..trace import Compute, Trace, VLoad
from .config import DMA_GRANULE_LINES, HIT_LAT, ISSUE, OOO_WINDOW
from .result import SimResult


def _lines(addrs: np.ndarray) -> np.ndarray:
    return np.unique(addrs // LINE_BYTES)


# -- seed memory-system model (verbatim) -------------------------------------

@dataclass
class _SeedDRAM:
    latency: float = 150.0
    bytes_per_cycle: float = 16.0
    busy_until: float = 0.0
    bytes_transferred: float = 0.0

    def fetch(self, now: float, nbytes: int = LINE_BYTES) -> float:
        occupancy = nbytes / self.bytes_per_cycle
        start = max(now, self.busy_until)
        self.busy_until = start + occupancy
        self.bytes_transferred += nbytes
        return start + occupancy + self.latency


@dataclass
class _SeedCacheStats:
    hits: int = 0
    misses: int = 0
    demand_misses: int = 0
    prefetch_fills: int = 0
    prefetch_used: int = 0
    prefetch_unused_evicted: int = 0
    coalesced: int = 0

    @property
    def accesses(self) -> int:
        return self.hits + self.misses


class _SeedCache:
    def __init__(self, size_bytes: int, ways: int, hit_latency: float,
                 name: str = "L2") -> None:
        self.name = name
        self.size_bytes = size_bytes
        self.ways = ways
        self.hit_latency = hit_latency
        self.num_sets = max(1, size_bytes // LINE_BYTES // ways)
        self.sets: list[OrderedDict] = [OrderedDict()
                                        for _ in range(self.num_sets)]
        self.mshr: dict[int, float] = {}
        self.mshr_prefetch: set[int] = set()
        self.stats = _SeedCacheStats()

    def _set(self, line: int) -> OrderedDict:
        return self.sets[line % self.num_sets]

    def present(self, line: int, now: float) -> bool:
        s = self._set(line)
        if line in s:
            return True
        return line in self.mshr and self.mshr[line] <= now

    def probe(self, line: int, now: float, demand: bool = True) -> float | None:
        s = self._set(line)
        if line in s:
            fill, was_pf, used = s[line]
            if was_pf and not used and demand:
                self.stats.prefetch_used += 1
            s[line] = (fill, was_pf, True if demand else used)
            s.move_to_end(line)
            self.stats.hits += 1
            return now + self.hit_latency
        if line in self.mshr:
            ready = self.mshr[line]
            if ready <= now:
                self._install(line, ready,
                              was_prefetch=line in self.mshr_prefetch,
                              used=demand)
                if line in self.mshr_prefetch and demand:
                    self.stats.prefetch_used += 1
                del self.mshr[line]
                self.mshr_prefetch.discard(line)
                self.stats.hits += 1
                return now + self.hit_latency
            self.stats.coalesced += 1
            if line in self.mshr_prefetch and demand:
                self.stats.prefetch_used += 1
                self.mshr_prefetch.discard(line)
            self.stats.hits += 1
            return ready + self.hit_latency
        self.stats.misses += 1
        if demand:
            self.stats.demand_misses += 1
        return None

    def _install(self, line: int, fill_cycle: float, was_prefetch: bool,
                 used: bool) -> None:
        s = self._set(line)
        if line in s:
            return
        if len(s) >= self.ways:
            _, (f, pf, u) = s.popitem(last=False)
            if pf and not u:
                self.stats.prefetch_unused_evicted += 1
        s[line] = (fill_cycle, was_prefetch, used)

    def fill(self, line: int, ready: float, prefetch: bool = False) -> None:
        if line in self.mshr:
            self.mshr[line] = min(self.mshr[line], ready)
            return
        s = self._set(line)
        if line in s:
            return
        self.mshr[line] = ready
        if prefetch:
            self.mshr_prefetch.add(line)
            self.stats.prefetch_fills += 1

    def drain(self, now: float) -> None:
        done = [l for l, r in self.mshr.items() if r <= now]
        for l in done:
            self._install(l, self.mshr[l], l in self.mshr_prefetch, False)
            del self.mshr[l]
            self.mshr_prefetch.discard(l)


@dataclass
class _SeedHierarchy:
    l2: _SeedCache
    dram: _SeedDRAM
    nsb: _SeedCache | None = None
    demand_offchip_bytes: float = 0.0
    prefetch_offchip_bytes: float = 0.0

    def _dram_fill(self, line: int, now: float, granule_lines: int,
                   also_nsb: bool, skip_l2: bool = False) -> float:
        ready = self.dram.fetch(now, nbytes=granule_lines * LINE_BYTES)
        self.demand_offchip_bytes += granule_lines * LINE_BYTES
        if not skip_l2:
            self.l2.fill(line, ready)
        if also_nsb and self.nsb is not None:
            self.nsb.fill(line, ready)
        return ready

    def access(self, line: int, now: float, indirect: bool,
               granule_lines: int = 1) -> float:
        if self.nsb is not None and indirect:
            t = self.nsb.probe(line, now)
            if t is not None:
                return t
            t2 = self.l2.probe(line, now + self.nsb.hit_latency)
            if t2 is None:
                ready = self._dram_fill(line, now + self.nsb.hit_latency,
                                        granule_lines, also_nsb=True)
                return ready + self.nsb.hit_latency
            self.nsb.fill(line, t2)
            return t2
        t = self.l2.probe(line, now)
        if t is not None:
            return t
        ready = self._dram_fill(line, now, granule_lines, also_nsb=False)
        return ready + self.l2.hit_latency

    def prefetch(self, line: int, now: float, into_nsb: bool = False) -> None:
        target = self.nsb if (into_nsb and self.nsb is not None) else self.l2
        if target.present(line, now) or line in target.mshr:
            return
        if target is self.nsb:
            if self.l2.present(line, now):
                self.nsb.fill(line, now + self.l2.hit_latency, prefetch=True)
                return
            if line in self.l2.mshr:
                self.nsb.fill(line, self.l2.mshr[line], prefetch=True)
                return
        ready = self.dram.fetch(now)
        self.prefetch_offchip_bytes += LINE_BYTES
        target.fill(line, ready, prefetch=True)
        if target is self.nsb:
            self.l2.fill(line, ready)

    def drain(self, now: float) -> None:
        self.l2.drain(now)
        if self.nsb is not None:
            self.nsb.drain(now)


def _seed_make_hierarchy(l2_kb: int = 256, nsb_kb: int = 0,
                         dram_latency: float = 150.0,
                         dram_bw: float = 16.0) -> _SeedHierarchy:
    l2 = _SeedCache(l2_kb * 1024, ways=8, hit_latency=cache_latency(l2_kb),
                    name="L2")
    nsb = None
    if nsb_kb:
        nsb = _SeedCache(nsb_kb * 1024, ways=16,
                         hit_latency=cache_latency(nsb_kb, 16, 2.0),
                         name="NSB")
    return _SeedHierarchy(l2=l2, dram=_SeedDRAM(latency=dram_latency,
                                                bytes_per_cycle=dram_bw),
                          nsb=nsb)


class _SeedPrefetcher:
    name = "none"
    mshr_cap = 10 ** 9

    def __init__(self) -> None:
        self.issued_lines = 0

    def _issue(self, hier: _SeedHierarchy, line: int, now: float,
               into_nsb: bool = False) -> bool:
        if len(hier.l2.mshr) >= self.mshr_cap:
            return False
        self.issued_lines += 1
        hier.prefetch(int(line), now, into_nsb=into_nsb)
        return True

    def on_vload(self, i, op, trace, now, hier) -> None:
        pass

    def on_miss(self, i, op, trace, now, hier) -> None:
        pass


class _SeedStream(_SeedPrefetcher):
    name = "stream"

    def __init__(self, depth: int = 4) -> None:
        super().__init__()
        self.depth = depth
        self.table: dict[int, tuple[int, int, int]] = {}

    def on_vload(self, i, op, trace, now, hier) -> None:
        a0 = int(op.addrs[0])
        span = int(op.addrs[-1]) - a0 + LINE_BYTES
        last, stride, conf = self.table.get(op.pc, (a0, 0, 0))
        new_stride = a0 - last
        if new_stride == stride and stride != 0:
            conf = min(conf + 1, 3)
        else:
            conf = 0
        self.table[op.pc] = (a0, new_stride, conf)
        if conf >= 2:
            for k in range(1, self.depth + 1):
                base = a0 + k * new_stride
                for ln in range((base // LINE_BYTES),
                                (base + span) // LINE_BYTES + 1):
                    self._issue(hier, ln, now)


class _SeedIMP(_SeedPrefetcher):
    name = "imp"
    mshr_cap = 64

    def __init__(self, learn_after: int = 2, lookahead_ops: int = 40,
                 max_chains: int = 2) -> None:
        super().__init__()
        self.learn_after = learn_after
        self.lookahead_ops = lookahead_ops
        self.max_chains = max_chains
        self.observed: dict[int, int] = {}
        self.chains: dict[int, list[int]] = {}
        self.stream = _SeedStream(depth=2)

    def on_vload(self, i, op, trace, now, hier) -> None:
        self.stream.issued_lines = self.issued_lines
        self.stream.on_vload(i, op, trace, now, hier)
        self.issued_lines = self.stream.issued_lines
        if op.kind == "indirect":
            self.observed[op.idx_pc] = self.observed.get(op.idx_pc, 0) + 1
            learned = self.chains.setdefault(op.idx_pc, [])
            if op.pc not in learned and len(learned) < self.max_chains:
                learned.append(op.pc)
            return
        pc = op.pc
        if self.observed.get(pc, 0) < self.learn_after:
            return
        learned = self.chains.get(pc, [])
        bound = op.bound_id
        for j in range(i + 1, min(len(trace.ops), i + 1 + self.lookahead_ops)):
            nxt = trace.ops[j]
            if isinstance(nxt, Compute):
                continue
            if nxt.bound_id != bound:
                break
            if nxt.kind == "indirect" and nxt.idx_pc == pc and nxt.pc in learned:
                for ln in _lines(nxt.addrs):
                    self._issue(hier, ln, now)


class _SeedDVR(_SeedPrefetcher):
    name = "dvr"
    mshr_cap = 128

    def __init__(self, window: int = 48, issue_width: int = 16) -> None:
        super().__init__()
        self.window = window
        self.issue_width = issue_width

    @staticmethod
    def _bound_ok(op: VLoad) -> bool:
        return (op.bound_id * 2654435761 + op.pc) % 100 < 72

    def on_miss(self, i, op, trace, now, hier) -> None:
        cur = op.bound_id
        seen = 0
        t = now
        for j in range(i + 1, len(trace.ops)):
            if seen >= self.window:
                break
            nxt = trace.ops[j]
            if isinstance(nxt, Compute):
                continue
            seen += 1
            t += 1.0 / self.issue_width
            if nxt.bound_id == cur or self._bound_ok(nxt):
                for ln in _lines(nxt.addrs):
                    self._issue(hier, ln, t)
            else:
                junk = int(nxt.addrs[-1] // LINE_BYTES) + 4
                for k in range(min(4, len(nxt.addrs))):
                    self._issue(hier, junk + k, t)


class _SeedNVR(_SeedPrefetcher):
    name = "nvr"
    mshr_cap = 256

    def __init__(self, depth: int = 96, fuzzy_every: int = 8,
                 fill_nsb: bool = False, near_depth: int = 12,
                 scd: bool = True, lbd: bool = True,
                 vmig: bool = True) -> None:
        super().__init__()
        self.depth = depth
        self.near_depth = near_depth
        self.fuzzy_every = fuzzy_every
        self.fill_nsb = fill_nsb
        self.scd = scd
        self.lbd = lbd
        self.vmig = vmig
        self._covered_until = -1
        self._near_until = -1
        self._fuzzy_ctr = 0

    def on_vload(self, i, op, trace, now, hier) -> None:
        start = max(i + 1, self._covered_until + 1)
        end = min(len(trace.ops), i + 1 + self.depth)
        t = now
        cur_bound = op.bound_id
        for j in range(start, end):
            nxt = trace.ops[j]
            if isinstance(nxt, Compute):
                self._covered_until = j
                continue
            if not self.scd and nxt.kind == "indirect":
                self._covered_until = j
                continue
            lines = _lines(nxt.addrs)
            if len(hier.l2.mshr) + len(lines) > self.mshr_cap:
                break
            t += (1.0 / 16.0) if self.vmig else float(len(lines))
            if not self.lbd and nxt.bound_id != cur_bound \
                    and not _SeedDVR._bound_ok(nxt):
                junk = int(nxt.addrs[-1] // LINE_BYTES) + 4
                for kk in range(min(4, len(lines))):
                    self._issue(hier, junk + kk, t)
                self._covered_until = j
                continue
            for ln in lines:
                self._issue(hier, ln, t)
            if nxt.kind == "indirect":
                self._fuzzy_ctr += 1
                if self.fuzzy_every and \
                        self._fuzzy_ctr % self.fuzzy_every == 0:
                    self._issue(hier, int(lines[-1]) + 1, t)
            self._covered_until = j
        if not self.fill_nsb:
            return
        nstart = max(i + 1, self._near_until + 1)
        nend = min(len(trace.ops), i + 1 + self.near_depth)
        for j in range(nstart, nend):
            nxt = trace.ops[j]
            self._near_until = j
            if isinstance(nxt, Compute) or nxt.kind != "indirect":
                continue
            for ln in _lines(nxt.addrs):
                self._issue(hier, ln, now, into_nsb=True)


_SEED_PREFETCHERS = {
    "stream": _SeedStream,
    "imp": _SeedIMP,
    "dvr": _SeedDVR,
    "nvr": _SeedNVR,
}


def simulate_reference(trace: Trace, mode: str = "inorder",
                       prefetcher: str | None = None, l2_kb: int = 256,
                       nsb_kb: int = 0, dram_latency: float = 150.0,
                       dram_bw: float = 16.0,
                       pf_kwargs: dict | None = None) -> SimResult:
    """The seed ``simulate()`` loop, byte-for-byte in behaviour."""
    hier = _seed_make_hierarchy(l2_kb=l2_kb, nsb_kb=nsb_kb,
                                dram_latency=dram_latency, dram_bw=dram_bw)
    pf: _SeedPrefetcher | None = None
    if prefetcher:
        kwargs = dict(pf_kwargs or {})
        if prefetcher == "nvr" and nsb_kb and "fill_nsb" not in kwargs:
            kwargs["fill_nsb"] = True
        pf = _SEED_PREFETCHERS[prefetcher](**kwargs)

    if mode == "dense":
        comp = trace.total_compute() * trace.dense_compute_scale
        dense_bytes = trace.meta.get("dense_bytes",
                                     trace.total_compute() * 64)
        mem = dense_bytes / dram_bw + dram_latency
        total = max(comp, mem)
        return SimResult(workload=trace.name, mode=mode, prefetcher="",
                         dtype_bytes=0, nsb_kb=nsb_kb, total=total,
                         base=comp, stall=total - comp, compute=comp,
                         n_vloads=0, demand_misses=0, l2_accesses=0,
                         demand_offchip=dense_bytes, prefetch_offchip=0.0,
                         pf_issued=0, pf_used=0)

    granule = 1 if pf is not None else DMA_GRANULE_LINES
    t = 0.0
    mem_ready = 0.0
    base = 0.0
    stall = 0.0
    compute = 0.0
    n_vloads = 0
    window: list[float] = []
    for i, op in enumerate(trace.ops):
        if isinstance(op, Compute):
            t += op.cycles
            base += op.cycles
            compute += op.cycles
            continue
        n_vloads += 1
        hier.drain(t)
        if pf is not None:
            pf.on_vload(i, op, trace, t, hier)
        lines = np.unique(op.addrs // LINE_BYTES)
        indirect = op.kind == "indirect"
        miss_before = hier.l2.stats.demand_misses
        ready = t
        for ln in lines:
            ready = max(ready, hier.access(int(ln), t, indirect, granule))
        if pf is not None and hier.l2.stats.demand_misses > miss_before:
            pf.on_miss(i, op, trace, t, hier)
        if mode == "inorder":
            t0 = t + ISSUE + HIT_LAT
            base += ISSUE + HIT_LAT
            if ready > t0:
                stall += ready - t0
                t = ready
            else:
                t = t0
        elif mode == "ooo":
            t += ISSUE
            base += ISSUE
            window.append(ready)
            if len(window) > OOO_WINDOW:
                blocker = window.pop(0)
                if blocker > t:
                    stall += blocker - t
                    t = blocker
            mem_ready = max(mem_ready, ready)
        else:
            raise ValueError(mode)
    if mode == "ooo":
        total = max(t, mem_ready)
        stall = total - base
    else:
        total = t

    pf_issued = (hier.l2.stats.prefetch_fills
                 + (hier.nsb.stats.prefetch_fills if hier.nsb else 0))
    pf_used = hier.l2.stats.prefetch_used
    nsb_hits = 0
    if hier.nsb is not None:
        pf_used += hier.nsb.stats.prefetch_used
        nsb_hits = hier.nsb.stats.hits
    return SimResult(
        workload=trace.name, mode=mode, prefetcher=prefetcher or "",
        dtype_bytes=0, nsb_kb=nsb_kb, total=total, base=base, stall=stall,
        compute=compute, n_vloads=n_vloads,
        demand_misses=hier.l2.stats.demand_misses,
        l2_accesses=hier.l2.stats.accesses,
        demand_offchip=hier.demand_offchip_bytes,
        prefetch_offchip=hier.prefetch_offchip_bytes,
        pf_issued=pf_issued, pf_used=pf_used, nsb_hits=nsb_hits)


def run_modes_reference(trace: Trace, dtype_bytes: int, nsb_kb: int = 0,
                        l2_kb: int = 256) -> list[SimResult]:
    """Seed ``run_modes()``: the Fig. 5 mode set via the reference loop."""
    results = []
    baseline = None
    for mode in ("dense", "inorder", "ooo", "stream", "imp", "dvr", "nvr"):
        if mode in ("dense", "inorder", "ooo"):
            r = simulate_reference(trace, mode=mode, l2_kb=l2_kb,
                                   nsb_kb=nsb_kb)
        else:
            r = simulate_reference(trace, mode="inorder", prefetcher=mode,
                                   l2_kb=l2_kb, nsb_kb=nsb_kb)
        r.dtype_bytes = dtype_bytes
        if mode == "inorder":
            baseline = r
        if baseline is not None and baseline.demand_misses:
            r.coverage = 1.0 - r.demand_misses / baseline.demand_misses
        results.append(r)
    return results
