"""Simulation result records + sweep accumulation (CSV/JSON)."""

from __future__ import annotations

import json
from dataclasses import dataclass, field


@dataclass
class SimResult:
    workload: str
    mode: str                 # execution model: dense | inorder | ooo
    dtype_bytes: int
    nsb_kb: int
    total: float
    base: float
    stall: float
    compute: float
    n_vloads: int
    demand_misses: int
    l2_accesses: int
    demand_offchip: float
    prefetch_offchip: float
    pf_issued: int
    pf_used: int
    prefetcher: str = ""      # registry name, "" when no prefetcher ran
    nsb_hits: int = 0
    coverage: float = float("nan")  # filled by sweeps vs baseline

    @property
    def label(self) -> str:
        """Fig. 5 bar label: the prefetcher when one ran, else the mode.
        (The seed overwrote ``mode`` with the prefetcher name; the two are
        now separate fields and ``label`` is the display key.)"""
        return self.prefetcher or self.mode

    @property
    def offchip(self) -> float:
        return self.demand_offchip + self.prefetch_offchip

    @property
    def accuracy(self) -> float:
        return self.pf_used / self.pf_issued if self.pf_issued else float("nan")

    @property
    def miss_rate(self) -> float:
        return self.demand_misses / max(1, self.l2_accesses)


CSV_HEADER = ("workload,mode,prefetcher,dtype_bytes,nsb_kb,total,base,stall,"
              "compute,n_vloads,demand_misses,miss_rate,accuracy,coverage,"
              "demand_offchip,prefetch_offchip,offchip")


def _csv_row(r: SimResult) -> str:
    return (f"{r.workload},{r.mode},{r.prefetcher},{r.dtype_bytes},"
            f"{r.nsb_kb},{r.total:.0f},{r.base:.0f},{r.stall:.0f},"
            f"{r.compute:.0f},{r.n_vloads},{r.demand_misses},"
            f"{r.miss_rate:.4f},{r.accuracy:.4f},{r.coverage:.4f},"
            f"{r.demand_offchip:.0f},{r.prefetch_offchip:.0f},"
            f"{r.offchip:.0f}")


@dataclass
class SweepResult:
    rows: list = field(default_factory=list)

    def add(self, r: SimResult) -> None:
        self.rows.append(r)

    def extend(self, rs) -> None:
        self.rows.extend(rs)

    def csv(self) -> str:
        return "\n".join([CSV_HEADER] + [_csv_row(r) for r in self.rows])

    def to_records(self) -> list[dict]:
        keys = CSV_HEADER.split(",")
        out = []
        for r in self.rows:
            rec = {k: getattr(r, k) for k in keys
                   if k not in ("miss_rate", "accuracy", "offchip")}
            rec.update(miss_rate=r.miss_rate, accuracy=r.accuracy,
                       offchip=r.offchip, label=r.label)
            out.append(rec)
        return out

    def json(self, **meta) -> str:
        return json.dumps({"meta": meta, "rows": self.to_records()},
                          indent=1, default=float)
