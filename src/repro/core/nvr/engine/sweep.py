"""Grid sweep runner: workload x dtype x prefetcher x nsb_kb.

``run_sweep(SweepSpec(...))`` drives the event-driven engine over the full
grid and returns a :class:`~.result.SweepResult`; ``write_artifacts``
persists any benchmark's rows as paired CSV + JSON files so downstream
tooling (plots, dashboards, regression diffs) has one artifact format.
"""

from __future__ import annotations

import json
import os
from dataclasses import dataclass, field

from .config import SimConfig
from .core import SimEngine
from .result import SimResult, SweepResult

#: Fig. 5 bar set: three execution models + four prefetchers on in-order.
POINTS_FIG5 = ("dense", "inorder", "ooo", "stream", "imp", "dvr", "nvr")


def _point_config(point: str, **kw) -> SimConfig:
    """A sweep *point* is either an execution mode or a prefetcher name
    riding on the in-order core (the Fig. 5 convention)."""
    if point in ("dense", "inorder", "ooo"):
        return SimConfig(mode=point, **kw)
    return SimConfig(mode="inorder", prefetcher=point, **kw)


@dataclass
class SweepSpec:
    workloads: tuple = ()            # () -> all Table-II workloads
    dtypes: tuple = (1, 2, 4)        # INT8 / FP16 / INT32
    points: tuple = POINTS_FIG5
    nsb_kbs: tuple = (0, 16)
    l2_kb: int = 256
    scale: float = 0.5
    pf_kwargs: dict = field(default_factory=dict)

    def grid_size(self) -> int:
        from ..traces import WORKLOADS
        n_wl = len(self.workloads or WORKLOADS)
        return n_wl * len(self.dtypes) * len(self.points) * len(self.nsb_kbs)


def _run_cell(spec: SweepSpec, wl: str, dtb: int) -> list[SimResult]:
    """All (nsb_kb x point) runs for one (workload, dtype) cell.  The trace
    is generated inside the cell so worker processes never pickle traces;
    one VecTrace compilation is shared by every run in the cell."""
    from ..traces import make_trace

    trace = make_trace(wl, dtype_bytes=dtb, scale=spec.scale)
    out: list[SimResult] = []
    for nsb_kb in spec.nsb_kbs:
        baseline: SimResult | None = None
        for point in spec.points:
            cfg = _point_config(point, l2_kb=spec.l2_kb, nsb_kb=nsb_kb,
                                pf_kwargs=dict(spec.pf_kwargs))
            r = SimEngine(cfg).run(trace, dtype_bytes=dtb)
            if point == "inorder":
                baseline = r
            if baseline is not None and baseline.demand_misses:
                r.coverage = 1.0 - r.demand_misses / baseline.demand_misses
            out.append(r)
    return out


def _run_cell_star(args) -> list[SimResult]:
    return _run_cell(*args)


def run_sweep(spec: SweepSpec, workers: int = 1) -> SweepResult:
    """Run the grid; coverage is annotated per (workload, dtype, nsb_kb)
    against that cell's in-order baseline.

    ``workers > 1`` fans the (workload, dtype) cells out over a process
    pool — every cell is independent, results are deterministic and
    returned in grid order regardless of worker count."""
    from ..traces import WORKLOADS

    cells = [(spec, wl, dtb)
             for wl in (spec.workloads or tuple(WORKLOADS))
             for dtb in spec.dtypes]
    out = SweepResult()
    if workers > 1 and len(cells) > 1:
        import multiprocessing
        from concurrent.futures import ProcessPoolExecutor

        # spawn, not fork: the caller may have a multithreaded jax
        # runtime loaded, and the workers only need numpy anyway
        ctx = multiprocessing.get_context("spawn")
        with ProcessPoolExecutor(max_workers=workers,
                                 mp_context=ctx) as ex:
            for rows in ex.map(_run_cell_star, cells):
                out.extend(rows)
    else:
        for cell in cells:
            out.extend(_run_cell_star(cell))
    return out


def write_artifacts(name: str, header: str, rows: list, out_dir: str,
                    **meta) -> dict:
    """Write ``rows`` (sequences matching the comma-separated ``header``)
    as ``<name>.csv`` and ``<name>.json`` under ``out_dir``."""
    os.makedirs(out_dir, exist_ok=True)
    csv_path = os.path.join(out_dir, f"{name}.csv")
    with open(csv_path, "w") as f:
        f.write(header + "\n")
        for r in rows:
            f.write(",".join(str(x) for x in r) + "\n")
    keys = header.split(",")
    json_path = os.path.join(out_dir, f"{name}.json")
    with open(json_path, "w") as f:
        json.dump({"meta": meta,
                   "rows": [dict(zip(keys, r)) for r in rows]},
                  f, indent=1, default=float)
    return {"csv": csv_path, "json": json_path}


def write_sweep(result: SweepResult, out_dir: str, name: str = "sweep",
                **meta) -> dict:
    """Persist a SweepResult as CSV + JSON artifacts."""
    os.makedirs(out_dir, exist_ok=True)
    csv_path = os.path.join(out_dir, f"{name}.csv")
    with open(csv_path, "w") as f:
        f.write(result.csv() + "\n")
    json_path = os.path.join(out_dir, f"{name}.json")
    with open(json_path, "w") as f:
        f.write(result.json(**meta))
    return {"csv": csv_path, "json": json_path}
