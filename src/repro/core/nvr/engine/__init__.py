"""Event-driven NVR simulation engine.

The package splits the seed's monolithic ``simulate()`` loop into:

* ``config``    — :class:`SimConfig`, one dataclass for every knob that was
  previously scattered across ``simulate()`` keyword arguments.
* ``registry``  — the ``@register_prefetcher`` decorator; prefetchers
  self-register and are instantiated by name.
* ``vectrace``  — :class:`VecTrace`, a structure-of-arrays compilation of a
  :class:`~repro.core.nvr.trace.Trace` with per-op unique cache-line arrays
  precomputed once and shared by every mode/prefetcher run.
* ``core``      — :class:`SimEngine`, the event-driven timing loop.
  Observers (prefetchers, capture hooks, stats collectors) subscribe to
  ``vload`` / ``miss`` / ``retire`` events instead of being hardcoded.
* ``reference`` — a frozen copy of the seed per-op/per-line loop, kept as
  the parity oracle and the baseline for the speedup benchmark.
* ``sweep``     — grid runner (workload x dtype x prefetcher x nsb_kb)
  emitting CSV + JSON artifacts.
"""

from .config import (DMA_GRANULE_LINES, HIT_LAT, ISSUE, OOO_WINDOW,
                     SimConfig)
from .core import SimEngine
from .registry import (available_prefetchers, get_prefetcher,
                       register_prefetcher)
from .sweep import SweepSpec, run_sweep, write_artifacts
from .vectrace import (KIND_COMPUTE, KIND_INDIRECT, KIND_STREAM, VecTrace,
                       compile_trace)

__all__ = [
    "DMA_GRANULE_LINES", "HIT_LAT", "ISSUE", "OOO_WINDOW", "SimConfig",
    "SimEngine",
    "available_prefetchers", "get_prefetcher", "register_prefetcher",
    "SweepSpec", "run_sweep", "write_artifacts",
    "KIND_COMPUTE", "KIND_INDIRECT", "KIND_STREAM", "VecTrace",
    "compile_trace",
]
