"""Prefetcher registry: ``@register_prefetcher("name")`` replaces the seed's
hardcoded ``PREFETCHERS`` dict so new prefetchers (including out-of-tree
experiments) plug in without touching the engine."""

from __future__ import annotations

_REGISTRY: dict[str, type] = {}


def register_prefetcher(name: str):
    """Class decorator: register a :class:`Prefetcher` subclass under
    ``name`` and stamp it as ``cls.name``."""

    def deco(cls):
        if name in _REGISTRY and _REGISTRY[name] is not cls:
            raise ValueError(f"prefetcher {name!r} already registered "
                             f"by {_REGISTRY[name].__name__}")
        cls.name = name
        _REGISTRY[name] = cls
        return cls

    return deco


def _ensure_builtins() -> None:
    # importing the module runs the @register_prefetcher decorators
    from .. import prefetchers  # noqa: F401


def get_prefetcher(name: str) -> type:
    _ensure_builtins()
    try:
        return _REGISTRY[name]
    except KeyError:
        raise KeyError(f"unknown prefetcher {name!r}; available: "
                       f"{sorted(_REGISTRY)}") from None


def available_prefetchers() -> list[str]:
    _ensure_builtins()
    return sorted(_REGISTRY)
