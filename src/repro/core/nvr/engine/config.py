"""Unified simulation configuration.

The seed scattered its knobs across ``simulate()`` keyword arguments and
module-level constants; :class:`SimConfig` collects every one of them in a
single dataclass that builds the memory hierarchy and the (registry-
resolved) prefetcher, so sweeps, capture replays, and tests all construct
runs the same way.
"""

from __future__ import annotations

from dataclasses import dataclass, field, replace

from ..machine import Hierarchy, make_hierarchy
from .registry import get_prefetcher

ISSUE = 1.0     # cycles to issue a vector load
HIT_LAT = 2.0   # scratchpad/L1-equivalent hit latency
OOO_WINDOW = 8  # ideal-OoO outstanding vector loads (coarse-grained NPU ROB)
DMA_GRANULE_LINES = 4  # rigid preload granularity without µ-inst prefetch

MODES = ("dense", "inorder", "ooo")


@dataclass
class SimConfig:
    """Everything one simulator run depends on.

    ``mode`` is the execution model (dense / inorder / ooo); ``prefetcher``
    is the registry name of an optional prefetcher riding on top of the
    in-order core (the Fig. 5 ``stream``/``imp``/``dvr``/``nvr`` bars).
    """

    mode: str = "inorder"
    prefetcher: str | None = None
    l2_kb: int = 256
    nsb_kb: int = 0
    dram_latency: float = 150.0
    dram_bw: float = 16.0
    pf_kwargs: dict = field(default_factory=dict)
    issue_cycles: float = ISSUE
    hit_latency: float = HIT_LAT
    ooo_window: int = OOO_WINDOW
    dma_granule_lines: int = DMA_GRANULE_LINES

    def __post_init__(self) -> None:
        if self.mode not in MODES:
            raise ValueError(f"mode {self.mode!r} not in {MODES}")
        if self.prefetcher:
            get_prefetcher(self.prefetcher)  # raises on unknown name

    def replace(self, **kw) -> "SimConfig":
        return replace(self, **kw)

    def build_hierarchy(self) -> Hierarchy:
        return make_hierarchy(l2_kb=self.l2_kb, nsb_kb=self.nsb_kb,
                              dram_latency=self.dram_latency,
                              dram_bw=self.dram_bw)

    def build_prefetcher(self):
        """Instantiate the configured prefetcher (fresh state per run)."""
        if not self.prefetcher:
            return None
        kwargs = dict(self.pf_kwargs)
        if self.prefetcher == "nvr" and self.nsb_kb \
                and "fill_nsb" not in kwargs:
            # the NSB is a *speculative* buffer: NVR prefetches fill it
            kwargs["fill_nsb"] = True
        return get_prefetcher(self.prefetcher)(**kwargs)
