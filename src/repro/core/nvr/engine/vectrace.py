"""Structure-of-arrays trace compilation.

The seed loop recomputed ``np.unique(op.addrs // LINE_BYTES)`` for every op
on every ``simulate()`` call — a full Fig. 5 sweep touches each op seven
times from the engine plus O(window) more times from prefetcher runahead
scans.  :func:`compile_trace` does that work exactly once per trace and
lowers every per-op scalar the engine or a prefetcher reads (kind, bound,
PC, first/last address, line list) into flat arrays, so the hot loops do
plain list indexing instead of dataclass attribute access and isinstance
dispatch.

The compiled form is cached on the ``Trace`` object: all seven Fig. 5 mode
runs of ``run_modes()`` share one compilation.
"""

from __future__ import annotations

import numpy as np

from ..machine import LINE_BYTES
from ..trace import Compute, Trace

KIND_COMPUTE = 0
KIND_STREAM = 1
KIND_INDIRECT = 2

_CACHE_ATTR = "_vectrace"


class VecTrace:
    """Read-only structure-of-arrays view of a :class:`Trace`.

    Per-op scalars are Python lists (fastest for interpreter-loop access);
    the unique-line sets are additionally exposed flat (``lines_flat`` /
    ``lines_off``) for vectorized analytics (e.g. footprint statistics in
    the sweep runner).
    """

    __slots__ = (
        "trace", "n_ops", "kind", "cycles", "bound", "pc", "idx_pc",
        "addr_first", "addr_last", "n_addrs", "lines",
        "n_vloads", "total_compute", "_flat_cache",
    )

    # 64 is a power of two and addresses are non-negative, so the line id
    # is a plain right-shift — set/sort over <=16 Python ints beats
    # np.unique's fixed overhead by ~3x at trace-compile time
    _LINE_SHIFT = LINE_BYTES.bit_length() - 1

    def __init__(self, trace: Trace) -> None:
        self.trace = trace
        n = len(trace.ops)
        self.n_ops = n
        kind: list[int] = [0] * n
        cycles: list[float] = [0.0] * n
        bound: list[int] = [0] * n
        pc: list[int] = [0] * n
        idx_pc: list[int] = [-1] * n
        addr_first: list[int] = [0] * n
        addr_last: list[int] = [0] * n
        n_addrs: list[int] = [0] * n
        lines: list[tuple] = [()] * n
        shift = self._LINE_SHIFT
        n_vloads = 0
        total_compute = 0.0
        for i, op in enumerate(trace.ops):
            if isinstance(op, Compute):
                cycles[i] = op.cycles
                total_compute += op.cycles
                continue
            n_vloads += 1
            kind[i] = KIND_INDIRECT if op.kind == "indirect" else KIND_STREAM
            bound[i] = op.bound_id
            pc[i] = op.pc
            idx_pc[i] = op.idx_pc
            addrs = op.addrs.tolist()
            addr_first[i] = addrs[0]
            addr_last[i] = addrs[-1]
            n_addrs[i] = len(addrs)
            lines[i] = tuple(sorted({a >> shift for a in addrs}))
        self.kind = kind
        self.cycles = cycles
        self.bound = bound
        self.pc = pc
        self.idx_pc = idx_pc
        self.addr_first = addr_first
        self.addr_last = addr_last
        self.n_addrs = n_addrs
        self.lines = lines
        self.n_vloads = n_vloads
        self.total_compute = total_compute
        self._flat_cache = None

    # -- analytics ---------------------------------------------------------
    @property
    def lines_flat(self) -> np.ndarray:
        """All per-op unique lines, concatenated (lazy; analytics only)."""
        if self._flat_cache is None:
            off = np.zeros(self.n_ops + 1, dtype=np.int64)
            for i, ln in enumerate(self.lines):
                off[i + 1] = off[i] + len(ln)
            flat = np.fromiter(
                (l for ln in self.lines for l in ln), dtype=np.int64,
                count=int(off[-1]))
            self._flat_cache = (flat, off)
        return self._flat_cache[0]

    @property
    def lines_off(self) -> np.ndarray:
        """Per-op offsets into :attr:`lines_flat` (length ``n_ops + 1``)."""
        self.lines_flat  # ensure built
        return self._flat_cache[1]

    def footprint_lines(self) -> int:
        """Distinct cache lines touched by the whole trace."""
        return int(np.unique(self.lines_flat).size)

    def line_reuse(self) -> float:
        """Mean touches per distinct line (>1 means temporal reuse)."""
        fp = self.footprint_lines()
        return float(self.lines_flat.size / fp) if fp else float("nan")


def compile_trace(trace: Trace) -> VecTrace:
    """Compile (and cache on the trace) the structure-of-arrays form."""
    vt = getattr(trace, _CACHE_ATTR, None)
    if vt is None or vt.trace is not trace:
        vt = VecTrace(trace)
        setattr(trace, _CACHE_ATTR, vt)
    return vt
