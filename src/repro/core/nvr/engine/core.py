"""The event-driven simulation engine.

:class:`SimEngine` walks a compiled :class:`~.vectrace.VecTrace` and
publishes three events per retired op:

* ``"vload"``  — a vector load entered execution ``(i, now)``.
* ``"miss"``   — that load demand-missed in L2 ``(i, now)``.
* ``"retire"`` — any op (load or compute tile) retired ``(i, now)``.

The configured prefetcher is just the first subscriber (its ``on_vload`` /
``on_miss`` hooks); capture adapters and stats collectors attach with
:meth:`SimEngine.subscribe` without the timing loop knowing about them.

Timing semantics are bit-identical to the seed ``simulate()`` loop (the
parity oracle lives in :mod:`.reference`); the speed comes from the
structure-of-arrays trace — per-op unique-line lists are precomputed once
per trace and shared by all mode/prefetcher runs — not from approximating
the model.
"""

from __future__ import annotations

from collections import deque

from ..trace import Trace
from .config import SimConfig
from .result import SimResult
from .vectrace import KIND_COMPUTE, KIND_INDIRECT, compile_trace

_EVENTS = ("vload", "miss", "retire")


class SimEngine:
    """Reusable engine for one :class:`SimConfig` (state is per-``run``)."""

    def __init__(self, config: SimConfig | None = None, **kw) -> None:
        self.config = config if config is not None else SimConfig(**kw)
        self._subs: dict[str, list] = {e: [] for e in _EVENTS}

    def subscribe(self, event: str, fn) -> None:
        """Attach ``fn(i, now)`` to ``event`` for every subsequent run."""
        self._subs[event].append(fn)

    # ------------------------------------------------------------------
    def run(self, trace: Trace, dtype_bytes: int = 0) -> SimResult:
        cfg = self.config
        vt = compile_trace(trace)
        if cfg.mode == "dense":
            return self._run_dense(trace, vt, dtype_bytes)

        hier = cfg.build_hierarchy()
        pf = cfg.build_prefetcher()
        # without µ-inst-level (VMIG) restructuring, demand fetches happen
        # at rigid scratchpad-DMA granularity (paper §II-B / §IV-F)
        granule = 1 if pf is not None else cfg.dma_granule_lines
        issue, hit_lat = cfg.issue_cycles, cfg.hit_latency
        ooo = cfg.mode == "ooo"
        ooo_window = cfg.ooo_window
        vload_subs, miss_subs, retire_subs = (
            self._subs["vload"], self._subs["miss"], self._subs["retire"])
        on_vload = pf.on_vload if pf is not None else None
        on_miss = pf.on_miss if pf is not None else None

        kind, cycles, all_lines = vt.kind, vt.cycles, vt.lines
        l2 = hier.l2
        nsb = hier.nsb
        l2_stats = l2.stats
        access_lines = hier.access_lines

        t = 0.0
        mem_ready = 0.0
        base = 0.0
        stall = 0.0
        compute = 0.0
        n_vloads = 0
        window = deque()  # OoO outstanding-load completion times
        for i, k in enumerate(kind):
            if k == KIND_COMPUTE:
                c = cycles[i]
                t += c
                base += c
                compute += c
                if retire_subs:
                    for cb in retire_subs:
                        cb(i, t)
                continue
            n_vloads += 1
            if l2._min_ready <= t:       # inline hier.drain guard
                l2.drain(t)
            if nsb is not None and nsb._min_ready <= t:
                nsb.drain(t)
            if on_vload is not None:
                on_vload(i, vt, t, hier)
            if vload_subs:
                for cb in vload_subs:
                    cb(i, t)
            miss_before = l2_stats.demand_misses
            ready = access_lines(all_lines[i], t, k == KIND_INDIRECT,
                                 granule)
            if l2_stats.demand_misses > miss_before:
                if on_miss is not None:
                    on_miss(i, vt, t, hier)
                if miss_subs:
                    for cb in miss_subs:
                        cb(i, t)
            if not ooo:
                t0 = t + issue + hit_lat
                base += issue + hit_lat
                if ready > t0:
                    stall += ready - t0
                    t = ready
                else:
                    t = t0
            else:
                t += issue
                base += issue
                window.append(ready)
                if len(window) > ooo_window:
                    # coarse-grained ROB: the oldest outstanding vector
                    # load must retire before a new one can issue
                    blocker = window.popleft()
                    if blocker > t:
                        stall += blocker - t
                        t = blocker
                if ready > mem_ready:
                    mem_ready = ready
            if retire_subs:
                for cb in retire_subs:
                    cb(i, t)
        if ooo:
            total = max(t, mem_ready)
            stall = total - base
        else:
            total = t

        pf_issued = (l2_stats.prefetch_fills
                     + (hier.nsb.stats.prefetch_fills if hier.nsb else 0))
        pf_used = l2_stats.prefetch_used
        nsb_hits = 0
        if hier.nsb is not None:
            pf_used += hier.nsb.stats.prefetch_used
            nsb_hits = hier.nsb.stats.hits
        return SimResult(
            workload=trace.name, mode=cfg.mode,
            prefetcher=cfg.prefetcher or "",
            dtype_bytes=dtype_bytes, nsb_kb=cfg.nsb_kb, total=total,
            base=base, stall=stall, compute=compute, n_vloads=n_vloads,
            demand_misses=l2_stats.demand_misses,
            l2_accesses=l2_stats.accesses,
            demand_offchip=hier.demand_offchip_bytes,
            prefetch_offchip=hier.prefetch_offchip_bytes,
            pf_issued=pf_issued, pf_used=pf_used, nsb_hits=nsb_hits)

    # ------------------------------------------------------------------
    def _run_dense(self, trace: Trace, vt, dtype_bytes: int) -> SimResult:
        cfg = self.config
        comp = vt.total_compute * trace.dense_compute_scale
        dense_bytes = trace.meta.get("dense_bytes", vt.total_compute * 64)
        mem = dense_bytes / cfg.dram_bw + cfg.dram_latency
        total = max(comp, mem)
        return SimResult(
            workload=trace.name, mode="dense", prefetcher="",
            dtype_bytes=dtype_bytes, nsb_kb=cfg.nsb_kb, total=total,
            base=comp, stall=total - comp, compute=comp, n_vloads=0,
            demand_misses=0, l2_accesses=0, demand_offchip=dense_bytes,
            prefetch_offchip=0.0, pf_issued=0, pf_used=0)
