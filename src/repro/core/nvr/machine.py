"""Memory-system model for the NVR simulator (paper-faithful layer).

Models the Gemmini-like NPU memory hierarchy from the paper:

    NPU <-> [optional NSB (16 KB, high-assoc, MSHRs)] <-> shared L2 <-> DRAM

All structures operate on *cache lines* (64 B).  The DRAM model is a simple
latency + bandwidth-occupancy queue: each line fetch occupies the channel for
``line_bytes / bytes_per_cycle`` cycles, so prefetchers that waste bandwidth
(low accuracy) produce real queuing slowdown — this is how the paper's
"stream prefetchers occasionally introduce performance penalties" emerges.

Everything is deterministic; no wall-clock or RNG in this module.

The implementation is tuned for the event-driven engine's hot loop but is
bit-identical in behaviour to the seed model (the frozen copy in
``engine/reference.py``; parity is asserted in tests/test_engine.py):

* LRU sets are plain insertion-ordered dicts (delete + reinsert on touch);
  resident-line state is a small-int bitfield, so touches allocate nothing.
* The in-flight prefetch tag lives in the *sign* of the MSHR ready cycle
  instead of a side set.
* ``drain()`` keeps a min-ready watermark (O(1) no-op when nothing can have
  completed) and exploits that DRAM-sourced fills arrive ready-sorted —
  the seed scanned every MSHR entry on every vector load.
* ``access_lines`` / ``prefetch_lines`` process a whole vector load per
  call with DRAM clock, byte counters and stats accumulated in locals.
"""

from __future__ import annotations

from dataclasses import dataclass

_INF = float("inf")
_TINY = 5e-324  # smallest positive float: stand-in for a 0.0 prefetch ready

LINE_BYTES = 64

# cache-set entry bitfield values (see Cache.sets)
_E_PF = 1        # line was installed by a prefetch
_E_USED = 2      # line has been demand-used
_E_PF_USED = 3


def line_of(addr: int) -> int:
    return addr // LINE_BYTES


@dataclass(slots=True)
class DRAM:
    """Latency + bandwidth-occupancy DRAM channel model."""

    latency: float = 150.0          # cycles, unloaded
    bytes_per_cycle: float = 16.0   # channel bandwidth
    busy_until: float = 0.0         # channel occupancy clock
    bytes_transferred: float = 0.0  # total off-chip traffic (demand+prefetch)

    def fetch(self, now: float, nbytes: int = LINE_BYTES) -> float:
        """Issue a line fetch at cycle ``now``; returns completion cycle."""
        occupancy = nbytes / self.bytes_per_cycle
        start = now if now > self.busy_until else self.busy_until
        self.busy_until = start + occupancy
        self.bytes_transferred += nbytes
        return start + occupancy + self.latency

    def reset(self) -> None:
        self.busy_until = 0.0
        self.bytes_transferred = 0.0


@dataclass(slots=True)
class CacheStats:
    hits: int = 0
    misses: int = 0
    demand_misses: int = 0
    prefetch_fills: int = 0
    prefetch_used: int = 0
    prefetch_unused_evicted: int = 0
    coalesced: int = 0  # MSHR hits on in-flight lines

    @property
    def accesses(self) -> int:
        return self.hits + self.misses


class Cache:
    """Set-associative, LRU, non-blocking (MSHR) cache.

    ``probe`` returns the cycle at which the line is available (for hits the
    access latency; for in-flight MSHR lines the fill time; misses return
    ``None`` and the caller decides where to fetch from).

    Prefetch fills are tagged so accuracy (used / issued) can be measured:
    in flight, the tag is the sign of the MSHR value (negative = prefetch);
    resident, it is bit0 of the set-entry bitfield.
    """

    __slots__ = ("name", "size_bytes", "ways", "hit_latency", "num_sets",
                 "sets", "mshr", "stats", "_min_ready",
                 "_fifo_ok", "_last_fill_ready", "_set_mask")

    def __init__(self, size_bytes: int, ways: int, hit_latency: float,
                 name: str = "L2") -> None:
        self.name = name
        self.size_bytes = size_bytes
        self.ways = ways
        self.hit_latency = hit_latency
        self.num_sets = max(1, size_bytes // LINE_BYTES // ways)
        # per-set insertion-ordered dict: line -> entry bitfield
        # (bit0 = was_prefetch, bit1 = demand-used).  Small ints are
        # interned in CPython, so touches/installs allocate nothing; LRU
        # order is maintained by delete + reinsert on touch.  The seed
        # stored (fill_cycle, was_prefetch, used) tuples, but the fill
        # cycle was never read back — behaviour is identical.
        self.sets: list[dict] = [{} for _ in range(self.num_sets)]
        # line -> ready cycle; NEGATIVE ready marks an in-flight prefetch
        self.mshr: dict[int, float] = {}
        self.stats = CacheStats()
        # num_sets is a power of two for every real config: index with a
        # mask (bulk paths fall back to the scalar path otherwise)
        self._set_mask = (self.num_sets - 1
                          if self.num_sets & (self.num_sets - 1) == 0
                          else -1)
        self._min_ready = _INF  # watermark: earliest in-flight completion
        # MSHR entries whose fill times only ever come from the (monotone)
        # DRAM channel clock are ready-sorted in insertion order, letting
        # drain() stop at the first not-yet-ready entry.  The flag clears
        # itself the moment any fill violates sortedness (e.g. NSB
        # forwarding fills), falling back to the full scan.
        self._fifo_ok = True
        self._last_fill_ready = -_INF

    # -- internals ---------------------------------------------------------
    def present(self, line: int, now: float) -> bool:
        if line in self.sets[line % self.num_sets]:
            return True
        ready = self.mshr.get(line)
        if ready is None:
            return False
        return (-ready if ready < 0 else ready) <= now

    def probe(self, line: int, now: float, demand: bool = True) -> float | None:
        """Access ``line`` at ``now``.  Returns availability cycle or None."""
        s = self.sets[line % self.num_sets]
        entry = s.pop(line, None)  # hit: removed here, reinserted as MRU
        stats = self.stats
        if entry is not None:
            if entry == _E_PF:          # unused prefetch line
                if demand:
                    stats.prefetch_used += 1
                    s[line] = _E_PF_USED
                else:
                    s[line] = _E_PF
            else:
                s[line] = entry | _E_USED if demand else entry
            stats.hits += 1
            return now + self.hit_latency
        ready = self.mshr.get(line)
        if ready is not None:
            was_pf = ready < 0
            if was_pf:
                ready = -ready
            if ready <= now:
                # fill completed: install
                self._install(line, ready, was_prefetch=was_pf, used=demand)
                if was_pf and demand:
                    stats.prefetch_used += 1
                del self.mshr[line]
                stats.hits += 1
                return now + self.hit_latency
            # still in flight: MSHR coalescing — wait for it, no new fetch
            stats.coalesced += 1
            if demand and was_pf:
                stats.prefetch_used += 1
                self.mshr[line] = ready  # count once: clear prefetch tag
            stats.hits += 1  # not an off-chip miss
            return ready + self.hit_latency
        stats.misses += 1
        if demand:
            stats.demand_misses += 1
        return None

    def _install(self, line: int, fill_cycle: float, was_prefetch: bool,
                 used: bool) -> None:
        s = self.sets[line % self.num_sets]
        if line in s:
            return
        if len(s) >= self.ways:
            lru = next(iter(s))            # oldest-inserted = LRU
            if s.pop(lru) == _E_PF:        # prefetched, never used
                self.stats.prefetch_unused_evicted += 1
        s[line] = (_E_PF if was_prefetch else 0) | (_E_USED if used else 0)

    def fill(self, line: int, ready: float, prefetch: bool = False) -> None:
        """Register an incoming fill (from DRAM or lower level)."""
        mshr = self.mshr
        cur = mshr.get(line)
        if cur is not None:
            if ready < (-cur if cur < 0 else cur):
                # earlier completion: keep the existing prefetch tag
                mshr[line] = (-ready or -_TINY) if cur < 0 else ready
                self._fifo_ok = False  # lowered mid-queue: order broken
                if ready < self._min_ready:
                    self._min_ready = ready
            return
        if line in self.sets[line % self.num_sets]:
            return
        mshr[line] = (-ready or -_TINY) if prefetch else ready
        if ready < self._last_fill_ready:
            self._fifo_ok = False
        else:
            self._last_fill_ready = ready
        if ready < self._min_ready:
            self._min_ready = ready
        if prefetch:
            self.stats.prefetch_fills += 1

    def drain(self, now: float) -> None:
        """Install all fills that have completed by ``now``."""
        if now < self._min_ready:
            return  # nothing in flight can have completed yet
        mshr = self.mshr
        sets, num_sets, ways = self.sets, self.num_sets, self.ways
        stats = self.stats
        if self._fifo_ok and mshr:
            last = next(reversed(mshr.values()))
            if (-last if last < 0 else last) <= now:
                # everything in flight has completed (common right after
                # a long stall): install all, clear in one shot
                for l, r in mshr.items():
                    s = sets[l % num_sets]         # inline _install
                    if l not in s:
                        if len(s) >= ways:
                            lru = next(iter(s))
                            if s.pop(lru) == _E_PF:
                                stats.prefetch_unused_evicted += 1
                        s[l] = _E_PF if r < 0 else 0
                mshr.clear()
                self._min_ready = _INF
                return
        done = []
        if self._fifo_ok:
            # ready-sorted queue: completed fills are a prefix — install
            # in the same pass, collect keys, delete after iteration
            for l, r in mshr.items():
                if (-r if r < 0 else r) > now:
                    break
                done.append(l)
                s = sets[l % num_sets]             # inline _install
                if l not in s:
                    if len(s) >= ways:
                        lru = next(iter(s))
                        if s.pop(lru) == _E_PF:    # prefetched, never used
                            stats.prefetch_unused_evicted += 1
                    s[l] = _E_PF if r < 0 else 0
        else:
            for l, r in mshr.items():
                if (-r if r < 0 else r) > now:
                    continue
                done.append(l)
                s = sets[l % num_sets]             # inline _install
                if l not in s:
                    if len(s) >= ways:
                        lru = next(iter(s))
                        if s.pop(lru) == _E_PF:
                            stats.prefetch_unused_evicted += 1
                    s[l] = _E_PF if r < 0 else 0
        for l in done:
            del mshr[l]
        if not mshr:
            self._min_ready = _INF
        elif self._fifo_ok:
            v = next(iter(mshr.values()))
            self._min_ready = -v if v < 0 else v
        else:
            self._min_ready = min(-v if v < 0 else v
                                  for v in mshr.values())

    def reset(self) -> None:
        self.sets = [{} for _ in range(self.num_sets)]
        self.mshr.clear()
        self.stats = CacheStats()
        self._min_ready = _INF
        self._fifo_ok = True
        self._last_fill_ready = -_INF


@dataclass(slots=True)
class Hierarchy:
    """L2 (+ optional NSB) + DRAM, with simple fetch plumbing.

    The NSB sits in front of L2 *for indirect (discrete) lines only*, per the
    paper (§IV-G): dense/continuous data stays in the scratchpad (modelled as
    always-hit) while sparse discrete data benefits from implicit cache-line
    reuse in the small high-associativity NSB.
    """

    l2: Cache
    dram: DRAM
    nsb: Cache | None = None
    demand_offchip_bytes: float = 0.0
    prefetch_offchip_bytes: float = 0.0

    def _dram_fill(self, line: int, now: float, granule_lines: int,
                   also_nsb: bool, skip_l2: bool = False) -> float:
        """Fetch ``line`` from DRAM at scratchpad-DMA granularity.

        NPUs without µ-instruction-level prefetch issue *rigid* preload DMAs
        (paper §II-B / §IV-F): the whole aligned granule is transferred even
        if only one line is needed.  VMIG-restructured (prefetcher) accesses
        bypass this and are line-granular (granule_lines=1).
        """
        ready = self.dram.fetch(now, nbytes=granule_lines * LINE_BYTES)
        self.demand_offchip_bytes += granule_lines * LINE_BYTES
        # only the demanded line is architecturally useful: the rest of the
        # rigid DMA granule is padding streamed into the scratchpad, not
        # cacheable for reuse (it wastes bandwidth, not cache capacity)
        if not skip_l2:
            self.l2.fill(line, ready)
        if also_nsb and self.nsb is not None:
            self.nsb.fill(line, ready)
        return ready

    def access(self, line: int, now: float, indirect: bool,
               granule_lines: int = 1) -> float:
        """Demand access; returns data-ready cycle."""
        nsb = self.nsb
        if nsb is not None and indirect:
            t = nsb.probe(line, now)
            if t is not None:
                return t
            # NSB miss -> L2 (fill NSB on return)
            t2 = self.l2.probe(line, now + nsb.hit_latency)
            if t2 is None:
                ready = self._dram_fill(line, now + nsb.hit_latency,
                                        granule_lines, also_nsb=True)
                return ready + nsb.hit_latency
            nsb.fill(line, t2)
            return t2
        t = self.l2.probe(line, now)
        if t is not None:
            return t
        ready = self._dram_fill(line, now, granule_lines, also_nsb=False)
        return ready + self.l2.hit_latency

    def access_lines(self, lines, now: float, indirect: bool,
                     granule_lines: int = 1) -> float:
        """Bulk demand access: the max data-ready cycle over ``lines``.

        Semantically identical to ``max(access(ln, ...) for ln in lines)``
        but one Python call per *vector load* instead of one per line —
        the engine's hottest path.  The L2-only branch inlines
        ``Cache.probe`` (demand=True) and the DRAM miss fill; any change
        here must keep tests/test_engine.py parity green.
        """
        nsb = self.nsb
        l2 = self.l2
        mask = l2._set_mask
        if (nsb is not None and indirect) or mask < 0:
            ready = now
            for ln in lines:
                r = self.access(ln, now, indirect, granule_lines)
                if r > ready:
                    ready = r
            return ready
        sets = l2.sets
        mshr = l2.mshr
        lat = l2.hit_latency
        dram = self.dram
        gbytes = granule_lines * LINE_BYTES
        # DRAM clock, byte counters and stats accumulate in locals and
        # flush once per bundle: nothing else can touch them mid-bundle
        busy = dram.busy_until
        occupancy = gbytes / dram.bytes_per_cycle
        dlat = dram.latency
        nbytes = 0
        misses = coalesced = pf_used = 0
        ready = now
        hit_r = now + lat
        for ln in lines:
            s = sets[ln & mask]
            entry = s.pop(ln, None)  # hit: removed here, reinserted as MRU
            if entry is not None:                       # L2 hit
                if entry == _E_PF:     # unused prefetch line, first use
                    pf_used += 1
                    s[ln] = _E_PF_USED
                else:
                    s[ln] = entry | _E_USED
                r = hit_r
            else:
                rdy = mshr.get(ln)
                if rdy is not None:                     # in flight
                    if rdy < 0:                         # prefetch in flight
                        rdy = -rdy
                        if rdy <= now:
                            l2._install(ln, rdy, True, True)
                            pf_used += 1
                            del mshr[ln]
                            r = hit_r
                        else:
                            coalesced += 1
                            pf_used += 1
                            mshr[ln] = rdy  # count once: clear tag
                            r = rdy + lat
                    elif rdy <= now:
                        l2._install(ln, rdy, False, True)
                        del mshr[ln]
                        r = hit_r
                    else:
                        coalesced += 1
                        r = rdy + lat
                else:                                   # miss -> DRAM
                    misses += 1
                    start = now if now > busy else busy
                    busy = start + occupancy
                    nbytes += gbytes
                    fin = start + occupancy + dlat
                    mshr[ln] = fin      # inline l2.fill: ln known absent
                    if fin < l2._last_fill_ready:
                        l2._fifo_ok = False
                    else:
                        l2._last_fill_ready = fin
                    if fin < l2._min_ready:
                        l2._min_ready = fin
                    r = fin + lat
            if r > ready:
                ready = r
        dram.busy_until = busy
        dram.bytes_transferred += nbytes
        self.demand_offchip_bytes += nbytes
        stats = l2.stats
        stats.hits += len(lines) - misses   # every non-miss line is a hit
        stats.misses += misses
        stats.demand_misses += misses
        stats.coalesced += coalesced
        stats.prefetch_used += pf_used
        return ready

    def prefetch_lines(self, lines, now: float, cap: int,
                       into_nsb: bool = False) -> int:
        """Bulk prefetch with the per-line MSHR-cap check; returns the
        number of issue attempts that passed the cap (the prefetchers'
        ``issued_lines`` accounting).  One call per vector-issue bundle
        instead of one ``prefetch()`` per line; the L2 fast path inlines
        the dedup check and fill.  Within one bundle the L2 MSHR can only
        grow, so hitting the cap ends the bundle (identical outcome to
        the seed's per-line cap test)."""
        l2 = self.l2
        mshr = l2.mshr
        mask = l2._set_mask
        if (into_nsb and self.nsb is not None) or mask < 0:
            issued = 0
            for ln in lines:
                if len(mshr) >= cap:
                    break
                issued += 1
                self.prefetch(ln, now, into_nsb=into_nsb)
            return issued
        sets = l2.sets
        dram = self.dram
        busy = dram.busy_until
        occupancy = LINE_BYTES / dram.bytes_per_cycle
        dlat = dram.latency
        fills = 0
        free = cap - len(mshr)   # MSHR only grows within one bundle
        n = len(lines)
        if free >= n:
            # budget cannot bind: skip the per-line cap bookkeeping
            issued = n
            for ln in lines:
                if ln in mshr or ln in sets[ln & mask]:
                    continue            # on-chip or already in flight
                start = now if now > busy else busy
                busy = start + occupancy
                ready = start + occupancy + dlat
                mshr[ln] = -ready       # inline l2.fill(ln, ready, True)
                if ready < l2._last_fill_ready:
                    l2._fifo_ok = False
                else:
                    l2._last_fill_ready = ready
                if ready < l2._min_ready:
                    l2._min_ready = ready
                fills += 1
        else:
            issued = 0
            for ln in lines:
                if free <= 0:
                    break
                issued += 1
                if ln in mshr or ln in sets[ln & mask]:
                    continue            # on-chip or already in flight
                free -= 1
                start = now if now > busy else busy
                busy = start + occupancy
                ready = start + occupancy + dlat
                mshr[ln] = -ready       # inline l2.fill(ln, ready, True)
                if ready < l2._last_fill_ready:
                    l2._fifo_ok = False
                else:
                    l2._last_fill_ready = ready
                if ready < l2._min_ready:
                    l2._min_ready = ready
                fills += 1
        if fills:
            dram.busy_until = busy
            dram.bytes_transferred += fills * LINE_BYTES
            self.prefetch_offchip_bytes += fills * LINE_BYTES
            l2.stats.prefetch_fills += fills
        return issued

    def prefetch(self, line: int, now: float, into_nsb: bool = False) -> None:
        """Prefetch ``line``; fills L2 (and optionally NSB)."""
        nsb = self.nsb
        target = nsb if (into_nsb and nsb is not None) else self.l2
        # on-chip or in flight at the target: nothing to do
        if line in target.mshr or line in target.sets[line % target.num_sets]:
            return
        if target is nsb:
            l2 = self.l2
            if line in l2.sets[line % l2.num_sets]:
                # already on-chip: move into NSB without off-chip traffic
                nsb.fill(line, now + l2.hit_latency, prefetch=True)
                return
            ready = l2.mshr.get(line)
            if ready is not None:
                if ready < 0:
                    ready = -ready
                if ready <= now:
                    nsb.fill(line, now + l2.hit_latency, prefetch=True)
                else:
                    # in flight from a far (L2-level) prefetch: forward it
                    nsb.fill(line, ready, prefetch=True)
                return
        ready = self.dram.fetch(now)
        self.prefetch_offchip_bytes += LINE_BYTES
        target.fill(line, ready, prefetch=True)
        if target is nsb:
            self.l2.fill(line, ready)

    def drain(self, now: float) -> None:
        l2 = self.l2
        if l2._min_ready <= now:
            l2.drain(now)
        nsb = self.nsb
        if nsb is not None and nsb._min_ready <= now:
            nsb.drain(now)

    @property
    def offchip_bytes(self) -> float:
        return self.demand_offchip_bytes + self.prefetch_offchip_bytes


def cache_latency(size_kb: int, base_kb: int = 256,
                  base_lat: float = 20.0) -> float:
    """CACTI-style access-latency scaling: bigger SRAM arrays are slower —
    the physical argument for the paper's small NSB.  Exponent 0.3 sits
    between wire-delay (0.5) and bank-parallel (0) regimes; Fig. 9's
    NSB-vs-L2 ratio is sensitive to it (see EXPERIMENTS.md §Deviations)."""
    return base_lat * (size_kb / base_kb) ** 0.3


def make_hierarchy(l2_kb: int = 256, nsb_kb: int = 0,
                   dram_latency: float = 150.0,
                   dram_bw: float = 16.0) -> Hierarchy:
    l2 = Cache(l2_kb * 1024, ways=8, hit_latency=cache_latency(l2_kb),
               name="L2")
    nsb = None
    if nsb_kb:
        nsb = Cache(nsb_kb * 1024, ways=16,
                    hit_latency=cache_latency(nsb_kb, 16, 2.0), name="NSB")
    return Hierarchy(l2=l2, dram=DRAM(latency=dram_latency,
                                      bytes_per_cycle=dram_bw), nsb=nsb)
