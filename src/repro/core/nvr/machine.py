"""Memory-system model for the NVR simulator (paper-faithful layer).

Models the Gemmini-like NPU memory hierarchy from the paper:

    NPU <-> [optional NSB (16 KB, high-assoc, MSHRs)] <-> shared L2 <-> DRAM

All structures operate on *cache lines* (64 B).  The DRAM model is a simple
latency + bandwidth-occupancy queue: each line fetch occupies the channel for
``line_bytes / bytes_per_cycle`` cycles, so prefetchers that waste bandwidth
(low accuracy) produce real queuing slowdown — this is how the paper's
"stream prefetchers occasionally introduce performance penalties" emerges.

Everything is deterministic; no wall-clock or RNG in this module.
"""

from __future__ import annotations

from collections import OrderedDict
from dataclasses import dataclass, field


LINE_BYTES = 64


def line_of(addr: int) -> int:
    return addr // LINE_BYTES


@dataclass
class DRAM:
    """Latency + bandwidth-occupancy DRAM channel model."""

    latency: float = 150.0          # cycles, unloaded
    bytes_per_cycle: float = 16.0   # channel bandwidth
    busy_until: float = 0.0         # channel occupancy clock
    bytes_transferred: float = 0.0  # total off-chip traffic (demand+prefetch)

    def fetch(self, now: float, nbytes: int = LINE_BYTES) -> float:
        """Issue a line fetch at cycle ``now``; returns completion cycle."""
        occupancy = nbytes / self.bytes_per_cycle
        start = max(now, self.busy_until)
        self.busy_until = start + occupancy
        self.bytes_transferred += nbytes
        return start + occupancy + self.latency

    def reset(self) -> None:
        self.busy_until = 0.0
        self.bytes_transferred = 0.0


@dataclass
class CacheStats:
    hits: int = 0
    misses: int = 0
    demand_misses: int = 0
    prefetch_fills: int = 0
    prefetch_used: int = 0
    prefetch_unused_evicted: int = 0
    coalesced: int = 0  # MSHR hits on in-flight lines

    @property
    def accesses(self) -> int:
        return self.hits + self.misses


class Cache:
    """Set-associative, LRU, non-blocking (MSHR) cache.

    ``lookup`` returns the cycle at which the line is available (for hits the
    access latency; for in-flight MSHR lines the fill time; misses return
    ``None`` and the caller decides where to fetch from).

    Prefetch fills are tagged so accuracy (used / issued) can be measured.
    """

    def __init__(self, size_bytes: int, ways: int, hit_latency: float,
                 name: str = "L2") -> None:
        self.name = name
        self.size_bytes = size_bytes
        self.ways = ways
        self.hit_latency = hit_latency
        self.num_sets = max(1, size_bytes // LINE_BYTES // ways)
        # per-set OrderedDict: line -> (fill_cycle, was_prefetch, used)
        self.sets: list[OrderedDict] = [OrderedDict() for _ in range(self.num_sets)]
        self.mshr: dict[int, float] = {}   # line -> ready cycle (in flight)
        self.mshr_prefetch: set[int] = set()
        self.stats = CacheStats()

    # -- internals ---------------------------------------------------------
    def _set(self, line: int) -> OrderedDict:
        return self.sets[line % self.num_sets]

    def present(self, line: int, now: float) -> bool:
        s = self._set(line)
        if line in s:
            return True
        return line in self.mshr and self.mshr[line] <= now

    def probe(self, line: int, now: float, demand: bool = True) -> float | None:
        """Access ``line`` at ``now``.  Returns availability cycle or None."""
        s = self._set(line)
        if line in s:
            fill, was_pf, used = s[line]
            if was_pf and not used and demand:
                self.stats.prefetch_used += 1
            s[line] = (fill, was_pf, True if demand else used)
            s.move_to_end(line)
            self.stats.hits += 1
            return now + self.hit_latency
        if line in self.mshr:
            ready = self.mshr[line]
            if ready <= now:
                # fill completed: install
                self._install(line, ready,
                              was_prefetch=line in self.mshr_prefetch,
                              used=demand)
                if line in self.mshr_prefetch and demand:
                    self.stats.prefetch_used += 1
                del self.mshr[line]
                self.mshr_prefetch.discard(line)
                self.stats.hits += 1
                return now + self.hit_latency
            # still in flight: MSHR coalescing — wait for it, no new fetch
            self.stats.coalesced += 1
            if line in self.mshr_prefetch and demand:
                self.stats.prefetch_used += 1
                self.mshr_prefetch.discard(line)  # count once
            self.stats.hits += 1  # not an off-chip miss
            return ready + self.hit_latency
        self.stats.misses += 1
        if demand:
            self.stats.demand_misses += 1
        return None

    def _install(self, line: int, fill_cycle: float, was_prefetch: bool,
                 used: bool) -> None:
        s = self._set(line)
        if line in s:
            return
        if len(s) >= self.ways:
            _, (f, pf, u) = s.popitem(last=False)  # LRU eviction
            if pf and not u:
                self.stats.prefetch_unused_evicted += 1
        s[line] = (fill_cycle, was_prefetch, used)

    def fill(self, line: int, ready: float, prefetch: bool = False) -> None:
        """Register an incoming fill (from DRAM or lower level)."""
        if line in self.mshr:
            self.mshr[line] = min(self.mshr[line], ready)
            return
        s = self._set(line)
        if line in s:
            return
        self.mshr[line] = ready
        if prefetch:
            self.mshr_prefetch.add(line)
            self.stats.prefetch_fills += 1

    def drain(self, now: float) -> None:
        """Install all fills that have completed by ``now``."""
        done = [l for l, r in self.mshr.items() if r <= now]
        for l in done:
            self._install(l, self.mshr[l], l in self.mshr_prefetch, False)
            del self.mshr[l]
            self.mshr_prefetch.discard(l)

    def reset(self) -> None:
        self.sets = [OrderedDict() for _ in range(self.num_sets)]
        self.mshr.clear()
        self.mshr_prefetch.clear()
        self.stats = CacheStats()


@dataclass
class Hierarchy:
    """L2 (+ optional NSB) + DRAM, with simple fetch plumbing.

    The NSB sits in front of L2 *for indirect (discrete) lines only*, per the
    paper (§IV-G): dense/continuous data stays in the scratchpad (modelled as
    always-hit) while sparse discrete data benefits from implicit cache-line
    reuse in the small high-associativity NSB.
    """

    l2: Cache
    dram: DRAM
    nsb: Cache | None = None
    demand_offchip_bytes: float = 0.0
    prefetch_offchip_bytes: float = 0.0

    def _dram_fill(self, line: int, now: float, granule_lines: int,
                   also_nsb: bool, skip_l2: bool = False) -> float:
        """Fetch ``line`` from DRAM at scratchpad-DMA granularity.

        NPUs without µ-instruction-level prefetch issue *rigid* preload DMAs
        (paper §II-B / §IV-F): the whole aligned granule is transferred even
        if only one line is needed.  VMIG-restructured (prefetcher) accesses
        bypass this and are line-granular (granule_lines=1).
        """
        ready = self.dram.fetch(now, nbytes=granule_lines * LINE_BYTES)
        self.demand_offchip_bytes += granule_lines * LINE_BYTES
        # only the demanded line is architecturally useful: the rest of the
        # rigid DMA granule is padding streamed into the scratchpad, not
        # cacheable for reuse (it wastes bandwidth, not cache capacity)
        if not skip_l2:
            self.l2.fill(line, ready)
        if also_nsb and self.nsb is not None:
            self.nsb.fill(line, ready)
        return ready

    def access(self, line: int, now: float, indirect: bool,
               granule_lines: int = 1) -> float:
        """Demand access; returns data-ready cycle."""
        if self.nsb is not None and indirect:
            t = self.nsb.probe(line, now)
            if t is not None:
                return t
            # NSB miss -> L2 (fill NSB on return)
            t2 = self.l2.probe(line, now + self.nsb.hit_latency)
            if t2 is None:
                ready = self._dram_fill(line, now + self.nsb.hit_latency,
                                        granule_lines, also_nsb=True)
                return ready + self.nsb.hit_latency
            self.nsb.fill(line, t2)
            return t2
        t = self.l2.probe(line, now)
        if t is not None:
            return t
        ready = self._dram_fill(line, now, granule_lines, also_nsb=False)
        return ready + self.l2.hit_latency

    def prefetch(self, line: int, now: float, into_nsb: bool = False) -> None:
        """Prefetch ``line``; fills L2 (and optionally NSB)."""
        target = self.nsb if (into_nsb and self.nsb is not None) else self.l2
        if target.present(line, now) or line in target.mshr:
            return
        if target is self.nsb:
            if self.l2.present(line, now):
                # already on-chip: move into NSB without off-chip traffic
                self.nsb.fill(line, now + self.l2.hit_latency, prefetch=True)
                return
            if line in self.l2.mshr:
                # in flight from a far (L2-level) prefetch: forward the fill
                self.nsb.fill(line, self.l2.mshr[line], prefetch=True)
                return
        ready = self.dram.fetch(now)
        self.prefetch_offchip_bytes += LINE_BYTES
        target.fill(line, ready, prefetch=True)
        if target is self.nsb:
            self.l2.fill(line, ready)

    def drain(self, now: float) -> None:
        self.l2.drain(now)
        if self.nsb is not None:
            self.nsb.drain(now)

    @property
    def offchip_bytes(self) -> float:
        return self.demand_offchip_bytes + self.prefetch_offchip_bytes


def cache_latency(size_kb: int, base_kb: int = 256,
                  base_lat: float = 20.0) -> float:
    """CACTI-style access-latency scaling: bigger SRAM arrays are slower —
    the physical argument for the paper's small NSB.  Exponent 0.3 sits
    between wire-delay (0.5) and bank-parallel (0) regimes; Fig. 9's
    NSB-vs-L2 ratio is sensitive to it (see EXPERIMENTS.md §Deviations)."""
    return base_lat * (size_kb / base_kb) ** 0.3


def make_hierarchy(l2_kb: int = 256, nsb_kb: int = 0,
                   dram_latency: float = 150.0,
                   dram_bw: float = 16.0) -> Hierarchy:
    l2 = Cache(l2_kb * 1024, ways=8, hit_latency=cache_latency(l2_kb),
               name="L2")
    nsb = None
    if nsb_kb:
        nsb = Cache(nsb_kb * 1024, ways=16,
                    hit_latency=cache_latency(nsb_kb, 16, 2.0), name="NSB")
    return Hierarchy(l2=l2, dram=DRAM(latency=dram_latency,
                                      bytes_per_cycle=dram_bw), nsb=nsb)
