"""Trace-capture adapters: turn *real* model/serving traffic into traces.

The synthetic generators in :mod:`.traces` model Table-II workloads; this
module closes the loop with the rest of the repo by recording the actual
gather traffic the serving and model layers produce and lowering it into
the same :class:`~.trace.Trace` format the simulator consumes:

* :class:`PageStream` — a generic recorder for "select K rows of a table"
  events (TopK KV pages, MoE expert weight tiles, CSR rows, ...).
* :func:`to_trace` — lowers a recorded stream into the paper's
  (index stream load -> indirect row gather -> compute) bundle shape.
* :func:`kv_page_stream` — recorder preconfigured for TopK sparse-KV
  decode page selections (``serve.Engine`` / ``sparse_attention``).
* :func:`moe_expert_stream` — converts an MoE routing decision
  (per-token expert ids, as produced by ``kernels.ops
  .group_tokens_by_expert``) into expert-weight-tile gather traffic.
* :class:`PageCache` — the NSB hot-set model backed by the shared
  :class:`~.machine.Cache`, replacing the serving engine's ad-hoc LRU.

Everything here is numpy-only: the jax layers hand over concrete index
arrays (selections are materialised on host in the serving loop anyway),
so the simulator core stays importable without jax.
"""

from __future__ import annotations

from dataclasses import dataclass, field

import numpy as np

from .machine import Cache, LINE_BYTES, _E_PF
from .trace import Trace, TraceBuilder
from .traces import MAC_RATE, PC_IDX, _row_gather, _stream_idx

# memory-hierarchy tier tags for recorded page events (see
# docs/MEMORY_HIERARCHY.md): which tier *served or received* the pages
# of an event.  -1 = untagged (historic streams; treated as HBM demand).
TIER_HBM = 0        # demand-pool gather (the authoritative tier)
TIER_NSB = 1        # staged into / served from the NSB hot tail
TIER_HOST = 2       # host spill-tier transfer (swap-out or swap-in)


@dataclass
class PageStream:
    """Recorded row-selection traffic against one indexed table.

    ``events`` is a list of int arrays; each array holds the row ids one
    selection event touched (one decode step for one (batch, head) slot,
    one routed token block, ...).

    Multi-tenant traffic is tagged: ``rids[i]`` / ``steps[i]`` carry the
    request id and scheduler iteration that produced ``events[i]`` (-1
    when untagged, e.g. single-batch capture).  Tensor-parallel traffic
    adds ``shards[i]``: the model shard whose KV heads produced the
    selection (-1 when serving is single-shard) — each shard owns its own
    NSB, so per-shard streams replay through per-shard hot-set models
    (:func:`nsb_shard_rollup`).  Memory-tier traffic adds ``tiers[i]``:
    which hierarchy tier the event's pages moved through (``TIER_HBM``
    demand gathers, ``TIER_NSB`` staging copies, ``TIER_HOST`` spill
    swaps; -1 when untagged).  Tags are metadata only — ``to_trace``
    lowers events in recorded order, so a continuous-batching engine's
    interleaving is exactly what the simulator replays.
    """

    name: str
    n_rows: int             # number of rows in the indexed table
    row_bytes: int          # bytes gathered per selected row
    compute_per_row: float  # compute cycles per gathered row
    events: list = field(default_factory=list)
    rids: list = field(default_factory=list)
    steps: list = field(default_factory=list)
    shards: list = field(default_factory=list)
    tiers: list = field(default_factory=list)

    def record(self, idx, *, rid: int = -1, step: int = -1,
               shard: int = -1, tier: int = -1) -> None:
        """Record one selection event (any int array-like of row ids)."""
        arr = np.asarray(idx, dtype=np.int64).reshape(-1)
        if arr.size:
            self.events.append(arr)
            self.rids.append(int(rid))
            self.steps.append(int(step))
            self.shards.append(int(shard))
            self.tiers.append(int(tier))

    def record_batched(self, idx, *, rid: int = -1, step: int = -1,
                       shard: int = -1, tier: int = -1) -> None:
        """Record ``idx[..., K]`` as one event per leading slot — e.g. a
        ``[B, KV, K]`` TopK selection becomes ``B*KV`` events.  Empty
        rows (K == 0) are skipped, matching :meth:`record` — zero-length
        events would poison ``to_trace`` with empty bundles."""
        arr = np.asarray(idx, dtype=np.int64)
        if not arr.size:            # [B, KV, 0] selection: nothing chosen
            return
        for row in arr.reshape(-1, arr.shape[-1]):
            self.events.append(row.copy())
            self.rids.append(int(rid))
            self.steps.append(int(step))
            self.shards.append(int(shard))
            self.tiers.append(int(tier))

    @property
    def n_events(self) -> int:
        return len(self.events)

    @property
    def rows_selected(self) -> int:
        return sum(len(e) for e in self.events)

    # -- multi-request views -------------------------------------------------

    def request_ids(self) -> list:
        """Distinct request tags in first-appearance order (without -1)."""
        seen: dict = {}
        for r in self.rids:
            if r >= 0 and r not in seen:
                seen[r] = None
        return list(seen)

    def events_for(self, rid: int) -> list:
        """One request's events as ``(step, row-id array)`` in order."""
        return [(s, e) for e, r, s in zip(self.events, self.rids,
                                          self.steps) if r == rid]

    def _filtered(self, suffix: str, pred) -> "PageStream":
        """A new stream over the same table holding the events where
        ``pred(rid, shard, tier)`` is true, all tags preserved."""
        sub = PageStream(name=f"{self.name}/{suffix}", n_rows=self.n_rows,
                         row_bytes=self.row_bytes,
                         compute_per_row=self.compute_per_row)
        for ev, r, st, sh, ti in zip(self.events, self.rids, self.steps,
                                     self.shards, self.tiers):
            if pred(r, sh, ti):
                sub.record(ev, rid=r, step=st, shard=sh, tier=ti)
        return sub

    def subset(self, rid: int) -> "PageStream":
        """A single request's traffic as its own stream (same table)."""
        return self._filtered(f"r{rid}", lambda r, sh, ti: r == rid)

    # -- memory-tier views ---------------------------------------------------

    def tier_ids(self) -> list:
        """Distinct tier tags in first-appearance order (without -1)."""
        seen: dict = {}
        for t in self.tiers:
            if t >= 0 and t not in seen:
                seen[t] = None
        return list(seen)

    def subset_tier(self, tier: int) -> "PageStream":
        """One memory tier's traffic as its own stream: e.g.
        ``subset_tier(TIER_HOST)`` isolates the spill swap transfers
        from the demand gathers they hide behind.  Untagged events
        (``tier == -1``, historic recorders) count as ``TIER_HBM``."""
        return self._filtered(
            f"tier{tier}",
            lambda r, sh, ti: ti == tier
            or (tier == TIER_HBM and ti < 0))

    # -- tensor-parallel views -----------------------------------------------

    def shard_ids(self) -> list:
        """Distinct shard tags in first-appearance order (without -1)."""
        seen: dict = {}
        for s in self.shards:
            if s >= 0 and s not in seen:
                seen[s] = None
        return list(seen)

    def subset_shard(self, shard: int) -> "PageStream":
        """One model shard's traffic as its own stream: the page
        selections its KV heads produced, in recorded order — the
        traffic that shard's private NSB sees."""
        return self._filtered(f"shard{shard}",
                              lambda r, sh, ti: sh == shard)

    def interleave_spans(self) -> dict:
        """Per-request (first, last) positions in the recorded order —
        overlapping spans mean the requests' traffic interleaves."""
        spans: dict = {}
        for i, r in enumerate(self.rids):
            if r < 0:
                continue
            first, _ = spans.get(r, (i, i))
            spans[r] = (first, i)
        return spans

    def to_trace(self) -> Trace:
        return to_trace(self)


def to_trace(stream: PageStream) -> Trace:
    """Lower a recorded stream into a simulator trace.

    Each event becomes one sparse loop instance (bound): a stream load of
    the selected row ids, an indirect gather of the (sorted) rows, and
    the compute tile those rows feed — exactly the bundle shape the
    synthetic Table-II generators emit, so every prefetcher model sees
    the hardware-visible fields it expects.
    """
    if not stream.events:
        raise ValueError(f"PageStream {stream.name!r} has no recorded "
                         "events; run traffic through the recorder first")
    tb = TraceBuilder(stream.name)
    table = tb.alloc("table", stream.n_rows * stream.row_bytes,
                     indirect=True)
    idxb = tb.alloc("idx", max(4, stream.rows_selected * 4))
    pos = 0
    for ev in stream.events:
        tb.new_bound()
        _stream_idx(tb, idxb, pos, ev)
        pos += len(ev)
        _row_gather(tb, table, np.sort(ev), stream.row_bytes, PC_IDX)
        tb.compute(len(ev) * stream.compute_per_row)
    mean_k = stream.rows_selected / stream.n_events
    dense_bytes = stream.n_events * stream.n_rows * stream.row_bytes
    return tb.build(dense_compute_scale=stream.n_rows / max(1.0, mean_k),
                    dense_bytes=dense_bytes)


# -- concrete adapters --------------------------------------------------------

def kv_page_stream(name: str, n_pages: int, page_tokens: int, head_dim: int,
                   dtype_bytes: int = 2) -> PageStream:
    """Recorder for TopK sparse-KV decode: one row = one K+V page."""
    row_bytes = 2 * page_tokens * head_dim * dtype_bytes   # K and V planes
    comp = page_tokens * head_dim / MAC_RATE               # qk^T + pv MACs
    return PageStream(name=name, n_rows=n_pages, row_bytes=row_bytes,
                      compute_per_row=comp)


def moe_expert_stream(expert_ids, n_experts: int, d_model: int, d_ff: int,
                      dtype_bytes: int = 2, block_t: int = 16,
                      tile_rows: int = 32,
                      name: str = "MoE-route") -> PageStream:
    """Convert an MoE routing decision into expert weight-tile traffic.

    ``expert_ids`` is either ``[T]`` per-token routed experts (the top-1
    view the MoE dispatch / ``group_tokens_by_expert`` consumes) or
    ``[T, k]`` full top-k selections straight from the router
    (``jax.lax.top_k`` output): each of the ``T*k`` (token, expert)
    pairs demands its expert's weights, so a top-k matrix is the same
    traffic as ``T*k`` top-1 tokens — the flattening below *is* the
    semantics, not a shape accident.  Tokens are grouped per expert into
    ``block_t``-token blocks; each block streams a ``tile_rows``-row
    tile of its expert's weight matrix — the expert-blocked pattern of
    the paper's ST workload, but driven by real routing instead of a
    synthetic zipf draw.
    """
    raw = np.asarray(expert_ids, dtype=np.int64)
    if raw.ndim not in (1, 2):
        raise ValueError(
            f"expert_ids must be [T] top-1 or [T, k] top-k routed expert "
            f"ids, got shape {raw.shape}")
    eids = raw.reshape(-1)
    if eids.size and (eids.min() < 0 or eids.max() >= n_experts):
        raise ValueError(
            f"routed expert ids must lie in [0, {n_experts}), got range "
            f"[{eids.min()}, {eids.max()}]")
    stream = PageStream(name=name, n_rows=n_experts * d_ff,
                        row_bytes=d_model * dtype_bytes,
                        compute_per_row=16 * d_model / MAC_RATE)
    # clamp the tile to the expert's row range: with d_ff <= tile_rows an
    # unclamped tile would spill into the next expert's rows (and past
    # n_rows for the last expert)
    tile = min(tile_rows, d_ff)
    span = d_ff - tile + 1                 # valid tile start positions
    for e in range(n_experts):
        count = int((eids == e).sum())
        n_blocks = (count + block_t - 1) // block_t
        for bi in range(n_blocks):
            start = (bi * tile) % span
            rows = e * d_ff + start + np.arange(tile, dtype=np.int64)
            stream.record(rows)
    return stream


def expert_page_stream(name: str, n_pages: int, tile_rows: int,
                       d_model: int, dtype_bytes: int = 2) -> PageStream:
    """Recorder for paged expert-weight serving: one row = one expert
    weight tile page of the :class:`~repro.serve.expert_pool.ExpertPool`
    physical id space (``[tile_rows, d_model]`` of one gate/up/down
    plane).  Events are the tile pages one decode step's routing
    demanded (``TIER_HBM``) or the runahead stage copied into the NSB
    tail (``TIER_NSB``) — the expert twin of :func:`kv_page_stream`."""
    row_bytes = tile_rows * d_model * dtype_bytes
    comp = tile_rows * d_model / MAC_RATE      # one MAC per weight elem
    return PageStream(name=name, n_rows=n_pages, row_bytes=row_bytes,
                      compute_per_row=comp)


class PageCache:
    """NSB hot-set model over page ids, backed by the shared
    :class:`~.machine.Cache` (one fully-associative LRU set) — the same
    memory-system model the simulator uses, replacing the serving
    engine's ad-hoc ``HotSet`` LRU so the two layers cannot drift.

    Two usage modes share the accounting:

    * demand-LRU (the historic behaviour): every :meth:`touch` installs
      on miss — what the NSB hit rate "would have been" for an LRU tier.
    * speculative (the online runahead tier's twin): pages enter only
      through :meth:`stage` (counted as prefetch fills by the underlying
      :class:`~.machine.Cache` stats) and demand traffic probes with
      ``install=False`` — misses never install, exactly the physical
      staging buffer's behaviour.  :attr:`accuracy` (staged pages that
      got used) and :attr:`coverage` (demand touches served) then fall
      straight out of the Cache's built-in prefetch accounting.
    """

    def __init__(self, capacity_pages: int) -> None:
        self.capacity = capacity_pages
        self.cache = Cache(capacity_pages * LINE_BYTES,
                           ways=capacity_pages, hit_latency=2.0,
                           name="NSB-pages")
        self._now = 0.0

    def touch(self, page: int, install: bool = True) -> bool:
        """Access one page id; returns True on a hot-set hit.

        ``install=False`` is the physical-tier demand probe: a miss is
        counted but the page is *not* brought in — only :meth:`stage`
        installs there."""
        self._now += 1.0
        t = self.cache.probe(int(page), self._now)
        if t is None:
            if install:
                self.cache.fill(int(page), self._now)
                self.cache.drain(self._now)   # install immediately
            return False
        return True

    def stage(self, page: int) -> None:
        """Speculatively install one page (no probe: hit/miss stats are
        untouched; the fill is tagged prefetch so accuracy accounting
        sees it)."""
        self._now += 1.0
        self.cache.fill(int(page), self._now, prefetch=True)
        self.cache.drain(self._now)

    def drop(self, page: int) -> None:
        """Remove one page without stats side effects beyond the
        unused-prefetch-evicted counter — the invalidation twin of the
        physical tier dropping a stale staged copy."""
        p = int(page)
        s = self.cache.sets[p % self.cache.num_sets]
        entry = s.pop(p, None)
        if entry == _E_PF:            # staged, never demanded: wasted
            self.cache.stats.prefetch_unused_evicted += 1
        self.cache.mshr.pop(p, None)

    @property
    def stats(self):
        return self.cache.stats

    @property
    def hit_rate(self) -> float | None:
        """Demand hit rate, or None before any traffic (keeps
        ``json.dumps(metrics, allow_nan=False)`` valid on smoke runs)."""
        s = self.cache.stats
        tot = s.hits + s.misses
        return s.hits / tot if tot else None

    @property
    def accuracy(self) -> float | None:
        """Fraction of staged pages demanded before eviction/drop —
        the paper's prediction-accuracy axis.  None before staging."""
        s = self.cache.stats
        return s.prefetch_used / s.prefetch_fills if s.prefetch_fills \
            else None

    @property
    def coverage(self) -> float | None:
        """Fraction of demand touches served by the hot set — the
        coverage axis (equals :attr:`hit_rate` for a pure-speculative
        tier, where misses never install).  None before traffic."""
        return self.hit_rate


class ShardedPageCache:
    """Per-shard NSB hot-set models for tensor-parallel serving.

    Under TP the paper's near-storage buffer is a *per-NPU* structure:
    each model shard holds its slice of the KV pool and its own NSB, and
    only sees the page selections its local KV heads produce.  This
    wrapper keeps one :class:`PageCache` per shard, keyed by the shared
    *global* physical page ids (the page-id space is never sharded), so
    per-shard hit rates and the cross-shard roll-up stay directly
    comparable with the single-shard accounting.
    """

    def __init__(self, n_shards: int, capacity_pages: int) -> None:
        if n_shards < 1:
            raise ValueError(f"need >= 1 shard, got {n_shards}")
        self.caches = [PageCache(capacity_pages) for _ in range(n_shards)]

    @property
    def n_shards(self) -> int:
        return len(self.caches)

    def touch(self, page: int, shard: int, install: bool = True) -> bool:
        """Access one page id on one shard's NSB; True on a hit.
        ``install=False`` follows :meth:`PageCache.touch`."""
        return self.caches[shard].touch(page, install=install)

    def stage(self, page: int) -> None:
        """Speculatively install on *every* shard: the page-id axis is
        never sharded, so one staging copy lands each shard's KV-head
        slice of the page — every shard's NSB gains the entry."""
        for c in self.caches:
            c.stage(page)

    def drop(self, page: int) -> None:
        """Invalidate a staged page on every shard."""
        for c in self.caches:
            c.drop(page)

    def hit_rates(self) -> list:
        """Per-shard NSB hit rates, indexed by shard."""
        return [c.hit_rate for c in self.caches]

    def rollup(self) -> dict:
        """Aggregate view across shards: summed hits/misses plus the
        per-shard rates (the serve ``metrics()`` roll-up)."""
        hits = sum(c.stats.hits for c in self.caches)
        misses = sum(c.stats.misses for c in self.caches)
        fills = sum(c.stats.prefetch_fills for c in self.caches)
        used = sum(c.stats.prefetch_used for c in self.caches)
        tot = hits + misses
        return {
            "hits": hits,
            "misses": misses,
            "hit_rate": hits / tot if tot else None,
            "accuracy": used / fills if fills else None,
            "coverage": hits / tot if tot else None,
            "per_shard": self.hit_rates(),
        }


def nsb_shard_rollup(stream: PageStream, nsb_pages: int,
                     n_shards: int | None = None) -> dict:
    """Replay a shard-tagged stream through per-shard NSB models.

    Each recorded event is routed to its shard's :class:`PageCache`
    (untagged events, ``shard == -1``, route to shard 0 — the
    single-shard case), touching each distinct page id in the event
    once.  Returns the :meth:`ShardedPageCache.rollup` dict: what the
    NSB hit rate *would have been* per shard for the captured traffic —
    the offline twin of the engine's live per-shard accounting.
    """
    if n_shards is None:
        n_shards = max([s for s in stream.shards if s >= 0], default=0) + 1
    spc = ShardedPageCache(n_shards, nsb_pages)
    for ev, sh in zip(stream.events, stream.shards):
        for p in dict.fromkeys(int(x) for x in ev):
            spc.touch(p, max(sh, 0))
    return spc.rollup()
