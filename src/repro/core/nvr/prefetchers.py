"""Prefetcher models: stream [35], IMP [36], DVR [11][20], and NVR (ours).

Each prefetcher observes exactly what its hardware mechanism could observe:

* ``StreamPrefetcher`` — per-PC reference prediction table (addr, stride,
  confidence).  Covers sequential streams; mispredicts on indirect PCs and
  wastes bandwidth (the paper's "stream prefetchers occasionally introduce
  performance penalties").
* ``IMP`` — learns the indirect mapping ``addr = base + (idx << shift)`` per
  index-PC, then, when an index vector load completes, prefetches the
  *current batch*'s gather targets.  One-batch-ahead only: it cannot
  dereference future index values (no runahead), so latency hiding is
  partial and deep/dynamic chains (MK hash probes) are not covered.
* ``DVR`` — vector runahead triggered *on a demand L2 miss*: speculatively
  executes the dependency chain ahead (it can dereference future indices),
  vectorised 16-wide, up to a runahead window.  Boundary-blind: at sparse
  (dynamic) loop boundaries its fixed-trip-count assumption mispredicts,
  producing junk prefetches and lost coverage (modelled with a
  deterministic per-bound hash).
* ``NVR`` — enters runahead when a load *executes* (not when it misses),
  snoops exact sparse boundaries (LBD) and index chains (SCD) from the NPU
  sparse unit, bundles prefetches into vector requests (VMIG) and issues
  them far ahead.  Coverage-oriented fuzzy-range loading adds a small
  deterministic over-fetch (accuracy < 100 %, coverage ≈ 100 %).
"""

from __future__ import annotations

import numpy as np

from .machine import LINE_BYTES, Hierarchy
from .trace import Compute, Trace, VLoad


def _lines(addrs: np.ndarray) -> np.ndarray:
    return np.unique(addrs // LINE_BYTES)


class Prefetcher:
    name = "none"
    mshr_cap = 10 ** 9  # max prefetch lines in flight (hardware MSHR bound)

    def __init__(self) -> None:
        self.issued_lines = 0

    def _issue(self, hier: Hierarchy, line: int, now: float,
               into_nsb: bool = False) -> bool:
        if len(hier.l2.mshr) >= self.mshr_cap:
            return False
        self.issued_lines += 1
        hier.prefetch(int(line), now, into_nsb=into_nsb)
        return True

    def on_vload(self, i: int, op: VLoad, trace: Trace, now: float,
                 hier: Hierarchy) -> None:  # pragma: no cover - interface
        pass

    def on_miss(self, i: int, op: VLoad, trace: Trace, now: float,
                hier: Hierarchy) -> None:  # pragma: no cover - interface
        pass


class StreamPrefetcher(Prefetcher):
    name = "stream"

    def __init__(self, depth: int = 4) -> None:
        super().__init__()
        self.depth = depth
        self.table: dict[int, tuple[int, int, int]] = {}  # pc -> (last, stride, conf)

    def on_vload(self, i, op, trace, now, hier) -> None:
        a0 = int(op.addrs[0])
        span = int(op.addrs[-1]) - a0 + LINE_BYTES
        last, stride, conf = self.table.get(op.pc, (a0, 0, 0))
        new_stride = a0 - last
        if new_stride == stride and stride != 0:
            conf = min(conf + 1, 3)
        else:
            conf = 0
        self.table[op.pc] = (a0, new_stride, conf)
        if conf >= 2:
            for k in range(1, self.depth + 1):
                base = a0 + k * new_stride
                for ln in range((base // LINE_BYTES),
                                (base + span) // LINE_BYTES + 1):
                    self._issue(hier, ln, now)


class IMP(Prefetcher):
    name = "imp"
    mshr_cap = 64

    def __init__(self, learn_after: int = 2, lookahead_ops: int = 40,
                 max_chains: int = 2) -> None:
        super().__init__()
        self.learn_after = learn_after
        self.lookahead_ops = lookahead_ops
        self.max_chains = max_chains  # IPT capacity per index stream
        self.observed: dict[int, int] = {}     # idx_pc -> #observations
        self.chains: dict[int, list[int]] = {}  # idx_pc -> learned gather PCs
        self.stream = StreamPrefetcher(depth=2)

    def on_vload(self, i, op, trace, now, hier) -> None:
        # stream component covers the index/weight streams themselves
        self.stream.issued_lines = self.issued_lines
        self.stream.on_vload(i, op, trace, now, hier)
        self.issued_lines = self.stream.issued_lines
        if op.kind == "indirect":
            self.observed[op.idx_pc] = self.observed.get(op.idx_pc, 0) + 1
            learned = self.chains.setdefault(op.idx_pc, [])
            # limited pattern-table capacity: only the first ``max_chains``
            # (idx_pc -> gather_pc) mappings are captured — deep/multi-slice
            # chains exceed the IPT (the paper's §II-C criticism)
            if op.pc not in learned and len(learned) < self.max_chains:
                learned.append(op.pc)
            return
        # an index stream load completed: prefetch this batch's gather
        # targets (the values just became architecturally visible)
        pc = op.pc
        if self.observed.get(pc, 0) < self.learn_after:
            return
        learned = self.chains.get(pc, [])
        bound = op.bound_id
        for j in range(i + 1, min(len(trace.ops), i + 1 + self.lookahead_ops)):
            nxt = trace.ops[j]
            if isinstance(nxt, Compute):
                continue
            if nxt.bound_id != bound:
                break  # IMP has no loop-boundary knowledge beyond the batch
            if nxt.kind == "indirect" and nxt.idx_pc == pc and nxt.pc in learned:
                for ln in _lines(nxt.addrs):
                    self._issue(hier, ln, now)


class DVR(Prefetcher):
    name = "dvr"
    mshr_cap = 128

    def __init__(self, window: int = 48, issue_width: int = 16) -> None:
        super().__init__()
        self.window = window
        self.issue_width = issue_width

    @staticmethod
    def _bound_ok(op: VLoad) -> bool:
        # deterministic boundary-speculation outcome: ~72 % of cross-bound
        # chains survive the fixed-trip-count assumption
        return (op.bound_id * 2654435761 + op.pc) % 100 < 72

    def on_miss(self, i, op, trace, now, hier) -> None:
        cur = op.bound_id
        seen = 0
        t = now
        for j in range(i + 1, len(trace.ops)):
            if seen >= self.window:
                break
            nxt = trace.ops[j]
            if isinstance(nxt, Compute):
                continue
            seen += 1
            # runahead issue rate: issue_width lines per cycle group
            t += 1.0 / self.issue_width
            if nxt.bound_id == cur or self._bound_ok(nxt):
                for ln in _lines(nxt.addrs):
                    self._issue(hier, ln, t)
            else:
                # boundary mispredict: junk prefetch past the row end
                junk = int(nxt.addrs[-1] // LINE_BYTES) + 4
                for k in range(min(4, len(nxt.addrs))):
                    self._issue(hier, junk + k, t)


class NVR(Prefetcher):
    """NPU Vector Runahead: SD + SCD + LBD + VMIG (+ optional NSB fill)."""

    name = "nvr"
    mshr_cap = 256

    def __init__(self, depth: int = 96, fuzzy_every: int = 8,
                 fill_nsb: bool = False, near_depth: int = 12,
                 scd: bool = True, lbd: bool = True,
                 vmig: bool = True) -> None:
        """Component flags support the ablation study
        (benchmarks/paper_figs.py::ablation_nvr):
          scd=False  — no Sparse Chain Detector: indirect targets cannot
                       be computed ahead; only stream PCs prefetch.
          lbd=False  — boundary-blind: cross-bound chains mispredict like
                       DVR's fixed-trip-count assumption.
          vmig=False — scalar issue (1 line/cycle) instead of 16-wide
                       vectorised micro-instruction bundles.
        """
        super().__init__()
        self.depth = depth              # far runahead window, in vector loads
        self.near_depth = near_depth    # near window staged into the NSB
        self.fuzzy_every = fuzzy_every  # fuzzy-range over-fetch granularity
        self.fill_nsb = fill_nsb
        self.scd = scd
        self.lbd = lbd
        self.vmig = vmig
        self._covered_until = -1
        self._near_until = -1
        self._fuzzy_ctr = 0

    def on_vload(self, i, op, trace, now, hier) -> None:
        # runahead entered when a load executes in the ROB (Q&A1): extend
        # coverage to [i, i+depth] — bounds are exact via LBD snooping.
        start = max(i + 1, self._covered_until + 1)
        end = min(len(trace.ops), i + 1 + self.depth)
        t = now
        cur_bound = op.bound_id
        for j in range(start, end):
            nxt = trace.ops[j]
            if isinstance(nxt, Compute):
                self._covered_until = j
                continue
            if not self.scd and nxt.kind == "indirect":
                self._covered_until = j   # chain unresolvable without SCD
                continue
            lines = _lines(nxt.addrs)
            if len(hier.l2.mshr) + len(lines) > self.mshr_cap:
                break  # MSHR-file full: resume next trigger (non-blocking)
            t += (1.0 / 16.0) if self.vmig else float(len(lines))
            if not self.lbd and nxt.bound_id != cur_bound \
                    and not DVR._bound_ok(nxt):
                # boundary-blind: mispredicted chain past the row end
                junk = int(nxt.addrs[-1] // LINE_BYTES) + 4
                for kk in range(min(4, len(lines))):
                    self._issue(hier, junk + kk, t)
                self._covered_until = j
                continue
            for ln in lines:
                self._issue(hier, ln, t)
            if nxt.kind == "indirect":
                # coverage-oriented fuzzy range loading: deterministic
                # trailing-line over-fetch every ``fuzzy_every`` rows
                # (fuzzy_every=0 disables — ablation knob)
                self._fuzzy_ctr += 1
                if self.fuzzy_every and \
                        self._fuzzy_ctr % self.fuzzy_every == 0:
                    self._issue(hier, int(lines[-1]) + 1, t)
            self._covered_until = j
        if not self.fill_nsb:
            return
        # near window: stage imminently-needed indirect lines from L2 (or
        # the in-flight far prefetch) into the NSB — this is what cuts
        # NPU-to-L2 latency during actual load execution (paper §IV-G)
        nstart = max(i + 1, self._near_until + 1)
        nend = min(len(trace.ops), i + 1 + self.near_depth)
        for j in range(nstart, nend):
            nxt = trace.ops[j]
            self._near_until = j
            if isinstance(nxt, Compute) or nxt.kind != "indirect":
                continue
            for ln in _lines(nxt.addrs):
                self._issue(hier, ln, now, into_nsb=True)


PREFETCHERS = {
    "stream": StreamPrefetcher,
    "imp": IMP,
    "dvr": DVR,
    "nvr": NVR,
}
