"""Prefetcher models: stream [35], IMP [36], DVR [11][20], and NVR (ours).

Each prefetcher observes exactly what its hardware mechanism could observe:

* ``StreamPrefetcher`` — per-PC reference prediction table (addr, stride,
  confidence).  Covers sequential streams; mispredicts on indirect PCs and
  wastes bandwidth (the paper's "stream prefetchers occasionally introduce
  performance penalties").
* ``IMP`` — learns the indirect mapping ``addr = base + (idx << shift)`` per
  index-PC, then, when an index vector load completes, prefetches the
  *current batch*'s gather targets.  One-batch-ahead only: it cannot
  dereference future index values (no runahead), so latency hiding is
  partial and deep/dynamic chains (MK hash probes) are not covered.
* ``DVR`` — vector runahead triggered *on a demand L2 miss*: speculatively
  executes the dependency chain ahead (it can dereference future indices),
  vectorised 16-wide, up to a runahead window.  Boundary-blind: at sparse
  (dynamic) loop boundaries its fixed-trip-count assumption mispredicts,
  producing junk prefetches and lost coverage (modelled with a
  deterministic per-bound hash).
* ``NVR`` — enters runahead when a load *executes* (not when it misses),
  snoops exact sparse boundaries (LBD) and index chains (SCD) from the NPU
  sparse unit, bundles prefetches into vector requests (VMIG) and issues
  them far ahead.  Coverage-oriented fuzzy-range loading adds a small
  deterministic over-fetch (accuracy < 100 %, coverage ≈ 100 %).

Prefetchers subscribe to engine events (``on_vload`` fires when a vector
load executes, ``on_miss`` when it demand-misses in L2) and read the
compiled :class:`~.engine.vectrace.VecTrace` — per-op unique-line arrays
are precomputed, so runahead scans never touch numpy.  New prefetchers
self-register via :func:`~.engine.registry.register_prefetcher`.
"""

from __future__ import annotations

from types import MappingProxyType

from .engine.registry import _REGISTRY, register_prefetcher
from .engine.vectrace import KIND_COMPUTE, KIND_INDIRECT, VecTrace
from .machine import LINE_BYTES, Hierarchy


def bound_ok(bound_id: int, pc: int) -> bool:
    """Deterministic boundary-speculation outcome for boundary-blind
    runahead: ~72 % of cross-bound chains survive the fixed-trip-count
    assumption."""
    return (bound_id * 2654435761 + pc) % 100 < 72


class Prefetcher:
    name = "none"
    mshr_cap = 10 ** 9  # max prefetch lines in flight (hardware MSHR bound)

    def __init__(self) -> None:
        self.issued_lines = 0

    def _issue(self, hier: Hierarchy, line: int, now: float,
               into_nsb: bool = False) -> bool:
        if len(hier.l2.mshr) >= self.mshr_cap:
            return False
        self.issued_lines += 1
        hier.prefetch(int(line), now, into_nsb=into_nsb)
        return True

    def on_vload(self, i: int, vt: VecTrace, now: float,
                 hier: Hierarchy) -> None:  # pragma: no cover - interface
        pass

    def on_miss(self, i: int, vt: VecTrace, now: float,
                hier: Hierarchy) -> None:  # pragma: no cover - interface
        pass


@register_prefetcher("stream")
class StreamPrefetcher(Prefetcher):

    def __init__(self, depth: int = 4) -> None:
        super().__init__()
        self.depth = depth
        self.table: dict[int, tuple[int, int, int]] = {}  # pc -> (last, stride, conf)

    def on_vload(self, i, vt, now, hier) -> None:
        a0 = vt.addr_first[i]
        span = vt.addr_last[i] - a0 + LINE_BYTES
        pc = vt.pc[i]
        last, stride, conf = self.table.get(pc, (a0, 0, 0))
        new_stride = a0 - last
        if new_stride == stride and stride != 0:
            conf = min(conf + 1, 3)
        else:
            conf = 0
        self.table[pc] = (a0, new_stride, conf)
        if conf >= 2:
            cap = self.mshr_cap
            for k in range(1, self.depth + 1):
                base = a0 + k * new_stride
                self.issued_lines += hier.prefetch_lines(
                    range(base // LINE_BYTES,
                          (base + span) // LINE_BYTES + 1), now, cap)


@register_prefetcher("imp")
class IMP(Prefetcher):
    mshr_cap = 64

    def __init__(self, learn_after: int = 2, lookahead_ops: int = 40,
                 max_chains: int = 2) -> None:
        super().__init__()
        self.learn_after = learn_after
        self.lookahead_ops = lookahead_ops
        self.max_chains = max_chains  # IPT capacity per index stream
        self.observed: dict[int, int] = {}     # idx_pc -> #observations
        self.chains: dict[int, list[int]] = {}  # idx_pc -> learned gather PCs
        self.stream = StreamPrefetcher(depth=2)

    def on_vload(self, i, vt, now, hier) -> None:
        # stream component covers the index/weight streams themselves
        self.stream.issued_lines = self.issued_lines
        self.stream.on_vload(i, vt, now, hier)
        self.issued_lines = self.stream.issued_lines
        kind = vt.kind
        pc = vt.pc[i]
        if kind[i] == KIND_INDIRECT:
            ipc = vt.idx_pc[i]
            self.observed[ipc] = self.observed.get(ipc, 0) + 1
            learned = self.chains.setdefault(ipc, [])
            # limited pattern-table capacity: only the first ``max_chains``
            # (idx_pc -> gather_pc) mappings are captured — deep/multi-slice
            # chains exceed the IPT (the paper's §II-C criticism)
            if pc not in learned and len(learned) < self.max_chains:
                learned.append(pc)
            return
        # an index stream load completed: prefetch this batch's gather
        # targets (the values just became architecturally visible)
        if self.observed.get(pc, 0) < self.learn_after:
            return
        learned = self.chains.get(pc, [])
        bound = vt.bound[i]
        for j in range(i + 1, min(vt.n_ops, i + 1 + self.lookahead_ops)):
            kj = kind[j]
            if kj == KIND_COMPUTE:
                continue
            if vt.bound[j] != bound:
                break  # IMP has no loop-boundary knowledge beyond the batch
            if kj == KIND_INDIRECT and vt.idx_pc[j] == pc \
                    and vt.pc[j] in learned:
                self.issued_lines += hier.prefetch_lines(
                    vt.lines[j], now, self.mshr_cap)


@register_prefetcher("dvr")
class DVR(Prefetcher):
    mshr_cap = 128

    def __init__(self, window: int = 48, issue_width: int = 16) -> None:
        super().__init__()
        self.window = window
        self.issue_width = issue_width

    def on_miss(self, i, vt, now, hier) -> None:
        cur = vt.bound[i]
        seen = 0
        t = now
        kind, bound, lines = vt.kind, vt.bound, vt.lines
        step = 1.0 / self.issue_width
        for j in range(i + 1, vt.n_ops):
            if seen >= self.window:
                break
            if kind[j] == KIND_COMPUTE:
                continue
            seen += 1
            # runahead issue rate: issue_width lines per cycle group
            t += step
            if bound[j] == cur or bound_ok(bound[j], vt.pc[j]):
                self.issued_lines += hier.prefetch_lines(
                    lines[j], t, self.mshr_cap)
            else:
                # boundary mispredict: junk prefetch past the row end
                junk = vt.addr_last[j] // LINE_BYTES + 4
                self.issued_lines += hier.prefetch_lines(
                    range(junk, junk + min(4, vt.n_addrs[j])), t,
                    self.mshr_cap)


@register_prefetcher("nvr")
class NVR(Prefetcher):
    """NPU Vector Runahead: SD + SCD + LBD + VMIG (+ optional NSB fill)."""

    mshr_cap = 256

    def __init__(self, depth: int = 96, fuzzy_every: int = 8,
                 fill_nsb: bool = False, near_depth: int = 12,
                 scd: bool = True, lbd: bool = True,
                 vmig: bool = True) -> None:
        """Component flags support the ablation study
        (benchmarks/paper_figs.py::ablation_nvr):
          scd=False  — no Sparse Chain Detector: indirect targets cannot
                       be computed ahead; only stream PCs prefetch.
          lbd=False  — boundary-blind: cross-bound chains mispredict like
                       DVR's fixed-trip-count assumption.
          vmig=False — scalar issue (1 line/cycle) instead of 16-wide
                       vectorised micro-instruction bundles.
        """
        super().__init__()
        self.depth = depth              # far runahead window, in vector loads
        self.near_depth = near_depth    # near window staged into the NSB
        self.fuzzy_every = fuzzy_every  # fuzzy-range over-fetch granularity
        self.fill_nsb = fill_nsb
        self.scd = scd
        self.lbd = lbd
        self.vmig = vmig
        self._covered_until = -1
        self._near_until = -1
        self._fuzzy_ctr = 0

    def on_vload(self, i, vt, now, hier) -> None:
        # runahead entered when a load executes in the ROB (Q&A1): extend
        # coverage to [i, i+depth] — bounds are exact via LBD snooping.
        start = max(i + 1, self._covered_until + 1)
        end = min(vt.n_ops, i + 1 + self.depth)
        t = now
        cur_bound = vt.bound[i]
        kind, bound, all_lines = vt.kind, vt.bound, vt.lines
        l2_mshr = hier.l2.mshr
        for j in range(start, end):
            kj = kind[j]
            if kj == KIND_COMPUTE:
                self._covered_until = j
                continue
            if not self.scd and kj == KIND_INDIRECT:
                self._covered_until = j   # chain unresolvable without SCD
                continue
            lines = all_lines[j]
            if len(l2_mshr) + len(lines) > self.mshr_cap:
                break  # MSHR-file full: resume next trigger (non-blocking)
            t += (1.0 / 16.0) if self.vmig else float(len(lines))
            if not self.lbd and bound[j] != cur_bound \
                    and not bound_ok(bound[j], vt.pc[j]):
                # boundary-blind: mispredicted chain past the row end
                junk = vt.addr_last[j] // LINE_BYTES + 4
                self.issued_lines += hier.prefetch_lines(
                    range(junk, junk + min(4, len(lines))), t,
                    self.mshr_cap)
                self._covered_until = j
                continue
            self.issued_lines += hier.prefetch_lines(lines, t,
                                                     self.mshr_cap)
            if kj == KIND_INDIRECT:
                # coverage-oriented fuzzy range loading: deterministic
                # trailing-line over-fetch every ``fuzzy_every`` rows
                # (fuzzy_every=0 disables — ablation knob)
                self._fuzzy_ctr += 1
                if self.fuzzy_every and \
                        self._fuzzy_ctr % self.fuzzy_every == 0:
                    self.issued_lines += hier.prefetch_lines(
                        (lines[-1] + 1,), t, self.mshr_cap)
            self._covered_until = j
        if not self.fill_nsb:
            return
        # near window: stage imminently-needed indirect lines from L2 (or
        # the in-flight far prefetch) into the NSB — this is what cuts
        # NPU-to-L2 latency during actual load execution (paper §IV-G)
        nstart = max(i + 1, self._near_until + 1)
        nend = min(vt.n_ops, i + 1 + self.near_depth)
        for j in range(nstart, nend):
            self._near_until = j
            if kind[j] != KIND_INDIRECT:
                continue
            self.issued_lines += hier.prefetch_lines(
                all_lines[j], now, self.mshr_cap, into_nsb=True)


# live, read-only view of the registry kept for backwards compatibility
# with the seed's hardcoded ``PREFETCHERS`` dict
PREFETCHERS = MappingProxyType(_REGISTRY)
