"""Trace representation for the NVR simulator.

A trace is the NPU-visible instruction stream of one sparse kernel region:
interleaved vector loads (16-lane, matching the paper's N=16 parallel width)
and compute tiles.  Indirect loads carry *chain metadata* — the information a
hardware snooper would extract from the NPU's sparse-unit registers (base
address, index values, row boundaries).  Prefetchers are given access to
exactly the fields their mechanism could observe in hardware:

  * stream  prefetcher: past addresses per PC only
  * IMP     : index-load values after completion + learned (base, shift)
  * DVR     : lookahead within the current bound (boundary-blind runahead)
  * NVR     : lookahead across bounds with exact boundaries (snooped)
"""

from __future__ import annotations

from dataclasses import dataclass, field

import numpy as np

VECTOR_LANES = 16


@dataclass
class VLoad:
    pc: int
    addrs: np.ndarray            # byte addresses, one per active lane
    kind: str                    # "stream" | "indirect"
    bound_id: int = 0            # row / expert / query id (loop instance)
    idx_pc: int = -1             # PC of the stream load producing the indices
    idx_values: np.ndarray | None = None  # indices backing indirect addrs
    base: int = 0                # base address of the indirectly-indexed array
    elem_shift: int = 0          # log2(bytes per indexed element row step)


@dataclass
class Compute:
    cycles: float


Op = VLoad | Compute


@dataclass
class Trace:
    """Instruction stream + region map (for NSB indirect-line filtering)."""

    ops: list
    name: str = ""
    indirect_regions: list = field(default_factory=list)  # (lo, hi) bytes
    dense_compute_scale: float = 1.0  # dense/sparse compute ratio (Fig. 5)
    meta: dict = field(default_factory=dict)

    def is_indirect_addr(self, addr: int) -> bool:
        for lo, hi in self.indirect_regions:
            if lo <= addr < hi:
                return True
        return False

    @property
    def n_vloads(self) -> int:
        return sum(1 for o in self.ops if isinstance(o, VLoad))

    def total_compute(self) -> float:
        return sum(o.cycles for o in self.ops if isinstance(o, Compute))


class TraceBuilder:
    """Helper that lays out arrays in a flat byte address space and emits
    (stream index load -> indirect gather -> compute) bundles the way the
    paper's SpMM listing does (Fig. 2)."""

    def __init__(self, name: str) -> None:
        self.name = name
        self.ops: list = []
        self._cursor = 0x1000_0000
        self.regions: dict[str, tuple[int, int]] = {}
        self.indirect_regions: list = []
        self._bound = 0

    def alloc(self, name: str, nbytes: int, indirect: bool = False) -> int:
        base = self._cursor
        self._cursor += (nbytes + 4095) // 4096 * 4096 + 4096
        self.regions[name] = (base, base + nbytes)
        if indirect:
            self.indirect_regions.append((base, base + nbytes))
        return base

    def new_bound(self) -> int:
        self._bound += 1
        return self._bound

    def stream_load(self, pc: int, base: int, offsets: np.ndarray,
                    elem_bytes: int, bound: int | None = None) -> None:
        addrs = base + offsets.astype(np.int64) * elem_bytes
        self.ops.append(VLoad(pc=pc, addrs=addrs, kind="stream",
                              bound_id=self._bound if bound is None else bound))

    def indirect_load(self, pc: int, base: int, idx: np.ndarray,
                      elem_shift: int, idx_pc: int,
                      bound: int | None = None) -> None:
        addrs = base + (idx.astype(np.int64) << elem_shift)
        self.ops.append(VLoad(
            pc=pc, addrs=addrs, kind="indirect",
            bound_id=self._bound if bound is None else bound,
            idx_pc=idx_pc, idx_values=idx.astype(np.int64), base=base,
            elem_shift=elem_shift))

    def compute(self, cycles: float) -> None:
        self.ops.append(Compute(cycles))

    def build(self, dense_compute_scale: float = 1.0, **meta) -> Trace:
        return Trace(ops=self.ops, name=self.name,
                     indirect_regions=self.indirect_regions,
                     dense_compute_scale=dense_compute_scale, meta=meta)


def chunk_lanes(values: np.ndarray, lanes: int = VECTOR_LANES):
    """Split an index vector into <=lanes-wide vector-instruction groups."""
    for i in range(0, len(values), lanes):
        yield values[i:i + lanes]
