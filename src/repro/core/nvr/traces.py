"""Workload trace generators — Table II of the paper.

Each generator emits the NPU-visible memory instruction stream of the
workload's sparse inner loops (linear-layer memory access patterns, as the
paper extracts them).  All are parameterised by ``dtype_bytes`` (INT8=1,
FP16=2, INT32=4 — Fig. 5) and a ``scale`` knob for quick tests.

| short | domain             | dominant pattern modelled                      |
|-------|--------------------|------------------------------------------------|
| DS    | LLM (KV sparsity)  | per-step TopK KV-row gather, drifting hot set  |
| GAT   | GNN                | CSR neighbor row gather, two passes (reuse)    |
| GCN   | GNN                | CSR neighbor row gather, power-law hubs        |
| GSABT | sparse attention   | block-sparse key-block gather (long strides)   |
| H2O   | LLM (KV sparsity)  | heavy-hitter KV gather, stable hot set         |
| MK    | point cloud        | 27-neighborhood hash probes (element gather)   |
| SCN   | point cloud        | rulebook offset-grouped gather (quasi-sorted)  |
| ST    | MoE                | expert-blocked streaming (block-local)         |
"""

from __future__ import annotations

import numpy as np

from .trace import Trace, TraceBuilder, chunk_lanes

LINE = 64
MAC_RATE = 128.0  # effective MACs/cycle of the sparse unit (16x16 array, 50% util)

# PCs (arbitrary but stable identifiers for prefetcher tables)
PC_ROWPTR, PC_IDX, PC_GATHER, PC_W, PC_GATHER2 = 0x100, 0x104, 0x108, 0x10C, 0x110


def _row_gather(tb: TraceBuilder, base: int, rows: np.ndarray, row_bytes: int,
                idx_pc: int, pc: int = PC_GATHER, bound: int | None = None) -> None:
    """Gather ``rows`` (16-lane groups); each row spans row_bytes -> emit one
    vector load per 64B slice so long rows create densely packed strides."""
    shift = int(np.log2(row_bytes)) if row_bytes & (row_bytes - 1) == 0 else 0
    n_slices = max(1, row_bytes // LINE)
    for lanes in chunk_lanes(rows):
        for j in range(n_slices):
            if shift:
                tb.indirect_load(pc + j, base + j * LINE, lanes, shift,
                                 idx_pc=idx_pc, bound=bound)
            else:
                addrs_idx = lanes * (row_bytes // max(1, LINE))
                tb.indirect_load(pc + j, base + j * LINE, addrs_idx, 6,
                                 idx_pc=idx_pc, bound=bound)


def _stream_idx(tb: TraceBuilder, base: int, start: int, vals: np.ndarray,
                pc: int = PC_IDX) -> None:
    offs = np.arange(start, start + len(vals), dtype=np.int64)
    tb.stream_load(pc, base, offs, 4)


# ---------------------------------------------------------------------------
# LLM KV-cache sparsity: Double Sparsity (DS) and H2O
# ---------------------------------------------------------------------------

def _kv_topk(name: str, dtype_bytes: int, scale: float, persistence: float,
             seed: int, topk: int = 64, n_ctx: int = 4096,
             heads: int = 4, steps: int = 24) -> Trace:
    rng = np.random.default_rng(seed)
    steps = max(2, int(steps * scale))
    head_dim = 128
    row_bytes = head_dim * dtype_bytes
    tb = TraceBuilder(name)
    kv = [tb.alloc(f"kv_h{h}", n_ctx * row_bytes, indirect=True)
          for h in range(heads)]
    idxb = tb.alloc("topk_idx", steps * heads * topk * 4)
    hot = [rng.choice(n_ctx, size=topk, replace=False) for _ in range(heads)]
    pos = 0
    for s in range(steps):
        for h in range(heads):
            keep = rng.random(topk) < persistence
            idx = hot[h].copy()
            idx[~keep] = rng.choice(n_ctx, size=int((~keep).sum()))
            hot[h] = idx
            tb.new_bound()
            _stream_idx(tb, idxb, pos, idx)
            pos += topk
            _row_gather(tb, kv[h], np.sort(idx), row_bytes, PC_IDX)
            # attention compute: topk * head_dim MACs @256/cyc
            tb.compute(topk * head_dim / MAC_RATE)
    dense_bytes = steps * heads * n_ctx * row_bytes  # full KV scan per step
    return tb.build(dense_compute_scale=n_ctx / topk, dense_bytes=dense_bytes)


def gen_ds(dtype_bytes: int = 2, scale: float = 1.0, seed: int = 0) -> Trace:
    return _kv_topk("DS", dtype_bytes, scale, persistence=0.70, seed=seed)


def gen_h2o(dtype_bytes: int = 2, scale: float = 1.0, seed: int = 1) -> Trace:
    return _kv_topk("H2O", dtype_bytes, scale, persistence=0.88, seed=seed,
                    topk=48)


# ---------------------------------------------------------------------------
# GNNs: GCN / GAT — CSR adjacency feature gather
# ---------------------------------------------------------------------------

def _powerlaw_graph(rng, n: int, avg_deg: int):
    degs = np.clip(rng.zipf(1.7, size=n), 2, 8 * avg_deg)
    degs = (degs * (avg_deg / degs.mean())).astype(np.int64).clip(1, 8 * avg_deg)
    hubs = rng.choice(n, size=max(4, n // 64), replace=False)
    rows = []
    for d in degs:
        k_hub = int(d * 0.3)
        nb = np.concatenate([rng.choice(hubs, size=k_hub),
                             rng.integers(0, n, size=int(d) - k_hub)])
        rows.append(np.sort(nb))
    return rows


def _gnn(name: str, dtype_bytes: int, scale: float, seed: int,
         two_pass: bool) -> Trace:
    rng = np.random.default_rng(seed)
    n = max(256, int(3072 * scale))
    d_feat = 64
    row_bytes = d_feat * dtype_bytes
    rows = _powerlaw_graph(rng, n, avg_deg=8)
    n_rows = max(16, int(220 * scale))
    tb = TraceBuilder(name)
    feat = tb.alloc("features", n * row_bytes, indirect=True)
    colb = tb.alloc("col_indices", sum(len(r) for r in rows) * 4)
    rpb = tb.alloc("rowptr", (n + 1) * 4)
    pos = 0
    order = rng.permutation(n)[:n_rows]
    for r in order:
        nb = rows[r]
        tb.new_bound()
        tb.stream_load(PC_ROWPTR, rpb, np.array([r, r + 1]), 4)
        _stream_idx(tb, colb, pos, nb)
        pos += len(nb)
        _row_gather(tb, feat, nb, row_bytes, PC_IDX)
        tb.compute(len(nb) * d_feat / MAC_RATE)
        if two_pass:  # GAT: edge-softmax then weighted aggregate (reuse)
            _row_gather(tb, feat, nb, row_bytes, PC_IDX, pc=PC_GATHER2)
            tb.compute(len(nb) * d_feat / MAC_RATE)
    dense_bytes = n_rows * n * row_bytes / 8  # dense adjacency row sweep
    return tb.build(dense_compute_scale=n / 8 / 8, dense_bytes=dense_bytes)


def gen_gcn(dtype_bytes: int = 2, scale: float = 1.0, seed: int = 2) -> Trace:
    return _gnn("GCN", dtype_bytes, scale, seed, two_pass=False)


def gen_gat(dtype_bytes: int = 2, scale: float = 1.0, seed: int = 3) -> Trace:
    return _gnn("GAT", dtype_bytes, scale, seed, two_pass=True)


# ---------------------------------------------------------------------------
# GSABT — block-sparse attention: gather random key *blocks*
# ---------------------------------------------------------------------------

def gen_gsabt(dtype_bytes: int = 2, scale: float = 1.0, seed: int = 4) -> Trace:
    rng = np.random.default_rng(seed)
    n_blocks = 256
    tok_per_block, head_dim = 16, 64
    block_bytes = tok_per_block * head_dim * dtype_bytes
    n_q = max(8, int(96 * scale))
    k_sel = 8
    tb = TraceBuilder("GSABT")
    kv = tb.alloc("kv_blocks", n_blocks * block_bytes, indirect=True)
    idxb = tb.alloc("block_idx", n_q * k_sel * 4)
    pos = 0
    for q in range(n_q):
        sel = np.sort(rng.choice(n_blocks, size=k_sel, replace=False))
        tb.new_bound()
        _stream_idx(tb, idxb, pos, sel)
        pos += k_sel
        # token rows inside each selected block (sequential within block)
        tok_rows = (sel[:, None] * tok_per_block
                    + np.arange(tok_per_block)[None, :]).reshape(-1)
        _row_gather(tb, kv, tok_rows, head_dim * dtype_bytes, PC_IDX)
        tb.compute(k_sel * tok_per_block * head_dim / MAC_RATE)
    dense_bytes = n_q * n_blocks * block_bytes
    return tb.build(dense_compute_scale=n_blocks / k_sel,
                    dense_bytes=dense_bytes)


# ---------------------------------------------------------------------------
# Point cloud: MinkowskiNet (hash probes) / SparseConvNet (rulebook)
# ---------------------------------------------------------------------------

def _hash3(c: np.ndarray, size: int) -> np.ndarray:
    h = (c[..., 0] * 73856093) ^ (c[..., 1] * 19349663) ^ (c[..., 2] * 83492791)
    return (h % size).astype(np.int64)


def gen_mk(dtype_bytes: int = 2, scale: float = 1.0, seed: int = 5) -> Trace:
    rng = np.random.default_rng(seed)
    table = 1 << 17           # hash table entries (8 B each)
    n_pts = max(32, int(160 * scale))
    d_feat = 32
    tb = TraceBuilder("MK")
    ht = tb.alloc("hash_table", table * 8, indirect=True)
    feat = tb.alloc("features", table * d_feat * dtype_bytes, indirect=True)
    coords = np.cumsum(rng.integers(-1, 2, size=(n_pts, 3)), axis=0) + 512
    offs = np.stack(np.meshgrid([-1, 0, 1], [-1, 0, 1], [-1, 0, 1]),
                    -1).reshape(-1, 3)
    for p in range(n_pts):
        nb = coords[p][None, :] + offs          # 27 neighbor probes
        probes = _hash3(nb, table)
        tb.new_bound()
        _row_gather(tb, ht, probes, 8, PC_IDX, pc=PC_GATHER)
        hits = probes[rng.random(len(probes)) < 0.5]
        if len(hits):
            _row_gather(tb, feat, hits, d_feat * dtype_bytes, PC_IDX,
                        pc=PC_GATHER2)
        tb.compute(27 * d_feat / MAC_RATE)
    return tb.build(dense_compute_scale=8.0,
                    dense_bytes=n_pts * 64 * d_feat * dtype_bytes)


def gen_scn(dtype_bytes: int = 2, scale: float = 1.0, seed: int = 6) -> Trace:
    rng = np.random.default_rng(seed)
    n_vox = 1 << 14
    n_active = max(64, int(1400 * scale))
    d_feat = 32
    row_bytes = d_feat * dtype_bytes
    tb = TraceBuilder("SCN")
    feat = tb.alloc("features", n_vox * row_bytes, indirect=True)
    ruleb = tb.alloc("rulebook", 27 * n_active * 4)
    active = np.sort(rng.choice(n_vox, size=n_active, replace=False))
    pos = 0
    for off in range(9):     # offset-grouped passes over quasi-sorted lists
        m = rng.random(n_active) < 0.4
        idx = active[m] + rng.integers(-2, 3, size=int(m.sum()))
        idx = np.clip(idx, 0, n_vox - 1)
        tb.new_bound()
        _stream_idx(tb, ruleb, pos, idx)
        pos += len(idx)
        _row_gather(tb, feat, idx, row_bytes, PC_IDX)
        tb.compute(len(idx) * d_feat / MAC_RATE)
    return tb.build(dense_compute_scale=n_vox / n_active,
                    dense_bytes=9 * n_vox * row_bytes / 4)


# ---------------------------------------------------------------------------
# ST — Switch Transformer MoE: expert-blocked streaming
# ---------------------------------------------------------------------------

def gen_st(dtype_bytes: int = 2, scale: float = 1.0, seed: int = 7) -> Trace:
    rng = np.random.default_rng(seed)
    n_exp, d_model, d_ff = 8, 128, 256
    exp_bytes = d_model * d_ff * dtype_bytes
    n_groups = max(8, int(100 * scale))
    tb = TraceBuilder("ST")
    wb = tb.alloc("expert_w", n_exp * exp_bytes, indirect=True)
    route = tb.alloc("route", n_groups * 4)
    # zipf-ish routing: a few experts dominate (block-local, low miss — the
    # paper's noted exception)
    probs = np.array([0.35, 0.25, 0.15, 0.10, 0.06, 0.04, 0.03, 0.02])
    for g in range(n_groups):
        e = rng.choice(n_exp, p=probs)
        tb.new_bound()
        tb.stream_load(PC_ROWPTR, route, np.array([g]), 4)
        # stream a tile of the expert's weights: sequential rows
        n_rows_tile = 32
        start = rng.integers(0, d_ff - n_rows_tile)
        row_ids = e * d_ff + start + np.arange(n_rows_tile)
        _row_gather(tb, wb, row_ids, d_model * dtype_bytes, PC_ROWPTR)
        tb.compute(16 * d_model * n_rows_tile / MAC_RATE)  # GEMM tile: compute-rich
    return tb.build(dense_compute_scale=n_exp / 2,
                    dense_bytes=n_groups * n_exp * exp_bytes // 8)


WORKLOADS = {
    "DS": gen_ds, "GAT": gen_gat, "GCN": gen_gcn, "GSABT": gen_gsabt,
    "H2O": gen_h2o, "MK": gen_mk, "SCN": gen_scn, "ST": gen_st,
}


def make_trace(name: str, dtype_bytes: int = 2, scale: float = 1.0) -> Trace:
    return WORKLOADS[name](dtype_bytes=dtype_bytes, scale=scale)
