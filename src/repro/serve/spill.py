"""Host-resident KV spill tier: the third level of the memory hierarchy.

The serve engine's pages live in three tiers (docs/MEMORY_HIERARCHY.md):
the NSB staging tail (hot, speculative copies), the HBM demand pool
(authoritative), and — this module — a **host spill pool** that holds
whole-page snapshots of preempted requests so preemption becomes
*swap-out* instead of free-and-recompute.

:class:`HostSpillPool` owns the host-side bytes only; slot *ids* are
allocated by :class:`~.kv_allocator.KVBlockAllocator` (so the
one-tier-per-page-id invariant is checkable in one place) and the engine
performs the actual device<->host copies when it drains the allocator's
transfer queues.  One slot stores one physical page across every layer
and plane — K, V, and the fp32 page summary the TopK selection reads —
so a swap-in restores not just attention content but the *selection*
behaviour byte-for-byte.

Beyond preemption, the tier doubles as the parking lot for **idle
multi-turn sessions** (``PagedEngine(session_hold=True, idle_swap=True)``):
when a conversation turn finishes, the engine adopts the request's block
table onto a holder rid and spills it here for the think-time gap, then
restores it — same snapshot/restore path, same strict drain order — when
the follow-up turn arrives carrying the conversation history.  Because
slots snapshot K, V, *and* the selection summaries exactly (uncompressed
tier), a resumed turn's prefix attach is byte-identical to a session
that was never swapped out; the allocator's ``session_rids`` accounting
distinguishes these parked pages from live-request spills.

Storage is pinned host memory by intent: arrays are committed to the
first CPU device via ``jax.device_put`` when a non-CPU backend is
present (so transfers are real host<->HBM DMAs), and plain numpy on a
CPU-only container where the distinction does not exist.  Either way
the pool never aliases device pool buffers.

Compression (``compress=True``) runs the spilled K/V planes through
``optim.compress.quantize_int8`` vmapped to **per-page, per-layer
scales** (one scale per (slot, layer, plane)): 2-byte KV dtypes spill at
~2x fewer host bytes, at the cost of bitwise resume — parity becomes
tolerance-bounded, with the worst-case absolute error of any restored
element ``scale/2`` per plane (asserted in tests/test_spill.py).  Page
summaries are always kept exact: they are tiny (one vector per page) and
keeping them exact keeps the post-resume TopK *selection* identical even
on the int8 tier.
"""

from __future__ import annotations

import numpy as np

from ..optim import compress as _compress


def _pin_host(x):
    """Commit ``x`` to host memory.  On accelerator backends this is a
    ``jax.device_put`` onto the first CPU device (pinned host staging
    buffer); on a CPU-only jax install the array is already host bytes
    and a plain numpy view avoids a pointless copy."""
    import jax
    try:
        cpu = jax.local_devices(backend="cpu")[0]
    except RuntimeError:
        return np.asarray(x)
    if jax.default_backend() == "cpu":
        return np.asarray(x)
    return np.asarray(jax.device_put(x, cpu))


class HostSpillPool:
    """Fixed-slot host pool for spilled physical pages.

    Layout per slot (one physical page, all layers):

    * ``k``/``v``: ``[L, page, KV, D]`` in the pool dtype, or int8 with
      per-(slot, layer) scales when ``compress=True``;
    * ``s``: ``[L, KV, D]`` fp32 page summaries, always exact.

    The pool is indexed by *slot id*; the slot<->(request, logical page)
    bookkeeping lives in the allocator.  ``store``/``load`` operate on
    batches of slots so a whole swap lands in one vectorised call.
    """

    def __init__(self, n_slots: int, n_layers: int, page_tokens: int,
                 n_kv_heads: int, head_dim: int, dtype,
                 compress: bool = False) -> None:
        if n_slots < 1:
            raise ValueError(f"need >= 1 spill slot, got {n_slots}")
        self.n_slots = n_slots
        self.dtype = np.dtype(dtype)
        self.compress = bool(compress)
        shape = (n_slots, n_layers, page_tokens, n_kv_heads, head_dim)
        store_dt = np.int8 if self.compress else self.dtype
        self._k = np.zeros(shape, store_dt)
        self._v = np.zeros(shape, store_dt)
        self._s = np.zeros((n_slots, n_layers, n_kv_heads, head_dim),
                           np.float32)
        if self.compress:
            # per-page, per-layer, per-plane scales (k and v quantise
            # independently: their dynamic ranges differ per layer)
            self._scale_k = np.zeros((n_slots, n_layers), np.float32)
            self._scale_v = np.zeros((n_slots, n_layers), np.float32)

    # -- geometry ------------------------------------------------------------

    @property
    def host_bytes(self) -> int:
        """Resident host bytes of the pool (all slots, scales included)."""
        n = self._k.nbytes + self._v.nbytes + self._s.nbytes
        if self.compress:
            n += self._scale_k.nbytes + self._scale_v.nbytes
        return n

    def error_bound(self, slots) -> float:
        """Worst-case absolute dequantisation error over ``slots`` —
        half an int8 step of the largest per-page scale (0.0 when the
        pool is uncompressed: snapshots are bitwise)."""
        if not self.compress:
            return 0.0
        slots = np.asarray(list(slots), dtype=np.int64)
        if not slots.size:
            return 0.0
        return float(max(self._scale_k[slots].max(),
                         self._scale_v[slots].max()) / 2.0)

    # -- transfers -----------------------------------------------------------

    def _quantize(self, x: np.ndarray):
        """Per-(slot, layer) int8 quantisation via the shared
        ``optim.compress`` kernels (vmapped over the two leading axes so
        every page gets its own scale)."""
        import jax

        q, scale = jax.vmap(jax.vmap(_compress.quantize_int8))(
            np.asarray(x, np.float32))
        return np.asarray(q), np.asarray(scale, np.float32)

    def store(self, slots, k, v, s) -> None:
        """Write page snapshots into ``slots``.

        ``k``/``v`` are ``[n, L, page, KV, D]`` device-read bytes in the
        pool dtype, ``s`` is ``[n, L, KV, D]`` fp32; all are pinned to
        host before landing so the pool never holds device buffers."""
        slots = np.asarray(list(slots), dtype=np.int64)
        k = _pin_host(k)
        v = _pin_host(v)
        if self.compress:
            qk, sk = self._quantize(k)
            qv, sv = self._quantize(v)
            self._k[slots] = qk
            self._v[slots] = qv
            self._scale_k[slots] = sk
            self._scale_v[slots] = sv
        else:
            self._k[slots] = np.asarray(k, self.dtype)
            self._v[slots] = np.asarray(v, self.dtype)
        self._s[slots] = np.asarray(_pin_host(s), np.float32)

    def load(self, slots):
        """Read snapshots back: ``(k, v, s)`` with k/v dequantised to
        the pool dtype (bitwise-identical bytes when uncompressed)."""
        slots = np.asarray(list(slots), dtype=np.int64)
        if self.compress:
            import jax

            deq = jax.vmap(jax.vmap(_compress.dequantize_int8))
            k = np.asarray(deq(self._k[slots],
                               self._scale_k[slots])).astype(self.dtype)
            v = np.asarray(deq(self._v[slots],
                               self._scale_v[slots])).astype(self.dtype)
        else:
            k = self._k[slots]
            v = self._v[slots]
        return k, v, self._s[slots]
