"""Paged expert-weight pool: MoE expert tiles as first-class pages.

The serve engines built the full paged/NSB/runahead machinery for KV
pages (PRs 2-8) while the one workload the paper's runahead thread was
designed around — dynamic routing decisions picking which expert weight
tiles to fetch — still read dense ``[E, D, F]`` weight cubes.  This
module closes that gap: expert FFN weights become fixed row-tile pages
in a physical page-id space, resolved through per-layer block tables,
with an NSB staging tail for router-predicted hot tiles.

Layout contract
---------------

Each layer's three expert planes (gate, up, down) are stored row-major
in the FFN hidden dimension: gate/up transpose from ``[D, F]`` to
``[F, D]`` so every plane is ``F`` rows of ``D`` features, cut into
``NT = F // tile_rows`` pages of ``tile_rows`` rows.  The physical pool
is ``[n_pages + nsb_slots, tile_rows, D]``:

* page ``0`` is the reserved scratch page (all zeros) — the same NULL
  convention the KV pool uses, so fixed-shape staging gathers can pad
  with value-identical ``(0, 0)`` self-copies;
* pages ``1 .. L*E*3*NT`` are the demand region, laid out
  ``page = 1 + (((layer*E + expert)*3 + plane)*NT + tile)`` — one
  expert's tiles are contiguous, so "stage expert e" is a contiguous
  page range (the paper's coverage-oriented fuzzy fetch at expert
  granularity);
* the tail ``[n_pages, n_pages + nsb_slots)`` is the NSB hot tier:
  byte-exact staged copies addressed through a
  :class:`~repro.serve.runahead.NSBHotTier` hot-map, exactly as the KV
  pools' staging tail.  Expert weights are read-only for the whole
  serve lifetime, so — unlike KV pages — a staged expert tile can
  never go stale and the tier never needs invalidation.

The block table ``[L, E, 3, NT]`` maps (layer, expert, plane, tile) to
physical page id.  Because the layout is static the table is an
affine function of its indices — but the serve path still resolves
through it (``bt[layer][eids]``), because the *indirection* is the
point: the demand gather and the runahead predictor meet in one
physical page-id space, the same currency trick the KV side uses.

Bitwise parity contract
-----------------------

:func:`dense_moe_ffn` (weights gathered from a dense per-layer
``[E, 3, NT, tile, D]`` materialisation) and :func:`paged_moe_ffn`
(weights gathered from the pool through the block table, hot-map remap
included) share :func:`route` and :func:`_combine` — the gathers
differ, but gathers are pure copies and the math downstream runs on
identically-shaped, bitwise-identical operands, so tokens and logits
are bitwise-invariant across dense / paged / paged+runahead
(``moe_serve_bench`` asserts this in-run).  The ``kernel="pallas"``
path lowers the two GEMMs to ``kernels.moe_paged_gateup`` /
``moe_paged_down`` (scalar-prefetched page ids, double-buffered tile
DMAs); off-TPU it runs the Pallas interpreter and parity is
tolerance-level, like the attention kernel.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from ..kernels import moe_paged_down, moe_paged_gateup
from . import runahead as runahead_mod

MODES = ("off", "dense", "paged")
PLANE_GATE, PLANE_UP, PLANE_DOWN = 0, 1, 2
N_PLANES = 3


class ExpertPool:
    """Physical expert-weight pool + block table + optional NSB tier.

    Built once from the model params at engine construction; the pool
    array is handed to the decode jit as a (non-donated) read-only
    operand, except for the staging gather which rewrites tail slots.
    """

    def __init__(self, cfg, params, *, tile_rows: int = 32,
                 nsb_slots: int = 0) -> None:
        lp = params["layers"]
        gate, up, down = lp["we_gate"], lp["we_up"], lp["we_down"]
        l, e, d, f = gate.shape
        if f % tile_rows:
            raise ValueError(
                f"expert tile_rows {tile_rows} must divide d_ff_expert "
                f"{f} (pages are fixed-size row tiles)")
        self.n_layers, self.n_experts = l, e
        self.d_model, self.d_ff = d, f
        self.tile_rows = tile_rows
        self.nt = f // tile_rows
        # demand region: scratch page 0 + one page per (l, e, plane, tile)
        self.n_pages = 1 + l * e * N_PLANES * self.nt
        self.nsb_slots = nsb_slots
        # all three planes as [F, D] row planes (gate/up transposed),
        # stacked to [L, E, 3, F, D] and cut into row tiles
        planes = jnp.stack([jnp.swapaxes(gate, 2, 3),
                            jnp.swapaxes(up, 2, 3),
                            down], axis=2)
        tiles = planes.reshape(l * e * N_PLANES * self.nt, tile_rows, d)
        zeros = jnp.zeros((1 + nsb_slots, tile_rows, d), tiles.dtype)
        self.pool = jnp.concatenate([zeros[:1], tiles, zeros[1:]], axis=0)
        self.block_table = np.arange(
            1, self.n_pages, dtype=np.int32).reshape(l, e, N_PLANES,
                                                     self.nt)
        # the staging tier (None without slots): FIFO slot recycling +
        # hot-map + PageCache accounting twin, shared with the KV side.
        # Weights are read-only, so invalidate() is never needed here.
        self.tier = (runahead_mod.NSBHotTier(self.n_pages, nsb_slots)
                     if nsb_slots > 0 else None)

    # -- id space ------------------------------------------------------------

    def pages_for_experts(self, layer: int, eids) -> np.ndarray:
        """All physical pages (3 planes x NT tiles) the given experts of
        ``layer`` occupy — the traffic one routed (token, expert) pair
        demands.  ``eids`` is any int array-like of expert ids."""
        eids = np.asarray(eids, dtype=np.int64).reshape(-1)
        return self.block_table[layer, eids].reshape(-1)

    @property
    def pages_per_expert(self) -> int:
        return N_PLANES * self.nt

    @property
    def page_bytes(self) -> int:
        return self.tile_rows * self.d_model * self.pool.dtype.itemsize

    @property
    def pool_bytes(self) -> int:
        return int(self.pool.nbytes)

    # -- views ---------------------------------------------------------------

    def table_device(self) -> jax.Array:
        """The block table as a device array for the decode jit."""
        return jnp.asarray(self.block_table)

    def dense_rows(self) -> jax.Array:
        """The dense-materialised baseline view ``[L, E, 3, NT, tile,
        D]``: the same bytes as the demand pages, without the page
        indirection — what :func:`dense_moe_ffn` gathers from."""
        return self.pool[self.block_table]

    def hot_map_device(self) -> jax.Array:
        """Snapshot the tier's hot-map for one decode dispatch."""
        return jnp.asarray(self.tier.hot_map().copy())


# -- the serve-side expert FFN -------------------------------------------------

def route(xr: jax.Array, router: jax.Array, k: int):
    """Top-k routing head: f32 logits, top-k, softmax over the selected
    gates — the same math :func:`repro.models.moe._route_row` front-ends
    the capacity dispatch with, minus the capacity machinery (a decode
    step routes R independent single-token rows; nothing can be
    dropped).  Returns (gates [R, k] f32, eids int32 [R, k])."""
    logits = jnp.einsum("rd,de->re", xr.astype(jnp.float32),
                        router.astype(jnp.float32))
    gates, eids = jax.lax.top_k(logits, k)
    gates = jax.nn.softmax(gates, axis=-1)
    return gates, eids.astype(jnp.int32)


def _combine(xr: jax.Array, gates: jax.Array, w: jax.Array) -> jax.Array:
    """The shared SwiGLU expert mix: ``w`` [R, K, 3, NT, tile, D] holds
    the routed experts' weight tiles (however they were gathered); both
    FFN variants funnel through this one function so their math is the
    same jaxpr on the same shapes — the bitwise-parity hinge."""
    r, k = gates.shape
    d = xr.shape[-1]
    w = w.astype(xr.dtype)
    wg = w[:, :, PLANE_GATE].reshape(r, k, -1, d)
    wu = w[:, :, PLANE_UP].reshape(r, k, -1, d)
    wd = w[:, :, PLANE_DOWN].reshape(r, k, -1, d)
    g = jnp.einsum("rd,rkfd->rkf", xr, wg)
    u = jnp.einsum("rd,rkfd->rkf", xr, wu)
    h = jax.nn.silu(g) * u
    y = jnp.einsum("rkf,rkfd->rkd", h, wd)
    return jnp.einsum("rk,rkd->rd", gates.astype(y.dtype), y)


def dense_moe_ffn(x: jax.Array, lp: dict, rows_l: jax.Array, cfg):
    """Dense-materialised expert FFN for one decode step of one layer.

    ``x`` [R, 1, D]; ``rows_l`` [E, 3, NT, tile, D] this layer's slice
    of :meth:`ExpertPool.dense_rows`.  Returns ([R, 1, D], eids [R, k]).
    """
    xr = x[:, 0]
    gates, eids = route(xr, lp["router"], cfg.top_k)
    w = jnp.take(rows_l, eids, axis=0)          # [R,K,3,NT,tile,D]
    out = _combine(xr, gates, w)
    return out[:, None].astype(x.dtype), eids


def paged_moe_ffn(x: jax.Array, lp: dict, bt_l: jax.Array,
                  pool: jax.Array, cfg, *, hot_map=None, n_demand: int = 0,
                  kernel: str = "xla"):
    """Paged expert FFN: resolve routed expert ids to physical tile
    pages through the block table (hot-map remap into the NSB tail when
    the runahead tier is live) and gather from the pool.

    ``x`` [R, 1, D]; ``bt_l`` int32 [E, 3, NT] this layer's block-table
    slice; ``pool`` [n_pages + slots, tile, D].  ``kernel="pallas"``
    runs the scalar-prefetched tile-GEMM kernels instead of the XLA
    gather oracle.  Returns ([R, 1, D], eids [R, k]).
    """
    xr = x[:, 0]
    gates, eids = route(xr, lp["router"], cfg.top_k)
    pids = jnp.take(bt_l, eids, axis=0)         # [R,K,3,NT]
    if n_demand:
        # staged tiles are byte-exact copies of read-only weights, so
        # the remap moves the read, never the value
        slot = hot_map[pids]
        pids = jnp.where(slot >= 0, n_demand + slot, pids)
    if kernel == "pallas":
        g = moe_paged_gateup(pids[:, :, PLANE_GATE], xr, pool)
        u = moe_paged_gateup(pids[:, :, PLANE_UP], xr, pool)
        h = jax.nn.silu(g) * u
        y = moe_paged_down(pids[:, :, PLANE_DOWN], h, pool)
        out = jnp.einsum("rk,rkd->rd", gates.astype(y.dtype), y)
    else:
        w = jnp.take(pool, pids, axis=0)        # [R,K,3,NT,tile,D]
        out = _combine(xr, gates, w)
    return out[:, None].astype(x.dtype), eids
