"""Serving engines with NVR sparse-KV decode.

Two engines share one memory-system story:

:class:`Engine` — the single-batch baseline.  One fixed batch prefills
together and decodes in lockstep; no new request joins until the batch
drains.  Kept as the reference point ``benchmarks/serve_bench.py``
measures continuous batching against.

:class:`PagedEngine` — the continuous-batching engine.  Requests arrive
through an admission queue (:mod:`.scheduler`), an iteration-level
scheduler mixes prefill chunks and decode steps under a token budget, and
the KV cache is a pool of physical pages managed by
:class:`.kv_allocator.KVBlockAllocator` (block table per request,
free-list, preempt-and-evict under pressure).  The step loop is the
repo's serving fast path: pool buffers are *donated* into the decode and
prefill jits (no per-call pool copy), ragged decode batches pad to
power-of-two row buckets (O(log max_batch) traces, padded compute that
tracks the live batch), and the decode attention can run either the XLA
gather oracle or the fused Pallas runahead kernel
(``kernels.paged_decode_attn``) on the same pool layout.  The *physical page id* is
the shared currency across layers: the TopK paged-attention gather
(``sparse_attention.select_pages_blocktable``), the NSB hot-set
accounting (``capture.PageCache``), and the captured simulator trace
(``capture.PageStream`` with request/step tags) all account in the
allocator's page ids, so eviction policy, hot-set reuse, and NVR
prefetch simulation see one memory model.

Preemption is engineered for *bitwise-identical* resume under either
policy.  With a host spill tier (``spill_pages > 0``) eviction is
**swap-out**: the victim's pages snapshot whole (K, V, and the fp32
page summaries the TopK selection reads) into a host pool
(:mod:`.spill`), and resume restores them onto fresh physical ids —
identical content in identical logical order, and the paged attention
selects and gathers through the block table, so physical renaming
cannot change a logit.  Without the tier (or when it is full) the
recompute policy applies: prompts re-prefill through the same chunk
schedule, and already-generated tokens *replay* through the decode path
(teacher forcing), so the same jitted functions see the same inputs and
the request's logits are reproduced exactly.  The int8-compressed spill
tier (``spill_compress=True``) trades bitwise K/V restore for ~2x fewer
host bytes with a per-page ``scale/2`` error bound — summaries stay
exact, so page *selection* survives even compressed swaps.

With ``mesh=`` the engine is tensor-parallel: pools and QKV weights
shard along the KV-head axis over a ``("model",)`` mesh while the page
id space, block tables and scheduler state stay global, and every
cross-shard combine is a concatenation — logits remain bitwise-identical
to the unsharded engine (see ``_paged_decode_fn`` and the sharded-serve
section of ARCHITECTURE.md).

Per-step page traffic is scored against the NSB model, and with
``capture_trace=True`` each decode step's *layer-0* TopK selection (the
same layer-0 traffic proxy the single-batch engine uses, but computed
from the real decode queries) is recorded, tagged with request id and
scheduler iteration, into a
:class:`~repro.core.nvr.capture.PageStream`; ``captured_trace()`` lowers
it to a simulator ``Trace``, so multi-tenant serving traffic — not a
synthetic generator — drives the NVR/inorder comparison.  This container
is CPU-only: reported rates are traffic counts, not wall-clock.
"""

from __future__ import annotations

import math
from collections import deque
from dataclasses import dataclass, field

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

from .. import sharding
from ..configs.base import ArchConfig
from ..core.nvr import capture
from ..models import api, sparse_attention, transformer
from ..models import layers as mlayers
from . import expert_pool as expert_pool_mod
from . import runahead as runahead_mod
from . import scheduler as scheduler_mod
from .kv_allocator import NULL_PAGE, KVBlockAllocator, PagePoolConfig
from .scheduler import PrefillJob, Request, RequestState, Scheduler
from .spill import HostSpillPool


def percentile(xs, q: float) -> float | None:
    """Nearest-rank (ceil-rank) percentile: the ``ceil(q*n)``-th order
    statistic, 1-indexed — numpy's ``inverted_cdf`` method, and the one
    definition engine metrics and serve_bench share.  (The earlier
    ``round(q*(n-1))`` form banker's-rounded ``.5`` ranks upward: p50 of
    4 samples returned the 3rd order statistic instead of the 2nd.)

    Empty input returns None, not NaN: ``metrics()`` flows into
    ``json.dumps``, and a NaN there emits a non-strict-JSON token that
    breaks downstream parsers on zero-traffic smoke runs."""
    xs = sorted(xs)
    if not xs:
        return None
    return float(xs[min(len(xs) - 1, max(0, math.ceil(q * len(xs)) - 1))])


@dataclass
class ServeStats:
    steps: int = 0
    pages_touched: int = 0
    pages_unique: int = 0
    nsb_hits: int = 0
    nsb_misses: int = 0
    tokens_out: int = 0
    row_bytes: int = 0              # K+V bytes fetched per demanded page

    @property
    def hot_hit_rate(self) -> float | None:
        """NSB hit rate, or None before any traffic (keeps metrics
        strict-JSON-serialisable on zero-traffic runs)."""
        tot = self.nsb_hits + self.nsb_misses
        return self.nsb_hits / tot if tot else None

    @property
    def demand_bytes(self) -> int:
        """Total off-chip demand: every touched page is one K+V page
        fetch of ``row_bytes`` (the same per-row size the capture
        recorder charges, so serve metrics and simulator replay count
        the same bytes)."""
        return (self.nsb_hits + self.nsb_misses) * self.row_bytes

    @property
    def offchip_reduction(self) -> float | None:
        """Fetch-bytes reduction from the NSB hot-set: bytes *not*
        fetched (hot-set hits x per-page fetch bytes) over total demand
        bytes — the bytes-over-bytes definition the NVR simulator's
        ``demand_miss_reduction`` uses, so the two metrics compare like
        with like.  None until the engine sets ``row_bytes`` and traffic
        has been scored."""
        tot = self.demand_bytes
        return (self.nsb_hits * self.row_bytes) / tot if tot else None


class Engine:
    """Single-batch baseline: batched prefill + lockstep sparse decode."""

    def __init__(self, cfg: ArchConfig, params, max_len: int = 1024,
                 sparse: bool = True, nsb_pages: int = 64,
                 capture_trace: bool = False,
                 kv_dtype_bytes: int = 2) -> None:
        self.cfg = cfg
        self.params = params
        self.max_len = max_len
        self.sparse = sparse and cfg.sparse_kv
        self.stats = ServeStats(
            row_bytes=2 * cfg.kv_page * cfg.hd * kv_dtype_bytes)
        # NSB hot-set accounting on the shared simulator cache model
        self.hot = capture.PageCache(nsb_pages)
        self._seen_pages: set[int] = set()
        self.recorder = None
        if capture_trace and self.sparse:
            self.recorder = capture.kv_page_stream(
                f"serve-{cfg.name}", n_pages=max_len // cfg.kv_page,
                page_tokens=cfg.kv_page, head_dim=cfg.hd,
                dtype_bytes=kv_dtype_bytes)
        self._decode = jax.jit(
            lambda p, c, t: api.decode_fn(cfg, p, c, t, sparse=self.sparse))
        self.cache = None
        self._last = None

    def prefill(self, batch: dict) -> jax.Array:
        logits, cache = api.prefill_fn(self.cfg, self.params, batch,
                                       remat="none")
        self.cache = self._pad_cache(cache)
        self._last = jnp.argmax(logits, axis=-1)
        return self._last

    def _pad_cache(self, cache: dict) -> dict:
        cfg = self.cfg
        l, b, s, kv, hd = cache["k"].shape
        pad = self.max_len - s
        if pad <= 0:
            return cache
        z = jnp.zeros((l, b, pad, kv, hd), cache["k"].dtype)
        out = dict(cache)
        out["k"] = jnp.concatenate([cache["k"], z], axis=2)
        out["v"] = jnp.concatenate([cache["v"], z], axis=2)
        if "kpage" in cache:
            npad = self.max_len // cfg.kv_page - cache["kpage"].shape[2]
            out["kpage"] = jnp.concatenate(
                [cache["kpage"],
                 jnp.zeros((l, b, npad, kv, hd), jnp.float32)], axis=2)
        return out

    def _track_pages(self) -> None:
        """NSB accounting: which pages would the next step's selection
        touch (layer-0 scorer as the traffic proxy)."""
        cfg = self.cfg
        cache = self.cache
        if "kpage" not in cache:
            return
        kp0 = cache["kpage"][0]
        b = kp0.shape[0]
        q = jnp.ones((b, cfg.n_kv_heads, cfg.n_heads // cfg.n_kv_heads,
                      cfg.hd), kp0.dtype)
        n_valid = cache["pos"] // cfg.kv_page + 1
        k_pages = min(cfg.kv_topk_pages, kp0.shape[1])
        if self.recorder is not None:
            idx = np.asarray(sparse_attention.select_pages_recorded(
                q, kp0, n_valid, k_pages, self.recorder))
        else:
            idx = np.asarray(sparse_attention.select_pages(
                q, kp0, n_valid, k_pages))
        uniq = np.unique(idx)
        self._seen_pages.update(int(p) for p in uniq)
        self.stats.pages_unique = len(self._seen_pages)  # run footprint
        for p in uniq:
            self.stats.pages_touched += 1
            if self.hot.touch(int(p)):
                self.stats.nsb_hits += 1
            else:
                self.stats.nsb_misses += 1

    def step(self) -> jax.Array:
        if self.sparse:
            self._track_pages()
        logits, self.cache = self._decode(self.params, self.cache,
                                          self._last)
        self._last = jnp.argmax(logits, axis=-1)
        self.stats.steps += 1
        self.stats.tokens_out += int(self._last.shape[0])
        return self._last

    def generate(self, batch: dict, n_steps: int) -> np.ndarray:
        toks = [self.prefill(batch)]
        for _ in range(n_steps - 1):
            toks.append(self.step())
        return np.stack([np.asarray(t) for t in toks], axis=1)

    def captured_trace(self):
        """The decode run's recorded page traffic as a simulator Trace
        (requires ``capture_trace=True`` and at least one sparse step)."""
        if self.recorder is None:
            raise RuntimeError(
                "no trace recorder: construct the Engine with "
                "capture_trace=True AND the sparse-KV path enabled "
                "(sparse=True and cfg.sparse_kv) to record selections")
        return self.recorder.to_trace()


# -- continuous batching -------------------------------------------------------

@dataclass
class PagedServeStats(ServeStats):
    iterations: int = 0
    prefill_tokens: int = 0
    decode_tokens: int = 0
    preemptions: int = 0
    finished: int = 0
    cow_page_copies: int = 0
    decode_rows_padded: int = 0     # NULL rows computed across the run
    prefill_calls: int = 0          # executed prefill-chunk jit calls
    swap_out_pages: int = 0         # pages snapshotted device -> host
    swap_in_pages: int = 0          # pages restored host -> device
    fetch_backs: int = 0            # runahead-window early swap-resumes
    # multi-turn session layer (session_hold=True)
    session_holds: int = 0          # finished turns whose KV was pinned
    turns_submitted: int = 0        # follow-up turns re-entering the door
    idle_swap_outs: int = 0         # holds parked in the host spill tier
    idle_swap_ins: int = 0          # holds restored for their next turn
    idle_evictions: int = 0         # holds released under page pressure
    # expert-weight page traffic (expert_pool != "off"): unique tile
    # pages demanded per decode step, scored against the expert NSB
    expert_pages_touched: int = 0
    expert_nsb_hits: int = 0
    expert_nsb_misses: int = 0
    # per-stream iteration accounting (the disaggregated executor's
    # TTFT/TPOT split): an iteration belongs to the prefill stream when
    # it ran >=1 prompt chunk, to the decode stream when it ran a decode
    # batch, and to both when the streams overlap
    prefill_iterations: int = 0
    decode_iterations: int = 0
    overlap_iterations: int = 0     # iterations where both streams ran
    # (n_prefill_chunks, n_decode_rows) per iteration — the shared
    # timeline overlap_bench's deterministic cost model replays to
    # compare sync (streams serial) vs async (streams overlapped)
    iter_log: list = field(default_factory=list)

    @property
    def expert_hot_hit_rate(self) -> float | None:
        """Expert-tile NSB hit rate (None before expert traffic)."""
        tot = self.expert_nsb_hits + self.expert_nsb_misses
        return self.expert_nsb_hits / tot if tot else None


# sentinel distinguishing "run _fetch_back inline" (sync loop) from "the
# executor already ran it in the overlap window, possibly returning None"
_FETCH_UNSET = object()


def _paged_decode_fn(cfg: ArchConfig, kernel: str = "xla", tp: int = 1,
                     tp_axis: str | None = None, n_demand: int = 0,
                     ep_mode: str = "off", ep_n_demand: int = 0):
    """Build the ragged decode step over the physical page pools.

    One call advances R requests by one token each: per-request positions
    (no lockstep), KV written through the block table into physical
    pages, page summaries recomputed exactly, TopK selection + gather by
    physical page id.  Padded rows carry block table NULLs and scribble
    the reserved scratch page 0.

    ``kernel`` picks the attention implementation: ``"xla"`` is the
    ``attend_pages_paged`` gather (runs everywhere; the parity oracle),
    ``"pallas"`` is the fused ``kernels.paged_decode_attn`` runahead
    kernel on the same pool layout (scalar-prefetched page ids,
    double-buffered indirect DMAs; interpret mode off-TPU).

    With ``tp > 1`` this is the *per-shard* body run under ``shard_map``
    (see :func:`_shard_serve_fn`): params carry this shard's head slice
    of the QKV projections, pools its KV-head slice, and the per-head
    attention outputs are all-gathered (``tp_axis``) before the
    replicated output projection.  Every cross-shard combine is a
    concatenation of independent per-head results — never an arithmetic
    reduction — which is what keeps tp>1 logits bitwise-identical to
    tp=1.  Block tables, frontiers and the returned TopK ids stay in the
    one global physical page-id space.

    With ``n_demand > 0`` the function is the *runahead* variant: it
    takes a trailing ``hot_map`` argument (int32 [n_demand], demand page
    id -> staged NSB slot, -1 = not staged) and the attention gather
    resolves TopK ids through it, reading staged copies from the pool's
    contiguous tail at ``n_demand + slot`` (see
    ``sparse_attention.attend_pages_paged``).  Staged pages are
    byte-exact copies, so logits — and the *returned selection*, which
    stays in original demand page ids — are bitwise-identical to the
    no-runahead variant; with ``n_demand == 0`` the built graph is
    exactly the historic one (no extra argument, no remap ops).

    ``ep_mode`` selects the expert-FFN implementation for MoE configs
    (see :mod:`.expert_pool`): ``"off"`` keeps the historic
    ``transformer._ffn`` (capacity-dispatch ``moe_ffn``); ``"dense"``
    takes a trailing dense-materialised ``ep_rows [L,E,3,NT,tile,D]``
    operand; ``"paged"`` takes trailing ``(ep_bt [L,E,3,NT], ep_pool
    [P+slots,tile,D])`` and resolves routed expert ids through the
    block table (plus a trailing ``ep_hot`` hot-map when
    ``ep_n_demand > 0`` — expert tiles staged in the pool's NSB tail).
    Both expert modes additionally return the per-layer routed expert
    ids ``esel [L, R, top_k]``; dense and paged gather bitwise-
    identical weight bytes into the same combine graph, so tokens and
    logits are bitwise-invariant across dense / paged / paged+runahead.
    Expert weights and routing are replicated under tp (only QKV
    shards), so ``esel`` is shard-invariant.
    """
    page = cfg.kv_page
    dt = jnp.dtype(cfg.param_dtype)
    kv_l = cfg.n_kv_heads // tp              # KV heads on this shard
    g = cfg.n_heads // cfg.n_kv_heads        # GQA groups stay whole
    h_l = kv_l * g

    def fn(params, k_pool, v_pool, s_pool, token, pos, bt, *extra):
        i = 0
        hot_map = None
        if n_demand:
            hot_map, i = extra[0], 1
        ep_rows = ep_bt = ep_pool = ep_hot = None
        if ep_mode == "dense":
            ep_rows = extra[i]
        elif ep_mode == "paged":
            ep_bt, ep_pool = extra[i], extra[i + 1]
            if ep_n_demand:
                ep_hot = extra[i + 2]
        r = token.shape[0]
        nl = bt.shape[1]
        k_sel = int(min(cfg.kv_topk_pages, nl))
        x = jnp.take(params["embed"], token[:, None], axis=0).astype(dt)
        if getattr(cfg, "scale_embed", False):
            x = x * (cfg.d_model ** 0.5)
        pos_arr = pos[:, None]                       # [R,1]
        lp_w = pos // page
        off = pos % page
        phys_w = jnp.take_along_axis(bt, lp_w[:, None], axis=1)[:, 0]
        n_valid = lp_w + 1
        lidx = jnp.arange(cfg.n_layers, dtype=jnp.int32)

        def body(carry, lp_li):
            xc, kp_, vp_, sp_ = carry
            lp, li = lp_li
            h = mlayers.rms_norm(xc, lp["ln1"], cfg.norm_eps)
            q, k_new, v_new = mlayers.gqa_project(h, lp, cfg, h_l, kv_l)
            q = mlayers.apply_rope(q, pos_arr, cfg.rope_theta)
            k_new = mlayers.apply_rope(k_new, pos_arr, cfg.rope_theta)
            kq = sparse_attention.kv_quant(k_new[:, 0], kp_.dtype)
            vq = sparse_attention.kv_quant(v_new[:, 0], vp_.dtype)
            kp_ = kp_.at[li, phys_w, off].set(kq)
            vp_ = vp_.at[li, phys_w, off].set(vq)
            if n_demand:
                # write-through into the NSB tail: when the frontier
                # page has a staged copy, mirror the new KV bytes into
                # it so staging survives the decode's own writes.  For
                # unstaged pages the target collapses to the primary
                # location — a re-write of the identical values — so
                # pool contents are unchanged either way.
                slot_w = hot_map[phys_w]
                wt = jnp.where(slot_w >= 0, n_demand + slot_w, phys_w)
                kp_ = kp_.at[li, wt, off].set(kq)
                vp_ = vp_.at[li, wt, off].set(vq)
            summ = sparse_attention.page_summary_from_pool(
                kp_[li], phys_w, off + 1)
            sp_ = sp_.at[li, phys_w].set(summ)
            qh = q.reshape(r, kv_l, g, cfg.hd)
            idx, phys = sparse_attention.select_pages_blocktable(
                qh, sp_[li], bt, n_valid, k_sel)
            if kernel == "pallas":
                # the fused runahead kernel streams its shard's pages
                # end to end; per-head outputs concat across shards
                # (tolerance-level parity, as on a single shard)
                o = sparse_attention.attend_pages_paged_kernel(
                    qh, kp_[li], vp_[li], idx, phys, pos, page,
                    hot_map=hot_map, n_demand=n_demand)
                o = o.reshape(r, 1, h_l, cfg.hd)
                if tp_axis is not None:
                    o = jax.lax.all_gather(o, tp_axis, axis=2,
                                           tiled=True)
            else:
                # XLA oracle: local pool gather, then the small TopK
                # tiles all-gather and the softmax math replays at the
                # full-KV shape — bitwise equal to tp=1 (see
                # attend_pages_paged)
                o = sparse_attention.attend_pages_paged(
                    qh, kp_[li], vp_[li], idx, phys, pos, page,
                    tp_axis=tp_axis, hot_map=hot_map, n_demand=n_demand)
                o = o.reshape(r, 1, cfg.n_heads if tp_axis is not None
                              else h_l, cfg.hd)
            xc = xc + mlayers.attn_out(o, lp, cfg.d_model)
            h2 = mlayers.rms_norm(xc, lp["ln2"], cfg.norm_eps)
            if ep_mode == "off":
                xc = xc + transformer._ffn(h2, lp, cfg)
                return (xc, kp_, vp_, sp_), phys
            if ep_mode == "dense":
                y, eids = expert_pool_mod.dense_moe_ffn(
                    h2, lp, jnp.take(ep_rows, li, axis=0), cfg)
            else:
                y, eids = expert_pool_mod.paged_moe_ffn(
                    h2, lp, jnp.take(ep_bt, li, axis=0), ep_pool, cfg,
                    hot_map=ep_hot, n_demand=ep_n_demand, kernel=kernel)
            xc = xc + y
            return (xc, kp_, vp_, sp_), (phys, eids)

        (x, k2, v2, s2), sel = mlayers.scan_layers(
            body, (x, k_pool, v_pool, s_pool), (params["layers"], lidx))
        x = mlayers.rms_norm(x, params["ln_f"], cfg.norm_eps)
        logits = transformer.logits_last(params, cfg, x)
        if ep_mode == "off":
            return logits, k2, v2, s2, sel
        return logits, k2, v2, s2, sel[0], sel[1]

    return fn


def _paged_prefill_fn(cfg: ArchConfig, chunk: int, tp: int = 1,
                      tp_axis: str | None = None):
    """Build the chunked-prefill step for one request.

    Processes ``t_valid <= chunk`` prompt tokens starting at absolute
    position ``start``: dense causal attention over the request's paged
    context (gathered through the block table), KV scattered into the
    pool, page summaries recomputed through the same
    ``page_summary_from_pool`` the decode path uses.  Padded positions
    write to scratch page 0.

    ``tp``/``tp_axis`` follow :func:`_paged_decode_fn`: with tp > 1 this
    is the per-shard body — projection and pool writes run on local KV
    heads, then the per-request *context view* (block-table-gathered
    from the sharded pools) all-gathers and the dense attention replays
    at the full-head shape identically on every shard, the same
    bitwise mechanism as the decode path.
    """
    page = cfg.kv_page
    dt = jnp.dtype(cfg.param_dtype)
    ntp = chunk // page + 2           # touched-page bound per chunk
    kv_l = cfg.n_kv_heads // tp
    h_l = (cfg.n_heads // cfg.n_kv_heads) * kv_l

    def fn(params, k_pool, v_pool, s_pool, tokens, start, t_valid, bt):
        nl = bt.shape[0]
        c = tokens.shape[0]
        x = jnp.take(params["embed"], tokens[None, :], axis=0).astype(dt)
        if getattr(cfg, "scale_embed", False):
            x = x * (cfg.d_model ** 0.5)
        pos = start + jnp.arange(c)                  # [C]
        in_chunk = jnp.arange(c) < t_valid
        lp_w = jnp.clip(pos // page, 0, nl - 1)
        phys_w = jnp.where(in_chunk, bt[lp_w], 0)
        off = pos % page
        end = start + t_valid
        lps = start // page + jnp.arange(ntp)
        pvalid = lps <= (end - 1) // page
        phys_s = jnp.where(pvalid, bt[jnp.clip(lps, 0, nl - 1)], 0)
        cnts = jnp.clip(end - lps * page, 1, page)
        lidx = jnp.arange(cfg.n_layers, dtype=jnp.int32)

        def body(carry, lp_li):
            xc, kp_, vp_, sp_ = carry
            lp, li = lp_li
            h = mlayers.rms_norm(xc, lp["ln1"], cfg.norm_eps)
            q, k_new, v_new = mlayers.gqa_project(h, lp, cfg, h_l, kv_l)
            q = mlayers.apply_rope(q, pos[None, :], cfg.rope_theta)
            k_new = mlayers.apply_rope(k_new, pos[None, :], cfg.rope_theta)
            kq = sparse_attention.kv_quant(k_new[0], kp_.dtype)
            vq = sparse_attention.kv_quant(v_new[0], vp_.dtype)
            kp_ = kp_.at[li, phys_w, off].set(kq)
            vp_ = vp_.at[li, phys_w, off].set(vq)
            summ = sparse_attention.page_summary_from_pool(
                kp_[li], phys_s, cnts)
            sp_ = sp_.at[li, phys_s].set(summ)
            # dense causal attention over the paged context: the block
            # table linearises this request's pages back into logical
            # order, so positions align with q_offset=start
            kctx = kp_[li, bt].reshape(1, nl * page, kv_l, cfg.hd)
            vctx = vp_[li, bt].reshape(1, nl * page, kv_l, cfg.hd)
            if tp_axis is not None:
                # same bitwise mechanism as decode: the context view is
                # gathered from the sharded pools (pool *storage* stays
                # 1/tp) and the attention math replays at the full-head
                # shape identically on every shard — per-head softmax
                # lowering is shape-dependent at ulp level, so local-
                # shape attention would drift from the tp=1 oracle
                q, kctx, vctx = jax.lax.all_gather(
                    (q, kctx, vctx), tp_axis, axis=2, tiled=True)
            o = mlayers.chunked_attention(
                q, kctx, vctx, causal=True, q_offset=start,
                chunk=min(1024, nl * page),
                logit_softcap=cfg.logit_softcap)
            xc = xc + mlayers.attn_out(o, lp, cfg.d_model)
            h2 = mlayers.rms_norm(xc, lp["ln2"], cfg.norm_eps)
            xc = xc + transformer._ffn(h2, lp, cfg)
            return (xc, kp_, vp_, sp_), None

        (x, k2, v2, s2), _ = mlayers.scan_layers(
            body, (x, k_pool, v_pool, s_pool), (params["layers"], lidx))
        x = mlayers.rms_norm(x, params["ln_f"], cfg.norm_eps)
        hl = jax.lax.dynamic_index_in_dim(x[0], t_valid - 1, 0,
                                          keepdims=True)      # [1,D]
        logits = transformer.logits_last(params, cfg, hl[None])[0]
        return logits, k2, v2, s2

    return fn


def _norm_spec(spec: P) -> P:
    """Strip trailing Nones: jitted-call cache keys compare PartitionSpecs
    *literally* (on jax 0.4.3x ``P(a, None) != P(a)``), and shard_map
    output shardings come back trailing-None-normalised — pools must be
    device_put with the same normal form or the second call retraces."""
    dims = list(spec)
    while dims and dims[-1] is None:
        dims.pop()
    return P(*dims)


def _shard_serve_fn(fn, mesh, param_specs, n_rep_args: int,
                    sel_out: bool = False, esel_out: bool = False,
                    axis: str = sharding.SERVE_TP_AXIS):
    """Wrap a per-shard decode/prefill body in ``shard_map`` over the
    KV-head axis.

    In: params per ``sharding.serve_param_specs`` (QKV head-sharded,
    rest replicated), k/v/s pools sharded on their KV-head dim, and
    ``n_rep_args`` replicated host args (tokens, positions, block
    tables).  Out: logits replicated (each shard computes the identical
    post-gather value — no reduction ever crosses shards), pools sharded
    as they came in (donation-compatible), and for decode the stacked
    TopK physical ids sharded on their KV-head dim — ``np.asarray`` on
    the host reassembles the global ``[L,R,KV,K]`` selection, so the
    allocator/NSB/capture layers keep seeing one physical page-id space.
    """
    from jax.experimental.shard_map import shard_map

    kv_spec, s_spec = sharding.serve_pool_specs(axis)
    in_specs = (param_specs, kv_spec, kv_spec, s_spec) \
        + (P(),) * n_rep_args
    out_specs = (P(), kv_spec, kv_spec, s_spec)
    if sel_out:
        out_specs = out_specs + (P(None, None, axis, None),)
    if esel_out:
        # routed expert ids: router and residual stream are replicated
        # under serve TP, so every shard computes the identical routing
        out_specs = out_specs + (P(),)
    return shard_map(fn, mesh=mesh, in_specs=in_specs,
                     out_specs=out_specs, check_rep=False)


class PagedEngine:
    """Continuous-batching serve engine on a paged KV allocator.

    ``submit()`` enqueues requests; ``step()`` runs one scheduler
    iteration (admission + mixed prefill chunks / ragged decode batch);
    ``run()`` drives an arrival workload to completion.

    With ``prefix_cache=True`` (default) physical prompt pages are
    shared across requests: completed whole prompt pages are published
    to the allocator's content-addressed prefix index after each prefill
    chunk, admission attaches matching cached pages (refcount++) and
    fast-forwards the request's KV frontier past them — shared prefixes
    cost zero model FLOPs while logits stay bitwise-identical to the
    uncached run (the final prompt token is always recomputed, on a
    copy-on-write private page when the whole prompt was cached).

    Step-loop fast-path knobs (all default-on except the kernel):

    * ``kernel="xla" | "pallas"`` — the decode attention implementation.
      ``"xla"`` (default) is the ``attend_pages_paged`` gather: runs on
      any backend and is the parity oracle the bitwise-resume guarantees
      are pinned to.  ``"pallas"`` fuses gather + online-softmax in
      ``kernels.paged_decode_attn`` with the TopK physical page ids
      scalar-prefetched (the NVR runahead pipeline on the pool layout);
      off-TPU it runs in interpret mode — parity is tolerance-level
      (fp32 online softmax), not bitwise.
    * ``donate_pools`` — donate the k/v/s pool buffers into the decode
      and prefill jits, so XLA updates pages in place instead of copying
      the full ``[L,P,page,KV,D]`` pools every call.
    * ``row_bucketing`` — pad ragged decode batches to power-of-two row
      buckets (NULL block-table rows) instead of always to
      ``max_batch``: padded compute tracks the live batch while the
      trace count stays O(log max_batch) (``metrics()["n_decode_traces"]``),
      and the scheduler tops buckets up with budget-deferred rows
      (free-slot decode).
    * ``mesh`` — tensor parallelism over a 1-axis ``("model",)`` mesh
      (``launch.mesh.make_serve_mesh``): the physical k/v/s pools and
      the QKV projection weights shard along the KV-head axis (1/tp of
      the pool bytes per shard), block tables / frontiers / TopK page
      ids stay replicated in the one global physical page-id space, and
      both step functions run as per-shard ``shard_map`` bodies whose
      only cross-shard traffic is an all-gather of independent per-head
      attention outputs — logits are *bitwise-identical* to the tp=1
      engine, so preemption-resume and prefix-cache guarantees survive
      sharding unchanged.  Requires ``tp`` to divide ``n_heads`` and
      ``n_kv_heads``; each shard runs its own NSB hot-set
      (``metrics()["nsb_shard_hit_rates"]``).
    * ``runahead="off" | "imp" | "nvr"`` — the online runahead stage
      (see :mod:`.runahead` and the "online runahead" section of
      ARCHITECTURE.md).  ``"nvr"`` predicts each live request's
      next-iteration TopK pages between decode steps (history
      predictors filtered DARE-style, layer-0 proxy scoring for the
      rest) and stages them into a physical NSB tail appended to the
      k/v pools; the decode gather resolves TopK ids through the
      hot-map into the staged copies.  ``"imp"`` is the one-step-behind
      baseline: it stages exactly the pages the current step selected.
      Staged pages are byte-exact copies and block tables stay
      authoritative, so tokens and logits are bitwise-identical with
      runahead on or off — mispredictions cost staging bandwidth only.
      ``runahead_pages`` bounds staging copies per iteration;
      ``nsb_pages`` sizes the staging tail (and the demand-LRU
      comparator).
    """

    def __init__(self, cfg: ArchConfig, params, max_len: int = 64,
                 n_pages: int = 0, max_batch: int = 8, chunk: int = 16,
                 token_budget: int = 0, nsb_pages: int = 64,
                 capture_trace: bool = False,
                 kv_dtype_bytes: int = 2,
                 prefix_cache: bool = True,
                 kernel: str = "xla",
                 donate_pools: bool = True,
                 row_bucketing: bool = True,
                 mesh=None,
                 runahead: str = "off",
                 runahead_pages: int = 8,
                 expert_pool: str = "off",
                 expert_tile_rows: int = 32,
                 expert_nsb_slots: int = 32,
                 expert_runahead: str = "off",
                 expert_runahead_pages: int = 16,
                 spill_pages: int = 0,
                 spill_compress: bool = False,
                 executor: str = "sync",
                 policy=None,
                 session_hold: bool = False,
                 idle_swap: bool = False) -> None:
        if cfg.family not in ("dense", "moe") or cfg.mrope_sections:
            raise NotImplementedError(
                "PagedEngine supports dense/moe decoder-only configs")
        if not cfg.sparse_kv:
            raise NotImplementedError(
                "PagedEngine requires the sparse-KV decode path")
        if max_len % cfg.kv_page:
            raise ValueError("max_len must be a multiple of cfg.kv_page")
        if kernel not in ("xla", "pallas"):
            raise ValueError(f"kernel must be 'xla' or 'pallas', "
                             f"got {kernel!r}")
        if runahead not in runahead_mod.MODES:
            raise ValueError(f"runahead must be one of "
                             f"{runahead_mod.MODES}, got {runahead!r}")
        if expert_pool not in expert_pool_mod.MODES:
            raise ValueError(f"expert_pool must be one of "
                             f"{expert_pool_mod.MODES}, got {expert_pool!r}")
        if expert_pool != "off" and not cfg.n_experts:
            raise ValueError("expert_pool requires an MoE-family config "
                             f"(cfg {cfg.name!r} has n_experts=0)")
        if expert_runahead not in runahead_mod.EXPERT_MODES:
            raise ValueError(
                f"expert_runahead must be one of "
                f"{runahead_mod.EXPERT_MODES}, got {expert_runahead!r}")
        if expert_runahead != "off" and expert_pool != "paged":
            raise ValueError(
                "expert_runahead needs expert_pool='paged': only the "
                "paged path resolves tiles through a hot-map")
        if executor not in ("sync", "async"):
            raise ValueError(f"executor must be 'sync' or 'async', "
                             f"got {executor!r}")
        self.mesh = mesh
        if mesh is not None:
            if sharding.SERVE_TP_AXIS not in dict(mesh.shape):
                raise ValueError(
                    f"serve mesh needs a {sharding.SERVE_TP_AXIS!r} "
                    f"axis, got {tuple(dict(mesh.shape))} (use "
                    "launch.mesh.make_serve_mesh)")
            self.tp = int(dict(mesh.shape)[sharding.SERVE_TP_AXIS])
            if cfg.n_kv_heads % self.tp or cfg.n_heads % self.tp:
                raise ValueError(
                    f"tp={self.tp} must divide both head counts "
                    f"(n_heads={cfg.n_heads}, n_kv_heads="
                    f"{cfg.n_kv_heads}): GQA groups shard whole, one "
                    "KV-head slice per shard")
        else:
            self.tp = 1
        self.cfg = cfg
        self.params = params
        self.max_len = max_len
        self.page = cfg.kv_page
        self.n_logical = max_len // self.page
        chunk = min(chunk, max_len)
        # pool default: every batch slot can hold a full-length request,
        # +1 for the reserved scratch page
        self.n_pages = n_pages or (1 + max_batch * self.n_logical)
        self.allocator = KVBlockAllocator(self.n_pages, self.page,
                                          prefix_cache=prefix_cache,
                                          spill_pages=spill_pages)
        self.kernel = kernel
        self.donate_pools = donate_pools
        self.row_buckets = (scheduler_mod.row_buckets(max_batch)
                            if row_bucketing else ())
        # online runahead: a physical NSB staging tail appended to the
        # k/v pools, a hot-map resolving TopK ids into it, and a
        # predict->filter->stage pipeline between decode steps.  With
        # runahead="off" everything below is inert and the decode graph
        # is the exact historic one.
        self.runahead = runahead
        self.runahead_pages = runahead_pages
        self.nsb_slots = (min(nsb_pages, self.n_pages - 1)
                          if runahead != "off" else 0)
        self._tier = (runahead_mod.NSBHotTier(self.n_pages,
                                              self.nsb_slots)
                      if runahead != "off" else None)
        self._predictor = (runahead_mod.RunaheadPredictor(mode=runahead)
                           if runahead != "off" else None)
        # paged expert-weight pool (MoE family): expert FFN weights as
        # fixed row-tile pages with per-layer block tables, an NSB
        # staging tail for router-predicted tiles, and a demand-LRU
        # comparator — the KV machinery's layout applied to the one
        # read-only gather workload the paper is about
        self.expert_pool_mode = expert_pool
        self.expert_runahead = expert_runahead
        self.expert_runahead_pages = expert_runahead_pages
        self.ep = None
        self._ep_tier = None
        self._ep_predictor = None
        self._ep_rows = None
        self._ep_bt = None
        self._ep_stage = None
        self._router_proxy = None
        self.ep_hot = None
        self.ep_recorder = None
        if expert_pool != "off":
            self.ep = expert_pool_mod.ExpertPool(
                cfg, params, tile_rows=expert_tile_rows,
                nsb_slots=(expert_nsb_slots if expert_runahead != "off"
                           else 0))
            self._ep_tier = self.ep.tier
            # the same demand traffic scored against a demand-install
            # LRU of the staging tier's capacity: the in-run baseline
            # the router-keyed hit rate is lifted over
            self.ep_hot = capture.PageCache(expert_nsb_slots)
            if expert_pool == "dense":
                self._ep_rows = self.ep.dense_rows()
            else:
                self._ep_bt = self.ep.table_device()
            if capture_trace:
                self.ep_recorder = capture.expert_page_stream(
                    f"serve-ep-{cfg.name}", n_pages=self.ep.n_pages,
                    tile_rows=self.ep.tile_rows, d_model=cfg.d_model,
                    dtype_bytes=self.ep.pool.dtype.itemsize)
            if expert_runahead != "off":
                self._ep_predictor = runahead_mod.RunaheadPredictor(
                    mode="nvr")
                self._router_proxy = jax.jit(
                    runahead_mod.make_router_scorer(cfg))

                # expert-tile staging gather: same donated fixed-shape
                # pattern as the KV _stage jit ((0,0) scratch self-copy
                # padding)
                def _ep_stage_body(pool, src, dst):
                    return pool.at[dst].set(pool[src])
                self._ep_stage = jax.jit(_ep_stage_body,
                                         donate_argnums=(0,))
        self.scheduler = Scheduler(
            self.allocator, max_batch=max_batch, chunk=chunk,
            token_budget=token_budget or (max_batch + chunk),
            row_buckets=self.row_buckets,
            # either runahead flavour claims the decode stream's
            # per-iteration staging grant
            runahead_pages=(runahead_pages if runahead != "off" else
                            (expert_runahead_pages
                             if expert_runahead != "off" else 0)),
            policy=policy)
        # multi-turn session layer: with session_hold, a finished
        # conversation turn's KV stays pinned under a *holder* rid until
        # the next turn arrives (idle_swap parks it in the host spill
        # tier instead of HBM), and the scheduler's idle-eviction hook
        # releases holds — idle sessions first — whenever live traffic
        # is starved for pages
        self.session_hold = session_hold
        self.idle_swap = idle_swap
        if idle_swap and spill_pages <= 0:
            raise ValueError("idle_swap=True needs a host spill tier "
                             "(spill_pages > 0) to park idle sessions in")
        self._sessions: dict[int, dict] = {}
        self._hold_order: list[int] = []    # sids with live holders, LRU
        self._deferred: list[int] = []      # sids with a pending turn
        self._next_sid = 0
        if session_hold:
            self.scheduler.idle_evict_hook = self._evict_idle_hold
        self.max_batch = max_batch
        self.chunk = chunk
        self.stats = PagedServeStats()
        self.hot = capture.PageCache(nsb_pages)
        # per-shard NSBs under TP: each model shard scores only the
        # pages its own KV heads select (the paper's per-NPU buffer)
        self.hot_shards = (capture.ShardedPageCache(self.tp, nsb_pages)
                           if self.tp > 1 else None)
        self._seen_pages: set[int] = set()
        self.recorder = None
        if capture_trace:
            self.recorder = capture.kv_page_stream(
                f"serve-cb-{cfg.name}", n_pages=self.n_pages,
                page_tokens=self.page, head_dim=cfg.hd,
                dtype_bytes=kv_dtype_bytes)
        kv_dt = (jnp.int8 if cfg.kv_dtype == "int8"
                 else jnp.dtype(cfg.param_dtype))
        # host spill tier: preemption becomes swap-out instead of
        # free-and-recompute (slot ids allocated by the allocator, bytes
        # owned by the pool, copies performed by _apply_spill_outs /
        # _apply_swap_ins in the step loop)
        self.spill_pool = (HostSpillPool(
            spill_pages, cfg.n_layers, self.page, cfg.n_kv_heads,
            cfg.hd, np.dtype(kv_dt), compress=spill_compress)
            if spill_pages > 0 else None)
        self._spill_err = 0.0       # running max dequant error bound
        self.pool_cfg = PagePoolConfig(
            n_pages=self.n_pages, page_tokens=self.page,
            n_layers=cfg.n_layers, n_kv_heads=cfg.n_kv_heads,
            head_dim=cfg.hd, dtype_bytes=jnp.dtype(kv_dt).itemsize)
        # same per-page fetch size the capture recorder charges
        # (kv_dtype_bytes models the production KV dtype, bf16 by
        # default), so demand_bytes and the captured-trace replay count
        # identical bytes per page
        self.stats.row_bytes = 2 * self.page * cfg.hd * kv_dtype_bytes
        # the k/v pools carry the demand region [0, n_pages) plus the
        # contiguous NSB staging tail [n_pages, n_pages + nsb_slots):
        # staged copies live there, addressed via the hot-map.  The
        # summary pool stays demand-sized — selection never reads the
        # tail, only the attention gather does.
        shape = (cfg.n_layers, self.n_pages + self.nsb_slots, self.page,
                 cfg.n_kv_heads, cfg.hd)
        self.k_pool = jnp.zeros(shape, kv_dt)
        self.v_pool = jnp.zeros(shape, kv_dt)
        self.s_pool = jnp.zeros(
            (cfg.n_layers, self.n_pages, cfg.n_kv_heads, cfg.hd),
            jnp.float32)
        # pool buffers are donated into both jits: the step loop rebinds
        # self.{k,v,s}_pool to the outputs, so XLA updates the pools in
        # place instead of round-tripping a full pool-sized copy per call
        donate = (1, 2, 3) if donate_pools else ()
        # runahead variants take the trailing replicated hot_map arg and
        # remap gathers into the staging tail; n_demand=0 builds the
        # exact historic graph (bitwise anchor for runahead="off")
        n_demand = self.n_pages if runahead != "off" else 0
        # expert-pool decode variants thread their (replicated) weight
        # operands as trailing args: dense rows, or block table + pool
        # (+ the expert hot-map when the staging tier is live)
        ep_n_demand = (self.ep.n_pages
                       if self._ep_tier is not None else 0)
        n_rep_decode = 3 if runahead == "off" else 4
        if expert_pool == "dense":
            n_rep_decode += 1
        elif expert_pool == "paged":
            n_rep_decode += 2 + (1 if ep_n_demand else 0)
        if mesh is None:
            self._pool_shardings = None
            self._decode = jax.jit(
                _paged_decode_fn(cfg, kernel, n_demand=n_demand,
                                 ep_mode=expert_pool,
                                 ep_n_demand=ep_n_demand),
                donate_argnums=donate)
            self._prefill = jax.jit(_paged_prefill_fn(cfg, chunk),
                                    donate_argnums=donate)
        else:
            # tensor parallelism: pools live KV-head-sharded on the mesh
            # (1/tp of the pool bytes per shard), params per the serve
            # TP rules (QKV head-sharded, the rest replicated), and both
            # step functions run as per-shard shard_map bodies — see
            # _paged_decode_fn for why this keeps logits bitwise equal
            # to tp=1
            kv_spec, s_spec = sharding.serve_pool_specs()
            self._pool_shardings = (
                NamedSharding(mesh, _norm_spec(kv_spec)),
                NamedSharding(mesh, _norm_spec(kv_spec)),
                NamedSharding(mesh, _norm_spec(s_spec)))
            self.k_pool = jax.device_put(self.k_pool,
                                         self._pool_shardings[0])
            self.v_pool = jax.device_put(self.v_pool,
                                         self._pool_shardings[1])
            self.s_pool = jax.device_put(self.s_pool,
                                         self._pool_shardings[2])
            pspecs = sharding.serve_param_specs(params)
            self.params = jax.device_put(
                params, jax.tree.map(lambda s: NamedSharding(mesh, s),
                                     pspecs,
                                     is_leaf=lambda x: isinstance(x, P)))
            axis = sharding.SERVE_TP_AXIS
            self._decode = jax.jit(
                _shard_serve_fn(
                    _paged_decode_fn(cfg, kernel, self.tp, axis,
                                     n_demand=n_demand,
                                     ep_mode=expert_pool,
                                     ep_n_demand=ep_n_demand),
                    mesh, pspecs, n_rep_args=n_rep_decode, sel_out=True,
                    esel_out=(expert_pool != "off")),
                donate_argnums=donate)
            self._prefill = jax.jit(
                _shard_serve_fn(
                    _paged_prefill_fn(cfg, chunk, self.tp, axis),
                    mesh, pspecs, n_rep_args=4),
                donate_argnums=donate)
        self._proxy = None
        self._stage = None
        self.tier_shards = None
        if self._tier is not None:
            # the staging gather: copy predicted demand pages into the
            # NSB tail in one donated jit (in-place pool update, no
            # pool-sized round trip).  src/dst are padded to a fixed
            # length with (0, 0) self-copies — page 0 is the reserved
            # scratch page, so padding is a value-identical no-op and
            # the call compiles exactly once.
            def _stage_body(k_pool, v_pool, src, dst):
                return (k_pool.at[:, dst].set(k_pool[:, src]),
                        v_pool.at[:, dst].set(v_pool[:, src]))
            self._stage = jax.jit(
                _stage_body, donate_argnums=(0, 1),
                out_shardings=(None if mesh is None else
                               (self._pool_shardings[0],
                                self._pool_shardings[1])))
            if runahead == "nvr":
                # the address-generation slice (layer-0 proxy scorer);
                # speculation-only, so plain jit is fine under tp (GSPMD
                # handles the sharded wq; no bitwise contract needed)
                self._proxy = jax.jit(runahead_mod.make_proxy_scorer(cfg))
            if self.tp > 1:
                # per-shard runahead rollups: the page axis is never
                # sharded, so one staging copy lands every shard's NSB —
                # mirror stage/drop into per-shard accounting twins
                self.tier_shards = capture.ShardedPageCache(
                    self.tp, self.nsb_slots)
                self._tier.mirrors.append(self.tier_shards)
        self.now = 0
        self._next_rid = 0
        self.requests: dict[int, Request] = {}
        # pipelined executor (executor="async"): prefill/decode streams
        # dispatch before either materialises, plans double-buffer via
        # schedule_speculative/commit, and runahead transfers ride the
        # overlap window.  The synchronous loop (_step_sync) stays as
        # the bitwise parity oracle.
        self.executor = executor
        if executor == "async":
            from .executor import PipelinedExecutor
            self._pipeline = PipelinedExecutor(self)
        else:
            self._pipeline = None

    # -- request lifecycle ---------------------------------------------------

    def submit(self, prompt, max_new_tokens: int,
               arrival: float | None = None,
               tenant: str = "default", priority: int = 0,
               session: int = -1, turn: int = 1,
               slo_ttft: float | None = None,
               slo_tpot: float | None = None) -> int:
        prompt = np.asarray(prompt, dtype=np.int64).reshape(-1)
        if len(prompt) + max_new_tokens > self.max_len:
            raise ValueError(
                f"prompt+gen {len(prompt)}+{max_new_tokens} exceeds "
                f"max_len {self.max_len}")
        if not len(prompt) or max_new_tokens < 1:
            raise ValueError("need a non-empty prompt and >=1 new token")
        need = self.allocator.pages_for_tokens(len(prompt) + max_new_tokens)
        if need > self.allocator.capacity:
            raise ValueError(
                f"request needs {need} pages but the pool only has "
                f"{self.allocator.capacity}: even a lone request could "
                "never finish (preemption cannot help)")
        rid = self._next_rid
        self._next_rid += 1
        req = Request(rid=rid, prompt=prompt,
                      max_new_tokens=max_new_tokens,
                      arrival=self.now if arrival is None else arrival,
                      tenant=tenant, priority=priority,
                      session=session, turn=turn,
                      slo_ttft=slo_ttft, slo_tpot=slo_tpot)
        self.requests[rid] = req
        self.scheduler.add(req)
        return rid

    def _finish_if_done(self, req: Request) -> None:
        if req.done:
            if req.session >= 0 and req.session in self._sessions:
                # before finish() frees the block table: the session
                # layer may adopt it as an idle hold for the next turn
                self._session_turn_done(req)
            self.scheduler.finish(req, self.now)
            self.stats.finished += 1
            if self._predictor is not None:
                self._predictor.forget(req.rid)
            if self._ep_predictor is not None:
                self._ep_predictor.forget(req.rid)

    # -- multi-turn sessions -------------------------------------------------
    #
    # A conversation's turn N+1 re-enters the front door carrying the
    # full history (turn N's prompt + generated tokens + fresh user
    # tokens) as its prompt.  Correctness never depends on what happened
    # to the old KV — prefix-cache attach, host-restore and recompute
    # all produce bitwise-identical logits — so the session layer is a
    # pure performance tier: with ``session_hold`` the finished turn's
    # pages stay pinned under a holder rid (registered in the prefix
    # index so the next turn attaches them), with ``idle_swap`` the hold
    # parks in the host spill tier between turns, and under page
    # pressure the scheduler's idle-eviction hook releases holds before
    # any live request is preempted.

    def _session_turn_done(self, req: Request) -> None:
        sess = self._sessions[req.session]
        sess["history"] = req.seq
        sess["hist_computed"] = req.computed
        if not sess["turns"]:
            del self._sessions[req.session]
            return
        if self.session_hold \
                and self.allocator.adopt_table(self._next_rid, req.rid):
            holder = self._next_rid
            self._next_rid += 1
            sess["holder"] = holder
            self._hold_order.append(req.session)
            self.stats.session_holds += 1
            swapped = False
            if self.idle_swap:
                # park the idle KV in the host tier right away; the
                # snapshot reads drain at the next iteration boundary,
                # before any pool write (same contract as preemption
                # swap-out)
                swapped = self.allocator.spill_request(holder)
                if swapped:
                    self.stats.idle_swap_outs += 1
            if not swapped:
                # publish the full sequence — prompt *and* generated
                # tokens — so the next turn's admission attaches it
                self.allocator.register_prefix(holder, sess["history"],
                                               req.computed)
        turn = sess["turns"].popleft()
        sess["next"] = (self.now + turn.think_time, turn)
        self._deferred.append(req.session)

    def _evict_idle_hold(self) -> bool:
        """The scheduler's idle-eviction hook: release one idle-session
        KV hold (oldest first) and return True, or False when no hold
        is pinning HBM pages.  Swap-out to the host tier is preferred —
        the session keeps its restore path; freeing is the fallback
        (registered pages park in the cached LRU, still attachable
        until evicted)."""
        for sid in self._hold_order:
            holder = self._sessions[sid].get("holder")
            if holder is None or self.allocator.is_spilled(holder):
                continue        # spilled holds pin no HBM pages
            if self.spill_pool is not None \
                    and self.allocator.spill_request(holder):
                self.stats.idle_swap_outs += 1
            else:
                self.allocator.free_request(holder)
                self._sessions[sid]["holder"] = None
                self._hold_order.remove(sid)
            self.stats.idle_evictions += 1
            return True
        return False

    def _submit_due_turns(self) -> None:
        due = [sid for sid in self._deferred
               if self._sessions[sid]["next"][0] <= self.now]
        # deterministic re-entry order: by due tick, then session id
        for sid in sorted(due, key=lambda s: (self._sessions[s]["next"][0],
                                              s)):
            self._deferred.remove(sid)
            self._start_next_turn(sid)

    def _start_next_turn(self, sid: int) -> None:
        sess = self._sessions[sid]
        tick, turn = sess.pop("next")
        hist = sess["history"]
        holder = sess.get("holder")
        if holder is not None:
            # an idle swap-out queued at the previous turn's finish may
            # still be awaiting its device->host snapshot read: drain it
            # before the restore below can reuse its source pages (and
            # before the restore reads the host slot it fills)
            self._apply_spill_outs()
            if self.allocator.is_spilled(holder) \
                    and self.allocator.resume_spilled(holder, 0):
                # restored byte-exact onto fresh page ids; republish so
                # this turn's admission attaches them (the copies
                # themselves drain before any compute reads them)
                self.stats.idle_swap_ins += 1
                self.allocator.register_prefix(holder, hist,
                                               sess["hist_computed"])
            # release the hold: restored/held pages drop to the cached
            # LRU (refcount 0, content registered) where admission
            # attaches them — or pressure evicts them, costing
            # recompute only.  An unrestorable snapshot (pool full) is
            # discarded; the turn re-prefills, still bitwise-identical.
            self.allocator.free_request(holder)
            # perform the queued restores *now*: once the holder's refs
            # drop, the next schedule() may hand the restored pages to
            # anyone — no restore may still be in flight when it does
            self._apply_swap_ins()
            sess["holder"] = None
            if sid in self._hold_order:
                self._hold_order.remove(sid)
        prompt = np.concatenate([hist, turn.user_tokens]) \
            if len(turn.user_tokens) else hist
        sess["turn"] = sess.get("turn", 1) + 1
        self.stats.turns_submitted += 1
        self.submit(prompt, turn.max_new_tokens, arrival=tick,
                    tenant=sess["tenant"], priority=sess["priority"],
                    session=sid, turn=sess["turn"],
                    slo_ttft=sess["slo_ttft"], slo_tpot=sess["slo_tpot"])

    def _apply_cow_copies(self) -> None:
        """Replay the allocator's pending copy-on-write page copies onto
        the physical pools (K, V, and page-summary planes), before any
        prefill/decode in this iteration reads the destination pages."""
        copies = self.allocator.drain_copies()
        if not copies:
            return
        src = np.asarray([s for s, _ in copies], dtype=np.int32)
        dst = np.asarray([d for _, d in copies], dtype=np.int32)
        if self._tier is not None:
            # COW destinations are about to carry fresh bytes: no staged
            # copy of their previous life may survive
            self._tier.invalidate(int(d) for d in dst)
        self.k_pool = self.k_pool.at[:, dst].set(self.k_pool[:, src])
        self.v_pool = self.v_pool.at[:, dst].set(self.v_pool[:, src])
        self.s_pool = self.s_pool.at[:, dst].set(self.s_pool[:, src])
        self._repin_pools()
        self.stats.cow_page_copies += len(copies)

    def _repin_pools(self) -> None:
        """Eager (non-donated) pool updates leave output sharding to
        propagation: re-pin so the next donated jit call sees the exact
        pool layout it expects (no-op when propagation already matched,
        and under tp=1)."""
        if self._pool_shardings is None:
            return
        self.k_pool = jax.device_put(self.k_pool, self._pool_shardings[0])
        self.v_pool = jax.device_put(self.v_pool, self._pool_shardings[1])
        self.s_pool = jax.device_put(self.s_pool, self._pool_shardings[2])

    # -- the host spill tier -------------------------------------------------

    def _apply_spill_outs(self) -> None:
        """Perform pending device->host page snapshots (swap-outs).

        Must run before *any* pool write of this iteration: a spilled
        source id is released the moment the scheduler swaps it out, so
        the same schedule can hand it to a COW copy, a swap-in, or a
        prefill as a destination — the snapshot read has to win."""
        if self.spill_pool is None:
            return
        moves = self.allocator.drain_spill_outs()
        if not moves:
            return
        pages = np.asarray([p for p, _ in moves], dtype=np.int32)
        slots = [s for _, s in moves]
        # pool-major [L, n, ...] -> slot-major [n, L, ...]
        k = np.asarray(self.k_pool[:, pages]).swapaxes(0, 1)
        v = np.asarray(self.v_pool[:, pages]).swapaxes(0, 1)
        s = np.asarray(self.s_pool[:, pages]).swapaxes(0, 1)
        self.spill_pool.store(slots, k, v, s)
        self.stats.swap_out_pages += len(moves)
        if self.recorder is not None:
            self.recorder.record(pages, step=self.now,
                                 tier=capture.TIER_HOST)

    def _apply_swap_ins(self) -> None:
        """Perform pending host->device restores (swap-ins) and carry
        the physical-id renames into the runahead predictor.

        Runs after spill-out reads and COW copies (both *read* pages a
        restore may be about to overwrite) and before any prefill or
        decode touches the restored pages."""
        if self.spill_pool is None:
            return
        moves = self.allocator.drain_swap_ins()
        if moves:
            slots = [s for s, _ in moves]
            pages = np.asarray([p for _, p in moves], dtype=np.int32)
            if self._tier is not None:
                # restored bytes land on re-taken ids: no staged copy
                # of a destination page's previous life may survive
                self._tier.invalidate(int(p) for p in pages)
            k, v, s = self.spill_pool.load(slots)
            self._spill_err = max(self._spill_err,
                                  self.spill_pool.error_bound(slots))
            self.k_pool = self.k_pool.at[:, pages].set(k.swapaxes(0, 1))
            self.v_pool = self.v_pool.at[:, pages].set(v.swapaxes(0, 1))
            self.s_pool = self.s_pool.at[:, pages].set(s.swapaxes(0, 1))
            self._repin_pools()
            self.stats.swap_in_pages += len(moves)
            if self.recorder is not None:
                self.recorder.record(pages, step=self.now,
                                     tier=capture.TIER_HOST)
        for rid, page_map in self.allocator.drain_remaps():
            if self._predictor is not None:
                self._predictor.remap(rid, page_map)

    def _dispatch_prefill(self, job: PrefillJob):
        """Dispatch one prefill chunk and return its (device-resident)
        logits without materialising them.

        Everything that must happen at *dispatch* time lives here: the
        staged-copy invalidation (the chunk rewrites KV on its pages),
        the jit call itself, the ``computed`` frontier advance, and the
        prefix registration — all host bookkeeping downstream scheduling
        depends on, none of it reading a sampled value.  The pipelined
        executor calls this for every chunk before blocking on any
        stream; :meth:`_commit_prefill` does the sampling."""
        req = job.req
        toks = np.zeros((self.chunk,), dtype=np.int32)
        toks[: job.n_tokens] = req.prompt[job.start:job.start + job.n_tokens]
        bt = self.allocator.table_array(req.rid, self.n_logical)
        if self._tier is not None:
            # the chunk rewrites KV (and summaries) on these pages:
            # staged copies of them are stale the moment the call runs
            tbl = self.allocator.table(req.rid)
            p0 = job.start // self.page
            p1 = (job.start + job.n_tokens - 1) // self.page
            self._tier.invalidate(tbl[p0:p1 + 1])
        logits, self.k_pool, self.v_pool, self.s_pool = self._prefill(
            self.params, self.k_pool, self.v_pool, self.s_pool,
            jnp.asarray(toks), np.int32(job.start), np.int32(job.n_tokens),
            jnp.asarray(bt))
        req.computed += job.n_tokens
        # whole prompt pages materialised by this chunk become
        # attachable by later requests with the same prefix
        self.allocator.register_prefix(req.rid, req.prompt,
                                       min(req.computed, req.prompt_len))
        self.stats.prefill_tokens += job.n_tokens
        self.stats.prefill_calls += 1
        return logits

    def _commit_prefill(self, job: PrefillJob, logits) -> None:
        """The prefill stream's sample/commit boundary: materialise the
        final chunk's logits and sample the first token."""
        req = job.req
        if req.computed == req.prompt_len:
            lg = np.asarray(logits)
            # first pass samples the first token here; a preemption
            # resume already holds it and moves on to decode replay
            if not req.out_tokens:
                req.out_tokens.append(int(lg.argmax()))
                req.first_token_at = self.now
                req.last_token_at = self.now
                req.token_ticks.append(self.now)
                req.last_logits = lg
                self.stats.tokens_out += 1
                if req.resumed_at >= 0:
                    # preempted before its first token: the resume gap
                    # ends at this prefill-produced token
                    req.resume_gaps.append(self.now - req.resumed_at)
                    req.resumed_at = -1.0
                self._finish_if_done(req)

    def _run_prefill(self, job: PrefillJob) -> None:
        self._commit_prefill(job, self._dispatch_prefill(job))

    def _dispatch_decode(self, pairs: list, rb: int):
        """Dispatch one decode batch over ``(row_slot, request)`` pairs
        and return its device-resident ``(logits, sel)``.

        The slot indirection is what lets the pipelined executor keep
        each request's decode row stable across iterations (maxtext-
        style per-slot insertion): a slot with no request behind it is a
        hole, and holes carry exactly the NULL-row padding the bucketed
        sync path pads with (token 0, pos 0, zeroed block table — every
        write lands on the reserved scratch page), so row placement
        never changes any occupied row's logits.  The synchronous loop
        passes the dense ``enumerate(rows)`` pairing."""
        token = np.zeros((rb,), dtype=np.int32)
        pos = np.zeros((rb,), dtype=np.int32)
        bts = np.zeros((rb, self.n_logical), dtype=np.int32)
        for slot, req in pairs:
            token[slot] = req.seq[req.computed]
            pos[slot] = req.computed
            bts[slot] = self.allocator.table_array(req.rid, self.n_logical)
        hot_args = ()
        if self._tier is not None:
            # frontier pages are written inside this call, but the
            # decode body write-throughs the new bytes into any staged
            # copy (see _paged_decode_fn), so their entries stay live —
            # snapshot the hot-map the gather will resolve through
            hot_args = (jnp.asarray(self._tier.hot_map().copy()),)
        if self.ep is not None:
            if self.expert_pool_mode == "dense":
                hot_args += (self._ep_rows,)
            else:
                hot_args += (self._ep_bt, self.ep.pool)
                if self._ep_tier is not None:
                    # expert tiles are read-only: staged copies never go
                    # stale, so the snapshot is only for dispatch-time
                    # consistency with the staging gather
                    hot_args += (self.ep.hot_map_device(),)
        out = self._decode(
            self.params, self.k_pool, self.v_pool, self.s_pool,
            jnp.asarray(token), jnp.asarray(pos), jnp.asarray(bts),
            *hot_args)
        if self.ep is not None:
            logits, self.k_pool, self.v_pool, self.s_pool, sel, esel = out
        else:
            logits, self.k_pool, self.v_pool, self.s_pool, sel = out
            esel = None
        return logits, sel, esel

    def _commit_decode(self, pairs: list, logits, sel, rb: int,
                       esel=None) -> None:
        """The decode stream's sample/commit boundary.

        Commits run in *plan order* (the order ``pairs`` carries), not
        slot order: request finishes free pages through the allocator's
        LIFO free list, so commit order is observable in later physical
        page assignment — plan order is what the synchronous loop uses,
        and following it keeps the async executor's allocator state
        bitwise-identical, not just its tokens."""
        lg = np.asarray(logits)
        sel0 = np.asarray(sel[0])                    # layer-0 [R,KV,K]
        kv_l = self.cfg.n_kv_heads // self.tp        # KV heads per shard
        for slot, req in pairs:
            frontier = req.computed == req.total_len - 1
            req.computed += 1
            self.stats.decode_tokens += 1
            if self.recorder is not None:
                # a request with fewer valid pages than the TopK budget
                # pads its selection with NULL (masked in attention, no
                # data fetched) — drop those from the traffic record.
                # Under TP the event is tagged with the shard whose KV
                # heads produced it (heads shard in contiguous slices).
                for h, head_sel in enumerate(sel0[slot]):
                    self.recorder.record(
                        head_sel[head_sel != NULL_PAGE],
                        rid=req.rid, step=self.now,
                        shard=h // kv_l if self.tp > 1 else -1,
                        tier=capture.TIER_HBM)
            if frontier:
                req.out_tokens.append(int(lg[slot].argmax()))
                req.last_logits = lg[slot].copy()
                req.last_token_at = self.now
                req.token_ticks.append(self.now)
                self.stats.tokens_out += 1
                if req.resumed_at >= 0:
                    # resume-TTFT sample: re-admission (swap or
                    # recompute) to the next *new* token
                    req.resume_gaps.append(self.now - req.resumed_at)
                    req.resumed_at = -1.0
                self._finish_if_done(req)
        self.stats.decode_rows_padded += rb - len(pairs)
        # NSB accounting over the iteration's unique physical pages —
        # indexed by occupied slots, so hole rows (all-NULL selections)
        # never enter; np.unique sorts, making the touch order a
        # function of the page *set* alone, identical however the
        # executor placed rows
        occ = np.asarray([slot for slot, _ in pairs], dtype=np.int64)
        uniq = np.unique(sel0[occ])
        uniq = uniq[uniq != NULL_PAGE]
        self._seen_pages.update(int(p) for p in uniq)
        self.stats.pages_unique = len(self._seen_pages)
        for p in uniq:
            self.stats.pages_touched += 1
            # the demand-LRU model is always scored: with runahead on it
            # is the in-run no-runahead comparator (nsb_demand_lru_hit_rate)
            lru_hit = self.hot.touch(int(p))
            hit = (self._tier.touch(int(p)) if self._tier is not None
                   else lru_hit)
            if hit:
                self.stats.nsb_hits += 1
            else:
                self.stats.nsb_misses += 1
        if self.hot_shards is not None:
            # per-shard NSBs see only their own KV heads' selections
            for s in range(self.tp):
                su = np.unique(sel0[occ][:, s * kv_l:(s + 1) * kv_l])
                for p in su[su != NULL_PAGE]:
                    self.hot_shards.touch(int(p), s)
                    if self.tier_shards is not None:
                        self.tier_shards.touch(int(p), s, install=False)
        if self._predictor is not None:
            # per-request history for the next prediction round (layer-0
            # selections — the repo's traffic-proxy convention)
            for slot, req in pairs:
                rp = np.unique(sel0[slot])
                self._predictor.observe(req.rid, rp[rp != NULL_PAGE])
        if esel is not None:
            self._account_expert_pages(pairs, np.asarray(esel), occ)

    def _account_expert_pages(self, pairs: list, es: np.ndarray,
                              occ: np.ndarray) -> None:
        """Expert-tile demand accounting for one committed decode step.

        ``es`` is the step's routed expert ids ``[L, R, top_k]``.  Every
        routed (request, layer, expert) demands the expert's full tile
        range (3 planes x NT pages); traffic is recorded per request
        (tier-tagged HBM demand), fed to the per-request history
        predictor across *all* layers, and scored — by unique page over
        the whole step, np.unique-sorted so the touch order is a
        function of the page set alone — against both the staging tier
        (when live) and the always-on demand-LRU comparator."""
        ep = self.ep
        layers = range(ep.n_layers)
        for slot, req in pairs:
            pages = np.concatenate(
                [ep.pages_for_experts(li, es[li, slot]) for li in layers])
            if self.ep_recorder is not None:
                self.ep_recorder.record(pages, rid=req.rid,
                                        step=self.now,
                                        tier=capture.TIER_HBM)
            if self._ep_predictor is not None:
                self._ep_predictor.observe(req.rid, np.unique(pages))
        uniq = np.unique(np.concatenate(
            [ep.pages_for_experts(li, es[li, occ]) for li in layers]))
        for p in uniq:
            self.stats.expert_pages_touched += 1
            lru_hit = self.ep_hot.touch(int(p))
            hit = (self._ep_tier.touch(int(p))
                   if self._ep_tier is not None else lru_hit)
            if hit:
                self.stats.expert_nsb_hits += 1
            else:
                self.stats.expert_nsb_misses += 1

    def _run_decode(self, rows: list, bucket: int = 0) -> None:
        # ragged batches pad to the scheduler's power-of-two row bucket
        # (NULL block tables, scratch-page scribbles) instead of always
        # to max_batch: O(log R_max) distinct decode traces, and the
        # padded compute shrinks with the actual batch
        rb = bucket or self.max_batch
        pairs = list(enumerate(rows))
        logits, sel, esel = self._dispatch_decode(pairs, rb)
        self._commit_decode(pairs, logits, sel, rb, esel)

    # -- iteration loop ------------------------------------------------------

    def step(self) -> int:
        """One scheduler iteration; returns scheduled token count.

        Dispatches to the pipelined executor when constructed with
        ``executor="async"`` (see :mod:`.executor`); the synchronous
        loop below is the bitwise parity oracle both paths answer to.
        """
        if self._pipeline is not None:
            return self._pipeline.step()
        return self._step_sync()

    def _step_sync(self) -> int:
        """The synchronous step loop: schedule, drain transfers, run
        prefill then decode to completion, then the runahead stage —
        every phase strictly ordered on the host.

        With runahead on, the iteration ends with the speculative
        stage: predict each live request's next-iteration TopK pages
        (history for stable selections, the layer-0 proxy slice for the
        rest), stage them into the NSB tail with one async-dispatched
        gather, and let the *next* decode resolve through the updated
        hot-map — the paper's decoupled runahead sub-thread, riding the
        host-side gap while the device drains this iteration's work.
        """
        self.now += 1
        self.stats.iterations += 1
        plan = self.scheduler.schedule(self.now)
        # strict transfer order: snapshot reads (swap-outs) before any
        # pool write, COW copies next, restores (swap-ins) last, all
        # before compute — see the individual method docstrings
        self._apply_spill_outs()
        if self._tier is not None:
            # pages whose last reference dropped since the previous
            # iteration (preemption, finish, COW release) may be
            # re-taken and rewritten at any point: staged copies of
            # their old content must never resolve again
            self._tier.invalidate(self.allocator.drain_released())
        self._apply_cow_copies()
        self._apply_swap_ins()
        for job in plan.prefill:
            self._run_prefill(job)
        if plan.decode:
            self._run_decode(plan.decode, plan.decode_bucket)
            self.stats.steps += 1
        if ((self._tier is not None or self._ep_tier is not None)
                and plan.runahead_budget > 0):
            self._run_runahead(plan)
        self._account_streams(plan)
        self.stats.preemptions = self.scheduler.n_preemptions
        return plan.n_tokens

    def _account_streams(self, plan) -> None:
        """Per-stream iteration accounting, shared by both executors so
        their iteration logs compare like with like."""
        n_p, n_d = len(plan.prefill), len(plan.decode)
        if n_p:
            self.stats.prefill_iterations += 1
        if n_d:
            self.stats.decode_iterations += 1
        if n_p and n_d:
            self.stats.overlap_iterations += 1
        self.stats.iter_log.append((n_p, n_d))

    def _run_runahead(self, plan, fetched=_FETCH_UNSET) -> None:
        """The between-steps runahead stage, per staging tier: the KV
        tier's predict/filter/stage (plus fetch-back) when KV runahead
        is on, then the expert-weight tier's router-keyed stage when
        expert runahead is on — both riding the same decode-stream
        budget window."""
        if self._tier is not None:
            self._run_kv_runahead(plan, fetched)
        if self._ep_tier is not None:
            self._run_expert_runahead(plan)

    def _run_kv_runahead(self, plan, fetched=_FETCH_UNSET) -> None:
        """The between-steps KV runahead stage: predict, filter, stage.

        Candidates are every request decoding next iteration — the
        rows just decoded plus requests that completed prefill this
        iteration (whose first decode selection is exactly what a
        demand-installed NSB always cold-misses).  The DARE-style
        filter routes stable selections to their history predictor and
        only the rest through the proxy scorer; staged pages land in
        the pool tail via one fixed-shape donated gather.  Everything
        here is speculative: it steers where bytes are *read from*
        next iteration, never what is computed.

        ``fetched``: the pipelined executor performs :meth:`_fetch_back`
        in its overlap window (while the device drains the dispatched
        streams) and passes the result here; the synchronous loop leaves
        it unset and fetch-back runs inline.
        """
        tier, pred = self._tier, self._predictor
        pages: list = []
        # fetch-back: a spilled queue head swap-resumes inside this
        # window (host -> HBM), and its remapped history pages go to
        # the *front* of the staging list (HBM -> NSB) — so the first
        # post-resume demand gather never touches a host page
        if fetched is _FETCH_UNSET:
            fetched = self._fetch_back()
        if fetched is not None and not fetched.done:
            hist = list(pred.history(fetched.rid))
            pages.extend(hist)
            if self.recorder is not None and hist:
                self.recorder.record(np.asarray(hist, dtype=np.int64),
                                     rid=fetched.rid, step=self.now,
                                     tier=capture.TIER_NSB)
        cands = [r for r in plan.decode if not r.done]
        seen = {r.rid for r in cands}
        for job in plan.prefill:
            req = job.req
            if (not req.done and req.rid not in seen
                    and req.computed >= req.prompt_len
                    and req.rid in self.allocator._tables):
                cands.append(req)
                seen.add(req.rid)
        if not cands and not pages:
            return
        if cands:
            covered, proxy = pred.split([r.rid for r in cands])
            tier.stats.filtered_rows += len(covered)
            for rid in covered:
                pages.extend(pred.history(rid))
            if proxy and self._proxy is not None:
                pages.extend(self._predict_proxy(
                    [self.requests[rid] for rid in proxy]))
        copies = tier.stage(pages, max_copies=plan.runahead_budget)
        if not copies:
            return
        # fixed-shape staging gather: pad with (0, 0) — a self-copy of
        # the reserved scratch page, value-identical — so the jit
        # compiles once for any copy count
        src = np.zeros((max(1, self.runahead_pages),), dtype=np.int32)
        dst = np.zeros((max(1, self.runahead_pages),), dtype=np.int32)
        for j, (s, slot) in enumerate(copies):
            src[j] = s
            dst[j] = self.n_pages + slot
        self.k_pool, self.v_pool = self._stage(
            self.k_pool, self.v_pool, jnp.asarray(src), jnp.asarray(dst))
        tier.stats.stage_calls += 1

    def _fetch_back(self):
        """Runahead-window early swap-resume of the spilled queue head.

        ``_admit`` would resume it at the *next* ``schedule()`` anyway;
        doing it here moves the host->device restore into the same
        between-steps window the staging gather rides (the decoupled
        sub-thread's budget), one iteration ahead of demand.  The resume
        follows ``_admit``'s exact state transitions — all-or-nothing
        restore, FIFO head only, ``max_running`` respected — so the
        schedule a fetch-back produces is one the admission path could
        also have produced.  Returns the resumed request, or None.
        """
        sched = self.scheduler
        if (self.spill_pool is None or not sched.waiting
                or len(sched.running) >= sched.max_running):
            return None
        # the candidate is whoever the *policy* would admit first —
        # under FIFO that is exactly the queue head, so the historic
        # behaviour is unchanged; under fairness/priority policies the
        # fetch-back restores the same request _admit would pick next
        head = sched.policy.admit_order(list(sched.waiting), self.now)[0]
        if not head.spilled or not self.allocator.resume_spilled(
                head.rid, max(head.prompt_len, head.computed)):
            return None
        # pending idle-session snapshot reads must land before this
        # restore writes pool pages (no-op without the session layer)
        self._apply_spill_outs()
        sched.waiting.remove(head)
        head.spilled = False
        head.state = RequestState.RUNNING
        if head.n_preemptions > 0:
            head.resumed_at = self.now
        sched.running.append(head)
        sched.n_swap_ins += 1
        sched.policy.on_admit(head, self.now)
        self.stats.fetch_backs += 1
        # the restore itself rides this window too, not the next step's
        self._apply_swap_ins()
        return head

    def _predict_proxy(self, reqs: list) -> list:
        """Run the layer-0 proxy scorer over ``reqs`` and return their
        predicted next-step physical pages (padded rows and NULL-page
        selections filtered out)."""
        tier = self._tier
        tier.stats.proxy_rows += len(reqs)
        out: list = []
        mb = self.max_batch
        for i0 in range(0, len(reqs), mb):
            grp = reqs[i0:i0 + mb]
            rb = (scheduler_mod.bucket_for(len(grp), self.row_buckets)
                  if self.row_buckets else mb)
            token = np.zeros((rb,), dtype=np.int32)
            pos = np.zeros((rb,), dtype=np.int32)
            bts = np.zeros((rb, self.n_logical), dtype=np.int32)
            nv = np.ones((rb,), dtype=np.int32)
            for i, req in enumerate(grp):
                token[i] = req.seq[req.computed]
                pos[i] = req.computed
                bts[i] = self.allocator.table_array(req.rid,
                                                    self.n_logical)
                nv[i] = pos[i] // self.page + 1
            phys = np.asarray(self._proxy(
                self.params, self.s_pool, jnp.asarray(token),
                jnp.asarray(pos), jnp.asarray(bts), jnp.asarray(nv)))
            for i in range(len(grp)):
                u = np.unique(phys[i])
                out.extend(int(p) for p in u if p != NULL_PAGE)
        return out

    def _run_expert_runahead(self, plan) -> None:
        """The expert-weight runahead stage: stage the tile pages the
        next decode step's routing will demand.

        Candidates are the requests decoding next iteration (rows just
        decoded plus prefill completions entering decode).  The
        DARE-style filter routes requests whose routed-expert selection
        has stabilised to their history predictor — covering *all*
        layers' tiles — and only the rest through the router scorer,
        which predicts layer-0 routing from each row's known next
        token (:func:`runahead.make_router_scorer`).  Staged tiles are
        byte-exact copies of read-only weights: no invalidation path
        exists or is needed, and a misprediction costs staging
        bandwidth, never a logit."""
        tier, pred = self._ep_tier, self._ep_predictor
        cands = [r for r in plan.decode if not r.done]
        seen = {r.rid for r in cands}
        for job in plan.prefill:
            req = job.req
            if (not req.done and req.rid not in seen
                    and req.computed >= req.prompt_len):
                cands.append(req)
                seen.add(req.rid)
        if not cands:
            return
        covered, proxy = pred.split([r.rid for r in cands])
        tier.stats.filtered_rows += len(covered)
        pages: list = []
        for rid in covered:
            pages.extend(pred.history(rid))
        if proxy:
            pages.extend(self._predict_router(
                [self.requests[rid] for rid in proxy]))
        copies = tier.stage(pages, max_copies=self.expert_runahead_pages)
        if not copies:
            return
        # fixed-shape staging gather, (0, 0) scratch-page self-copies
        # as padding — compiles once for any copy count
        src = np.zeros((max(1, self.expert_runahead_pages),),
                       dtype=np.int32)
        dst = np.zeros_like(src)
        for j, (s, slot) in enumerate(copies):
            src[j] = s
            dst[j] = self.ep.n_pages + slot
        self.ep.pool = self._ep_stage(self.ep.pool, jnp.asarray(src),
                                      jnp.asarray(dst))
        tier.stats.stage_calls += 1
        if self.ep_recorder is not None:
            self.ep_recorder.record(
                np.asarray([s for s, _ in copies], dtype=np.int64),
                step=self.now, tier=capture.TIER_NSB)

    def _predict_router(self, reqs: list) -> list:
        """Run the router scorer over ``reqs`` and return the predicted
        layer-0 expert tile pages (the proxy's reach; deeper layers are
        the history predictor's job)."""
        tier = self._ep_tier
        tier.stats.proxy_rows += len(reqs)
        out: list = []
        mb = self.max_batch
        for i0 in range(0, len(reqs), mb):
            grp = reqs[i0:i0 + mb]
            rb = (scheduler_mod.bucket_for(len(grp), self.row_buckets)
                  if self.row_buckets else mb)
            token = np.zeros((rb,), dtype=np.int32)
            for i, req in enumerate(grp):
                token[i] = req.seq[req.computed]
            eids = np.asarray(self._router_proxy(self.params,
                                                 jnp.asarray(token)))
            for i in range(len(grp)):
                out.extend(int(p) for p in
                           self.ep.pages_for_experts(0, eids[i]))
        return out

    def _submit_item(self, item) -> int:
        """Front-door entry for a workload.WorkItem: submit turn 1 and
        register the session when follow-up turns exist (they re-enter
        via :meth:`_submit_due_turns` after the previous turn finishes
        plus think time — a closed loop, like a real user)."""
        sid = -1
        if item.turns:
            sid = self._next_sid
            self._next_sid += 1
            self._sessions[sid] = {
                "turns": deque(item.turns), "holder": None,
                "history": None, "hist_computed": 0, "turn": 1,
                "tenant": item.tenant, "priority": item.priority,
                "slo_ttft": item.slo_ttft, "slo_tpot": item.slo_tpot,
            }
        return self.submit(item.prompt, item.max_new_tokens,
                           arrival=item.arrival, tenant=item.tenant,
                           priority=item.priority, session=sid,
                           slo_ttft=item.slo_ttft,
                           slo_tpot=item.slo_tpot)

    def run(self, workload=None, max_iters: int = 100000) -> dict:
        """Drive ``workload`` to completion; returns the request table.

        Items are either legacy ``(tick, prompt, max_new)`` tuples or
        :class:`~repro.serve.workload.WorkItem` rows (tenant, priority,
        SLOs, multi-turn conversations).  Multi-turn items are
        closed-loop: each follow-up turn is submitted only after the
        previous turn finishes plus its think time, carrying the full
        conversation history as its prompt.
        """
        def _tick(w):
            return w[0] if isinstance(w, tuple) else w.arrival
        pending = deque(sorted(workload or [], key=_tick))
        while (pending or self._deferred or self.scheduler.has_work):
            if max_iters <= 0:
                raise RuntimeError("run() exceeded max_iters")
            max_iters -= 1
            while pending and _tick(pending[0]) <= self.now:
                item = pending.popleft()
                if isinstance(item, tuple):
                    tick, prompt, max_new = item
                    self.submit(prompt, max_new, arrival=tick)
                else:
                    self._submit_item(item)
            self._submit_due_turns()
            self.step()
        return self.requests

    # -- reporting -----------------------------------------------------------

    def captured_trace(self):
        """Recorded multi-tenant page traffic as a simulator Trace."""
        if self.recorder is None:
            raise RuntimeError("construct PagedEngine with "
                               "capture_trace=True to record selections")
        return self.recorder.to_trace()

    @staticmethod
    def _trace_count(jitted) -> int:
        """Compilation count of a jitted function, via the (private)
        jax cache-size hook; -1 if a jax upgrade removes it — metrics
        must degrade, not raise."""
        try:
            return int(jitted._cache_size())
        except AttributeError:
            return -1

    def n_decode_traces(self) -> int:
        """Distinct decode-step compilations so far: one per row bucket
        actually used (bucketing caps this at O(log max_batch); padding
        every batch to max_batch pins it at 1 but wastes the padded
        rows' compute)."""
        return self._trace_count(self._decode)

    def n_prefill_traces(self) -> int:
        """Distinct prefill-chunk compilations (fixed chunk shape: 1)."""
        return self._trace_count(self._prefill)

    def metrics(self) -> dict:
        done = [r for r in self.requests.values()
                if r.finished_at >= 0]
        # the accessors are None-guarded (an unfinished request has no
        # latency, a one-token request no inter-token gap): filter, so
        # percentiles never mix sentinel negatives into the tail
        lat = [x for x in (r.latency() for r in done) if x is not None]
        ttft = [x for x in (r.ttft() for r in done) if x is not None]
        tpot = [x for x in (r.tpot() for r in done) if x is not None]
        out = {
            "n_finished": len(done),
            "iterations": self.stats.iterations,
            "tokens_out": self.stats.tokens_out,
            "p50_latency": percentile(lat, 0.50),
            "p99_latency": percentile(lat, 0.99),
            "p50_ttft": percentile(ttft, 0.50),
            "p99_ttft": percentile(ttft, 0.99),
            "p50_tpot": percentile(tpot, 0.50),
            "p99_tpot": percentile(tpot, 0.99),
            "executor": self.executor,
            "prefill_iterations": self.stats.prefill_iterations,
            "decode_iterations": self.stats.decode_iterations,
            "overlap_iterations": self.stats.overlap_iterations,
            "overlap_fraction": (
                self.stats.overlap_iterations / self.stats.iterations
                if self.stats.iterations else None),
            "nsb_hot_hit_rate": self.stats.hot_hit_rate,
            "offchip_fetch_reduction": self.stats.offchip_reduction,
            "tp": self.tp,
            "preemptions": self.stats.preemptions,
            "pages_peak_in_use": self.allocator.stats.peak_in_use,
            "kv_pool_mib": self.pool_cfg.pool_bytes / 2 ** 20,
            "kv_pool_mib_per_shard":
                self.pool_cfg.pool_bytes / 2 ** 20 / self.tp,
            "prefill_tokens_run": self.stats.prefill_tokens,
            "prefill_tokens_skipped": self.scheduler.prefill_tokens_skipped,
            "prefix_hit_pages": self.allocator.stats.prefix_hits,
            "prefix_evictions": self.allocator.stats.prefix_evictions,
            "cow_copies": self.allocator.stats.cow_copies,
            "n_decode_traces": self.n_decode_traces(),
            "n_prefill_traces": self.n_prefill_traces(),
            "decode_rows_padded": self.stats.decode_rows_padded,
        }
        # double-buffered plan quality (async executor; zeros under sync)
        sch = self.scheduler
        out["plan_commits"] = sch.plan_commits
        out["plan_repairs"] = sch.plan_repairs
        out["plan_reuse_fraction"] = (
            sch.plan_reuse / sch.plan_commits if sch.plan_commits
            else None)
        # resume-TTFT: re-admission to next new token, both policies —
        # the swap-vs-recompute headline spill_bench compares
        gaps = [g for r in self.requests.values() for g in r.resume_gaps]
        out["n_resumes"] = len(gaps)
        out["p50_resume_ttft"] = percentile(gaps, 0.50)
        out["p99_resume_ttft"] = percentile(gaps, 0.99)
        out["spill_pages"] = self.allocator.spill_pages
        if self.spill_pool is not None:
            out["swap_outs"] = self.scheduler.n_swap_outs
            out["swap_ins"] = self.scheduler.n_swap_ins
            out["swap_out_pages"] = self.stats.swap_out_pages
            out["swap_in_pages"] = self.stats.swap_in_pages
            out["spill_fallbacks"] = self.allocator.stats.spill_failures
            out["fetch_backs"] = self.stats.fetch_backs
            out["spill_host_mib"] = self.spill_pool.host_bytes / 2 ** 20
            out["spill_compressed"] = self.spill_pool.compress
            out["spill_dequant_error_bound"] = self._spill_err
        if self.hot_shards is not None:
            roll = self.hot_shards.rollup()
            out["nsb_shard_hit_rates"] = roll["per_shard"]
            out["nsb_shard_rollup_hit_rate"] = roll["hit_rate"]
        out["runahead_mode"] = self.runahead
        if self._tier is not None:
            t = self._tier
            out["nsb_staging_slots"] = self.nsb_slots
            out["runahead_staged_pages"] = t.stats.staged_pages
            out["runahead_stage_calls"] = t.stats.stage_calls
            out["runahead_invalidations"] = t.stats.invalidations
            out["runahead_proxy_rows"] = t.stats.proxy_rows
            out["runahead_filtered_rows"] = t.stats.filtered_rows
            out["runahead_accuracy"] = t.accuracy
            out["runahead_coverage"] = t.coverage
            out["runahead_overfetch"] = t.overfetch
            # the same demand traffic scored against a demand-install
            # LRU NSB of the same class: the in-run baseline the
            # runahead hit rate (nsb_hot_hit_rate above) is lifted over
            out["nsb_demand_lru_hit_rate"] = self.hot.hit_rate
            if self.tier_shards is not None:
                out["runahead_shard_hit_rates"] = \
                    self.tier_shards.hit_rates()
        out["expert_pool"] = self.expert_pool_mode
        if self.ep is not None:
            out["expert_pool_pages"] = self.ep.n_pages
            out["expert_pool_mib"] = self.ep.pool_bytes / 2 ** 20
            out["expert_tile_rows"] = self.ep.tile_rows
            out["expert_pages_touched"] = self.stats.expert_pages_touched
            out["expert_nsb_hit_rate"] = self.stats.expert_hot_hit_rate
            # the same demand traffic scored against a demand-install
            # LRU of the tier's capacity — the in-run baseline the
            # router-keyed hit rate is lifted over
            out["expert_demand_lru_hit_rate"] = self.ep_hot.hit_rate
            out["expert_runahead_mode"] = self.expert_runahead
        if self._ep_tier is not None:
            t = self._ep_tier
            out["expert_nsb_slots"] = self.ep.nsb_slots
            out["expert_staged_pages"] = t.stats.staged_pages
            out["expert_stage_calls"] = t.stats.stage_calls
            out["expert_proxy_rows"] = t.stats.proxy_rows
            out["expert_filtered_rows"] = t.stats.filtered_rows
            out["expert_runahead_accuracy"] = t.accuracy
            out["expert_runahead_coverage"] = t.coverage
            out["expert_runahead_overfetch"] = t.overfetch
        # front-door rollups: SLO attainment over requests that carry
        # deadlines (None when nothing does), plus per-tenant/per-class
        # slices of the same finished-request percentiles
        out["policy"] = self.scheduler.policy.name
        slos = [x for x in (r.slo_attained()
                            for r in self.requests.values())
                if x is not None]
        out["slo_attainment"] = (sum(slos) / len(slos)) if slos else None

        def _rollup(group) -> dict:
            per = {}
            for key, rs in sorted(group.items()):
                g_done = [r for r in rs if r.finished_at >= 0]
                g_ttft = [x for x in (r.ttft() for r in g_done)
                          if x is not None]
                g_slo = [x for x in (r.slo_attained() for r in rs)
                         if x is not None]
                per[key] = {
                    "n_finished": len(g_done),
                    "p50_ttft": percentile(g_ttft, 0.50),
                    "p99_ttft": percentile(g_ttft, 0.99),
                    "slo_attainment": (sum(g_slo) / len(g_slo)
                                       if g_slo else None),
                }
            return per

        by_tenant: dict[str, list] = {}
        by_class: dict[int, list] = {}
        for r in self.requests.values():
            by_tenant.setdefault(r.tenant, []).append(r)
            by_class.setdefault(r.priority, []).append(r)
        if len(by_tenant) > 1 or "default" not in by_tenant:
            out["per_tenant"] = _rollup(by_tenant)
        if len(by_class) > 1 or 0 not in by_class:
            out["per_class"] = _rollup(by_class)
        if self.session_hold or self.stats.turns_submitted:
            out["session_holds"] = self.stats.session_holds
            out["turns_submitted"] = self.stats.turns_submitted
            out["idle_swap_outs"] = self.stats.idle_swap_outs
            out["idle_swap_ins"] = self.stats.idle_swap_ins
            out["idle_evictions"] = self.stats.idle_evictions
            out["pages_session_held"] = \
                self.allocator.pages_session_held
        return out
