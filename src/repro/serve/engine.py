"""Batched serving engine with NVR sparse-KV decode.

Request lifecycle: enqueue -> batched prefill -> step-wise decode with
TopK-page sparse attention (the paper's Double-Sparsity/H2O use case).

The engine tracks per-step *page traffic* — which KV pages the selection
touched — and scores it against an NSB model.  The NSB accounting is
backed by the shared simulator memory model
(:class:`repro.core.nvr.capture.PageCache`, a fully-associative
:class:`repro.core.nvr.machine.Cache` over page ids), so the serving layer
and the cycle-level simulator share one notion of hot-set reuse instead of
two implementations that can drift.  ``stats()`` reports the measured
page-reuse rate and the implied off-chip fetch reduction, mirroring
Fig. 6(c)/Fig. 8 of the paper at the serving layer (this container is
CPU-only, so these are traffic counts, not wall-clock).

With ``capture_trace=True`` the engine additionally records every TopK
page selection into a :class:`~repro.core.nvr.capture.PageStream`;
``captured_trace()`` lowers the recorded traffic into a simulator
``Trace``, closing the capture -> simulate loop: a real decode run can be
replayed under inorder/ooo/stream/imp/dvr/nvr to see what NVR buys on
*this* traffic rather than on a synthetic generator.
"""

from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp
import numpy as np

from ..configs.base import ArchConfig
from ..core.nvr import capture
from ..models import api, sparse_attention, transformer


@dataclass
class ServeStats:
    steps: int = 0
    pages_touched: int = 0
    pages_unique: int = 0
    nsb_hits: int = 0
    nsb_misses: int = 0
    tokens_out: int = 0

    @property
    def hot_hit_rate(self) -> float:
        tot = self.nsb_hits + self.nsb_misses
        return self.nsb_hits / tot if tot else float("nan")

    @property
    def offchip_reduction(self) -> float:
        """Fetch reduction from the NSB hot-set (1 = everything reused)."""
        return self.hot_hit_rate


class Engine:
    def __init__(self, cfg: ArchConfig, params, max_len: int = 1024,
                 sparse: bool = True, nsb_pages: int = 64,
                 capture_trace: bool = False,
                 kv_dtype_bytes: int = 2) -> None:
        self.cfg = cfg
        self.params = params
        self.max_len = max_len
        self.sparse = sparse and cfg.sparse_kv
        self.stats = ServeStats()
        # NSB hot-set accounting on the shared simulator cache model
        self.hot = capture.PageCache(nsb_pages)
        self._seen_pages: set[int] = set()
        self.recorder = None
        if capture_trace and self.sparse:
            self.recorder = capture.kv_page_stream(
                f"serve-{cfg.name}", n_pages=max_len // cfg.kv_page,
                page_tokens=cfg.kv_page, head_dim=cfg.hd,
                dtype_bytes=kv_dtype_bytes)
        self._decode = jax.jit(
            lambda p, c, t: api.decode_fn(cfg, p, c, t, sparse=self.sparse))
        self.cache = None
        self._last = None

    def prefill(self, batch: dict) -> jax.Array:
        logits, cache = api.prefill_fn(self.cfg, self.params, batch,
                                       remat="none")
        self.cache = self._pad_cache(cache)
        self._last = jnp.argmax(logits, axis=-1)
        return self._last

    def _pad_cache(self, cache: dict) -> dict:
        cfg = self.cfg
        l, b, s, kv, hd = cache["k"].shape
        pad = self.max_len - s
        if pad <= 0:
            return cache
        z = jnp.zeros((l, b, pad, kv, hd), cache["k"].dtype)
        out = dict(cache)
        out["k"] = jnp.concatenate([cache["k"], z], axis=2)
        out["v"] = jnp.concatenate([cache["v"], z], axis=2)
        if "kpage" in cache:
            npad = self.max_len // cfg.kv_page - cache["kpage"].shape[2]
            out["kpage"] = jnp.concatenate(
                [cache["kpage"],
                 jnp.zeros((l, b, npad, kv, hd), jnp.float32)], axis=2)
        return out

    def _track_pages(self) -> None:
        """NSB accounting: which pages would the next step's selection
        touch (layer-0 scorer as the traffic proxy)."""
        cfg = self.cfg
        cache = self.cache
        if "kpage" not in cache:
            return
        kp0 = cache["kpage"][0]
        b = kp0.shape[0]
        q = jnp.ones((b, cfg.n_kv_heads, cfg.n_heads // cfg.n_kv_heads,
                      cfg.hd), kp0.dtype)
        n_valid = cache["pos"] // cfg.kv_page + 1
        k_pages = min(cfg.kv_topk_pages, kp0.shape[1])
        if self.recorder is not None:
            idx = np.asarray(sparse_attention.select_pages_recorded(
                q, kp0, n_valid, k_pages, self.recorder))
        else:
            idx = np.asarray(sparse_attention.select_pages(
                q, kp0, n_valid, k_pages))
        uniq = np.unique(idx)
        self._seen_pages.update(int(p) for p in uniq)
        self.stats.pages_unique = len(self._seen_pages)  # run footprint
        for p in uniq:
            self.stats.pages_touched += 1
            if self.hot.touch(int(p)):
                self.stats.nsb_hits += 1
            else:
                self.stats.nsb_misses += 1

    def step(self) -> jax.Array:
        if self.sparse:
            self._track_pages()
        logits, self.cache = self._decode(self.params, self.cache,
                                          self._last)
        self._last = jnp.argmax(logits, axis=-1)
        self.stats.steps += 1
        self.stats.tokens_out += int(self._last.shape[0])
        return self._last

    def generate(self, batch: dict, n_steps: int) -> np.ndarray:
        toks = [self.prefill(batch)]
        for _ in range(n_steps - 1):
            toks.append(self.step())
        return np.stack([np.asarray(t) for t in toks], axis=1)

    def captured_trace(self):
        """The decode run's recorded page traffic as a simulator Trace
        (requires ``capture_trace=True`` and at least one sparse step)."""
        if self.recorder is None:
            raise RuntimeError(
                "no trace recorder: construct the Engine with "
                "capture_trace=True AND the sparse-KV path enabled "
                "(sparse=True and cfg.sparse_kv) to record selections")
        return self.recorder.to_trace()
