"""Serving engines with NVR sparse-KV decode.

Two engines share one memory-system story:

:class:`Engine` — the single-batch baseline.  One fixed batch prefills
together and decodes in lockstep; no new request joins until the batch
drains.  Kept as the reference point ``benchmarks/serve_bench.py``
measures continuous batching against.

:class:`PagedEngine` — the continuous-batching engine.  Requests arrive
through an admission queue (:mod:`.scheduler`), an iteration-level
scheduler mixes prefill chunks and decode steps under a token budget, and
the KV cache is a pool of physical pages managed by
:class:`.kv_allocator.KVBlockAllocator` (block table per request,
free-list, preempt-and-evict under pressure).  The step loop is the
repo's serving fast path: pool buffers are *donated* into the decode and
prefill jits (no per-call pool copy), ragged decode batches pad to
power-of-two row buckets (O(log max_batch) traces, padded compute that
tracks the live batch), and the decode attention can run either the XLA
gather oracle or the fused Pallas runahead kernel
(``kernels.paged_decode_attn``) on the same pool layout.  The *physical page id* is
the shared currency across layers: the TopK paged-attention gather
(``sparse_attention.select_pages_blocktable``), the NSB hot-set
accounting (``capture.PageCache``), and the captured simulator trace
(``capture.PageStream`` with request/step tags) all account in the
allocator's page ids, so eviction policy, hot-set reuse, and NVR
prefetch simulation see one memory model.

Preemption uses the recompute policy, engineered for *bitwise-identical*
resume: prompts re-prefill through the same chunk schedule, and
already-generated tokens *replay* through the decode path (teacher
forcing), so the same jitted functions see the same inputs and the
request's logits are reproduced exactly.

Per-step page traffic is scored against the NSB model, and with
``capture_trace=True`` each decode step's *layer-0* TopK selection (the
same layer-0 traffic proxy the single-batch engine uses, but computed
from the real decode queries) is recorded, tagged with request id and
scheduler iteration, into a
:class:`~repro.core.nvr.capture.PageStream`; ``captured_trace()`` lowers
it to a simulator ``Trace``, so multi-tenant serving traffic — not a
synthetic generator — drives the NVR/inorder comparison.  This container
is CPU-only: reported rates are traffic counts, not wall-clock.
"""

from __future__ import annotations

from collections import deque
from dataclasses import dataclass

import jax
import jax.numpy as jnp
import numpy as np

from ..configs.base import ArchConfig
from ..core.nvr import capture
from ..models import api, sparse_attention, transformer
from ..models import layers as mlayers
from . import scheduler as scheduler_mod
from .kv_allocator import NULL_PAGE, KVBlockAllocator, PagePoolConfig
from .scheduler import PrefillJob, Request, Scheduler


def percentile(xs, q: float) -> float:
    """Nearest-rank percentile (the one definition engine metrics and
    serve_bench share)."""
    xs = sorted(xs)
    if not xs:
        return float("nan")
    return float(xs[min(len(xs) - 1, int(round(q * (len(xs) - 1))))])


@dataclass
class ServeStats:
    steps: int = 0
    pages_touched: int = 0
    pages_unique: int = 0
    nsb_hits: int = 0
    nsb_misses: int = 0
    tokens_out: int = 0

    @property
    def hot_hit_rate(self) -> float:
        tot = self.nsb_hits + self.nsb_misses
        return self.nsb_hits / tot if tot else float("nan")

    @property
    def offchip_reduction(self) -> float:
        """Fetch reduction from the NSB hot-set (1 = everything reused)."""
        return self.hot_hit_rate


class Engine:
    """Single-batch baseline: batched prefill + lockstep sparse decode."""

    def __init__(self, cfg: ArchConfig, params, max_len: int = 1024,
                 sparse: bool = True, nsb_pages: int = 64,
                 capture_trace: bool = False,
                 kv_dtype_bytes: int = 2) -> None:
        self.cfg = cfg
        self.params = params
        self.max_len = max_len
        self.sparse = sparse and cfg.sparse_kv
        self.stats = ServeStats()
        # NSB hot-set accounting on the shared simulator cache model
        self.hot = capture.PageCache(nsb_pages)
        self._seen_pages: set[int] = set()
        self.recorder = None
        if capture_trace and self.sparse:
            self.recorder = capture.kv_page_stream(
                f"serve-{cfg.name}", n_pages=max_len // cfg.kv_page,
                page_tokens=cfg.kv_page, head_dim=cfg.hd,
                dtype_bytes=kv_dtype_bytes)
        self._decode = jax.jit(
            lambda p, c, t: api.decode_fn(cfg, p, c, t, sparse=self.sparse))
        self.cache = None
        self._last = None

    def prefill(self, batch: dict) -> jax.Array:
        logits, cache = api.prefill_fn(self.cfg, self.params, batch,
                                       remat="none")
        self.cache = self._pad_cache(cache)
        self._last = jnp.argmax(logits, axis=-1)
        return self._last

    def _pad_cache(self, cache: dict) -> dict:
        cfg = self.cfg
        l, b, s, kv, hd = cache["k"].shape
        pad = self.max_len - s
        if pad <= 0:
            return cache
        z = jnp.zeros((l, b, pad, kv, hd), cache["k"].dtype)
        out = dict(cache)
        out["k"] = jnp.concatenate([cache["k"], z], axis=2)
        out["v"] = jnp.concatenate([cache["v"], z], axis=2)
        if "kpage" in cache:
            npad = self.max_len // cfg.kv_page - cache["kpage"].shape[2]
            out["kpage"] = jnp.concatenate(
                [cache["kpage"],
                 jnp.zeros((l, b, npad, kv, hd), jnp.float32)], axis=2)
        return out

    def _track_pages(self) -> None:
        """NSB accounting: which pages would the next step's selection
        touch (layer-0 scorer as the traffic proxy)."""
        cfg = self.cfg
        cache = self.cache
        if "kpage" not in cache:
            return
        kp0 = cache["kpage"][0]
        b = kp0.shape[0]
        q = jnp.ones((b, cfg.n_kv_heads, cfg.n_heads // cfg.n_kv_heads,
                      cfg.hd), kp0.dtype)
        n_valid = cache["pos"] // cfg.kv_page + 1
        k_pages = min(cfg.kv_topk_pages, kp0.shape[1])
        if self.recorder is not None:
            idx = np.asarray(sparse_attention.select_pages_recorded(
                q, kp0, n_valid, k_pages, self.recorder))
        else:
            idx = np.asarray(sparse_attention.select_pages(
                q, kp0, n_valid, k_pages))
        uniq = np.unique(idx)
        self._seen_pages.update(int(p) for p in uniq)
        self.stats.pages_unique = len(self._seen_pages)  # run footprint
        for p in uniq:
            self.stats.pages_touched += 1
            if self.hot.touch(int(p)):
                self.stats.nsb_hits += 1
            else:
                self.stats.nsb_misses += 1

    def step(self) -> jax.Array:
        if self.sparse:
            self._track_pages()
        logits, self.cache = self._decode(self.params, self.cache,
                                          self._last)
        self._last = jnp.argmax(logits, axis=-1)
        self.stats.steps += 1
        self.stats.tokens_out += int(self._last.shape[0])
        return self._last

    def generate(self, batch: dict, n_steps: int) -> np.ndarray:
        toks = [self.prefill(batch)]
        for _ in range(n_steps - 1):
            toks.append(self.step())
        return np.stack([np.asarray(t) for t in toks], axis=1)

    def captured_trace(self):
        """The decode run's recorded page traffic as a simulator Trace
        (requires ``capture_trace=True`` and at least one sparse step)."""
        if self.recorder is None:
            raise RuntimeError(
                "no trace recorder: construct the Engine with "
                "capture_trace=True AND the sparse-KV path enabled "
                "(sparse=True and cfg.sparse_kv) to record selections")
        return self.recorder.to_trace()


# -- continuous batching -------------------------------------------------------

@dataclass
class PagedServeStats(ServeStats):
    iterations: int = 0
    prefill_tokens: int = 0
    decode_tokens: int = 0
    preemptions: int = 0
    finished: int = 0
    cow_page_copies: int = 0
    decode_rows_padded: int = 0     # NULL rows computed across the run
    prefill_calls: int = 0          # executed prefill-chunk jit calls


def _paged_decode_fn(cfg: ArchConfig, kernel: str = "xla"):
    """Build the jitted ragged decode step over the physical page pools.

    One call advances R requests by one token each: per-request positions
    (no lockstep), KV written through the block table into physical
    pages, page summaries recomputed exactly, TopK selection + gather by
    physical page id.  Padded rows carry block table NULLs and scribble
    the reserved scratch page 0.

    ``kernel`` picks the attention implementation: ``"xla"`` is the
    ``attend_pages_paged`` gather (runs everywhere; the parity oracle),
    ``"pallas"`` is the fused ``kernels.paged_decode_attn`` runahead
    kernel on the same pool layout (scalar-prefetched page ids,
    double-buffered indirect DMAs; interpret mode off-TPU).
    """
    page = cfg.kv_page
    dt = jnp.dtype(cfg.param_dtype)

    def fn(params, k_pool, v_pool, s_pool, token, pos, bt):
        r = token.shape[0]
        nl = bt.shape[1]
        k_sel = int(min(cfg.kv_topk_pages, nl))
        x = jnp.take(params["embed"], token[:, None], axis=0).astype(dt)
        if getattr(cfg, "scale_embed", False):
            x = x * (cfg.d_model ** 0.5)
        pos_arr = pos[:, None]                       # [R,1]
        lp_w = pos // page
        off = pos % page
        phys_w = jnp.take_along_axis(bt, lp_w[:, None], axis=1)[:, 0]
        n_valid = lp_w + 1
        lidx = jnp.arange(cfg.n_layers, dtype=jnp.int32)
        g = cfg.n_heads // cfg.n_kv_heads

        def body(carry, lp_li):
            xc, kp_, vp_, sp_ = carry
            lp, li = lp_li
            h = mlayers.rms_norm(xc, lp["ln1"], cfg.norm_eps)
            q, k_new, v_new = mlayers.gqa_project(h, lp, cfg)
            q = mlayers.apply_rope(q, pos_arr, cfg.rope_theta)
            k_new = mlayers.apply_rope(k_new, pos_arr, cfg.rope_theta)
            kq = sparse_attention.kv_quant(k_new[:, 0], kp_.dtype)
            vq = sparse_attention.kv_quant(v_new[:, 0], vp_.dtype)
            kp_ = kp_.at[li, phys_w, off].set(kq)
            vp_ = vp_.at[li, phys_w, off].set(vq)
            summ = sparse_attention.page_summary_from_pool(
                kp_[li], phys_w, off + 1)
            sp_ = sp_.at[li, phys_w].set(summ)
            qh = q.reshape(r, cfg.n_kv_heads, g, cfg.hd)
            idx, phys = sparse_attention.select_pages_blocktable(
                qh, sp_[li], bt, n_valid, k_sel)
            if kernel == "pallas":
                o = sparse_attention.attend_pages_paged_kernel(
                    qh, kp_[li], vp_[li], idx, phys, pos, page)
            else:
                o = sparse_attention.attend_pages_paged(
                    qh, kp_[li], vp_[li], idx, phys, pos, page)
            o = o.reshape(r, 1, cfg.n_heads, cfg.hd)
            xc = xc + mlayers.attn_out(o, lp, cfg.d_model)
            h2 = mlayers.rms_norm(xc, lp["ln2"], cfg.norm_eps)
            xc = xc + transformer._ffn(h2, lp, cfg)
            return (xc, kp_, vp_, sp_), phys

        (x, k2, v2, s2), sel = mlayers.scan_layers(
            body, (x, k_pool, v_pool, s_pool), (params["layers"], lidx))
        x = mlayers.rms_norm(x, params["ln_f"], cfg.norm_eps)
        logits = transformer.logits_last(params, cfg, x)
        return logits, k2, v2, s2, sel

    return fn


def _paged_prefill_fn(cfg: ArchConfig, chunk: int):
    """Build the jitted chunked-prefill step for one request.

    Processes ``t_valid <= chunk`` prompt tokens starting at absolute
    position ``start``: dense causal attention over the request's paged
    context (gathered through the block table), KV scattered into the
    pool, page summaries recomputed through the same
    ``page_summary_from_pool`` the decode path uses.  Padded positions
    write to scratch page 0.
    """
    page = cfg.kv_page
    dt = jnp.dtype(cfg.param_dtype)
    ntp = chunk // page + 2           # touched-page bound per chunk

    def fn(params, k_pool, v_pool, s_pool, tokens, start, t_valid, bt):
        nl = bt.shape[0]
        c = tokens.shape[0]
        x = jnp.take(params["embed"], tokens[None, :], axis=0).astype(dt)
        if getattr(cfg, "scale_embed", False):
            x = x * (cfg.d_model ** 0.5)
        pos = start + jnp.arange(c)                  # [C]
        in_chunk = jnp.arange(c) < t_valid
        lp_w = jnp.clip(pos // page, 0, nl - 1)
        phys_w = jnp.where(in_chunk, bt[lp_w], 0)
        off = pos % page
        end = start + t_valid
        lps = start // page + jnp.arange(ntp)
        pvalid = lps <= (end - 1) // page
        phys_s = jnp.where(pvalid, bt[jnp.clip(lps, 0, nl - 1)], 0)
        cnts = jnp.clip(end - lps * page, 1, page)
        lidx = jnp.arange(cfg.n_layers, dtype=jnp.int32)

        def body(carry, lp_li):
            xc, kp_, vp_, sp_ = carry
            lp, li = lp_li
            h = mlayers.rms_norm(xc, lp["ln1"], cfg.norm_eps)
            q, k_new, v_new = mlayers.gqa_project(h, lp, cfg)
            q = mlayers.apply_rope(q, pos[None, :], cfg.rope_theta)
            k_new = mlayers.apply_rope(k_new, pos[None, :], cfg.rope_theta)
            kq = sparse_attention.kv_quant(k_new[0], kp_.dtype)
            vq = sparse_attention.kv_quant(v_new[0], vp_.dtype)
            kp_ = kp_.at[li, phys_w, off].set(kq)
            vp_ = vp_.at[li, phys_w, off].set(vq)
            summ = sparse_attention.page_summary_from_pool(
                kp_[li], phys_s, cnts)
            sp_ = sp_.at[li, phys_s].set(summ)
            # dense causal attention over the paged context: the block
            # table linearises this request's pages back into logical
            # order, so positions align with q_offset=start
            kv_h, hd = cfg.n_kv_heads, cfg.hd
            kctx = kp_[li, bt].reshape(1, nl * page, kv_h, hd)
            vctx = vp_[li, bt].reshape(1, nl * page, kv_h, hd)
            o = mlayers.chunked_attention(
                q, kctx, vctx, causal=True, q_offset=start,
                chunk=min(1024, nl * page),
                logit_softcap=cfg.logit_softcap)
            xc = xc + mlayers.attn_out(o, lp, cfg.d_model)
            h2 = mlayers.rms_norm(xc, lp["ln2"], cfg.norm_eps)
            xc = xc + transformer._ffn(h2, lp, cfg)
            return (xc, kp_, vp_, sp_), None

        (x, k2, v2, s2), _ = mlayers.scan_layers(
            body, (x, k_pool, v_pool, s_pool), (params["layers"], lidx))
        x = mlayers.rms_norm(x, params["ln_f"], cfg.norm_eps)
        hl = jax.lax.dynamic_index_in_dim(x[0], t_valid - 1, 0,
                                          keepdims=True)      # [1,D]
        logits = transformer.logits_last(params, cfg, hl[None])[0]
        return logits, k2, v2, s2

    return fn


class PagedEngine:
    """Continuous-batching serve engine on a paged KV allocator.

    ``submit()`` enqueues requests; ``step()`` runs one scheduler
    iteration (admission + mixed prefill chunks / ragged decode batch);
    ``run()`` drives an arrival workload to completion.

    With ``prefix_cache=True`` (default) physical prompt pages are
    shared across requests: completed whole prompt pages are published
    to the allocator's content-addressed prefix index after each prefill
    chunk, admission attaches matching cached pages (refcount++) and
    fast-forwards the request's KV frontier past them — shared prefixes
    cost zero model FLOPs while logits stay bitwise-identical to the
    uncached run (the final prompt token is always recomputed, on a
    copy-on-write private page when the whole prompt was cached).

    Step-loop fast-path knobs (all default-on except the kernel):

    * ``kernel="xla" | "pallas"`` — the decode attention implementation.
      ``"xla"`` (default) is the ``attend_pages_paged`` gather: runs on
      any backend and is the parity oracle the bitwise-resume guarantees
      are pinned to.  ``"pallas"`` fuses gather + online-softmax in
      ``kernels.paged_decode_attn`` with the TopK physical page ids
      scalar-prefetched (the NVR runahead pipeline on the pool layout);
      off-TPU it runs in interpret mode — parity is tolerance-level
      (fp32 online softmax), not bitwise.
    * ``donate_pools`` — donate the k/v/s pool buffers into the decode
      and prefill jits, so XLA updates pages in place instead of copying
      the full ``[L,P,page,KV,D]`` pools every call.
    * ``row_bucketing`` — pad ragged decode batches to power-of-two row
      buckets (NULL block-table rows) instead of always to
      ``max_batch``: padded compute tracks the live batch while the
      trace count stays O(log max_batch) (``metrics()["n_decode_traces"]``),
      and the scheduler tops buckets up with budget-deferred rows
      (free-slot decode).
    """

    def __init__(self, cfg: ArchConfig, params, max_len: int = 64,
                 n_pages: int = 0, max_batch: int = 8, chunk: int = 16,
                 token_budget: int = 0, nsb_pages: int = 64,
                 capture_trace: bool = False,
                 kv_dtype_bytes: int = 2,
                 prefix_cache: bool = True,
                 kernel: str = "xla",
                 donate_pools: bool = True,
                 row_bucketing: bool = True) -> None:
        if cfg.family not in ("dense", "moe") or cfg.mrope_sections:
            raise NotImplementedError(
                "PagedEngine supports dense/moe decoder-only configs")
        if not cfg.sparse_kv:
            raise NotImplementedError(
                "PagedEngine requires the sparse-KV decode path")
        if max_len % cfg.kv_page:
            raise ValueError("max_len must be a multiple of cfg.kv_page")
        if kernel not in ("xla", "pallas"):
            raise ValueError(f"kernel must be 'xla' or 'pallas', "
                             f"got {kernel!r}")
        self.cfg = cfg
        self.params = params
        self.max_len = max_len
        self.page = cfg.kv_page
        self.n_logical = max_len // self.page
        chunk = min(chunk, max_len)
        # pool default: every batch slot can hold a full-length request,
        # +1 for the reserved scratch page
        self.n_pages = n_pages or (1 + max_batch * self.n_logical)
        self.allocator = KVBlockAllocator(self.n_pages, self.page,
                                          prefix_cache=prefix_cache)
        self.kernel = kernel
        self.donate_pools = donate_pools
        self.row_buckets = (scheduler_mod.row_buckets(max_batch)
                            if row_bucketing else ())
        self.scheduler = Scheduler(
            self.allocator, max_batch=max_batch, chunk=chunk,
            token_budget=token_budget or (max_batch + chunk),
            row_buckets=self.row_buckets)
        self.max_batch = max_batch
        self.chunk = chunk
        self.stats = PagedServeStats()
        self.hot = capture.PageCache(nsb_pages)
        self._seen_pages: set[int] = set()
        self.recorder = None
        if capture_trace:
            self.recorder = capture.kv_page_stream(
                f"serve-cb-{cfg.name}", n_pages=self.n_pages,
                page_tokens=self.page, head_dim=cfg.hd,
                dtype_bytes=kv_dtype_bytes)
        kv_dt = (jnp.int8 if cfg.kv_dtype == "int8"
                 else jnp.dtype(cfg.param_dtype))
        self.pool_cfg = PagePoolConfig(
            n_pages=self.n_pages, page_tokens=self.page,
            n_layers=cfg.n_layers, n_kv_heads=cfg.n_kv_heads,
            head_dim=cfg.hd, dtype_bytes=jnp.dtype(kv_dt).itemsize)
        shape = (cfg.n_layers, self.n_pages, self.page, cfg.n_kv_heads,
                 cfg.hd)
        self.k_pool = jnp.zeros(shape, kv_dt)
        self.v_pool = jnp.zeros(shape, kv_dt)
        self.s_pool = jnp.zeros(
            (cfg.n_layers, self.n_pages, cfg.n_kv_heads, cfg.hd),
            jnp.float32)
        # pool buffers are donated into both jits: the step loop rebinds
        # self.{k,v,s}_pool to the outputs, so XLA updates the pools in
        # place instead of round-tripping a full pool-sized copy per call
        donate = (1, 2, 3) if donate_pools else ()
        self._decode = jax.jit(_paged_decode_fn(cfg, kernel),
                               donate_argnums=donate)
        self._prefill = jax.jit(_paged_prefill_fn(cfg, chunk),
                                donate_argnums=donate)
        self.now = 0
        self._next_rid = 0
        self.requests: dict[int, Request] = {}

    # -- request lifecycle ---------------------------------------------------

    def submit(self, prompt, max_new_tokens: int,
               arrival: float | None = None) -> int:
        prompt = np.asarray(prompt, dtype=np.int64).reshape(-1)
        if len(prompt) + max_new_tokens > self.max_len:
            raise ValueError(
                f"prompt+gen {len(prompt)}+{max_new_tokens} exceeds "
                f"max_len {self.max_len}")
        if not len(prompt) or max_new_tokens < 1:
            raise ValueError("need a non-empty prompt and >=1 new token")
        need = self.allocator.pages_for_tokens(len(prompt) + max_new_tokens)
        if need > self.allocator.capacity:
            raise ValueError(
                f"request needs {need} pages but the pool only has "
                f"{self.allocator.capacity}: even a lone request could "
                "never finish (preemption cannot help)")
        rid = self._next_rid
        self._next_rid += 1
        req = Request(rid=rid, prompt=prompt,
                      max_new_tokens=max_new_tokens,
                      arrival=self.now if arrival is None else arrival)
        self.requests[rid] = req
        self.scheduler.add(req)
        return rid

    def _finish_if_done(self, req: Request) -> None:
        if req.done:
            self.scheduler.finish(req, self.now)
            self.stats.finished += 1

    def _apply_cow_copies(self) -> None:
        """Replay the allocator's pending copy-on-write page copies onto
        the physical pools (K, V, and page-summary planes), before any
        prefill/decode in this iteration reads the destination pages."""
        copies = self.allocator.drain_copies()
        if not copies:
            return
        src = np.asarray([s for s, _ in copies], dtype=np.int32)
        dst = np.asarray([d for _, d in copies], dtype=np.int32)
        self.k_pool = self.k_pool.at[:, dst].set(self.k_pool[:, src])
        self.v_pool = self.v_pool.at[:, dst].set(self.v_pool[:, src])
        self.s_pool = self.s_pool.at[:, dst].set(self.s_pool[:, src])
        self.stats.cow_page_copies += len(copies)

    def _run_prefill(self, job: PrefillJob) -> None:
        req = job.req
        toks = np.zeros((self.chunk,), dtype=np.int32)
        toks[: job.n_tokens] = req.prompt[job.start:job.start + job.n_tokens]
        bt = self.allocator.table_array(req.rid, self.n_logical)
        logits, self.k_pool, self.v_pool, self.s_pool = self._prefill(
            self.params, self.k_pool, self.v_pool, self.s_pool,
            jnp.asarray(toks), np.int32(job.start), np.int32(job.n_tokens),
            jnp.asarray(bt))
        req.computed += job.n_tokens
        # whole prompt pages materialised by this chunk become
        # attachable by later requests with the same prefix
        self.allocator.register_prefix(req.rid, req.prompt,
                                       min(req.computed, req.prompt_len))
        self.stats.prefill_tokens += job.n_tokens
        self.stats.prefill_calls += 1
        if req.computed == req.prompt_len:
            lg = np.asarray(logits)
            # first pass samples the first token here; a preemption
            # resume already holds it and moves on to decode replay
            if not req.out_tokens:
                req.out_tokens.append(int(lg.argmax()))
                req.first_token_at = self.now
                req.last_logits = lg
                self.stats.tokens_out += 1
                self._finish_if_done(req)

    def _run_decode(self, rows: list, bucket: int = 0) -> None:
        r_act = len(rows)
        # ragged batches pad to the scheduler's power-of-two row bucket
        # (NULL block tables, scratch-page scribbles) instead of always
        # to max_batch: O(log R_max) distinct decode traces, and the
        # padded compute shrinks with the actual batch
        rb = bucket or self.max_batch
        token = np.zeros((rb,), dtype=np.int32)
        pos = np.zeros((rb,), dtype=np.int32)
        bts = np.zeros((rb, self.n_logical), dtype=np.int32)
        for i, req in enumerate(rows):
            token[i] = req.seq[req.computed]
            pos[i] = req.computed
            bts[i] = self.allocator.table_array(req.rid, self.n_logical)
        logits, self.k_pool, self.v_pool, self.s_pool, sel = self._decode(
            self.params, self.k_pool, self.v_pool, self.s_pool,
            jnp.asarray(token), jnp.asarray(pos), jnp.asarray(bts))
        lg = np.asarray(logits)
        sel0 = np.asarray(sel[0])                    # layer-0 [R,KV,K]
        for i, req in enumerate(rows):
            frontier = req.computed == req.total_len - 1
            req.computed += 1
            self.stats.decode_tokens += 1
            if self.recorder is not None:
                # a request with fewer valid pages than the TopK budget
                # pads its selection with NULL (masked in attention, no
                # data fetched) — drop those from the traffic record
                for head_sel in sel0[i]:
                    self.recorder.record(head_sel[head_sel != NULL_PAGE],
                                         rid=req.rid, step=self.now)
            if frontier:
                req.out_tokens.append(int(lg[i].argmax()))
                req.last_logits = lg[i].copy()
                self.stats.tokens_out += 1
                self._finish_if_done(req)
        self.stats.decode_rows_padded += rb - r_act
        # NSB accounting over the iteration's unique physical pages
        uniq = np.unique(sel0[:r_act])
        uniq = uniq[uniq != NULL_PAGE]
        self._seen_pages.update(int(p) for p in uniq)
        self.stats.pages_unique = len(self._seen_pages)
        for p in uniq:
            self.stats.pages_touched += 1
            if self.hot.touch(int(p)):
                self.stats.nsb_hits += 1
            else:
                self.stats.nsb_misses += 1

    # -- iteration loop ------------------------------------------------------

    def step(self) -> int:
        """One scheduler iteration; returns scheduled token count."""
        self.now += 1
        self.stats.iterations += 1
        plan = self.scheduler.schedule(self.now)
        self._apply_cow_copies()
        for job in plan.prefill:
            self._run_prefill(job)
        if plan.decode:
            self._run_decode(plan.decode, plan.decode_bucket)
            self.stats.steps += 1
        self.stats.preemptions = self.scheduler.n_preemptions
        return plan.n_tokens

    def run(self, workload=None, max_iters: int = 100000) -> dict:
        """Drive ``workload`` (iterable of (tick, prompt, max_new)) to
        completion; returns the request table."""
        pending = deque(sorted(workload or [], key=lambda w: w[0]))
        while (pending or self.scheduler.has_work):
            if max_iters <= 0:
                raise RuntimeError("run() exceeded max_iters")
            max_iters -= 1
            while pending and pending[0][0] <= self.now:
                tick, prompt, max_new = pending.popleft()
                self.submit(prompt, max_new, arrival=tick)
            self.step()
        return self.requests

    # -- reporting -----------------------------------------------------------

    def captured_trace(self):
        """Recorded multi-tenant page traffic as a simulator Trace."""
        if self.recorder is None:
            raise RuntimeError("construct PagedEngine with "
                               "capture_trace=True to record selections")
        return self.recorder.to_trace()

    @staticmethod
    def _trace_count(jitted) -> int:
        """Compilation count of a jitted function, via the (private)
        jax cache-size hook; -1 if a jax upgrade removes it — metrics
        must degrade, not raise."""
        try:
            return int(jitted._cache_size())
        except AttributeError:
            return -1

    def n_decode_traces(self) -> int:
        """Distinct decode-step compilations so far: one per row bucket
        actually used (bucketing caps this at O(log max_batch); padding
        every batch to max_batch pins it at 1 but wastes the padded
        rows' compute)."""
        return self._trace_count(self._decode)

    def n_prefill_traces(self) -> int:
        """Distinct prefill-chunk compilations (fixed chunk shape: 1)."""
        return self._trace_count(self._prefill)

    def metrics(self) -> dict:
        done = [r for r in self.requests.values()
                if r.finished_at >= 0]
        lat = [r.latency() for r in done]
        ttft = [r.ttft() for r in done]
        return {
            "n_finished": len(done),
            "iterations": self.stats.iterations,
            "tokens_out": self.stats.tokens_out,
            "p50_latency": percentile(lat, 0.50),
            "p99_latency": percentile(lat, 0.99),
            "p50_ttft": percentile(ttft, 0.50),
            "p99_ttft": percentile(ttft, 0.99),
            "nsb_hot_hit_rate": self.stats.hot_hit_rate,
            "preemptions": self.stats.preemptions,
            "pages_peak_in_use": self.allocator.stats.peak_in_use,
            "kv_pool_mib": self.pool_cfg.pool_bytes / 2 ** 20,
            "prefill_tokens_run": self.stats.prefill_tokens,
            "prefill_tokens_skipped": self.scheduler.prefill_tokens_skipped,
            "prefix_hit_pages": self.allocator.stats.prefix_hits,
            "prefix_evictions": self.allocator.stats.prefix_evictions,
            "cow_copies": self.allocator.stats.cow_copies,
            "n_decode_traces": self.n_decode_traces(),
            "n_prefill_traces": self.n_prefill_traces(),
            "decode_rows_padded": self.stats.decode_rows_padded,
        }
