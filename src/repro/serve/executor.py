"""Pipelined executor: disaggregated prefill/decode streams over one
:class:`~repro.serve.engine.PagedEngine`.

The synchronous step loop (``PagedEngine._step_sync``) is a strictly
ordered host program: schedule, drain transfers, run every prefill chunk
to completion, run the decode batch to completion, then the runahead
stage.  Each "run" hides a host sync — ``np.asarray(logits)`` blocks
until the device drains — so one long prompt's chunks serialise in front
of every decoding user's next token, and the runahead/spill transfers
run *after* compute instead of under it.  The paper's framing is the
mirror image: vector runahead works because it is a decoupled sub-thread
executing concurrently with the NPU's demand stream.

This module restructures the same iteration into dispatch / overlap /
commit:

- **dispatch**: every prefill chunk and the decode batch are *issued*
  (jit calls return device futures; JAX dispatch is asynchronous) before
  anything is materialised.  The two streams' pool writes cannot race —
  donated pools chain functionally through each call, so device-side
  execution is ordered by dataflow (an SSA chain of pool versions) even
  though the host no longer waits between calls.
- **overlap window**: with both streams in flight, the host performs the
  work the sync loop did serially — the spilled-queue-head fetch-back
  (host->HBM restore) and the *speculative* schedule for iteration N+1
  (``Scheduler.schedule_speculative``, a shadow-state draft that
  allocates nothing).
- **commit**: materialise the prefill logits in job order, then the
  decode logits, sampling tokens and finishing requests in **plan
  order** — the exact mutation order the sync loop performs — then run
  the runahead stage against post-commit state.

Policies ride the same double buffer: the scheduler's pluggable
admission/eviction policy (``serve/policy.py``) is deep-copied with the
shadow state by ``schedule_speculative``, so the draft and the commit
replay identical decisions as long as the policy honours the
decision-replay contract (pure ``admit_order``, deterministic
``choose_victim``, state charged only in ``on_admit``).  The engine's
idle-session eviction hook is deliberately *detached* around the shadow
copy: a draft admission that would need an idle-session swap-out blocks
conservatively in the draft and is repaired at commit, because the
shadow must never move real pages.  The overlap-window fetch-back below
probes ``policy.admit_order(...)[0]`` — the policy's head of line — so
non-FIFO policies resume the right request first.

Why the result is bitwise-identical to the sync loop: scheduling
consumes only token counts and page-pool state, never sampled values, so
the committed plan sequence matches sync's; decode rows are independent
(a request's logits do not depend on which row carries it — hole rows
are exactly the NULL padding rows the bucketed sync path already
computes); and commits replay sync's mutation order, so the allocator's
LIFO free list, the prefix trie, and the NSB tier all evolve
identically.  The one sanctioned divergence: with a spill tier, the
overlap-window fetch-back sees pre-commit pool occupancy (the sync loop
ran it post-commit), so a swap-resume can land an iteration apart and
the *timelines* may differ — per-request tokens and logits still cannot
(teacher-forced replay and block-table addressing make them
schedule-independent; see ``tests/test_serve.py``'s parity suite).

Per-slot insertion (the maxtext continuous-batching idiom): each running
request keeps a persistent decode row across iterations; a freshly
prefilled request drops into the lowest free slot rather than reshuffling
the batch.  Slots only compact when the power-of-two row bucket shrinks
below an occupied slot.
"""

from __future__ import annotations


class PipelinedExecutor:
    """Drives one engine iteration as dispatch -> overlap -> commit."""

    def __init__(self, engine) -> None:
        self.engine = engine
        self._spec = None              # draft plan for the next iteration
        self._slots: dict[int, int] = {}   # rid -> persistent decode row

    def _assign_slots(self, plan, rb: int) -> list:
        """Map the plan's decode rows onto persistent slots; returns
        ``(slot, request)`` pairs **in plan order** (commit order must
        match the sync loop — see ``_commit_decode``).

        Rows vacated since last iteration (finish, preemption, budget
        deferral) free their slots; entrants take the lowest free slot.
        If the bucket shrank below an occupied slot, compact preserving
        relative order — the one case a request's row can move, and row
        placement is logit-invariant either way."""
        live = {r.rid for r in plan.decode}
        for rid in [rid for rid in self._slots if rid not in live]:
            del self._slots[rid]
        used = set(self._slots.values())
        for req in plan.decode:
            if req.rid not in self._slots:
                slot = 0
                while slot in used:
                    slot += 1
                self._slots[req.rid] = slot
                used.add(slot)
        if used and max(used) >= rb:
            order = sorted(self._slots, key=self._slots.get)
            self._slots = {rid: i for i, rid in enumerate(order)}
        return [(self._slots[req.rid], req) for req in plan.decode]

    def step(self) -> int:
        """One pipelined iteration; returns scheduled token count."""
        eng = self.engine
        eng.now += 1
        eng.stats.iterations += 1
        # commit the double-buffered draft: revalidate against post-step
        # state, then run the authoritative schedule (the plan the sync
        # loop would build at this now)
        plan = eng.scheduler.commit(self._spec, eng.now)
        self._spec = None
        # iteration-boundary drains keep PR 7's strict transfer order:
        # snapshot reads (swap-outs) before any pool write, staged-copy
        # invalidations for released pages, COW copies, restores last
        eng._apply_spill_outs()
        if eng._tier is not None:
            eng._tier.invalidate(eng.allocator.drain_released())
        eng._apply_cow_copies()
        eng._apply_swap_ins()
        # -- dispatch: issue both streams, materialise neither ---------
        prefills = [(job, eng._dispatch_prefill(job))
                    for job in plan.prefill]
        rb = plan.decode_bucket or eng.max_batch
        pairs: list = []
        decode_out = None
        if plan.decode:
            pairs = self._assign_slots(plan, rb)
            decode_out = eng._dispatch_decode(pairs, rb)
        # -- overlap window: device drains, host works ahead -----------
        fetched = None
        run_stage = ((eng._tier is not None or eng._ep_tier is not None)
                     and plan.runahead_budget > 0)
        if run_stage and eng._tier is not None:
            # the spilled queue head's host->HBM restore rides under the
            # in-flight compute (pool dataflow orders it after); it sees
            # pre-commit occupancy — the sanctioned timeline divergence
            fetched = eng._fetch_back()
        # draft iteration N+1 while N executes: shadow-state schedule
        # seeded with the in-flight plan's count evolution
        self._spec = eng.scheduler.schedule_speculative(
            eng.now + 1, in_flight=plan)
        # -- commit: sample and mutate in the sync loop's order --------
        for job, logits in prefills:
            eng._commit_prefill(job, logits)
        if decode_out is not None:
            logits, sel, esel = decode_out
            eng._commit_decode(pairs, logits, sel, rb, esel=esel)
            eng.stats.steps += 1
        if run_stage:
            eng._run_runahead(plan, fetched=fetched)
        eng._account_streams(plan)
        eng.stats.preemptions = eng.scheduler.n_preemptions
        return plan.n_tokens
