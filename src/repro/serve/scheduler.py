"""Continuous-batching scheduler: admission queue + iteration-level plans.

One scheduler iteration mixes *decode steps* (one token per running
request) and *prefill chunks* (up to ``chunk`` prompt tokens of one
request) under a shared per-iteration token budget — the Orca/vLLM
iteration-level scheduling model, sized down to this repo's CPU smoke
scale.  Admission *order* and eviction *victim choice* are delegated to a
pluggable :class:`~repro.serve.policy.SchedPolicy`; the default
:class:`~repro.serve.policy.FifoPolicy` is strict FIFO with head-of-line
blocking — a request is only admitted when the paged allocator can hold
its whole prompt, and the queue head is never skipped in favour of a
smaller later request.  Whatever the policy, the scheduler walks the
policy's admission order and stops at the first failed reservation, so
head-of-line blocking applies to the *policy's* head of line.

Admission *reserves*: ``_admit`` allocates the entire prompt's pages
(all-or-nothing ``ensure_prompt``, attaching cached prefix pages for
free) at admission time, and decode allocation runs *before* admission in
``schedule()`` — so a request can never be admitted and then evicted by
the same iteration's decode steps (the admitted request holds the highest
``admission_seq`` and would otherwise be the preferred victim,
admit->evict churn that inflates ``n_preemptions``).  Prefix-cached
prompt pages fast-forward the request's KV frontier (``computed``) past
content another request already materialised — clamped one token short of
the prompt end, so the final prefill chunk always runs and produces the
first-token logits.

Preemption: when a decode step needs a fresh KV page and the pool is
exhausted, the policy's chosen victim (FIFO: the most-recently-admitted
running request) is evicted and re-enters the *front* of the waiting
queue, keeping its original FIFO rank.  Before any running request is
victimised, the optional ``idle_evict_hook`` gets a chance to release
idle-session KV holds (conversation turns parked between user messages)
— idle sessions are always preferred victims over live requests.  With a host spill tier configured (``allocator.spill_pages >
0``) eviction is **swap-out**: the victim's pages snapshot to host slots
and its KV frontier (``computed``) is preserved, so resume is a
host->device restore instead of recompute.  Without the tier — or when
the tier itself is full — eviction falls back to the recompute policy:
pages are freed, ``computed`` drops to 0, and on resume the engine
re-prefills the prompt and *replays* the already-generated tokens
through the decode path, which reproduces the original computation
exactly (see ``engine.PagedEngine``).

Scheduling invariants the engine and tests rely on:

* **Reservation is all-or-nothing** — ``_admit`` only admits the queue
  head when the allocator can hold its *entire* prompt (or, for a
  spilled head, restore its entire snapshot); a request never holds a
  partial reservation.
* **Admit-then-evict cannot happen within one iteration** — decode
  allocations run before admission, and prefill ensures on reserved
  prompts never allocate, so a request admitted by ``schedule()`` still
  holds its pages (and has drained its COW copies) when any later
  iteration preempts it.  Spill snapshots therefore always read
  fully-materialised pool bytes.
* **Preemption preserves FIFO rank** — a resumed request keeps its
  original ``admission_seq``, so it cannot be victimised by requests it
  used to outrank.

Bucket-aware plans: when constructed with ``row_buckets`` (the engine
passes ``row_buckets(max_batch)`` when decode-row bucketing is on), the
plan records the power-of-two row bucket the engine will pad the decode
batch to (``plan.decode_bucket``) and tops the batch up to that boundary
with budget-deferred decoding requests — the padded slots are computed
either way, so they might as well carry real tokens.  Top-up never
preempts.

Arrivals come from :class:`PoissonArrivals` (open-loop load generator) or
:class:`TraceArrivals` (replay a recorded workload); both yield
``(arrival_tick, prompt_len, max_new_tokens)`` tuples.
"""

from __future__ import annotations

import copy
import enum
from collections import deque
from dataclasses import dataclass, field

import math

import numpy as np

from .kv_allocator import KVBlockAllocator
from .policy import SchedPolicy, make_policy


class RequestState(enum.Enum):
    WAITING = "waiting"
    RUNNING = "running"
    PREEMPTED = "preempted"
    FINISHED = "finished"


@dataclass
class Request:
    """One serving request and its full lifecycle accounting.

    ``computed`` is the KV frontier: the number of positions whose K/V
    pages are materialised.  Positions ``[0, len(prompt))`` are filled by
    prefill chunks; positions beyond that by decode steps.  After a
    recompute preemption ``computed`` drops to 0 and climbs back through
    the same chunk schedule, then through decode *replay* of the tokens
    already in ``out_tokens``; after a swap-out preemption (host spill
    tier) ``computed`` is preserved and resume restores the snapshot
    instead.
    """

    rid: int
    prompt: np.ndarray
    max_new_tokens: int
    arrival: float = 0.0
    state: RequestState = RequestState.WAITING
    out_tokens: list = field(default_factory=list)
    computed: int = 0
    admitted_at: float = -1.0
    admission_seq: int = -1
    first_token_at: float = -1.0
    finished_at: float = -1.0
    n_preemptions: int = 0
    cached_tokens: int = 0          # prompt tokens skipped, last admission
    last_logits: np.ndarray | None = None
    spilled: bool = False           # snapshot lives in the host spill tier
    resumed_at: float = -1.0        # last re-admission after a preemption
    resume_gaps: list = field(default_factory=list)  # resume -> next token
    last_token_at: float = -1.0     # most recent emitted-token tick
    token_ticks: list = field(default_factory=list)  # tick per emitted token
    # -- front-door attributes (policy + workload layer) --
    tenant: str = "default"         # fairness accounting unit
    priority: int = 0               # class, lower = more important
    session: int = -1               # conversation id; -1 = single-shot
    turn: int = 1                   # 1-based turn within the session
    slo_ttft: float | None = None   # TTFT deadline in scheduler ticks
    slo_tpot: float | None = None   # mean inter-token deadline, ticks

    @property
    def prompt_len(self) -> int:
        return int(len(self.prompt))

    @property
    def seq(self) -> np.ndarray:
        """prompt + generated tokens: the token at each KV position."""
        return np.concatenate(
            [self.prompt, np.asarray(self.out_tokens, dtype=np.int64)]
        )

    @property
    def total_len(self) -> int:
        return self.prompt_len + len(self.out_tokens)

    @property
    def in_prefill(self) -> bool:
        return self.computed < self.prompt_len

    @property
    def done(self) -> bool:
        return len(self.out_tokens) >= self.max_new_tokens

    def latency(self) -> float | None:
        """End-to-end latency in scheduler ticks; None until finished.

        The guards on all three latency accessors matter for percentile
        honesty: the timestamps initialise to ``-1.0`` sentinels, so an
        unguarded accessor on an unfinished request returns a *negative*
        duration that silently drags percentiles taken over
        ``requests.values()`` toward zero."""
        if self.finished_at < 0:
            return None
        return self.finished_at - self.arrival

    def ttft(self) -> float | None:
        """Time to first token; None until the first token exists."""
        if self.first_token_at < 0:
            return None
        return self.first_token_at - self.arrival

    def tpot(self) -> float | None:
        """Mean inter-token gap after the first token (time per output
        token, the decode-stream latency metric); None until a second
        token exists — a one-token request has no inter-token gap."""
        if (self.first_token_at < 0 or self.last_token_at < 0
                or len(self.out_tokens) < 2):
            return None
        return ((self.last_token_at - self.first_token_at)
                / (len(self.out_tokens) - 1))

    def slo_attained(self) -> bool | None:
        """Did this request meet every deadline it carries?  ``None``
        when it carries none (excluded from attainment denominators).
        A TTFT deadline with no first token yet counts as missed —
        unfinished starved requests must drag attainment down, not
        vanish from it."""
        if self.slo_ttft is None and self.slo_tpot is None:
            return None
        ok = True
        if self.slo_ttft is not None:
            t = self.ttft()
            ok = ok and (t is not None and t <= self.slo_ttft)
        if self.slo_tpot is not None:
            g = self.tpot()
            ok = ok and (g is None or g <= self.slo_tpot)
        return ok


@dataclass
class PrefillJob:
    req: Request
    start: int
    n_tokens: int


@dataclass
class IterationPlan:
    decode: list = field(default_factory=list)      # [Request]
    prefill: list = field(default_factory=list)     # [PrefillJob]
    decode_bucket: int = 0    # padded decode rows (0 = engine default)
    runahead_budget: int = 0  # decode-stream staging copies this iteration
    speculative: bool = False  # built by schedule_speculative: shadow
    #                            requests, no real allocations — must pass
    #                            through Scheduler.commit before dispatch
    for_now: float = -1.0     # the tick the plan was built for

    @property
    def n_tokens(self) -> int:
        return len(self.decode) + sum(j.n_tokens for j in self.prefill)

    def signature(self) -> tuple:
        """Order-sensitive identity of the schedule decision: what the
        plan would dispatch, by rid — the unit ``Scheduler.commit``
        compares a speculative draft against the authoritative plan."""
        return (tuple(r.rid for r in self.decode),
                tuple((j.req.rid, j.start, j.n_tokens)
                      for j in self.prefill),
                self.decode_bucket, self.runahead_budget)


def row_buckets(max_rows: int) -> tuple[int, ...]:
    """Power-of-two decode-row buckets up to ``max_rows``: the fixed jit
    shapes a bucketing engine pads ragged batches to.  O(log R_max)
    buckets -> O(log R_max) decode traces over any workload."""
    if max_rows <= 0:
        raise ValueError(f"max_rows must be >= 1, got {max_rows}: a "
                         "degenerate bucket list would pad every decode "
                         "batch to zero rows")
    out = []
    b = 1
    while b < max_rows:
        out.append(b)
        b <<= 1
    out.append(max_rows)
    return tuple(out)


def bucket_for(n_rows: int, buckets: tuple[int, ...]) -> int:
    """Smallest bucket holding ``n_rows`` (the padded batch shape).

    ``n_rows`` above the largest bucket is an error, never a clamp: the
    bucket is the padded batch shape the engine allocates, so silently
    returning ``buckets[-1]`` would let a plan carry more decode rows
    than the jitted batch has slots (rows dropped at pad time)."""
    for b in buckets:
        if n_rows <= b:
            return b
    raise ValueError(f"n_rows={n_rows} exceeds the largest row bucket "
                     f"{buckets[-1]}: the padded batch cannot hold the "
                     "planned decode rows")


class PoissonArrivals:
    """Open-loop Poisson arrival process in scheduler-tick time.

    ``rate`` is the expected number of request arrivals per iteration;
    prompt and generation lengths are drawn uniformly from the given
    ranges.  Deterministic under ``seed``.
    """

    def __init__(self, n_requests: int, rate: float = 0.5,
                 prompt_len: tuple = (8, 32), gen_len: tuple = (4, 16),
                 seed: int = 0) -> None:
        rng = np.random.default_rng(seed)
        gaps = rng.exponential(1.0 / rate, size=n_requests)
        t = np.cumsum(gaps)
        self.schedule = [
            (float(t[i]),
             int(rng.integers(prompt_len[0], prompt_len[1] + 1)),
             int(rng.integers(gen_len[0], gen_len[1] + 1)))
            for i in range(n_requests)
        ]

    def __iter__(self):
        return iter(self.schedule)


class TraceArrivals:
    """Replay an explicit ``(tick, prompt_len, max_new)`` workload.

    The schedule is validated up front — non-empty, finite values,
    non-decreasing arrival times, positive lengths — and a violation
    raises ``ValueError`` naming the offending entry, instead of
    silently yielding garbage the engine only trips over many
    iterations later (or worse, never: a NaN tick just sorts
    somewhere)."""

    def __init__(self, schedule) -> None:
        rows = [(float(t), int(p), int(g)) for t, p, g in schedule]
        if not rows:
            raise ValueError("TraceArrivals: empty schedule — a trace "
                             "must contain at least one arrival")
        prev = None
        for i, (t, p, g) in enumerate(rows):
            if not math.isfinite(t):
                raise ValueError(f"TraceArrivals: non-finite arrival "
                                 f"tick {t!r} at entry {i}")
            if prev is not None and t < prev:
                raise ValueError(
                    f"TraceArrivals: arrival times must be "
                    f"non-decreasing, but entry {i} ({t}) precedes "
                    f"entry {i - 1} ({prev})")
            if p <= 0 or g <= 0:
                raise ValueError(
                    f"TraceArrivals: entry {i} has prompt_len={p}, "
                    f"max_new={g}; both must be >= 1")
            prev = t
        self.schedule = rows

    def __iter__(self):
        return iter(self.schedule)


class Scheduler:
    """Iteration-level scheduler over one :class:`KVBlockAllocator`."""

    def __init__(self, allocator: KVBlockAllocator, max_batch: int = 8,
                 chunk: int = 16, token_budget: int = 32,
                 max_running: int = 0,
                 row_buckets: tuple[int, ...] = (),
                 runahead_pages: int = 0,
                 policy: SchedPolicy | str | None = None) -> None:
        self.allocator = allocator
        # admission order + eviction victims are the policy's decisions;
        # the default FifoPolicy reproduces the pre-policy scheduler
        # verbatim.  The policy is deep-copied with the scheduler by
        # schedule_speculative, so its decisions replay identically in
        # draft and commit (the decision-replay contract).
        self.policy = make_policy(policy or "fifo")
        # optional engine callback: release one idle-session KV hold and
        # return True, or False when nothing is held.  Consulted before
        # any running request is victimised and before admission gives
        # up — idle conversations yield to live traffic.  Excluded from
        # speculative deep copies (a draft must not move real pages).
        self.idle_evict_hook = None
        self.max_batch = max_batch
        self.chunk = chunk
        self.token_budget = max(token_budget, 1)
        self.max_running = max_running or max_batch
        # runahead_pages: staging copies granted to the *decode stream*
        # per iteration it runs; 0 disables (the plan then never grants
        # a budget).  The grant is per-stream and independent of
        # co-scheduled prefill — see schedule() for the rationale.
        self.runahead_pages = runahead_pages
        # bucket-aware planning: when the engine pads decode batches to
        # power-of-two buckets, the padded slots cost the same jitted
        # call whether they carry NULL rows or real requests — so the
        # plan tops the decode batch up to the bucket boundary with
        # eligible rows the token budget alone would have deferred
        self.row_buckets = tuple(row_buckets)
        self.waiting: deque[Request] = deque()
        self.running: list[Request] = []
        self._admission_seq = 0
        self._now = 0.0                   # tick of the schedule() in flight
        self.n_preemptions = 0
        self.n_swap_outs = 0              # preemptions served by spill
        self.n_swap_ins = 0               # resumes served by restore
        self.prefill_tokens_skipped = 0   # prefix-cache fast-forwards
        # double-buffered plan accounting (the pipelined executor's
        # schedule_speculative/commit cycle)
        self.plan_commits = 0             # speculative plans committed
        self.plan_reuse = 0               # drafts that matched commit
        self.plan_repairs = 0             # drafts with dead rids dropped

    # -- queue interface -----------------------------------------------------

    def add(self, req: Request) -> None:
        req.state = RequestState.WAITING
        self.waiting.append(req)

    @property
    def has_work(self) -> bool:
        return bool(self.waiting or self.running)

    # -- internals -----------------------------------------------------------

    def _preempt(self, victim: Request) -> None:
        # swap-out when the spill tier can take the snapshot, recompute
        # otherwise (tier disabled, or short on slots right now)
        if self.allocator.spill_pages \
                and self.allocator.spill_request(victim.rid):
            victim.spilled = True       # computed preserved: swap resume
            self.n_swap_outs += 1
        else:
            self.allocator.free_request(victim.rid)
            victim.computed = 0
        victim.state = RequestState.PREEMPTED
        victim.n_preemptions += 1
        self.n_preemptions += 1
        self.running.remove(victim)
        # front of the queue: preempted requests keep FIFO priority
        self.waiting.appendleft(victim)

    def _ensure_with_preemption(self, req: Request, n_tokens: int) -> bool:
        """Allocate pages for ``req`` up to ``n_tokens`` positions,
        evicting the policy's chosen victims if the pool is full.
        Idle-session KV holds are released first (always-preferred
        victims); then the policy picks among running requests.  Returns
        False if ``req`` itself had to be preempted (the policy found no
        acceptable victim and deferred the requester)."""
        while not self.allocator.ensure(req.rid, n_tokens):
            # idle conversations yield before any live request does
            if self.idle_evict_hook is not None and self.idle_evict_hook():
                continue
            victim = self.policy.choose_victim(self.running, req,
                                               self._now, self)
            if victim is not None and victim is not req \
                    and any(victim is r for r in self.running):
                self._preempt(victim)
                continue
            # no acceptable victim: preempt the requester itself (defer)
            self._preempt(req)
            return False
        return True

    def _try_reserve(self, head: Request) -> bool:
        """One admission attempt for ``head``: all-or-nothing swap-in or
        prompt reservation.  Pure mechanism — no queue mutation."""
        if head.spilled:
            # swap-resume: restore the snapshot onto fresh HBM pages
            # (all-or-nothing, like a fresh reservation) and keep the
            # preserved KV frontier — no re-prefill, no replay
            if not self.allocator.resume_spilled(
                    head.rid, max(head.prompt_len, head.computed)):
                return False
            head.spilled = False
            self.n_swap_ins += 1
            return True
        # reserve the whole prompt now (all-or-nothing, cached prefix
        # pages attach for free): an admitted request can never lose its
        # prompt pages to this iteration's other allocations
        ok, cached = self.allocator.ensure_prompt(head.rid, head.prompt)
        if not ok:
            return False
        # fast-forward past prefix-cached pages, keeping the last prompt
        # token to recompute: its prefill produces the first-token
        # logits (its page was COW'd on a full hit)
        head.computed = min(cached, head.prompt_len - 1)
        head.cached_tokens = head.computed
        self.prefill_tokens_skipped += head.computed
        return True

    def _admit(self, now: float) -> list[Request]:
        admitted = []
        if not self.waiting:
            return admitted
        # the policy ranks the whole queue once per pass; nothing else
        # mutates ``waiting`` during admission, so the snapshot is exact
        for head in self.policy.admit_order(list(self.waiting), now):
            if len(self.running) >= self.max_running:
                break
            while not self._try_reserve(head):
                # idle-session KV yields its pages before admission
                # blocks on them
                if self.idle_evict_hook is None \
                        or not self.idle_evict_hook():
                    # head-of-line blocking on the *policy's* order: the
                    # ranked head is never skipped for a smaller request
                    return admitted
            self.waiting.remove(head)
            head.state = RequestState.RUNNING
            self.policy.on_admit(head, now)
            if head.n_preemptions > 0:
                # resume-TTFT clock for both policies: the engine appends
                # (token time - resumed_at) to resume_gaps at the next
                # emitted token
                head.resumed_at = now
            # a resumed (previously preempted) request keeps its original
            # admission_seq so it cannot be victimised by requests it
            # used to outrank
            if head.admission_seq < 0:
                head.admitted_at = now
                head.admission_seq = self._admission_seq
                self._admission_seq += 1
            self.running.append(head)
            admitted.append(head)
        return admitted

    # -- the per-iteration plan ----------------------------------------------

    def schedule(self, now: float = 0.0) -> IterationPlan:
        """Build one iteration's mixed prefill/decode plan.

        Decode steps are scheduled first (latency priority — and so their
        page allocations precede admission), then new admissions (whole
        prompts reserved), then prefill chunks — all under
        ``token_budget`` scheduled tokens and ``max_batch`` decode rows
        per iteration.
        """
        plan = IterationPlan()
        self._now = now         # victim scoring reads the current tick
        budget = self.token_budget

        # decode / replay steps: requests past their prompt frontier.
        # These run BEFORE admission so a decode page grab can never
        # victimise a request admitted in this very iteration.
        for req in sorted(self.running, key=lambda r: r.admission_seq):
            if req not in self.running or req.in_prefill or budget <= 0:
                continue
            if len(plan.decode) >= self.max_batch:
                break
            if not self._ensure_with_preemption(req, req.computed + 1):
                continue        # deferred: req preempted itself
            plan.decode.append(req)
            budget -= 1

        self._admit(now)

        # prefill chunks for running requests still materialising
        # prompts (their pages are already reserved from admission, so
        # the ensure below is a no-op safety net, never an eviction)
        for req in sorted(self.running, key=lambda r: r.admission_seq):
            if req not in self.running or not req.in_prefill or budget <= 0:
                continue
            n = min(self.chunk, req.prompt_len - req.computed, budget)
            if not self._ensure_with_preemption(req, req.computed + n):
                continue        # deferred: req preempted itself
            plan.prefill.append(PrefillJob(req, req.computed, n))
            budget -= n

        # a prefill allocation may have evicted a request planned above
        plan.decode = [r for r in plan.decode if r in self.running]
        plan.prefill = [j for j in plan.prefill if j.req in self.running]
        if self.row_buckets and plan.decode:
            self._fill_bucket(plan)
            plan.decode_bucket = bucket_for(len(plan.decode),
                                            self.row_buckets)
        # runahead staging budget is *per stream*: the decode stream is
        # granted the full ``runahead_pages`` whenever it runs, zero when
        # nothing decodes (no selection to predict for).  Prefill no
        # longer halves the grant — under the pipelined executor prefill
        # chunks dispatch on their own stream, so a co-scheduled long
        # prompt does not contend with the decode stream's staging
        # window the way the pre-disaggregation serial loop did.
        if self.runahead_pages > 0 and plan.decode:
            plan.runahead_budget = self.runahead_pages
        plan.for_now = now
        return plan

    def _fill_bucket(self, plan: IterationPlan) -> None:
        """Top the decode batch up to its bucket boundary.

        The engine pads the batch to ``bucket_for(len(decode))`` rows
        either way, so slots the token budget deferred are free compute:
        fill them with eligible decoding requests instead of NULL rows.
        ``plan.n_tokens`` may then exceed ``token_budget`` — by design,
        those tokens ride in already-paid-for padding.  Top-up never
        preempts (plain ``ensure``): a free slot is not worth an
        eviction."""
        bucket = bucket_for(len(plan.decode), self.row_buckets)
        planned = {r.rid for r in plan.decode}
        for req in sorted(self.running, key=lambda r: r.admission_seq):
            if len(plan.decode) >= bucket:
                break
            if req.rid in planned or req.in_prefill:
                continue
            if not self.allocator.ensure(req.rid, req.computed + 1):
                continue
            plan.decode.append(req)

    # -- double-buffered plans (pipelined executor) --------------------------

    def schedule_speculative(self, now: float,
                             in_flight: IterationPlan | None = None
                             ) -> IterationPlan:
        """Build iteration ``now``'s plan as a *draft*, without mutating
        any real scheduler or allocator state.

        This is the overlap-window half of the double buffer: the
        pipelined executor calls it while the device is still executing
        the ``in_flight`` plan's prefill/decode streams, so the host
        builds plan N+1 under step N.  The draft is computed on a deep
        shadow copy of the scheduler (allocator included; immutable
        request arrays are shared, never copied), after replaying the
        *count evolution* the in-flight step will commit — every decode
        row's frontier advances one position, frontier rows emit a
        token, prefill completions emit their first token, and requests
        that reach their token budget finish and free their pages.
        Scheduling decisions depend only on token counts and page-pool
        state, never on sampled token values, so when no new request
        arrives between draft and commit the draft is exact.

        Call this *after* the in-flight plan's prefill chunks have been
        dispatched (their ``computed`` advance happens at dispatch) and
        before the step's sample/commit boundary.  The returned plan
        references shadow requests and holds no real allocations — it
        must go through :meth:`commit` before anything dispatches it.
        """
        # share the immutable per-request arrays: prompts are never
        # mutated and last_logits only rebound, so the shadow can alias
        # them instead of copying megabytes per draft
        memo: dict = {}
        for req in list(self.running) + list(self.waiting):
            memo[id(req.prompt)] = req.prompt
            if req.last_logits is not None:
                memo[id(req.last_logits)] = req.last_logits
        # the idle-evict hook is an engine-bound callback: detach it
        # around the copy so (a) deepcopy never recurses into the
        # engine, and (b) the shadow cannot release real session holds
        # while drafting.  A draft admission that would have needed an
        # idle eviction simply blocks; commit() performs the real
        # eviction and repairs the plan.
        hook, self.idle_evict_hook = self.idle_evict_hook, None
        try:
            shadow = copy.deepcopy(self, memo)
        finally:
            self.idle_evict_hook = hook
        if in_flight is not None:
            by_rid = {r.rid: r for r in shadow.running}
            # decode stream: each row's frontier advances; frontier rows
            # emit (token value irrelevant to scheduling), finished rows
            # release their pages exactly as the commit will
            for row in in_flight.decode:
                r = by_rid.get(row.rid)
                if r is None:
                    continue
                frontier = r.computed == r.total_len - 1
                r.computed += 1
                if frontier:
                    r.out_tokens.append(0)
                    if r.done:
                        shadow.finish(r, now)
            # prefill stream: ``computed`` already advanced at dispatch
            # time (mirroring the engine), so only the completion
            # emission remains to simulate
            for job in in_flight.prefill:
                r = by_rid.get(job.req.rid)
                if r is None or r.computed < r.prompt_len or r.out_tokens:
                    continue
                r.out_tokens.append(0)
                if r.done:
                    shadow.finish(r, now)
        plan = shadow.schedule(now)
        plan.speculative = True
        return plan

    def commit(self, plan: IterationPlan | None,
               now: float) -> IterationPlan:
        """Revalidate a speculative draft against post-step state and
        return the authoritative plan for iteration ``now``.

        Revalidation drops draft rows whose request is no longer
        running, finished, was preempted, or whose KV frontier moved
        under the draft (a stale prefill start) — a speculative plan can
        therefore never dispatch a dead rid or address an
        un-materialised page.  The apply pass then runs the real
        :meth:`schedule` (performing the draft's allocations,
        admissions and preemptions against live state — the one place
        pages actually move), and the committed plan is *by
        construction* the plan the synchronous loop would have built,
        which is what keeps the async executor's schedule, tokens and
        logits bitwise-identical to the sync oracle.  The draft-vs-
        commit match rate is tracked in ``plan_reuse``/``plan_commits``
        (speculation quality; exact whenever no new arrival landed
        between draft and commit).
        """
        draft_sig = None
        if plan is not None and plan.speculative and plan.for_now == now:
            self.plan_commits += 1
            live = {r.rid: r for r in self.running}
            kept_d, kept_p = [], []
            for r in plan.decode:
                real = live.get(r.rid)
                if (real is not None and not real.in_prefill
                        and not real.done):
                    kept_d.append(r)
            for j in plan.prefill:
                real = live.get(j.req.rid)
                if (real is not None and real.in_prefill
                        and j.start == real.computed):
                    kept_p.append(j)
            if len(kept_d) != len(plan.decode) \
                    or len(kept_p) != len(plan.prefill):
                self.plan_repairs += 1
            plan.decode, plan.prefill = kept_d, kept_p
            draft_sig = plan.signature()
        committed = self.schedule(now)
        if draft_sig is not None and draft_sig == committed.signature():
            self.plan_reuse += 1
        return committed

    def finish(self, req: Request, now: float) -> None:
        req.state = RequestState.FINISHED
        req.finished_at = now
        self.allocator.free_request(req.rid)
        if req in self.running:
            self.running.remove(req)
