"""Paged KV-cache allocator: one physical page pool, per-request block tables.

The serving engine's KV cache is a pool of fixed-size physical pages
(``cfg.kv_page`` tokens each); every request owns a *block table* mapping
its logical pages (position // page) to physical page ids.  The allocator
manages the free list, grows block tables on demand, and frees a request's
pages on completion or preemption.

The physical page id is the unit the whole memory-system story shares:

* the TopK selection in the paged decode path gathers K/V *by physical
  page id* (``sparse_attention.select_pages_blocktable``),
* the NSB hot-set accounting (``capture.PageCache``) is keyed by the same
  physical ids, and
* the capture recorder (``capture.PageStream``) tags those ids per
  request/step so the NVR simulator replays the allocator's actual layout.

Physical page 0 is reserved as a scratch/null page: padded batch rows and
masked prefill positions write there, so the jitted model functions never
need data-dependent shapes.  The allocator never hands page 0 out.
"""

from __future__ import annotations

from dataclasses import dataclass

import numpy as np

NULL_PAGE = 0


@dataclass
class AllocatorStats:
    allocs: int = 0
    frees: int = 0
    alloc_failures: int = 0
    peak_in_use: int = 0


class KVBlockAllocator:
    """Free-list allocator over ``n_pages`` physical KV pages.

    ``n_pages`` includes the reserved scratch page 0, so ``capacity`` —
    the number of allocatable pages — is ``n_pages - 1``.
    """

    def __init__(self, n_pages: int, page_tokens: int) -> None:
        if n_pages < 2:
            raise ValueError("need at least 2 pages (page 0 is reserved)")
        self.n_pages = n_pages
        self.page_tokens = page_tokens
        # pop() from the end -> low page ids are handed out first
        self._free = list(range(n_pages - 1, NULL_PAGE, -1))
        self._tables: dict[int, list[int]] = {}
        self.stats = AllocatorStats()

    # -- capacity ------------------------------------------------------------

    @property
    def capacity(self) -> int:
        return self.n_pages - 1

    @property
    def pages_free(self) -> int:
        return len(self._free)

    @property
    def pages_in_use(self) -> int:
        return self.capacity - self.pages_free

    def pages_for_tokens(self, n_tokens: int) -> int:
        return -(-n_tokens // self.page_tokens)

    # -- block tables --------------------------------------------------------

    def table(self, rid: int) -> list[int]:
        return self._tables.setdefault(rid, [])

    def table_array(self, rid: int, n_logical: int) -> np.ndarray:
        """The request's block table padded with NULL_PAGE to length
        ``n_logical`` (the jitted functions take fixed-shape tables)."""
        bt = np.full((n_logical,), NULL_PAGE, dtype=np.int32)
        pages = self._tables.get(rid, [])
        bt[: len(pages)] = pages[:n_logical]
        return bt

    def ensure(self, rid: int, n_tokens: int) -> bool:
        """Grow ``rid``'s block table to cover ``n_tokens`` positions.

        All-or-nothing: returns False (and allocates nothing) if the free
        list cannot supply every page needed.
        """
        need = self.pages_for_tokens(n_tokens) - len(self.table(rid))
        if need <= 0:
            return True
        if need > self.pages_free:
            self.stats.alloc_failures += 1
            return False
        pages = [self._free.pop() for _ in range(need)]
        self._tables[rid].extend(pages)
        self.stats.allocs += need
        self.stats.peak_in_use = max(self.stats.peak_in_use,
                                     self.pages_in_use)
        return True

    def free_request(self, rid: int) -> list[int]:
        """Release every page ``rid`` owns; returns the freed ids."""
        pages = self._tables.pop(rid, [])
        self.stats.frees += len(pages)
        # LIFO reuse keeps the hot physical ids dense, which is what the
        # NSB hot-set model rewards (recently-freed pages are re-touched)
        self._free.extend(reversed(pages))
        return pages

    def owned(self, rid: int) -> int:
        return len(self._tables.get(rid, []))


@dataclass
class PagePoolConfig:
    """Geometry of the physical pools the engine allocates once."""

    n_pages: int
    page_tokens: int
    n_layers: int
    n_kv_heads: int
    head_dim: int
    dtype_bytes: int = 2

    @property
    def page_bytes(self) -> int:
        """K+V bytes of one physical page across all layers."""
        return (2 * self.n_layers * self.page_tokens * self.n_kv_heads
                * self.head_dim * self.dtype_bytes)

    @property
    def pool_bytes(self) -> int:
        return self.n_pages * self.page_bytes
