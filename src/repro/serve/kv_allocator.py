"""Paged KV-cache allocator: one physical page pool, per-request block tables.

The serving engine's KV cache is a pool of fixed-size physical pages
(``cfg.kv_page`` tokens each); every request owns a *block table* mapping
its logical pages (position // page) to physical page ids.  The allocator
manages the free list, grows block tables on demand, and releases a
request's pages on completion or preemption.

Cross-request prefix caching (the ROADMAP's "caching" lever): physical
pages are *ref-counted* and full prompt pages are *content-addressed* by
a hash chain over their token content.  ``ensure_prompt`` splits into a
cached-hit **attach** (refcount++ on a page another request already
materialised) and a fresh allocation; releasing a page whose content is
registered in the prefix index parks it in an LRU of
unreferenced-but-cached pages instead of the free list, so a later
request with the same prompt prefix can re-attach it.  When a request's
write frontier lands in a shared page (a fully-cached prompt whose last
token must be recomputed to produce logits) the allocator performs
**copy-on-write**: the request gets a private copy and the engine
replays the pool bytes via :meth:`drain_copies`.

The physical page id is the unit the whole memory-system story shares:

* the TopK selection in the paged decode path gathers K/V *by physical
  page id* (``sparse_attention.select_pages_blocktable``),
* the NSB hot-set accounting (``capture.PageCache``) is keyed by the same
  physical ids, and
* the capture recorder (``capture.PageStream``) tags those ids per
  request/step so the NVR simulator replays the allocator's actual
  layout — with prefix caching on, genuinely *shared* physical ids, so
  NSB hit rate and NVR miss reduction are measured on the real reuse
  structure of multi-tenant traffic.

Physical page 0 is reserved as a scratch/null page: padded batch rows and
masked prefill positions write there, so the jitted model functions never
need data-dependent shapes.  The allocator never hands page 0 out.

**Host spill tier** (``spill_pages > 0``): preemption can *swap out*
instead of free-and-recompute.  ``spill_request`` snapshots every page a
request holds into host spill slots (the engine performs the actual
device->host copies via :meth:`drain_spill_outs`) and releases the HBM
pages; ``resume_spilled`` allocates fresh HBM pages all-or-nothing and
queues the host->device restores (:meth:`drain_swap_ins`), so the
request resumes at its old KV frontier with zero recompute.  Spill slots
are only ids here — the bytes live in :class:`~.spill.HostSpillPool`.

Invariants this module maintains (audited by
:meth:`KVBlockAllocator.check_tier_invariants` and the hypothesis
property suite):

* **One tier per physical page id** — every allocatable HBM page id is
  in exactly one of {referenced by >= 1 block table, cached-but-free
  LRU, free list} at all times.  In particular a page released by a
  spill is *unregistered* from the prefix index first, so its content
  can never sit in the cached LRU and the spill pool simultaneously
  (resume restores from the spill snapshot, never from a maybe-evicted
  cache entry).
* **Refcount conservation** — ``_ref[p]`` equals the number of block
  tables containing ``p``; refs are only created by allocation/attach
  and only destroyed by ``_release_ref``.
* **Reservation is all-or-nothing** — ``ensure`` / ``ensure_prompt`` /
  ``resume_spilled`` either take every page they need or take none and
  leave state untouched (no partial reservations to unwind).
* **Spill-slot bijection** — a spill slot id is owned by exactly one
  (request, logical page) snapshot, or is free, or is draining (queued
  for an engine copy); slots drain before they recycle, so a queued
  host transfer can never read a slot a same-iteration spill reused.
"""

from __future__ import annotations

from collections import OrderedDict
from dataclasses import dataclass

import numpy as np

NULL_PAGE = 0

_CHAIN_SEED = 0x9E3779B9


@dataclass
class AllocatorStats:
    allocs: int = 0
    frees: int = 0
    alloc_failures: int = 0    # ensure() growth failures (preempt trigger)
    admission_blocks: int = 0  # ensure_prompt() refusals (HOL polling)
    peak_in_use: int = 0
    prefix_hits: int = 0       # pages attached from the prefix index
    prefix_evictions: int = 0  # cached pages reclaimed for fresh allocs
    cow_copies: int = 0        # shared pages privatised before a write
    spill_out_pages: int = 0   # page snapshots queued device -> host
    swap_in_pages: int = 0     # page restores queued host -> device
    spill_failures: int = 0    # spill refused (tier off / slots short)
    spill_unregistered: int = 0  # prefix entries dropped at spill time
    session_holds: int = 0     # block tables adopted by idle sessions
    session_releases: int = 0  # idle-session holds released


class KVBlockAllocator:
    """Free-list + prefix-cache allocator over ``n_pages`` physical pages.

    ``n_pages`` includes the reserved scratch page 0, so ``capacity`` —
    the number of allocatable pages — is ``n_pages - 1``.

    Page lifecycle: free -> referenced (refcount >= 1, possibly by
    several requests sharing a prompt prefix) -> either free again, or —
    when the page's content is registered in the prefix index — *cached*
    (refcount 0, content retained, LRU-evictable).  ``pages_free`` counts
    everything reclaimable (free list + cached LRU), so admission-control
    arithmetic is unchanged by caching.
    """

    def __init__(self, n_pages: int, page_tokens: int,
                 prefix_cache: bool = True, spill_pages: int = 0) -> None:
        if n_pages < 2:
            raise ValueError("need at least 2 pages (page 0 is reserved)")
        self.n_pages = n_pages
        self.page_tokens = page_tokens
        self.prefix_cache = prefix_cache
        # pop() from the end -> low page ids are handed out first
        self._free = list(range(n_pages - 1, NULL_PAGE, -1))
        self._tables: dict[int, list[int]] = {}
        self._ref: dict[int, int] = {}                 # page -> refcount
        # content-addressing: chain key -> (page, token tuple); the token
        # tuple is compared on attach, so a hash collision can never
        # splice the wrong content into a request
        self._index: dict[int, tuple[int, tuple]] = {}
        self._page_key: dict[int, int] = {}            # page -> chain key
        self._cached: OrderedDict[int, None] = OrderedDict()
        # rid -> (pages registered so far, chain key at that depth):
        # register_prefix resumes here instead of re-hashing the prompt
        self._reg_state: dict[int, tuple[int, int]] = {}
        self._pending_copies: list[tuple[int, int]] = []
        # pages whose last live reference dropped since the previous
        # drain_released(): the runahead hot tier invalidates these —
        # a freed page can be re-taken and rewritten, so a staged copy
        # of its old content must never resolve again
        self._released: list[int] = []
        # -- host spill tier (ids only; bytes live in spill.HostSpillPool)
        self.spill_pages = spill_pages
        self._spill_free = list(range(spill_pages - 1, -1, -1))
        # rid -> (slot ids, old physical page ids) aligned by logical page
        self._spilled: dict[int, tuple[list[int], list[int]]] = {}
        # engine transfer queues: device->host snapshots and host->device
        # restores.  Slots referenced by queued swap-ins are *draining*:
        # they recycle only when drain_swap_ins() hands the copies to the
        # engine, so a spill in the same scheduler pass cannot overwrite
        # a snapshot before its restore is read.
        self._pending_spill_out: list[tuple[int, int]] = []  # (page, slot)
        self._pending_swap_in: list[tuple[int, int]] = []    # (slot, page)
        self._slots_draining: list[int] = []
        # rid -> {old page id -> new page id} from the latest resume; the
        # engine drains these to remap predictor history onto the
        # restored physical ids
        self._pending_remaps: list[tuple[int, dict[int, int]]] = []
        # page id -> number of live host snapshots taken from it: while
        # > 0 a release must not park the id in the cached LRU (see
        # _release_ref — one home per content)
        self._snap_refs: dict[int, int] = {}
        # rids whose block table is an *idle-session hold*: KV pinned
        # between conversation turns by the engine's session layer, not
        # by a live request.  Pure accounting — the pages behave like
        # any other referenced pages; the gauge lets metrics and the
        # idle-eviction hook see how much of the pool sessions pin.
        self._session_rids: set[int] = set()
        self.stats = AllocatorStats()

    # -- capacity ------------------------------------------------------------

    @property
    def capacity(self) -> int:
        return self.n_pages - 1

    @property
    def pages_free(self) -> int:
        """Reclaimable pages: the free list plus cached-but-unreferenced
        pages (evictable, so they count as available for admission)."""
        return len(self._free) + len(self._cached)

    @property
    def pages_in_use(self) -> int:
        """Pages referenced by at least one live request."""
        return self.capacity - self.pages_free

    @property
    def pages_cached(self) -> int:
        return len(self._cached)

    def refcount(self, page: int) -> int:
        return self._ref.get(page, 0)

    def pages_for_tokens(self, n_tokens: int) -> int:
        return -(-n_tokens // self.page_tokens)

    # -- block tables --------------------------------------------------------

    def table(self, rid: int) -> list[int]:
        return self._tables.setdefault(rid, [])

    def table_array(self, rid: int, n_logical: int) -> np.ndarray:
        """The request's block table padded with NULL_PAGE to length
        ``n_logical`` (the jitted functions take fixed-shape tables)."""
        bt = np.full((n_logical,), NULL_PAGE, dtype=np.int32)
        pages = self._tables.get(rid, [])
        bt[: len(pages)] = pages[:n_logical]
        return bt

    # -- idle-session holds --------------------------------------------------

    def adopt_table(self, new_rid: int, old_rid: int) -> bool:
        """Hand ``old_rid``'s block table (and its prefix-registration
        cursor) to ``new_rid`` without touching refcounts.

        The engine's session layer uses this at request completion to
        keep a finished conversation turn's KV alive under a *holder*
        rid between turns — the pages stay referenced (un-evictable)
        until the holder is spilled (idle swap-out) or freed.  The
        holder is marked so :meth:`pages_session_held` and the tier
        invariants can account for session-pinned pages."""
        if new_rid in self._tables or new_rid in self._spilled \
                or old_rid not in self._tables:
            return False
        self._tables[new_rid] = self._tables.pop(old_rid)
        st = self._reg_state.pop(old_rid, None)
        if st is not None:
            self._reg_state[new_rid] = st
        self._session_rids.add(new_rid)
        self.stats.session_holds += 1
        return True

    @property
    def session_rids(self) -> frozenset:
        return frozenset(self._session_rids)

    @property
    def pages_session_held(self) -> int:
        """HBM pages pinned by idle-session holders."""
        return sum(len(self._tables.get(r, ())) for r in self._session_rids)

    @property
    def pages_session_spilled(self) -> int:
        """Host spill slots owned by idle-session holders (idle
        swap-outs waiting for the conversation's next turn)."""
        return sum(len(self._spilled[r][0]) for r in self._session_rids
                   if r in self._spilled)

    # -- page plumbing -------------------------------------------------------

    def _take_page(self) -> int:
        """One reclaimable page (caller has checked availability): free
        list first, then evict the least-recently-parked cached page."""
        if self._free:
            return self._free.pop()
        page, _ = self._cached.popitem(last=False)
        key = self._page_key.pop(page)
        del self._index[key]
        self.stats.prefix_evictions += 1
        return page

    def _release_ref(self, page: int) -> None:
        self._ref[page] -= 1
        if self._ref[page]:
            return
        del self._ref[page]
        self._released.append(page)
        if page in self._page_key and page in self._snap_refs:
            # the page's content is snapshotted in the host spill tier:
            # one home per content — unregister it so the id free-lists
            # instead of sitting in the cached LRU *and* the spill pool
            # (resume always restores from the snapshot; an LRU entry
            # could be evicted underneath it).  A later re-take of the
            # same id by unrelated content may lose its cache entry this
            # way — a conservative cache miss, never a correctness bug.
            key = self._page_key.pop(page)
            del self._index[key]
            self.stats.spill_unregistered += 1
        if page in self._page_key:
            # content survives for future prefix attaches, LRU order
            self._cached[page] = None
            self._cached.move_to_end(page)
        else:
            self._free.append(page)

    def _chain_keys(self, tokens, n_pages: int):
        """``(key, chunk)`` per full page of ``tokens``: key i hashes the
        chain of pages [0..i], so equal keys mean equal prefix *and*
        equal absolute positions (RoPE-safe sharing)."""
        pt = self.page_tokens
        out = []
        h = _CHAIN_SEED
        for i in range(n_pages):
            chunk = tuple(int(t) for t in tokens[i * pt:(i + 1) * pt])
            h = hash((h, chunk))
            out.append((h, chunk))
        return out

    # -- allocation ----------------------------------------------------------

    def ensure(self, rid: int, n_tokens: int) -> bool:
        """Grow ``rid``'s block table to cover ``n_tokens`` positions
        with freshly-allocated private pages.

        All-or-nothing: returns False (and allocates nothing) if the
        reclaimable pages cannot supply every page needed.
        """
        need = self.pages_for_tokens(n_tokens) - len(self.table(rid))
        if need <= 0:
            return True
        if need > self.pages_free:
            self.stats.alloc_failures += 1
            return False
        pages = [self._take_page() for _ in range(need)]
        for p in pages:
            self._ref[p] = 1
        self._tables[rid].extend(pages)
        self.stats.allocs += need
        self.stats.peak_in_use = max(self.stats.peak_in_use,
                                     self.pages_in_use)
        return True

    def ensure_prompt(self, rid: int, tokens) -> tuple[bool, int]:
        """Reserve every page of a prompt, attaching cached prefix pages.

        Walks the token-hash chain of full pages from the request's
        current frontier: each chain hit *attaches* the cached physical
        page (refcount++, zero fresh pages charged); the first miss ends
        the chain and the remainder is allocated fresh.  If the chain
        covers the *entire* prompt, the last page is immediately
        copied-on-write so the frontier token's recompute (needed to
        produce logits) never writes into a shared page.

        All-or-nothing over the fresh pages; returns ``(ok,
        cached_tokens)`` where ``cached_tokens`` is how far the KV
        frontier can fast-forward (pool content already materialised).
        """
        tokens = np.asarray(tokens).reshape(-1)
        n_tokens = len(tokens)
        total = self.pages_for_tokens(n_tokens)
        table = self.table(rid)
        have = len(table)
        if total <= have:
            return True, 0
        attach: list[tuple[int, int]] = []             # (page, key)
        if self.prefix_cache:
            keys = self._chain_keys(tokens, min(total, n_tokens
                                                // self.page_tokens))
            for i in range(have, len(keys)):
                key, chunk = keys[i]
                hit = self._index.get(key)
                if hit is None or hit[1] != chunk:
                    break
                attach.append((hit[0], key))
        def _avail() -> int:
            return (len(self._free) + len(self._cached)
                    - sum(1 for p, _ in attach if p in self._cached))

        fresh = total - have - len(attach)
        full_hit = have + len(attach) == total
        if full_hit and attach:
            fresh += 1                                 # COW of the tail page
            if fresh > _avail():
                # the COW page may only be missing because every
                # reclaimable page is one we meant to attach: degrade to
                # attaching one page fewer and *prefilling* the tail
                attach.pop()
                fresh = total - have - len(attach)
                full_hit = False
        if fresh > _avail():
            # a blocked queue head polls this every scheduler tick:
            # tracked separately so alloc_failures keeps meaning
            # "mid-stream growth failed" (the preemption trigger)
            self.stats.admission_blocks += 1
            return False, 0
        for p, _ in attach:
            self._cached.pop(p, None)
            self._ref[p] = self._ref.get(p, 0) + 1
            table.append(p)
            self.stats.prefix_hits += 1
        if full_hit and attach:
            shared = table[-1]
            private = self._take_page()
            self._ref[private] = 1
            self._pending_copies.append((shared, private))
            table[-1] = private
            self._release_ref(shared)
            self.stats.cow_copies += 1
            self.stats.allocs += 1
            fresh -= 1
        for _ in range(fresh):
            p = self._take_page()
            self._ref[p] = 1
            table.append(p)
            self.stats.allocs += 1
        self.stats.peak_in_use = max(self.stats.peak_in_use,
                                     self.pages_in_use)
        cached = min(len(attach) * self.page_tokens, n_tokens)
        return True, cached

    def drain_copies(self) -> list[tuple[int, int]]:
        """Pending ``(src, dst)`` copy-on-write pool copies; the engine
        must replay these on k/v/summary pools *before* running any
        prefill/decode that reads the destination pages."""
        out = self._pending_copies
        self._pending_copies = []
        return out

    def drain_released(self) -> list[int]:
        """Pages whose last live reference dropped since the previous
        call.  The runahead tier invalidates these before the next
        decode: once released a page may be re-taken and rewritten
        (directly from ``_free``, or evicted out of ``_cached``), and a
        staged copy of the old content must not survive that.  Cached
        pages that get re-attached later are re-staged on demand —
        conservatively losing a hit, never correctness."""
        out = self._released
        self._released = []
        return out

    # -- the prefix index ----------------------------------------------------

    def register_prefix(self, rid: int, tokens, n_computed: int) -> int:
        """Publish ``rid``'s fully-materialised whole prompt pages into
        the prefix index (call *after* their KV is written to the pool).
        Idempotent; an existing registration for the same content wins.
        Returns the number of newly-registered pages."""
        if not self.prefix_cache:
            return 0
        tokens = np.asarray(tokens).reshape(-1)
        n_full = min(n_computed, len(tokens)) // self.page_tokens
        table = self._tables.get(rid, [])
        n_full = min(n_full, len(table))
        done, h = self._reg_state.get(rid, (0, _CHAIN_SEED))
        pt = self.page_tokens
        new = 0
        for i in range(done, n_full):
            chunk = tuple(int(t) for t in tokens[i * pt:(i + 1) * pt])
            h = hash((h, chunk))
            page = table[i]
            if h not in self._index and page not in self._page_key:
                self._index[h] = (page, chunk)
                self._page_key[page] = h
                new += 1
        if n_full > done:
            self._reg_state[rid] = (n_full, h)
        return new

    # -- host spill tier ------------------------------------------------------

    @property
    def spill_slots_free(self) -> int:
        return len(self._spill_free)

    @property
    def pages_spilled(self) -> int:
        """Host snapshots currently held (slots owned by spilled rids)."""
        return sum(len(s) for s, _ in self._spilled.values())

    def is_spilled(self, rid: int) -> bool:
        return rid in self._spilled

    def _drop_snap_refs(self, old_pages) -> None:
        for p in old_pages:
            n = self._snap_refs.get(p, 0) - 1
            if n > 0:
                self._snap_refs[p] = n
            else:
                self._snap_refs.pop(p, None)

    def spill_request(self, rid: int) -> bool:
        """Swap ``rid`` out: snapshot every page it holds into host spill
        slots and release the HBM pages.

        All-or-nothing on the slots; returns False (state untouched,
        ``stats.spill_failures``) when the tier is disabled or short.
        The engine must drain :meth:`drain_spill_outs` — performing the
        device->host reads — before any pool write in the next
        iteration, because the released ids can be re-taken immediately.
        """
        pages = self._tables.get(rid, [])
        if not self.spill_pages or not pages \
                or len(pages) > len(self._spill_free):
            self.stats.spill_failures += 1
            return False
        slots = [self._spill_free.pop() for _ in pages]
        self._pending_spill_out.extend(zip(pages, slots))
        self._spilled[rid] = (slots, list(pages))
        for p in pages:
            self._snap_refs[p] = self._snap_refs.get(p, 0) + 1
        self._tables.pop(rid)
        self._reg_state.pop(rid, None)     # resume rebuilds on fresh ids
        self.stats.frees += len(pages)
        self.stats.spill_out_pages += len(pages)
        for p in reversed(pages):
            self._release_ref(p)
        return True

    def resume_spilled(self, rid: int, n_tokens: int = 0) -> bool:
        """Swap ``rid`` back in: allocate fresh HBM pages for every
        snapshot (plus enough extra private pages to cover ``n_tokens``
        positions, e.g. the rest of a partially-prefilled prompt) and
        queue the host->device restores (:meth:`drain_swap_ins`).

        All-or-nothing; returns False (``stats.admission_blocks``) when
        the pool cannot supply every page.  On success the request's
        block table covers its old KV frontier on *new* physical ids;
        the old->new map is queued for :meth:`drain_remaps` so the
        runahead predictor can carry its history across the rename.
        """
        rec = self._spilled.get(rid)
        if rec is None:
            return False
        slots, old_pages = rec
        extra = max(0, self.pages_for_tokens(n_tokens) - len(slots))
        if len(slots) + extra > self.pages_free:
            self.stats.admission_blocks += 1
            return False
        del self._spilled[rid]
        self._drop_snap_refs(old_pages)
        pages = [self._take_page() for _ in range(len(slots) + extra)]
        for p in pages:
            self._ref[p] = 1
        self._tables.setdefault(rid, []).extend(pages)
        self._pending_swap_in.extend(zip(slots, pages))
        # slots drain (recycle only once the engine takes the copies):
        # a spill queued later in the same scheduler pass must not reuse
        # a slot whose restore bytes have not been read yet
        self._slots_draining.extend(slots)
        self._pending_remaps.append((rid, dict(zip(old_pages, pages))))
        self.stats.allocs += len(pages)
        self.stats.swap_in_pages += len(slots)
        self.stats.peak_in_use = max(self.stats.peak_in_use,
                                     self.pages_in_use)
        return True

    def drain_spill_outs(self) -> list[tuple[int, int]]:
        """Pending ``(page, slot)`` device->host snapshots.  The engine
        must read the page bytes before this iteration writes any pool
        page (released ids are re-takeable the moment they free)."""
        out = self._pending_spill_out
        self._pending_spill_out = []
        return out

    def drain_swap_ins(self) -> list[tuple[int, int]]:
        """Pending ``(slot, page)`` host->device restores; taking them
        recycles the draining slots.  The engine applies these *after*
        spill-out reads and COW copies (both read pages a restore may
        overwrite) and before any prefill/decode touches the pages."""
        out = self._pending_swap_in
        self._pending_swap_in = []
        self._spill_free.extend(self._slots_draining)
        self._slots_draining = []
        return out

    def drain_remaps(self) -> list[tuple[int, dict[int, int]]]:
        """Pending ``(rid, {old page -> new page})`` renames from
        resumes, for predictor-history carry-over."""
        out = self._pending_remaps
        self._pending_remaps = []
        return out

    def check_tier_invariants(self) -> None:
        """Audit the one-tier-per-page partition and the spill-slot
        bijection (see the module docstring); raises AssertionError on
        the first violation.  O(n_pages) — called from tests and the
        hypothesis property suite, not the hot path."""
        held: dict[int, int] = {}
        for table in self._tables.values():
            for p in table:
                held[p] = held.get(p, 0) + 1
        assert held == self._ref, \
            f"refcount conservation broken: {held} != {self._ref}"
        live, free, cached = set(held), set(self._free), set(self._cached)
        assert len(free) == len(self._free), "duplicate free-list entries"
        assert live.isdisjoint(free), f"live∩free: {live & free}"
        assert live.isdisjoint(cached), f"live∩cached: {live & cached}"
        assert free.isdisjoint(cached), f"free∩cached: {free & cached}"
        assert live | free | cached == set(range(1, self.n_pages)), \
            "page ids lost or invented across tiers"
        for p in self._page_key:
            assert p not in free, f"registered page {p} on the free list"
        # spill slots: free + draining + owned partition [0, spill_pages)
        owned: list[int] = []
        snaps: dict[int, int] = {}
        for slots, old in self._spilled.values():
            assert len(slots) == len(old)
            owned.extend(slots)
            for p in old:
                snaps[p] = snaps.get(p, 0) + 1
        slots_all = self._spill_free + self._slots_draining + owned
        assert sorted(slots_all) == list(range(self.spill_pages)), \
            "spill slots lost, invented, or double-owned"
        assert snaps == self._snap_refs, \
            f"snapshot refcounts diverged: {snaps} != {self._snap_refs}"
        # the bugfix invariant: a snapshotted page id never also sits in
        # the cached-but-free LRU (one home per content)
        assert cached.isdisjoint(snaps), \
            f"pages in cached LRU and spill pool: {cached & set(snaps)}"
        # idle-session holders always have a home: a block table (pinned
        # in HBM) or a spill record (idle swap-out) — a mark without
        # either would be leaked session accounting
        for r in self._session_rids:
            assert r in self._tables or r in self._spilled, \
                f"session hold {r} has neither a table nor a snapshot"

    # -- release -------------------------------------------------------------

    def free_request(self, rid: int) -> list[int]:
        """Drop every reference ``rid`` holds; returns the released ids.
        Shared pages stay live for their other holders; registered pages
        whose refcount hits 0 park in the cached LRU, the rest return to
        the free list (LIFO, keeping hot physical ids dense).  A spilled
        rid's host slots are recycled too (snapshot discarded)."""
        rec = self._spilled.pop(rid, None)
        if rec is not None:
            slots, old_pages = rec
            self._spill_free.extend(slots)
            self._drop_snap_refs(old_pages)
        if rid in self._session_rids:
            self._session_rids.discard(rid)
            self.stats.session_releases += 1
        pages = self._tables.pop(rid, [])
        self._reg_state.pop(rid, None)     # a resume rebuilds its table
        self.stats.frees += len(pages)
        for p in reversed(pages):
            self._release_ref(p)
        return pages

    def owned(self, rid: int) -> int:
        return len(self._tables.get(rid, []))


@dataclass
class PagePoolConfig:
    """Geometry of the physical pools the engine allocates once."""

    n_pages: int
    page_tokens: int
    n_layers: int
    n_kv_heads: int
    head_dim: int
    dtype_bytes: int = 2

    @property
    def page_bytes(self) -> int:
        """K+V bytes of one physical page across all layers."""
        return (2 * self.n_layers * self.page_tokens * self.n_kv_heads
                * self.head_dim * self.dtype_bytes)

    @property
    def pool_bytes(self) -> int:
        return self.n_pages * self.page_bytes
