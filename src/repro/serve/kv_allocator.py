"""Paged KV-cache allocator: one physical page pool, per-request block tables.

The serving engine's KV cache is a pool of fixed-size physical pages
(``cfg.kv_page`` tokens each); every request owns a *block table* mapping
its logical pages (position // page) to physical page ids.  The allocator
manages the free list, grows block tables on demand, and releases a
request's pages on completion or preemption.

Cross-request prefix caching (the ROADMAP's "caching" lever): physical
pages are *ref-counted* and full prompt pages are *content-addressed* by
a hash chain over their token content.  ``ensure_prompt`` splits into a
cached-hit **attach** (refcount++ on a page another request already
materialised) and a fresh allocation; releasing a page whose content is
registered in the prefix index parks it in an LRU of
unreferenced-but-cached pages instead of the free list, so a later
request with the same prompt prefix can re-attach it.  When a request's
write frontier lands in a shared page (a fully-cached prompt whose last
token must be recomputed to produce logits) the allocator performs
**copy-on-write**: the request gets a private copy and the engine
replays the pool bytes via :meth:`drain_copies`.

The physical page id is the unit the whole memory-system story shares:

* the TopK selection in the paged decode path gathers K/V *by physical
  page id* (``sparse_attention.select_pages_blocktable``),
* the NSB hot-set accounting (``capture.PageCache``) is keyed by the same
  physical ids, and
* the capture recorder (``capture.PageStream``) tags those ids per
  request/step so the NVR simulator replays the allocator's actual
  layout — with prefix caching on, genuinely *shared* physical ids, so
  NSB hit rate and NVR miss reduction are measured on the real reuse
  structure of multi-tenant traffic.

Physical page 0 is reserved as a scratch/null page: padded batch rows and
masked prefill positions write there, so the jitted model functions never
need data-dependent shapes.  The allocator never hands page 0 out.
"""

from __future__ import annotations

from collections import OrderedDict
from dataclasses import dataclass

import numpy as np

NULL_PAGE = 0

_CHAIN_SEED = 0x9E3779B9


@dataclass
class AllocatorStats:
    allocs: int = 0
    frees: int = 0
    alloc_failures: int = 0    # ensure() growth failures (preempt trigger)
    admission_blocks: int = 0  # ensure_prompt() refusals (HOL polling)
    peak_in_use: int = 0
    prefix_hits: int = 0       # pages attached from the prefix index
    prefix_evictions: int = 0  # cached pages reclaimed for fresh allocs
    cow_copies: int = 0        # shared pages privatised before a write


class KVBlockAllocator:
    """Free-list + prefix-cache allocator over ``n_pages`` physical pages.

    ``n_pages`` includes the reserved scratch page 0, so ``capacity`` —
    the number of allocatable pages — is ``n_pages - 1``.

    Page lifecycle: free -> referenced (refcount >= 1, possibly by
    several requests sharing a prompt prefix) -> either free again, or —
    when the page's content is registered in the prefix index — *cached*
    (refcount 0, content retained, LRU-evictable).  ``pages_free`` counts
    everything reclaimable (free list + cached LRU), so admission-control
    arithmetic is unchanged by caching.
    """

    def __init__(self, n_pages: int, page_tokens: int,
                 prefix_cache: bool = True) -> None:
        if n_pages < 2:
            raise ValueError("need at least 2 pages (page 0 is reserved)")
        self.n_pages = n_pages
        self.page_tokens = page_tokens
        self.prefix_cache = prefix_cache
        # pop() from the end -> low page ids are handed out first
        self._free = list(range(n_pages - 1, NULL_PAGE, -1))
        self._tables: dict[int, list[int]] = {}
        self._ref: dict[int, int] = {}                 # page -> refcount
        # content-addressing: chain key -> (page, token tuple); the token
        # tuple is compared on attach, so a hash collision can never
        # splice the wrong content into a request
        self._index: dict[int, tuple[int, tuple]] = {}
        self._page_key: dict[int, int] = {}            # page -> chain key
        self._cached: OrderedDict[int, None] = OrderedDict()
        # rid -> (pages registered so far, chain key at that depth):
        # register_prefix resumes here instead of re-hashing the prompt
        self._reg_state: dict[int, tuple[int, int]] = {}
        self._pending_copies: list[tuple[int, int]] = []
        # pages whose last live reference dropped since the previous
        # drain_released(): the runahead hot tier invalidates these —
        # a freed page can be re-taken and rewritten, so a staged copy
        # of its old content must never resolve again
        self._released: list[int] = []
        self.stats = AllocatorStats()

    # -- capacity ------------------------------------------------------------

    @property
    def capacity(self) -> int:
        return self.n_pages - 1

    @property
    def pages_free(self) -> int:
        """Reclaimable pages: the free list plus cached-but-unreferenced
        pages (evictable, so they count as available for admission)."""
        return len(self._free) + len(self._cached)

    @property
    def pages_in_use(self) -> int:
        """Pages referenced by at least one live request."""
        return self.capacity - self.pages_free

    @property
    def pages_cached(self) -> int:
        return len(self._cached)

    def refcount(self, page: int) -> int:
        return self._ref.get(page, 0)

    def pages_for_tokens(self, n_tokens: int) -> int:
        return -(-n_tokens // self.page_tokens)

    # -- block tables --------------------------------------------------------

    def table(self, rid: int) -> list[int]:
        return self._tables.setdefault(rid, [])

    def table_array(self, rid: int, n_logical: int) -> np.ndarray:
        """The request's block table padded with NULL_PAGE to length
        ``n_logical`` (the jitted functions take fixed-shape tables)."""
        bt = np.full((n_logical,), NULL_PAGE, dtype=np.int32)
        pages = self._tables.get(rid, [])
        bt[: len(pages)] = pages[:n_logical]
        return bt

    # -- page plumbing -------------------------------------------------------

    def _take_page(self) -> int:
        """One reclaimable page (caller has checked availability): free
        list first, then evict the least-recently-parked cached page."""
        if self._free:
            return self._free.pop()
        page, _ = self._cached.popitem(last=False)
        key = self._page_key.pop(page)
        del self._index[key]
        self.stats.prefix_evictions += 1
        return page

    def _release_ref(self, page: int) -> None:
        self._ref[page] -= 1
        if self._ref[page]:
            return
        del self._ref[page]
        self._released.append(page)
        if page in self._page_key:
            # content survives for future prefix attaches, LRU order
            self._cached[page] = None
            self._cached.move_to_end(page)
        else:
            self._free.append(page)

    def _chain_keys(self, tokens, n_pages: int):
        """``(key, chunk)`` per full page of ``tokens``: key i hashes the
        chain of pages [0..i], so equal keys mean equal prefix *and*
        equal absolute positions (RoPE-safe sharing)."""
        pt = self.page_tokens
        out = []
        h = _CHAIN_SEED
        for i in range(n_pages):
            chunk = tuple(int(t) for t in tokens[i * pt:(i + 1) * pt])
            h = hash((h, chunk))
            out.append((h, chunk))
        return out

    # -- allocation ----------------------------------------------------------

    def ensure(self, rid: int, n_tokens: int) -> bool:
        """Grow ``rid``'s block table to cover ``n_tokens`` positions
        with freshly-allocated private pages.

        All-or-nothing: returns False (and allocates nothing) if the
        reclaimable pages cannot supply every page needed.
        """
        need = self.pages_for_tokens(n_tokens) - len(self.table(rid))
        if need <= 0:
            return True
        if need > self.pages_free:
            self.stats.alloc_failures += 1
            return False
        pages = [self._take_page() for _ in range(need)]
        for p in pages:
            self._ref[p] = 1
        self._tables[rid].extend(pages)
        self.stats.allocs += need
        self.stats.peak_in_use = max(self.stats.peak_in_use,
                                     self.pages_in_use)
        return True

    def ensure_prompt(self, rid: int, tokens) -> tuple[bool, int]:
        """Reserve every page of a prompt, attaching cached prefix pages.

        Walks the token-hash chain of full pages from the request's
        current frontier: each chain hit *attaches* the cached physical
        page (refcount++, zero fresh pages charged); the first miss ends
        the chain and the remainder is allocated fresh.  If the chain
        covers the *entire* prompt, the last page is immediately
        copied-on-write so the frontier token's recompute (needed to
        produce logits) never writes into a shared page.

        All-or-nothing over the fresh pages; returns ``(ok,
        cached_tokens)`` where ``cached_tokens`` is how far the KV
        frontier can fast-forward (pool content already materialised).
        """
        tokens = np.asarray(tokens).reshape(-1)
        n_tokens = len(tokens)
        total = self.pages_for_tokens(n_tokens)
        table = self.table(rid)
        have = len(table)
        if total <= have:
            return True, 0
        attach: list[tuple[int, int]] = []             # (page, key)
        if self.prefix_cache:
            keys = self._chain_keys(tokens, min(total, n_tokens
                                                // self.page_tokens))
            for i in range(have, len(keys)):
                key, chunk = keys[i]
                hit = self._index.get(key)
                if hit is None or hit[1] != chunk:
                    break
                attach.append((hit[0], key))
        def _avail() -> int:
            return (len(self._free) + len(self._cached)
                    - sum(1 for p, _ in attach if p in self._cached))

        fresh = total - have - len(attach)
        full_hit = have + len(attach) == total
        if full_hit and attach:
            fresh += 1                                 # COW of the tail page
            if fresh > _avail():
                # the COW page may only be missing because every
                # reclaimable page is one we meant to attach: degrade to
                # attaching one page fewer and *prefilling* the tail
                attach.pop()
                fresh = total - have - len(attach)
                full_hit = False
        if fresh > _avail():
            # a blocked queue head polls this every scheduler tick:
            # tracked separately so alloc_failures keeps meaning
            # "mid-stream growth failed" (the preemption trigger)
            self.stats.admission_blocks += 1
            return False, 0
        for p, _ in attach:
            self._cached.pop(p, None)
            self._ref[p] = self._ref.get(p, 0) + 1
            table.append(p)
            self.stats.prefix_hits += 1
        if full_hit and attach:
            shared = table[-1]
            private = self._take_page()
            self._ref[private] = 1
            self._pending_copies.append((shared, private))
            table[-1] = private
            self._release_ref(shared)
            self.stats.cow_copies += 1
            self.stats.allocs += 1
            fresh -= 1
        for _ in range(fresh):
            p = self._take_page()
            self._ref[p] = 1
            table.append(p)
            self.stats.allocs += 1
        self.stats.peak_in_use = max(self.stats.peak_in_use,
                                     self.pages_in_use)
        cached = min(len(attach) * self.page_tokens, n_tokens)
        return True, cached

    def drain_copies(self) -> list[tuple[int, int]]:
        """Pending ``(src, dst)`` copy-on-write pool copies; the engine
        must replay these on k/v/summary pools *before* running any
        prefill/decode that reads the destination pages."""
        out = self._pending_copies
        self._pending_copies = []
        return out

    def drain_released(self) -> list[int]:
        """Pages whose last live reference dropped since the previous
        call.  The runahead tier invalidates these before the next
        decode: once released a page may be re-taken and rewritten
        (directly from ``_free``, or evicted out of ``_cached``), and a
        staged copy of the old content must not survive that.  Cached
        pages that get re-attached later are re-staged on demand —
        conservatively losing a hit, never correctness."""
        out = self._released
        self._released = []
        return out

    # -- the prefix index ----------------------------------------------------

    def register_prefix(self, rid: int, tokens, n_computed: int) -> int:
        """Publish ``rid``'s fully-materialised whole prompt pages into
        the prefix index (call *after* their KV is written to the pool).
        Idempotent; an existing registration for the same content wins.
        Returns the number of newly-registered pages."""
        if not self.prefix_cache:
            return 0
        tokens = np.asarray(tokens).reshape(-1)
        n_full = min(n_computed, len(tokens)) // self.page_tokens
        table = self._tables.get(rid, [])
        n_full = min(n_full, len(table))
        done, h = self._reg_state.get(rid, (0, _CHAIN_SEED))
        pt = self.page_tokens
        new = 0
        for i in range(done, n_full):
            chunk = tuple(int(t) for t in tokens[i * pt:(i + 1) * pt])
            h = hash((h, chunk))
            page = table[i]
            if h not in self._index and page not in self._page_key:
                self._index[h] = (page, chunk)
                self._page_key[page] = h
                new += 1
        if n_full > done:
            self._reg_state[rid] = (n_full, h)
        return new

    # -- release -------------------------------------------------------------

    def free_request(self, rid: int) -> list[int]:
        """Drop every reference ``rid`` holds; returns the released ids.
        Shared pages stay live for their other holders; registered pages
        whose refcount hits 0 park in the cached LRU, the rest return to
        the free list (LIFO, keeping hot physical ids dense)."""
        pages = self._tables.pop(rid, [])
        self._reg_state.pop(rid, None)     # a resume rebuilds its table
        self.stats.frees += len(pages)
        for p in reversed(pages):
            self._release_ref(p)
        return pages

    def owned(self, rid: int) -> int:
        return len(self._tables.get(rid, []))


@dataclass
class PagePoolConfig:
    """Geometry of the physical pools the engine allocates once."""

    n_pages: int
    page_tokens: int
    n_layers: int
    n_kv_heads: int
    head_dim: int
    dtype_bytes: int = 2

    @property
    def page_bytes(self) -> int:
        """K+V bytes of one physical page across all layers."""
        return (2 * self.n_layers * self.page_tokens * self.n_kv_heads
                * self.head_dim * self.dtype_bytes)

    @property
    def pool_bytes(self) -> int:
        return self.n_pages * self.page_bytes
