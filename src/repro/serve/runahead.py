"""Online vector runahead for the paged serve engine.

The paper's core mechanism is a decoupled, speculative, lightweight
sub-thread that runs *ahead* of the compute stream and stages sparse
gather targets into a small Near-Storage Buffer (NSB) before the demand
access arrives.  This module is that mechanism mapped onto the serving
layer, closing ROADMAP priority #1: NVR stops being an offline replay
tool (``capture.py`` -> simulator) and becomes a live stage in
``PagedEngine.step()``.

Three pieces, mirroring the paper's decomposition:

:class:`NSBHotTier` — the physical staging buffer.  The engine extends
its K/V pools with ``n_slots`` extra *tail* pages (``[L, n_demand +
n_slots, page, KV, D]``); this class owns the mapping from demand
physical page id -> staged tail slot (the *hot-map*), FIFO slot
recycling, and explicit invalidation.  Staged pages are byte copies made
by a jitted gather; the demand region and the block tables stay
authoritative, so a stale entry is *dropped*, never patched — the
soundness contract is "the hot-map never resolves a page whose demand
copy has been written or freed since staging" (see ARCHITECTURE.md and
the hypothesis property test).  Accounting runs through a mirrored
:class:`~repro.core.nvr.capture.PageCache` twin so serve metrics and the
simulator share one accuracy/coverage definition.

:class:`RunaheadPredictor` — the DARE-style filter (PAPERS.md): per
request, a *history* predictor (last TopK selection; trivially right
while the selection is stable) plus a stability counter.  Only requests
the trivial predictor cannot cover — new rows entering decode, rows
whose selection churns — are handed to the expensive proxy scorer, so
runahead effort concentrates where speculation pays.

:func:`make_proxy_scorer` — the vector-runahead address-generation
slice.  Between decode steps the engine already knows each row's *next*
input token and position (teacher-forced replay rows trivially; frontier
rows from the argmax just computed), so the slice embeds that token,
applies layer 0's pre-attention norm + query projection + RoPE at the
next position, and scores the ``s_pool`` page summaries through the
block table — the same ``select_pages_blocktable`` the demand path runs,
one iteration early, at a tiny fraction of a forward pass.  Mispredicted
pages cost staging bandwidth only (fuzzy-fetch philosophy: over-fetch is
reported, never corrected-for).

IMP's one-batch-ahead limitation (``core/nvr/prefetchers.py``) is kept
as the in-repo baseline: ``mode="imp"`` stages exactly the pages the
*current* step selected — always one step behind the selection drift —
with no proxy slice and no stability filter.

With the host spill tier configured (``PagedEngine(spill_pages=...)``)
the same between-steps window also performs **fetch-back**: when the
waiting-queue head is a swapped-out request, the engine swap-resumes it
inside ``_run_runahead`` — host slots restore to fresh HBM pages, the
predictor's history renames through :meth:`RunaheadPredictor.remap`,
and the remapped history pages are staged into the NSB tail ahead of
the demand pile-up, so a resumed request's first post-resume gather
never touches a host page (host -> HBM -> NSB in one budget window).

Invariants this module holds (checked by the hypothesis suite):

* **Slot bijection** — every staged slot is owned by exactly one demand
  page and ``hot_map[page] == slot`` iff ``page`` owns ``slot``; free,
  staged, and (nothing else) partition the slot space.
* **Staleness-free resolution** — the hot-map never resolves a page
  whose demand copy was rewritten or freed after staging: writers
  invalidate first (or write through, for the decode frontier).
* **Speculation never steers computation** — predictor output and
  staged bytes only change where reads are served from; block tables
  and the demand pool stay authoritative, so tokens are bitwise
  invariant to runahead mode.
"""

from __future__ import annotations

from collections import OrderedDict
from dataclasses import dataclass, field

import numpy as np

from ..core.nvr.capture import PageCache

MODES = ("off", "imp", "nvr")

# expert-weight runahead modes (PagedEngine(expert_runahead=...)):
# "router" predicts the next decode batch's routed experts with the
# router itself as the address-generation slice (see make_router_scorer)
EXPERT_MODES = ("off", "router")


@dataclass
class RunaheadStats:
    staged_pages: int = 0           # staging copies issued (bandwidth)
    stage_calls: int = 0            # jitted gather dispatches
    invalidations: int = 0          # staged entries dropped by writes/frees
    proxy_rows: int = 0             # rows sent through the proxy scorer
    filtered_rows: int = 0          # rows the stability filter covered
    budget_truncated: int = 0       # candidate pages dropped by the budget


class NSBHotTier:
    """Hot-map + slot allocator over the pool's staged tail region.

    ``n_demand`` is the size of the demand page region (the allocator's
    id space); slots ``0..n_slots-1`` name the tail pages ``n_demand +
    slot`` of the physical pools.  ``stage()`` assigns slots (FIFO
    recycling, matching the machine-model NSB's insertion-order
    eviction) and returns the ``(src_page, slot)`` copies the engine's
    jitted gather must perform; ``invalidate()`` drops entries whose
    demand copy is about to be (or was) rewritten or freed.  The
    ``hot_map`` array — demand page id -> slot, -1 when unstaged — is
    what the decode step resolves TopK ids through.
    """

    def __init__(self, n_demand: int, n_slots: int) -> None:
        if n_slots < 1:
            raise ValueError(f"need >= 1 staging slot, got {n_slots}")
        self.n_demand = n_demand
        self.n_slots = n_slots
        self._slot_of: OrderedDict[int, int] = OrderedDict()  # staged order
        self._page_of = np.full((n_slots,), -1, dtype=np.int32)
        self._free = list(range(n_slots - 1, -1, -1))
        self._hot = np.full((n_demand,), -1, dtype=np.int32)
        # accounting twin: same capacity, mirrored stage/drop, so
        # accuracy/coverage use the one shared PageCache definition
        self.model = PageCache(n_slots)
        # extra mirrors (e.g. a ShardedPageCache for per-shard rollups
        # under tp): receive every stage/drop the twin does — eviction
        # victims are pre-dropped here, so mirrors never self-evict and
        # cannot drift from the tier's FIFO order
        self.mirrors: list = []
        self.stats = RunaheadStats()

    # -- queries -------------------------------------------------------------

    @property
    def n_staged(self) -> int:
        return len(self._slot_of)

    def resolve(self, page: int) -> int:
        """Staged slot of ``page``, or -1."""
        return self._slot_of.get(int(page), -1)

    def hot_map(self) -> np.ndarray:
        """The live demand-page-id -> slot map (int32 [n_demand]; -1 =
        not staged).  Returned by reference: snapshot with
        ``jnp.asarray`` / ``.copy()`` before mutating the tier."""
        return self._hot

    def staged_pages(self) -> list:
        return list(self._slot_of)

    # -- mutation ------------------------------------------------------------

    def _evict_oldest(self) -> int:
        victim, slot = self._slot_of.popitem(last=False)
        self._page_of[slot] = -1
        self._hot[victim] = -1
        self.model.drop(victim)
        for m in self.mirrors:
            m.drop(victim)
        return slot

    def stage(self, pages, max_copies: int | None = None) -> list:
        """Assign slots to ``pages`` (skipping NULL/out-of-range ids and
        pages already staged); returns the ``(src_page, slot)`` copy
        list, at most ``max_copies`` long.  The caller owns making the
        copies land before the next decode reads the hot-map.

        Every slot appears at most once per call: the caller performs
        all copies in one unordered scatter, so reusing a slot within a
        call (FIFO-evicting a page staged moments earlier) would leave
        the slot's bytes to scatter ordering while the hot-map names one
        owner.  When the only eviction victims left were staged by this
        same call, the remaining candidates are dropped as
        budget-truncated instead."""
        copies: list = []
        budget = self.n_slots if max_copies is None else max_copies
        new_slots: set = set()
        for p in pages:
            p = int(p)
            if p <= 0 or p >= self.n_demand or p in self._slot_of:
                continue
            if len(copies) >= budget:
                self.stats.budget_truncated += 1
                continue
            if self._free:
                slot = self._free.pop()
            else:
                # FIFO victim; same-call entries are the newest, so if
                # the oldest is one of ours the tier is all same-call
                oldest_slot = next(iter(self._slot_of.values()))
                if oldest_slot in new_slots:
                    self.stats.budget_truncated += 1
                    continue
                slot = self._evict_oldest()
            new_slots.add(slot)
            self._slot_of[p] = slot
            self._page_of[slot] = p
            self._hot[p] = slot
            self.model.stage(p)
            for m in self.mirrors:
                m.stage(p)
            self.stats.staged_pages += 1
            copies.append((p, slot))
        return copies

    def touch(self, page: int) -> bool:
        """Demand access accounting: True if ``page`` is staged.  Keeps
        the PageCache twin's hit/miss/prefetch-used counters (the
        accuracy/coverage source) in sync with the physical map."""
        hit = int(page) in self._slot_of
        model_hit = self.model.touch(int(page), install=False)
        assert model_hit == hit, \
            f"hot-tier accounting twin diverged on page {page}"
        return hit

    def invalidate(self, pages) -> int:
        """Drop staged entries for ``pages`` (rewritten or freed demand
        copies).  Idempotent; returns the number dropped."""
        n = 0
        for p in pages:
            slot = self._slot_of.pop(int(p), None)
            if slot is None:
                continue
            self._page_of[slot] = -1
            self._hot[int(p)] = -1
            self._free.append(slot)
            self.model.drop(int(p))
            for m in self.mirrors:
                m.drop(int(p))
            self.stats.invalidations += 1
            n += 1
        return n

    # -- derived metrics -----------------------------------------------------

    @property
    def hit_rate(self):
        """Demand hit rate against the staged tier (None pre-traffic)."""
        return self.model.hit_rate

    @property
    def accuracy(self):
        """Of the pages staged, the fraction demanded before eviction
        (the paper's prediction-accuracy axis; None before staging)."""
        return self.model.accuracy

    @property
    def coverage(self):
        """Of the pages demanded, the fraction served by a staged entry
        (the coverage axis; equals hit_rate for a pure-speculative
        tier — demand misses never install)."""
        return self.model.coverage

    @property
    def overfetch(self):
        """Staged-but-never-used fraction: wasted staging bandwidth
        (1 - accuracy; the fuzzy-fetch cost axis)."""
        acc = self.accuracy
        return None if acc is None else 1.0 - acc


@dataclass
class _ReqHistory:
    sel: tuple = ()                 # last observed selection (sorted ids)
    stable: int = 0                 # consecutive identical selections


@dataclass
class RunaheadPredictor:
    """Per-request history predictors + the DARE stability filter.

    ``observe()`` records each decode step's selected demand pages per
    request; a request whose selection repeats ``stable_after`` times is
    *stable* — its history predicts the next step, no proxy needed.
    ``split()`` partitions next-step rows into (covered, needs-proxy).
    """

    mode: str = "nvr"
    stable_after: int = 2
    _hist: dict = field(default_factory=dict)

    def observe(self, rid: int, pages: np.ndarray) -> None:
        sel = tuple(sorted(int(p) for p in pages))
        h = self._hist.setdefault(rid, _ReqHistory())
        h.stable = h.stable + 1 if sel == h.sel and sel else 0
        h.sel = sel

    def history(self, rid: int) -> tuple:
        h = self._hist.get(rid)
        return h.sel if h is not None else ()

    def is_stable(self, rid: int) -> bool:
        h = self._hist.get(rid)
        return h is not None and h.stable >= self.stable_after

    def forget(self, rid: int) -> None:
        self._hist.pop(rid, None)

    def remap(self, rid: int, page_map: dict) -> None:
        """Rename ``rid``'s history through ``page_map`` (old physical
        page id -> new), preserving the stability counter: a swap-resume
        restores identical page *content* onto fresh physical ids, so
        the request's selection pattern — and therefore its stability —
        carries over; only the ids it is expressed in change.  Ids not
        in the map (e.g. still-live shared prefix pages) pass through."""
        h = self._hist.get(rid)
        if h is not None and h.sel:
            h.sel = tuple(sorted(page_map.get(p, p) for p in h.sel))

    def split(self, rids) -> tuple[list, list]:
        """(history-covered rids, proxy rids) for the next step.  In
        ``imp`` mode everything is history — IMP has no runahead slice,
        so it is structurally one step behind any selection drift."""
        if self.mode == "imp":
            return list(rids), []
        covered = [r for r in rids if self.is_stable(r)]
        proxy = [r for r in rids if not self.is_stable(r)]
        return covered, proxy


def make_proxy_scorer(cfg):
    """Build the address-generation slice: next-step TopK prediction.

    Returns ``fn(params, s_pool, token, pos, bt, n_valid) -> phys``
    with token/pos int32 [R], bt int32 [R, NL], n_valid int32 [R] and
    phys int32 [R, KV, K] — the *predicted* next-iteration physical
    page selection.  Only layer 0's ln1/wq (+bq) and the embedding are
    read: the slice approximates the next decode's layer-0 selection
    query from the known next token, skipping the residual stream
    entirely — the few-percent-of-a-forward-pass cost budget the
    paper's decoupled sub-thread rides in.  Speculative by
    construction: its output steers staging only, never the demand
    computation, so prediction error costs bandwidth, not correctness.
    """
    import jax
    import jax.numpy as jnp

    from ..models import layers as mlayers
    from ..models import sparse_attention

    dt = jnp.dtype(cfg.param_dtype)
    g = cfg.n_heads // cfg.n_kv_heads

    def fn(params, s_pool, token, pos, bt, n_valid):
        r = token.shape[0]
        k_sel = int(min(cfg.kv_topk_pages, bt.shape[1]))
        x = jnp.take(params["embed"], token[:, None], axis=0).astype(dt)
        if getattr(cfg, "scale_embed", False):
            x = x * (cfg.d_model ** 0.5)
        lp0 = jax.tree.map(lambda a: a[0], params["layers"])
        h = mlayers.rms_norm(x, lp0["ln1"], cfg.norm_eps)
        q = jnp.einsum("bsd,dh->bsh", h, lp0["wq"].astype(h.dtype))
        if cfg.qkv_bias:
            q = q + lp0["bq"].astype(h.dtype)
        q = q.reshape(r, 1, cfg.n_heads, cfg.hd)
        q = mlayers.apply_rope(q, pos[:, None], cfg.rope_theta)
        qh = q[:, 0].reshape(r, cfg.n_kv_heads, g, cfg.hd)
        _, phys = sparse_attention.select_pages_blocktable(
            qh, s_pool[0], bt, n_valid, k_sel)
        return phys

    return fn


def make_router_scorer(cfg):
    """Build the expert-weight address-generation slice: next-step
    TopK *expert* prediction from the router itself.

    Returns ``fn(params, token) -> eids`` with token int32 [R] (each
    row's known next input token) and eids int32 [R, top_k] — the
    predicted layer-0 routing of the next decode step.  The slice
    embeds the token, applies layer 0's pre-FFN norm, and scores it
    through layer 0's router: the router *is* the paper's cheap
    address-generation function here (NeutronSparse's coordinated-
    engines framing — routing computes the gather addresses an
    iteration before the FFN demands the tiles), and skipping the
    attention/residual stream keeps it inside the decoupled
    sub-thread's few-percent cost budget.  Deeper layers' routing is
    not modelled — the per-request history predictor covers them once a
    request's expert selection stabilises, the same DARE-style division
    of labour as the KV proxy.  Speculative by construction: output
    steers staging only, so a misrouted prediction costs staging
    bandwidth, never a logit.
    """
    import jax
    import jax.numpy as jnp

    from ..models import layers as mlayers

    dt = jnp.dtype(cfg.param_dtype)

    def fn(params, token):
        x = jnp.take(params["embed"], token[:, None], axis=0).astype(dt)
        if getattr(cfg, "scale_embed", False):
            x = x * (cfg.d_model ** 0.5)
        lp0 = jax.tree.map(lambda a: a[0], params["layers"])
        h = mlayers.rms_norm(x, lp0["ln2"], cfg.norm_eps)[:, 0]
        logits = jnp.einsum("rd,de->re", h.astype(jnp.float32),
                            lp0["router"].astype(jnp.float32))
        _, eids = jax.lax.top_k(logits, cfg.top_k)
        return eids.astype(jnp.int32)

    return fn
