"""Pluggable scheduling policies: who gets admitted, who gets evicted.

The :class:`~repro.serve.scheduler.Scheduler` owns the *mechanism* of
iteration-level scheduling — reservation, token budgets, plan assembly,
the speculative/commit double buffer — and delegates exactly two
*decisions* to a policy object:

* ``admit_order(waiting, now)`` — the order in which waiting requests
  are offered admission.  The scheduler walks the order and stops at the
  first request whose reservation fails (head-of-line blocking **on the
  policy's order**), so the policy controls who the head of line *is*
  but not the all-or-nothing reservation contract.
* ``choose_victim(running, requester, now, sched)`` — which running
  request to evict when ``requester`` needs a page and the pool is
  full.  Returning ``None`` means "no acceptable victim": the scheduler
  then preempts (defers) the requester itself.

``on_admit(req, now)`` is the bookkeeping hook: the scheduler calls it
once per *actual* admission so stateful policies (tenant deficit
counters) charge only for service that really happened — ``admit_order``
itself must be a **pure read** of policy + request state.

Decision-replay contract (the speculative scheduler): policies live as
an attribute of the scheduler, so ``schedule_speculative`` deep-copies
them along with the queues.  A draft built on the shadow and the real
``commit`` therefore start from identical policy state, and as long as
decisions are deterministic functions of request/queue/counter state
(never wall clock, never RNG, never sampled token values) the draft
replays exactly — which is what keeps PR 8's double-buffered plans
valid under any policy.  Stateful policies must also deep-copy cleanly:
keep counters in plain dicts keyed by tenant strings, never hold
references to engine-side objects.

Policies:

* :class:`FifoPolicy` — the pre-refactor behaviour, verbatim: strict
  FIFO admission with head-of-line blocking, preempt-youngest eviction
  (the running request with the highest ``admission_seq`` that is
  younger than the requester).  This is the default and the parity
  oracle: with it, tokens and logits are bitwise-identical to the
  hardwired scheduler on every bench.
* :class:`PriorityPolicy` — strict priority classes (lower ``priority``
  value = more important), FIFO within a class; eviction victimises the
  lowest class first, youngest within the class, and never a request
  that outranks the requester.
* :class:`SloFairPolicy` — per-tenant deficit-round-robin admission
  (fair-queueing by cumulative service counters) and SLO-aware
  eviction: the victim is the running request whose eviction least
  harms aggregate SLO attainment, scored from per-request TTFT/TPOT
  deadlines and the known swap-vs-recompute resume cost of the spill
  tier.
"""

from __future__ import annotations

import math


class SchedPolicy:
    """Admission-order + eviction-victim decisions for the scheduler.

    Subclasses override the three hooks; state (if any) must deep-copy
    cleanly and decisions must be deterministic — see the module
    docstring for the decision-replay contract.
    """

    name = "base"

    def admit_order(self, waiting, now):
        """Return the waiting requests in admission-offer order.

        Must be a **pure** function of policy + request state (no
        mutation: the speculative scheduler and the engine's fetch-back
        probe call this without admitting anyone), and must return every
        waiting request exactly once — completeness is what rules out
        starvation-by-omission for any policy.
        """
        raise NotImplementedError

    def choose_victim(self, running, requester, now, sched=None):
        """Pick the running request to evict so ``requester`` can
        allocate, or ``None`` to defer the requester instead."""
        raise NotImplementedError

    def on_admit(self, req, now):
        """Bookkeeping callback: ``req`` was actually admitted."""


class FifoPolicy(SchedPolicy):
    """Strict FIFO admission, preempt-youngest eviction (the
    pre-refactor scheduler's hardwired behaviour, verbatim)."""

    name = "fifo"

    def admit_order(self, waiting, now):
        return list(waiting)

    def choose_victim(self, running, requester, now, sched=None):
        victims = [r for r in running
                   if r is not requester
                   and r.admission_seq > requester.admission_seq]
        if not victims:
            return None
        return max(victims, key=lambda r: r.admission_seq)


class PriorityPolicy(SchedPolicy):
    """Strict priority classes; FIFO within a class.

    ``Request.priority`` is the class (lower value = more important;
    the default 0 is the highest class).  Admission offers classes in
    order, FIFO within each (stable sort).  Eviction victimises the
    request with the *worst* ``(priority, admission_seq)`` rank, and
    only if that rank is strictly worse than the requester's — a
    request is never evicted for one it outranks, which is the same
    no-inversion guard FIFO gets from ``admission_seq`` alone.
    """

    name = "priority"

    @staticmethod
    def _rank(r):
        return (r.priority, r.admission_seq)

    def admit_order(self, waiting, now):
        return sorted(waiting, key=lambda r: r.priority)

    def choose_victim(self, running, requester, now, sched=None):
        victims = [r for r in running
                   if r is not requester
                   and self._rank(r) > self._rank(requester)]
        if not victims:
            return None
        return max(victims, key=self._rank)


class SloFairPolicy(SchedPolicy):
    """Per-tenant deficit-round-robin admission + SLO-aware eviction.

    Admission is deficit round robin over tenants with *token* costs
    (classic DRR charges bytes; prompts are the serve-side analogue):
    ``served`` holds one cumulative service counter per tenant (the
    deficit bookkeeping — tenant *t*'s deficit versus *u* is
    ``served[u] - served[t]``), and each queued request gets the virtual
    start tag ``served[tenant] + cost of the tenant's queued requests
    ahead of it``.  Ordering by start tag interleaves tenants in
    proportion to what they have already consumed, so one tenant's burst
    of *long* prompts cannot head-of-line block another tenant's cheap
    interactive requests (the count-based variant would actually favour
    the bursty tenant: few huge requests look "under-served" per
    request), while requests within a tenant stay FIFO.  Counters are
    charged in :meth:`on_admit` only — one charge per actual admission,
    so ``sum(served.values())`` always equals the summed cost of all
    admissions (the conservation invariant the property tests audit)
    and ``admit_order`` stays pure.

    Eviction minimises aggregate SLO harm.  Each candidate is scored
    ``harm = resume_cost x urgency``: ``resume_cost`` is the known
    swap-vs-recompute cost of bringing the victim back (restore ticks
    when the spill tier has slots for its pages, re-prefill + decode
    replay ticks otherwise), and ``urgency`` grows as the candidate's
    TTFT/TPOT deadline slack shrinks.  Requests with no SLO — or whose
    SLO is already lost — are nearly free to evict.  The victim is the
    minimum-harm candidate, and only if evicting it harms less than
    deferring the requester itself; otherwise ``None`` (defer).
    """

    name = "slo_fair"

    # urgency multipliers for the no-deadline / already-lost cases: tiny
    # but nonzero, so resume cost still breaks ties among "free" victims
    NO_SLO_URGENCY = 0.1
    LOST_URGENCY = 0.2

    def __init__(self):
        self.served: dict[str, int] = {}

    @staticmethod
    def _cost(r) -> int:
        """Admission cost in tokens: the prompt the prefill must chew
        through (decode length is unknown at admission time)."""
        return max(int(r.prompt_len), 1)

    def admit_order(self, waiting, now):
        acc: dict[str, int] = {}
        keyed = []
        for i, r in enumerate(waiting):
            start = self.served.get(r.tenant, 0) + acc.get(r.tenant, 0)
            acc[r.tenant] = acc.get(r.tenant, 0) + self._cost(r)
            keyed.append((start, i, r))
        keyed.sort(key=lambda e: (e[0], e[1]))
        return [r for _, _, r in keyed]

    def on_admit(self, req, now):
        self.served[req.tenant] = (self.served.get(req.tenant, 0)
                                   + self._cost(req))

    # -- eviction-harm model -------------------------------------------------

    def _resume_cost(self, r, sched) -> float:
        """Modeled ticks to bring ``r`` back after eviction."""
        if sched is None:
            return 1.0
        al = sched.allocator
        pages = al.owned(r.rid)
        if al.spill_pages > 0 and al.spill_slots_free >= pages:
            # swap-out/swap-in: one drained restore pass; per-page copy
            # cost is small against a re-prefill
            return 1.0 + 0.125 * pages
        # recompute: re-prefill the materialised prompt in chunks, then
        # replay every already-generated token through decode
        chunks = math.ceil(min(r.computed, r.prompt_len)
                           / max(sched.chunk, 1))
        return 1.0 + chunks + len(r.out_tokens)

    def _harm(self, r, now, sched) -> float:
        resume = self._resume_cost(r, sched)
        if r.first_token_at < 0:
            # pre-first-token: eviction lands squarely on TTFT
            if r.slo_ttft is None:
                return resume * self.NO_SLO_URGENCY
            slack = (r.arrival + r.slo_ttft) - now
        else:
            # decoding: eviction stalls the token stream, harming TPOT
            if r.slo_tpot is None:
                return resume * self.NO_SLO_URGENCY
            remaining = max(r.max_new_tokens - len(r.out_tokens), 1)
            gaps = max(len(r.out_tokens) - 1, 0) + remaining
            # ticks of stall absorbable before the finished request's
            # mean inter-token gap exceeds its TPOT deadline
            slack = (r.slo_tpot * gaps
                     - (now - r.first_token_at) - remaining)
        if slack <= 0:
            return resume * self.LOST_URGENCY
        return resume * (1.0 + resume / slack)

    def choose_victim(self, running, requester, now, sched=None):
        cands = [r for r in running if r is not requester]
        if not cands:
            return None
        # min harm; ties broken youngest-first (FIFO-like churn order)
        victim = min(cands,
                     key=lambda r: (self._harm(r, now, sched),
                                    -r.admission_seq))
        if self._harm(victim, now, sched) < self._harm(requester, now,
                                                       sched):
            return victim
        return None


POLICIES = {
    "fifo": FifoPolicy,
    "priority": PriorityPolicy,
    "slo_fair": SloFairPolicy,
}


def make_policy(policy) -> SchedPolicy:
    """Resolve a policy spec: an instance passes through, a name
    constructs from :data:`POLICIES`."""
    if isinstance(policy, SchedPolicy):
        return policy
    try:
        return POLICIES[policy]()
    except KeyError:
        raise ValueError(
            f"unknown scheduling policy {policy!r}: "
            f"expected one of {sorted(POLICIES)} or a SchedPolicy "
            "instance") from None
