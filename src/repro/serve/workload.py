"""Trace-driven workload generation: bursty arrivals, heavy tails,
tenant mixes, multi-turn conversations.

Every benchmark before this layer drove the serve stack with single-shot
uniform Poisson traffic — which under-stresses exactly the machinery the
NVR story cares about: the prefix cache (no cross-turn reuse), the spill
tier (no idle sessions to park), and the runahead predictors (uniform
arrival spacing means no bursty locality).  This module produces the
realistic shape:

* **Bursty/diurnal arrivals** — a Markov-modulated Poisson process:
  the base rate follows a slow sinusoid (the diurnal swell) and
  alternates calm/burst phases where the burst multiplies the rate.
* **Heavy-tailed lengths** — prompt lengths are clipped lognormal,
  output lengths clipped Zipf; most requests are short, a few dominate.
* **Tenant mixes** — each request belongs to a tenant drawn from a
  weighted mix; a tenant carries a priority class, TTFT/TPOT SLOs, its
  own length scales, and a shared system prompt (so same-tenant
  requests hit the COW prefix cache the way production traffic does).
* **Multi-turn conversations** — a request may carry follow-up turns;
  each turn re-enters the front door after a think time with a prompt
  equal to the full conversation history plus fresh user tokens,
  exercising cross-turn COW prefix reuse and idle-session swap-out
  between turns.

Two representations:

* :class:`RequestSpec` — lengths only, JSON-serialisable: what a trace
  file (``traces/*.json``) stores and :func:`save_trace` /
  :func:`load_trace` round-trip.
* :class:`WorkItem` — concrete token arrays, produced by
  :func:`materialize` under an explicit seed; what
  ``PagedEngine.run`` consumes.  Same spec + same seed + same vocab =>
  identical arrays, so every bench built on this module is reproducible
  run-to-run (asserted in tests).
"""

from __future__ import annotations

import json
import math
from dataclasses import dataclass, field

import numpy as np


@dataclass
class TurnSpec:
    """One follow-up conversation turn, lengths only."""

    think_time: float        # ticks after the previous turn finishes
    new_tokens: int          # fresh user tokens appended to the history
    max_new_tokens: int      # generation budget for this turn


@dataclass
class RequestSpec:
    """One front-door arrival, lengths only (JSON-serialisable)."""

    arrival: float
    prompt_len: int
    max_new_tokens: int
    tenant: str = "default"
    priority: int = 0
    slo_ttft: float | None = None
    slo_tpot: float | None = None
    turns: list = field(default_factory=list)   # [TurnSpec]

    def total_len(self) -> int:
        """KV positions the *last* turn's sequence occupies — the
        engine ``max_len`` this conversation needs."""
        n = self.prompt_len + self.max_new_tokens
        for t in self.turns:
            n += t.new_tokens + t.max_new_tokens
        return n


@dataclass
class Turn:
    """A materialised follow-up turn: concrete user tokens."""

    think_time: float
    user_tokens: np.ndarray
    max_new_tokens: int


@dataclass
class WorkItem:
    """A materialised arrival: what ``PagedEngine.run`` consumes."""

    arrival: float
    prompt: np.ndarray
    max_new_tokens: int
    tenant: str = "default"
    priority: int = 0
    slo_ttft: float | None = None
    slo_tpot: float | None = None
    turns: list = field(default_factory=list)   # [Turn]


@dataclass
class TenantSpec:
    """One tenant's traffic profile in the mix."""

    name: str
    weight: float = 1.0          # share of arrivals
    priority: int = 0            # class, lower = more important
    slo_ttft: float | None = None
    slo_tpot: float | None = None
    # lognormal prompt-length parameters (of the underlying normal)
    prompt_mu: float = 2.5
    prompt_sigma: float = 0.6
    prompt_cap: int = 48
    # Zipf output-length parameters
    gen_zipf_a: float = 2.0
    gen_cap: int = 16
    multi_turn_p: float = 0.0    # chance each turn spawns a follow-up
    max_turns: int = 3
    think_mean: float = 6.0      # mean think time between turns, ticks
    shared_prefix: int = 0       # tenant system-prompt tokens (COW bait)


def synthesize(n_requests: int, seed: int,
               tenants: list[TenantSpec],
               base_rate: float = 0.5,
               burst_factor: float = 6.0,
               burst_len: float = 12.0,
               calm_len: float = 36.0,
               diurnal_amp: float = 0.5,
               diurnal_period: float = 200.0) -> list:
    """Generate ``n_requests`` :class:`RequestSpec` rows, sorted by
    arrival.  Deterministic under ``seed``.

    Arrivals are a Markov-modulated Poisson process: exponential gaps at
    instantaneous rate ``base_rate * diurnal(t) * (burst_factor if the
    process is inside a burst phase else 1)``, with exponential
    calm/burst phase lengths — so load comes in waves, and during a
    wave one tenant's burst can head-of-line block the others under
    FIFO (the contention the policy layer exists to fix).
    """
    if n_requests <= 0:
        raise ValueError(f"n_requests must be >= 1, got {n_requests}")
    if not tenants:
        raise ValueError("need at least one TenantSpec")
    rng = np.random.default_rng(seed)
    weights = np.array([max(t.weight, 0.0) for t in tenants], dtype=float)
    if weights.sum() <= 0:
        raise ValueError("tenant weights must sum to > 0")
    weights /= weights.sum()

    specs: list[RequestSpec] = []
    t = 0.0
    in_burst = False
    phase_end = float(rng.exponential(calm_len))
    for _ in range(n_requests):
        # phase machine first, then a gap at the phase's rate
        while t >= phase_end:
            in_burst = not in_burst
            phase_end = t + float(rng.exponential(
                burst_len if in_burst else calm_len))
        diurnal = 1.0 + diurnal_amp * math.sin(
            2.0 * math.pi * t / diurnal_period)
        rate = base_rate * max(diurnal, 0.05) \
            * (burst_factor if in_burst else 1.0)
        t += float(rng.exponential(1.0 / rate))

        ten = tenants[int(rng.choice(len(tenants), p=weights))]
        p = int(np.clip(round(rng.lognormal(ten.prompt_mu,
                                            ten.prompt_sigma)),
                        1, ten.prompt_cap))
        g = int(np.clip(rng.zipf(ten.gen_zipf_a), 1, ten.gen_cap))
        turns: list[TurnSpec] = []
        while (len(turns) + 1 < ten.max_turns
               and rng.random() < ten.multi_turn_p):
            turns.append(TurnSpec(
                think_time=float(
                    np.clip(rng.exponential(ten.think_mean), 1.0, None)),
                new_tokens=int(np.clip(
                    round(rng.lognormal(ten.prompt_mu - 0.7,
                                        ten.prompt_sigma)),
                    1, ten.prompt_cap)),
                max_new_tokens=int(np.clip(rng.zipf(ten.gen_zipf_a),
                                           1, ten.gen_cap))))
        specs.append(RequestSpec(
            arrival=round(t, 3), prompt_len=p, max_new_tokens=g,
            tenant=ten.name, priority=ten.priority,
            slo_ttft=ten.slo_ttft, slo_tpot=ten.slo_tpot, turns=turns))
    specs.sort(key=lambda s: s.arrival)
    return specs


def materialize(specs, vocab: int, seed: int,
                shared_prefix: dict | None = None) -> list:
    """Turn :class:`RequestSpec` rows into :class:`WorkItem` rows with
    concrete token arrays.  Deterministic under ``seed``.

    ``shared_prefix`` maps tenant name -> system-prompt length; each
    tenant gets one fixed token array reused as the head of every one of
    its prompts (drawn once per tenant, so same-tenant requests share a
    COW-cacheable prefix — the "realistic locality" the runahead and
    prefix-cache numbers should be measured under).
    """
    rng = np.random.default_rng(seed)
    shared_prefix = shared_prefix or {}
    sys_prompts: dict[str, np.ndarray] = {}
    for name in sorted(shared_prefix):
        n = int(shared_prefix[name])
        sys_prompts[name] = rng.integers(0, vocab, size=n) if n > 0 \
            else np.zeros((0,), dtype=np.int64)
    items: list[WorkItem] = []
    for s in specs:
        head = sys_prompts.get(s.tenant)
        body_len = s.prompt_len if head is None \
            else max(s.prompt_len - len(head), 1)
        body = rng.integers(0, vocab, size=body_len)
        prompt = body if head is None else np.concatenate([head, body])
        turns = [Turn(think_time=t.think_time,
                      user_tokens=rng.integers(0, vocab,
                                               size=t.new_tokens),
                      max_new_tokens=t.max_new_tokens)
                 for t in s.turns]
        items.append(WorkItem(
            arrival=s.arrival, prompt=prompt,
            max_new_tokens=s.max_new_tokens, tenant=s.tenant,
            priority=s.priority, slo_ttft=s.slo_ttft,
            slo_tpot=s.slo_tpot, turns=turns))
    return items


# -- trace files -------------------------------------------------------------

TRACE_VERSION = 1


def save_trace(path: str, specs, meta: dict | None = None) -> None:
    """Write specs to a JSON trace file (stable key order)."""
    doc = {
        "version": TRACE_VERSION,
        "meta": meta or {},
        "requests": [
            {"arrival": s.arrival, "prompt_len": s.prompt_len,
             "max_new": s.max_new_tokens, "tenant": s.tenant,
             "priority": s.priority, "slo_ttft": s.slo_ttft,
             "slo_tpot": s.slo_tpot,
             "turns": [{"think": t.think_time, "new_tokens": t.new_tokens,
                        "max_new": t.max_new_tokens} for t in s.turns]}
            for s in specs
        ],
    }
    with open(path, "w") as f:
        json.dump(doc, f, indent=1, sort_keys=True, allow_nan=False)
        f.write("\n")


def load_trace(path: str) -> list:
    """Read a JSON trace file back into validated RequestSpec rows.

    The same schedule validation TraceArrivals performs (non-empty,
    finite, non-decreasing, positive lengths) applies here — a corrupt
    trace fails at load with the offending entry named."""
    with open(path) as f:
        doc = json.load(f)
    if doc.get("version") != TRACE_VERSION:
        raise ValueError(f"{path}: unsupported trace version "
                         f"{doc.get('version')!r} (expected "
                         f"{TRACE_VERSION})")
    rows = doc.get("requests", [])
    if not rows:
        raise ValueError(f"{path}: empty trace — no requests")
    specs = []
    prev = None
    for i, r in enumerate(rows):
        t = float(r["arrival"])
        if not math.isfinite(t):
            raise ValueError(f"{path}: non-finite arrival at entry {i}")
        if prev is not None and t < prev:
            raise ValueError(f"{path}: arrivals must be non-decreasing "
                             f"(entry {i}: {t} < {prev})")
        prev = t
        p, g = int(r["prompt_len"]), int(r["max_new"])
        if p <= 0 or g <= 0:
            raise ValueError(f"{path}: entry {i} has prompt_len={p}, "
                             f"max_new={g}; both must be >= 1")
        specs.append(RequestSpec(
            arrival=t, prompt_len=p, max_new_tokens=g,
            tenant=str(r.get("tenant", "default")),
            priority=int(r.get("priority", 0)),
            slo_ttft=r.get("slo_ttft"), slo_tpot=r.get("slo_tpot"),
            turns=[TurnSpec(think_time=float(u["think"]),
                            new_tokens=int(u["new_tokens"]),
                            max_new_tokens=int(u["max_new"]))
                   for u in r.get("turns", [])]))
    return specs


# -- the canonical bursty multi-tenant multi-turn preset ---------------------

def bursty_multiturn_tenants() -> list:
    """The tenant mix behind ``traces/bursty_multiturn.json`` and
    ``workload_bench``: an interactive chat tenant with tight SLOs and
    multi-turn sessions, a second interactive tenant, and a bursty
    batch tenant with long prompts and no deadlines whose waves
    head-of-line block everyone under FIFO."""
    return [
        TenantSpec(name="chat", weight=3.0, priority=0,
                   slo_ttft=10.0, slo_tpot=4.0,
                   prompt_mu=2.2, prompt_sigma=0.5, prompt_cap=24,
                   gen_zipf_a=2.2, gen_cap=8,
                   multi_turn_p=0.6, max_turns=3, think_mean=5.0,
                   shared_prefix=8),
        TenantSpec(name="assist", weight=2.0, priority=1,
                   slo_ttft=18.0, slo_tpot=6.0,
                   prompt_mu=2.6, prompt_sigma=0.6, prompt_cap=32,
                   gen_zipf_a=2.0, gen_cap=10,
                   multi_turn_p=0.3, max_turns=2, think_mean=8.0,
                   shared_prefix=8),
        TenantSpec(name="batch", weight=3.0, priority=2,
                   slo_ttft=None, slo_tpot=None,
                   prompt_mu=3.5, prompt_sigma=0.4, prompt_cap=40,
                   gen_zipf_a=1.8, gen_cap=16,
                   multi_turn_p=0.0, max_turns=1,
                   shared_prefix=0),
    ]


def bursty_multiturn(n_requests: int, seed: int = 7) -> list:
    """RequestSpec rows for the canonical bursty multi-tenant
    multi-turn trace (deterministic under ``seed``)."""
    return synthesize(n_requests, seed,
                      tenants=bursty_multiturn_tenants(),
                      base_rate=0.5, burst_factor=12.0,
                      burst_len=14.0, calm_len=22.0,
                      diurnal_amp=0.6, diurnal_period=120.0)


def shared_prefix_map(tenants) -> dict:
    """tenant name -> shared system-prompt length, for materialize()."""
    return {t.name: t.shared_prefix for t in tenants if t.shared_prefix}
