"""Production mesh definitions.

A pod is a 16x16 (256-chip) slice with axes ("data", "model"); the
multi-pod configuration adds a leading "pod" axis (2 x 16 x 16 = 512
chips).  Exposed as a FUNCTION so importing this module never touches jax
device state.
"""

from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_serve_mesh(tp: int = 1):
    """1-axis ``("model",)`` mesh for tensor-parallel paged serving: the
    KV pools and QKV weights shard ``tp`` ways along the KV-head axis
    (``sharding.serve_pool_specs`` / ``serve_param_specs``).

    On CPU, force host devices first:
    ``XLA_FLAGS=--xla_force_host_platform_device_count=N``.
    """
    n = jax.device_count()
    if tp > n:
        raise ValueError(
            f"serve mesh wants tp={tp} but only {n} device(s) exist; on "
            "CPU set XLA_FLAGS=--xla_force_host_platform_device_count="
            f"{tp} before jax initialises")
    return jax.make_mesh((tp,), ("model",))


def make_host_mesh(data: int = 1, model: int = 1, pod: int = 0):
    """Small mesh over however many (host) devices exist — used by tests
    and CPU examples."""
    shape = (pod, data, model) if pod else (data, model)
    axes = ("pod", "data", "model") if pod else ("data", "model")
    return jax.make_mesh(shape, axes)


# hardware constants (TPU v5e-class, per the assignment)
PEAK_FLOPS_BF16 = 197e12      # FLOP/s per chip
HBM_BW = 819e9                # bytes/s per chip
ICI_BW = 50e9                 # bytes/s per link (one direction)
VMEM_BYTES = 128 * 1024 * 1024
HBM_BYTES = 16 * 1024 ** 3
