"""Training launcher.

On this CPU container it trains *reduced* configs end-to-end (the examples
use it); on a TPU fleet the same entry point runs the full configs over
``make_production_mesh()``.

  PYTHONPATH=src python -m repro.launch.train --arch tinyllama-1.1b \
      --reduced --steps 50 --batch 8 --seq 128 --ckpt-dir /tmp/ckpt
"""

from __future__ import annotations

import argparse

import jax

from ..configs import get_config
from ..data import pipeline
from ..optim import AdamWConfig
from ..train import trainer
from . import mesh as meshlib


def main(argv=None):
    p = argparse.ArgumentParser()
    p.add_argument("--arch", required=True)
    p.add_argument("--reduced", action="store_true",
                   help="tiny same-family config (CPU)")
    p.add_argument("--steps", type=int, default=50)
    p.add_argument("--batch", type=int, default=8)
    p.add_argument("--seq", type=int, default=128)
    p.add_argument("--lr", type=float, default=1e-3)
    p.add_argument("--microbatch", type=int, default=0)
    p.add_argument("--ckpt-dir", default="")
    p.add_argument("--ckpt-every", type=int, default=25)
    p.add_argument("--data-mesh", type=int, default=0,
                   help=">0: build a (data, model) host mesh for pjit")
    p.add_argument("--model-mesh", type=int, default=1)
    args = p.parse_args(argv)

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    tcfg = trainer.TrainConfig(
        steps=args.steps, lr=args.lr, ckpt_dir=args.ckpt_dir,
        ckpt_every=args.ckpt_every, microbatch=args.microbatch,
        remat="none" if args.reduced else "full",
        opt=AdamWConfig())
    dcfg = pipeline.DataConfig(vocab=cfg.vocab, seq_len=args.seq,
                               global_batch=args.batch)

    def data_iter():
        for step, (toks, labels) in pipeline.batches(dcfg):
            batch = {"tokens": toks, "labels": labels}
            if cfg.family == "vlm":
                import jax.numpy as jnp
                npatch = min(cfg.n_patches, args.seq // 2)
                batch["patches"] = jnp.zeros(
                    (args.batch, npatch, cfg.d_model), jnp.float32)
            if cfg.family == "encdec":
                import jax.numpy as jnp
                batch["src_embeds"] = jnp.zeros(
                    (args.batch, cfg.src_len, cfg.d_model), jnp.float32)
            yield step, batch

    mesh = None
    ctx = None
    if args.data_mesh:
        mesh = meshlib.make_host_mesh(args.data_mesh, args.model_mesh)
        ctx = jax.set_mesh(mesh)
        ctx.__enter__()
    try:
        state, history = trainer.run(cfg, tcfg, data_iter(), mesh=mesh)
    finally:
        if ctx is not None:
            ctx.__exit__(None, None, None)
    first, last = history[0]["loss"], history[-1]["loss"]
    print(f"[train] loss {first:.4f} -> {last:.4f} over "
          f"{len(history)} steps")
    return history


if __name__ == "__main__":
    main()
