"""Serving launcher: batched decode with NVR sparse-KV attention.

  PYTHONPATH=src python -m repro.launch.serve --arch qwen2-1.5b --reduced \
      --batch 4 --prompt-len 64 --gen 32
"""

from __future__ import annotations

import argparse

import jax

from ..configs import get_config
from ..models import api
from ..serve.engine import Engine


def main(argv=None):
    p = argparse.ArgumentParser()
    p.add_argument("--arch", required=True)
    p.add_argument("--reduced", action="store_true")
    p.add_argument("--batch", type=int, default=4)
    p.add_argument("--prompt-len", type=int, default=64)
    p.add_argument("--gen", type=int, default=32)
    p.add_argument("--dense", action="store_true",
                   help="disable the NVR sparse-KV path")
    args = p.parse_args(argv)

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    key = jax.random.PRNGKey(0)
    params = api.init_params(cfg, key)
    from ..configs.base import ShapeCell
    cell = ShapeCell("serve", args.prompt_len, args.batch, "prefill")
    batch = api.make_inputs(cfg, cell, key)
    eng = Engine(cfg, params, max_len=args.prompt_len + args.gen,
                 sparse=not args.dense)
    out = eng.generate(batch, args.gen)
    s = eng.stats
    print(f"[serve] generated {out.shape} tokens; sparse={eng.sparse}")
    if eng.sparse:
        print(f"[serve] NSB hot-set hit rate {s.hot_hit_rate:.3f} "
              f"(pages touched {s.pages_touched}, unique-miss "
              f"{s.nsb_misses}) -> off-chip fetch reduction "
              f"{100 * s.offchip_reduction:.1f}%")
    return out


if __name__ == "__main__":
    main()
