"""Serving launcher: batched decode with NVR sparse-KV attention.

Single-batch (lockstep) mode:

  PYTHONPATH=src python -m repro.launch.serve --arch qwen2-1.5b --reduced \
      --batch 4 --prompt-len 64 --gen 32

Continuous-batching mode — Poisson arrivals through the paged engine
(admission queue, chunked prefill, preempt-and-evict KV allocator):

  PYTHONPATH=src python -m repro.launch.serve --arch qwen2-1.5b --reduced \
      --continuous --requests 16 --rate 0.5 --max-batch 8 --pages 49

Multi-tenant prefix reuse — requests share one of N system prompts and
the COW prefix cache skips their recomputation (--no-prefix-cache to
compare against the uncached run):

  PYTHONPATH=src python -m repro.launch.serve --arch qwen2-1.5b --reduced \
      --continuous --requests 16 --shared-prefix 4 --capture

Tensor-parallel serving — the physical KV pools and QKV weights shard
across a ("model",) mesh along the KV-head axis (1/tp pool bytes per
shard, per-shard NSBs, logits bitwise-identical to --tp 1).  On CPU,
force host devices first:

  XLA_FLAGS=--xla_force_host_platform_device_count=2 \
  PYTHONPATH=src python -m repro.launch.serve --arch qwen2-1.5b --reduced \
      --continuous --tp 2 --requests 16

Online runahead — between decode steps the engine predicts each live
request's next-iteration TopK pages and stages them into a physical NSB
tail on the KV pools (tokens stay bitwise-identical; see
ARCHITECTURE.md "online runahead"):

  PYTHONPATH=src python -m repro.launch.serve --arch qwen2-1.5b --reduced \
      --continuous --requests 16 --shared-prefix 4 --runahead nvr

Host KV spill tier — preemption under pool pressure swaps pages to a
host pool and resume swaps them back (no re-prefill, tokens unchanged;
--spill-compress stores the spilled K/V planes int8 with per-page
scales).  Pair with a small --pages to oversubscribe (every request
must still fit the pool alone: pages > (prompt_len + gen) / kv_page):

  PYTHONPATH=src python -m repro.launch.serve --arch qwen2-1.5b --reduced \
      --continuous --requests 16 --prompt-len 24 --gen 8 --pages 12 \
      --spill 64 --runahead nvr

Paged expert-weight streaming (MoE archs) — expert FFN weights become
fixed row-tile pages resolved through block tables, optionally with
router-keyed runahead staging predicted tiles into the expert pool's
NSB tail (tokens bitwise-identical to --expert-pool dense; see
ARCHITECTURE.md "paged expert-weight streaming"):

  PYTHONPATH=src python -m repro.launch.serve --arch qwen3-moe-235b-a22b \
      --reduced --continuous --requests 16 --expert-pool paged \
      --expert-runahead router

Scheduling policies + trace-driven workloads — the front door delegates
admission order and eviction victims to a pluggable policy
(``--policy fifo|priority|slo_fair``; fifo is the bitwise-parity
default), and ``--workload`` replaces the synthetic Poisson stream with
a trace file (``serve/workload.py`` schema: bursty multi-tenant
arrivals, priority classes, TTFT/TPOT SLOs, multi-turn conversations).
Multi-turn sessions hold their KV between turns (``--session-hold``)
and can park it in the host spill tier during think time
(``--idle-swap``, needs ``--spill``):

  PYTHONPATH=src python -m repro.launch.serve --arch qwen2-1.5b --reduced \
      --continuous --policy slo_fair \
      --workload traces/bursty_multiturn.json \
      --pages 28 --spill 64 --session-hold --idle-swap --runahead nvr
"""

from __future__ import annotations

import argparse

import jax
import numpy as np

from ..configs import get_config
from ..models import api
from ..serve.engine import Engine, PagedEngine
from ..serve.scheduler import PoissonArrivals


def _run_single_batch(cfg, params, args):
    from ..configs.base import ShapeCell
    cell = ShapeCell("serve", args.prompt_len, args.batch, "prefill")
    batch = api.make_inputs(cfg, cell, jax.random.PRNGKey(0))
    eng = Engine(cfg, params, max_len=args.prompt_len + args.gen,
                 sparse=not args.dense)
    out = eng.generate(batch, args.gen)
    s = eng.stats
    print(f"[serve] generated {out.shape} tokens; sparse={eng.sparse}")
    if eng.sparse and s.hot_hit_rate is not None:
        print(f"[serve] NSB hot-set hit rate {s.hot_hit_rate:.3f} "
              f"(pages touched {s.pages_touched}, unique-miss "
              f"{s.nsb_misses}) -> off-chip fetch reduction "
              f"{100 * s.offchip_reduction:.1f}%")
    return out


def _fmt(x, spec: str = ".3f") -> str:
    """Format a metric that is None before any traffic (zero-traffic
    smoke runs) without crashing the report."""
    return "n/a" if x is None else format(x, spec)


def _run_continuous(cfg, params, args):
    rng = np.random.default_rng(args.seed)
    if args.workload:
        from ..serve.workload import load_trace, materialize
        specs = load_trace(args.workload)
        workload = materialize(specs, cfg.vocab, seed=args.seed)
        n_requests = sum(1 + len(w.turns) for w in workload)
        # a turn-N prompt is the whole conversation so far: size max_len
        # for the longest possible final turn
        longest = max(len(w.prompt) + w.max_new_tokens
                      + sum(len(t.user_tokens) + t.max_new_tokens
                            for t in w.turns)
                      for w in workload)
    elif args.shared_prefix:
        # multi-tenant shape: every request opens with one of a handful
        # of system prompts, so whole prompt pages repeat across requests
        sys_len = max(cfg.kv_page,
                      args.prompt_len // 2 // cfg.kv_page * cfg.kv_page)
        sys_prompts = [rng.integers(1, cfg.vocab, size=sys_len)
                       for _ in range(args.shared_prefix)]
        arrivals = PoissonArrivals(
            args.requests, rate=args.rate,
            prompt_len=(1, max(1, args.prompt_len - sys_len)),
            gen_len=(max(1, args.gen // 2), args.gen), seed=args.seed)
        workload = [(t, np.concatenate(
            [sys_prompts[i % args.shared_prefix],
             rng.integers(1, cfg.vocab, size=p)]), g)
            for i, (t, p, g) in enumerate(arrivals)]
    else:
        arrivals = PoissonArrivals(
            args.requests, rate=args.rate,
            prompt_len=(max(1, args.prompt_len // 2), args.prompt_len),
            gen_len=(max(1, args.gen // 2), args.gen), seed=args.seed)
        workload = [(t, rng.integers(1, cfg.vocab, size=p), g)
                    for t, p, g in arrivals]
    if not args.workload:
        n_requests = len(workload)
        # sized from the built workload: a shared-prefix prompt (system
        # prompt + suffix) may exceed --prompt-len
        longest = max(len(p) + g for _, p, g in workload)
    max_len = -(-longest // cfg.kv_page) * cfg.kv_page
    mesh = None
    if args.tp > 1:
        from .mesh import make_serve_mesh
        mesh = make_serve_mesh(args.tp)
    eng = PagedEngine(cfg, params, max_len=max_len, n_pages=args.pages,
                      max_batch=args.max_batch, chunk=args.chunk,
                      nsb_pages=args.nsb_pages, capture_trace=args.capture,
                      prefix_cache=not args.no_prefix_cache,
                      kernel=args.kernel,
                      donate_pools=not args.no_donate,
                      row_bucketing=not args.no_buckets,
                      mesh=mesh,
                      runahead=args.runahead,
                      runahead_pages=args.runahead_pages,
                      spill_pages=args.spill,
                      spill_compress=args.spill_compress,
                      executor=args.executor,
                      expert_pool=args.expert_pool,
                      expert_tile_rows=args.expert_tile_rows,
                      expert_nsb_slots=args.expert_nsb_slots,
                      expert_runahead=args.expert_runahead,
                      expert_runahead_pages=args.expert_runahead_pages,
                      policy=args.policy,
                      session_hold=args.session_hold,
                      idle_swap=args.idle_swap)
    eng.run(workload)
    m = eng.metrics()
    print(f"[serve-cb] {m['n_finished']}/{n_requests} requests in "
          f"{m['iterations']} iterations ({m['tokens_out']} tokens, "
          f"{m['preemptions']} preemptions, peak "
          f"{m['pages_peak_in_use']}/{eng.allocator.capacity} pages)")
    if eng.tp > 1:
        rates = ", ".join(_fmt(r) for r in m["nsb_shard_hit_rates"])
        print(f"[serve-cb] tp={eng.tp}: "
              f"{m['kv_pool_mib_per_shard']:.2f} MiB KV pool per shard, "
              f"per-shard NSB hit rates [{rates}] "
              f"(roll-up {_fmt(m['nsb_shard_rollup_hit_rate'])})")
    print(f"[serve-cb] step loop: {m['n_decode_traces']} decode traces "
          f"({eng.kernel} kernel), {m['decode_rows_padded']} padded "
          f"decode rows")
    print(f"[serve-cb] latency p50/p99 {_fmt(m['p50_latency'], '.0f')}/"
          f"{_fmt(m['p99_latency'], '.0f')} iters; TTFT p50/p99 "
          f"{_fmt(m['p50_ttft'], '.0f')}/{_fmt(m['p99_ttft'], '.0f')}; "
          f"TPOT p50/p99 {_fmt(m['p50_tpot'], '.2f')}/"
          f"{_fmt(m['p99_tpot'], '.2f')}")
    if args.executor == "async":
        print(f"[serve-cb] executor=async: overlap fraction "
              f"{_fmt(m['overlap_fraction'])} "
              f"({m['prefill_iterations']} prefill / "
              f"{m['decode_iterations']} decode / "
              f"{m['overlap_iterations']} overlapped iterations), "
              f"plan reuse {_fmt(m['plan_reuse_fraction'])} "
              f"({m['plan_repairs']} repairs), p99 TPOT "
              f"{_fmt(m['p99_tpot'], '.2f')} iters/token")
    print(f"[serve-cb] NSB hot-set hit rate "
          f"{_fmt(m['nsb_hot_hit_rate'])}")
    if args.runahead != "off":
        print(f"[serve-cb] runahead={m['runahead_mode']}: "
              f"{m['runahead_staged_pages']} pages staged "
              f"({m['runahead_stage_calls']} gathers, "
              f"{m['runahead_invalidations']} invalidations), "
              f"accuracy {_fmt(m['runahead_accuracy'])}, coverage "
              f"{_fmt(m['runahead_coverage'])}, over-fetch "
              f"{_fmt(m['runahead_overfetch'])}; demand-LRU baseline "
              f"hit rate {_fmt(m['nsb_demand_lru_hit_rate'])}")
    if args.spill > 0:
        print(f"[serve-cb] spill: {m['swap_outs']} swap-outs / "
              f"{m['swap_ins']} swap-ins ({m['swap_out_pages']} pages "
              f"out, {m['swap_in_pages']} in, {m['fetch_backs']} "
              f"runahead fetch-backs, {m['spill_fallbacks']} recompute "
              f"fallbacks); host pool {m['spill_host_mib']:.2f} MiB"
              + (f", int8 err bound "
                 f"{m['spill_dequant_error_bound']:.2e}"
                 if m["spill_compressed"] else "")
              + f"; resume-TTFT p50 {_fmt(m['p50_resume_ttft'], '.0f')}")
    if args.expert_pool != "off":
        print(f"[serve-cb] expert pool={m['expert_pool']}: "
              f"{m['expert_pool_pages']} tile pages "
              f"({m['expert_pool_mib']:.2f} MiB, "
              f"{m['expert_tile_rows']}-row tiles), "
              f"{m['expert_pages_touched']} demand touches, hit rate "
              f"{_fmt(m['expert_nsb_hit_rate'])} (demand-LRU baseline "
              f"{_fmt(m['expert_demand_lru_hit_rate'])})")
    if args.expert_runahead != "off":
        print(f"[serve-cb] expert runahead={m['expert_runahead_mode']}: "
              f"{m['expert_staged_pages']} tiles staged "
              f"({m['expert_stage_calls']} gathers, "
              f"{m['expert_nsb_slots']} NSB slots), accuracy "
              f"{_fmt(m['expert_runahead_accuracy'])}, coverage "
              f"{_fmt(m['expert_runahead_coverage'])}, over-fetch "
              f"{_fmt(m['expert_runahead_overfetch'])}")
    if args.policy != "fifo" or m["slo_attainment"] is not None:
        print(f"[serve-cb] policy={m['policy']}: SLO attainment "
              f"{_fmt(m['slo_attainment'])}")
        for kind in ("per_tenant", "per_class"):
            for key, g in m.get(kind, {}).items():
                print(f"[serve-cb]   {kind[4:]} {key}: "
                      f"{g['n_finished']} finished, TTFT p50/p99 "
                      f"{_fmt(g['p50_ttft'], '.0f')}/"
                      f"{_fmt(g['p99_ttft'], '.0f')}, SLO "
                      f"{_fmt(g['slo_attainment'])}")
    if m.get("turns_submitted"):
        print(f"[serve-cb] sessions: {m['turns_submitted']} follow-up "
              f"turns, {m['session_holds']} KV holds, "
              f"{m['idle_swap_outs']} idle swap-outs / "
              f"{m['idle_swap_ins']} swap-ins, "
              f"{m['idle_evictions']} idle evictions")
    if not args.no_prefix_cache:
        print(f"[serve-cb] prefix cache: {m['prefix_hit_pages']} page "
              f"hits, {m['prefill_tokens_skipped']} prompt tokens "
              f"skipped ({m['prefill_tokens_run']} run), "
              f"{m['cow_copies']} COW copies, "
              f"{m['prefix_evictions']} evictions")
    if args.capture:
        from ..core.nvr import demand_miss_reduction_from, run_modes
        rs = {r.label: r for r in run_modes(eng.captured_trace(), 2)}
        ino, nvr = rs["inorder"], rs["nvr"]
        red = demand_miss_reduction_from(rs)
        print(f"[serve-cb] captured-trace NVR: demand-miss reduction "
              f"{100 * red:.1f}% ({ino.demand_misses} -> "
              f"{nvr.demand_misses}), speedup "
              f"{ino.total / nvr.total:.2f}x vs in-order")
    return eng


def main(argv=None):
    p = argparse.ArgumentParser()
    p.add_argument("--arch", required=True)
    p.add_argument("--reduced", action="store_true")
    p.add_argument("--batch", type=int, default=4)
    p.add_argument("--prompt-len", type=int, default=64)
    p.add_argument("--gen", type=int, default=32)
    p.add_argument("--dense", action="store_true",
                   help="disable the NVR sparse-KV path")
    p.add_argument("--continuous", action="store_true",
                   help="continuous batching on the paged KV allocator")
    p.add_argument("--requests", type=int, default=16)
    p.add_argument("--rate", type=float, default=0.5,
                   help="Poisson arrivals per scheduler iteration")
    p.add_argument("--pages", type=int, default=0,
                   help="physical KV pages (0 = worst-case sized)")
    p.add_argument("--max-batch", type=int, default=8)
    p.add_argument("--chunk", type=int, default=16,
                   help="prefill chunk tokens per iteration")
    p.add_argument("--nsb-pages", type=int, default=64)
    p.add_argument("--shared-prefix", type=int, default=0, metavar="N",
                   help="draw prompts over N shared system prompts "
                        "(multi-tenant prefix-reuse workload; 0 = "
                        "independent random prompts)")
    p.add_argument("--no-prefix-cache", action="store_true",
                   help="disable cross-request COW prefix caching")
    p.add_argument("--kernel", choices=("xla", "pallas"), default="xla",
                   help="paged decode attention impl (pallas = fused "
                        "runahead kernel; interpret mode off-TPU)")
    p.add_argument("--no-donate", action="store_true",
                   help="disable pool-buffer donation (pre-PR copies)")
    p.add_argument("--no-buckets", action="store_true",
                   help="pad every decode batch to --max-batch")
    p.add_argument("--tp", type=int, default=1,
                   help="tensor-parallel shards: KV pools + QKV weights "
                        "shard along the KV-head axis over a (model,) "
                        "mesh (continuous mode; head counts must divide; "
                        "on CPU force devices with XLA_FLAGS=--xla_force"
                        "_host_platform_device_count=N)")
    p.add_argument("--runahead", choices=("off", "imp", "nvr"),
                   default="off",
                   help="online runahead: predict next-iteration TopK "
                        "pages between decode steps and stage them into "
                        "a physical NSB tail (nvr = history + proxy "
                        "scoring; imp = one-step-behind baseline; "
                        "tokens bitwise-identical either way)")
    p.add_argument("--runahead-pages", type=int, default=8,
                   help="staging copies per iteration (runahead budget)")
    p.add_argument("--spill", type=int, default=0, metavar="SLOTS",
                   help="host spill-tier slots (pages): preemption "
                        "swaps KV to a host pool and resume swaps it "
                        "back instead of re-prefilling; 0 = recompute "
                        "policy (the historic behaviour)")
    p.add_argument("--spill-compress", action="store_true",
                   help="int8-compress spilled K/V planes (per-page "
                        "scales via optim.compress; page summaries stay "
                        "exact, so TopK selection survives bitwise)")
    p.add_argument("--expert-pool", choices=("off", "dense", "paged"),
                   default="off",
                   help="MoE expert-weight serving: dense = per-layer "
                        "materialised expert rows; paged = expert FFN "
                        "weights as fixed row-tile pages resolved "
                        "through block tables (MoE archs only; tokens "
                        "bitwise-identical across modes)")
    p.add_argument("--expert-tile-rows", type=int, default=32,
                   help="rows of d_ff per expert weight tile page")
    p.add_argument("--expert-nsb-slots", type=int, default=32,
                   help="expert-pool NSB staging-tail slots (tiles)")
    p.add_argument("--expert-runahead", choices=("off", "router"),
                   default="off",
                   help="router-keyed expert runahead: score the next "
                        "decode batch's tokens against the layer-0 "
                        "router between steps and stage the predicted "
                        "expert tiles into the pool's NSB tail (needs "
                        "--expert-pool paged)")
    p.add_argument("--expert-runahead-pages", type=int, default=16,
                   help="expert tile staging copies per iteration")
    p.add_argument("--executor", choices=("sync", "async"),
                   default="sync",
                   help="step-loop executor: sync = monolithic oracle "
                        "loop; async = pipelined prefill/decode streams "
                        "with double-buffered plans and overlapped "
                        "runahead staging (tokens + logits bitwise-"
                        "identical to sync)")
    p.add_argument("--policy", choices=("fifo", "priority", "slo_fair"),
                   default="fifo",
                   help="scheduling policy: fifo = strict arrival order "
                        "(bitwise-parity default); priority = strict "
                        "classes, FIFO within; slo_fair = per-tenant "
                        "deficit-round-robin admission + SLO-aware "
                        "eviction (serve/policy.py)")
    p.add_argument("--workload", metavar="TRACE.json", default=None,
                   help="trace-driven workload (serve/workload.py "
                        "schema: tenants, priorities, SLOs, multi-turn "
                        "conversations) instead of Poisson arrivals; "
                        "see traces/bursty_multiturn.json")
    p.add_argument("--session-hold", action="store_true",
                   help="hold a finished turn's KV pages for the "
                        "session's next turn (COW prefix reuse across "
                        "turns; multi-turn traces only)")
    p.add_argument("--idle-swap", action="store_true",
                   help="park held session KV in the host spill tier "
                        "during think time (needs --session-hold and "
                        "--spill)")
    p.add_argument("--capture", action="store_true",
                   help="record page traffic and replay through the "
                        "NVR simulator")
    p.add_argument("--seed", type=int, default=0)
    args = p.parse_args(argv)
    if args.tp > 1 and not args.continuous:
        p.error("--tp needs --continuous (only the paged engine shards)")
    if args.workload and not args.continuous:
        p.error("--workload needs --continuous (trace-driven front door)")
    if args.idle_swap and not args.session_hold:
        p.error("--idle-swap needs --session-hold (nothing to park)")
    if args.idle_swap and args.spill <= 0:
        p.error("--idle-swap needs --spill (the host tier holds the "
                "parked pages)")

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    params = api.init_params(cfg, jax.random.PRNGKey(0))
    if args.continuous:
        return _run_continuous(cfg, params, args)
    return _run_single_batch(cfg, params, args)


if __name__ == "__main__":
    main()
