import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

For each cell we build ShapeDtypeStruct inputs, explicit in/out shardings,
``jax.jit(step).lower(...).compile()``, and record:

  * memory_analysis()  — per-device bytes (proves the cell fits HBM)
  * cost_analysis()    — per-device HLO FLOPs + bytes accessed
  * collective bytes   — parsed from the compiled HLO (all-gather /
    all-reduce / reduce-scatter / all-to-all / collective-permute)

Results go to benchmarks/results/dryrun/<cell>.json; the roofline report
(repro.roofline) and EXPERIMENTS.md read from there.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch tinyllama-1.1b \
      --shape train_4k --mesh pod
  PYTHONPATH=src python -m repro.launch.dryrun --all --mesh both
"""

import argparse
import functools
import json
import re
import time
import traceback

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from .. import sharding
from ..configs import ARCH_NAMES, SHAPES, get_config
from ..models import api
from ..optim import AdamWConfig
from ..train import trainer
from . import mesh as meshlib

RESULTS_DIR = os.path.join(os.path.dirname(__file__),
                           "../../../benchmarks/results/dryrun")

_DTYPE_BITS = {"f64": 64, "f32": 32, "bf16": 16, "f16": 16, "s32": 32,
               "u32": 32, "s16": 16, "u16": 16, "s8": 8, "u8": 8,
               "pred": 8, "f8e4m3fn": 8, "f8e5m2": 8, "s64": 64, "u64": 64}

_COLL_RE = re.compile(
    r"= ([a-z0-9]+)\[([0-9,]*)\][^ ]* "
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(-start)?\(")

# wire-byte multiplier per collective kind (ring algorithms, (n-1)/n ~ 1)
_WIRE_MULT = {"all-reduce": 2.0, "all-gather": 1.0, "reduce-scatter": 1.0,
              "all-to-all": 1.0, "collective-permute": 1.0}


def collective_bytes(hlo: str) -> dict:
    out = {k: 0.0 for k in _WIRE_MULT}
    count = {k: 0 for k in _WIRE_MULT}
    for m in _COLL_RE.finditer(hlo):
        dt, dims, kind = m.group(1), m.group(2), m.group(3)
        bits = _DTYPE_BITS.get(dt, 32)
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        out[kind] += n * bits / 8
        count[kind] += 1
    wire = sum(_WIRE_MULT[k] * v for k, v in out.items())
    return {"by_kind": out, "counts": count, "wire_bytes": wire}


def make_mesh_for(name: str):
    if name == "pod":
        return meshlib.make_production_mesh(multi_pod=False)
    if name == "multipod":
        return meshlib.make_production_mesh(multi_pod=True)
    raise ValueError(name)


def dp_axes(mesh) -> tuple:
    return tuple(a for a in ("pod", "data") if a in mesh.shape)


# -- per-kind builders ----------------------------------------------------------

def aux_configs(cfg):
    """Reduced-depth unrolled configs for per-layer cost extrapolation.

    XLA's cost model counts while-loop bodies once, so true totals are
    linear-extrapolated: total(L) = x(1) + (units - 1) * (x(2) - x(1)).
    """
    import dataclasses
    if cfg.family == "hybrid":
        g = len(cfg.pattern)
        c1 = dataclasses.replace(cfg, n_layers=g)
        c2 = dataclasses.replace(cfg, n_layers=2 * g)
        units = cfg.n_layers / g
    elif cfg.family == "encdec":
        c1 = dataclasses.replace(cfg, n_layers=1, n_enc_layers=1)
        c2 = dataclasses.replace(cfg, n_layers=2, n_enc_layers=2)
        units = cfg.n_layers
    else:
        c1 = dataclasses.replace(cfg, n_layers=1)
        c2 = dataclasses.replace(cfg, n_layers=2)
        units = cfg.n_layers
    return c1, c2, units


def train_policy(cfg, cell) -> dict:
    """Memory policy for huge archs: bf16 second moment (>=100B params)
    and microbatch gradient accumulation (see EXPERIMENTS.md §Perf).
    Env overrides (hillclimbing knobs): REPRO_TRAIN_MICROBATCH,
    REPRO_V_DTYPE."""
    n = cfg.params_count()
    v_dtype = os.environ.get(
        "REPRO_V_DTYPE", "bfloat16" if n > 100e9 else "float32")
    mb_env = os.environ.get("REPRO_TRAIN_MICROBATCH")
    if mb_env is not None:
        return {"v_dtype": v_dtype, "microbatch": int(mb_env)}
    microbatch = 0
    if n > 100e9:
        microbatch = max(1, cell.global_batch // 2)
    elif n > 30e9:
        microbatch = max(1, cell.global_batch // 2)
    return {"v_dtype": v_dtype, "microbatch": microbatch}


def build_train(cfg, cell, mesh, unroll=False):
    pol = train_policy(cfg, cell)
    tc = trainer.TrainConfig(
        remat=os.environ.get("REPRO_REMAT", "full"),
        unroll=unroll, microbatch=pol["microbatch"],
        opt=AdamWConfig(m_dtype="bfloat16", v_dtype=pol["v_dtype"]))
    state_specs = jax.eval_shape(
        functools.partial(trainer.init_state, cfg, tc),
        jax.random.PRNGKey(0))
    shardings = trainer.state_shardings(state_specs, mesh)
    batch_specs = api.input_specs(cfg, cell)
    bsh = trainer.batch_shardings(batch_specs, mesh)
    step_fn = trainer.make_train_step(cfg, tc)
    fn = jax.jit(step_fn,
                 in_shardings=(shardings, bsh, None),
                 out_shardings=(shardings, None),
                 donate_argnums=(0,))
    args = (state_specs, batch_specs,
            jax.ShapeDtypeStruct((), jnp.int32))
    return fn, args


def build_prefill(cfg, cell, mesh, unroll=False):
    pspecs = api.param_specs(cfg)
    psh = jax.tree.map(lambda s: NamedSharding(mesh, s),
                       sharding.tree_param_specs(pspecs, dict(mesh.shape)))
    batch_specs = api.input_specs(cfg, cell)
    bsh = trainer.batch_shardings(batch_specs, mesh)
    csh = cache_shardings(cfg, cell, mesh,
                          jax.eval_shape(
                              functools.partial(_prefill_shape_fn, cfg),
                              pspecs, batch_specs)[1])
    logits_sh = _logits_sharding(cfg, cell, mesh)

    def fn(params, batch):
        return api.prefill_fn(cfg, params, batch, remat="full",
                              unroll=unroll)

    jfn = jax.jit(fn, in_shardings=(psh, bsh),
                  out_shardings=(logits_sh, csh))
    return jfn, (pspecs, batch_specs)


def _prefill_shape_fn(cfg, params, batch):
    return api.prefill_fn(cfg, params, batch, remat="none")


def _logits_sharding(cfg, cell, mesh):
    axes = dict(mesh.shape)
    dp = dp_axes(mesh) if cell.global_batch > 1 else None
    vocab = "model" if cfg.vocab % axes.get("model", 1) == 0 else None
    return NamedSharding(mesh, P(dp, vocab))


def decode_dist(cfg, cell, mesh):
    """Distribution mode for the sparse decode path (see DESIGN.md §4 SP)."""
    if cfg.family in ("ssm", "hybrid", "encdec") or not cfg.sparse_kv:
        return None
    axes = dict(mesh.shape)
    model = axes.get("model", 1)
    dp = dp_axes(mesh)
    if cell.global_batch == 1:
        seq = tuple(a for a in ("pod", "data", "model") if a in axes)
        return {"mesh": mesh, "batch_axes": (), "seq_axes": seq,
                "kv_axes": ()}
    if cfg.n_kv_heads % model == 0:
        # (§Perf iteration 3, refuted: dropping the shard_map boundary and
        # letting GSPMD handle the batched gather replicates the cache —
        # bytes/device 1.1e11 -> 1.1e12 on gemma decode_32k.  Keep the
        # manual shard_map.)
        return {"mesh": mesh, "batch_axes": dp, "seq_axes": (),
                "kv_axes": ("model",)}
    return {"mesh": mesh, "batch_axes": dp, "seq_axes": ("model",),
            "kv_axes": ()}


def cache_shardings(cfg, cell, mesh, cache_specs):
    axes = dict(mesh.shape)
    model = axes.get("model", 1)
    dp = dp_axes(mesh) if cell.global_batch > 1 else None
    seq_all = tuple(a for a in ("pod", "data", "model") if a in axes)
    kv_div = cfg.n_kv_heads % model == 0

    def spec_for(name: str, ndim: int) -> P:
        if name == "pos":
            return P()
        if cfg.family == "ssm":
            # [L,B,...]: batch on dp, last dim on model when divisible
            s = [None] * ndim
            if dp:
                s[1] = dp
            return P(*s)
        if cfg.family == "hybrid":
            s = [None] * ndim
            if dp:
                s[0 if name.startswith("tail_") else 1] = dp
            return P(*s)
        # transformer-family KV caches
        if name in ("k", "v"):                    # [L,B,S,KV,D]
            if cell.global_batch == 1:
                return P(None, None, seq_all, None, None)
            if kv_div:
                return P(None, dp, None, "model", None)
            return P(None, dp, "model", None, None)
        if name == "kpage":                       # [L,B,NP,KV,D]
            if cell.global_batch == 1:
                return P(None, None, seq_all, None, None)
            if kv_div:
                return P(None, dp, None, "model", None)
            return P(None, dp, "model", None, None)
        if name in ("xk", "xv"):                  # [L,B,Ssrc,KV,D]
            return P(None, dp, None, "model" if kv_div else None, None)
        s = [None] * ndim
        if dp and ndim >= 2:
            s[1] = dp
        return P(*s)

    def walk(tree):
        return {k: (NamedSharding(mesh, spec_for(k, v.ndim))
                    if hasattr(v, "ndim") else walk(v))
                for k, v in tree.items()}

    return walk(cache_specs)


def build_decode(cfg, cell, mesh, unroll=False):
    kvd = os.environ.get("REPRO_KV_DTYPE")   # e.g. int8 (§Perf lever)
    if kvd:
        import dataclasses
        cfg = dataclasses.replace(cfg, kv_dtype=kvd)
    pspecs = api.param_specs(cfg)
    psh = jax.tree.map(lambda s: NamedSharding(mesh, s),
                       sharding.tree_param_specs(pspecs, dict(mesh.shape)))
    b, s = cell.global_batch, cell.seq_len
    params_for_cache = None
    if cfg.family == "encdec":
        params_for_cache = pspecs
    cache_specs = jax.eval_shape(
        functools.partial(api.init_cache, cfg, b, s,
                          params=params_for_cache))
    csh = cache_shardings(cfg, cell, mesh, cache_specs)
    token_specs = jax.ShapeDtypeStruct((b,), jnp.int32)
    tsh = NamedSharding(mesh, P(dp_axes(mesh) if b > 1 else None))
    dist = decode_dist(cfg, cell, mesh)
    logits_sh = _logits_sharding(cfg, cell, mesh)
    use_sparse = cfg.sparse_kv and cfg.family not in ("ssm", "hybrid",
                                                      "encdec")
    if os.environ.get("REPRO_DECODE_DENSE"):   # baseline-comparison knob
        use_sparse = False
        dist = None

    def fn(params, cache, token):
        return api.decode_fn(cfg, params, cache, token,
                             sparse=use_sparse if cfg.family not in
                             ("ssm", "hybrid") else None,
                             dist=dist, unroll=unroll)

    jfn = jax.jit(fn, in_shardings=(psh, csh, tsh),
                  out_shardings=(logits_sh, csh), donate_argnums=(1,))
    return jfn, (pspecs, cache_specs, token_specs)


def _build(cfg, cell, mesh, unroll=False):
    if cell.kind == "train":
        return build_train(cfg, cell, mesh, unroll)
    if cell.kind == "prefill":
        return build_prefill(cfg, cell, mesh, unroll)
    return build_decode(cfg, cell, mesh, unroll)


def inner_undercount(cfg, cell) -> float:
    """Correction for inner loops longer than the 64-iteration unroll cap
    (only mamba2's SSD chunk loop at 32k+ sequences exceeds it).  Applied
    to the per-layer cost delta — an upper bound, since the non-SSD part
    of the layer scales sub-linearly."""
    if cfg.family != "ssm" or cell.kind == "decode":
        return 1.0
    n_chunks = max(1, cell.seq_len // cfg.ssm_chunk)
    return max(1.0, n_chunks / 64.0)


def _compile_cost(cfg, cell, mesh):
    """(flops, hbm bytes, wire bytes, coll detail) with inner unrolling."""
    from ..models import layers
    layers.set_inner_unroll(True)
    try:
        fn, args = _build(cfg, cell, mesh, unroll=True)
        compiled = fn.lower(*args).compile()
    finally:
        layers.set_inner_unroll(False)
    ca = compiled.cost_analysis() or {}
    coll = collective_bytes(compiled.as_text())
    return (float(ca.get("flops", 0.0)),
            float(ca.get("bytes accessed", 0.0)),
            coll["wire_bytes"], coll)


def run_cell(arch: str, shape: str, mesh_name: str,
             skip_cost: bool = False) -> dict:
    cfg = get_config(arch)
    cell = SHAPES[shape]
    mesh = make_mesh_for(mesh_name)
    n_chips = 1
    for v in mesh.shape.values():
        n_chips *= v
    t0 = time.time()
    with jax.set_mesh(mesh):
        fn, args = _build(cfg, cell, mesh)
        lowered = fn.lower(*args)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower
        # cost extrapolation from unrolled depth-1/2 compiles (XLA counts
        # while bodies once; see aux_configs)
        if skip_cost:
            per_layer = None
        else:
            c1, c2, units = aux_configs(cfg)
            f1, b1, w1, coll1 = _compile_cost(c1, cell, mesh)
            f2, b2, w2, coll2 = _compile_cost(c2, cell, mesh)
            per_layer = {
                "flops": f2 - f1, "bytes": b2 - b1, "wire": w2 - w1,
                "base_flops": f1, "units": units,
            }
    ma = compiled.memory_analysis()
    ca = compiled.cost_analysis() or {}
    hlo = compiled.as_text()
    coll = collective_bytes(hlo)
    mem = {k: float(getattr(ma, k, 0) or 0) for k in (
        "argument_size_in_bytes", "output_size_in_bytes",
        "temp_size_in_bytes", "alias_size_in_bytes")}
    live = (mem["argument_size_in_bytes"] + mem["temp_size_in_bytes"]
            + mem["output_size_in_bytes"] - mem["alias_size_in_bytes"])
    if per_layer is not None:
        u = per_layer["units"]
        corr = inner_undercount(cfg, cell)
        flops_dev = (f1 + (u - 1) * (f2 - f1)) * corr
        bytes_dev = (b1 + (u - 1) * (b2 - b1)) * corr
        wire_dev = w1 + (u - 1) * (w2 - w1)
        per_layer["inner_undercount_corr"] = corr
    else:
        flops_dev = float(ca.get("flops", 0.0))
        bytes_dev = float(ca.get("bytes accessed", 0.0))
        wire_dev = coll["wire_bytes"]
    rec = {
        "arch": arch, "shape": shape, "mesh": mesh_name, "chips": n_chips,
        "kind": cell.kind,
        "flops_per_device": flops_dev,
        "bytes_per_device": bytes_dev,
        "wire_bytes_per_device": wire_dev,
        "scan_body_flops_per_device": float(ca.get("flops", 0.0)),
        "per_layer": per_layer,
        "collectives": coll,
        "memory": mem,
        "live_bytes_per_device": live,
        "fits_hbm": live <= meshlib.HBM_BYTES,
        "model_flops_global": api.model_flops(cfg, cell),
        "lower_s": round(t_lower, 2), "compile_s": round(t_compile, 2),
    }
    print(compiled.memory_analysis())
    print({k: v for k, v in ca.items() if k in ("flops", "bytes accessed")})
    return rec


def cell_path(arch, shape, mesh_name):
    return os.path.join(RESULTS_DIR, f"{arch}__{shape}__{mesh_name}.json")


def main():
    p = argparse.ArgumentParser()
    p.add_argument("--arch", default=None)
    p.add_argument("--shape", default=None)
    p.add_argument("--mesh", default="pod", choices=["pod", "multipod",
                                                     "both"])
    p.add_argument("--all", action="store_true")
    p.add_argument("--force", action="store_true")
    args = p.parse_args()
    archs = ARCH_NAMES if (args.all or not args.arch) else [args.arch]
    shapes = list(SHAPES) if (args.all or not args.shape) else [args.shape]
    meshes = ["pod", "multipod"] if args.mesh == "both" else [args.mesh]
    os.makedirs(RESULTS_DIR, exist_ok=True)
    failures = []
    for arch in archs:
        for shape in shapes:
            for mesh_name in meshes:
                path = cell_path(arch, shape, mesh_name)
                if os.path.exists(path) and not args.force:
                    print(f"[skip] {arch} x {shape} x {mesh_name}")
                    continue
                print(f"=== {arch} x {shape} x {mesh_name} ===", flush=True)
                try:
                    rec = run_cell(arch, shape, mesh_name)
                    with open(path, "w") as f:
                        json.dump(rec, f, indent=1)
                    print(f"[ok] flops/dev={rec['flops_per_device']:.3e} "
                          f"live={rec['live_bytes_per_device']/2**30:.2f}GiB "
                          f"wire={rec['collectives']['wire_bytes']:.3e}B "
                          f"({rec['compile_s']}s compile)", flush=True)
                except Exception as e:  # noqa: BLE001 - report and continue
                    failures.append((arch, shape, mesh_name, repr(e)))
                    traceback.print_exc()
    if failures:
        print("\nFAILURES:")
        for f in failures:
            print(" ", f)
        raise SystemExit(1)
    print("\nall requested dry-run cells compiled OK")


if __name__ == "__main__":
    main()
