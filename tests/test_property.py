"""Hypothesis property tests on system invariants."""

import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="optional dev dependency; "
                    "install with pip install -e .[dev]")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core.nvr.machine import Cache, DRAM, LINE_BYTES
from repro.kernels import coalesce_indices, ops
from repro.models import layers
from repro.optim import compress
from repro.serve.kv_allocator import NULL_PAGE, KVBlockAllocator
from repro.serve.runahead import NSBHotTier
from repro.serve.scheduler import (Request, RequestState, Scheduler,
                                   row_buckets)

SET = settings(max_examples=25, deadline=None)


@SET
@given(st.lists(st.integers(0, 4096), min_size=1, max_size=200),
       st.integers(2, 8))
def test_cache_capacity_invariant(lines, ways):
    """A cache never holds more lines than its capacity, and a re-probe of
    the most recent line always hits."""
    c = Cache(16 * LINE_BYTES * ways, ways=ways, hit_latency=1.0)
    t = 0.0
    for ln in lines:
        t += 1.0
        if c.probe(ln, t) is None:
            c.fill(ln, t)
            c.probe(ln, t + 1)
    held = sum(len(s) for s in c.sets)
    assert held <= c.num_sets * ways
    assert c.probe(lines[-1], t + 10) is not None


# four prompts with heavy prefix overlap, so attach/refcount paths fire
_ALLOC_PROMPTS = [
    np.arange(100, 100 + 12),
    np.arange(100, 100 + 15),              # shares 3 whole pages with [0]
    np.concatenate([np.arange(100, 108), [7, 8, 9, 10]]),  # 2 shared pages
    np.arange(200, 200 + 8),               # disjoint
]

_alloc_op = st.one_of(
    st.tuples(st.just("prompt"), st.integers(0, 3), st.integers(0, 3)),
    st.tuples(st.just("ensure"), st.integers(0, 3), st.integers(1, 20)),
    st.tuples(st.just("register"), st.integers(0, 3), st.integers(0, 0)),
    st.tuples(st.just("free"), st.integers(0, 3), st.integers(0, 0)),
    st.tuples(st.just("spill"), st.integers(0, 3), st.integers(0, 0)),
    st.tuples(st.just("resume"), st.integers(0, 3), st.integers(0, 20)),
)


def _alloc_invariants(al: KVBlockAllocator) -> None:
    held: dict = {}
    for rid, table in al._tables.items():
        assert NULL_PAGE not in table, "NULL page handed out"
        for p in table:
            held[p] = held.get(p, 0) + 1
    # every held page is refcounted exactly as many times as it appears
    # across tables (a page in two tables only via a counted attach —
    # never a double allocation)
    assert held == al._ref
    live = set(held)
    assert live.isdisjoint(al._free)
    assert live.isdisjoint(al._cached)
    assert set(al._cached).isdisjoint(al._free)
    assert al.pages_in_use + al.pages_free == al.capacity
    assert al.pages_in_use == len(live)
    for rid in al._tables:
        bt = al.table_array(rid, 16)
        assert all(bt[al.owned(rid):] == NULL_PAGE)
    # every physical page id lives in exactly one tier (live HBM, free,
    # cached LRU) and spill slots form a bijection with host snapshots
    al.check_tier_invariants()


@SET
@given(st.lists(_alloc_op, min_size=1, max_size=60), st.integers(4, 12),
       st.integers(0, 8))
def test_kv_allocator_refcount_invariants(ops_list, n_pages, spill_pages):
    """Random ensure/prefix-attach/register/free/spill/resume sequences:
    never hand out NULL_PAGE, never double-allocate a live page,
    conservation of pages, NULL padding beyond the owned table, and the
    tier partition — each page id in exactly one of {live HBM, free
    list, cached LRU}, never simultaneously snapshotted-on-host and
    parked in the cached LRU."""
    al = KVBlockAllocator(n_pages=n_pages, page_tokens=4,
                          spill_pages=spill_pages)
    assigned: dict = {}                     # rid -> prompt in its table
    for kind, rid, arg in ops_list:
        spilled = al.is_spilled(rid)
        if kind == "prompt" and not spilled:
            prompt = assigned.get(rid, _ALLOC_PROMPTS[arg])
            ok, cached = al.ensure_prompt(rid, prompt)
            if ok:
                assigned[rid] = prompt
                assert cached <= len(prompt)
        elif kind == "ensure" and not spilled:
            before = al.owned(rid)
            if al.ensure(rid, arg):
                assert al.owned(rid) >= before
        elif kind == "register" and not spilled:
            if rid in assigned:
                p = assigned[rid]
                al.register_prefix(rid, p, al.owned(rid)
                                   * al.page_tokens)
        elif kind == "free":
            al.free_request(rid)
            assigned.pop(rid, None)
        elif kind == "spill" and not spilled:
            held = al.owned(rid)
            if al.spill_request(rid):
                assert al.owned(rid) == 0          # HBM side released
                assert al.is_spilled(rid)
            else:
                assert al.owned(rid) == held       # all-or-nothing
        elif kind == "resume" and spilled:
            if al.resume_spilled(rid, n_tokens=arg):
                assert not al.is_spilled(rid)
                assert al.owned(rid) >= al.pages_for_tokens(arg)
        al.drain_copies()                   # keep the queues bounded
        al.drain_spill_outs()
        al.drain_swap_ins()
        al.drain_remaps()
        _alloc_invariants(al)
    for rid in range(4):
        al.free_request(rid)
    al.drain_swap_ins()
    _alloc_invariants(al)
    assert al.pages_in_use == 0
    assert al.pages_spilled == 0
    assert al.spill_slots_free == spill_pages


_tier_op = st.one_of(
    st.tuples(st.just("stage"),
              st.lists(st.integers(-1, 20), min_size=1, max_size=6),
              st.integers(0, 4)),
    st.tuples(st.just("invalidate"),
              st.lists(st.integers(-1, 20), min_size=1, max_size=6),
              st.just(0)),
    st.tuples(st.just("touch"), st.integers(1, 20), st.just(0)),
)


@SET
@given(st.lists(_tier_op, min_size=1, max_size=80),
       st.integers(8, 20),                     # demand region pages
       st.integers(1, 6))                      # staging slots
def test_nsb_hot_tier_never_resolves_stale_pages(ops_list, n_demand,
                                                 n_slots):
    """Random stage/invalidate/touch sequences through the runahead hot
    tier: the soundness contract is that the hot-map never resolves a
    page after it was invalidated (rewritten or freed demand copy) or
    FIFO-evicted for slot reuse — resolving a stale slot would gather
    dead NSB bytes into attention.  Also: slot bijection (each live slot
    maps one page and back), NULL/out-of-range ids never staged, the
    free-list + live slots conserve capacity, and the PageCache
    accounting twin never diverges (touch() asserts parity itself)."""
    tier = NSBHotTier(n_demand, n_slots)
    staged: dict = {}                          # page -> generation staged
    dropped: set = set()                       # pages explicitly dropped
    for kind, arg, budget in ops_list:
        if kind == "stage":
            copies = tier.stage(arg, max_copies=budget)
            assert len(copies) <= budget
            for p, slot in copies:
                assert 0 < p < n_demand        # NULL / out-of-range barred
                assert 0 <= slot < n_slots
                staged[p] = True
                dropped.discard(p)
            # one unordered scatter performs the call's copies: a page
            # never earns two copies and a slot is never written twice
            # (duplicate dst would leave the bytes/hot-map agreement to
            # scatter ordering)
            assert len({p for p, _ in copies}) == len(copies)
            assert len({s for _, s in copies}) == len(copies)
        elif kind == "invalidate":
            tier.invalidate(arg)
            for p in arg:
                if staged.pop(int(p), None):
                    dropped.add(int(p))
        else:
            hit = tier.touch(arg)              # twin-parity asserts inside
            assert hit == (arg in staged)
        # FIFO eviction may have dropped old pages to recycle slots:
        # reconcile our model against the tier's authoritative order
        evicted = [p for p in staged if tier.resolve(p) < 0]
        for p in evicted:
            staged.pop(p)
            dropped.add(p)
        # -- invariants
        hot = tier.hot_map()
        assert tier.n_staged == len(staged) <= n_slots
        for p in staged:
            slot = tier.resolve(p)
            assert slot >= 0 and hot[p] == slot
            assert tier._page_of[slot] == p    # slot bijection
        for p in dropped:
            if p not in staged:                # not re-staged since
                assert tier.resolve(p) < 0
                assert not (0 <= p < n_demand) or hot[p] < 0
        live_slots = {tier.resolve(p) for p in staged}
        assert len(live_slots) == len(staged)  # no slot double-booked
        assert live_slots.isdisjoint(tier._free)
        assert len(live_slots) + len(tier._free) == n_slots
        # hot-map and staged set agree everywhere, not just at live pages
        assert {int(p) for p in np.flatnonzero(hot >= 0)} == set(staged)
    assert tier.stats.staged_pages >= len(staged)
    if tier.model.stats.hits + tier.model.stats.misses:
        assert 0.0 <= tier.hit_rate <= 1.0


@SET
@given(
    st.lists(st.tuples(st.integers(1, 16),     # prompt_len
                       st.integers(1, 5),      # max_new_tokens
                       st.integers(0, 12)),    # arrival tick
            min_size=1, max_size=8),
    st.integers(6, 16),                        # allocatable pool pages
    st.integers(1, 8),                         # max_batch
    st.integers(1, 24),                        # token budget
    st.booleans(),                             # row bucketing on/off
)
def test_scheduler_plan_invariants(reqs, pool, max_batch, budget,
                                   buckets_on):
    """Random workloads through ``Scheduler.schedule``: per-iteration
    plan invariants under preemption + bucket top-up.

    * no rid planned twice in one iteration (decode and prefill are
      disjoint; a request never decodes twice per plan),
    * ``len(plan.decode) <= plan.decode_bucket <= max_batch`` when
      bucketing, and <= max_batch always,
    * budget accounting: without buckets ``plan.n_tokens`` never
      exceeds the budget; with buckets only top-up decode rows may ride
      over it, bounded by the bucket boundary,
    * preempted requests keep FIFO priority: they wait *ahead* of
      never-admitted requests, and admission order follows arrival,
    * every request that fits the pool eventually finishes, releasing
      every page.
    """
    al = KVBlockAllocator(n_pages=pool + 1, page_tokens=4)
    bks = row_buckets(max_batch) if buckets_on else ()
    s = Scheduler(al, max_batch=max_batch, chunk=4, token_budget=budget,
                  row_buckets=bks)
    live = []
    for rid, (plen, gen, tick) in enumerate(reqs):
        # clamp so every request individually fits (engine submit() bars
        # the rest); keeps the liveness assertion meaningful
        while al.pages_for_tokens(plen + gen) > al.capacity:
            plen = max(1, plen // 2)
            gen = max(1, gen - 1)
        live.append((tick, Request(rid=rid, prompt=np.arange(plen),
                                   max_new_tokens=gen,
                                   arrival=float(tick))))
    live.sort(key=lambda x: (x[0], x[1].rid))
    pending = list(live)
    for now in range(400):
        while pending and pending[0][0] <= now:
            s.add(pending.pop(0)[1])
        plan = s.schedule(float(now))
        # -- plan invariants
        rids = [r.rid for r in plan.decode] \
            + [j.req.rid for j in plan.prefill]
        assert len(rids) == len(set(rids)), "rid planned twice"
        assert len(plan.decode) <= max_batch
        prefill_toks = sum(j.n_tokens for j in plan.prefill)
        if bks and plan.decode:
            assert plan.decode_bucket in bks
            assert len(plan.decode) <= plan.decode_bucket <= max_batch
            # only bucket top-up rows may exceed the budget, and the
            # budget admitted at least one decode row before top-up
            assert plan.n_tokens <= budget + plan.decode_bucket - 1
        else:
            assert plan.decode_bucket == 0
            assert plan.n_tokens <= budget
        # -- queue invariants: preempted requests sit ahead of
        # never-admitted ones (appendleft vs append)
        waiting = list(s.waiting)
        seen_fresh = False
        for r in waiting:
            if r.admission_seq < 0:
                seen_fresh = True
            else:
                assert not seen_fresh, "preempted request lost priority"
        # drive the fake model
        for job in plan.prefill:
            job.req.computed += job.n_tokens
            if job.req.computed == job.req.prompt_len \
                    and not job.req.out_tokens:
                job.req.out_tokens.append(0)
                job.req.first_token_at = float(now)
                if job.req.done:
                    s.finish(job.req, float(now))
        for req in plan.decode:
            frontier = req.computed == req.total_len - 1
            req.computed += 1
            if frontier:
                req.out_tokens.append(0)
                if req.done:
                    s.finish(req, float(now))
        if not pending and not s.has_work:
            break
    assert not s.has_work, "scheduler failed to drain the workload"
    for _, r in live:
        assert r.state is RequestState.FINISHED
        assert len(r.out_tokens) == r.max_new_tokens
    assert al.pages_in_use == 0
    # admission order followed arrival order (FIFO, no bypass)
    admitted = sorted((r for _, r in live), key=lambda r: r.admission_seq)
    arrivals = [r.arrival for r in admitted]
    assert arrivals == sorted(arrivals)


@SET
@given(
    st.lists(st.tuples(st.integers(1, 24),       # prompt_len
                       st.integers(1, 6),        # max_new_tokens
                       st.integers(0, 10)),      # arrival tick
            min_size=1, max_size=8),
    st.integers(6, 16),                        # allocatable pool pages
    st.integers(1, 8),                         # max_batch
    st.integers(1, 24),                        # token budget
    st.booleans(),                             # row bucketing on/off
)
def test_overlapped_schedule_machine(reqs, pool, max_batch, budget,
                                     buckets_on):
    """Random workloads through the pipelined executor's double-buffer
    cycle (``schedule_speculative`` in the overlap window, ``commit`` at
    the next iteration boundary) with a fake count-model driving the
    same dispatch/commit split the engine performs.

    * ``schedule_speculative`` is pure: no real scheduler or allocator
      state moves while the draft is built (the draft runs on shadow
      state — a page it "allocates" must not exist),
    * a committed plan never contains a finished or preempted rid, and
      never plans a prefill chunk from a stale KV frontier,
    * the committed plan obeys the same token-budget bound as the
      synchronous scheduler (buckets may ride top-up rows over it),
    * page conservation holds across every commit boundary, and the
      machine drains: all requests finish and release every page.
    """
    al = KVBlockAllocator(n_pages=pool + 1, page_tokens=4)
    bks = row_buckets(max_batch) if buckets_on else ()
    s = Scheduler(al, max_batch=max_batch, chunk=4, token_budget=budget,
                  row_buckets=bks)
    live = []
    for rid, (plen, gen, tick) in enumerate(reqs):
        while al.pages_for_tokens(plen + gen) > al.capacity:
            plen = max(1, plen // 2)
            gen = max(1, gen - 1)
        live.append((tick, Request(rid=rid, prompt=np.arange(plen),
                                   max_new_tokens=gen,
                                   arrival=float(tick))))
    live.sort(key=lambda x: (x[0], x[1].rid))
    pending = list(live)

    def fingerprint():
        return (al.pages_in_use, al.pages_free, s.n_preemptions,
                tuple((r.rid, r.computed, len(r.out_tokens))
                      for r in s.running),
                tuple(r.rid for r in s.waiting))

    spec = None
    for now in range(400):
        while pending and pending[0][0] <= now:
            s.add(pending.pop(0)[1])
        plan = s.commit(spec, float(now))
        # -- committed plan references only live, consistent requests
        running = {r.rid: r for r in s.running}
        for r in plan.decode:
            assert r.rid in running, "committed plan holds a dead rid"
            assert not r.done and not r.in_prefill
        for j in plan.prefill:
            assert j.req.rid in running, "committed plan holds a dead rid"
            assert j.start == j.req.computed, "stale prefill frontier"
        # -- budget bound post-commit, same contract as schedule()
        if bks and plan.decode:
            assert plan.n_tokens <= budget + plan.decode_bucket - 1
        else:
            assert plan.n_tokens <= budget
        # dispatch phase: prefill frontiers advance before the draft is
        # taken, exactly as the engine dispatches chunks pre-overlap
        for job in plan.prefill:
            job.req.computed += job.n_tokens
        # overlap window: draft N+1 on shadow state — must be pure
        before = fingerprint()
        spec = s.schedule_speculative(float(now) + 1.0, in_flight=plan)
        assert fingerprint() == before, \
            "speculative schedule mutated real state"
        assert spec.speculative and spec.for_now == float(now) + 1.0
        # commit phase: emissions and finishes, sync mutation order
        for job in plan.prefill:
            if job.req.computed == job.req.prompt_len \
                    and not job.req.out_tokens:
                job.req.out_tokens.append(0)
                job.req.first_token_at = float(now)
                if job.req.done:
                    s.finish(job.req, float(now))
        for req in plan.decode:
            frontier = req.computed == req.total_len - 1
            req.computed += 1
            if frontier:
                req.out_tokens.append(0)
                if req.done:
                    s.finish(req, float(now))
        # -- page conservation across the commit boundary
        assert al.pages_in_use + al.pages_free == al.capacity
        if not pending and not s.has_work:
            break
    assert not s.has_work, "overlapped machine failed to drain"
    for _, r in live:
        assert r.state is RequestState.FINISHED
        assert len(r.out_tokens) == r.max_new_tokens
    assert al.pages_in_use == 0
    assert s.plan_commits > 0


@SET
@given(st.lists(st.integers(0, 1000), min_size=2, max_size=100))
def test_dram_fifo_monotonic(addrs):
    """DRAM completion times are monotone for same-time issues (FIFO)."""
    d = DRAM(latency=50.0, bytes_per_cycle=8.0)
    times = [d.fetch(0.0) for _ in addrs]
    assert all(b > a for a, b in zip(times, times[1:]))
    assert d.bytes_transferred == len(addrs) * LINE_BYTES


@SET
@given(st.lists(st.integers(0, 63), min_size=1, max_size=128))
def test_coalesce_indices_permutation(idx):
    arr = jnp.asarray(np.array(idx, np.int32))
    sorted_idx, inv = coalesce_indices(arr)
    assert bool(jnp.all(jnp.diff(sorted_idx) >= 0))
    np.testing.assert_array_equal(np.asarray(sorted_idx[inv]),
                                  np.asarray(arr))


@SET
@given(st.integers(1, 6), st.integers(1, 4), st.integers(16, 64))
def test_group_tokens_by_expert_sound(e_pow, bt_pow, t_scale)\
        :
    """Every kept token lands in a block labelled with its own expert."""
    e, bt = 2 ** e_pow, 8 * bt_pow
    t = t_scale * 4
    rng = np.random.default_rng(e * bt + t)
    eids = jnp.asarray(rng.integers(0, e, t), jnp.int32)
    perm, group_ids, inv = ops.group_tokens_by_expert(eids, e, bt)
    kept = np.asarray(inv >= 0)
    pos = np.asarray(inv)[kept]
    assert len(np.unique(pos)) == kept.sum()        # injective placement
    np.testing.assert_array_equal(np.asarray(group_ids)[pos // bt],
                                  np.asarray(eids)[kept])


@SET
@given(st.floats(0.01, 100.0), st.integers(1, 8))
def test_int8_compress_error_bound(scale, seed):
    """Quantisation error is bounded by half a quantisation step."""
    rng = np.random.default_rng(seed)
    g = jnp.asarray(rng.normal(size=(64,)) * scale, jnp.float32)
    q, s = compress.quantize_int8(g)
    err = np.abs(np.asarray(compress.dequantize_int8(q, s) - g))
    assert err.max() <= float(s) * 0.5 + 1e-6


@SET
@given(st.integers(1, 5))
def test_error_feedback_converges(seed):
    """With error feedback, the accumulated compressed signal converges to
    the true accumulated gradient (bias-free compression)."""
    rng = np.random.default_rng(seed)
    g = jnp.asarray(rng.normal(size=(32,)), jnp.float32)
    err = jnp.zeros_like(g)
    acc = jnp.zeros_like(g)
    n = 50
    for _ in range(n):
        deq, err = compress.compress_with_feedback(g, err)
        acc = acc + deq
    np.testing.assert_allclose(np.asarray(acc + err), np.asarray(g * n),
                               rtol=1e-4, atol=1e-4)


@SET
@given(st.integers(1, 3), st.integers(1, 4), st.integers(1, 3),
       st.integers(2, 5))
def test_chunked_attention_matches_naive(b, sq_b, h, sk_chunks):
    """Flash-style chunked attention == naive softmax attention."""
    sq, sk, d = 4 * sq_b, 8 * sk_chunks, 16
    rng = np.random.default_rng(b * 100 + sq + h + sk_chunks)
    q = jnp.asarray(rng.normal(size=(b, sq, h, d)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(b, sk, h, d)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(b, sk, h, d)), jnp.float32)
    out = layers.chunked_attention(q, k, v, causal=False, chunk=8)
    s = np.einsum("bqhd,bkhd->bqhk", np.asarray(q),
                  np.asarray(k)) / np.sqrt(d)
    p = np.exp(s - s.max(-1, keepdims=True))
    p /= p.sum(-1, keepdims=True)
    want = np.einsum("bqhk,bkhd->bqhd", p, np.asarray(v))
    np.testing.assert_allclose(np.asarray(out), want, rtol=2e-4, atol=2e-4)


@SET
@given(st.integers(0, 1000), st.integers(1, 30))
def test_rglru_decay_bounded(seed, s):
    """RG-LRU hidden state norm stays bounded (contraction property)."""
    from repro.models.hybrid import rglru
    rng = np.random.default_rng(seed)
    ru = 8
    p = {"w_rg_r": jnp.asarray(rng.normal(size=(ru, ru)) * 0.1, jnp.float32),
         "w_rg_i": jnp.asarray(rng.normal(size=(ru, ru)) * 0.1, jnp.float32),
         "lam": jnp.full((ru,), 3.0, jnp.float32)}
    x = jnp.asarray(rng.normal(size=(1, s, ru)), jnp.float32)
    y, h_last = rglru(x, p)
    assert bool(jnp.all(jnp.isfinite(y)))
    # sqrt(1-a^2) gating makes the map non-expansive per step
    assert float(jnp.max(jnp.abs(h_last))) <= float(
        jnp.max(jnp.abs(x))) * (1 + 1e-3) * s ** 0.5 + 1.0


@SET
@given(st.integers(1, 4), st.integers(1, 3), st.integers(1, 3))
def test_ssd_chunked_equals_sequential(b, nh, chunks):
    from repro.models.ssm import ssd_chunked
    s, hd, ds, ck = 4 * chunks, 4, 5, 4
    rng = np.random.default_rng(b * 7 + nh * 3 + chunks)
    xh = jnp.asarray(rng.normal(size=(b, s, nh, hd)), jnp.float32)
    dt = jnp.asarray(rng.uniform(0.1, 0.5, size=(b, s, nh)), jnp.float32)
    A = jnp.asarray(-rng.uniform(0.3, 1.0, size=(nh,)), jnp.float32)
    B = jnp.asarray(rng.normal(size=(b, s, ds)), jnp.float32)
    C = jnp.asarray(rng.normal(size=(b, s, ds)), jnp.float32)
    y, st_ = ssd_chunked(xh, dt, A, B, C, chunk=ck)
    h = np.zeros((b, nh, hd, ds))
    ys = []
    for t in range(s):
        a = np.exp(np.asarray(dt[:, t]) * np.asarray(A))
        h = h * a[:, :, None, None] + np.einsum(
            "bn,bnp,bs->bnps", np.asarray(dt[:, t]), np.asarray(xh[:, t]),
            np.asarray(B[:, t]))
        ys.append(np.einsum("bs,bnps->bnp", np.asarray(C[:, t]), h))
    np.testing.assert_allclose(np.asarray(y), np.stack(ys, 1), rtol=1e-4,
                               atol=1e-5)
    np.testing.assert_allclose(np.asarray(st_), h, rtol=1e-4, atol=1e-5)
