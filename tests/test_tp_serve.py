"""Tensor-parallel paged serving: KV-head-sharded pools over a host mesh.

Sharded engines need more than one jax device, and the device count is
fixed at jax init — so every sharded test runs in a subprocess with
``XLA_FLAGS=--xla_force_host_platform_device_count=N`` (the same pattern
as tests/test_distributed.py).  Only ``jax.make_mesh`` + ``shard_map`` +
``NamedSharding`` are used, so these run on jax 0.4.3x as well.

The acceptance bar is *bitwise*: at tp=2 (and tp=4 where head counts
divide) every request's token stream and logits must equal the tp=1
engine's exactly — including across a forced preemption/resume — while
the pools physically shard (1/tp of the KV-head dim per device) and pool
donation keeps consuming buffers.
"""

import os
import subprocess
import sys

import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def run_py(code: str, n_dev: int, timeout: int = 600):
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={n_dev}"
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    return subprocess.run([sys.executable, "-c", code], env=env,
                          capture_output=True, text=True, timeout=timeout)


# -- fast, device-free: the serve TP sharding rules ---------------------------

class TestServeParamSpecs:
    def test_qkv_sharded_rest_replicated(self):
        from repro import sharding

        params = {
            "embed": np.zeros((512, 128)),
            "layers": {
                "wq": np.zeros((2, 128, 128)),
                "wk": np.zeros((2, 128, 64)),
                "wv": np.zeros((2, 128, 64)),
                "bq": np.zeros((2, 128)),
                "wo": np.zeros((2, 128, 128)),
                "ln1": np.zeros((2, 128)),
                "wi": np.zeros((2, 128, 256)),
                "wo_mlp": np.zeros((2, 256, 128)),
            },
            "ln_f": np.zeros((128,)),
            "lm_head": np.zeros((128, 512)),
        }
        specs = sharding.serve_param_specs(params)
        lay = specs["layers"]
        assert lay["wq"] == P(None, None, "model")
        assert lay["wk"] == P(None, None, "model")
        assert lay["wv"] == P(None, None, "model")
        assert lay["bq"] == P(None, "model")
        # everything feeding the post-gather (replicated) math stays
        # unsharded: no psum may ever cross shards
        for name in ("wo", "ln1", "wi", "wo_mlp"):
            assert lay[name] == P(), name
        assert specs["embed"] == P()
        assert specs["lm_head"] == P()

    def test_pool_specs_never_shard_the_page_axis(self):
        from repro import sharding

        kv_spec, s_spec = sharding.serve_pool_specs()
        assert kv_spec == P(None, None, None, "model", None)
        assert s_spec == P(None, None, "model", None)


# -- sharded engines (subprocess, forced host devices) ------------------------

_COMMON = """
import numpy as np, jax
from repro.configs import get_config
from repro.models import api
from repro.launch.mesh import make_serve_mesh
from repro.serve.engine import PagedEngine

cfg = get_config("qwen2-1.5b").reduced()
params = api.init_params(cfg, jax.random.PRNGKey(0))
rng = np.random.default_rng(11)
sys_prompts = [rng.integers(1, cfg.vocab, size=12) for _ in range(2)]
work = []
for i in range(6):
    sfx = rng.integers(1, cfg.vocab, size=int(rng.integers(2, 6)))
    work.append((float(i) * 0.5,
                 np.concatenate([sys_prompts[i % 2], sfx]), 5))

def run(mesh=None, n_pages=0, kernel="xla", capture=False,
        runahead="off", spill=0, executor="sync"):
    eng = PagedEngine(cfg, params, max_len=48, n_pages=n_pages,
                      max_batch=4, chunk=8, nsb_pages=32, mesh=mesh,
                      kernel=kernel, capture_trace=capture,
                      runahead=runahead, runahead_pages=8,
                      spill_pages=spill, executor=executor)
    eng.run([(t, p.copy(), g) for t, p, g in work])
    return eng

def assert_bitwise(a_eng, b_eng):
    for rid in a_eng.requests:
        a, b = a_eng.requests[rid], b_eng.requests[rid]
        assert a.out_tokens == b.out_tokens, f"rid {rid} tokens"
        assert np.array_equal(a.last_logits, b.last_logits), \\
            f"rid {rid} logits"
"""


@pytest.mark.slow
def test_tp2_bitwise_sharded_pools_preemption_and_nsb():
    """The tp=2 engine on the shared-prefix fixture: pools physically
    sharded, logits/token streams bitwise-identical to tp=1 — in the
    calm run AND across a forced preemption/resume — with per-shard NSB
    stats rolled up and the captured stream shard-tagged."""
    code = _COMMON + """
from repro.core.nvr.capture import nsb_shard_rollup

base = run()
mesh = make_serve_mesh(2)
tp2 = run(mesh=mesh, capture=True)
assert_bitwise(base, tp2)

# pools physically sharded: each device holds half the KV-head dim
shards = tp2.k_pool.addressable_shards
assert len(shards) == 2
assert [s.data.shape[3] for s in shards] == [cfg.n_kv_heads // 2] * 2
assert tp2.s_pool.addressable_shards[0].data.shape[2] \\
    == cfg.n_kv_heads // 2

# forced preemption under sharding resumes bitwise (vs the calm tp=1)
tight = run(mesh=mesh, n_pages=1 + 9)
assert tight.scheduler.n_preemptions > 0
assert_bitwise(base, tight)

# per-shard NSBs: one rate per shard, traffic shard-tagged end to end
m = tp2.metrics()
assert m["tp"] == 2 and len(m["nsb_shard_hit_rates"]) == 2
assert all(0.0 <= r <= 1.0 for r in m["nsb_shard_hit_rates"])
assert sorted(tp2.recorder.shard_ids()) == [0, 1]
roll = nsb_shard_rollup(tp2.recorder, 32, 2)
assert roll["hits"] + roll["misses"] > 0
assert len(roll["per_shard"]) == 2
print("TP2_OK")
"""
    r = run_py(code, n_dev=2)
    assert r.returncode == 0, (r.stderr[-3000:], r.stdout[-500:])
    assert "TP2_OK" in r.stdout


@pytest.mark.slow
def test_tp2_donation_buckets_and_pallas():
    """Step-loop invariants survive sharding: pool donation consumes the
    sharded buffers, decode-trace count stays O(log max_batch), and the
    per-shard Pallas runahead kernel matches the sharded XLA oracle at
    tolerance (same contract as on a single shard)."""
    code = _COMMON + """
import math
mesh = make_serve_mesh(2)

# donation: the jitted step consumes the sharded input pool buffers
eng = PagedEngine(cfg, params, max_len=48, max_batch=4, chunk=8,
                  nsb_pages=32, mesh=mesh)
eng.submit(np.arange(1, 15), max_new_tokens=4)
k0, v0, s0 = eng.k_pool, eng.v_pool, eng.s_pool
eng.step()
assert k0.is_deleted() and v0.is_deleted() and s0.is_deleted()

# bucketing: a full run still compiles <= O(log max_batch) decode traces
full = run(mesh=mesh)
m = full.metrics()
assert m["n_decode_traces"] <= math.ceil(math.log2(4)) + 1
assert m["n_prefill_traces"] == 1

# pallas path per shard vs sharded XLA oracle: tokens equal, logits at
# interpret-mode tolerance
pal = run(mesh=mesh, kernel="pallas")
for rid in full.requests:
    a, b = full.requests[rid], pal.requests[rid]
    assert a.out_tokens == b.out_tokens, f"rid {rid}"
    np.testing.assert_allclose(a.last_logits, b.last_logits,
                               rtol=2e-5, atol=2e-5)
print("TP2_FAST_OK")
"""
    r = run_py(code, n_dev=2)
    assert r.returncode == 0, (r.stderr[-3000:], r.stdout[-500:])
    assert "TP2_FAST_OK" in r.stdout


@pytest.mark.slow
def test_tp4_bitwise_where_heads_divide_and_guard():
    """tp=4 on a 4-KV-head config variant is bitwise vs tp=1; tp=4 on
    the stock 2-KV-head config raises the GQA-divisibility error."""
    code = """
import numpy as np, jax, dataclasses
from repro.configs import get_config
from repro.models import api
from repro.launch.mesh import make_serve_mesh
from repro.serve.engine import PagedEngine

cfg2 = get_config("qwen2-1.5b").reduced()
cfg4 = dataclasses.replace(cfg2, n_kv_heads=4)
params = api.init_params(cfg4, jax.random.PRNGKey(0))
rng = np.random.default_rng(3)
work = [(0.0, rng.integers(1, cfg4.vocab, size=int(p)), 4)
        for p in rng.integers(8, 20, size=4)]

def run(mesh=None):
    eng = PagedEngine(cfg4, params, max_len=48, max_batch=4, chunk=8,
                      mesh=mesh)
    eng.run([(t, p.copy(), g) for t, p, g in work])
    return eng

base = run()
tp4 = run(make_serve_mesh(4))
for rid in base.requests:
    a, b = base.requests[rid], tp4.requests[rid]
    assert a.out_tokens == b.out_tokens, f"rid {rid} tokens"
    assert np.array_equal(a.last_logits, b.last_logits), f"rid {rid}"
assert len(tp4.k_pool.addressable_shards) == 4

try:
    PagedEngine(cfg2, api.init_params(cfg2, jax.random.PRNGKey(0)),
                max_len=48, mesh=make_serve_mesh(4))
    raise SystemExit("divisibility guard did not fire")
except ValueError as e:
    assert "divide" in str(e)
print("TP4_OK")
"""
    r = run_py(code, n_dev=4)
    assert r.returncode == 0, (r.stderr[-3000:], r.stdout[-500:])
    assert "TP4_OK" in r.stdout


@pytest.mark.slow
def test_tp2_runahead_bitwise_and_staged_tail_sharded():
    """Online runahead composes with tensor parallelism: the staged NSB
    tail rides the KV-head-sharded pools (1/tp of the head dim per
    device, page axis never sharded), the hot-map remap replays inside
    the sharded decode, and tokens/logits stay bitwise-identical to the
    unsharded runahead-off engine — including across a forced
    preemption/resume with staging active."""
    code = _COMMON + """
base = run()                                   # tp=1, runahead off
mesh = make_serve_mesh(2)
tp2 = run(mesh=mesh, runahead="nvr")
assert_bitwise(base, tp2)

# the staging tail extends the *page* axis of the sharded pools: each
# shard still holds half the KV-head dim, over demand + staged pages
shards = tp2.k_pool.addressable_shards
assert len(shards) == 2
assert tp2.k_pool.shape[1] == tp2.n_pages + tp2.nsb_slots
assert [s.data.shape[3] for s in shards] == [cfg.n_kv_heads // 2] * 2

m = tp2.metrics()
assert m["runahead_staged_pages"] > 0
# per-shard staged-tier mirrors: one rate per shard, rollup defined
assert len(m["runahead_shard_hit_rates"]) == 2
assert all(r is None or 0.0 <= r <= 1.0
           for r in m["runahead_shard_hit_rates"])

# forced preemption under sharding + staging resumes bitwise
tight = run(mesh=mesh, n_pages=1 + 9, runahead="nvr")
assert tight.scheduler.n_preemptions > 0
assert_bitwise(base, tight)
assert tight.metrics()["runahead_invalidations"] > 0
print("TP2_RUNAHEAD_OK")
"""
    r = run_py(code, n_dev=2)
    assert r.returncode == 0, (r.stderr[-3000:], r.stdout[-500:])
    assert "TP2_RUNAHEAD_OK" in r.stdout


@pytest.mark.slow
def test_tp2_host_spill_swap_resume_bitwise():
    """The host spill tier composes with tensor parallelism: swap-out
    snapshots the *sharded* pools (device->host gather re-assembles the
    full KV-head dim), swap-in restores onto freshly re-pinned sharded
    pools, and tokens/logits stay bitwise-identical to the calm tp=1
    run — including with runahead fetch-back active."""
    code = _COMMON + """
base = run()                                   # tp=1, calm, no spill
mesh = make_serve_mesh(2)
tight = run(mesh=mesh, n_pages=1 + 9, spill=16)
assert tight.scheduler.n_swap_outs > 0
assert tight.scheduler.n_swap_ins == tight.scheduler.n_swap_outs
assert_bitwise(base, tight)

# restored pools stay physically sharded after the host round-trip
shards = tight.k_pool.addressable_shards
assert len(shards) == 2
assert [s.data.shape[3] for s in shards] == [cfg.n_kv_heads // 2] * 2
tight.allocator.check_tier_invariants()
m = tight.metrics()
assert m["tp"] == 2 and m["swap_out_pages"] == m["swap_in_pages"] > 0

# fetch-back under sharding: the spilled queue head resumes in the
# runahead window and its history pages stage onto the sharded tail
ra = run(mesh=mesh, n_pages=1 + 9, spill=16, runahead="nvr")
assert ra.scheduler.n_swap_outs > 0
assert_bitwise(base, ra)
print("TP2_SPILL_OK")
"""
    r = run_py(code, n_dev=2)
    assert r.returncode == 0, (r.stderr[-3000:], r.stdout[-500:])
    assert "TP2_SPILL_OK" in r.stdout


@pytest.mark.slow
def test_tp2_prefix_cache_cow_under_sharding():
    """COW prefix caching composes with sharding: cached pages attach,
    COW pool copies replay onto the sharded pools, and logits stay
    bitwise-identical to the uncached tp=1 run."""
    code = _COMMON + """
mesh = make_serve_mesh(2)
base = PagedEngine(cfg, params, max_len=48, max_batch=4, chunk=8,
                   nsb_pages=32, prefix_cache=False)
base.run([(t, p.copy(), g) for t, p, g in work])
tp2 = run(mesh=mesh)                      # prefix cache on (default)
assert tp2.allocator.stats.prefix_hits > 0
assert_bitwise(base, tp2)

# an identical page-aligned prompt pair forces a tail-page COW whose
# bytes must land on the *sharded* pools
rng2 = np.random.default_rng(13)
prompt = rng2.integers(1, cfg.vocab, size=16)
pair = [(0.0, prompt, 4), (4.0, prompt.copy(), 4)]
cow = PagedEngine(cfg, params, max_len=48, max_batch=4, chunk=8,
                  nsb_pages=32, mesh=mesh)
cow.run([(t, p.copy(), g) for t, p, g in pair])
ref = PagedEngine(cfg, params, max_len=48, max_batch=4, chunk=8,
                  nsb_pages=32, prefix_cache=False)
ref.run([(t, p.copy(), g) for t, p, g in pair])
assert cow.stats.cow_page_copies >= 1
for rid in ref.requests:
    assert ref.requests[rid].out_tokens == cow.requests[rid].out_tokens
    assert np.array_equal(ref.requests[rid].last_logits,
                          cow.requests[rid].last_logits)
print("TP2_COW_OK")
"""
    r = run_py(code, n_dev=2)
    assert r.returncode == 0, (r.stderr[-3000:], r.stdout[-500:])
    assert "TP2_COW_OK" in r.stdout


@pytest.mark.slow
def test_tp2_async_executor_bitwise():
    """The pipelined executor composes with tensor parallelism: both
    streams dispatch as shard_map jits over the KV-head-sharded pools,
    the overlap-window fetch-back restores onto sharded pools, and the
    async tp=2 engine stays bitwise-identical to the synchronous tp=1
    oracle — calm, under forced preemption, and with runahead + spill."""
    code = _COMMON + """
base = run()                                   # sync, tp=1: the oracle
mesh = make_serve_mesh(2)
pipe = run(mesh=mesh, executor="async")
assert_bitwise(base, pipe)
m = pipe.metrics()
assert m["tp"] == 2 and m["executor"] == "async"
assert m["plan_commits"] > 0 and m["overlap_iterations"] > 0

# forced preemption/resume: draft repairs recover, tokens stay bitwise
tight = run(mesh=mesh, n_pages=1 + 9, executor="async")
assert tight.scheduler.n_preemptions > 0
assert_bitwise(base, tight)

# runahead staging + spill fetch-back in the overlap window, sharded
ra = run(mesh=mesh, n_pages=1 + 9, runahead="nvr", spill=16,
         executor="async")
assert ra.scheduler.n_swap_outs > 0
assert_bitwise(base, ra)
ra.allocator.check_tier_invariants()
print("TP2_ASYNC_OK")
"""
    r = run_py(code, n_dev=2)
    assert r.returncode == 0, (r.stderr[-3000:], r.stdout[-500:])
    assert "TP2_ASYNC_OK" in r.stdout
