"""Event-driven engine: seed parity, registry, config, sweep runner,
and prefetcher invariants."""


import numpy as np
import pytest

from repro.core.nvr import (SimConfig, SimEngine, SweepSpec,
                            available_prefetchers, compile_trace,
                            get_prefetcher, make_trace, register_prefetcher,
                            run_modes, run_sweep, simulate)
from repro.core.nvr.engine.reference import (run_modes_reference,
                                             simulate_reference)
from repro.core.nvr.engine.result import SweepResult
from repro.core.nvr.prefetchers import NVR, Prefetcher
from repro.core.nvr.traces import WORKLOADS

ALL = list(WORKLOADS)

FIELDS = ("total", "base", "stall", "compute", "n_vloads", "demand_misses",
          "l2_accesses", "demand_offchip", "prefetch_offchip", "pf_issued",
          "pf_used", "nsb_hits")


def _tup(r):
    return tuple(getattr(r, f) for f in FIELDS)


class TestSeedParity:
    """The engine must reproduce the seed ``simulate()`` loop bit-exactly —
    not just totals but every counter — on all 8 Table-II workloads."""

    @pytest.mark.parametrize("wl", ALL)
    def test_all_modes_match_reference(self, wl):
        tr = make_trace(wl, dtype_bytes=2, scale=0.25)
        for a, b in zip(run_modes(tr, 2), run_modes_reference(tr, 2)):
            assert a.label == b.label
            assert _tup(a) == _tup(b), (wl, a.label)

    @pytest.mark.parametrize("wl", ["DS", "MK", "GAT"])
    def test_nsb_and_ablations_match_reference(self, wl):
        tr = make_trace(wl, dtype_bytes=4, scale=0.25)
        cases = [dict(prefetcher="nvr", nsb_kb=16),
                 dict(prefetcher="nvr", pf_kwargs={"scd": False}),
                 dict(prefetcher="nvr", pf_kwargs={"lbd": False}),
                 dict(prefetcher="nvr", pf_kwargs={"vmig": False}),
                 dict(prefetcher="dvr"),
                 dict(prefetcher="imp", nsb_kb=16)]
        for kw in cases:
            a = simulate(tr, "inorder", **kw)
            b = simulate_reference(tr, "inorder", **kw)
            assert _tup(a) == _tup(b), (wl, kw)

    def test_mode_and_prefetcher_are_separate_fields(self):
        tr = make_trace("DS", dtype_bytes=2, scale=0.1)
        r = simulate(tr, "inorder", prefetcher="nvr")
        assert r.mode == "inorder"          # the seed overwrote this
        assert r.prefetcher == "nvr"
        assert r.label == "nvr"
        base = simulate(tr, "inorder")
        assert base.prefetcher == "" and base.label == "inorder"


class TestConfigAndRegistry:
    def test_registry_has_builtins(self):
        assert {"stream", "imp", "dvr", "nvr"} <= set(
            available_prefetchers())
        assert get_prefetcher("nvr") is NVR

    def test_unknown_prefetcher_raises(self):
        with pytest.raises(KeyError):
            get_prefetcher("does-not-exist")
        with pytest.raises(KeyError):
            SimConfig(prefetcher="does-not-exist")

    def test_bad_mode_raises(self):
        with pytest.raises(ValueError):
            SimConfig(mode="speculative")

    def test_custom_prefetcher_registers_and_runs(self):
        @register_prefetcher("test-noop")
        class NoOp(Prefetcher):
            pass

        try:
            tr = make_trace("SCN", dtype_bytes=2, scale=0.1)
            r = simulate(tr, "inorder", prefetcher="test-noop")
            base = simulate(tr, "inorder")
            # a no-op prefetcher still switches demand fetches to
            # line granularity (granule=1), so totals differ from the
            # rigid-DMA baseline but the run must be well-formed
            assert r.total > 0 and r.pf_issued == 0
            assert base.total > 0
        finally:
            from repro.core.nvr.engine.registry import _REGISTRY
            _REGISTRY.pop("test-noop", None)

    def test_nvr_nsb_defaults_fill_nsb(self):
        cfg = SimConfig(prefetcher="nvr", nsb_kb=16)
        assert cfg.build_prefetcher().fill_nsb
        cfg2 = SimConfig(prefetcher="nvr", nsb_kb=0)
        assert not cfg2.build_prefetcher().fill_nsb


class TestVecTrace:
    def test_compile_matches_ops(self):
        tr = make_trace("GCN", dtype_bytes=2, scale=0.25)
        vt = compile_trace(tr)
        assert vt.n_ops == len(tr.ops)
        assert vt.n_vloads == tr.n_vloads
        assert vt.total_compute == pytest.approx(tr.total_compute())
        # unique-line arrays match the seed's np.unique per op
        from repro.core.nvr.machine import LINE_BYTES
        for i, op in enumerate(tr.ops):
            if not hasattr(op, "addrs"):
                continue
            want = np.unique(op.addrs // LINE_BYTES)
            np.testing.assert_array_equal(np.array(vt.lines[i]), want)
        assert vt.lines_flat.size == int(vt.lines_off[-1])

    def test_compile_is_cached(self):
        tr = make_trace("ST", dtype_bytes=2, scale=0.1)
        assert compile_trace(tr) is compile_trace(tr)

    def test_line_reuse_positive(self):
        tr = make_trace("H2O", dtype_bytes=2, scale=0.25)
        vt = compile_trace(tr)
        assert vt.footprint_lines() > 0
        assert vt.line_reuse() > 1.0   # H2O has a stable hot set


class TestEvents:
    def test_subscribers_fire(self):
        tr = make_trace("DS", dtype_bytes=2, scale=0.1)
        eng = SimEngine(SimConfig(mode="inorder", prefetcher="nvr"))
        seen = {"vload": 0, "miss": 0, "retire": 0}
        for ev in seen:
            eng.subscribe(ev, lambda i, now, _ev=ev: seen.__setitem__(
                _ev, seen[_ev] + 1))
        r = eng.run(tr)
        assert seen["vload"] == r.n_vloads
        assert seen["retire"] == len(tr.ops)
        assert 0 < seen["miss"] <= r.demand_misses
        # observers must not perturb the simulation
        r2 = SimEngine(SimConfig(mode="inorder", prefetcher="nvr")).run(tr)
        assert _tup(r) == _tup(r2)


class TestPrefetcherInvariants:
    @pytest.mark.parametrize("wl", ["MK", "GAT"])
    def test_nvr_coverage_at_least_dvr(self, wl):
        """Exact loop bounds (LBD) must not lose coverage vs the
        boundary-blind DVR runahead on deep-chain workloads."""
        tr = make_trace(wl, dtype_bytes=2, scale=0.5)
        rs = {r.label: r for r in run_modes(tr, 2)}
        assert rs["nvr"].coverage >= rs["dvr"].coverage

    def test_stream_accuracy_below_nvr_on_ds(self):
        """Stride prediction mispredicts the DS TopK gather targets;
        SCD-computed addresses must be strictly more accurate."""
        tr = make_trace("DS", dtype_bytes=2, scale=0.5)
        rs = {r.label: r for r in run_modes(tr, 2)}
        assert rs["stream"].accuracy < rs["nvr"].accuracy


class TestSweepRunner:
    def test_grid_shape_and_artifacts(self, tmp_path):
        from repro.core.nvr.engine.sweep import write_sweep

        spec = SweepSpec(workloads=("SCN", "ST"), dtypes=(2,),
                         points=("inorder", "nvr"), nsb_kbs=(0, 16),
                         scale=0.1)
        res = run_sweep(spec)
        assert len(res.rows) == spec.grid_size() == 2 * 1 * 2 * 2
        # coverage annotated against the cell's inorder baseline
        nvr_rows = [r for r in res.rows if r.label == "nvr"]
        assert all(np.isfinite(r.coverage) for r in nvr_rows)
        paths = write_sweep(res, str(tmp_path), name="t")
        csv = open(paths["csv"]).read().splitlines()
        assert csv[0].startswith("workload,mode,prefetcher,")
        assert len(csv) == 1 + len(res.rows)
        import json
        blob = json.loads(open(paths["json"]).read())
        assert len(blob["rows"]) == len(res.rows)
        assert blob["rows"][0]["label"] in ("inorder", "nvr")

    def test_parallel_matches_serial(self):
        spec = SweepSpec(workloads=("MK", "SCN"), dtypes=(1, 2),
                         points=("inorder", "dvr"), nsb_kbs=(0,),
                         scale=0.1)
        a = [(r.workload, r.dtype_bytes, r.label, r.total)
             for r in run_sweep(spec, workers=1).rows]
        b = [(r.workload, r.dtype_bytes, r.label, r.total)
             for r in run_sweep(spec, workers=2).rows]
        assert a == b

    def test_sweepresult_csv_has_separate_columns(self):
        tr = make_trace("ST", dtype_bytes=2, scale=0.1)
        res = SweepResult()
        res.add(simulate(tr, "inorder", prefetcher="nvr", dtype_bytes=2))
        line = res.csv().splitlines()[1]
        cells = line.split(",")
        assert cells[1] == "inorder" and cells[2] == "nvr"


def test_engine_faster_than_reference():
    """Smoke-level speed check (the real measurement lives in
    benchmarks/run.py engine_speedup): the engine must beat the frozen
    seed loop on a mid-size sweep even with cold compiles."""
    import time

    traces = [make_trace(wl, dtype_bytes=2, scale=0.25) for wl in ALL]
    t0 = time.perf_counter()
    for tr in traces:
        run_modes_reference(tr, 2)
    t_ref = time.perf_counter() - t0
    t0 = time.perf_counter()
    for tr in traces:
        run_modes(tr, 2)
    t_eng = time.perf_counter() - t0
    assert t_eng < t_ref, (t_eng, t_ref)
