"""Multi-device tests (subprocess: device count is fixed at jax init)."""

import os
import subprocess
import sys

import pytest

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

_requires_explicit_sharding = pytest.mark.skipif(
    not hasattr(__import__("jax").sharding, "AxisType"),
    reason="needs the jax>=0.5 explicit-sharding API (AxisType/set_mesh); "
           "gated on older jax")


def run_py(code: str, n_dev: int = 8, timeout: int = 300):
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={n_dev}"
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    return subprocess.run([sys.executable, "-c", code], env=env,
                          capture_output=True, text=True, timeout=timeout)


@pytest.mark.slow
@_requires_explicit_sharding
def test_distributed_sparse_decode_exact():
    r = run_py(
        "import runpy, sys; sys.argv=['x'];"
        f"runpy.run_path('{ROOT}/examples/long_context_decode.py',"
        "run_name='__main__')")
    assert r.returncode == 0, r.stderr[-2000:]
    assert "full-coverage distributed decode == exact attention" in r.stdout


@pytest.mark.slow
@_requires_explicit_sharding
def test_sharded_train_step_on_host_mesh():
    code = """
import jax, numpy as np
from repro.configs import get_config
from repro.data import pipeline
from repro.train import trainer
from repro.launch import mesh as meshlib

cfg = get_config("qwen2-1.5b").reduced()
mesh = meshlib.make_host_mesh(2, 2, pod=2)   # 2x2x2 = 8 devices, 3 axes
dcfg = pipeline.DataConfig(vocab=cfg.vocab, seq_len=64, global_batch=8)
tc = trainer.TrainConfig(steps=4, log_every=100, remat="none")
it = ((s, {"tokens": t, "labels": l})
      for s, (t, l) in pipeline.batches(dcfg))
with jax.set_mesh(mesh):
    state, hist = trainer.run(cfg, tc, it, mesh=mesh)
losses = [h["loss"] for h in hist]
assert all(np.isfinite(losses)), losses
assert losses[-1] < losses[0] + 0.5
print("SHARDED_OK", losses[0], losses[-1])
"""
    r = run_py(code)
    assert r.returncode == 0, (r.stderr[-3000:], r.stdout[-500:])
    assert "SHARDED_OK" in r.stdout


@pytest.mark.slow
@_requires_explicit_sharding
def test_compressed_psum_matches_exact():
    code = """
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import PartitionSpec as P
from repro.optim import compress

mesh = jax.make_mesh((8,), ("pod",),
                     axis_types=(jax.sharding.AxisType.Auto,))
g = jnp.arange(64.0).reshape(8, 8) / 7.0
err = jnp.zeros((8, 8), jnp.float32)

def f(g, err):
    return compress.compressed_psum({"g": g}, {"g": err}, "pod")

out = jax.shard_map(f, mesh=mesh, in_specs=(P("pod"), P("pod")),
                    out_specs=(P("pod"), P("pod")), check_vma=False)(g, err)
red = np.asarray(out[0]["g"])
want = np.broadcast_to(np.asarray(g).mean(0, keepdims=True), (8, 8))
np.testing.assert_allclose(red, want, rtol=2e-2, atol=2e-2)
print("COMPRESS_OK")
"""
    r = run_py(code)
    assert r.returncode == 0, (r.stderr[-3000:], r.stdout[-500:])
    assert "COMPRESS_OK" in r.stdout
