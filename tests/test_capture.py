"""Trace capture: serving/model traffic -> simulator round trip."""

import numpy as np
import pytest

from repro.core.nvr import capture, run_modes
from repro.core.nvr.trace import Compute, VLoad


class TestPageStream:
    def test_record_and_shape(self):
        st = capture.PageStream("t", n_rows=64, row_bytes=128,
                                compute_per_row=2.0)
        st.record([3, 1, 2])
        st.record_batched(np.arange(12).reshape(2, 2, 3))
        assert st.n_events == 5
        assert st.rows_selected == 3 + 4 * 3

    def test_empty_event_dropped(self):
        st = capture.PageStream("t", n_rows=8, row_bytes=64,
                                compute_per_row=1.0)
        st.record(np.array([], dtype=np.int64))
        assert st.n_events == 0
        with pytest.raises(ValueError):
            st.to_trace()

    def test_record_batched_drops_empty_rows_like_record(self):
        """Regression: record() drops empty selections but
        record_batched() used to append them, poisoning to_trace with
        zero-length events."""
        a = capture.PageStream("a", n_rows=8, row_bytes=64,
                               compute_per_row=1.0)
        b = capture.PageStream("b", n_rows=8, row_bytes=64,
                               compute_per_row=1.0)
        empty = np.zeros((2, 3, 0), dtype=np.int64)
        a.record_batched(empty, rid=1, step=2)
        for _ in range(2 * 3):
            b.record(np.zeros((0,), dtype=np.int64), rid=1, step=2)
        assert a.n_events == b.n_events == 0
        assert a.rids == b.rids == []
        # non-empty rows still recorded, tags intact
        a.record_batched(np.arange(6).reshape(2, 3), rid=4, step=5)
        assert a.n_events == 2 and a.rids == [4, 4]
        a.to_trace()                         # lowers clean

    def test_to_trace_bundle_shape(self):
        st = capture.PageStream("t", n_rows=32, row_bytes=256,
                                compute_per_row=2.0)
        st.record([5, 1, 9])
        st.record([2, 5])
        tr = st.to_trace()
        kinds = [type(op) for op in tr.ops]
        assert kinds.count(Compute) == 2
        vloads = [op for op in tr.ops if isinstance(op, VLoad)]
        assert any(op.kind == "stream" for op in vloads)
        gathers = [op for op in vloads if op.kind == "indirect"]
        # 256B rows -> 4 line-slices per gathered row group
        assert gathers and all(tr.is_indirect_addr(int(g.addrs[0]))
                               for g in gathers)
        # bounds separate the two events (plus builder's initial bound)
        assert len({op.bound_id for op in vloads}) == 2


class TestMoEAdapter:
    def test_routing_becomes_expert_tiles(self):
        rng = np.random.default_rng(0)
        eids = rng.choice(8, p=[.35, .25, .15, .1, .06, .04, .03, .02],
                          size=400)
        st = capture.moe_expert_stream(eids, n_experts=8, d_model=128,
                                       d_ff=256)
        assert st.n_rows == 8 * 256
        # block counts follow the routing histogram
        counts = np.bincount(eids, minlength=8)
        want_blocks = sum(-(-int(c) // 16) for c in counts)
        assert st.n_events == want_blocks
        # every recorded row belongs to one expert's weight slab
        for ev in st.events:
            assert len({int(r) // 256 for r in ev}) == 1

    def test_small_dff_stays_in_expert_slab(self):
        """Regression: with d_ff <= tile_rows the unclamped tile spilled
        into the next expert's rows (and past n_rows for the last
        expert)."""
        eids = np.repeat(np.arange(4), 40)       # every expert routed
        st = capture.moe_expert_stream(eids, n_experts=4, d_model=64,
                                       d_ff=16, tile_rows=32)
        assert st.n_rows == 4 * 16
        for ev in st.events:
            experts = {int(r) // 16 for r in ev}
            assert len(experts) == 1             # one expert's slab only
            assert ev.min() >= 0 and ev.max() < st.n_rows

    def test_tile_never_exceeds_table(self):
        for d_ff in (8, 32, 33, 256):
            st = capture.moe_expert_stream(np.zeros(100), n_experts=2,
                                           d_model=32, d_ff=d_ff,
                                           tile_rows=32)
            for ev in st.events:
                assert ev.max() < st.n_rows
                assert len(ev) == min(32, d_ff)

    def test_topk_matrix_counts_every_pair(self):
        """Regression: a ``[T, k]`` top-k routing matrix is the same
        traffic as its ``T*k`` flattened top-1 view — each (token,
        expert) pair demands its expert's weights once."""
        rng = np.random.default_rng(3)
        topk = rng.integers(0, 8, size=(100, 2))
        st2 = capture.moe_expert_stream(topk, n_experts=8, d_model=64,
                                        d_ff=128)
        st1 = capture.moe_expert_stream(topk.reshape(-1), n_experts=8,
                                        d_model=64, d_ff=128)
        assert st2.n_events == st1.n_events
        for a, b in zip(st2.events, st1.events):
            np.testing.assert_array_equal(a, b)

    def test_bad_expert_ids_rejected(self):
        with pytest.raises(ValueError, match="top-1 or"):
            capture.moe_expert_stream(np.zeros((2, 3, 4)), n_experts=4,
                                      d_model=32, d_ff=64)
        with pytest.raises(ValueError, match="must lie in"):
            capture.moe_expert_stream(np.array([0, 4]), n_experts=4,
                                      d_model=32, d_ff=64)
        with pytest.raises(ValueError, match="must lie in"):
            capture.moe_expert_stream(np.array([[0, -1]]), n_experts=4,
                                      d_model=32, d_ff=64)

    def test_nvr_covers_routed_traffic(self):
        rng = np.random.default_rng(1)
        eids = rng.choice(4, p=[.5, .3, .15, .05], size=256)
        tr = capture.moe_expert_stream(eids, n_experts=4, d_model=128,
                                       d_ff=256).to_trace()
        rs = {r.label: r for r in run_modes(tr, 2)}
        assert rs["nvr"].demand_misses < rs["inorder"].demand_misses


class TestPageCache:
    def test_lru_semantics_match_hotset(self):
        """The shared-Cache page model must behave exactly like the old
        ad-hoc HotSet LRU (capacity-bounded, recency on touch)."""
        from collections import OrderedDict

        class HotSet:  # the seed's implementation, inlined as the oracle
            def __init__(self, capacity):
                self.capacity = capacity
                self.lru = OrderedDict()

            def touch(self, page):
                hit = page in self.lru
                if hit:
                    self.lru.move_to_end(page)
                else:
                    self.lru[page] = True
                    if len(self.lru) > self.capacity:
                        self.lru.popitem(last=False)
                return hit

        rng = np.random.default_rng(2)
        pages = rng.zipf(1.5, size=500) % 37
        pc = capture.PageCache(8)
        hs = HotSet(8)
        for p in pages:
            assert pc.touch(int(p)) == hs.touch(int(p)), p
        assert pc.stats.hits + pc.stats.misses == len(pages)


class TestPageStreamTags:
    def test_tags_default_untagged(self):
        st = capture.PageStream("t", n_rows=16, row_bytes=64,
                                compute_per_row=1.0)
        st.record([1, 2])
        assert st.rids == [-1] and st.steps == [-1]
        assert st.request_ids() == []

    def test_per_request_views(self):
        st = capture.PageStream("t", n_rows=16, row_bytes=64,
                                compute_per_row=1.0)
        st.record([1, 2], rid=7, step=0)
        st.record([3], rid=9, step=0)
        st.record([4, 5], rid=7, step=1)
        assert st.request_ids() == [7, 9]
        assert [s for s, _ in st.events_for(7)] == [0, 1]
        sub = st.subset(7)
        assert sub.n_events == 2 and sub.rows_selected == 4
        assert sub.n_rows == st.n_rows          # same table address space
        spans = st.interleave_spans()
        assert spans[7] == (0, 2) and spans[9] == (1, 1)

    def test_shard_tags_and_views(self):
        st = capture.PageStream("t", n_rows=16, row_bytes=64,
                                compute_per_row=1.0)
        st.record([1, 2], rid=0, step=0, shard=0)
        st.record([3, 4], rid=0, step=0, shard=1)
        st.record([1, 5], rid=1, step=1, shard=0)
        st.record([6], rid=1, step=1)           # untagged rides along
        assert st.shard_ids() == [0, 1]
        s0 = st.subset_shard(0)
        assert s0.n_events == 2 and s0.rows_selected == 4
        assert s0.rids == [0, 1]                # request tags preserved
        assert s0.n_rows == st.n_rows           # one global page-id space
        # per-request views keep shard attribution too
        assert st.subset(0).shards == [0, 1]
        assert st.subset(1).shards == [0, -1]
        # lists stay parallel (to_trace / merge invariants)
        assert len(st.shards) == len(st.events) == len(st.rids)


class TestShardedNSB:
    def test_per_shard_caches_are_independent(self):
        spc = capture.ShardedPageCache(2, capacity_pages=4)
        assert not spc.touch(3, 0)              # miss fills shard 0 only
        assert spc.touch(3, 0)                  # shard-0 hit
        assert not spc.touch(3, 1)              # shard 1 never saw page 3
        roll = spc.rollup()
        assert roll["hits"] == 1 and roll["misses"] == 2
        assert roll["per_shard"][0] == 0.5 and roll["per_shard"][1] == 0.0
        assert roll["hit_rate"] == pytest.approx(1 / 3)

    def test_rollup_replays_shard_tagged_stream(self):
        st = capture.PageStream("t", n_rows=32, row_bytes=64,
                                compute_per_row=1.0)
        for step in range(4):                   # heavy reuse per shard
            st.record([1, 2, 3], shard=0, step=step)
            st.record([9, 10], shard=1, step=step)
        roll = capture.nsb_shard_rollup(st, nsb_pages=8, n_shards=2)
        # first touch of each page misses, every revisit hits
        assert roll["misses"] == 5
        assert roll["hits"] == 3 * 3 + 2 * 3
        assert len(roll["per_shard"]) == 2
        # untagged streams degrade to one shard (the single-NPU case)
        st1 = capture.PageStream("u", n_rows=8, row_bytes=64,
                                 compute_per_row=1.0)
        st1.record([1, 2])
        st1.record([1, 2])
        roll1 = capture.nsb_shard_rollup(st1, nsb_pages=4)
        assert roll1["per_shard"] == [0.5]

    def test_rollup_dedups_within_event_only(self):
        st = capture.PageStream("t", n_rows=8, row_bytes=64,
                                compute_per_row=1.0)
        st.record([5, 5, 5], shard=0)           # one demand, not three
        roll = capture.nsb_shard_rollup(st, nsb_pages=4, n_shards=1)
        assert roll["hits"] == 0 and roll["misses"] == 1


@pytest.mark.slow
class TestMultiRequestRoundTrip:
    """Acceptance: multi-tenant captured traffic — per-request streams
    interleave, and the lowered Trace replays under nvr with miss
    reduction at least as good as the single-request case."""

    @pytest.fixture(scope="class")
    def engine_run(self):
        import jax

        from repro.configs import get_config
        from repro.models import api
        from repro.serve.engine import PagedEngine

        cfg = get_config("qwen2-1.5b").reduced()
        params = api.init_params(cfg, jax.random.PRNGKey(0))
        rng = np.random.default_rng(3)
        work = [(float(i) * 0.7,
                 rng.integers(1, cfg.vocab, size=int(rng.integers(10, 22))),
                 8) for i in range(4)]
        eng = PagedEngine(cfg, params, max_len=48, max_batch=4, chunk=8,
                          nsb_pages=32, capture_trace=True)
        eng.run(work)
        return eng

    def test_per_request_streams_interleave(self, engine_run):
        st = engine_run.recorder
        rids = st.request_ids()
        assert len(rids) == 4
        # every request's events arrive in scheduler order
        for rid in rids:
            steps = [s for s, _ in st.events_for(rid)]
            assert steps == sorted(steps)
        # concurrent requests overlap in the recorded order: each span
        # must overlap at least one other request's span
        spans = st.interleave_spans()
        for rid, (lo, hi) in spans.items():
            assert any(o_lo <= hi and lo <= o_hi
                       for o, (o_lo, o_hi) in spans.items() if o != rid)

    def test_multi_tenant_nvr_reduction_ge_single(self, engine_run):
        st = engine_run.recorder

        def reduction(trace):
            rs = {r.label: r for r in run_modes(trace, 2)}
            assert rs["inorder"].demand_misses > 0
            return 1 - rs["nvr"].demand_misses / rs["inorder"].demand_misses

        multi = reduction(st.to_trace())
        singles = [reduction(st.subset(rid).to_trace())
                   for rid in st.request_ids()]
        assert multi >= max(singles) - 1e-9
        assert multi > 0.5      # NVR must actually help on real traffic

    def test_physical_ids_within_pool(self, engine_run):
        st = engine_run.recorder
        top = engine_run.n_pages
        for ev in st.events:
            assert ev.min() >= 1 and ev.max() < top   # page 0 never read


@pytest.mark.slow
class TestServeRoundTrip:
    """Acceptance: a serving-engine decode run yields a Trace whose
    run_modes() results show nvr demand-miss reduction vs inorder."""

    @pytest.fixture(scope="class")
    def engine_run(self):
        import jax

        from repro.configs import get_config
        from repro.configs.base import ShapeCell
        from repro.models import api
        from repro.serve.engine import Engine

        cfg = get_config("qwen2-1.5b").reduced()
        key = jax.random.PRNGKey(1)
        params = api.init_params(cfg, key)
        batch = api.make_inputs(cfg, ShapeCell("s", 32, 2, "prefill"), key)
        eng = Engine(cfg, params, max_len=64, sparse=True, nsb_pages=32,
                     capture_trace=True)
        eng.generate(batch, 16)
        return eng

    def test_capture_simulate_roundtrip(self, engine_run):
        tr = engine_run.captured_trace()
        assert tr.n_vloads > 0
        rs = {r.label: r for r in run_modes(tr, 2)}
        assert rs["inorder"].demand_misses > 0
        assert rs["nvr"].demand_misses < rs["inorder"].demand_misses
        assert rs["nvr"].total < rs["inorder"].total

    def test_nsb_accounting_on_shared_cache(self, engine_run):
        s = engine_run.stats
        assert s.pages_touched > 0
        # decode TopK selections exhibit strong temporal reuse (the
        # paper's premise for the NSB) — now measured by the shared
        # machine.Cache model instead of the ad-hoc HotSet
        assert s.hot_hit_rate > 0.5
        assert engine_run.hot.stats.hits == s.nsb_hits
