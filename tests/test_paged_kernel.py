"""Paged sparse-decode Pallas kernel vs the XLA oracle, and the engine
kernel switch.

``kernels.paged_decode_attn`` consumes the serve layer's native layout
(physical page pools + block-table-resolved TopK page ids); its
correctness contract is ``sparse_attention.attend_pages_paged`` — the
XLA path the continuous-batching engine uses on CPU and pins its
bitwise-resume guarantees to.  Parity here is tolerance-based: the
kernel runs an fp32 online softmax (streaming max/sum), the oracle
normalises the materialised gather once.
"""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import paged_decode_attn
from repro.models import sparse_attention

RNG = np.random.default_rng(1234)


def _pool_case(r, kv, g, d, n_pages, n_logical, k_sel, page,
               pool_dtype=jnp.float32, short_row=True, shared_rows=False):
    """Synthetic pool + block tables in the allocator's conventions:
    page 0 reserved (NULL), per-request tables over physical ids,
    per-request frontiers, TopK selection by the real scorer."""
    q = jnp.asarray(RNG.normal(size=(r, kv, g, d)), jnp.float32)
    kp = sparse_attention.kv_quant(
        jnp.asarray(RNG.normal(size=(n_pages, page, kv, d)), jnp.float32),
        pool_dtype)
    vp = sparse_attention.kv_quant(
        jnp.asarray(RNG.normal(size=(n_pages, page, kv, d)), jnp.float32),
        pool_dtype)
    spool = jnp.asarray(RNG.normal(size=(n_pages, kv, d)), jnp.float32)
    bt = np.zeros((r, n_logical), np.int32)
    for i in range(r):
        bt[i] = RNG.choice(np.arange(1, n_pages), size=n_logical,
                           replace=False)
    if shared_rows and r >= 2:
        # COW-style sharing: rows 0/1 share their prompt pages but own
        # private tails (the prefix-cache layout)
        bt[1, :n_logical - 1] = bt[0, :n_logical - 1]
    pos = RNG.integers(page, n_logical * page, size=r).astype(np.int32)
    if short_row:
        # fewer valid pages than the TopK budget: the selection pads
        # with frontier-masked slots (and NULL physical ids via bt)
        pos[0] = page // 2
    n_valid = jnp.asarray(pos) // page + 1
    idx, phys = sparse_attention.select_pages_blocktable(
        q, spool, jnp.asarray(bt), n_valid, k_sel)
    return q, kp, vp, idx, phys, jnp.asarray(pos)


@pytest.mark.parametrize("page", [8, 16])
@pytest.mark.parametrize("r,kv,g,d,k_sel", [
    (4, 2, 2, 32, 4),
    (2, 2, 6, 64, 3),     # wide GQA group
    (3, 1, 1, 32, 2),     # MQA, single-head group
])
def test_paged_kernel_matches_xla_oracle(page, r, kv, g, d, k_sel):
    q, kp, vp, idx, phys, pos = _pool_case(
        r, kv, g, d, n_pages=24, n_logical=8, k_sel=k_sel, page=page)
    want = sparse_attention.attend_pages_paged(q, kp, vp, idx, phys,
                                               pos, page)
    got = paged_decode_attn(phys, idx, pos, q, kp, vp, page_size=page,
                            interpret=True)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("pool_dtype", [jnp.bfloat16, jnp.int8])
def test_paged_kernel_pool_dtypes(pool_dtype):
    q, kp, vp, idx, phys, pos = _pool_case(
        3, 2, 2, 32, n_pages=16, n_logical=6, k_sel=3, page=8,
        pool_dtype=pool_dtype)
    want = sparse_attention.attend_pages_paged(q, kp, vp, idx, phys,
                                               pos, 8)
    got = paged_decode_attn(phys, idx, pos, q, kp, vp, page_size=8,
                            interpret=True)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               rtol=1e-5, atol=1e-5)


def test_paged_kernel_null_padded_batch_row():
    """A padded batch slot (all-NULL block table, pos 0) must produce
    finite output matching the oracle, never NaNs."""
    r, kv, g, d, page = 2, 2, 2, 32, 8
    q, kp, vp, _, _, _ = _pool_case(r, kv, g, d, n_pages=16, n_logical=6,
                                    k_sel=3, page=page, short_row=False)
    bt = np.zeros((r, 6), np.int32)
    bt[0] = RNG.choice(np.arange(1, 16), size=6, replace=False)
    pos = jnp.asarray([2 * page + 1, 0], jnp.int32)
    n_valid = pos // page + 1
    spool = jnp.asarray(RNG.normal(size=(16, kv, d)), jnp.float32)
    idx, phys = sparse_attention.select_pages_blocktable(
        q, spool, jnp.asarray(bt), n_valid, 3)
    want = sparse_attention.attend_pages_paged(q, kp, vp, idx, phys,
                                               pos, page)
    got = paged_decode_attn(phys, idx, pos, q, kp, vp, page_size=page,
                            interpret=True)
    assert np.isfinite(np.asarray(got, np.float32)).all()
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               rtol=1e-5, atol=1e-5)


def test_paged_kernel_cow_shared_block_tables():
    """Two requests whose block tables share physical prompt pages (the
    prefix-cache COW layout) attend through the same pool bytes; each
    row's output must still match the oracle independently."""
    q, kp, vp, idx, phys, pos = _pool_case(
        2, 2, 2, 32, n_pages=16, n_logical=4, k_sel=3, page=8,
        short_row=False, shared_rows=True)
    assert len(set(np.asarray(phys[0]).ravel())
               & set(np.asarray(phys[1]).ravel())) > 0
    want = sparse_attention.attend_pages_paged(q, kp, vp, idx, phys,
                                               pos, 8)
    got = paged_decode_attn(phys, idx, pos, q, kp, vp, page_size=8,
                            interpret=True)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               rtol=1e-5, atol=1e-5)


@pytest.mark.slow
class TestEngineKernelSwitch:
    """PagedEngine(kernel="pallas") vs the XLA path on the shared-prefix
    multi-tenant workload (the TestPrefixCacheEngine fixture shape):
    same tokens greedily decoded, logits within fp32 online-softmax
    tolerance, across page-size {8,16} pool geometries, NULL-padded rows
    and COW-shared block tables."""

    @pytest.fixture(scope="class")
    def setup(self):
        import jax

        from repro.configs import get_config
        from repro.models import api

        cfg = get_config("qwen2-1.5b").reduced()
        params = api.init_params(cfg, jax.random.PRNGKey(0))
        rng = np.random.default_rng(11)
        sys_prompts = [rng.integers(1, cfg.vocab, size=12)
                       for _ in range(2)]
        work = []
        for i in range(4):
            suffix = rng.integers(1, cfg.vocab,
                                  size=int(rng.integers(2, 6)))
            prompt = np.concatenate([sys_prompts[i % 2], suffix])
            work.append((float(i) * 0.5, prompt, 4))
        return cfg, params, work

    def _run(self, cfg, params, work, kernel):
        from repro.serve.engine import PagedEngine

        eng = PagedEngine(cfg, params, max_len=48, max_batch=4, chunk=8,
                          nsb_pages=32, kernel=kernel)
        eng.run([(t, p.copy(), g) for t, p, g in work])
        return eng

    @pytest.mark.parametrize("kv_page", [8, 16])
    def test_pallas_engine_matches_xla_engine(self, setup, kv_page):
        from dataclasses import replace

        cfg, params, work = setup
        cfg = replace(cfg, kv_page=kv_page)
        xla = self._run(cfg, params, work, "xla")
        pal = self._run(cfg, params, work, "pallas")
        if kv_page == 8:
            # 12-token system prompts fill a whole page only at page=8:
            # that geometry exercises COW-shared block tables
            assert pal.allocator.stats.prefix_hits > 0
        for rid in xla.requests:
            a, b = xla.requests[rid], pal.requests[rid]
            assert a.out_tokens == b.out_tokens
            np.testing.assert_allclose(a.last_logits, b.last_logits,
                                       rtol=2e-5, atol=2e-5)

    def test_bitwise_resume_stays_on_xla_path(self, setup):
        """The preemption bitwise-resume contract is pinned to the XLA
        oracle: the default engine kernel must remain "xla"."""
        from repro.serve.engine import PagedEngine

        cfg, params, _ = setup
        eng = PagedEngine(cfg, params, max_len=48, max_batch=2, chunk=8)
        assert eng.kernel == "xla"

    def test_rejects_unknown_kernel(self, setup):
        from repro.serve.engine import PagedEngine

        cfg, params, _ = setup
        with pytest.raises(ValueError):
            PagedEngine(cfg, params, max_len=48, kernel="cuda")
