"""Unit + paper-claim tests for the NVR simulator (paper-faithful layer)."""

import statistics

import numpy as np
import pytest

from repro.core.nvr import (Cache, DRAM, LINE_BYTES, make_trace,
                            run_modes, simulate)
from repro.core.nvr.traces import WORKLOADS

ALL = list(WORKLOADS)


class TestCache:
    def test_hit_after_fill(self):
        c = Cache(64 * 1024, ways=8, hit_latency=20.0)
        c.fill(123, ready=5.0)
        assert c.probe(123, now=10.0) == pytest.approx(30.0)
        assert c.stats.hits == 1

    def test_miss_is_none(self):
        c = Cache(64 * 1024, ways=8, hit_latency=20.0)
        assert c.probe(7, now=0.0) is None
        assert c.stats.demand_misses == 1

    def test_lru_eviction(self):
        c = Cache(8 * LINE_BYTES, ways=2, hit_latency=1.0)  # 4 sets x 2 ways
        s = c.num_sets
        a, b, d = 0, s, 2 * s          # all map to set 0
        for line in (a, b):
            c.fill(line, 0.0)
            c.probe(line, 1.0)
        c.fill(d, 2.0)
        c.drain(3.0)
        assert c.probe(a, 4.0) is None          # a was LRU -> evicted
        assert c.probe(b, 5.0) is not None

    def test_mshr_coalescing(self):
        c = Cache(64 * 1024, ways=8, hit_latency=2.0)
        c.fill(9, ready=100.0)
        t = c.probe(9, now=10.0)      # in flight: coalesced, waits
        assert t == pytest.approx(102.0)
        assert c.stats.coalesced == 1
        assert c.stats.demand_misses == 0

    def test_prefetch_accounting(self):
        c = Cache(64 * 1024, ways=8, hit_latency=2.0)
        c.fill(5, ready=1.0, prefetch=True)
        assert c.stats.prefetch_fills == 1
        c.probe(5, now=10.0)
        assert c.stats.prefetch_used == 1


class TestDRAM:
    def test_bandwidth_queuing(self):
        d = DRAM(latency=100.0, bytes_per_cycle=16.0)
        t1 = d.fetch(0.0)             # 64B -> 4 cycles occupancy
        t2 = d.fetch(0.0)
        assert t1 == pytest.approx(104.0)
        assert t2 == pytest.approx(108.0)   # queued behind the first
        assert d.bytes_transferred == 128


@pytest.mark.parametrize("wl", ALL)
def test_workload_traces_deterministic(wl):
    t1 = make_trace(wl, dtype_bytes=2, scale=0.25)
    t2 = make_trace(wl, dtype_bytes=2, scale=0.25)
    assert t1.n_vloads == t2.n_vloads > 0
    a1 = [op.addrs for op in t1.ops if hasattr(op, "addrs")]
    a2 = [op.addrs for op in t2.ops if hasattr(op, "addrs")]
    np.testing.assert_array_equal(np.concatenate(a1), np.concatenate(a2))


@pytest.mark.parametrize("wl", ALL)
def test_prefetchers_never_corrupt_metrics(wl):
    tr = make_trace(wl, dtype_bytes=2, scale=0.25)
    for r in run_modes(tr, 2):
        assert r.total > 0
        assert r.stall >= 0 or r.mode == "dense"
        assert r.demand_misses >= 0


class TestPaperClaims:
    """Soft quantitative checks against the paper's headline numbers
    (tolerances documented in EXPERIMENTS.md §Paper-claims)."""

    @pytest.fixture(scope="class")
    def results(self):
        out = {}
        for wl in ALL:
            tr = make_trace(wl, dtype_bytes=2, scale=0.5)
            out[wl] = {r.label: r for r in run_modes(tr, 2)}
        return out

    def test_nvr_speedup_vs_no_prefetch(self, results):
        sp = [rs["inorder"].total / rs["nvr"].total
              for rs in results.values()]
        g = statistics.geometric_mean(sp)
        assert g > 3.0, f"paper ~4x, got {g:.2f}x"

    def test_miss_reduction_vs_sota(self, results):
        red = []
        for rs in results.values():
            best = min(rs["imp"].demand_misses, rs["dvr"].demand_misses)
            if best:
                red.append(1 - rs["nvr"].demand_misses / best)
        assert statistics.mean(red) > 0.75, "paper ~90%"

    def test_accuracy_coverage_above_90(self, results):
        acc = [rs["nvr"].accuracy for rs in results.values()
               if np.isfinite(rs["nvr"].accuracy)]
        cov = [rs["nvr"].coverage for rs in results.values()]
        assert statistics.mean(acc) > 0.9
        assert statistics.mean(cov) > 0.9

    def test_bandwidth_reduction(self, results):
        red = [1 - rs["nvr"].offchip / rs["inorder"].offchip
               for rs in results.values()]
        assert 0.55 < statistics.mean(red) < 0.95, "paper ~75%"

    def test_nvr_beats_all_baselines_on_misses(self, results):
        for wl, rs in results.items():
            for other in ("stream", "imp", "dvr"):
                assert rs["nvr"].demand_misses <= rs[other].demand_misses, \
                    f"{wl}: nvr vs {other}"

    def test_nsb_helps_nvr(self):
        gains = []
        for wl in ALL:
            tr = make_trace(wl, dtype_bytes=4, scale=0.5)
            nvr = simulate(tr, "inorder", prefetcher="nvr")
            nsb = simulate(tr, "inorder", prefetcher="nvr", nsb_kb=16)
            gains.append(1 - nsb.stall / nvr.stall)
        assert statistics.mean(gains) > 0.2, "paper ~40%"


def test_ooo_between_inorder_and_nvr():
    tr = make_trace("DS", dtype_bytes=2, scale=0.5)
    rs = {r.label: r for r in run_modes(tr, 2)}
    assert rs["nvr"].total < rs["ooo"].total < rs["inorder"].total


def test_nvr_component_ablation_ordering():
    """Beyond-paper ablation invariant: disabling the Sparse Chain
    Detector (indirect resolution) must hurt more than disabling the
    Loop Bound Detector, and both must be worse than full NVR."""
    import statistics
    sp = {"full": [], "no_scd": [], "no_lbd": []}
    for wl in ("DS", "GCN", "MK"):
        tr = make_trace(wl, dtype_bytes=2, scale=0.25)
        ino = simulate(tr, "inorder")
        for name, kw in (("full", {}), ("no_scd", {"scd": False}),
                         ("no_lbd", {"lbd": False})):
            r = simulate(tr, "inorder", prefetcher="nvr", pf_kwargs=kw)
            sp[name].append(ino.total / r.total)
    g = {k: statistics.geometric_mean(v) for k, v in sp.items()}
    assert g["no_scd"] < g["no_lbd"] < g["full"], g
