"""Continuous-batching serve layer: allocator, scheduler, paged engine."""

import dataclasses
import math

import numpy as np
import pytest

from repro.serve.kv_allocator import NULL_PAGE, KVBlockAllocator
from repro.serve.scheduler import (PoissonArrivals, Request, RequestState,
                                   Scheduler, TraceArrivals, bucket_for,
                                   row_buckets)


class TestAllocator:
    def test_page0_reserved(self):
        al = KVBlockAllocator(n_pages=8, page_tokens=4)
        assert al.capacity == 7
        handed = []
        for rid in range(7):
            assert al.ensure(rid, 4)
            handed += al.table(rid)
        assert NULL_PAGE not in handed
        assert sorted(handed) == list(range(1, 8))

    def test_ensure_grows_and_is_idempotent(self):
        al = KVBlockAllocator(n_pages=16, page_tokens=4)
        assert al.ensure(0, 1)
        assert al.owned(0) == 1
        assert al.ensure(0, 4)          # same page covers 4 tokens
        assert al.owned(0) == 1
        assert al.ensure(0, 5)
        assert al.owned(0) == 2
        assert al.pages_in_use == 2

    def test_all_or_nothing_failure(self):
        al = KVBlockAllocator(n_pages=4, page_tokens=4)   # 3 allocatable
        assert al.ensure(0, 8)          # 2 pages
        assert not al.ensure(1, 8)      # needs 2, only 1 free
        assert al.owned(1) == 0         # nothing partially allocated
        assert al.stats.alloc_failures == 1
        assert al.ensure(1, 4)          # 1 page still fits

    def test_free_and_reuse(self):
        al = KVBlockAllocator(n_pages=8, page_tokens=4)
        al.ensure(0, 12)
        pages = al.free_request(0)
        assert len(pages) == 3 and al.pages_free == 7
        al.ensure(1, 4)
        assert al.table(1)[0] == pages[0]   # LIFO: hot ids come back first

    def test_table_array_padding(self):
        al = KVBlockAllocator(n_pages=8, page_tokens=4)
        al.ensure(0, 8)
        bt = al.table_array(0, 6)
        assert bt.shape == (6,) and bt.dtype == np.int32
        assert list(bt[:2]) == al.table(0)
        assert all(bt[2:] == NULL_PAGE)


class TestPrefixCacheAllocator:
    def _prompt(self, n, base=100):
        return np.arange(base, base + n)

    def test_attach_shares_pages_and_refcounts(self):
        al = KVBlockAllocator(n_pages=16, page_tokens=4)
        p = self._prompt(10)                 # 2 full pages + 1 partial
        ok, cached = al.ensure_prompt(0, p)
        assert ok and cached == 0            # nothing registered yet
        al.register_prefix(0, p, 10)         # 2 full pages published
        free_before = al.pages_free
        ok, cached = al.ensure_prompt(1, p)
        assert ok and cached == 8            # both full pages attached
        assert al.table(1)[:2] == al.table(0)[:2]
        assert al.table(1)[2] != al.table(0)[2]     # partial page private
        assert al.refcount(al.table(0)[0]) == 2
        assert al.stats.prefix_hits == 2
        # only the private tail page was charged
        assert free_before - al.pages_free == 1

    def test_full_hit_cows_tail_page(self):
        al = KVBlockAllocator(n_pages=16, page_tokens=4)
        p = self._prompt(8)                  # exactly 2 pages
        al.ensure_prompt(0, p)
        al.register_prefix(0, p, 8)
        ok, cached = al.ensure_prompt(1, p)
        assert ok and cached == 8
        assert al.table(1)[0] == al.table(0)[0]
        assert al.table(1)[1] != al.table(0)[1]     # COW'd private copy
        assert al.stats.cow_copies == 1
        assert al.drain_copies() == [(al.table(0)[1], al.table(1)[1])]
        assert al.drain_copies() == []              # drained once
        assert al.refcount(al.table(0)[1]) == 1     # shared ref dropped

    def test_release_parks_registered_pages_in_lru(self):
        al = KVBlockAllocator(n_pages=8, page_tokens=4)
        p = self._prompt(8)
        al.ensure_prompt(0, p)
        al.register_prefix(0, p, 8)
        pages = list(al.table(0))
        al.free_request(0)
        assert al.pages_in_use == 0
        assert al.pages_cached == 2          # retained, not freed
        assert al.pages_free == al.capacity  # but still reclaimable
        # a later identical prompt re-attaches the cached pages
        ok, cached = al.ensure_prompt(1, p)
        assert ok and cached == 8
        assert al.table(1)[0] == pages[0]

    def test_lru_eviction_when_free_list_empty(self):
        al = KVBlockAllocator(n_pages=6, page_tokens=4)   # 5 allocatable
        a, b = self._prompt(4, 0), self._prompt(4, 50)
        al.ensure_prompt(0, a)
        al.register_prefix(0, a, 4)
        al.free_request(0)                   # page cached (LRU oldest)
        al.ensure_prompt(1, b)
        al.register_prefix(1, b, 4)
        al.free_request(1)                   # page cached (LRU newest)
        assert al.pages_cached == 2
        assert al.ensure(2, 20)              # 5 pages: must evict both
        assert al.stats.prefix_evictions == 2
        assert al.pages_cached == 0
        # the evicted content is gone from the index
        ok, cached = al.ensure_prompt(3, a)
        assert not ok and cached == 0        # pool exhausted, no attach

    def test_full_hit_degrades_when_cow_page_unavailable(self):
        """If every reclaimable page is one the prompt would attach, a
        full hit must degrade (attach one page fewer, prefill the tail)
        rather than spuriously refuse admission."""
        al = KVBlockAllocator(n_pages=4, page_tokens=4)   # 3 allocatable
        p = self._prompt(8)                  # exactly 2 pages
        al.ensure_prompt(0, p)
        al.register_prefix(0, p, 8)
        al.ensure(1, 4)                      # a bystander holds page 3
        al.free_request(0)                   # both prompt pages cached
        assert al.pages_free == 2
        ok, cached = al.ensure_prompt(2, p)
        assert ok and cached == 4            # first page attached...
        assert al.stats.cow_copies == 0      # ...tail prefills, not COWs
        assert al.owned(2) == 2
        assert al.stats.admission_blocks == 0

    def test_prefix_cache_disabled(self):
        al = KVBlockAllocator(n_pages=16, page_tokens=4, prefix_cache=False)
        p = self._prompt(8)
        al.ensure_prompt(0, p)
        assert al.register_prefix(0, p, 8) == 0
        ok, cached = al.ensure_prompt(1, p)
        assert ok and cached == 0
        assert set(al.table(0)).isdisjoint(al.table(1))
        al.free_request(0)
        assert al.pages_cached == 0

    def test_chain_key_is_position_sensitive(self):
        """The same page content at a different prefix depth must not
        attach (RoPE makes KV position-dependent)."""
        al = KVBlockAllocator(n_pages=16, page_tokens=4)
        p0 = np.array([1, 2, 3, 4, 5, 6, 7, 8])
        al.ensure_prompt(0, p0)
        al.register_prefix(0, p0, 8)
        # p1's first page equals p0's SECOND page content
        p1 = np.array([5, 6, 7, 8])
        ok, cached = al.ensure_prompt(1, p1)
        assert ok and cached == 0
        assert al.table(1)[0] not in al.table(0)


class TestPercentile:
    """``engine.percentile`` is the documented nearest-rank (ceil-rank)
    definition: the ceil(q*n)-th order statistic, 1-indexed — numpy's
    ``inverted_cdf``.  The old round()-based form banker's-rounded .5
    ranks upward (p50 of 4 samples gave the 3rd order statistic)."""

    def test_p50_of_four_is_second_order_statistic(self):
        from repro.serve.engine import percentile

        xs = [4.0, 1.0, 3.0, 2.0]
        assert percentile(xs, 0.5) == 2.0
        assert percentile(xs, 0.5) == float(np.percentile(
            xs, 50, method="closest_observation"))

    def test_matches_numpy_inverted_cdf(self):
        from repro.serve.engine import percentile

        rng = np.random.default_rng(0)
        for _ in range(200):
            n = int(rng.integers(1, 40))
            xs = list(rng.normal(size=n))
            q = float(rng.choice([0.0, 0.5, 0.9, 0.95, 0.99, 1.0,
                                  rng.uniform()]))
            want = float(np.percentile(xs, q * 100,
                                       method="inverted_cdf"))
            assert percentile(xs, q) == want, (n, q)

    def test_always_an_order_statistic_and_none_on_empty(self):
        """Zero traffic has no order statistics: the old NaN sentinel
        poisoned JSON artifacts (NaN is not valid JSON) and every
        ``{v:.0f}`` report format; None is the explicit absence."""
        from repro.serve.engine import percentile

        rng = np.random.default_rng(1)
        xs = list(rng.normal(size=17))
        for q in (0.0, 0.25, 0.5, 0.9, 0.99, 1.0):
            assert percentile(xs, q) in xs
        assert percentile([], 0.5) is None


class TestServeStatsBytes:
    """``offchip_reduction`` is a fetch-*bytes* ratio (bytes avoided over
    bytes demanded), the same bytes-over-bytes shape as the simulator's
    ``demand_miss_reduction`` — not a bare event-count alias."""

    def test_reduction_is_bytes_ratio(self):
        from repro.serve.engine import ServeStats

        s = ServeStats(nsb_hits=3, nsb_misses=1, row_bytes=256)
        assert s.demand_bytes == 4 * 256
        assert s.offchip_reduction == (3 * 256) / (4 * 256)

    def test_none_without_row_bytes_or_traffic(self):
        """No traffic (or no byte size) -> the ratios are undefined:
        None, not NaN — NaN leaked into JSON artifacts and crashed
        format specs in the launcher's report."""
        from repro.serve.engine import ServeStats

        assert ServeStats(nsb_hits=3, nsb_misses=1).offchip_reduction \
            is None
        assert ServeStats(row_bytes=64).offchip_reduction is None
        assert ServeStats().hot_hit_rate is None


def _mk(rid, plen, gen, arrival=0.0):
    return Request(rid=rid, prompt=np.arange(plen), max_new_tokens=gen,
                   arrival=arrival)


def _drive(sched, now):
    """Advance one iteration without a model: prefill chunks bump the
    frontier; decode rows append a fake token at the frontier."""
    plan = sched.schedule(now)
    for job in plan.prefill:
        job.req.computed += job.n_tokens
        if job.req.computed == job.req.prompt_len:
            job.req.out_tokens.append(0)
            job.req.first_token_at = now
    for req in plan.decode:
        frontier = req.computed == req.total_len - 1
        req.computed += 1
        if frontier:
            req.out_tokens.append(0)
            if req.done:
                sched.finish(req, now)
    return plan


class TestScheduler:
    def test_fifo_admission_with_head_of_line_blocking(self):
        al = KVBlockAllocator(n_pages=9, page_tokens=4)   # 8 pages
        s = Scheduler(al, max_batch=4, chunk=8, token_budget=64)
        big = _mk(0, 24, 2)       # needs 6 pages
        small1 = _mk(1, 4, 2)     # needs 1 page
        small2 = _mk(2, 4, 2)
        for r in (big, small1, small2):
            s.add(r)
        s.schedule(0.0)
        # big admitted first and fills most of the pool; the smalls fit
        assert big.admission_seq == 0
        # now exhaust: a second big request must NOT be bypassed by a
        # later small one
        big2 = _mk(3, 24, 2)
        small3 = _mk(4, 4, 2)
        s.add(big2)
        s.add(small3)
        s.schedule(1.0)
        assert big2.state is RequestState.WAITING
        assert small3.state is RequestState.WAITING     # blocked behind big2
        assert [r.rid for r in s.waiting] == [3, 4]

    def test_admission_order_matches_arrival_under_load(self):
        al = KVBlockAllocator(n_pages=17, page_tokens=4)
        s = Scheduler(al, max_batch=4, chunk=8, token_budget=16)
        reqs = [_mk(i, 8 + 4 * (i % 3), 3, arrival=float(i)) for i in range(8)]
        for r in reqs:
            s.add(r)
        now = 0.0
        while s.has_work and now < 200:
            now += 1
            _drive(s, now)
        seqs = [r.admission_seq for r in reqs]
        assert seqs == sorted(seqs)                  # FIFO admission
        firsts = [r.first_token_at for r in reqs]
        assert all(f >= 0 for f in firsts)

    def test_exhaustion_preempts_youngest(self):
        # 4 allocatable pages of 4 tokens; two requests that each grow to
        # 3 pages -> the pool cannot hold both at full length
        al = KVBlockAllocator(n_pages=5, page_tokens=4)
        s = Scheduler(al, max_batch=2, chunk=8, token_budget=16)
        r0 = _mk(0, 8, 4)
        r1 = _mk(1, 8, 4)
        s.add(r0)
        s.add(r1)
        now = 0.0
        while s.has_work and now < 100:
            now += 1
            _drive(s, now)
        assert s.n_preemptions > 0
        assert r1.n_preemptions > 0        # the younger request yields
        assert r0.n_preemptions == 0       # the elder never does
        assert r0.done and r1.done
        assert al.pages_in_use == 0        # everything released

    def test_preempted_request_keeps_queue_priority(self):
        al = KVBlockAllocator(n_pages=5, page_tokens=4)
        s = Scheduler(al, max_batch=2, chunk=8, token_budget=16)
        r0, r1 = _mk(0, 8, 6), _mk(1, 8, 6)
        s.add(r0)
        s.add(r1)
        _drive(s, 1.0)
        s.add(_mk(2, 4, 2))
        # drive until r1 is preempted; it must sit AHEAD of rid 2
        for now in range(2, 50):
            _drive(s, float(now))
            if r1.state is RequestState.WAITING and r1.n_preemptions:
                break
        assert r1.n_preemptions > 0
        ids = [r.rid for r in s.waiting]
        assert ids.index(1) < ids.index(2) if 2 in ids else True

    def test_admit_never_thrashes_same_iteration(self):
        """A schedule() call must never preempt a request it just
        admitted: admission reserves the whole prompt and runs after
        decode allocation, so the fresh admittee (highest admission_seq,
        the preferred victim) cannot be evicted by the same iteration."""
        al = KVBlockAllocator(n_pages=5, page_tokens=4)   # 4 allocatable
        s = Scheduler(al, max_batch=2, chunk=8, token_budget=16)
        r0 = _mk(0, 8, 4)                    # 2 prompt pages, grows to 3
        s.add(r0)
        _drive(s, 1.0)                       # r0 prefilled, enters decode
        r1 = _mk(1, 8, 2)                    # 2 prompt pages
        s.add(r1)
        # this iteration r0's decode grabs a 3rd page, leaving 1 free:
        # r1 must be blocked at admission, NOT admitted-then-evicted
        plan = s.schedule(2.0)
        assert [r.rid for r in plan.decode] == [0]
        assert al.owned(0) == 3
        assert r1.n_preemptions == 0
        assert r1.state is RequestState.WAITING
        assert r1.admission_seq == -1        # never admitted, not churned
        assert s.n_preemptions == 0

    def test_admission_reserves_whole_prompt(self):
        al = KVBlockAllocator(n_pages=9, page_tokens=4)
        s = Scheduler(al, max_batch=2, chunk=4, token_budget=16)
        r0 = _mk(0, 16, 2)                   # 4 pages
        s.add(r0)
        s.schedule(0.0)
        # all prompt pages held from the first iteration, before any
        # prefill chunk ran
        assert al.owned(0) == 4
        assert r0.computed == 0              # nothing cached: no skip

    def test_mixed_plan_respects_budget(self):
        al = KVBlockAllocator(n_pages=33, page_tokens=4)
        s = Scheduler(al, max_batch=4, chunk=8, token_budget=10)
        decoding = _mk(0, 4, 8)
        s.add(decoding)
        _drive(s, 0.0)     # prefill whole 4-token prompt
        s.add(_mk(1, 32, 2))
        plan = s.schedule(1.0)
        assert len(plan.decode) == 1
        assert sum(j.n_tokens for j in plan.prefill) <= 9
        assert plan.n_tokens <= 10


class TestRowBuckets:
    def test_bucket_helpers(self):
        assert row_buckets(8) == (1, 2, 4, 8)
        assert row_buckets(1) == (1,)
        assert row_buckets(6) == (1, 2, 4, 6)    # cap is always a bucket
        bks = row_buckets(8)
        assert bucket_for(1, bks) == 1
        assert bucket_for(3, bks) == 4
        assert bucket_for(8, bks) == 8

    def test_bucket_for_rejects_overflow(self):
        """More rows than the largest bucket is a plan that would drop
        decode rows at pad time — an error, never a silent clamp."""
        with pytest.raises(ValueError, match="exceeds the largest"):
            bucket_for(9, row_buckets(8))

    def test_row_buckets_rejects_degenerate_max(self):
        with pytest.raises(ValueError):
            row_buckets(0)
        with pytest.raises(ValueError):
            row_buckets(-3)

    def test_bucket_count_is_log_of_max_batch(self):
        import math

        for mb in (1, 2, 4, 8, 16, 64):
            assert len(row_buckets(mb)) <= math.ceil(math.log2(mb)) + 1

    def test_schedule_fills_bucket_with_deferred_rows(self):
        """Padded decode slots are free compute: a bucket-aware plan
        tops the batch up to the bucket boundary with decoding requests
        the token budget alone would have deferred."""
        al = KVBlockAllocator(n_pages=33, page_tokens=4)
        s = Scheduler(al, max_batch=8, chunk=4, token_budget=32,
                      row_buckets=row_buckets(8))
        reqs = [_mk(i, 4, 8) for i in range(5)]
        for r in reqs:
            s.add(r)
        for now in range(1, 12):                 # prefill everyone
            if all(not r.in_prefill for r in reqs):
                break
            _drive(s, float(now))
        s.token_budget = 3                       # now constrain decode
        plan = s.schedule(99.0)
        # budget admits 3 decode rows; the bucket boundary is 4, so one
        # deferred row rides in the padding for free
        assert len(plan.decode) == 4
        assert plan.decode_bucket == 4
        assert plan.n_tokens == 4                # over budget by design

    def test_fill_never_preempts(self):
        """Topping a bucket up uses plain ensure(): a free slot must
        never evict another request's pages."""
        al = KVBlockAllocator(n_pages=7, page_tokens=4)   # 6 allocatable
        s = Scheduler(al, max_batch=4, chunk=8, token_budget=2,
                      row_buckets=row_buckets(4))
        reqs = [_mk(i, 8, 8) for i in range(3)]            # 2 pages each
        for r in reqs:
            s.add(r)
        for now in range(1, 8):
            if all(not r.in_prefill for r in reqs if
                   r.state is RequestState.RUNNING):
                break
            _drive(s, float(now))
        pre = s.n_preemptions
        plan = s.schedule(50.0)
        # budget schedules 2; filling toward bucket 4 may fail page
        # allocation for the third — that must defer, not preempt
        assert s.n_preemptions == pre
        assert len(plan.decode) <= 4

    def test_no_buckets_means_no_fill_and_zero_bucket(self):
        al = KVBlockAllocator(n_pages=33, page_tokens=4)
        s = Scheduler(al, max_batch=8, chunk=4, token_budget=32)
        reqs = [_mk(i, 4, 8) for i in range(5)]
        for r in reqs:
            s.add(r)
        for now in range(1, 12):
            if all(not r.in_prefill for r in reqs):
                break
            _drive(s, float(now))
        s.token_budget = 3
        plan = s.schedule(99.0)
        assert len(plan.decode) == 3             # budget only
        assert plan.decode_bucket == 0           # engine pads to max_batch


class TestArrivals:
    def test_poisson_deterministic_and_sorted(self):
        a = PoissonArrivals(16, rate=0.5, seed=3)
        b = PoissonArrivals(16, rate=0.5, seed=3)
        assert a.schedule == b.schedule
        ticks = [t for t, _, _ in a.schedule]
        assert ticks == sorted(ticks) and len(ticks) == 16

    def test_poisson_seed_changes_schedule(self):
        assert PoissonArrivals(16, rate=0.5, seed=3).schedule \
            != PoissonArrivals(16, rate=0.5, seed=4).schedule

    def test_trace_arrivals_roundtrip(self):
        tr = TraceArrivals([(0, 8, 4), (2.5, 16, 2)])
        assert list(tr) == [(0.0, 8, 4), (2.5, 16, 2)]


@pytest.mark.slow
class TestPagedEngine:
    @pytest.fixture(scope="class")
    def setup(self):
        import jax

        from repro.configs import get_config
        from repro.models import api

        cfg = get_config("qwen2-1.5b").reduced()
        params = api.init_params(cfg, jax.random.PRNGKey(0))
        rng = np.random.default_rng(7)
        work = [(0.0, rng.integers(1, cfg.vocab, size=int(p)), 6)
                for p in rng.integers(10, 22, size=3)]
        return cfg, params, work

    def _run(self, cfg, params, work, n_pages):
        from repro.serve.engine import PagedEngine

        eng = PagedEngine(cfg, params, max_len=48, n_pages=n_pages,
                          max_batch=4, chunk=8, nsb_pages=32)
        eng.run([(t, p.copy(), g) for t, p, g in work])
        return eng

    def test_all_finish_and_pool_drains(self, setup):
        cfg, params, work = setup
        eng = self._run(cfg, params, work, 0)
        assert all(r.state is RequestState.FINISHED
                   for r in eng.requests.values())
        assert all(len(r.out_tokens) == r.max_new_tokens
                   for r in eng.requests.values())
        assert eng.allocator.pages_in_use == 0
        # bytes-based off-chip metric is live (and, with one uniform
        # page size, numerically the hit rate — by a bytes definition);
        # row_bytes matches the capture recorder's per-page charge
        # (kv_dtype_bytes defaults to 2, the production bf16 KV)
        assert eng.stats.row_bytes == 2 * cfg.kv_page * cfg.hd * 2
        assert (eng.stats.offchip_reduction
                == pytest.approx(eng.stats.hot_hit_rate))
        assert eng.metrics()["offchip_fetch_reduction"] == pytest.approx(
            eng.stats.nsb_hits * eng.stats.row_bytes
            / eng.stats.demand_bytes)

    def test_preemption_resume_identical_logits(self, setup):
        """Allocator exhaustion forces preemption; recompute + decode
        replay must reproduce the unpressured run bit-for-bit."""
        cfg, params, work = setup
        calm = self._run(cfg, params, work, 0)
        # 11 pages hold every concurrent prompt (admission reserves whole
        # prompts now) but not the decode growth -> eviction mid-stream
        tight = self._run(cfg, params, work, 1 + 11)
        assert calm.scheduler.n_preemptions == 0
        assert tight.scheduler.n_preemptions > 0
        for rid in calm.requests:
            a, b = calm.requests[rid], tight.requests[rid]
            assert a.out_tokens == b.out_tokens
            np.testing.assert_allclose(a.last_logits, b.last_logits,
                                       rtol=1e-5, atol=1e-5)

    def test_admission_fifo_under_mixed_load(self, setup):
        cfg, params, _ = setup
        rng = np.random.default_rng(9)
        work = [(float(i) * 0.5, rng.integers(1, cfg.vocab, size=12), 4)
                for i in range(6)]
        eng = self._run(cfg, params, work, 1 + 16)
        reqs = [eng.requests[r] for r in sorted(eng.requests)]
        seqs = [r.admission_seq for r in reqs]
        assert seqs == sorted(seqs)

    def test_short_prompt_never_records_null_page(self, setup):
        """A request with fewer valid pages than the TopK budget pads its
        selection with the reserved NULL page; those slots are masked in
        attention and must not leak into capture or NSB accounting."""
        cfg, params, _ = setup
        from repro.serve.engine import PagedEngine

        eng = PagedEngine(cfg, params, max_len=48, max_batch=2, chunk=8,
                          nsb_pages=16, capture_trace=True)
        eng.submit(np.arange(1, 7), max_new_tokens=4)    # 6-token prompt
        eng.run()
        assert eng.recorder.n_events > 0
        for ev in eng.recorder.events:
            assert ev.min() >= 1
        assert 0 not in eng._seen_pages

    def test_run_preserves_fractional_arrival_ticks(self, setup):
        cfg, params, _ = setup
        from repro.serve.engine import PagedEngine

        eng = PagedEngine(cfg, params, max_len=48, max_batch=2, chunk=8)
        eng.run([(0.7, np.arange(1, 9), 2)])
        req = eng.requests[0]
        assert req.arrival == 0.7
        assert req.latency() == req.finished_at - 0.7

    def test_rejects_oversized_request(self, setup):
        cfg, params, _ = setup
        from repro.serve.engine import PagedEngine

        eng = PagedEngine(cfg, params, max_len=48, n_pages=1 + 4,
                          max_batch=2, chunk=8)
        with pytest.raises(ValueError):
            eng.submit(np.arange(1, 30), max_new_tokens=10)


@pytest.mark.slow
class TestPrefixCacheEngine:
    """Acceptance: cross-request prefix sharing costs zero model FLOPs
    for cached pages while per-request logits stay bitwise-identical to
    the uncached run — including under forced preemption of a request
    holding shared pages — and the captured COW traffic replays through
    the simulator end-to-end."""

    @pytest.fixture(scope="class")
    def setup(self):
        import jax

        from repro.configs import get_config
        from repro.models import api

        cfg = get_config("qwen2-1.5b").reduced()
        params = api.init_params(cfg, jax.random.PRNGKey(0))
        rng = np.random.default_rng(11)
        # two system prompts (3 whole pages each at kv_page=4), short
        # user suffixes: the multi-tenant shared-prefix shape
        sys_prompts = [rng.integers(1, cfg.vocab, size=12) for _ in range(2)]
        work = []
        for i in range(6):
            suffix = rng.integers(1, cfg.vocab, size=int(rng.integers(2, 6)))
            prompt = np.concatenate([sys_prompts[i % 2], suffix])
            work.append((float(i) * 0.5, prompt, 5))
        return cfg, params, work

    def _run(self, cfg, params, work, n_pages=0, prefix_cache=True,
             capture=False):
        from repro.serve.engine import PagedEngine

        eng = PagedEngine(cfg, params, max_len=48, n_pages=n_pages,
                          max_batch=4, chunk=8, nsb_pages=32,
                          prefix_cache=prefix_cache, capture_trace=capture)
        eng.run([(t, p.copy(), g) for t, p, g in work])
        return eng

    def test_shared_prefix_skips_prefill_bitwise_identical(self, setup):
        cfg, params, work = setup
        base = self._run(cfg, params, work, prefix_cache=False)
        cached = self._run(cfg, params, work, prefix_cache=True)
        assert cached.allocator.stats.prefix_hits > 0
        assert cached.scheduler.prefill_tokens_skipped > 0
        assert (cached.stats.prefill_tokens
                == base.stats.prefill_tokens
                - cached.scheduler.prefill_tokens_skipped)
        for rid in base.requests:
            a, b = base.requests[rid], cached.requests[rid]
            assert a.out_tokens == b.out_tokens
            assert np.array_equal(a.last_logits, b.last_logits)
            assert b.first_token_at <= a.first_token_at    # TTFT no worse

    def test_identical_prompt_full_hit_triggers_cow(self, setup):
        cfg, params, _ = setup
        rng = np.random.default_rng(13)
        prompt = rng.integers(1, cfg.vocab, size=16)   # page-aligned
        # second arrival lands after the first prompt is fully
        # registered -> whole-prompt cache hit -> tail-page COW
        work = [(0.0, prompt, 4), (4.0, prompt.copy(), 4)]
        base = self._run(cfg, params, work, prefix_cache=False)
        cached = self._run(cfg, params, work, prefix_cache=True)
        assert cached.allocator.stats.cow_copies >= 1
        assert cached.stats.cow_page_copies >= 1       # pool bytes moved
        for rid in base.requests:
            a, b = base.requests[rid], cached.requests[rid]
            assert a.out_tokens == b.out_tokens
            assert np.array_equal(a.last_logits, b.last_logits)

    def test_preemption_of_shared_pages_bitwise_identical(self, setup):
        """Force eviction of requests whose tables hold shared pages;
        recompute + re-attach must still reproduce the uncached run."""
        cfg, params, work = setup
        base = self._run(cfg, params, work, prefix_cache=False)
        tight = self._run(cfg, params, work, n_pages=1 + 9,
                          prefix_cache=True)
        assert tight.scheduler.n_preemptions > 0
        assert tight.allocator.stats.prefix_hits > 0
        for rid in base.requests:
            a, b = base.requests[rid], tight.requests[rid]
            assert a.out_tokens == b.out_tokens
            assert np.array_equal(a.last_logits, b.last_logits)

    def test_captured_cow_traffic_replays_end_to_end(self, setup):
        from repro.core.nvr import run_modes

        cfg, params, work = setup
        eng = self._run(cfg, params, work, prefix_cache=True, capture=True)
        st = eng.recorder
        assert st.n_events > 0
        # genuinely shared physical ids: some page appears in the
        # selection streams of two different requests
        by_rid = {rid: set(np.concatenate(
            [e for _, e in st.events_for(rid)]))
            for rid in st.request_ids()}
        rids = list(by_rid)
        assert any(by_rid[a] & by_rid[b]
                   for i, a in enumerate(rids) for b in rids[i + 1:])
        rs = {r.label: r for r in run_modes(st.to_trace(), 2)}
        assert rs["inorder"].demand_misses > 0
        assert rs["nvr"].demand_misses < rs["inorder"].demand_misses

    def test_pool_drains_and_cache_parks(self, setup):
        cfg, params, work = setup
        eng = self._run(cfg, params, work, prefix_cache=True)
        assert eng.allocator.pages_in_use == 0
        assert eng.allocator.pages_cached > 0
        assert eng.allocator.pages_free == eng.allocator.capacity


@pytest.mark.slow
class TestStepLoopFastPath:
    """The donated + bucketed step loop: no per-call pool copy, a
    trace-count ceiling of O(log max_batch), and unchanged outputs."""

    @pytest.fixture(scope="class")
    def setup(self):
        import jax

        from repro.configs import get_config
        from repro.models import api

        cfg = get_config("qwen2-1.5b").reduced()
        params = api.init_params(cfg, jax.random.PRNGKey(0))
        return cfg, params

    def _engine(self, cfg, params, **kw):
        from repro.serve.engine import PagedEngine

        return PagedEngine(cfg, params, max_len=48, max_batch=4, chunk=8,
                           nsb_pages=32, **kw)

    def test_donation_consumes_pool_buffers(self, setup):
        """With donate_pools the jitted step consumes the input pool
        buffer (XLA reuses it for the output) instead of allocating a
        fresh pool-sized copy; without it the input stays live."""
        cfg, params = setup
        eng = self._engine(cfg, params)
        eng.submit(np.arange(1, 15), max_new_tokens=4)
        k0, v0, s0 = eng.k_pool, eng.v_pool, eng.s_pool
        eng.step()
        assert k0.is_deleted() and v0.is_deleted() and s0.is_deleted()

        base = self._engine(cfg, params, donate_pools=False)
        base.submit(np.arange(1, 15), max_new_tokens=4)
        k0 = base.k_pool
        base.step()
        assert not k0.is_deleted()    # pre-PR behaviour: copy survives

    def test_donation_keeps_live_pool_buffer_count_flat(self, setup):
        import jax

        cfg, params = setup
        eng = self._engine(cfg, params)
        eng.submit(np.arange(1, 15), max_new_tokens=8)
        eng.step()
        eng.step()                     # decode path warm

        def pool_buffers():
            return sum(1 for a in jax.live_arrays()
                       if a.shape == eng.k_pool.shape)

        before = pool_buffers()
        for _ in range(4):
            eng.step()
        assert pool_buffers() == before

    def test_bucketing_caps_decode_traces(self, setup):
        """A full Poisson run through the bucketed engine compiles at
        most one decode trace per row bucket — O(log max_batch) — while
        computing strictly fewer padded rows than the pad-to-max
        baseline."""
        import math

        cfg, params = setup
        rng = np.random.default_rng(5)
        arrivals = PoissonArrivals(10, rate=0.7, prompt_len=(6, 16),
                                   gen_len=(3, 8), seed=5)
        work = [(t, rng.integers(1, cfg.vocab, size=p), g)
                for t, p, g in arrivals]

        eng = self._engine(cfg, params)
        eng.run([(t, p.copy(), g) for t, p, g in work])
        m = eng.metrics()
        assert m["n_decode_traces"] <= math.ceil(math.log2(4)) + 1
        assert m["n_prefill_traces"] == 1

        base = self._engine(cfg, params, row_bucketing=False)
        base.run([(t, p.copy(), g) for t, p, g in work])
        assert base.metrics()["n_decode_traces"] == 1    # always max_batch
        assert (m["decode_rows_padded"]
                < base.metrics()["decode_rows_padded"])
        # free-path changes must not change what anyone generated
        for rid in base.requests:
            a, b = base.requests[rid], eng.requests[rid]
            assert a.out_tokens == b.out_tokens
            assert np.array_equal(a.last_logits, b.last_logits)


@pytest.mark.slow
class TestRunahead:
    """Acceptance for the online runahead stage: speculation is *free* of
    correctness — every request's tokens and logits are bitwise-identical
    with runahead off / imp / nvr, under allocator pressure (forced
    preemption + resume) and under COW shared-prefix attaches — and the
    staged tier actually moves: pages staged, demand hits observed,
    accuracy/coverage reported."""

    @pytest.fixture(scope="class")
    def setup(self):
        import jax

        from repro.configs import get_config
        from repro.models import api

        cfg = get_config("qwen2-1.5b").reduced()
        params = api.init_params(cfg, jax.random.PRNGKey(0))
        rng = np.random.default_rng(11)
        # shared-prefix multi-tenant shape: 2 system prompts of 3 whole
        # pages each (kv_page=4), short user suffixes
        sys_prompts = [rng.integers(1, cfg.vocab, size=12) for _ in range(2)]
        work = []
        for i in range(6):
            suffix = rng.integers(1, cfg.vocab, size=int(rng.integers(2, 6)))
            work.append((float(i) * 0.5,
                         np.concatenate([sys_prompts[i % 2], suffix]), 5))
        return cfg, params, work

    def _run(self, cfg, params, work, n_pages=0, runahead="off",
             prefix_cache=True):
        from repro.serve.engine import PagedEngine

        eng = PagedEngine(cfg, params, max_len=48, n_pages=n_pages,
                          max_batch=4, chunk=8, nsb_pages=32,
                          prefix_cache=prefix_cache, runahead=runahead,
                          runahead_pages=8)
        eng.run([(t, p.copy(), g) for t, p, g in work])
        return eng

    def _assert_bitwise(self, a_eng, b_eng, why):
        for rid in a_eng.requests:
            a, b = a_eng.requests[rid], b_eng.requests[rid]
            assert a.out_tokens == b.out_tokens, (why, rid)
            assert np.array_equal(a.last_logits, b.last_logits), (why, rid)

    def test_bitwise_identical_across_modes(self, setup):
        cfg, params, work = setup
        base = self._run(cfg, params, work)
        for mode in ("imp", "nvr"):
            eng = self._run(cfg, params, work, runahead=mode)
            self._assert_bitwise(base, eng, mode)
            m = eng.metrics()
            assert m["runahead_mode"] == mode
            assert m["runahead_staged_pages"] > 0
            assert eng.stats.nsb_hits > 0
            # staged-tier accounting live: both axes defined post-traffic
            assert 0.0 <= m["runahead_accuracy"] <= 1.0
            assert 0.0 <= m["runahead_coverage"] <= 1.0
            assert m["runahead_overfetch"] == pytest.approx(
                1.0 - m["runahead_accuracy"])
            # comparator LRU sees the identical demand stream
            assert (m["nsb_demand_lru_hit_rate"]
                    == base.metrics()["nsb_hot_hit_rate"])

    def test_bitwise_under_forced_preemption_and_resume(self, setup):
        """Freed pages (preempt evictions) must be invalidated out of the
        hot tier before their physical slots are re-allocated; resume
        recompute must still replay bit-for-bit with staging active."""
        cfg, params, work = setup
        calm = self._run(cfg, params, work)
        tight = self._run(cfg, params, work, n_pages=1 + 11,
                          runahead="nvr")
        assert tight.scheduler.n_preemptions > 0
        self._assert_bitwise(calm, tight, "preempt+runahead")
        assert tight.metrics()["runahead_invalidations"] > 0

    def test_bitwise_with_cow_shared_prefix_attaches(self, setup):
        """COW dst pages are rewritten by the pool copy: stale staged
        entries must drop, and cached-attach runs must match the
        uncached run bit-for-bit with runahead on."""
        cfg, params, work = setup
        base = self._run(cfg, params, work, prefix_cache=False)
        cow = self._run(cfg, params, work, runahead="nvr")
        assert cow.allocator.stats.prefix_hits > 0
        self._assert_bitwise(base, cow, "cow+runahead")

    def test_off_engine_has_no_tier(self, setup):
        cfg, params, work = setup
        eng = self._run(cfg, params, work)
        assert eng._tier is None and eng._predictor is None
        m = eng.metrics()
        assert m["runahead_mode"] == "off"
        assert "runahead_staged_pages" not in m
        # the demand pools carry no staging tail when runahead is off
        assert eng.k_pool.shape[1] == eng.n_pages


class TestLatencyAccessors:
    """TTFT/TPOT/latency guards: -1.0 sentinels must surface as None,
    never as negative durations that drag percentiles toward zero."""

    def test_unstarted_request_returns_none(self):
        r = _mk(0, 8, 4, arrival=3.0)
        assert r.latency() is None
        assert r.ttft() is None
        assert r.tpot() is None

    def test_one_token_request_has_no_tpot(self):
        r = _mk(0, 8, 1, arrival=0.0)
        r.out_tokens = [5]
        r.first_token_at = 2.0
        r.last_token_at = 2.0
        r.finished_at = 2.0
        assert r.ttft() == 2.0 and r.latency() == 2.0
        assert r.tpot() is None          # no inter-token gap exists

    def test_tpot_is_mean_inter_token_gap(self):
        r = _mk(0, 8, 4, arrival=1.0)
        r.out_tokens = [1, 2, 3, 4]
        r.first_token_at = 3.0
        r.last_token_at = 9.0            # 3 gaps over 6 ticks
        assert r.tpot() == pytest.approx(2.0)

    def test_metrics_percentiles_skip_unfinished(self):
        # an unfinished request contributes nothing (None filtered),
        # instead of a negative sentinel duration
        from repro.serve.engine import percentile
        rs = [_mk(i, 8, 2) for i in range(3)]
        rs[0].first_token_at = 2.0
        rs[0].finished_at = 4.0
        vals = [x for x in (r.latency() for r in rs) if x is not None]
        assert vals == [4.0]
        assert percentile(vals, 0.99) == 4.0


class TestPerStreamRunaheadBudget:
    """The staging budget is a decode-stream grant: co-scheduled prefill
    no longer halves it (the streams are disaggregated)."""

    def test_full_budget_with_prefill_in_iteration(self):
        al = KVBlockAllocator(n_pages=33, page_tokens=4)
        s = Scheduler(al, max_batch=4, chunk=4, token_budget=16,
                      runahead_pages=8)
        decoding = _mk(0, 4, 4)
        s.add(decoding)
        _drive(s, 1.0)                       # prefill completes
        s.add(_mk(1, 12, 2))                 # long prompt joins
        plan = s.schedule(2.0)
        assert plan.decode and plan.prefill  # mixed iteration
        assert plan.runahead_budget == 8     # full, not halved

    def test_no_budget_without_decode(self):
        al = KVBlockAllocator(n_pages=33, page_tokens=4)
        s = Scheduler(al, max_batch=4, chunk=4, token_budget=16,
                      runahead_pages=8)
        s.add(_mk(0, 12, 2))
        plan = s.schedule(1.0)
        assert plan.prefill and not plan.decode
        assert plan.runahead_budget == 0     # nothing to predict for


class TestPlanDoubleBuffer:
    """Scheduler.schedule_speculative / commit: the draft-commit cycle
    the pipelined executor runs every iteration."""

    def _sched(self, **kw):
        al = KVBlockAllocator(n_pages=33, page_tokens=4)
        kw.setdefault("max_batch", 4)
        kw.setdefault("chunk", 4)
        kw.setdefault("token_budget", 16)
        return Scheduler(al, **kw), al

    def test_commit_none_is_plain_schedule(self):
        s, _ = self._sched()
        s.add(_mk(0, 8, 2))
        plan = s.commit(None, 1.0)
        assert plan.prefill and not plan.speculative
        assert s.plan_commits == 0           # nothing was speculated

    def test_speculative_plan_allocates_nothing(self):
        s, al = self._sched()
        s.add(_mk(0, 8, 2))
        in_use = al.pages_in_use
        spec = s.schedule_speculative(1.0)
        assert spec.speculative and spec.prefill
        assert al.pages_in_use == in_use     # draft ran on shadow state
        assert not s.running                 # no real admission happened

    def test_commit_drops_finished_rid(self):
        s, _ = self._sched()
        r0, r1 = _mk(0, 4, 1), _mk(1, 4, 3)
        s.add(r0)
        s.add(r1)
        plan = s.commit(None, 1.0)           # both prefill fully
        for job in plan.prefill:
            job.req.computed += job.n_tokens
        spec = s.schedule_speculative(2.0, in_flight=plan)
        # commit-phase: both emit; r0 (max_new=1) finishes
        for job in plan.prefill:
            job.req.out_tokens.append(0)
            if job.req.done:
                s.finish(job.req, 1.0)
        committed = s.commit(spec, 2.0)
        assert r0.rid not in {r.rid for r in committed.decode}
        assert r1.rid in {r.rid for r in committed.decode}
        assert s.plan_commits == 1

    def test_exact_speculation_counts_as_reuse(self):
        s, _ = self._sched()
        s.add(_mk(0, 4, 4))
        plan = s.commit(None, 1.0)
        for _ in range(6):
            for job in plan.prefill:
                job.req.computed += job.n_tokens
            spec = s.schedule_speculative(plan.for_now + 1.0,
                                          in_flight=plan)
            for job in plan.prefill:
                if (job.req.computed == job.req.prompt_len
                        and not job.req.out_tokens):
                    job.req.out_tokens.append(0)
                    if job.req.done:
                        s.finish(job.req, plan.for_now)
            for req in plan.decode:
                frontier = req.computed == req.total_len - 1
                req.computed += 1
                if frontier:
                    req.out_tokens.append(0)
                    if req.done:
                        s.finish(req, plan.for_now)
            if not s.has_work:
                break
            plan = s.commit(spec, plan.for_now + 1.0)
        # no arrivals between draft and commit: every draft was exact
        assert s.plan_commits > 0
        assert s.plan_reuse == s.plan_commits
        assert s.plan_repairs == 0

    def test_stale_draft_is_ignored(self):
        s, _ = self._sched()
        s.add(_mk(0, 8, 2))
        spec = s.schedule_speculative(1.0)
        # committed at a different tick than the draft was built for
        s.commit(spec, 5.0)
        assert s.plan_commits == 0


@pytest.mark.slow
class TestPipelinedExecutor:
    """Acceptance for the pipelined executor: tokens and logits are
    bitwise-identical to the synchronous loop across plain runs,
    preemption/resume, COW prefix attaches, and spill swap-back — while
    the overlap metrics show the streams actually disaggregated."""

    @pytest.fixture(scope="class")
    def setup(self):
        import jax

        from repro.configs import get_config
        from repro.models import api

        cfg = get_config("qwen2-1.5b").reduced()
        params = api.init_params(cfg, jax.random.PRNGKey(0))
        rng = np.random.default_rng(17)
        sys_p = rng.integers(1, cfg.vocab, size=12)
        work = []
        for i in range(5):
            if i % 2:
                prompt = np.concatenate(
                    [sys_p, rng.integers(1, cfg.vocab, size=3)])
            else:
                prompt = rng.integers(1, cfg.vocab, size=14)
            work.append((float(i) * 0.5, prompt, 5))
        return cfg, params, work

    def _run(self, cfg, params, work, executor, **kw):
        from repro.serve.engine import PagedEngine

        eng = PagedEngine(cfg, params, max_len=48, max_batch=4, chunk=8,
                          nsb_pages=32, executor=executor, **kw)
        eng.run([(t, p.copy(), g) for t, p, g in work])
        return eng

    def _assert_bitwise(self, a_eng, b_eng, why):
        assert a_eng.requests.keys() == b_eng.requests.keys()
        for rid in a_eng.requests:
            a, b = a_eng.requests[rid], b_eng.requests[rid]
            assert a.out_tokens == b.out_tokens, (why, rid)
            assert np.array_equal(a.last_logits, b.last_logits), (why, rid)

    def test_rejects_unknown_executor(self, setup):
        cfg, params, _ = setup
        from repro.serve.engine import PagedEngine

        with pytest.raises(ValueError, match="executor"):
            PagedEngine(cfg, params, max_len=48, executor="threads")

    def test_bitwise_identical_plain_run(self, setup):
        cfg, params, work = setup
        sync = self._run(cfg, params, work, "sync")
        pipe = self._run(cfg, params, work, "async")
        self._assert_bitwise(sync, pipe, "plain")
        # identical timelines too: same plans, same per-stream split
        assert sync.stats.iter_log == pipe.stats.iter_log
        m = pipe.metrics()
        assert m["executor"] == "async"
        assert m["plan_commits"] > 0
        assert m["overlap_iterations"] > 0
        assert m["overlap_fraction"] > 0.0
        assert m["p99_tpot"] is not None and m["p99_tpot"] >= 1.0
        assert sync.metrics()["executor"] == "sync"
        assert sync.metrics()["plan_commits"] == 0

    def test_bitwise_under_preemption_and_resume(self, setup):
        cfg, params, work = setup
        sync = self._run(cfg, params, work, "sync", n_pages=1 + 12)
        pipe = self._run(cfg, params, work, "async", n_pages=1 + 12)
        assert pipe.scheduler.n_preemptions > 0
        self._assert_bitwise(sync, pipe, "preempt")
        # recovered drafts show up as repairs, not wrong schedules
        assert pipe.scheduler.plan_repairs > 0

    def test_bitwise_with_cow_prefix_and_runahead(self, setup):
        cfg, params, work = setup
        sync = self._run(cfg, params, work, "sync", runahead="nvr",
                         runahead_pages=8)
        pipe = self._run(cfg, params, work, "async", runahead="nvr",
                         runahead_pages=8)
        assert pipe.allocator.stats.prefix_hits > 0
        self._assert_bitwise(sync, pipe, "cow+runahead")
        # identical plans -> identical staged-tier traffic
        assert (sync.metrics()["runahead_staged_pages"]
                == pipe.metrics()["runahead_staged_pages"])

    def test_bitwise_with_spill_swap_back(self, setup):
        """Fetch-back moves to the overlap window (pre-commit pool
        occupancy): timelines may diverge from sync, tokens and logits
        may not."""
        cfg, params, work = setup
        sync = self._run(cfg, params, work, "sync", n_pages=1 + 12,
                         runahead="nvr", runahead_pages=8, spill_pages=16)
        pipe = self._run(cfg, params, work, "async", n_pages=1 + 12,
                         runahead="nvr", runahead_pages=8, spill_pages=16)
        assert pipe.scheduler.n_swap_outs > 0
        self._assert_bitwise(sync, pipe, "spill")
        pipe.allocator.check_tier_invariants()

    def test_slot_stability_across_iterations(self, setup):
        """Per-slot insertion: a running request keeps its decode row
        while others come and go (no batch reshuffle on entry)."""
        cfg, params, work = setup
        from repro.serve.engine import PagedEngine

        eng = PagedEngine(cfg, params, max_len=48, max_batch=4, chunk=8,
                          nsb_pages=32, executor="async")
        slots_seen: dict = {}
        eng.submit(np.arange(1, 9), max_new_tokens=6)
        orig = eng._pipeline._assign_slots

        def spy(plan, rb):
            pairs = orig(plan, rb)
            for slot, req in pairs:
                slots_seen.setdefault(req.rid, set()).add(slot)
            return pairs

        eng._pipeline._assign_slots = spy
        for t, p, g in [(2.0, np.arange(20, 34), 3)]:
            eng.run([(t, p.copy(), g)])
        # rid 0 decoded across the second request's entry/exit without
        # ever moving rows (bucket never shrank below its slot)
        assert slots_seen and all(len(s) == 1
                                  for s in slots_seen.values())


@pytest.mark.slow
class TestMultiTurnSessions:
    """Multi-turn front door: follow-up turns re-enter through admission
    carrying session KV.  Every turn's tokens and logits must be
    bitwise-identical whether the idle session's pages stayed resident,
    were held on-device, or were swapped out to the host tier between
    turns — and independent of the scheduling policy."""

    @pytest.fixture(scope="class")
    def setup(self):
        import jax

        from repro.configs import get_config
        from repro.models import api
        from repro.serve.workload import Turn, WorkItem

        cfg = get_config("qwen2-1.5b").reduced()
        params = api.init_params(cfg, jax.random.PRNGKey(0))
        rng = np.random.default_rng(11)
        items = []
        for i in range(3):
            prompt = rng.integers(1, cfg.vocab, size=12)
            turns = [Turn(think_time=4.0,
                          user_tokens=rng.integers(1, cfg.vocab, size=6),
                          max_new_tokens=4)] if i < 2 else []
            items.append(WorkItem(arrival=float(i) * 0.5, prompt=prompt,
                                  max_new_tokens=4, tenant=f"t{i % 2}",
                                  priority=i % 2, slo_ttft=20.0,
                                  slo_tpot=6.0, turns=turns))

        def run(session_hold, idle_swap, spill, policy="fifo"):
            from repro.serve.engine import PagedEngine

            eng = PagedEngine(cfg, params, max_len=64, n_pages=0,
                              max_batch=4, chunk=8, spill_pages=spill,
                              policy=policy, session_hold=session_hold,
                              idle_swap=idle_swap)
            eng.run([dataclasses.replace(it, prompt=it.prompt.copy())
                     for it in items])
            return eng

        return {
            "base": run(False, False, 0),     # never held, never swapped
            "hold": run(True, False, 0),      # pages pinned between turns
            "swap": run(True, True, 16),      # parked in the host tier
            "slo": run(True, True, 16, policy="slo_fair"),
        }

    @staticmethod
    def _by_turn(eng):
        """(session, turn) -> (tokens, logits); rid-independent (rids
        diverge across configurations because holder rids and turn
        interleaving consume the counter differently)."""
        out = {}
        for r in eng.requests.values():
            key = (r.session, r.turn) if r.session >= 0 else ("one", r.rid)
            out[key] = (list(r.out_tokens), r.last_logits)
        return out

    def test_all_turns_finish_everywhere(self, setup):
        for name, eng in setup.items():
            for r in eng.requests.values():
                assert r.state is RequestState.FINISHED, (name, r.rid)
                assert len(r.out_tokens) == r.max_new_tokens, (name, r.rid)
            assert eng.allocator.pages_in_use == 0, name
            eng.allocator.check_tier_invariants()

    def test_turn2_bitwise_with_and_without_idle_swap(self, setup):
        ref = self._by_turn(setup["base"])
        for name in ("hold", "swap", "slo"):
            got = self._by_turn(setup[name])
            assert set(got) == set(ref), name
            for k in ref:
                assert ref[k][0] == got[k][0], (name, k)
                np.testing.assert_array_equal(ref[k][1], got[k][1],
                                              err_msg=f"{name} {k}")

    def test_session_layer_exercised(self, setup):
        mh = setup["hold"].metrics()
        assert mh["session_holds"] == 2
        assert mh["turns_submitted"] == 2
        ms = setup["swap"].metrics()
        assert ms["idle_swap_outs"] >= 2     # both sessions parked
        assert ms["idle_swap_ins"] >= 1      # and restored for turn 2
        # turn-2 prefill reattached the session's KV instead of
        # recomputing it
        assert mh["prefill_tokens_skipped"] > 0
        assert ms["prefill_tokens_skipped"] > 0

    def test_policy_metrics_surface(self, setup):
        m = setup["slo"].metrics()
        assert m["policy"] == "slo_fair"
        assert 0.0 <= m["slo_attainment"] <= 1.0
        assert set(m["per_tenant"]) == {"t0", "t1"}
        assert setup["base"].metrics()["policy"] == "fifo"
