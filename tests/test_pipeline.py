"""Pipeline parallelism (pod axis): forward equivalence + trainability."""

import os
import subprocess
import sys

import pytest

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

_requires_explicit_sharding = pytest.mark.skipif(
    not hasattr(__import__("jax").sharding, "AxisType"),
    reason="needs the jax>=0.5 explicit-sharding API (AxisType/set_mesh); "
           "gated on older jax")

CODE = """
import jax, jax.numpy as jnp, numpy as np
from repro.train import pipeline_parallel as pp

mesh = jax.make_mesh((4, 2), ("pod", "data"),
                     axis_types=(jax.sharding.AxisType.Auto,) * 2)
L, D, MB, M = 8, 16, 4, 6
rng = np.random.default_rng(0)
W = jnp.asarray(rng.normal(size=(L, D, D)) * 0.3, jnp.float32)
x = jnp.asarray(rng.normal(size=(M, MB, D)), jnp.float32)

def layer_fn(w, h):
    return jnp.tanh(h @ w)

stage_fn = pp.make_stage_fn(layer_fn)
stages = pp.split_stages(W, 4)

with jax.set_mesh(mesh):
    out_pp = pp.pipeline_forward(stages, x, stage_fn, mesh)

# sequential reference
def seq(h):
    for i in range(L):
        h = layer_fn(W[i], h)
    return h
ref = jax.vmap(seq)(x)
np.testing.assert_allclose(np.asarray(out_pp), np.asarray(ref),
                           rtol=2e-5, atol=2e-5)
print("PP_FWD_OK")

# trainability: grads flow through ppermute
def loss(stages_, x_):
    y = pp.pipeline_forward(stages_, x_, stage_fn, mesh)
    return jnp.mean(y ** 2)

with jax.set_mesh(mesh):
    g = jax.jit(jax.grad(loss))(stages, x)
gn = sum(float(jnp.sum(jnp.abs(t))) for t in jax.tree.leaves(g))
assert np.isfinite(gn) and gn > 0
# compare against sequential-model grads
def loss_seq(W_, x_):
    def seq1(h):
        for i in range(L):
            h = layer_fn(W_[i], h)
        return h
    return jnp.mean(jax.vmap(seq1)(x_) ** 2)
g_ref = jax.grad(loss_seq)(W, x)
g_pp = jax.tree.leaves(g)[0].reshape(L, D, D)
np.testing.assert_allclose(np.asarray(g_pp), np.asarray(g_ref),
                           rtol=2e-4, atol=2e-5)
print("PP_GRAD_OK  bubble=%.2f" % pp.bubble_fraction(4, M))
"""


@pytest.mark.slow
@_requires_explicit_sharding
def test_pipeline_parallel_forward_and_grads():
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    r = subprocess.run([sys.executable, "-c", CODE], env=env,
                       capture_output=True, text=True, timeout=300)
    assert r.returncode == 0, (r.stderr[-3000:], r.stdout[-500:])
    assert "PP_FWD_OK" in r.stdout and "PP_GRAD_OK" in r.stdout
