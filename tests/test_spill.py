"""Host KV spill tier: allocator swap-out/swap-in, the host pool's
round-trip guarantees, and swap-resume parity through the paged engine.

Acceptance: preemption under a spill tier snapshots pages to host and
resume restores them onto fresh HBM ids with **bitwise-identical**
tokens and logits vs the free-and-recompute baseline (int8 spill trades
the bitwise K/V claim for a scale/2 dequantisation bound, asserted at
the pool level); every physical page id lives in exactly one tier at
all times (``check_tier_invariants``)."""

import numpy as np
import pytest

from repro.core.nvr import capture
from repro.serve.kv_allocator import KVBlockAllocator
from repro.serve.scheduler import RequestState
from repro.serve.spill import HostSpillPool


class TestSpillAllocator:
    def test_spill_releases_pages_and_resume_remaps(self):
        al = KVBlockAllocator(n_pages=8, page_tokens=4, spill_pages=8)
        assert al.ensure(0, 12)                    # 3 pages
        old = list(al.table(0))
        assert al.spill_request(0)
        assert al.is_spilled(0) and al.pages_in_use == 0
        assert al.pages_spilled == 3
        # snapshots queued before the ids were released
        outs = al.drain_spill_outs()
        assert [p for p, _ in outs] == old
        # another request may take the released ids meanwhile
        assert al.ensure(1, 8)
        assert al.resume_spilled(0)
        assert not al.is_spilled(0) and al.owned(0) == 3
        ins = al.drain_swap_ins()
        assert [p for _, p in ins] == al.table(0)
        assert set(al.table(0)).isdisjoint(al.table(1))
        [(rid, remap)] = al.drain_remaps()
        assert rid == 0 and set(remap) == set(old)
        assert sorted(remap.values()) == sorted(al.table(0))
        al.check_tier_invariants()

    def test_spill_disabled_or_short_is_all_or_nothing(self):
        al = KVBlockAllocator(n_pages=8, page_tokens=4)   # tier off
        al.ensure(0, 4)
        assert not al.spill_request(0)
        assert al.stats.spill_failures == 1
        assert al.owned(0) == 1                    # state untouched
        al2 = KVBlockAllocator(n_pages=8, page_tokens=4, spill_pages=2)
        al2.ensure(0, 12)                          # 3 pages > 2 slots
        assert not al2.spill_request(0)
        assert al2.owned(0) == 3 and al2.pages_spilled == 0
        al2.check_tier_invariants()

    def test_resume_blocked_then_retried(self):
        al = KVBlockAllocator(n_pages=4, page_tokens=4, spill_pages=4)
        al.ensure(0, 8)                            # 2 of 3 pages
        assert al.spill_request(0)
        al.drain_spill_outs()
        al.ensure(1, 12)                           # pool now full
        assert not al.resume_spilled(0)
        assert al.is_spilled(0)                    # snapshot kept
        assert al.stats.admission_blocks == 1
        al.free_request(1)
        assert al.resume_spilled(0)
        al.drain_swap_ins()
        al.check_tier_invariants()

    def test_resume_covers_extra_prompt_pages(self):
        """A request spilled mid-prefill resumes with enough private
        pages for the whole reserved prompt, not just the snapshots."""
        al = KVBlockAllocator(n_pages=16, page_tokens=4, spill_pages=8)
        al.ensure(0, 8)                            # 2 pages computed
        assert al.spill_request(0)
        al.drain_spill_outs()
        assert al.resume_spilled(0, n_tokens=14)   # needs 4 pages total
        assert al.owned(0) == 4
        assert len(al.drain_swap_ins()) == 2       # only snapshots restore
        al.check_tier_invariants()

    def test_spilled_shared_pages_never_park_in_cached_lru(self):
        """The one-home-per-content bugfix: a page whose bytes live on in
        a host snapshot is unregistered from the prefix index when its
        last HBM holder releases it — free list, never the cached LRU
        (a later prefix attach would resurrect a page a resume is about
        to overwrite)."""
        al = KVBlockAllocator(n_pages=16, page_tokens=4, spill_pages=8)
        prompt = np.arange(100, 112)               # 3 full pages
        al.ensure_prompt(0, prompt)
        al.register_prefix(0, prompt, 12)
        al.ensure_prompt(1, prompt)        # attaches 2, COWs the tail
        shared = al.table(0)[:2]
        assert al.table(1)[:2] == shared
        assert al.table(1)[2] != al.table(0)[2]
        assert al.spill_request(1)                 # snapshots shared pages
        al.drain_spill_outs()
        al.free_request(0)                         # last HBM holder gone
        assert set(shared).isdisjoint(al._cached)
        assert set(shared) <= set(al._free)
        assert al.stats.spill_unregistered == 2
        al.check_tier_invariants()
        # a fresh identical prompt gets no stale attach
        ok, cached = al.ensure_prompt(2, prompt)
        assert ok and cached == 0
        al.check_tier_invariants()

    def test_free_while_spilled_recycles_slots(self):
        al = KVBlockAllocator(n_pages=8, page_tokens=4, spill_pages=3)
        al.ensure(0, 12)
        assert al.spill_request(0)
        al.drain_spill_outs()
        assert al.spill_slots_free == 0
        al.free_request(0)                         # snapshot discarded
        assert al.spill_slots_free == 3 and not al.is_spilled(0)
        al.check_tier_invariants()

    def test_slots_drain_before_recycling(self):
        """Resumed slots stay off the free list until the engine takes
        the host->device restores — recycling them earlier would let a
        new spill overwrite bytes still queued for restore."""
        al = KVBlockAllocator(n_pages=8, page_tokens=4, spill_pages=2)
        al.ensure(0, 8)
        assert al.spill_request(0)
        al.drain_spill_outs()
        assert al.resume_spilled(0)
        assert al.spill_slots_free == 0            # draining, not free
        al.ensure(1, 8)
        assert not al.spill_request(1)             # tier genuinely full
        al.drain_swap_ins()
        assert al.spill_slots_free == 2
        assert al.spill_request(1)
        al.check_tier_invariants()


class TestHostSpillPool:
    def _planes(self, rng, n, layers=2, page=4, kv=2, d=8):
        k = rng.normal(size=(n, layers, page, kv, d)).astype(np.float32)
        v = rng.normal(size=(n, layers, page, kv, d)).astype(np.float32)
        s = rng.normal(size=(n, layers, kv, d)).astype(np.float32)
        return k, v, s

    def test_uncompressed_roundtrip_is_bitwise(self):
        rng = np.random.default_rng(0)
        pool = HostSpillPool(4, 2, 4, 2, 8, np.dtype(np.float32))
        k, v, s = self._planes(rng, 3)
        pool.store([0, 2, 3], k, v, s)
        k2, v2, s2 = pool.load([0, 2, 3])
        assert np.array_equal(k, k2) and np.array_equal(v, v2)
        assert np.array_equal(s, s2)
        assert pool.error_bound([0, 2, 3]) == 0.0

    def test_int8_roundtrip_within_scale_bound(self):
        rng = np.random.default_rng(1)
        pool = HostSpillPool(4, 2, 4, 2, 8, np.dtype(np.float32),
                             compress=True)
        k, v, s = self._planes(rng, 2)
        pool.store([1, 3], k, v, s)
        k2, v2, s2 = pool.load([1, 3])
        bound = pool.error_bound([1, 3])
        assert bound > 0.0
        assert np.abs(k - k2).max() <= bound + 1e-6
        assert np.abs(v - v2).max() <= bound + 1e-6
        # page summaries drive TopK selection: always stored exact
        assert np.array_equal(s, s2)

    def test_int8_halves_host_bytes(self):
        a = HostSpillPool(4, 2, 4, 2, 8, np.dtype(np.float16))
        b = HostSpillPool(4, 2, 4, 2, 8, np.dtype(np.float16),
                          compress=True)
        assert b.host_bytes < a.host_bytes


def _mk(cfg, params, work, n_pages, **kw):
    from repro.serve.engine import PagedEngine

    eng = PagedEngine(cfg, params, max_len=48, n_pages=n_pages,
                      max_batch=4, chunk=8, nsb_pages=8, **kw)
    eng.run([(t, p.copy(), g) for t, p, g in work])
    eng.allocator.check_tier_invariants()
    return eng


@pytest.mark.slow
class TestSpillEngine:
    @pytest.fixture(scope="class")
    def setup(self):
        import jax

        from repro.configs import get_config
        from repro.models import api

        cfg = get_config("qwen2-1.5b").reduced()
        params = api.init_params(cfg, jax.random.PRNGKey(0))
        rng = np.random.default_rng(3)
        work = [(0.0, rng.integers(1, cfg.vocab, size=12), 6)
                for _ in range(5)]
        return cfg, params, work

    def test_swap_resume_bitwise_identical(self, setup):
        """Forced preemption with the spill tier: swap-out + swap-in
        reproduces the recompute run bit-for-bit (same tokens, same
        logits) while skipping the re-prefill."""
        cfg, params, work = setup
        base = _mk(cfg, params, work, 9)                 # recompute
        swap = _mk(cfg, params, work, 9, spill_pages=16)
        assert base.scheduler.n_preemptions > 0
        assert swap.scheduler.n_swap_outs > 0
        assert swap.scheduler.n_swap_ins == swap.scheduler.n_swap_outs
        assert swap.stats.swap_in_pages == swap.stats.swap_out_pages > 0
        for rid in base.requests:
            a, b = base.requests[rid], swap.requests[rid]
            assert a.out_tokens == b.out_tokens
            assert np.array_equal(a.last_logits, b.last_logits)
        # swap resumes skip the replay prefill the recompute path pays
        assert (swap.scheduler.prefill_tokens_skipped
                < base.scheduler.prefill_tokens_skipped) \
            or swap.stats.prefill_tokens < base.stats.prefill_tokens
        assert swap.allocator.pages_spilled == 0         # tier drained
        m = swap.metrics()
        assert m["swap_outs"] > 0 and m["spill_host_mib"] > 0

    def test_cow_shared_pages_spill_bitwise(self, setup):
        """A request holding COW-shared prefix pages is spilled while
        another request still holds them: the snapshot reads shared
        bytes, resume lands on private ids, logits stay bitwise."""
        cfg, params, _ = setup
        rng = np.random.default_rng(5)
        sys_p = rng.integers(1, cfg.vocab, size=8)       # 2 shared pages
        work = [(0.0, np.concatenate(
            [sys_p, rng.integers(1, cfg.vocab, size=6)]), 6)
            for _ in range(5)]
        base = _mk(cfg, params, work, 10)
        swap = _mk(cfg, params, work, 10, spill_pages=16)
        assert swap.scheduler.n_swap_outs > 0
        assert swap.allocator.stats.prefix_hits > 0
        for rid in base.requests:
            a, b = base.requests[rid], swap.requests[rid]
            assert a.out_tokens == b.out_tokens
            assert np.array_equal(a.last_logits, b.last_logits)

    def test_runahead_fetch_back_bitwise(self, setup):
        """nvr runahead + spill: fetch-back swap-resumes the queue head
        in the between-steps window and pre-stages its history pages —
        still bitwise vs the recompute baseline."""
        cfg, params, work = setup
        base = _mk(cfg, params, work, 9)
        ra = _mk(cfg, params, work, 9, spill_pages=16,
                 runahead="nvr", runahead_pages=8)
        assert ra.stats.fetch_backs > 0
        for rid in base.requests:
            a, b = base.requests[rid], ra.requests[rid]
            assert a.out_tokens == b.out_tokens
            assert np.array_equal(a.last_logits, b.last_logits)

    def test_int8_spill_stays_within_reported_bound(self, setup):
        """Compressed spill completes the oversubscribed workload and
        reports the worst-case dequantisation bound it actually hit;
        logits track the exact run within a loose envelope of it."""
        cfg, params, work = setup
        base = _mk(cfg, params, work, 9, spill_pages=16)
        q = _mk(cfg, params, work, 9, spill_pages=16, spill_compress=True)
        assert q.scheduler.n_swap_outs > 0
        m = q.metrics()
        assert m["spill_compressed"]
        assert 0.0 < m["spill_dequant_error_bound"] < 0.5
        assert all(r.state is RequestState.FINISHED
                   for r in q.requests.values())
        for rid in base.requests:
            np.testing.assert_allclose(
                base.requests[rid].last_logits,
                q.requests[rid].last_logits, atol=0.5, rtol=0.1)

    def test_resume_ttft_metrics_both_policies(self, setup):
        """Resume-TTFT (re-admission to next new token) is measured for
        recompute *and* swap so the bench comparison is apples-to-apples
        — and swap's gap excludes the replay the recompute path pays."""
        cfg, params, work = setup
        base = _mk(cfg, params, work, 9)
        swap = _mk(cfg, params, work, 9, spill_pages=16)
        mb, ms = base.metrics(), swap.metrics()
        assert mb["n_resumes"] > 0 and ms["n_resumes"] > 0
        assert ms["p50_resume_ttft"] <= mb["p50_resume_ttft"]
        assert "swap_outs" not in mb                 # gated on the tier

    def test_capture_tags_swap_traffic_as_host_tier(self, setup):
        cfg, params, work = setup
        eng = _mk(cfg, params, work, 9, spill_pages=16,
                  capture_trace=True)
        rec = eng.recorder
        assert capture.TIER_HOST in rec.tier_ids()
        host = rec.subset_tier(capture.TIER_HOST)
        assert host.n_events > 0
        hbm = rec.subset_tier(capture.TIER_HBM)
        assert hbm.n_events > 0
        assert host.n_events + hbm.n_events <= rec.n_events
