"""Paged expert-weight pool: layout, parity, serve-path invariance.

The soundness bar is **bitwise**: dense-materialised, block-table-paged
and paged+runahead expert FFNs must produce identical tokens and logits
— gathers are pure copies, the math downstream shares one function
(``expert_pool._combine``), and staged NSB-tail copies are byte-exact
relocations of read-only weights.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import api
from repro.serve import expert_pool
from repro.serve.engine import PagedEngine
from repro.serve.runahead import make_router_scorer


@pytest.fixture(scope="module")
def moe_setup():
    cfg = get_config("qwen3-moe-235b-a22b").reduced()
    params = api.init_params(cfg, jax.random.PRNGKey(0))
    return cfg, params


@pytest.fixture(scope="module")
def pool(moe_setup):
    cfg, params = moe_setup
    return expert_pool.ExpertPool(cfg, params, tile_rows=32, nsb_slots=8)


class TestLayout:
    def test_page_id_space(self, moe_setup, pool):
        cfg, params = moe_setup
        l, e = cfg.n_layers, cfg.n_experts
        nt = (cfg.d_ff_expert or cfg.d_ff) // 32
        assert pool.n_pages == 1 + l * e * 3 * nt
        assert pool.pool.shape == (pool.n_pages + 8, 32, cfg.d_model)
        # page 0 is the zero scratch page; the tail starts zeroed
        assert not np.asarray(pool.pool[0]).any()
        assert not np.asarray(pool.pool[pool.n_pages:]).any()
        # affine layout: one expert's tiles are one contiguous range
        for li in range(l):
            for ei in range(e):
                pages = pool.pages_for_experts(li, [ei])
                assert len(pages) == pool.pages_per_expert == 3 * nt
                assert (np.diff(np.sort(pages)) == 1).all()

    def test_pages_hold_the_weights(self, moe_setup, pool):
        cfg, params = moe_setup
        lp = params["layers"]
        bt = pool.block_table
        # gate/up planes transpose [D,F] -> [F,D]; down stays [F,D]
        got = np.asarray(pool.pool[bt[1, 2, expert_pool.PLANE_GATE]]
                         ).reshape(-1, cfg.d_model)
        want = np.asarray(lp["we_gate"][1, 2]).T
        np.testing.assert_array_equal(got, want)
        got = np.asarray(pool.pool[bt[0, 3, expert_pool.PLANE_DOWN]]
                         ).reshape(-1, cfg.d_model)
        np.testing.assert_array_equal(got, np.asarray(lp["we_down"][0, 3]))

    def test_dense_rows_same_bytes(self, moe_setup, pool):
        cfg, _ = moe_setup
        rows = np.asarray(pool.dense_rows())
        assert rows.shape[:3] == (cfg.n_layers, cfg.n_experts, 3)
        np.testing.assert_array_equal(
            rows[1, 0], np.asarray(pool.pool[pool.block_table[1, 0]]))

    def test_tile_rows_must_divide(self, moe_setup):
        cfg, params = moe_setup
        with pytest.raises(ValueError, match="must divide"):
            expert_pool.ExpertPool(cfg, params, tile_rows=24)


class TestFFNParity:
    def test_dense_vs_paged_bitwise(self, moe_setup, pool):
        cfg, params = moe_setup
        x = jnp.asarray(np.random.default_rng(1).normal(
            size=(5, 1, cfg.d_model)), pool.pool.dtype)
        lp = jax.tree.map(lambda a: a[0], params["layers"])
        rows = pool.dense_rows()
        yd, ed = expert_pool.dense_moe_ffn(x, lp, rows[0], cfg)
        yp, ep = expert_pool.paged_moe_ffn(
            x, lp, pool.table_device()[0], pool.pool, cfg)
        np.testing.assert_array_equal(np.asarray(yd), np.asarray(yp))
        np.testing.assert_array_equal(np.asarray(ed), np.asarray(ep))

    def test_hot_remap_is_value_invisible(self, moe_setup, pool):
        """Staged tail copies are byte-exact: resolving reads through
        the hot-map must not change a single bit."""
        cfg, _ = moe_setup
        x = jnp.asarray(np.random.default_rng(2).normal(
            size=(4, 1, cfg.d_model)), pool.pool.dtype)
        lp = jax.tree.map(lambda a: a[0],
                          moe_setup[1]["layers"])
        bt0 = pool.table_device()[0]
        base, _ = expert_pool.paged_moe_ffn(x, lp, bt0, pool.pool, cfg)
        # stage pages 1..8 into the tail and point the hot map at them
        staged = pool.pool.at[pool.n_pages:pool.n_pages + 8].set(
            pool.pool[1:9])
        hot = np.full(pool.n_pages, -1, np.int32)
        hot[1:9] = np.arange(8)
        got, _ = expert_pool.paged_moe_ffn(
            x, lp, bt0, staged, cfg, hot_map=jnp.asarray(hot),
            n_demand=pool.n_pages)
        np.testing.assert_array_equal(np.asarray(base), np.asarray(got))

    def test_pallas_kernel_path(self, moe_setup, pool):
        cfg, params = moe_setup
        x = jnp.asarray(np.random.default_rng(3).normal(
            size=(4, 1, cfg.d_model)), pool.pool.dtype)
        lp = jax.tree.map(lambda a: a[0], params["layers"])
        bt0 = pool.table_device()[0]
        ref, er = expert_pool.paged_moe_ffn(x, lp, bt0, pool.pool, cfg)
        got, eg = expert_pool.paged_moe_ffn(x, lp, bt0, pool.pool, cfg,
                                            kernel="pallas")
        np.testing.assert_array_equal(np.asarray(er), np.asarray(eg))
        np.testing.assert_allclose(np.asarray(got, np.float32),
                                   np.asarray(ref, np.float32),
                                   rtol=2e-5, atol=2e-5)

    def test_route_matches_moe_routing(self, moe_setup):
        """The serve route() must pick the same experts as the training
        path's dispatch (``moe._route_row``) — prediction and demand
        live in one id space."""
        from repro.models import moe

        cfg, params = moe_setup
        xr = jnp.asarray(np.random.default_rng(4).normal(
            size=(6, cfg.d_model)), jnp.float32)
        router = params["layers"]["router"][0]
        _, eids = expert_pool.route(xr, router, cfg.top_k)
        logits = jnp.einsum("sd,de->se", xr, router.astype(jnp.float32))
        _, want = jax.lax.top_k(logits, cfg.top_k)
        np.testing.assert_array_equal(np.asarray(eids), np.asarray(want))


class TestRouterScorer:
    def test_predicts_layer0_routing(self, moe_setup):
        cfg, params = moe_setup
        fn = make_router_scorer(cfg)
        token = jnp.asarray([3, 99, 1024, 7], jnp.int32)
        eids = np.asarray(fn(params, token))
        assert eids.shape == (4, cfg.top_k)
        assert (eids >= 0).all() and (eids < cfg.n_experts).all()


def _run_engine(cfg, params, workload, **kw):
    eng = PagedEngine(cfg, params, n_pages=24, max_batch=4, chunk=16,
                      **kw)
    for p, g in workload:
        eng.submit(p, g)
    eng.run()
    return eng


@pytest.fixture(scope="module")
def workload(moe_setup):
    cfg, _ = moe_setup
    rng = np.random.default_rng(5)
    return [(list(rng.integers(1, cfg.vocab, size=int(n))), int(g))
            for n, g in zip(rng.integers(6, 20, size=6),
                            rng.integers(4, 9, size=6))]


class TestServeParity:
    def test_bitwise_across_modes(self, moe_setup, workload):
        cfg, params = moe_setup
        engines = {
            "dense": _run_engine(cfg, params, workload,
                                 expert_pool="dense"),
            "paged": _run_engine(cfg, params, workload,
                                 expert_pool="paged"),
            "router": _run_engine(cfg, params, workload,
                                  expert_pool="paged",
                                  expert_runahead="router",
                                  expert_nsb_slots=8,
                                  expert_runahead_pages=8),
        }
        base = engines["dense"]
        for name, eng in engines.items():
            for rid, a in base.requests.items():
                b = eng.requests[rid]
                assert a.out_tokens == b.out_tokens, (name, rid)
                np.testing.assert_array_equal(a.last_logits,
                                              b.last_logits)
        m = engines["router"].metrics()
        assert m["expert_pool"] == "paged"
        assert m["expert_runahead_mode"] == "router"
        assert m["expert_pages_touched"] > 0
        assert m["expert_staged_pages"] > 0

    def test_async_executor_parity(self, moe_setup, workload):
        cfg, params = moe_setup
        kw = dict(expert_pool="paged", expert_runahead="router",
                  expert_nsb_slots=8, expert_runahead_pages=8)
        sync = _run_engine(cfg, params, workload, **kw)
        pipe = _run_engine(cfg, params, workload, executor="async", **kw)
        for rid, a in sync.requests.items():
            b = pipe.requests[rid]
            assert a.out_tokens == b.out_tokens
            np.testing.assert_array_equal(a.last_logits, b.last_logits)

    def test_capture_tier_tags(self, moe_setup, workload):
        from repro.core.nvr import capture

        cfg, params = moe_setup
        eng = _run_engine(cfg, params, workload, expert_pool="paged",
                          expert_runahead="router", expert_nsb_slots=8,
                          expert_runahead_pages=8, capture_trace=True)
        rec = eng.ep_recorder
        assert rec.n_events > 0
        tiers = set(rec.tier_ids())
        assert capture.TIER_HBM in tiers     # demand gathers
        assert capture.TIER_NSB in tiers     # staged tile copies
        # every recorded page id lives in the demand region
        for ev in rec.events:
            assert ev.min() >= 1 and ev.max() < eng.ep.n_pages
        # the demand view lowers to a simulator trace
        tr = rec.subset_tier(capture.TIER_HBM).to_trace()
        assert tr is not None

    def test_validation(self, moe_setup):
        cfg, params = moe_setup
        with pytest.raises(ValueError, match="expert_pool must be"):
            PagedEngine(cfg, params, n_pages=24, expert_pool="bogus")
        with pytest.raises(ValueError, match="needs expert_pool"):
            PagedEngine(cfg, params, n_pages=24, expert_pool="dense",
                        expert_runahead="router")
        dense_cfg = get_config("qwen2-1.5b").reduced()
        dp = api.init_params(dense_cfg, jax.random.PRNGKey(0))
        with pytest.raises(ValueError, match="MoE-family"):
            PagedEngine(dense_cfg, dp, n_pages=24, expert_pool="paged")
