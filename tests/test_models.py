"""Per-architecture smoke tests (reduced configs, one fwd/train step on
CPU, output shapes + no NaNs) and decode/forward consistency."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_NAMES, get_config, smoke_shape
from repro.models import api, transformer as T

KEY = jax.random.PRNGKey(0)


@pytest.mark.parametrize("arch", ARCH_NAMES)
def test_arch_smoke_train_step(arch):
    cfg = get_config(arch).reduced()
    params = api.init_params(cfg, KEY)
    cell = smoke_shape("train")
    batch = api.make_inputs(cfg, cell, KEY)
    loss, grads = jax.value_and_grad(
        lambda p: api.loss_fn(cfg, p, batch, remat="none"))(params)
    assert np.isfinite(float(loss))
    assert 0.5 * np.log(cfg.vocab) < float(loss) < 3 * np.log(cfg.vocab)
    for g in jax.tree.leaves(grads):
        assert bool(jnp.all(jnp.isfinite(g)))


@pytest.mark.parametrize("arch", ARCH_NAMES)
def test_arch_smoke_decode_step(arch):
    cfg = get_config(arch).reduced()
    params = api.init_params(cfg, KEY)
    b, max_len = 2, 32
    cache = api.init_cache(cfg, b, max_len, params=params)
    token = jnp.zeros((b,), jnp.int32)
    logits, cache2 = api.decode_fn(cfg, params, cache, token)
    assert logits.shape == (b, cfg.vocab)
    assert bool(jnp.all(jnp.isfinite(logits)))
    assert int(cache2["pos"]) == 1
    logits3, _ = api.decode_fn(cfg, params, cache2, token)
    assert bool(jnp.all(jnp.isfinite(logits3)))


@pytest.fixture(scope="module")
def dense_setup():
    from repro.configs.base import ArchConfig
    cfg = ArchConfig(name="t", family="dense", n_layers=2, d_model=64,
                     n_heads=4, n_kv_heads=2, d_ff=128, vocab=256,
                     head_dim=16, act="swiglu", qkv_bias=True,
                     tie_embeddings=True, param_dtype="float32",
                     kv_page=4, kv_topk_pages=16)
    params = T.init_params(cfg, KEY)
    toks = jax.random.randint(KEY, (2, 32), 0, cfg.vocab)
    return cfg, params, toks


def _pad_cache(cfg, cache, max_len):
    l, b, s, kv, d = cache["k"].shape
    z = jnp.zeros((l, b, max_len - s, kv, d), cache["k"].dtype)
    out = {"k": jnp.concatenate([cache["k"], z], 2),
           "v": jnp.concatenate([cache["v"], z], 2), "pos": cache["pos"]}
    npad = max_len // cfg.kv_page - cache["kpage"].shape[2]
    out["kpage"] = jnp.concatenate(
        [cache["kpage"], jnp.zeros((l, b, npad, kv, d), jnp.float32)], 2)
    return out


class TestDecodeConsistency:
    def test_prefill_matches_forward(self, dense_setup):
        cfg, params, toks = dense_setup
        logits_p, _ = T.prefill(params, cfg, toks)
        hidden, _ = T.forward(params, cfg, toks)
        np.testing.assert_allclose(
            np.asarray(logits_p),
            np.asarray(T.logits_last(params, cfg, hidden)),
            rtol=1e-5, atol=1e-5)

    def test_dense_decode_matches_forward(self, dense_setup):
        cfg, params, toks = dense_setup
        logits_p, cache = T.prefill(params, cfg, toks)
        cache = _pad_cache(cfg, cache, 64)
        nxt = jnp.argmax(logits_p, -1)
        lg_dec, _ = T.decode_step(params, cfg, cache, nxt, sparse=False)
        toks2 = jnp.concatenate([toks, nxt[:, None]], 1)
        h2, _ = T.forward(params, cfg, toks2)
        np.testing.assert_allclose(
            np.asarray(lg_dec),
            np.asarray(T.logits_last(params, cfg, h2)),
            rtol=3e-4, atol=3e-4)

    def test_sparse_decode_full_coverage_matches_dense(self, dense_setup):
        cfg, params, toks = dense_setup
        logits_p, cache = T.prefill(params, cfg, toks)
        cache = _pad_cache(cfg, cache, 64)
        nxt = jnp.argmax(logits_p, -1)
        lg_dense, _ = T.decode_step(params, cfg, cache, nxt, sparse=False)
        lg_sparse, _ = T.decode_step(params, cfg, cache, nxt, sparse=True)
        np.testing.assert_allclose(np.asarray(lg_sparse),
                                   np.asarray(lg_dense),
                                   rtol=3e-3, atol=3e-3)

    def test_sparse_decode_low_coverage_approximates(self, dense_setup):
        """Dropping pages degrades gracefully.  Note: at *random init*
        attention is diffuse (no heavy hitters), so this is the worst case
        for TopK sparsity — trained models concentrate much harder."""
        cfg, params, toks = dense_setup
        for k_pages, min_corr in ((8, 0.9), (6, 0.8)):
            cfgk = dataclasses.replace(cfg, kv_topk_pages=k_pages)
            logits_p, cache = T.prefill(params, cfgk, toks)
            cache = _pad_cache(cfgk, cache, 64)
            nxt = jnp.argmax(logits_p, -1)
            lg_dense, _ = T.decode_step(params, cfgk, cache, nxt,
                                        sparse=False)
            lg_sparse, _ = T.decode_step(params, cfgk, cache, nxt,
                                         sparse=True)
            d = np.asarray(lg_dense)
            s = np.asarray(lg_sparse)
            corr = np.corrcoef(d.ravel(), s.ravel())[0, 1]
            assert corr > min_corr, (k_pages, corr)


def test_ssm_decode_matches_forward():
    from repro.models import ssm
    cfg = get_config("mamba2-130m").reduced()
    params = ssm.init_params(cfg, KEY)
    toks = jax.random.randint(KEY, (2, 16), 0, cfg.vocab)
    cache = ssm.init_cache(cfg, 2)
    for t in range(8):
        logits_seq, cache = ssm.decode_step(params, cfg, cache, toks[:, t])
    hidden = ssm.forward(params, cfg, toks[:, :8])
    logits_full = jnp.einsum("bd,vd->bv", hidden[:, -1].astype(jnp.float32),
                             params["embed"].astype(jnp.float32))
    np.testing.assert_allclose(np.asarray(logits_seq),
                               np.asarray(logits_full), rtol=3e-3, atol=3e-3)


def test_hybrid_decode_matches_forward():
    from repro.models import hybrid
    cfg = get_config("recurrentgemma-9b").reduced()
    cfg = dataclasses.replace(cfg, n_layers=5)   # 1 group + 2-layer tail
    params = hybrid.init_params(cfg, KEY)
    toks = jax.random.randint(KEY, (2, 24), 0, cfg.vocab)
    cache = hybrid.init_cache(cfg, 2, max_len=24)
    for t in range(12):
        logits_seq, cache = hybrid.decode_step(params, cfg, cache,
                                               toks[:, t])
    hidden = hybrid.forward(params, cfg, toks[:, :12])
    logits_full = jnp.einsum("bd,vd->bv", hidden[:, -1].astype(jnp.float32),
                             params["embed"].astype(jnp.float32))
    np.testing.assert_allclose(np.asarray(logits_seq),
                               np.asarray(logits_full), rtol=5e-3, atol=5e-3)


def test_unroll_matches_scan(dense_setup):
    cfg, params, toks = dense_setup
    labels = jnp.roll(toks, -1, 1)
    l_scan = T.loss_fn(params, cfg, toks, labels, remat="none")
    l_unroll = T.loss_fn(params, cfg, toks, labels, remat="none",
                         unroll=True)
    np.testing.assert_allclose(float(l_scan), float(l_unroll), rtol=1e-5)


def test_params_count_close_to_reference():
    # tinyllama is 1.1B; analytic count should be within 5%
    cfg = get_config("tinyllama-1.1b")
    assert abs(cfg.params_count() - 1.1e9) / 1.1e9 < 0.05
    moe = get_config("qwen3-moe-235b-a22b")
    assert 200e9 < moe.params_count() < 280e9
    assert 15e9 < moe.active_params_count() < 30e9


def test_ssm_prefill_then_decode_matches_forward():
    from repro.models import ssm
    cfg = get_config("mamba2-130m").reduced()
    params = ssm.init_params(cfg, KEY)
    toks = jax.random.randint(KEY, (2, 24), 0, cfg.vocab)
    lg, cache = ssm.prefill(params, cfg, toks[:, :16], remat="none")
    for t in range(16, 20):
        lg, cache = ssm.decode_step(params, cfg, cache, toks[:, t])
    hidden = ssm.forward(params, cfg, toks[:, :20])
    lf = jnp.einsum("bd,vd->bv", hidden[:, -1].astype(jnp.float32),
                    params["embed"].astype(jnp.float32))
    np.testing.assert_allclose(np.asarray(lg), np.asarray(lf), rtol=3e-3,
                               atol=3e-3)


def test_hybrid_prefill_then_decode_matches_forward():
    from repro.models import hybrid
    cfg = dataclasses.replace(get_config("recurrentgemma-9b").reduced(),
                              n_layers=5, window=8)
    params = hybrid.init_params(cfg, KEY)
    toks = jax.random.randint(KEY, (2, 24), 0, cfg.vocab)
    lg, cache = hybrid.prefill(params, cfg, toks[:, :16], remat="none")
    for t in range(16, 20):
        lg, cache = hybrid.decode_step(params, cfg, cache, toks[:, t])
    hidden = hybrid.forward(params, cfg, toks[:, :20])
    lf = jnp.einsum("bd,vd->bv", hidden[:, -1].astype(jnp.float32),
                    params["embed"].astype(jnp.float32))
    np.testing.assert_allclose(np.asarray(lg), np.asarray(lf), rtol=5e-3,
                               atol=5e-3)


def test_int8_kv_cache_quality():
    """int8 KV (beyond-paper §Perf lever): decode logits match bf16-cache
    decode almost exactly (fixed-scale symmetric quant)."""
    from repro.configs.base import ArchConfig
    cfg = ArchConfig(name="t8", family="dense", n_layers=2, d_model=64,
                     n_heads=4, n_kv_heads=2, d_ff=128, vocab=256,
                     head_dim=16, act="swiglu", tie_embeddings=True,
                     param_dtype="float32", kv_page=4, kv_topk_pages=16)
    params = T.init_params(cfg, KEY)
    toks = jax.random.randint(KEY, (2, 32), 0, cfg.vocab)

    def run(kv_dtype, sparse):
        c = dataclasses.replace(cfg, kv_dtype=kv_dtype)
        logits_p, cache = T.prefill(params, c, toks)
        cache = _pad_cache(c, cache, 64) if kv_dtype != "int8" else cache
        if kv_dtype == "int8":
            l, b, s, kv, d = cache["k"].shape
            z = jnp.zeros((l, b, 64 - s, kv, d), cache["k"].dtype)
            cache = {"k": jnp.concatenate([cache["k"], z], 2),
                     "v": jnp.concatenate([cache["v"], z], 2),
                     "pos": cache["pos"],
                     "kpage": jnp.concatenate(
                         [cache["kpage"],
                          jnp.zeros((l, b, (64 - s) // c.kv_page, kv, d),
                                    jnp.float32)], 2)}
        nxt = jnp.argmax(logits_p, -1)
        lg, _ = T.decode_step(params, c, cache, nxt, sparse=sparse)
        return np.asarray(lg)

    for sparse in (False, True):
        ref_l = run("bfloat16", sparse)
        q8 = run("int8", sparse)
        corr = np.corrcoef(ref_l.ravel(), q8.ravel())[0, 1]
        assert corr > 0.995, (sparse, corr)
        assert (ref_l.argmax(-1) == q8.argmax(-1)).all()
