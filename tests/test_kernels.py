"""Per-kernel shape/dtype sweeps against the pure-jnp oracles (ref.py).

Kernels execute in interpret mode on CPU (the TPU lowering is exercised
structurally — BlockSpecs, scalar prefetch — with the same code path)."""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import (coalesce_indices, csr_to_ell, gather_rows,
                           gather_spmm, group_tokens_by_expert,
                           moe_dispatch_matmul, ops, sparse_decode_attn,
                           topk_pages)
from repro.kernels import ref

RNG = np.random.default_rng(42)


def rand(shape, dtype):
    x = RNG.normal(size=shape).astype(np.float32)
    return jnp.asarray(x, dtype=dtype)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("n,d,k", [(32, 128, 8), (64, 256, 24), (16, 512, 5)])
def test_gather_rows(n, d, k, dtype):
    idx = jnp.asarray(RNG.integers(0, n, k), jnp.int32)
    tbl = rand((n, d), dtype)
    out = gather_rows(idx, tbl)
    np.testing.assert_array_equal(np.asarray(out),
                                  np.asarray(ref.gather_rows_ref(idx, tbl)))


@pytest.mark.parametrize("dtype,rtol", [(jnp.float32, 1e-5),
                                        (jnp.bfloat16, 2e-2)])
@pytest.mark.parametrize("m,j,nin,n,bn", [(8, 4, 16, 128, 128),
                                          (16, 8, 32, 256, 128),
                                          (4, 16, 64, 512, 256)])
def test_gather_spmm(m, j, nin, n, bn, dtype, rtol):
    cols = jnp.asarray(RNG.integers(0, nin, (m, j)), jnp.int32)
    vals = rand((m, j), dtype)
    dense = rand((nin, n), dtype)
    out = gather_spmm(cols, vals, dense, block_n=bn)
    want = ref.gather_spmm_ref(cols, vals, dense)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=rtol, atol=rtol)


@pytest.mark.parametrize("dtype,rtol", [(jnp.float32, 3e-5),
                                        (jnp.bfloat16, 3e-2)])
@pytest.mark.parametrize("b,hkv,g,d,s,p,page", [
    (2, 2, 4, 64, 128, 6, 8),
    (1, 4, 2, 128, 256, 8, 16),
    (3, 1, 8, 64, 64, 4, 1),     # page=1: exact row selection
])
def test_sparse_decode_attn(b, hkv, g, d, s, p, page, dtype, rtol):
    q = rand((b, hkv, g, d), dtype)
    k = rand((b, s, hkv, d), dtype)
    v = rand((b, s, hkv, d), dtype)
    idx = jnp.asarray(RNG.integers(0, s // page, (b, hkv, p)), jnp.int32)
    out = sparse_decode_attn(idx, q, k, v, page_size=page)
    want = ref.sparse_decode_attn_ref(idx, q, k, v, page_size=page)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32),
                               rtol=rtol, atol=rtol)


@pytest.mark.parametrize("dtype,rtol", [(jnp.float32, 1e-4),
                                        (jnp.bfloat16, 3e-2)])
@pytest.mark.parametrize("t,d,e,f,bt", [(256, 128, 4, 256, 64),
                                        (128, 256, 8, 128, 32),
                                        (512, 64, 2, 512, 128)])
def test_moe_dispatch_matmul(t, d, e, f, bt, dtype, rtol):
    x = rand((t, d), dtype)
    w = rand((e, d, f), dtype)
    gids = jnp.asarray(RNG.integers(0, e, t // bt), jnp.int32)
    out = moe_dispatch_matmul(gids, x, w, block_t=bt,
                              block_f=min(f, 128), block_d=min(d, 128))
    want = ref.moe_dispatch_matmul_ref(gids, x, w, block_t=bt)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32),
                               rtol=rtol, atol=rtol * 10)


@pytest.mark.parametrize("capacity_factor", [1.25, 0.5, 0.25])
def test_moe_ffn_vs_dispatch_matmul_with_drops(capacity_factor):
    """``moe_ffn``'s einsum expert compute vs the ``moe_dispatch_matmul``
    grouped-GEMM kernel on the *same* dispatch plan (``moe._route_row``),
    including capacity factors low enough that pairs get dropped — the
    two paths must drop identically and agree on every surviving token."""
    import jax

    from repro.models import moe

    class Cfg:
        d_model, n_experts, top_k, d_ff_expert = 64, 4, 2, 128

    cfg, s, bt = Cfg(), 64, 16
    key = jax.random.PRNGKey(7)
    p = moe.init_moe(cfg, key, jnp.float32)
    x = rand((1, s, cfg.d_model), jnp.float32)
    want = moe.moe_ffn(x, p, cfg, capacity_factor=capacity_factor)

    e, k, d = cfg.n_experts, cfg.top_k, cfg.d_model
    cap = moe._capacity(s, k, e, capacity_factor)
    assert cap % bt == 0, "capacity is 16-aligned by construction"
    if capacity_factor < 1.0:
        assert cap < s * k / e + 1, "low factor must actually drop pairs"
    slot, keep, pair_token, gates, order = moe._route_row(
        x[0], p["router"].astype(jnp.float32), e, k, cap)
    src = jnp.where(keep[:, None], x[0][pair_token], 0.0)
    xg = jnp.zeros((e * cap, d), x.dtype).at[
        jnp.where(keep, slot, 0)].add(src, mode="drop")
    # grouped GEMMs over the dispatched rows: block group ids walk the
    # experts cap/bt blocks at a time
    gids = jnp.repeat(jnp.arange(e, dtype=jnp.int32), cap // bt)
    gate = jax.nn.silu(moe_dispatch_matmul(gids, xg, p["we_gate"],
                                           block_t=bt))
    up = moe_dispatch_matmul(gids, xg, p["we_up"], block_t=bt)
    yg = moe_dispatch_matmul(gids, gate * up, p["we_down"], block_t=bt)
    pair_out = jnp.where(keep[:, None], yg[slot], 0.0)
    pair_gate = gates.reshape(-1)[order].astype(yg.dtype)
    got = jnp.zeros((s, d), yg.dtype).at[pair_token].add(
        pair_out * pair_gate[:, None])
    np.testing.assert_allclose(np.asarray(got), np.asarray(want[0]),
                               rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("dtype,rtol", [(jnp.float32, 1e-5),
                                        (jnp.bfloat16, 2e-2)])
@pytest.mark.parametrize("tile_f", [16, 32, 64])
def test_moe_paged_gemm_vs_dense(tile_f, dtype, rtol):
    """Paged gate/up/down GEMMs vs the dense einsum on the same expert
    weights, across tile sizes: page 0 is the zero scratch page, pages
    1.. are each expert's ``[F, D]`` plane cut into ``tile_f``-row tiles
    (the expert_pool layout), and the tiling must be value-invisible."""
    from repro.kernels import moe_paged_down, moe_paged_gateup

    r, k, e, f, d = 4, 2, 4, 128, 64
    nt = f // tile_f
    wg = rand((e, f, d), dtype)               # gate/up plane, [F, D] rows
    wd = rand((e, f, d), dtype)               # down plane
    pool_g = jnp.concatenate([jnp.zeros((1, tile_f, d), dtype),
                              wg.reshape(e * nt, tile_f, d)])
    pool_d = jnp.concatenate([jnp.zeros((1, tile_f, d), dtype),
                              wd.reshape(e * nt, tile_f, d)])
    table = jnp.arange(1, 1 + e * nt, dtype=jnp.int32).reshape(e, nt)
    eids = jnp.asarray(RNG.integers(0, e, (r, k)), jnp.int32)
    pids = table[eids]                        # [R, K, NT]
    x = rand((r, d), dtype)
    h = rand((r, k, f), dtype)

    got_g = moe_paged_gateup(pids, x, pool_g)
    want_g = jnp.einsum("rd,rkfd->rkf", x.astype(jnp.float32),
                        wg[eids].astype(jnp.float32))
    np.testing.assert_allclose(np.asarray(got_g, np.float32),
                               np.asarray(want_g), rtol=rtol, atol=rtol)
    np.testing.assert_array_equal(
        np.asarray(got_g),
        np.asarray(ref.moe_paged_gateup_ref(pids, x, pool_g)))

    got_d = moe_paged_down(pids, h, pool_d)
    want_d = jnp.einsum("rkf,rkfd->rkd", h.astype(jnp.float32),
                        wd[eids].astype(jnp.float32))
    np.testing.assert_allclose(np.asarray(got_d, np.float32),
                               np.asarray(want_d), rtol=rtol,
                               atol=rtol * 10)
    np.testing.assert_allclose(
        np.asarray(got_d, np.float32),
        np.asarray(ref.moe_paged_down_ref(pids, h, pool_d), np.float32),
        rtol=rtol, atol=rtol * 10)


def test_coalesce_indices_roundtrip():
    idx = jnp.asarray(RNG.integers(0, 50, 64), jnp.int32)
    sorted_idx, inv = coalesce_indices(idx)
    assert bool(jnp.all(jnp.diff(sorted_idx) >= 0))
    np.testing.assert_array_equal(np.asarray(sorted_idx[inv]),
                                  np.asarray(idx))


def test_csr_to_ell_matches_dense():
    m, n = 16, 32
    dense = (RNG.random((m, n)) < 0.2) * RNG.normal(size=(m, n))
    rowptr = np.zeros(m + 1, np.int32)
    cols, vals = [], []
    for r in range(m):
        nz = np.nonzero(dense[r])[0]
        rowptr[r + 1] = rowptr[r] + len(nz)
        cols.extend(nz)
        vals.extend(dense[r, nz])
    ecols, evals = csr_to_ell(rowptr, np.array(cols, np.int32),
                              np.array(vals, np.float32))
    rhs = RNG.normal(size=(n, 8)).astype(np.float32)
    out = ref.gather_spmm_ref(jnp.asarray(ecols), jnp.asarray(evals),
                              jnp.asarray(rhs))
    np.testing.assert_allclose(np.asarray(out), dense @ rhs, rtol=1e-5,
                               atol=1e-5)


def test_topk_pages_selects_highest():
    scores = jnp.asarray(RNG.normal(size=(2, 3, 64)), jnp.float32)
    idx = topk_pages(scores, n_pages=8, page_size=8, k_pages=3)
    ps = np.asarray(scores).reshape(2, 3, 8, 8).max(-1)
    want = np.argsort(-ps, axis=-1)[..., :3]
    np.testing.assert_array_equal(np.sort(np.asarray(idx), -1),
                                  np.sort(want, -1))


@pytest.mark.parametrize("dtype,rtol", [(jnp.float32, 3e-4),
                                        (jnp.bfloat16, 3e-2)])
@pytest.mark.parametrize("b,s,h,kv,d,causal,bq,bk", [
    (2, 64, 4, 2, 32, True, 32, 32),
    (1, 128, 8, 8, 64, True, 64, 32),
    (2, 64, 4, 1, 32, False, 32, 64),
    (1, 256, 2, 2, 128, True, 128, 128),
])
def test_flash_prefill(b, s, h, kv, d, causal, bq, bk, dtype, rtol):
    from repro.kernels.flash_prefill import flash_prefill
    from repro.models.layers import chunked_attention
    q = rand((b, s, h, d), dtype)
    k = rand((b, s, kv, d), dtype)
    v = rand((b, s, kv, d), dtype)
    out = flash_prefill(q, k, v, causal=causal, block_q=bq, block_k=bk)
    ref_out = chunked_attention(q, k, v, causal=causal, chunk=32)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref_out, np.float32),
                               rtol=rtol, atol=rtol)
