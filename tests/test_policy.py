"""Scheduling-policy layer: admission order, eviction victims, the
decision-replay contract, and the trace-driven workload generator."""

import numpy as np
import pytest

from repro.serve.kv_allocator import KVBlockAllocator
from repro.serve.scheduler import (Request, RequestState, Scheduler,
                                   TraceArrivals)
from repro.serve.policy import (POLICIES, FifoPolicy, PriorityPolicy,
                                SchedPolicy, SloFairPolicy, make_policy)
from repro.serve.workload import (RequestSpec, TenantSpec, TurnSpec,
                                  bursty_multiturn,
                                  bursty_multiturn_tenants, load_trace,
                                  materialize, save_trace,
                                  shared_prefix_map, synthesize)


def _mk(rid, plen, gen, arrival=0.0, tenant="default", priority=0,
        slo_ttft=None, slo_tpot=None, seq=-1):
    r = Request(rid=rid, prompt=np.arange(plen), max_new_tokens=gen,
                arrival=arrival, tenant=tenant, priority=priority,
                slo_ttft=slo_ttft, slo_tpot=slo_tpot)
    r.admission_seq = seq
    return r


def _drive(sched, now):
    """One model-free iteration (same fake as test_serve's driver)."""
    plan = sched.schedule(now)
    for job in plan.prefill:
        job.req.computed += job.n_tokens
        if job.req.computed == job.req.prompt_len:
            job.req.out_tokens.append(0)
            job.req.first_token_at = now
            if job.req.done:        # max_new_tokens == 1
                sched.finish(job.req, now)
    for req in plan.decode:
        frontier = req.computed == req.total_len - 1
        req.computed += 1
        if frontier:
            req.out_tokens.append(0)
            if req.done:
                sched.finish(req, now)
    return plan


class TestMakePolicy:
    def test_name_resolution_and_passthrough(self):
        assert isinstance(make_policy("fifo"), FifoPolicy)
        assert isinstance(make_policy("priority"), PriorityPolicy)
        assert isinstance(make_policy("slo_fair"), SloFairPolicy)
        inst = SloFairPolicy()
        assert make_policy(inst) is inst

    def test_unknown_name_lists_choices(self):
        with pytest.raises(ValueError, match="slo_fair"):
            make_policy("lifo")

    def test_registry_names_match_instances(self):
        for name, cls in POLICIES.items():
            assert cls.name == name

    def test_base_hooks_are_abstract(self):
        with pytest.raises(NotImplementedError):
            SchedPolicy().admit_order([], 0.0)
        with pytest.raises(NotImplementedError):
            SchedPolicy().choose_victim([], None, 0.0)


class TestFifoPolicy:
    def test_admit_order_is_queue_order(self):
        waiting = [_mk(i, 4, 2, arrival=float(i)) for i in (3, 1, 2)]
        assert FifoPolicy().admit_order(waiting, 0.0) == waiting

    def test_victim_is_youngest_younger_than_requester(self):
        running = [_mk(i, 4, 2, seq=i) for i in range(4)]
        v = FifoPolicy().choose_victim(running, running[1], 0.0)
        assert v is running[3]

    def test_no_victim_when_requester_is_youngest(self):
        running = [_mk(i, 4, 2, seq=i) for i in range(3)]
        assert FifoPolicy().choose_victim(running, running[2], 0.0) is None


class TestPriorityPolicy:
    def test_classes_then_fifo_within_class(self):
        w = [_mk(0, 4, 2, priority=2), _mk(1, 4, 2, priority=0),
             _mk(2, 4, 2, priority=2), _mk(3, 4, 2, priority=0)]
        assert [r.rid for r in PriorityPolicy().admit_order(w, 0.0)] \
            == [1, 3, 0, 2]

    def test_victim_is_worst_class_youngest(self):
        running = [_mk(0, 4, 2, priority=0, seq=0),
                   _mk(1, 4, 2, priority=2, seq=1),
                   _mk(2, 4, 2, priority=2, seq=2),
                   _mk(3, 4, 2, priority=1, seq=3)]
        v = PriorityPolicy().choose_victim(running, running[0], 0.0)
        assert v is running[2]

    def test_never_evicts_an_outranking_request(self):
        running = [_mk(0, 4, 2, priority=0, seq=0)]
        low = _mk(1, 4, 2, priority=2, seq=1)
        assert PriorityPolicy().choose_victim(running, low, 0.0) is None


class TestSloFairPolicy:
    def test_token_cost_deficit_interleaves_tenants(self):
        """A burst of long batch prompts queued ahead of one cheap chat
        request: token-cost DRR pulls the chat request past all but the
        first batch prompt (classic DRR would not — per-request counting
        favours the tenant with fewer, bigger requests)."""
        pol = SloFairPolicy()
        w = [_mk(0, 40, 2, tenant="batch"), _mk(1, 40, 2, tenant="batch"),
             _mk(2, 40, 2, tenant="batch"), _mk(3, 4, 2, tenant="chat")]
        order = [r.rid for r in pol.admit_order(w, 0.0)]
        assert order.index(3) == 1      # behind exactly one batch prompt

    def test_served_charges_rebalance(self):
        pol = SloFairPolicy()
        chat, batch = _mk(0, 4, 2, tenant="chat"), _mk(1, 40, 2,
                                                       tenant="batch")
        pol.on_admit(batch, 0.0)
        # batch has consumed 40 tokens; chat's head-of-queue start tag
        # (0) beats batch's next (40)
        order = pol.admit_order([_mk(2, 40, 2, tenant="batch"),
                                 _mk(3, 4, 2, tenant="chat")], 1.0)
        assert [r.rid for r in order] == [3, 2]
        pol.on_admit(chat, 1.0)
        assert pol.served == {"batch": 40, "chat": 4}

    def test_admit_order_is_pure_and_complete(self):
        pol = SloFairPolicy()
        w = [_mk(i, 4 + i, 2, tenant=f"t{i % 3}") for i in range(7)]
        before = dict(pol.served)
        order = pol.admit_order(w, 0.0)
        assert pol.served == before                 # pure read
        assert sorted(r.rid for r in order) == list(range(7))

    def test_victim_prefers_no_slo_over_tight_slack(self):
        al = KVBlockAllocator(n_pages=16, page_tokens=4)
        s = Scheduler(al, max_batch=4, chunk=8, token_budget=64,
                      policy="slo_fair")
        urgent = _mk(0, 8, 4, arrival=0.0, tenant="chat",
                     slo_ttft=6.0, slo_tpot=2.0, seq=0)
        free = _mk(1, 8, 4, arrival=0.0, tenant="batch", seq=1)
        requester = _mk(2, 8, 4, arrival=0.0, tenant="chat",
                        slo_ttft=6.0, slo_tpot=2.0, seq=2)
        for r in (urgent, free, requester):
            al.ensure(r.rid, 8)
        v = s.policy.choose_victim([urgent, free], requester, 2.0, s)
        assert v is free

    def test_defers_requester_when_it_is_least_urgent(self):
        al = KVBlockAllocator(n_pages=16, page_tokens=4)
        s = Scheduler(al, max_batch=4, chunk=8, token_budget=64,
                      policy="slo_fair")
        urgent = _mk(0, 8, 4, arrival=0.0, tenant="chat",
                     slo_ttft=6.0, slo_tpot=2.0, seq=0)
        lazy = _mk(1, 8, 4, arrival=0.0, tenant="batch", seq=1)
        al.ensure(0, 8)
        al.ensure(1, 8)
        assert s.policy.choose_victim([urgent], lazy, 2.0, s) is None


class TestSchedulerPolicyIntegration:
    def test_priority_overtakes_fifo_admission(self):
        al = KVBlockAllocator(n_pages=65, page_tokens=4)
        s = Scheduler(al, max_batch=2, chunk=8, token_budget=64,
                      policy="priority")
        lows = [_mk(i, 8, 2, arrival=0.0, priority=2) for i in range(3)]
        hi = _mk(3, 8, 2, arrival=1.0, priority=0)
        for r in lows:
            s.add(r)
        s.add(hi)
        s.schedule(1.0)
        assert hi.state is RequestState.RUNNING      # jumped the queue
        assert lows[2].state is RequestState.WAITING

    def test_priority_eviction_never_inverts(self):
        al = KVBlockAllocator(n_pages=5, page_tokens=4)
        s = Scheduler(al, max_batch=2, chunk=8, token_budget=16,
                      policy="priority")
        hi = _mk(0, 8, 4, arrival=0.0, priority=0)
        lo = _mk(1, 8, 4, arrival=0.0, priority=2)
        s.add(lo)       # the low class arrives (and is admitted) first
        s.add(hi)
        for now in range(1, 60):
            _drive(s, float(now))
            if not s.has_work:
                break
        assert hi.done and lo.done
        assert hi.n_preemptions == 0    # high class never yielded
        assert lo.n_preemptions > 0

    def test_policy_object_passes_through(self):
        al = KVBlockAllocator(n_pages=16, page_tokens=4)
        pol = SloFairPolicy()
        s = Scheduler(al, max_batch=2, chunk=8, token_budget=16,
                      policy=pol)
        assert s.policy is pol


class TestTraceArrivalsValidation:
    def test_empty_schedule_rejected(self):
        with pytest.raises(ValueError, match="empty"):
            TraceArrivals([])

    def test_decreasing_times_rejected(self):
        with pytest.raises(ValueError, match="entry 1"):
            TraceArrivals([(2.0, 8, 4), (1.0, 8, 4)])

    def test_non_finite_tick_rejected(self):
        with pytest.raises(ValueError, match="entry 0"):
            TraceArrivals([(float("nan"), 8, 4)])

    def test_non_positive_lengths_rejected(self):
        with pytest.raises(ValueError, match="entry 0"):
            TraceArrivals([(0.0, 0, 4)])
        with pytest.raises(ValueError, match="entry 1"):
            TraceArrivals([(0.0, 8, 4), (1.0, 8, 0)])

    def test_valid_schedule_unchanged(self):
        tr = TraceArrivals([(0.0, 8, 4), (0.0, 4, 2), (2.5, 16, 2)])
        assert list(tr) == [(0.0, 8, 4), (0.0, 4, 2), (2.5, 16, 2)]


class TestWorkloadGenerator:
    def test_same_seed_same_workload(self):
        a = bursty_multiturn(32, seed=7)
        b = bursty_multiturn(32, seed=7)
        assert a == b
        sp = shared_prefix_map(bursty_multiturn_tenants())
        ia = materialize(a, 1000, seed=7, shared_prefix=sp)
        ib = materialize(b, 1000, seed=7, shared_prefix=sp)
        for x, y in zip(ia, ib):
            assert x.arrival == y.arrival and x.tenant == y.tenant
            assert np.array_equal(x.prompt, y.prompt)
            assert len(x.turns) == len(y.turns)
            for tx, ty in zip(x.turns, y.turns):
                assert np.array_equal(tx.user_tokens, ty.user_tokens)
                assert tx.think_time == ty.think_time

    def test_different_seed_differs(self):
        a = bursty_multiturn(32, seed=7)
        b = bursty_multiturn(32, seed=8)
        assert a != b

    def test_arrivals_sorted_and_lengths_bounded(self):
        tenants = [TenantSpec(name="t", prompt_cap=10, gen_cap=5,
                              multi_turn_p=0.5)]
        specs = synthesize(64, seed=3, tenants=tenants)
        ts = [s.arrival for s in specs]
        assert ts == sorted(ts)
        for s in specs:
            assert 1 <= s.prompt_len <= 10
            assert 1 <= s.max_new_tokens <= 5
            assert len(s.turns) < tenants[0].max_turns

    def test_shared_prefix_heads_match_within_tenant(self):
        specs = bursty_multiturn(32, seed=7)
        sp = shared_prefix_map(bursty_multiturn_tenants())
        items = materialize(specs, 1000, seed=7, shared_prefix=sp)
        chat = [i for i in items if i.tenant == "chat"]
        assert len(chat) >= 2
        head = sp["chat"]
        for i in chat[1:]:
            assert np.array_equal(i.prompt[:head], chat[0].prompt[:head])

    def test_synthesize_input_validation(self):
        with pytest.raises(ValueError, match="n_requests"):
            synthesize(0, seed=0, tenants=[TenantSpec(name="t")])
        with pytest.raises(ValueError, match="TenantSpec"):
            synthesize(4, seed=0, tenants=[])
        with pytest.raises(ValueError, match="weights"):
            synthesize(4, seed=0, tenants=[TenantSpec(name="t",
                                                      weight=0.0)])


class TestTraceFiles:
    def test_round_trip(self, tmp_path):
        specs = bursty_multiturn(16, seed=7)
        path = str(tmp_path / "trace.json")
        save_trace(path, specs, meta={"seed": 7})
        assert load_trace(path) == specs

    def test_version_mismatch_rejected(self, tmp_path):
        path = tmp_path / "bad.json"
        path.write_text('{"version": 99, "requests": [{}]}')
        with pytest.raises(ValueError, match="version"):
            load_trace(str(path))

    def test_empty_trace_rejected(self, tmp_path):
        path = tmp_path / "empty.json"
        path.write_text('{"version": 1, "requests": []}')
        with pytest.raises(ValueError, match="empty"):
            load_trace(str(path))

    def test_decreasing_arrivals_rejected(self, tmp_path):
        path = str(tmp_path / "dec.json")
        save_trace(path, [RequestSpec(arrival=1.0, prompt_len=4,
                                      max_new_tokens=2)])
        import json
        doc = json.load(open(path))
        doc["requests"].append(dict(doc["requests"][0], arrival=0.5))
        open(path, "w").write(json.dumps(doc))
        with pytest.raises(ValueError, match="non-decreasing"):
            load_trace(str(path))

    def test_bad_lengths_rejected(self, tmp_path):
        path = str(tmp_path / "len.json")
        save_trace(path, [RequestSpec(arrival=0.0, prompt_len=4,
                                      max_new_tokens=2)])
        import json
        doc = json.load(open(path))
        doc["requests"][0]["prompt_len"] = 0
        open(path, "w").write(json.dumps(doc))
        with pytest.raises(ValueError, match="entry 0"):
            load_trace(str(path))

    def test_committed_trace_loads(self):
        import os
        path = os.path.join(os.path.dirname(__file__), "..", "traces",
                            "bursty_multiturn.json")
        specs = load_trace(path)
        assert len(specs) == 48
        assert {s.tenant for s in specs} == {"chat", "assist", "batch"}
        assert any(s.turns for s in specs)
        # regenerable bit-for-bit from the preset
        assert specs == bursty_multiturn(48, seed=7)


class TestTurnSpecTotalLen:
    def test_total_len_spans_all_turns(self):
        s = RequestSpec(arrival=0.0, prompt_len=10, max_new_tokens=4,
                        turns=[TurnSpec(think_time=2.0, new_tokens=6,
                                        max_new_tokens=3)])
        assert s.total_len() == 10 + 4 + 6 + 3


# ---------------------------------------------------------------------------
# property tests
#
# Each property is a plain checker function exercised two ways: a seeded
# random sweep that always runs (hypothesis is an optional dependency in
# this image), and @given wrappers that shrink counterexamples when
# hypothesis is installed.
# ---------------------------------------------------------------------------

import random  # noqa: E402
from collections import Counter  # noqa: E402

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False


class _PreRefactorFifo(SchedPolicy):
    """The scheduler's decision logic as hardwired before the policy
    extraction, vendored verbatim: admit strictly in queue order; the
    eviction victim is the youngest admission (max ``admission_seq``)
    strictly younger than the requester.  FifoPolicy must be
    decision-equivalent to this on every schedule."""
    name = "fifo_vendored"

    def admit_order(self, waiting, now):
        return list(waiting)

    def choose_victim(self, running, requester, now, sched=None):
        victim = None
        for r in running:
            if r is requester or r.admission_seq <= requester.admission_seq:
                continue
            if victim is None or r.admission_seq > victim.admission_seq:
                victim = r
        return victim


def _rand_sched(rng):
    """(prompt_len, max_new_tokens, arrival_tick, tenant_idx) tuples."""
    return [(rng.randint(1, 20), rng.randint(1, 6), rng.randint(0, 6),
             rng.randint(0, 2)) for _ in range(rng.randint(1, 12))]


def _build(reqs, extra_pages, max_batch, policy):
    # +1: the allocator reserves one page, so usable pages = n_pages - 1
    need = max((p + g + 3) // 4 for p, g, _, _ in reqs)
    al = KVBlockAllocator(n_pages=need + 1 + extra_pages, page_tokens=4)
    s = Scheduler(al, max_batch=max_batch, chunk=8, token_budget=64,
                  policy=policy)
    pending = sorted(
        (_mk(i, p, g, arrival=float(t), tenant=f"t{ti}")
         for i, (p, g, t, ti) in enumerate(reqs)),
        key=lambda r: (r.arrival, r.rid))
    return s, pending


def _run_to_drain(s, pending, trace=None, max_ticks=600):
    pending = list(pending)
    now = 0.0
    for _ in range(max_ticks):
        while pending and pending[0].arrival <= now:
            s.add(pending.pop(0))
        plan = _drive(s, now)
        if trace is not None:
            trace.append((
                tuple(sorted((j.req.rid, j.n_tokens)
                             for j in plan.prefill)),
                tuple(sorted(r.rid for r in plan.decode)),
                tuple((r.rid, r.admission_seq) for r in s.waiting),
                s.n_preemptions,
            ))
        now += 1.0
        if not pending and not s.has_work:
            return True
    return False


def _check_fifo_equivalence(reqs, extra_pages, max_batch):
    """FifoPolicy is decision-equivalent to the vendored pre-refactor
    logic: identical per-tick plans, waiting queues, admission seqs and
    preemption counts."""
    ta, tb = [], []
    sa, pa = _build(reqs, extra_pages, max_batch, FifoPolicy())
    sb, pb = _build(reqs, extra_pages, max_batch, _PreRefactorFifo())
    assert _run_to_drain(sa, pa, ta)
    assert _run_to_drain(sb, pb, tb)
    assert ta == tb


def _check_no_starvation(reqs, extra_pages, max_batch):
    """Every request finishes under SloFairPolicy on any schedule that
    fits the pool — deficit round-robin may reorder but never starves."""
    s, pending = _build(reqs, extra_pages, max_batch, SloFairPolicy())
    reqs_all = list(pending)
    assert _run_to_drain(s, pending)
    assert all(r.done for r in reqs_all)
    assert all(r.state is RequestState.FINISHED for r in reqs_all)


class _AuditedSloFair(SloFairPolicy):
    """Records every admission charge so conservation can be checked
    against the policy's own counters."""
    def __init__(self):
        super().__init__()
        self.charges = []

    def on_admit(self, req, now):
        self.charges.append((req.rid, self._cost(req)))
        super().on_admit(req, now)


def _check_deficit_conservation(reqs, extra_pages, max_batch):
    """sum(served) equals the summed token cost of every admission —
    counters never leak, decay or double-charge outside on_admit."""
    pol = _AuditedSloFair()
    s, pending = _build(reqs, extra_pages, max_batch, pol)
    reqs_all = list(pending)
    assert _run_to_drain(s, pending)
    assert sum(pol.served.values()) == sum(c for _, c in pol.charges)
    by_rid = {r.rid: r for r in reqs_all}
    for rid, c in pol.charges:
        assert c == max(by_rid[rid].prompt_len, 1)
    # one charge per admission: the initial one plus at most one per
    # resume-after-preemption
    n_charges = Counter(rid for rid, _ in pol.charges)
    for rid, r in by_rid.items():
        assert 1 <= n_charges[rid] <= 1 + r.n_preemptions


def _check_admit_order_permutation(specs, served):
    """admit_order returns every waiting request exactly once and never
    mutates counters, whatever the prior served state."""
    pol = SloFairPolicy()
    pol.served.update(served)
    w = [_mk(i, p, 2, tenant=f"t{ti}") for i, (p, ti) in enumerate(specs)]
    before = dict(pol.served)
    order = pol.admit_order(w, 0.0)
    assert sorted(r.rid for r in order) == sorted(r.rid for r in w)
    assert pol.served == before


@pytest.mark.parametrize("seed", range(25))
def test_fifo_policy_matches_pre_refactor_decisions(seed):
    rng = random.Random(seed)
    _check_fifo_equivalence(_rand_sched(rng), rng.randint(0, 8),
                            rng.randint(1, 4))


@pytest.mark.parametrize("seed", range(25))
def test_slo_fair_no_starvation(seed):
    rng = random.Random(seed)
    _check_no_starvation(_rand_sched(rng), rng.randint(0, 8),
                         rng.randint(1, 4))


@pytest.mark.parametrize("seed", range(25))
def test_slo_fair_deficit_counters_conserved(seed):
    rng = random.Random(seed)
    _check_deficit_conservation(_rand_sched(rng), rng.randint(0, 8),
                                rng.randint(1, 4))


@pytest.mark.parametrize("seed", range(25))
def test_slo_fair_admit_order_is_permutation(seed):
    rng = random.Random(seed)
    specs = [(rng.randint(1, 40), rng.randint(0, 2))
             for _ in range(rng.randint(1, 16))]
    served = {f"t{i}": rng.randint(0, 200) for i in range(rng.randint(0, 3))}
    _check_admit_order_permutation(specs, served)


if HAVE_HYPOTHESIS:
    SET = settings(max_examples=25, deadline=None)
    _req_s = st.tuples(st.integers(1, 20), st.integers(1, 6),
                       st.integers(0, 6), st.integers(0, 2))
    _sched_s = st.lists(_req_s, min_size=1, max_size=12)
    _knobs = dict(extra_pages=st.integers(0, 8),
                  max_batch=st.integers(1, 4))

    @given(reqs=_sched_s, **_knobs)
    @SET
    def test_fifo_equivalence_hypothesis(reqs, extra_pages, max_batch):
        _check_fifo_equivalence(reqs, extra_pages, max_batch)

    @given(reqs=_sched_s, **_knobs)
    @SET
    def test_no_starvation_hypothesis(reqs, extra_pages, max_batch):
        _check_no_starvation(reqs, extra_pages, max_batch)

    @given(reqs=_sched_s, **_knobs)
    @SET
    def test_deficit_conservation_hypothesis(reqs, extra_pages,
                                             max_batch):
        _check_deficit_conservation(reqs, extra_pages, max_batch)

    @given(st.lists(st.tuples(st.integers(1, 40), st.integers(0, 2)),
                    min_size=1, max_size=16),
           st.dictionaries(st.sampled_from(["t0", "t1", "t2"]),
                           st.integers(0, 200)))
    @SET
    def test_admit_order_permutation_hypothesis(specs, served):
        _check_admit_order_permutation(specs, served)
