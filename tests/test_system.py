"""End-to-end behaviour tests: training learns, serving generates, the
NVR sparse path is a faithful accelerator of the dense path, and the
sharding rules produce coherent specs."""

import jax
import jax.numpy as jnp
import numpy as np

from repro import sharding
from repro.configs import get_config
from repro.data import pipeline
from repro.models import api
from repro.serve.engine import Engine
from repro.train import trainer


def test_training_reduces_loss():
    cfg = get_config("llama3.2-1b").reduced()
    dcfg = pipeline.DataConfig(vocab=cfg.vocab, seq_len=64, global_batch=4)
    tc = trainer.TrainConfig(steps=30, lr=1e-3, warmup=5, log_every=100,
                             remat="none")
    it = ((s, {"tokens": t, "labels": l})
          for s, (t, l) in pipeline.batches(dcfg))
    _, hist = trainer.run(cfg, tc, it)
    first = np.mean([h["loss"] for h in hist[:3]])
    last = np.mean([h["loss"] for h in hist[-3:]])
    assert last < first - 0.25, f"{first} -> {last}"


def test_training_with_microbatch_matches_full():
    cfg = get_config("qwen2-1.5b").reduced()
    dcfg = pipeline.DataConfig(vocab=cfg.vocab, seq_len=32, global_batch=4)

    def run(mb):
        tc = trainer.TrainConfig(steps=4, log_every=100, remat="none",
                                 microbatch=mb)
        it = ((s, {"tokens": t, "labels": l})
              for s, (t, l) in pipeline.batches(dcfg))
        state, hist = trainer.run(cfg, tc, it, key=jax.random.PRNGKey(3))
        return state, [h["loss"] for h in hist]

    s_full, l_full = run(0)
    s_mb, l_mb = run(2)
    np.testing.assert_allclose(l_full, l_mb, rtol=2e-2)
    for a, b in zip(jax.tree.leaves(s_full["params"]),
                    jax.tree.leaves(s_mb["params"])):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32),
                                   rtol=2e-2, atol=2e-3)


def test_serving_engine_sparse_vs_dense_agree():
    """With the TopK budget covering the whole context, the sparse decode
    must reproduce the dense trajectory exactly.  (At partial coverage and
    *random init* attention is diffuse — the worst case for TopK — so
    trajectory agreement is only asserted in the full-coverage regime;
    quality-at-coverage is studied in test_models.py.)"""
    import dataclasses
    cfg = get_config("tinyllama-1.1b").reduced()
    cfg = dataclasses.replace(cfg, kv_topk_pages=12)  # 48/4 pages: full
    key = jax.random.PRNGKey(0)
    params = api.init_params(cfg, key)
    from repro.configs.base import ShapeCell
    cell = ShapeCell("s", 32, 2, "prefill")
    batch = api.make_inputs(cfg, cell, key)
    out_d = Engine(cfg, params, max_len=48, sparse=False).generate(batch, 12)
    out_s = Engine(cfg, params, max_len=48, sparse=True).generate(batch, 12)
    agree = (out_d == out_s).mean()
    assert agree > 0.9, f"sparse/dense token agreement {agree}"


def test_serving_engine_nsb_stats():
    cfg = get_config("qwen2-1.5b").reduced()
    key = jax.random.PRNGKey(1)
    params = api.init_params(cfg, key)
    from repro.configs.base import ShapeCell
    cell = ShapeCell("s", 32, 2, "prefill")
    batch = api.make_inputs(cfg, cell, key)
    eng = Engine(cfg, params, max_len=64, sparse=True, nsb_pages=32)
    eng.generate(batch, 16)
    s = eng.stats
    assert s.pages_touched > 0
    assert 0.0 <= s.hot_hit_rate <= 1.0
    # decode TopK selections exhibit strong temporal reuse (the paper's
    # premise for the NSB)
    assert s.hot_hit_rate > 0.5


def test_benchmark_runner_exit_codes(monkeypatch, capsys, tmp_path):
    """benchmarks.run must exit non-zero when a named benchmark raises
    (CI smoke jobs depend on the failure propagating) and 2 on unknown
    names."""
    import os as _os
    import sys as _sys
    root = _os.path.dirname(_os.path.dirname(_os.path.abspath(__file__)))
    _sys.path.insert(0, root)
    try:
        from benchmarks import paper_figs, run
    finally:
        _sys.path.remove(root)

    def boom():
        raise RuntimeError("injected failure")

    def fine():
        return [("r", 1)], {"metric": 1.0}

    # artifacts (BENCH_fine.json) go to the canonical results dir —
    # point it at a tmpdir so the self-test never pollutes real results
    monkeypatch.setenv("BENCH_RESULTS_DIR", str(tmp_path))
    monkeypatch.setattr(paper_figs, "ALL", {"boom": boom, "fine": fine})
    assert run.main(["fine"]) == 0
    assert (tmp_path / "BENCH_fine.json").exists()
    assert run.main(["boom"]) == 1
    assert run.main(["boom", "fine"]) == 1      # keeps running the rest
    out = capsys.readouterr().out
    assert "boom,FAILED" in out and "fine," in out
    assert run.main(["nope"]) == 2


def test_sharding_rules_divisibility():
    """Every assigned arch's parameter specs divide evenly on the
    production mesh axes."""
    axes = {"data": 16, "model": 16}
    from repro.configs import ARCH_NAMES
    for arch in ARCH_NAMES:
        cfg = get_config(arch)
        specs = sharding.tree_param_specs(api.param_specs(cfg), axes)
        flat_p = jax.tree_util.tree_flatten_with_path(
            api.param_specs(cfg))[0]
        flat_s = jax.tree.leaves(specs,
                                 is_leaf=lambda x: isinstance(
                                     x, jax.sharding.PartitionSpec))
        for (path, leaf), spec in zip(flat_p, flat_s):
            for dim, s in zip(leaf.shape, spec):
                if s is None:
                    continue
                n = int(np.prod([axes[a] for a in
                                 ((s,) if isinstance(s, str) else s)]))
                assert dim % n == 0, (arch, path, leaf.shape, spec)


def test_constrain_is_noop_without_mesh():
    x = jnp.ones((4, 8))
    y = sharding.constrain(x, "batch", "mlp")
    np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


def test_grad_compression_wire_bytes():
    from repro.optim import compress
    grads = {"a": jnp.ones((1024,)), "b": jnp.ones((256, 256))}
    full = compress.wire_bytes(grads, compressed=False)
    comp = compress.wire_bytes(grads, compressed=True)
    assert comp < full / 1.9   # ~2x fewer wire bytes than bf16 (int8)
