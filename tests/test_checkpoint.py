"""Checkpoint save/restore, atomicity/GC, resharding, fault tolerance."""


import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.train import checkpoint, fault

_requires_explicit_sharding = pytest.mark.skipif(
    not hasattr(jax.sharding, "AxisType"),
    reason="needs the jax>=0.5 explicit-sharding API (AxisType/set_mesh); "
           "gated on older jax")


@pytest.fixture
def state():
    return {"params": {"w": jnp.arange(12.0).reshape(3, 4),
                       "layers": {"wq": jnp.ones((2, 4, 4))}},
            "opt": {"count": jnp.asarray(7, jnp.int32)}}


def test_save_restore_roundtrip(tmp_path, state):
    d = str(tmp_path)
    checkpoint.save(d, 5, state, extra={"note": "x"})
    restored, step, extra = checkpoint.restore(d, state)
    assert step == 5 and extra == {"note": "x"}
    for a, b in zip(jax.tree.leaves(state), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_gc_keeps_last_n(tmp_path, state):
    d = str(tmp_path)
    for s in range(6):
        checkpoint.save(d, s, state, keep=3)
    assert checkpoint.latest_steps(d) == [3, 4, 5]


def test_restore_latest_by_default(tmp_path, state):
    d = str(tmp_path)
    for s in (1, 9, 4):
        checkpoint.save(d, s, state)
    _, step, _ = checkpoint.restore(d, state)
    assert step == 9


def test_restore_missing_array_fails(tmp_path, state):
    d = str(tmp_path)
    checkpoint.save(d, 0, {"params": state["params"]})
    with pytest.raises(ValueError):
        checkpoint.restore(d, state)


@_requires_explicit_sharding
def test_restore_with_shardings_replaces_devices(tmp_path, state):
    """Elastic restore: same checkpoint re-placed under a (new) mesh."""
    d = str(tmp_path)
    checkpoint.save(d, 2, state)
    mesh = jax.make_mesh((1,), ("data",),
                         axis_types=(jax.sharding.AxisType.Auto,))
    sh = jax.tree.map(
        lambda _: jax.NamedSharding(mesh, jax.sharding.PartitionSpec()),
        state)
    restored, step, _ = checkpoint.restore(d, state, shardings=sh)
    assert step == 2
    for leaf in jax.tree.leaves(restored):
        assert isinstance(leaf, jax.Array)


class TestFault:
    def test_preemption_flag(self):
        h = fault.PreemptionHandler()
        assert not h.should_checkpoint_and_exit
        h.request()
        assert h.should_checkpoint_and_exit

    def test_watchdog_flags_stragglers(self, monkeypatch):
        w = fault.StragglerWatchdog(alpha=0.5, threshold=2.0)
        times = iter([0.0, 1.0, 1.0, 2.0, 2.0, 3.0, 3.0, 13.0])
        monkeypatch.setattr(fault.time, "monotonic", lambda: next(times))
        for step in range(4):
            w.start()
            w.stop(step)
        assert len(w.flagged) == 1
        assert w.flagged[0][0] == 3
        assert "re-dispatch" in w.mitigation_plan()

    def test_failure_injection_env(self, monkeypatch):
        monkeypatch.setenv("REPRO_FAIL_AT_STEP", "7")
        assert fault.should_inject_failure(7)
        assert not fault.should_inject_failure(6)


def test_train_restart_resumes_identically(tmp_path):
    """Train 6 steps with a checkpoint at 3; crash; resume; the final state
    equals an uninterrupted 6-step run (deterministic data by step)."""
    from repro.configs import get_config
    from repro.data import pipeline
    from repro.train import trainer

    cfg = get_config("qwen2-1.5b").reduced()
    dcfg = pipeline.DataConfig(vocab=cfg.vocab, seq_len=32, global_batch=2)

    def data():
        return ({"tokens": t, "labels": l} for _, (t, l)
                in pipeline.batches(dcfg))

    def data_iter():
        return ((s, {"tokens": t, "labels": l})
                for s, (t, l) in pipeline.batches(dcfg))

    tc = trainer.TrainConfig(steps=6, ckpt_every=3, log_every=100,
                             ckpt_dir=str(tmp_path / "a"), remat="none")
    state_a, hist_a = trainer.run(cfg, tc, data_iter(),
                                  key=jax.random.PRNGKey(1))

    # interrupted run: 3 steps, then a fresh process resumes from ckpt
    tc_b1 = trainer.TrainConfig(steps=3, ckpt_every=3, log_every=100,
                                ckpt_dir=str(tmp_path / "b"), remat="none")
    trainer.run(cfg, tc_b1, data_iter(), key=jax.random.PRNGKey(1))
    tc_b2 = trainer.TrainConfig(steps=6, ckpt_every=3, log_every=100,
                                ckpt_dir=str(tmp_path / "b"), remat="none")
    state_b, hist_b = trainer.run(cfg, tc_b2, data_iter(),
                                  key=jax.random.PRNGKey(1))
    la = [h["loss"] for h in hist_a if h["step"] >= 3]
    lb = [h["loss"] for h in hist_b]
    np.testing.assert_allclose(la, lb, rtol=1e-5)
    for a, b in zip(jax.tree.leaves(state_a["params"]),
                    jax.tree.leaves(state_b["params"])):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32), rtol=1e-5,
                                   atol=1e-6)
