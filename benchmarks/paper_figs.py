"""One benchmark per paper table/figure (NVR, DAC'25).

Each ``figN_*`` function runs the corresponding experiment on the
simulator / analytic model and returns (rows, headline-dict).  CSVs land in
benchmarks/results/.  ``BENCH_SCALE`` (default 0.5) controls trace sizes.
"""

from __future__ import annotations

import os
import statistics
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np

from repro.core import analytic
from repro.core.nvr import overhead, run_modes, simulate
from repro.core.nvr.engine.sweep import write_artifacts
from repro.core.nvr.traces import WORKLOADS, make_trace

SCALE = float(os.environ.get("BENCH_SCALE", "0.5"))
from .paths import results_dir
DTYPES = {"INT8": 1, "FP16": 2, "INT32": 4}


def _write(name: str, header: str, rows: list) -> str:
    """Persist one figure's rows as CSV + JSON via the shared sweep-runner
    artifact writer (benchmarks and sweeps share one artifact format)."""
    stem = name[:-4] if name.endswith(".csv") else name
    paths = write_artifacts(stem, header, rows, results_dir(), scale=SCALE)
    return paths["csv"]


def fig5_latency():
    """Fig. 5: normalised wall-clock per workload x mode x dtype (+NSB)."""
    rows = []
    stall_red = {d: [] for d in DTYPES}
    speedup = []
    nsb_red = []
    for dt_name, dtb in DTYPES.items():
        for wl in WORKLOADS:
            tr = make_trace(wl, dtype_bytes=dtb, scale=SCALE)
            rs = {r.label: r for r in run_modes(tr, dtb)}
            ino = rs["inorder"]
            for mode, r in rs.items():
                rows.append((wl, dt_name, mode, f"{r.total:.0f}",
                             f"{r.base:.0f}", f"{r.stall:.0f}",
                             f"{r.total / ino.total:.4f}"))
            if ino.stall:
                stall_red[dt_name].append(1 - rs["nvr"].stall / ino.stall)
            speedup.append(ino.total / rs["nvr"].total)
            if dt_name == "INT32":   # Fig. 5(a): NSB at INT32
                nvr_nsb = simulate(tr, "inorder", prefetcher="nvr",
                                   nsb_kb=16)
                rows.append((wl, dt_name, "nvr+nsb", f"{nvr_nsb.total:.0f}",
                             f"{nvr_nsb.base:.0f}", f"{nvr_nsb.stall:.0f}",
                             f"{nvr_nsb.total / ino.total:.4f}"))
                if rs["nvr"].stall:
                    nsb_red.append(1 - nvr_nsb.stall / rs["nvr"].stall)
    headline = {
        "stall_reduction_int8": statistics.mean(stall_red["INT8"]),
        "stall_reduction_fp16": statistics.mean(stall_red["FP16"]),
        "stall_reduction_int32": statistics.mean(stall_red["INT32"]),
        "speedup_vs_noprefetch_geomean": statistics.geometric_mean(speedup),
        "nsb_extra_stall_reduction": statistics.mean(nsb_red),
        "paper": "98.3%/99.2%/97.3% stall red.; ~4x speedup; NSB -40%",
    }
    _write("fig5_latency.csv",
           "workload,dtype,mode,total,base,stall,normalized", rows)
    return rows, headline


def fig6_prefetch():
    """Fig. 6: accuracy & coverage per prefetcher + off-chip reduction."""
    rows = []
    acc = {p: [] for p in ("stream", "imp", "dvr", "nvr")}
    cov = {p: [] for p in ("stream", "imp", "dvr", "nvr")}
    nvr_load_red, nsb_extra, miss_red_sota = [], [], []
    for wl in WORKLOADS:
        tr = make_trace(wl, dtype_bytes=2, scale=SCALE)
        rs = {r.label: r for r in run_modes(tr, 2)}
        ino = rs["inorder"]
        for p in acc:
            r = rs[p]
            if np.isfinite(r.accuracy):
                acc[p].append(r.accuracy)
            cov[p].append(max(0.0, r.coverage))
            rows.append((wl, p, f"{r.accuracy:.4f}", f"{r.coverage:.4f}",
                         f"{r.demand_offchip:.0f}"))
        if rs["nvr"].demand_offchip:
            nvr_load_red.append(ino.demand_offchip
                                / rs["nvr"].demand_offchip)
        nsb = simulate(tr, "inorder", prefetcher="nvr", nsb_kb=16)
        if nsb.demand_offchip:
            nsb_extra.append(rs["nvr"].demand_offchip / nsb.demand_offchip)
        best = min(rs["imp"].demand_misses, rs["dvr"].demand_misses)
        if best:
            miss_red_sota.append(1 - rs["nvr"].demand_misses / best)
    headline = {
        "nvr_accuracy_mean": statistics.mean(acc["nvr"]),
        "nvr_coverage_mean": statistics.mean(cov["nvr"]),
        "offchip_load_exec_reduction_x": statistics.median(nvr_load_red),
        "nsb_extra_reduction_x": statistics.geometric_mean(
            [max(x, 1.0) for x in nsb_extra]) if nsb_extra else 1.0,
        "miss_reduction_vs_best_sota": statistics.mean(miss_red_sota),
        "paper": ">90% acc/cov; 30x load-exec off-chip red., +5x NSB; ~90% "
                 "miss red. vs SOTA",
    }
    _write("fig6_prefetch.csv",
           "workload,prefetcher,accuracy,coverage,demand_offchip_bytes",
           rows)
    return rows, headline


def fig7_bandwidth():
    """Fig. 7: off-chip bandwidth (demand+prefetch) without/with NSB."""
    rows = []
    red, red_nsb = [], []
    for wl in WORKLOADS:
        tr = make_trace(wl, dtype_bytes=2, scale=SCALE)
        ino = simulate(tr, "inorder")
        nvr = simulate(tr, "inorder", prefetcher="nvr")
        nvr_nsb = simulate(tr, "inorder", prefetcher="nvr", nsb_kb=16)
        rows.append((wl, f"{ino.offchip:.0f}", f"{nvr.offchip:.0f}",
                     f"{nvr_nsb.offchip:.0f}"))
        red.append(1 - nvr.offchip / ino.offchip)
        red_nsb.append(1 - nvr_nsb.offchip / ino.offchip)
    headline = {
        "bandwidth_reduction_vs_ino": statistics.mean(red),
        "bandwidth_reduction_with_nsb": statistics.mean(red_nsb),
        "paper": "~75% off-chip bandwidth reduction vs InO",
    }
    _write("fig7_bandwidth.csv",
           "workload,ino_bytes,nvr_bytes,nvr_nsb_bytes", rows)
    return rows, headline


def fig8_llm_system():
    """Fig. 8: LLM prefill/decode throughput vs bandwidth (analytic)."""
    rows = analytic.fig8_sweep()
    gains = [nvr / base for stage, _, _, base, nvr in rows
             if stage == "decode"]
    pre = [nvr / base for stage, _, bw, base, nvr in rows
           if stage == "prefill" and bw <= 100]
    headline = {
        "decode_throughput_gain_mean": statistics.mean(gains),
        "prefill_gain_lowbw_mean": statistics.mean(pre),
        "paper": "avg +50% decode (IO-bound) throughput",
    }
    _write("fig8_llm_system.csv",
           "stage,seq,bw_GBs,tok_s_base,tok_s_nvr",
           [(s, q, f"{b:.0f}", f"{x:.1f}", f"{y:.1f}")
            for s, q, b, x, y in rows])
    return rows, headline


def fig9_nsb_sensitivity():
    """Fig. 9: NSB-vs-L2 scaling at equal area (perf = 1/latency/area)."""
    rows = []
    workloads = ["DS", "GAT", "MK", "H2O"]
    # paper metric: perf = 1/(latency x NSB_KB x L2_KB); note that
    # (256,16) and (1024,4) have EQUAL area products, so the comparison
    # reduces to which quadrupling cuts latency more
    configs = [(256, 4), (256, 8), (256, 16), (512, 4), (1024, 4)]
    lat = {}
    for l2, nsb in configs:
        tot = []
        for wl in workloads:
            tr = make_trace(wl, dtype_bytes=4, scale=SCALE)
            r = simulate(tr, "inorder", prefetcher="nvr", l2_kb=l2,
                         nsb_kb=nsb)
            tot.append(r.total)
        lat[(l2, nsb)] = statistics.geometric_mean(tot)
        p = 1e9 / (lat[(l2, nsb)] * l2 * nsb)
        rows.append((l2, nsb, f"{lat[(l2, nsb)]:.0f}", f"{p:.4f}"))
    nsb_gain = lat[(256, 4)] / lat[(256, 16)] - 1
    l2_gain = lat[(256, 4)] / lat[(1024, 4)] - 1
    headline = {
        "nsb_4to16k_latency_gain": nsb_gain,
        "l2_256to1024k_latency_gain": l2_gain,
        "nsb_vs_l2_advantage_x": (nsb_gain / l2_gain) if l2_gain > 0
        else float("inf"),
        "paper": "4x NSB beats 4x L2 by ~5x at equal area product",
    }
    _write("fig9_nsb_sensitivity.csv", "l2_kb,nsb_kb,geomean_cycles,"
           "perf_per_area", rows)
    return rows, headline


def ablation_nvr():
    """BEYOND-PAPER: component ablation the paper does not include.

    Quantifies each NVR component's contribution by disabling it:
    SCD (indirect-chain resolution), LBD (boundary knowledge), VMIG
    (vectorised issue), fuzzy fetch, and the runahead-depth sensitivity.
    """
    variants = {
        "full": {},
        "no_scd": {"scd": False},
        "no_lbd": {"lbd": False},
        "no_vmig": {"vmig": False},
        "no_fuzzy": {"fuzzy_every": 0},
        "depth_8": {"depth": 8},
        "depth_24": {"depth": 24},
        "depth_48": {"depth": 48},
    }
    rows = []
    agg = {v: [] for v in variants}
    for wl in WORKLOADS:
        tr = make_trace(wl, dtype_bytes=2, scale=SCALE)
        ino = simulate(tr, "inorder")
        for vname, kw in variants.items():
            r = simulate(tr, "inorder", prefetcher="nvr", pf_kwargs=kw)
            sp = ino.total / r.total
            agg[vname].append(sp)
            rows.append((wl, vname, f"{r.total:.0f}", f"{r.demand_misses}",
                         f"{sp:.3f}"))
    gm = {v: statistics.geometric_mean(s) for v, s in agg.items()}
    headline = {
        "speedup_full": gm["full"],
        "speedup_no_scd": gm["no_scd"],
        "speedup_no_lbd": gm["no_lbd"],
        "speedup_no_vmig": gm["no_vmig"],
        "speedup_no_fuzzy": gm["no_fuzzy"],
        "speedup_depth8": gm["depth_8"],
        "paper": "(beyond-paper ablation) SCD is the load-bearing "
                 "component; depth saturates by ~48",
    }
    _write("ablation_nvr.csv",
           "workload,variant,total_cycles,demand_misses,speedup_vs_ino",
           rows)
    return rows, headline


def table1_overhead():
    rows = [(s.name, s.n, s.bits, s.paper_bits)
            for s in overhead.table1()]
    total = sum(r[2] for r in rows)
    headline = {
        "field_sum_kib": total / 8192,
        "paper_headline_kib": overhead.PAPER_TOTAL_KIB,
        "paper": "9.72 KiB control state (+16 KiB optional NSB)",
    }
    _write("table1_overhead.csv", "structure,N,field_sum_bits,paper_bits",
           rows)
    return rows, headline


def engine_speedup():
    """Tentpole acceptance: the full Fig. 5 mode sweep (8 workloads x 7
    modes) on the event-driven engine vs the frozen seed per-op/per-line
    ``simulate()`` loop (``engine/reference.py``), with bit-exact result
    parity asserted on every row.

    ``cold`` includes the one-time structure-of-arrays trace compilation;
    ``steady`` is the best of two sweeps (the compile is cached on the
    trace and shared by all mode/prefetcher runs — that amortisation is
    the design, not a benchmarking artifact).
    """
    import gc
    import time

    from repro.core.nvr.engine.reference import run_modes_reference

    traces_ref = {wl: make_trace(wl, dtype_bytes=2, scale=SCALE)
                  for wl in WORKLOADS}
    traces_eng = {wl: make_trace(wl, dtype_bytes=2, scale=SCALE)
                  for wl in WORKLOADS}
    gc.disable()  # timeit convention: measure the loops, not the collector
    try:
        t0 = time.perf_counter()
        ref = {wl: run_modes_reference(tr, 2)
               for wl, tr in traces_ref.items()}
        t_ref = time.perf_counter() - t0

        t_cold = t_steady = float("inf")
        eng = {}
        for rep in range(3):
            t0 = time.perf_counter()
            eng = {wl: run_modes(tr, 2) for wl, tr in traces_eng.items()}
            dt = time.perf_counter() - t0
            if rep == 0:
                t_cold = dt
            t_steady = min(t_steady, dt)
    finally:
        gc.enable()

    rows = []
    parity = True
    for wl in WORKLOADS:
        for a, b in zip(eng[wl], ref[wl]):
            same = (a.total == b.total
                    and a.demand_misses == b.demand_misses
                    and a.pf_issued == b.pf_issued
                    and a.pf_used == b.pf_used)
            parity &= same
            rows.append((wl, a.label, f"{a.total:.0f}", f"{b.total:.0f}",
                         int(same)))
    # the CI smoke step runs this benchmark: a parity regression must
    # fail loudly, not just flip a float in the artifact
    assert parity, "engine/reference divergence — see engine_speedup.csv"
    headline = {
        "seed_loop_s": t_ref,
        "engine_cold_s": t_cold,
        "engine_steady_s": t_steady,
        "speedup_cold_x": t_ref / t_cold,
        "speedup_x": t_ref / t_steady,
        "parity_ok": float(parity),
        "paper": "(engineering) 5x sweep target; measured ~4.5-5x on this "
                 "1-core-quota container, bit-exact vs seed loop",
    }
    _write("engine_speedup.csv",
           "workload,label,engine_total,seed_total,parity", rows)
    return rows, headline


def sweep_grid():
    """Full grid through the sweep runner: workload x dtype x point x
    nsb_kb, CSV + JSON artifacts in benchmarks/results/."""
    import time

    from repro.core.nvr import SweepSpec, run_sweep
    from repro.core.nvr.engine.sweep import write_sweep

    spec = SweepSpec(dtypes=(1, 2, 4), nsb_kbs=(0, 16), scale=SCALE)
    t0 = time.perf_counter()
    result = run_sweep(spec)
    dt = time.perf_counter() - t0
    write_sweep(result, results_dir(), name="sweep_grid", scale=SCALE)
    import statistics as _st
    sp = [ino.total / nvr.total for ino, nvr in zip(
        (r for r in result.rows if r.label == "inorder"),
        (r for r in result.rows if r.label == "nvr"))]
    headline = {
        "grid_points": float(len(result.rows)),
        "sweep_s": dt,
        "nvr_speedup_geomean": _st.geometric_mean(sp),
        "paper": "~4x speedup across Table II / dtypes / NSB",
    }
    rows = [(r.workload, r.dtype_bytes, r.nsb_kb, r.label,
             f"{r.total:.0f}") for r in result.rows]
    return rows, headline


def capture_roundtrip():
    """Acceptance: capture -> simulate round trip.  A real serving-engine
    decode run (TopK sparse-KV) is recorded by the capture adapters and
    replayed through the full Fig. 5 mode set; NVR must cut demand misses
    vs the in-order baseline on the *captured* traffic.  Also replays an
    MoE routing decision through the expert-tile adapter."""
    import jax
    import numpy as np

    from repro.configs import get_config
    from repro.configs.base import ShapeCell
    from repro.core.nvr import capture
    from repro.models import api
    from repro.serve.engine import Engine

    cfg = get_config("qwen2-1.5b").reduced()
    key = jax.random.PRNGKey(0)
    params = api.init_params(cfg, key)
    batch = api.make_inputs(cfg, ShapeCell("bench", 32, 2, "prefill"), key)
    eng = Engine(cfg, params, max_len=64, sparse=True, nsb_pages=32,
                 capture_trace=True)
    eng.generate(batch, 12)
    serve_rs = {r.label: r for r in run_modes(eng.captured_trace(), 2)}

    rng = np.random.default_rng(0)
    eids = rng.choice(8, p=[.35, .25, .15, .10, .06, .04, .03, .02],
                      size=max(64, int(512 * SCALE)))
    moe = capture.moe_expert_stream(eids, n_experts=8, d_model=128,
                                    d_ff=256)
    moe_rs = {r.label: r for r in run_modes(moe.to_trace(), 2)}

    rows = []
    for src, rs in (("serve_kv", serve_rs), ("moe_route", moe_rs)):
        for label in ("inorder", "ooo", "stream", "imp", "dvr", "nvr"):
            r = rs[label]
            rows.append((src, label, f"{r.total:.0f}", r.demand_misses,
                         f"{rs['inorder'].total / r.total:.3f}"))
    headline = {
        "serve_nvr_miss_reduction": 1 - (serve_rs["nvr"].demand_misses
                                         / serve_rs["inorder"].demand_misses),
        "serve_nvr_speedup": (serve_rs["inorder"].total
                              / serve_rs["nvr"].total),
        "serve_nsb_hot_hit_rate": eng.stats.hot_hit_rate,
        "moe_nvr_miss_reduction": 1 - (moe_rs["nvr"].demand_misses
                                       / moe_rs["inorder"].demand_misses),
        "paper": "Fig. 8 decode story on *captured* serving traffic",
    }
    _write("capture_roundtrip.csv",
           "source,label,total,demand_misses,speedup_vs_inorder", rows)
    return rows, headline


def serve_bench():
    """Continuous-batching Poisson load vs the single-batch baseline
    (defined in benchmarks/serve_bench.py; imported lazily so the numpy-
    only figures stay importable without jax)."""
    from .serve_bench import serve_bench as _sb
    return _sb()


def prefix_bench():
    """Shared-prefix multi-tenant serving with vs without the COW prefix
    cache (defined in benchmarks/serve_bench.py; lazy import as above)."""
    from .serve_bench import prefix_bench as _pb
    return _pb()


def paged_kernel_bench():
    """Donated + bucketed paged-decode step loop vs the pre-PR path,
    with Pallas-kernel/XLA parity asserted in the same run (defined in
    benchmarks/paged_kernel_bench.py; lazy import as above)."""
    from .paged_kernel_bench import paged_kernel_bench as _pk
    return _pk()


def tp_serve_bench():
    """Tensor-parallel paged serving: tokens/s at tp=1/2/4 over the
    KV-head-sharded pool, bitwise cross-tp parity + pool donation
    asserted in-run (defined in benchmarks/serve_bench.py; lazy import
    as above; sharded levels need forced host devices)."""
    from .serve_bench import tp_serve_bench as _tp
    return _tp()


def runahead_bench():
    """Online vector runahead (off / imp / nvr) on shared-prefix Poisson
    serving: bitwise token/logit parity across modes, NSB hit-rate lift
    over the demand-LRU no-runahead tier, prediction accuracy/coverage/
    over-fetch, modeled memory-stall throughput gain (defined in
    benchmarks/serve_bench.py; lazy import as above)."""
    from .serve_bench import runahead_bench as _ra
    return _ra()


def spill_bench():
    """Host KV spill tier under pool oversubscription: preemption as
    swap-out vs free-and-recompute, runahead fetch-back, int8 spill
    compression — bitwise token/logit parity recompute=swap=swap+ra and
    resume-TTFT improvement asserted in-run (defined in
    benchmarks/serve_bench.py; lazy import as above)."""
    from .serve_bench import spill_bench as _sp
    return _sp()


def overlap_bench():
    """Pipelined executor vs the synchronous step loop under mixed
    long-prefill/steady-decode load: bitwise token/logit parity and an
    identical iteration log asserted in-run, TTFT/TPOT split per stream,
    modeled p99 TPOT improvement from overlapping the streams (defined
    in benchmarks/serve_bench.py; lazy import as above)."""
    from .serve_bench import overlap_bench as _ov
    return _ov()


def workload_bench():
    """The scheduling-policy layer under a bursty multi-tenant
    multi-turn trace: slo_fair vs fifo on SLO attainment and p99 TTFT,
    with per-(item, turn) token/logit bitwise parity against a
    never-swapped run and the NSB/runahead hit rate re-measured under
    realistic locality (defined in benchmarks/serve_bench.py; lazy
    import as above)."""
    from .serve_bench import workload_bench as _wb
    return _wb()


def moe_serve_bench():
    """Paged expert-weight streaming on a live MoE serve load: expert
    tiles as pages with router-keyed runahead staging the predicted
    tiles — bitwise token/logit parity dense=paged=paged+router (and
    tp=2) asserted in-run, expert-tile NSB hit-rate lift over the
    demand-LRU baseline, modeled stall gain (defined in
    benchmarks/serve_bench.py; lazy import as above)."""
    from .serve_bench import moe_serve_bench as _ms
    return _ms()


ALL = {
    "fig5_latency": fig5_latency,
    "fig6_prefetch": fig6_prefetch,
    "fig7_bandwidth": fig7_bandwidth,
    "fig8_llm_system": fig8_llm_system,
    "fig9_nsb_sensitivity": fig9_nsb_sensitivity,
    "table1_overhead": table1_overhead,
    "ablation_nvr": ablation_nvr,     # beyond-paper component ablation
    "engine_speedup": engine_speedup,  # engine vs frozen seed loop
    "sweep_grid": sweep_grid,          # grid sweep runner + artifacts
    "capture_roundtrip": capture_roundtrip,  # serve/MoE capture -> sim
    "serve_bench": serve_bench,        # continuous batching vs lockstep
    "prefix_bench": prefix_bench,      # COW prefix cache on/off
    "paged_kernel_bench": paged_kernel_bench,  # donated+bucketed decode
    "tp_serve_bench": tp_serve_bench,  # KV-head-sharded TP serving
    "runahead_bench": runahead_bench,  # online runahead off/imp/nvr
    "spill_bench": spill_bench,        # host spill swap vs recompute
    "overlap_bench": overlap_bench,    # pipelined vs sync executor
    "moe_serve_bench": moe_serve_bench,  # paged expert tiles + router RA
    "workload_bench": workload_bench,  # policy layer on realistic trace
}
