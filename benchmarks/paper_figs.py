"""One benchmark per paper table/figure (NVR, DAC'25).

Each ``figN_*`` function runs the corresponding experiment on the
simulator / analytic model and returns (rows, headline-dict).  CSVs land in
benchmarks/results/.  ``BENCH_SCALE`` (default 0.5) controls trace sizes.
"""

from __future__ import annotations

import os
import statistics
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np

from repro.core import analytic
from repro.core.nvr import overhead, run_modes, simulate
from repro.core.nvr.traces import WORKLOADS, make_trace

SCALE = float(os.environ.get("BENCH_SCALE", "0.5"))
RESULTS = os.path.join(os.path.dirname(__file__), "results")
DTYPES = {"INT8": 1, "FP16": 2, "INT32": 4}


def _write(name: str, header: str, rows: list) -> str:
    os.makedirs(RESULTS, exist_ok=True)
    path = os.path.join(RESULTS, name)
    with open(path, "w") as f:
        f.write(header + "\n")
        for r in rows:
            f.write(",".join(str(x) for x in r) + "\n")
    return path


def fig5_latency():
    """Fig. 5: normalised wall-clock per workload x mode x dtype (+NSB)."""
    rows = []
    stall_red = {d: [] for d in DTYPES}
    speedup = []
    nsb_red = []
    for dt_name, dtb in DTYPES.items():
        for wl in WORKLOADS:
            tr = make_trace(wl, dtype_bytes=dtb, scale=SCALE)
            rs = {r.mode: r for r in run_modes(tr, dtb)}
            ino = rs["inorder"]
            for mode, r in rs.items():
                rows.append((wl, dt_name, mode, f"{r.total:.0f}",
                             f"{r.base:.0f}", f"{r.stall:.0f}",
                             f"{r.total / ino.total:.4f}"))
            if ino.stall:
                stall_red[dt_name].append(1 - rs["nvr"].stall / ino.stall)
            speedup.append(ino.total / rs["nvr"].total)
            if dt_name == "INT32":   # Fig. 5(a): NSB at INT32
                nvr_nsb = simulate(tr, "inorder", prefetcher="nvr",
                                   nsb_kb=16)
                rows.append((wl, dt_name, "nvr+nsb", f"{nvr_nsb.total:.0f}",
                             f"{nvr_nsb.base:.0f}", f"{nvr_nsb.stall:.0f}",
                             f"{nvr_nsb.total / ino.total:.4f}"))
                if rs["nvr"].stall:
                    nsb_red.append(1 - nvr_nsb.stall / rs["nvr"].stall)
    headline = {
        "stall_reduction_int8": statistics.mean(stall_red["INT8"]),
        "stall_reduction_fp16": statistics.mean(stall_red["FP16"]),
        "stall_reduction_int32": statistics.mean(stall_red["INT32"]),
        "speedup_vs_noprefetch_geomean": statistics.geometric_mean(speedup),
        "nsb_extra_stall_reduction": statistics.mean(nsb_red),
        "paper": "98.3%/99.2%/97.3% stall red.; ~4x speedup; NSB -40%",
    }
    _write("fig5_latency.csv",
           "workload,dtype,mode,total,base,stall,normalized", rows)
    return rows, headline


def fig6_prefetch():
    """Fig. 6: accuracy & coverage per prefetcher + off-chip reduction."""
    rows = []
    acc = {p: [] for p in ("stream", "imp", "dvr", "nvr")}
    cov = {p: [] for p in ("stream", "imp", "dvr", "nvr")}
    nvr_load_red, nsb_extra, miss_red_sota = [], [], []
    for wl in WORKLOADS:
        tr = make_trace(wl, dtype_bytes=2, scale=SCALE)
        rs = {r.mode: r for r in run_modes(tr, 2)}
        ino = rs["inorder"]
        for p in acc:
            r = rs[p]
            if np.isfinite(r.accuracy):
                acc[p].append(r.accuracy)
            cov[p].append(max(0.0, r.coverage))
            rows.append((wl, p, f"{r.accuracy:.4f}", f"{r.coverage:.4f}",
                         f"{r.demand_offchip:.0f}"))
        if rs["nvr"].demand_offchip:
            nvr_load_red.append(ino.demand_offchip
                                / rs["nvr"].demand_offchip)
        nsb = simulate(tr, "inorder", prefetcher="nvr", nsb_kb=16)
        if nsb.demand_offchip:
            nsb_extra.append(rs["nvr"].demand_offchip / nsb.demand_offchip)
        best = min(rs["imp"].demand_misses, rs["dvr"].demand_misses)
        if best:
            miss_red_sota.append(1 - rs["nvr"].demand_misses / best)
    headline = {
        "nvr_accuracy_mean": statistics.mean(acc["nvr"]),
        "nvr_coverage_mean": statistics.mean(cov["nvr"]),
        "offchip_load_exec_reduction_x": statistics.median(nvr_load_red),
        "nsb_extra_reduction_x": statistics.geometric_mean(
            [max(x, 1.0) for x in nsb_extra]) if nsb_extra else 1.0,
        "miss_reduction_vs_best_sota": statistics.mean(miss_red_sota),
        "paper": ">90% acc/cov; 30x load-exec off-chip red., +5x NSB; ~90% "
                 "miss red. vs SOTA",
    }
    _write("fig6_prefetch.csv",
           "workload,prefetcher,accuracy,coverage,demand_offchip_bytes",
           rows)
    return rows, headline


def fig7_bandwidth():
    """Fig. 7: off-chip bandwidth (demand+prefetch) without/with NSB."""
    rows = []
    red, red_nsb = [], []
    for wl in WORKLOADS:
        tr = make_trace(wl, dtype_bytes=2, scale=SCALE)
        ino = simulate(tr, "inorder")
        nvr = simulate(tr, "inorder", prefetcher="nvr")
        nvr_nsb = simulate(tr, "inorder", prefetcher="nvr", nsb_kb=16)
        rows.append((wl, f"{ino.offchip:.0f}", f"{nvr.offchip:.0f}",
                     f"{nvr_nsb.offchip:.0f}"))
        red.append(1 - nvr.offchip / ino.offchip)
        red_nsb.append(1 - nvr_nsb.offchip / ino.offchip)
    headline = {
        "bandwidth_reduction_vs_ino": statistics.mean(red),
        "bandwidth_reduction_with_nsb": statistics.mean(red_nsb),
        "paper": "~75% off-chip bandwidth reduction vs InO",
    }
    _write("fig7_bandwidth.csv",
           "workload,ino_bytes,nvr_bytes,nvr_nsb_bytes", rows)
    return rows, headline


def fig8_llm_system():
    """Fig. 8: LLM prefill/decode throughput vs bandwidth (analytic)."""
    rows = analytic.fig8_sweep()
    gains = [nvr / base for stage, _, _, base, nvr in rows
             if stage == "decode"]
    pre = [nvr / base for stage, _, bw, base, nvr in rows
           if stage == "prefill" and bw <= 100]
    headline = {
        "decode_throughput_gain_mean": statistics.mean(gains),
        "prefill_gain_lowbw_mean": statistics.mean(pre),
        "paper": "avg +50% decode (IO-bound) throughput",
    }
    _write("fig8_llm_system.csv",
           "stage,seq,bw_GBs,tok_s_base,tok_s_nvr",
           [(s, q, f"{b:.0f}", f"{x:.1f}", f"{y:.1f}")
            for s, q, b, x, y in rows])
    return rows, headline


def fig9_nsb_sensitivity():
    """Fig. 9: NSB-vs-L2 scaling at equal area (perf = 1/latency/area)."""
    rows = []
    workloads = ["DS", "GAT", "MK", "H2O"]
    # paper metric: perf = 1/(latency x NSB_KB x L2_KB); note that
    # (256,16) and (1024,4) have EQUAL area products, so the comparison
    # reduces to which quadrupling cuts latency more
    configs = [(256, 4), (256, 8), (256, 16), (512, 4), (1024, 4)]
    lat = {}
    for l2, nsb in configs:
        tot = []
        for wl in workloads:
            tr = make_trace(wl, dtype_bytes=4, scale=SCALE)
            r = simulate(tr, "inorder", prefetcher="nvr", l2_kb=l2,
                         nsb_kb=nsb)
            tot.append(r.total)
        lat[(l2, nsb)] = statistics.geometric_mean(tot)
        p = 1e9 / (lat[(l2, nsb)] * l2 * nsb)
        rows.append((l2, nsb, f"{lat[(l2, nsb)]:.0f}", f"{p:.4f}"))
    nsb_gain = lat[(256, 4)] / lat[(256, 16)] - 1
    l2_gain = lat[(256, 4)] / lat[(1024, 4)] - 1
    headline = {
        "nsb_4to16k_latency_gain": nsb_gain,
        "l2_256to1024k_latency_gain": l2_gain,
        "nsb_vs_l2_advantage_x": (nsb_gain / l2_gain) if l2_gain > 0
        else float("inf"),
        "paper": "4x NSB beats 4x L2 by ~5x at equal area product",
    }
    _write("fig9_nsb_sensitivity.csv", "l2_kb,nsb_kb,geomean_cycles,"
           "perf_per_area", rows)
    return rows, headline


def ablation_nvr():
    """BEYOND-PAPER: component ablation the paper does not include.

    Quantifies each NVR component's contribution by disabling it:
    SCD (indirect-chain resolution), LBD (boundary knowledge), VMIG
    (vectorised issue), fuzzy fetch, and the runahead-depth sensitivity.
    """
    variants = {
        "full": {},
        "no_scd": {"scd": False},
        "no_lbd": {"lbd": False},
        "no_vmig": {"vmig": False},
        "no_fuzzy": {"fuzzy_every": 0},
        "depth_8": {"depth": 8},
        "depth_24": {"depth": 24},
        "depth_48": {"depth": 48},
    }
    rows = []
    agg = {v: [] for v in variants}
    for wl in WORKLOADS:
        tr = make_trace(wl, dtype_bytes=2, scale=SCALE)
        ino = simulate(tr, "inorder")
        for vname, kw in variants.items():
            r = simulate(tr, "inorder", prefetcher="nvr", pf_kwargs=kw)
            sp = ino.total / r.total
            agg[vname].append(sp)
            rows.append((wl, vname, f"{r.total:.0f}", f"{r.demand_misses}",
                         f"{sp:.3f}"))
    gm = {v: statistics.geometric_mean(s) for v, s in agg.items()}
    headline = {
        "speedup_full": gm["full"],
        "speedup_no_scd": gm["no_scd"],
        "speedup_no_lbd": gm["no_lbd"],
        "speedup_no_vmig": gm["no_vmig"],
        "speedup_no_fuzzy": gm["no_fuzzy"],
        "speedup_depth8": gm["depth_8"],
        "paper": "(beyond-paper ablation) SCD is the load-bearing "
                 "component; depth saturates by ~48",
    }
    _write("ablation_nvr.csv",
           "workload,variant,total_cycles,demand_misses,speedup_vs_ino",
           rows)
    return rows, headline


def table1_overhead():
    rows = [(s.name, s.n, s.bits, s.paper_bits)
            for s in overhead.table1()]
    total = sum(r[2] for r in rows)
    headline = {
        "field_sum_kib": total / 8192,
        "paper_headline_kib": overhead.PAPER_TOTAL_KIB,
        "paper": "9.72 KiB control state (+16 KiB optional NSB)",
    }
    _write("table1_overhead.csv", "structure,N,field_sum_bits,paper_bits",
           rows)
    return rows, headline


ALL = {
    "fig5_latency": fig5_latency,
    "fig6_prefetch": fig6_prefetch,
    "fig7_bandwidth": fig7_bandwidth,
    "fig8_llm_system": fig8_llm_system,
    "fig9_nsb_sensitivity": fig9_nsb_sensitivity,
    "table1_overhead": table1_overhead,
    "ablation_nvr": ablation_nvr,     # beyond-paper component ablation
}
