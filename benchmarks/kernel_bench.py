"""TPU-layer kernel benchmark: runahead kernels vs their XLA-path oracles.

On this CPU container the Pallas kernels run in interpret mode (Python) —
wall-clock is meaningless for them — so this bench reports (a) oracle
XLA-path wall time (a real number on CPU), (b) the kernel's structural
roofline: bytes moved per call vs the dense alternative, i.e. the
NVR-mechanism win the dry-run measures at model scale.

  PYTHONPATH=src python -m benchmarks.kernel_bench
"""

from __future__ import annotations

import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ref


def timeit(fn, *args, n=20):
    fn(*args)[0].block_until_ready() if isinstance(fn(*args), tuple) else \
        jax.block_until_ready(fn(*args))
    t0 = time.perf_counter()
    for _ in range(n):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / n * 1e6


def main():
    rng = np.random.default_rng(0)
    rows = []

    # sparse_decode_attn: TopK pages vs dense attention over the cache
    b, hkv, g, d, s, p, page = 4, 4, 8, 128, 4096, 16, 16
    q = jnp.asarray(rng.normal(size=(b, hkv, g, d)), jnp.bfloat16)
    k = jnp.asarray(rng.normal(size=(b, s, hkv, d)), jnp.bfloat16)
    v = jnp.asarray(rng.normal(size=(b, s, hkv, d)), jnp.bfloat16)
    idx = jnp.asarray(rng.integers(0, s // page, (b, hkv, p)), jnp.int32)
    sparse_fn = jax.jit(lambda i, q_, k_, v_: ref.sparse_decode_attn_ref(
        i, q_, k_, v_, page_size=page))
    us_sparse = timeit(sparse_fn, idx, q, k, v)

    def dense_attn(q_, k_, v_):
        sc = jnp.einsum("bkgd,bskd->bkgs", q_.astype(jnp.float32),
                        k_.astype(jnp.float32)) / (d ** 0.5)
        w = jax.nn.softmax(sc, axis=-1)
        return jnp.einsum("bkgs,bskd->bkgd", w, v_.astype(jnp.float32))
    us_dense = timeit(jax.jit(dense_attn), q, k, v)
    bytes_sparse = b * hkv * p * page * d * 2 * 2
    bytes_dense = b * s * hkv * d * 2 * 2
    rows.append(("sparse_decode_attn", us_sparse,
                 f"dense_us={us_dense:.0f};kv_bytes_ratio="
                 f"{bytes_dense / bytes_sparse:.1f}x"))

    # paged sparse decode on the serve layer's block-table layout: the
    # same TopK computation as above, but on the [P,page,KV,D] physical
    # pool + per-request block tables the continuous-batching engine
    # actually produces (contiguous [B,S,KV,D] never exists there) — so
    # kernel numbers and serve_bench numbers are comparable
    from repro.models import sparse_attention

    r, nl, pp = 8, s // page, 1 + 8 * (s // page)
    kpool = jnp.asarray(rng.normal(size=(pp, page, hkv, d)), jnp.bfloat16)
    vpool = jnp.asarray(rng.normal(size=(pp, page, hkv, d)), jnp.bfloat16)
    spool = jnp.asarray(rng.normal(size=(pp, hkv, d)), jnp.float32)
    bt = np.stack([rng.choice(np.arange(1, pp), size=nl, replace=False)
                   for _ in range(r)])
    qr = jnp.asarray(rng.normal(size=(r, hkv, g, d)), jnp.float32)
    pos = jnp.asarray(rng.integers(page, nl * page, size=r), jnp.int32)
    n_valid = pos // page + 1
    idx_bt, phys = sparse_attention.select_pages_blocktable(
        qr, spool, jnp.asarray(bt), n_valid, p)

    paged_fn = jax.jit(lambda q_, k_, v_, i_, ph_, po_:
                       sparse_attention.attend_pages_paged(
                           q_, k_, v_, i_, ph_, po_, page))
    us_paged = timeit(paged_fn, qr, kpool, vpool, idx_bt, phys, pos)
    # structural run + parity of the Pallas paged kernel on this layout
    from repro.kernels import paged_decode_attn
    got = paged_decode_attn(phys, idx_bt, pos, qr, kpool, vpool,
                            page_size=page, interpret=True)
    want = paged_fn(qr, kpool, vpool, idx_bt, phys, pos)
    err = float(np.abs(np.asarray(got, np.float32)
                       - np.asarray(want, np.float32)).max())
    assert err < 1e-5, f"paged kernel parity: {err}"
    bytes_paged = r * hkv * p * page * d * 2 * 2
    rows.append(("paged_decode_attn", us_paged,
                 f"layout=blocktable_pool;pallas_parity_err={err:.1e};"
                 f"kv_bytes_ratio={bytes_dense / bytes_paged:.1f}x"))

    # gather_spmm: ELL sparse vs dense matmul
    m, j, nin, n = 256, 16, 1024, 1024
    cols = jnp.asarray(rng.integers(0, nin, (m, j)), jnp.int32)
    vals = jnp.asarray(rng.normal(size=(m, j)), jnp.float32)
    dense = jnp.asarray(rng.normal(size=(nin, n)), jnp.float32)
    us_spmm = timeit(jax.jit(ref.gather_spmm_ref), cols, vals, dense)
    wd = jnp.asarray(rng.normal(size=(m, nin)), jnp.float32)
    us_mm = timeit(jax.jit(lambda a, b_: a @ b_), wd, dense)
    rows.append(("gather_spmm", us_spmm,
                 f"dense_matmul_us={us_mm:.0f};"
                 f"flops_ratio={nin / j:.0f}x_fewer"))

    # moe grouped GEMM vs dense all-experts
    t, dm, e, f, bt = 512, 256, 8, 512, 64
    x = jnp.asarray(rng.normal(size=(t, dm)), jnp.bfloat16)
    w = jnp.asarray(rng.normal(size=(e, dm, f)), jnp.bfloat16)
    gids = jnp.asarray(rng.integers(0, e, t // bt), jnp.int32)
    us_moe = timeit(jax.jit(lambda g_, x_, w_: ref.moe_dispatch_matmul_ref(
        g_, x_, w_, block_t=bt)), gids, x, w)
    us_all = timeit(jax.jit(lambda x_, w_: jnp.einsum("td,edf->etf", x_, w_)),
                    x, w)
    rows.append(("moe_dispatch_matmul", us_moe,
                 f"all_experts_us={us_all:.0f};compute_ratio={e}x_fewer"))

    print("name,us_per_call,derived")
    for name, us, derived in rows:
        print(f"{name},{us:.0f},{derived}")
    from repro.core.nvr.engine.sweep import write_artifacts

    from .paths import results_dir
    paths = write_artifacts(
        "kernel_bench", "name,us_per_call,derived",
        [(n, f"{us:.0f}", d) for n, us, d in rows],
        results_dir(),
        backend=jax.default_backend())
    print(f"# artifacts: {paths['csv']} {paths['json']}")


if __name__ == "__main__":
    main()
